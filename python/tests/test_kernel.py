"""Kernel-vs-oracle correctness: the CORE build-time signal.

Hypothesis sweeps shapes (and a couple of dtypes) of the Pallas blocked
GEMM and fused attention against the pure-jnp references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention_pallas import attention, mha_from_packed
from compile.kernels.gemm_pallas import gemm, vmem_footprint_bytes, tpu_tiles
from compile.kernels.ref import attention_ref, gemm_ref

RTOL = 1e-5
ATOL = 1e-5


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


class TestGemm:
    def test_exact_small(self):
        a = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
        b = jnp.eye(4, dtype=jnp.float32)
        np.testing.assert_allclose(gemm(a, b), a, rtol=RTOL)

    def test_tile_multiple_shapes(self):
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        a = rand(k1, (32, 64))
        b = rand(k2, (64, 48))
        np.testing.assert_allclose(gemm(a, b), gemm_ref(a, b), rtol=1e-4, atol=1e-4)

    @settings(deadline=None, max_examples=24)
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 70),
        n=st.integers(1, 70),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, m, k, n, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        a = rand(k1, (m, k))
        b = rand(k2, (k, n))
        got = gemm(a, b)
        want = gemm_ref(a, b)
        assert got.shape == (m, n)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes_accumulate_f32(self, dtype):
        key = jax.random.PRNGKey(3)
        k1, k2 = jax.random.split(key)
        a = rand(k1, (24, 40), dtype)
        b = rand(k2, (40, 24), dtype)
        got = gemm(a, b)
        assert got.dtype == jnp.float32
        want = gemm_ref(a, b)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 16, 32), (32, 16, 16)])
    def test_block_shape_invariance(self, bm, bn, bk):
        key = jax.random.PRNGKey(5)
        k1, k2 = jax.random.split(key)
        a = rand(k1, (33, 29))
        b = rand(k2, (29, 31))
        got = gemm(a, b, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, gemm_ref(a, b), rtol=1e-4, atol=1e-4)

    def test_zero_matrix(self):
        a = jnp.zeros((16, 16), jnp.float32)
        b = jnp.ones((16, 16), jnp.float32)
        assert float(jnp.abs(gemm(a, b)).max()) == 0.0

    def test_vmem_estimate_under_budget(self):
        t = tpu_tiles()
        assert vmem_footprint_bytes(t["bm"], t["bn"], t["bk"]) < 16 * 1024 * 1024


class TestAttention:
    @settings(deadline=None, max_examples=12)
    @given(
        h=st.integers(1, 4),
        s=st.sampled_from([8, 16, 32]),
        d=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_vs_ref(self, h, s, d, seed):
        key = jax.random.PRNGKey(seed)
        kq, kk, kv = jax.random.split(key, 3)
        q = rand(kq, (h, s, d))
        k = rand(kk, (h, s, d))
        v = rand(kv, (h, s, d))
        np.testing.assert_allclose(
            attention(q, k, v), attention_ref(q, k, v), rtol=1e-4, atol=1e-5
        )

    def test_softmax_rows_bounded(self):
        # Output rows are convex combinations of V rows.
        key = jax.random.PRNGKey(1)
        kq, kk, kv = jax.random.split(key, 3)
        q, k, v = (rand(x, (2, 16, 8)) for x in (kq, kk, kv))
        out = attention(q, k, v)
        assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-5

    def test_large_logits_stable(self):
        q = jnp.full((1, 8, 8), 100.0, jnp.float32)
        k = jnp.full((1, 8, 8), 100.0, jnp.float32)
        v = rand(jax.random.PRNGKey(2), (1, 8, 8))
        out = attention(q, k, v)
        assert bool(jnp.isfinite(out).all())

    def test_packed_wrapper_shapes(self):
        x = rand(jax.random.PRNGKey(4), (16, 32))
        out = mha_from_packed(x, n_heads=4)
        assert out.shape == (16, 32)
