"""L2 model tests: shapes, determinism, and that the Pallas-kernel path
matches an all-jnp recomputation of the same network."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import gelu_ref, layernorm_ref
from compile.model import EncoderConfig, encoder_forward, init_params, make_forward_fn

CFG = EncoderConfig(d_model=32, n_heads=2, d_ff=64, n_layers=2, seq=16)


def _jnp_forward(cfg, x, params):
    """The same network with plain jnp matmuls (no Pallas)."""
    h = x
    per = 10
    for layer in range(cfg.n_layers):
        (g1, b1, wq, wk, wv, wo, g2, b2, w1, w2) = params[layer * per:(layer + 1) * per]
        ln1 = layernorm_ref(h, g1, b1)
        q, k, v = ln1 @ wq, ln1 @ wk, ln1 @ wv
        dh = cfg.d_head
        outs = []
        for hh in range(cfg.n_heads):
            lo = hh * dh
            qh, kh, vh = q[:, lo:lo + dh], k[:, lo:lo + dh], v[:, lo:lo + dh]
            p = jax.nn.softmax(qh @ kh.T / jnp.sqrt(jnp.float32(dh)), axis=-1)
            outs.append(p @ vh)
        h = h + jnp.concatenate(outs, axis=1) @ wo
        ln2 = layernorm_ref(h, g2, b2)
        h = h + gelu_ref(ln2 @ w1) @ w2
    return h


def test_forward_shape_and_finite():
    params = init_params(CFG, 0)
    x = jax.random.normal(jax.random.PRNGKey(1), (CFG.seq, CFG.d_model))
    out = encoder_forward(CFG, x, params)
    assert out.shape == (CFG.seq, CFG.d_model)
    assert bool(jnp.isfinite(out).all())


def test_pallas_path_matches_jnp_path():
    params = init_params(CFG, 0)
    x = jax.random.normal(jax.random.PRNGKey(2), (CFG.seq, CFG.d_model))
    got = encoder_forward(CFG, x, params)
    want = _jnp_forward(CFG, x, params)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_deterministic():
    params = init_params(CFG, 0)
    x = jax.random.normal(jax.random.PRNGKey(3), (CFG.seq, CFG.d_model))
    a = encoder_forward(CFG, x, params)
    b = encoder_forward(CFG, x, params)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_param_shapes_contract():
    shapes = CFG.param_shapes()
    assert len(shapes) == 10 * CFG.n_layers
    names = [n for n, _ in shapes[:10]]
    assert names == [
        "ln1_gamma", "ln1_beta", "wq", "wk", "wv", "wo",
        "ln2_gamma", "ln2_beta", "w1", "w2",
    ]


def test_forward_fn_tuple_return():
    params = init_params(CFG, 0)
    x = jax.random.normal(jax.random.PRNGKey(4), (CFG.seq, CFG.d_model))
    fn = make_forward_fn(CFG)
    out = fn(x, *params)
    # jit may return the 1-tuple as tuple or list depending on version.
    assert isinstance(out, (tuple, list)) and len(out) == 1
    np.testing.assert_allclose(out[0], encoder_forward(CFG, x, params), rtol=1e-4, atol=1e-5)
