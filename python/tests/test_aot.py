"""AOT pipeline smoke: every artifact lowers to parseable HLO text and
the manifest/blob are internally consistent."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.aot import to_hlo_text, ENCODER_CFG
from compile.kernels.gemm_pallas import gemm
from compile.model import init_params, make_forward_fn


def test_gemm_lowers_to_hlo_text():
    spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    lowered = jax.jit(lambda a, b: (gemm(a, b),)).lower(spec, spec)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "dot" in text  # the matmul survives lowering


def test_encoder_lowers_and_counts_inputs():
    cfg = ENCODER_CFG
    params = init_params(cfg, 0)
    fn = make_forward_fn(cfg)
    x = jax.ShapeDtypeStruct((cfg.seq, cfg.d_model), jnp.float32)
    specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params]
    text = to_hlo_text(jax.jit(fn).lower(x, *specs))
    assert "HloModule" in text
    # At least 1 activation + 10 params per layer reach the entry
    # computation (nested fusion computations re-declare parameters, so
    # the global count is larger).
    assert text.count("parameter(") >= 1 + 10 * cfg.n_layers


def test_full_export_roundtrip(tmp_path):
    out = str(tmp_path)
    aot.export_gemms(out)
    aot.export_attention(out)
    aot.export_encoder(out)
    files = os.listdir(out)
    assert "encoder.hlo.txt" in files
    assert "encoder.params.bin" in files
    assert "encoder.manifest.txt" in files
    # Manifest offsets must tile the blob exactly.
    blob = np.fromfile(os.path.join(out, "encoder.params.bin"), np.float32)
    total = 0
    for line in open(os.path.join(out, "encoder.manifest.txt")):
        parts = line.split()
        if len(parts) == 5 and parts[3] == "param":
            dims = [int(d) for d in parts[2].split("x")]
            off = int(parts[4])
            assert off == total, "offsets must be dense and ordered"
            total += int(np.prod(dims))
    assert total == blob.size
