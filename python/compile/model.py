"""Layer-2 JAX model: the tiny transformer encoder, matmuls routed
through the Layer-1 Pallas kernels.

The op sequence (pre-LN residual blocks, tanh-GELU, per-head attention)
mirrors `rust/src/xformer/model.rs` operation-for-operation; the rust
float model loads the parameters this module exports (see ``aot.py``),
so the three paths — rust float, rust CGRA-int8, and the AOT-compiled
XLA artifact — are directly comparable.

Parameter order per layer (the manifest contract):
``ln1_gamma, ln1_beta, wq, wk, wv, wo, ln2_gamma, ln2_beta, w1, w2``.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.gemm_pallas import gemm
from .kernels.ref import gelu_ref, layernorm_ref


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 128
    n_layers: int = 2
    seq: int = 32

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def param_shapes(self):
        """Flat parameter shape list, model order (see module docstring)."""
        shapes = []
        for _ in range(self.n_layers):
            shapes += [
                ("ln1_gamma", (self.d_model,)),
                ("ln1_beta", (self.d_model,)),
                ("wq", (self.d_model, self.d_model)),
                ("wk", (self.d_model, self.d_model)),
                ("wv", (self.d_model, self.d_model)),
                ("wo", (self.d_model, self.d_model)),
                ("ln2_gamma", (self.d_model,)),
                ("ln2_beta", (self.d_model,)),
                ("w1", (self.d_model, self.d_ff)),
                ("w2", (self.d_ff, self.d_model)),
            ]
        return shapes


def init_params(cfg: EncoderConfig, seed: int = 0):
    """Xavier-ish init, flat list in model order."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in cfg.param_shapes():
        if name.endswith("gamma"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith("beta"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            key, sub = jax.random.split(key)
            scale = (2.0 / sum(shape)) ** 0.5
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _attention(cfg: EncoderConfig, x, wq, wk, wv, wo):
    """Multi-head attention; all four projections and the per-head GEMMs
    go through the Pallas blocked-GEMM kernel."""
    s, d = x.shape
    q = gemm(x, wq)
    k = gemm(x, wk)
    v = gemm(x, wv)
    dh = cfg.d_head
    outs = []
    for h in range(cfg.n_heads):
        lo = h * dh
        qh, kh, vh = q[:, lo:lo + dh], k[:, lo:lo + dh], v[:, lo:lo + dh]
        scores = gemm(qh, kh.T) / jnp.sqrt(jnp.float32(dh))
        probs = jax.nn.softmax(scores, axis=-1)
        outs.append(gemm(probs, vh))
    ctx = jnp.concatenate(outs, axis=1)
    return gemm(ctx, wo)


def encoder_forward(cfg: EncoderConfig, x, params):
    """Full encoder forward pass. ``params`` is the flat list from
    :func:`init_params` (10 entries per layer)."""
    h = x
    per = 10
    for layer in range(cfg.n_layers):
        (ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, w2) = params[
            layer * per:(layer + 1) * per
        ]
        ln1 = layernorm_ref(h, ln1_g, ln1_b)
        h = h + _attention(cfg, ln1, wq, wk, wv, wo)
        ln2 = layernorm_ref(h, ln2_g, ln2_b)
        h = h + gemm(gelu_ref(gemm(ln2, w1)), w2)
    return h


def make_forward_fn(cfg: EncoderConfig):
    """A jit-able ``fn(x, *params) -> (out,)`` for AOT lowering (tuple
    return per the HLO-text interchange recipe)."""

    @functools.partial(jax.jit)
    def fn(x, *params):
        return (encoder_forward(cfg, x, list(params)),)

    return fn
