"""Layer-1 Pallas kernel: per-head fused attention.

Maps the attention mechanism's two GEMMs + softmax into one kernel with a
grid over heads — the analogue of the CGRA executing the per-head score
and context GEMMs back-to-back from L1-resident Q/K/V panels (paper
§IV-B1). Edge sequence lengths are small (≤128), so each head's full
S×S score tile fits on-chip (VMEM / the 32 KiB L1) without flash-style
streaming; the BlockSpec keeps one head resident per grid step.

``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]  # [S, D] (leading head axis blocked to 1)
    k = k_ref[0]
    v = v_ref[0]
    d = q.shape[-1]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    # Numerically-stable softmax, in-kernel.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


@jax.jit
def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Fused multi-head attention. q, k, v: [H, S, D] → [H, S, D]."""
    h, s, d = q.shape
    assert k.shape == (h, s, d) and v.shape == (h, s, d)
    spec = pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _attn_kernel,
        grid=(h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        interpret=True,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("n_heads",))
def mha_from_packed(x_heads: jax.Array, *, n_heads: int) -> jax.Array:
    """Convenience wrapper splitting a packed [S, H*D] tensor into heads,
    running fused attention with q = k = v (self-similarity smoke shape
    used by the AOT artifact tests)."""
    s, hd = x_heads.shape
    d = hd // n_heads
    xh = x_heads.reshape(s, n_heads, d).transpose(1, 0, 2)
    out = attention(xh, xh, xh)
    return out.transpose(1, 0, 2).reshape(s, hd)
