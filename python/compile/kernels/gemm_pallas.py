"""Layer-1 Pallas kernel: block-wise GEMM (paper §IV-A1).

The BlockSpec tiling *is* the paper's execution strategy translated to the
TPU memory hierarchy (DESIGN.md §2 Hardware-Adaptation):

- the output-stationary ``(bm, bn)`` tile corresponds to the 4×4 PE array
  holding a C tile in accumulators (our default ``bm = bn = 16`` is
  exactly the CGRA tile: 4×4 PEs × 4×4-element sub-tiles);
- the k-grid dimension streams ``(bm, bk)`` / ``(bk, bn)`` operand panels
  through VMEM the way the 4×2 MOB array streams packed operands from the
  shared L1 (BlockSpec index maps = MOB address generators);
- revisiting the same output block across the k dimension keeps C resident
  (data reuse; the paper's "keeping data within the PE array as long as
  possible").

On a real TPU one would pick MXU-shaped tiles (``bm = bn = bk = 128``,
bf16 operands); the ``tpu_tiles()`` helper below returns that
configuration and DESIGN.md §6 records the estimated VMEM footprint.
``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO the rust runtime can
run (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The CGRA-equivalent tile (4x4 PEs × 4x4-element sub-tiles).
DEFAULT_BM = 16
DEFAULT_BN = 16
DEFAULT_BK = 32


def _gemm_kernel(a_ref, b_ref, o_ref):
    """One grid step: accumulate an (bm, bk) × (bk, bn) product into the
    output block. Grid dim 2 is the k loop; the first step zeroes C."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def pad_to(x: int, mult: int) -> int:
    """Round ``x`` up to a multiple of ``mult``."""
    return (x + mult - 1) // mult * mult


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(a: jax.Array, b: jax.Array, *, bm: int = DEFAULT_BM,
         bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> jax.Array:
    """Blocked GEMM ``C = A·B`` via Pallas. Arbitrary shapes (internally
    zero-padded to tile multiples, result sliced back)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    mp, kp, np_ = pad_to(m, bm), pad_to(k, bk), pad_to(n, bn)
    a_p = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _gemm_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def tpu_tiles() -> dict:
    """MXU-shaped tile configuration for a real-TPU build, with the VMEM
    footprint estimate recorded in DESIGN.md §6 / EXPERIMENTS.md §Perf.

    Footprint per grid step (f32): A block 128×128×4 B + B block + C block
    = 3 × 64 KiB = 192 KiB, ×2 for double buffering = 384 KiB — well
    under the ~16 MiB VMEM budget, leaving room to widen bk to 512
    (0.75 MiB ×2) for fewer grid steps and better MXU occupancy.
    """
    return {"bm": 128, "bn": 128, "bk": 512, "vmem_bytes_est": 2 * 3 * 128 * 512 * 4}


def vmem_footprint_bytes(bm: int, bn: int, bk: int, *, dtype_bytes: int = 4,
                         double_buffered: bool = True) -> int:
    """VMEM bytes a grid step holds: A, B and C blocks (×2 if double
    buffered)."""
    blocks = bm * bk + bk * bn + bm * bn
    mult = 2 if double_buffered else 1
    return blocks * dtype_bytes * mult
