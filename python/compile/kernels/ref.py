"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
contract: every kernel in this package must match its oracle to float32
tolerance across the pytest/hypothesis sweeps in ``python/tests``)."""

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference GEMM with f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Reference multi-head scaled dot-product attention.

    Shapes: q, k, v are [heads, seq, d_head]; output matches.
    """
    d = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(d))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def gelu_ref(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU (same formula as the rust host model)."""
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    """Row-wise LayerNorm over the last axis."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
