"""AOT pipeline: lower the L2 model (and standalone L1 kernels) to HLO
*text* artifacts the rust runtime loads via PJRT.

HLO text — not ``.serialize()`` — is the interchange format: the `xla`
crate's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id
protos, while the text parser reassigns ids (see
/opt/xla-example/README.md and gen_hlo.py).

Artifacts (``make artifacts`` → ``artifacts/``):
  gemm_{M}x{K}x{N}.hlo.txt      — standalone blocked-GEMM kernels
  attention_h{H}_s{S}_d{D}.hlo.txt — fused per-head attention
  encoder.hlo.txt               — full tiny-encoder forward pass
  encoder.params.bin            — raw LE f32 parameter blob
  encoder.manifest.txt          — input list (name, shape, blob offset)

Usage: ``python -m compile.aot --out ../artifacts`` (from ``python/``).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.attention_pallas import attention
from .kernels.gemm_pallas import gemm
from .model import EncoderConfig, init_params, make_forward_fn

# The canonical exported encoder (matches the e2e example's expectations).
ENCODER_CFG = EncoderConfig(d_model=64, n_heads=4, d_ff=128, n_layers=2, seq=32)
ENCODER_SEED = 0

GEMM_SHAPES = [(16, 16, 16), (32, 32, 32), (64, 64, 64)]
ATTN_SHAPES = [(4, 32, 16)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def export_gemms(outdir: str) -> None:
    for (m, k, n) in GEMM_SHAPES:
        def fn(a, b):
            return (gemm(a, b),)

        spec_a = jax.ShapeDtypeStruct((m, k), jnp.float32)
        spec_b = jax.ShapeDtypeStruct((k, n), jnp.float32)
        lowered = jax.jit(fn).lower(spec_a, spec_b)
        write(os.path.join(outdir, f"gemm_{m}x{k}x{n}.hlo.txt"), to_hlo_text(lowered))


def export_attention(outdir: str) -> None:
    for (h, s, d) in ATTN_SHAPES:
        def fn(q, k, v):
            return (attention(q, k, v),)

        spec = jax.ShapeDtypeStruct((h, s, d), jnp.float32)
        lowered = jax.jit(fn).lower(spec, spec, spec)
        write(os.path.join(outdir, f"attention_h{h}_s{s}_d{d}.hlo.txt"), to_hlo_text(lowered))


def export_encoder(outdir: str) -> None:
    cfg = ENCODER_CFG
    params = init_params(cfg, ENCODER_SEED)
    fn = make_forward_fn(cfg)
    x_spec = jax.ShapeDtypeStruct((cfg.seq, cfg.d_model), jnp.float32)
    p_specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params]
    lowered = jax.jit(fn).lower(x_spec, *p_specs)
    write(os.path.join(outdir, "encoder.hlo.txt"), to_hlo_text(lowered))

    # Parameter blob + manifest.
    import numpy as np

    blob = np.concatenate([np.asarray(p, np.float32).reshape(-1) for p in params])
    blob.tofile(os.path.join(outdir, "encoder.params.bin"))
    lines = [f"input x {cfg.seq}x{cfg.d_model}"]
    off = 0
    for (name, shape), p in zip(cfg.param_shapes(), params):
        dims = "x".join(str(d) for d in shape)
        lines.append(f"input {name} {dims} param {off}")
        off += int(np.prod(shape))
    with open(os.path.join(outdir, "encoder.manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote encoder.manifest.txt ({len(lines)} inputs, blob {off} f32 words)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    export_gemms(args.out)
    export_attention(args.out)
    export_encoder(args.out)


if __name__ == "__main__":
    main()
