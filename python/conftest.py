"""Make the `compile` package importable regardless of pytest's cwd."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
