//! END-TO-END driver (DESIGN.md experiment E2E): all layers composed.
//!
//! 1. Load the AOT-compiled JAX encoder (HLO text + parameter blob +
//!    manifest, produced by `make artifacts` — L2 calling the L1 Pallas
//!    kernels) and execute it through the PJRT runtime.
//! 2. Build the rust host model from the *same* parameter blob and serve
//!    a batch of synthetic requests through the coordinator, every GEMM
//!    running int8 on the cycle-level CGRA simulator (L3).
//! 3. Cross-validate: XLA float output vs rust float reference
//!    (must agree to float tolerance) vs CGRA int8 path (must agree to
//!    quantization tolerance). Report latency/throughput/energy.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use cgra_edge::config::ArchConfig;
use cgra_edge::coordinator::{Coordinator, Request};
use cgra_edge::energy::EnergyModel;
use cgra_edge::runtime::{assemble_inputs, read_f32_blob, Manifest, XlaRuntime};
use cgra_edge::util::mat::MatF32;
use cgra_edge::util::rng::XorShiftRng;
use cgra_edge::xformer::{EncoderModel, XformerConfig};

const ART: &str = "artifacts";

fn main() -> anyhow::Result<()> {
    // The canonical exported model (python/compile/aot.py ENCODER_CFG).
    let xcfg = XformerConfig { d_model: 64, n_heads: 4, d_ff: 128, n_layers: 2, seq: 32 };
    let manifest = Manifest::load(format!("{ART}/encoder.manifest.txt"))?;
    let blob = read_f32_blob(format!("{ART}/encoder.params.bin"))?;
    let model = EncoderModel::from_blob(xcfg, &blob)?;
    println!("model    : {:?} ({} params)", xcfg, xcfg.param_count());

    // --- 1. XLA reference path (PJRT) ---
    let rt = XlaRuntime::cpu()?;
    println!("runtime  : PJRT platform = {}", rt.platform());
    let xla_model = rt.load_hlo_text(format!("{ART}/encoder.hlo.txt"))?;

    let n_requests = 8u64;
    let cfg = ArchConfig::default();
    let mut rng = XorShiftRng::new(123);
    let mut inputs = Vec::new();
    for _ in 0..n_requests {
        let mut x = MatF32::zeros(xcfg.seq, xcfg.d_model);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        inputs.push(x);
    }

    // XLA outputs for every request.
    let mut xla_outs = Vec::new();
    let t0 = std::time::Instant::now();
    for x in &inputs {
        let run_inputs = assemble_inputs(&manifest, &blob, &[("x", x.data.clone())])?;
        let flat = xla_model.run_f32(&run_inputs)?;
        xla_outs.push(MatF32 { rows: xcfg.seq, cols: xcfg.d_model, data: flat });
    }
    let xla_wall = t0.elapsed().as_secs_f64();

    // Rust float reference must track XLA bit-for-bit-ish.
    let mut max_ref_err = 0.0f32;
    for (x, xo) in inputs.iter().zip(&xla_outs) {
        let ro = model.forward_f32(x)?;
        max_ref_err = max_ref_err.max(ro.max_abs_diff(xo));
    }
    println!(
        "validate : rust-float vs XLA max |Δ| = {max_ref_err:.2e} over {n_requests} requests \
         (float tolerance)"
    );
    anyhow::ensure!(max_ref_err < 2e-3, "reference paths diverged");

    // --- 2. Serve through the coordinator on the simulated CGRA ---
    let coord = Coordinator::spawn(cfg.clone(), model.clone(), 4);
    // Poisson arrivals at 200 req/s.
    let mut t = 0.0f64;
    let mut arrival_rng = XorShiftRng::new(9);
    for (id, x) in inputs.iter().enumerate() {
        t += arrival_rng.exp(200.0);
        coord.submit(Request {
            id: id as u64,
            input: x.clone(),
            arrival_cycle: (t * cfg.freq_mhz * 1e6) as u64,
        })?;
    }
    let mut cgra_outs: Vec<Option<MatF32>> = (0..n_requests).map(|_| None).collect();
    let mut lat_cycles = Vec::new();
    for _ in 0..n_requests {
        let r = coord.recv()?;
        lat_cycles.push(r.queue_cycles + r.service_cycles);
        cgra_outs[r.id as usize] = Some(r.output);
    }
    let metrics = coord.shutdown()?;

    // --- 3. Cross-validate the CGRA path and report ---
    let mut max_q_err = 0.0f32;
    for (xo, co) in xla_outs.iter().zip(&cgra_outs) {
        max_q_err = max_q_err.max(co.as_ref().unwrap().max_abs_diff(xo));
    }
    let amax = xla_outs.iter().map(|m| m.abs_max()).fold(0.0f32, f32::max);
    println!(
        "validate : CGRA-int8 vs XLA max |Δ| = {max_q_err:.4} (output amax {amax:.3}, \
         int8 tolerance)"
    );
    anyhow::ensure!(max_q_err < amax * 0.15 + 0.05, "quantized path diverged");

    lat_cycles.sort_unstable();
    let p50 = lat_cycles[lat_cycles.len() / 2];
    let p99 = lat_cycles[(lat_cycles.len() * 99 / 100).min(lat_cycles.len() - 1)];
    let em = EnergyModel::default();
    let e = em.evaluate(&metrics.stats, cfg.freq_mhz);
    println!("serving  : {} requests, batch 4, Poisson 200 req/s", metrics.completed);
    println!(
        "latency  : p50 {:.3} ms, p99 {:.3} ms (simulated @ {} MHz)",
        p50 as f64 / (cfg.freq_mhz * 1e3),
        p99 as f64 / (cfg.freq_mhz * 1e3),
        cfg.freq_mhz
    );
    println!("thruput  : {:.1} req/s simulated", metrics.throughput_rps(cfg.freq_mhz));
    println!(
        "energy   : {:.1} µJ/request, avg power {:.3} mW",
        e.total_uj() / metrics.completed as f64,
        em.avg_power_mw(&metrics.stats, cfg.freq_mhz)
    );
    println!("xla wall : {:.1} ms for {n_requests} reference inferences", xla_wall * 1e3);
    println!("\nE2E OK: all three layers compose and agree.");
    Ok(())
}
