//! Fleet-serving walkthrough: scale one CGRA to a dispatched fleet.
//!
//! 1. Generate a reproducible bursty workload over a two-model mix.
//! 2. Serve it on 1 vs 4 devices and watch tail latency collapse.
//! 3. Compare placement policies under the same stream.
//! 4. Build a heterogeneous fleet (`--fleet`-style class roster) and
//!    watch class-aware SJF + work-stealing exploit the fast silicon.
//! 5. Compare FIFO vs EDF-with-drop under an impossible SLA.
//! 6. Split one large GEMM across devices (2D tile sharding) and
//!    verify the merged output is bit-identical, with the broadcast
//!    traffic accounted per replica.
//!
//! Run with: `cargo run --release --example fleet_serving`

use cgra_edge::cluster::{
    run_gemm_sharded, ArrivalProcess, DeviceClass, Discipline, FleetConfig, FleetSim,
    ModelClass, Placement, WorkloadGen,
};
use cgra_edge::config::ArchConfig;
use cgra_edge::gemm::{oracle_quant, run_gemm, GemmPlan, OutputMode};
use cgra_edge::sim::CgraSim;
use cgra_edge::util::mat::MatI8;
use cgra_edge::util::rng::XorShiftRng;

fn main() -> anyhow::Result<()> {
    let arch = ArchConfig::default();
    let freq = arch.freq_mhz;
    let classes = ModelClass::edge_mix();
    let ms = |cy: u64| cy as f64 / (freq * 1e3);
    let bursty = ArrivalProcess::BurstyOnOff {
        rate_on_rps: 8000.0,
        rate_off_rps: 100.0,
        mean_on_s: 0.002,
        mean_off_s: 0.004,
    };
    let n = 24;
    let seed = 7u64;
    let workload = |s: u64| {
        WorkloadGen::new(bursty, classes.clone(), freq, s).generate(n)
    };

    // --- 1+2: one device vs a small fleet on the same burst ---
    println!("== bursty stream, {n} requests, 1 vs 4 devices (least-loaded / FIFO) ==");
    for devices in [1usize, 4] {
        let mut fleet = FleetSim::new(FleetConfig::paper_fleet(devices), &classes, 42);
        let m = fleet.run(workload(seed))?;
        println!(
            "{devices} device(s): {} served, p50 {:.3} ms, p99 {:.3} ms, {:.0} req/s, util {:.2}",
            m.completed,
            ms(m.latency.p50()),
            ms(m.latency.p99()),
            m.throughput_rps(freq),
            m.mean_utilization()
        );
    }

    // --- 3: placement policies under the identical stream ---
    println!("\n== placement policies, 4 devices, same stream ==");
    for (name, policy) in [
        ("round-robin", Placement::RoundRobin),
        ("least-loaded", Placement::LeastLoaded),
        ("shortest-expected-job", Placement::ShortestExpectedJob),
        ("model-affinity", Placement::ModelAffinity),
    ] {
        let mut fleet = FleetSim::new(
            FleetConfig { policy, ..FleetConfig::paper_fleet(4) },
            &classes,
            42,
        );
        let m = fleet.run(workload(seed))?;
        println!(
            "{name:>22}: p99 {:.3} ms, queue-wait p99 {:.3} ms, SLA misses {}, steals {}",
            ms(m.latency.p99()),
            ms(m.queue_wait.p99()),
            m.sla_misses,
            m.steals
        );
    }

    // --- 4: a heterogeneous class roster (big.LITTLE fleet) ---
    println!("\n== heterogeneous fleet: 3x4x4@100 + 1x8x4@200, SJF, same stream ==");
    let mixed = DeviceClass::parse_roster("4x4@100:3,8x4@200:1")?;
    for (name, steal) in [("stealing off", false), ("stealing on", true)] {
        let mut fleet = FleetSim::new(
            FleetConfig {
                roster: mixed.clone(),
                policy: Placement::ShortestExpectedJob,
                steal,
                ..Default::default()
            },
            &classes,
            42,
        );
        let m = fleet.run(workload(seed))?;
        let fast_share = m.per_device[3].served;
        println!(
            "{name:>13}: p99 {:.3} ms, fast device served {fast_share}/{}, steals {}",
            ms(m.latency.p99()),
            m.completed,
            m.steals
        );
    }

    // --- 5: FIFO vs EDF under an SLA the burst cannot meet ---
    println!("\n== queue disciplines under a 0.2 ms SLA, 1 device ==");
    let mut tight = classes.clone();
    for c in &mut tight {
        c.sla_ms = 0.2;
    }
    for (name, discipline) in [("fifo", Discipline::Fifo), ("edf+drop", Discipline::Edf)] {
        let reqs = WorkloadGen::new(bursty, tight.clone(), freq, seed).generate(n);
        let mut fleet = FleetSim::new(
            FleetConfig { discipline, ..FleetConfig::paper_fleet(1) },
            &tight,
            42,
        );
        let m = fleet.run(reqs)?;
        println!(
            "{name:>8}: served {} / dropped {} / late {}, p99 {:.3} ms",
            m.completed,
            m.dropped,
            m.sla_misses,
            ms(m.latency.p99())
        );
    }

    // --- 6: 2D tile sharding of one large GEMM ---
    println!("\n== 128x64x128 GEMM split across devices (2D tile sharding) ==");
    let (m_dim, k, n_dim) = (128usize, 64, 128);
    let mut rng = XorShiftRng::new(0x5AAD);
    let mut a = MatI8::zeros(m_dim, k);
    let mut b = MatI8::zeros(k, n_dim);
    rng.fill_i8(&mut a.data, 14);
    rng.fill_i8(&mut b.data, 14);
    let want = oracle_quant(&a, &b, 7);

    let mut single = CgraSim::new(arch.clone());
    let plan = GemmPlan::new(&single.cfg, m_dim, k, n_dim, OutputMode::Quant { shift: 7 })?;
    let run1 = run_gemm(&mut single, &a, &b, &plan)?;
    let t1 = run1.outcome.cycles + run1.outcome.config_cycles;
    assert_eq!(run1.c_i8.as_ref().unwrap(), &want);
    println!("1 device : {t1} cycles");

    for devices in [2usize, 4] {
        let mut sims: Vec<CgraSim> = (0..devices).map(|_| CgraSim::new(arch.clone())).collect();
        let sharded = run_gemm_sharded(&mut sims, &a, &b, 7)?;
        assert_eq!(sharded.c, want, "sharded output must be bit-identical");
        println!(
            "{devices} devices: {} cycles makespan ({:.2}x speedup, {}x{} grid, \
             {} broadcast words, bit-identical ✓)",
            sharded.parallel_cycles(),
            t1 as f64 / sharded.parallel_cycles() as f64,
            sharded.grid.0,
            sharded.grid.1,
            sharded.broadcast_ext_words()
        );
    }

    // Heterogeneous sharding: the 8x4@200 shard takes the lion's share
    // and the merge still matches bit-for-bit.
    let mut sims = vec![
        CgraSim::new(arch.clone()),
        CgraSim::new(DeviceClass::parse("8x4@200")?.arch),
    ];
    let sharded = run_gemm_sharded(&mut sims, &a, &b, 7)?;
    assert_eq!(sharded.c, want, "heterogeneous shard merge must be bit-identical");
    for s in &sharded.shards {
        println!(
            "hetero   : device {} ({} MHz) computed a {}x{} block",
            s.device, s.freq_mhz, s.mi, s.nj
        );
    }
    Ok(())
}
