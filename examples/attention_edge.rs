//! Attention at the edge: run one full multi-head-attention block (the
//! paper's motivating workload, §IV-B1) with every GEMM on the simulated
//! CGRA, and report per-stage latency and the GEMM/host split.
//!
//! Run: `cargo run --release --example attention_edge`

use cgra_edge::baseline::Gpp;
use cgra_edge::config::ArchConfig;
use cgra_edge::energy::EnergyModel;
use cgra_edge::sim::CgraSim;
use cgra_edge::util::mat::MatF32;
use cgra_edge::util::rng::XorShiftRng;
use cgra_edge::xformer::{run_encoder_on_cgra, EncoderModel, XformerConfig};

fn main() -> anyhow::Result<()> {
    let cfg = ArchConfig::default();
    // One encoder layer, attention-dominated configuration.
    let xcfg = XformerConfig { n_layers: 1, seq: 64, d_model: 64, n_heads: 4, d_ff: 128 };
    let model = EncoderModel::new(xcfg, 7);
    println!("architecture : {}", cfg.summary());
    println!(
        "workload     : 1 encoder layer, seq={} d_model={} heads={}",
        xcfg.seq, xcfg.d_model, xcfg.n_heads
    );
    println!("GEMM MACs    : {}", xcfg.gemm_macs());

    let mut rng = XorShiftRng::new(3);
    let mut x = MatF32::zeros(xcfg.seq, xcfg.d_model);
    for v in &mut x.data {
        *v = rng.normal() * 0.5;
    }

    let want = model.forward_f32(&x)?;
    let mut sim = CgraSim::new(cfg.clone());
    let (got, rep) = run_encoder_on_cgra(&mut sim, &model, &x)?;

    let total = rep.cycles + rep.config_cycles;
    println!(
        "CGRA         : {} kernels, {} cycles (+{} config) = {:.3} ms @ {} MHz",
        rep.kernels,
        rep.cycles,
        rep.config_cycles,
        total as f64 / (cfg.freq_mhz * 1e3),
        cfg.freq_mhz
    );
    // Host-side softmax/LN/GELU cost, modelled on the scalar companion core.
    let gpp = Gpp::default();
    let host = gpp.elementwise_cost(rep.host_elems as usize, 1.0);
    println!(
        "host ops     : {} elem-ops ≈ {} cycles ({:.1}% of end-to-end)",
        rep.host_elems,
        host.cycles,
        100.0 * host.cycles as f64 / (host.cycles + total) as f64
    );
    println!(
        "accuracy     : max |Δ| vs float reference {:.4} (output amax {:.3})",
        got.max_abs_diff(&want),
        want.abs_max()
    );
    let em = EnergyModel::default();
    println!(
        "energy       : {:.2} µJ on-array, avg power {:.3} mW",
        em.evaluate(&sim.stats, cfg.freq_mhz).total_uj(),
        em.avg_power_mw(&sim.stats, cfg.freq_mhz)
    );

    // The all-scalar alternative.
    let sc = gpp.gemm_cost(xcfg.seq, xcfg.d_model, xcfg.d_model); // representative proj
    let scalar_total: u64 = xcfg.gemm_macs() * sc.cycles
        / (xcfg.seq as u64 * xcfg.d_model as u64 * xcfg.d_model as u64);
    println!(
        "vs GPP-only  : GEMMs alone would take ≈{} cycles on the scalar core ({:.1}× slower)",
        scalar_total,
        scalar_total as f64 / total as f64
    );
    Ok(())
}
