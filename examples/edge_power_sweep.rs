//! Edge power sweep (§IV-B2 "ultra-low-power"): sweep clock frequency and
//! voltage-scaled energy parameters across workloads, print the power
//! frontier, and mark the sub-mW operating points.
//!
//! Run: `cargo run --release --example edge_power_sweep`

use cgra_edge::bench_util::{f2, f3, Table};
use cgra_edge::config::ArchConfig;
use cgra_edge::energy::{EnergyModel, EnergyParams};
use cgra_edge::gemm::{run_gemm, GemmPlan, OutputMode};
use cgra_edge::sim::CgraSim;
use cgra_edge::util::mat::MatI8;
use cgra_edge::util::rng::XorShiftRng;

fn main() -> anyhow::Result<()> {
    let cfg = ArchConfig::default();
    let (m, k, n) = (64, 64, 64);
    let mut rng = XorShiftRng::new(5);
    let mut a = MatI8::zeros(m, k);
    let mut b = MatI8::zeros(k, n);
    rng.fill_i8(&mut a.data, 16);
    rng.fill_i8(&mut b.data, 16);

    // Simulate once (the cycle model is frequency-independent).
    let mut sim = CgraSim::new(cfg);
    let plan = GemmPlan::new(&sim.cfg, m, k, n, OutputMode::Quant { shift: 7 })?;
    let run = run_gemm(&mut sim, &a, &b, &plan)?;
    println!(
        "workload: {m}×{k}×{n} int8 GEMM, {} cycles (+{} config)\n",
        run.outcome.cycles, run.outcome.config_cycles
    );

    // Voltage/frequency corners: near-threshold operation scales dynamic
    // energy ~V² — model three corners.
    let corners: [(&str, f64, f64); 3] = [
        ("0.9V nominal", 1.00, 1.00),
        ("0.7V low", 0.60, 0.80),
        ("0.55V near-Vt", 0.37, 0.60),
    ];
    let mut table = Table::new(&[
        "corner", "freq MHz", "latency µs", "power mW", "GOPS/W", "sub-mW",
    ]);
    for (name, dyn_f, leak_f) in corners {
        let em = EnergyModel::new(EnergyParams::default().scaled(dyn_f, leak_f));
        for freq in [25.0, 50.0, 100.0, 200.0] {
            let mw = em.avg_power_mw(&sim.stats, freq);
            let total = run.outcome.cycles + run.outcome.config_cycles;
            table.row(&[
                name.into(),
                format!("{freq:.0}"),
                f2(total as f64 / freq),
                f3(mw),
                format!("{:.0}", em.gops_per_watt(&sim.stats, freq)),
                if mw < 1.0 { "✓".into() } else { "·".into() },
            ]);
        }
    }
    table.print();
    println!("\nThe sub-mW column marks operating points satisfying the paper's");
    println!("ultra-low-power (<1 mW) envelope; see TAB6 for the full study.");
    Ok(())
}
