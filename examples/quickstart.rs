//! Quickstart: simulate one blocked GEMM on the paper's 4×4 + 4×2 CGRA,
//! verify it bit-exactly against the host oracle, and print the
//! performance/energy report.
//!
//! Run: `cargo run --release --example quickstart`

use cgra_edge::baseline::Gpp;
use cgra_edge::config::ArchConfig;
use cgra_edge::energy::EnergyModel;
use cgra_edge::gemm::{oracle_quant, run_gemm, GemmPlan, OutputMode};
use cgra_edge::sim::CgraSim;
use cgra_edge::util::mat::MatI8;
use cgra_edge::util::rng::XorShiftRng;

fn main() -> anyhow::Result<()> {
    let cfg = ArchConfig::default();
    println!("architecture : {}", cfg.summary());

    // A 64×64×64 int8 GEMM — the self-attention projection shape of a
    // d_model=64 edge transformer.
    let (m, k, n) = (64, 64, 64);
    let mut rng = XorShiftRng::new(2024);
    let mut a = MatI8::zeros(m, k);
    let mut b = MatI8::zeros(k, n);
    rng.fill_i8(&mut a.data, 16);
    rng.fill_i8(&mut b.data, 16);

    let mut sim = CgraSim::new(cfg.clone());
    let plan = GemmPlan::new(&sim.cfg, m, k, n, OutputMode::Quant { shift: 7 })?;
    println!(
        "plan         : {:?} feed={:?}, {} tiles, context {} B (≤ 4096 B budget)",
        plan.strategy,
        plan.feed,
        plan.tiles(),
        cgra_edge::gemm::build_context(&plan)?.0.encoded_size()
    );

    let run = run_gemm(&mut sim, &a, &b, &plan)?;
    let exact = run.c_i8.as_ref().unwrap() == &oracle_quant(&a, &b, 7);
    println!(
        "result       : {} ({} cycles + {} config, ideal {})",
        if exact { "BIT-EXACT vs host oracle" } else { "MISMATCH (bug!)" },
        run.outcome.cycles,
        run.outcome.config_cycles,
        plan.ideal_cycles()
    );
    assert!(exact);

    let em = EnergyModel::default();
    let e = em.evaluate(&sim.stats, cfg.freq_mhz);
    println!(
        "throughput   : {:.1} MACs/cycle (peak 64), PE utilization {:.1}%",
        sim.stats.macs_per_cycle(),
        100.0 * sim.stats.pe_utilization(16)
    );
    println!(
        "energy       : {:.2} µJ, avg power {:.3} mW @ {} MHz, {:.0} GOPS/W",
        e.total_uj(),
        em.avg_power_mw(&sim.stats, cfg.freq_mhz),
        cfg.freq_mhz,
        em.gops_per_watt(&sim.stats, cfg.freq_mhz)
    );

    let gpp = Gpp::default();
    let gc = gpp.gemm_cost(m, k, n);
    println!(
        "vs scalar GPP: {:.1}× faster, {:.1}× less energy",
        gc.cycles as f64 / (run.outcome.cycles + run.outcome.config_cycles) as f64,
        gc.energy_pj / e.total_pj()
    );
    Ok(())
}
