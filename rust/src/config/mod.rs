//! Configuration system.
//!
//! [`ArchConfig`] bundles every architectural parameter the simulator,
//! mapper and energy model consume. Configs can be parsed from simple
//! `key = value` files (`#` comments; no vendored TOML crate — see
//! DESIGN.md §1) so benches and the CLI can sweep parameters without
//! recompiling.

use crate::arch::mem::MemParams;
use crate::interconnect::{FabricKind, Topology};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Full architectural configuration. Defaults are the paper's system:
/// 4×4 PEs + 4×2 MOBs, switchless torus, 4 KiB context memory, 100 MHz
/// edge clock.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Grid geometry.
    pub topo: Topology,
    /// Interconnect model.
    pub fabric: FabricKind,
    /// Router pipeline depth per hop (switched fabric only).
    pub hop_latency: u64,
    /// Input-port FIFO depth per node (elastic buffering; ≥ 4 sustains
    /// the GEMM schedule at one MAC/PE/cycle — see fabric docs).
    pub port_fifo: usize,
    /// Memory hierarchy parameters.
    pub mem: MemParams,
    /// Context memory capacity in bytes.
    pub ctx_bytes: usize,
    /// Clock frequency in MHz (power reporting only; the cycle model is
    /// frequency-independent).
    pub freq_mhz: f64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            topo: Topology::default(),
            fabric: FabricKind::Torus,
            hop_latency: 3,
            port_fifo: crate::interconnect::fabric::DEFAULT_PORT_FIFO,
            mem: MemParams::default(),
            ctx_bytes: crate::arch::context::DEFAULT_CTX_BYTES,
            freq_mhz: 100.0,
        }
    }
}

impl ArchConfig {
    /// The paper's configuration with the switched-NoC baseline fabric
    /// (TAB3's comparison arm).
    pub fn switched_baseline() -> Self {
        Self { fabric: FabricKind::Switched, ..Self::default() }
    }

    /// Parse from `key = value` text. Unknown keys are rejected (typos in
    /// sweep scripts should fail loudly).
    pub fn from_kv_text(text: &str) -> Result<Self> {
        let mut cfg = Self::default();
        let kv = parse_kv(text)?;
        for (k, v) in &kv {
            match k.as_str() {
                "rows" => cfg.topo.rows = parse_num(k, v)?,
                "pe_cols" => cfg.topo.pe_cols = parse_num(k, v)?,
                "mob_cols" => cfg.topo.mob_cols = parse_num(k, v)?,
                "fabric" => {
                    cfg.fabric = match v.as_str() {
                        "torus" => FabricKind::Torus,
                        "switched" => FabricKind::Switched,
                        other => bail!("unknown fabric '{other}' (torus|switched)"),
                    }
                }
                "hop_latency" => cfg.hop_latency = parse_num(k, v)?,
                "port_fifo" => cfg.port_fifo = parse_num(k, v)?,
                "l1_kib" => cfg.mem.l1_words = parse_num::<usize>(k, v)? * 1024 / 4,
                "l1_banks" => cfg.mem.l1_banks = parse_num(k, v)?,
                "l1_latency" => cfg.mem.l1_latency = parse_num(k, v)?,
                "ext_latency" => cfg.mem.ext_latency = parse_num(k, v)?,
                "ext_bw" => cfg.mem.ext_bw = parse_num(k, v)?,
                "dma_bw" => cfg.mem.dma_bw = parse_num(k, v)?,
                "ctx_bytes" => cfg.ctx_bytes = parse_num(k, v)?,
                "freq_mhz" => cfg.freq_mhz = parse_num(k, v)?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_kv_text(&text)
    }

    /// Sanity-check parameter combinations.
    pub fn validate(&self) -> Result<()> {
        if self.topo.rows == 0 || self.topo.pe_cols == 0 || self.topo.mob_cols == 0 {
            bail!("grid dimensions must be positive");
        }
        if self.mem.l1_banks == 0 || !self.mem.l1_banks.is_power_of_two() {
            bail!("l1_banks must be a positive power of two");
        }
        if self.mem.ext_bw == 0 {
            bail!("ext_bw must be positive");
        }
        if self.port_fifo == 0 {
            bail!("port_fifo must be at least 1");
        }
        if self.freq_mhz <= 0.0 {
            bail!("freq_mhz must be positive");
        }
        Ok(())
    }

    /// One-line summary for logs and bench headers.
    pub fn summary(&self) -> String {
        format!(
            "{}x{} PEs + {}x{} MOBs, {} fabric, L1 {} KiB, {} MHz",
            self.topo.rows,
            self.topo.pe_cols,
            self.topo.rows,
            self.topo.mob_cols,
            match self.fabric {
                FabricKind::Torus => "torus",
                FabricKind::Switched => "switched",
            },
            self.mem.l1_words * 4 / 1024,
            self.freq_mhz
        )
    }
}

/// Parse `key = value` lines; `#` starts a comment; blank lines ignored.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
        };
        let key = k.trim().to_string();
        if out.contains_key(&key) {
            bail!("line {}: duplicate key '{key}'", lineno + 1);
        }
        out.insert(key, v.trim().to_string());
    }
    Ok(out)
}

fn parse_num<T: std::str::FromStr>(key: &str, v: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    v.parse::<T>()
        .map_err(|e| anyhow::anyhow!("config key '{key}': bad value '{v}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_system() {
        let c = ArchConfig::default();
        assert_eq!(c.topo.rows, 4);
        assert_eq!(c.topo.pe_cols, 4);
        assert_eq!(c.topo.mob_cols, 2);
        assert_eq!(c.ctx_bytes, 4096);
        assert_eq!(c.fabric, FabricKind::Torus);
    }

    #[test]
    fn parse_roundtrip() {
        let c = ArchConfig::from_kv_text(
            "rows = 8\npe_cols=8 # big array\nfabric = switched\nl1_kib = 64\nfreq_mhz = 200\n",
        )
        .unwrap();
        assert_eq!(c.topo.rows, 8);
        assert_eq!(c.topo.pe_cols, 8);
        assert_eq!(c.fabric, FabricKind::Switched);
        assert_eq!(c.mem.l1_words, 64 * 1024 / 4);
        assert_eq!(c.freq_mhz, 200.0);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ArchConfig::from_kv_text("bogus = 1").is_err());
    }

    #[test]
    fn bad_fabric_rejected() {
        assert!(ArchConfig::from_kv_text("fabric = crossbar").is_err());
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(ArchConfig::from_kv_text("rows = 1\nrows = 2").is_err());
    }

    #[test]
    fn validation_catches_bad_banks() {
        assert!(ArchConfig::from_kv_text("l1_banks = 3").is_err());
        assert!(ArchConfig::from_kv_text("l1_banks = 0").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let kv = parse_kv("# header\n\n a = 1 # trailing\n").unwrap();
        assert_eq!(kv.get("a").map(String::as_str), Some("1"));
    }

    #[test]
    fn summary_mentions_geometry() {
        let s = ArchConfig::default().summary();
        assert!(s.contains("4x4 PEs"));
        assert!(s.contains("torus"));
    }
}
