//! Configuration system.
//!
//! [`ArchConfig`] bundles every architectural parameter the simulator,
//! mapper and energy model consume. Configs can be parsed from simple
//! `key = value` files (`#` comments; no vendored TOML crate — see
//! DESIGN.md §1) so benches and the CLI can sweep parameters without
//! recompiling.

use crate::arch::mem::MemParams;
use crate::interconnect::{FabricKind, Topology};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Full architectural configuration. Defaults are the paper's system:
/// 4×4 PEs + 4×2 MOBs, switchless torus, 4 KiB context memory, 100 MHz
/// edge clock.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Grid geometry.
    pub topo: Topology,
    /// Interconnect model.
    pub fabric: FabricKind,
    /// Router pipeline depth per hop (switched fabric only).
    pub hop_latency: u64,
    /// Input-port FIFO depth per node (elastic buffering; ≥ 4 sustains
    /// the GEMM schedule at one MAC/PE/cycle — see fabric docs).
    pub port_fifo: usize,
    /// Memory hierarchy parameters.
    pub mem: MemParams,
    /// Context memory capacity in bytes.
    pub ctx_bytes: usize,
    /// Clock frequency in MHz (power reporting only; the cycle model is
    /// frequency-independent).
    pub freq_mhz: f64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            topo: Topology::default(),
            fabric: FabricKind::Torus,
            hop_latency: 3,
            port_fifo: crate::interconnect::fabric::DEFAULT_PORT_FIFO,
            mem: MemParams::default(),
            ctx_bytes: crate::arch::context::DEFAULT_CTX_BYTES,
            freq_mhz: 100.0,
        }
    }
}

impl ArchConfig {
    /// The paper's configuration with the switched-NoC baseline fabric
    /// (TAB3's comparison arm).
    pub fn switched_baseline() -> Self {
        Self { fabric: FabricKind::Switched, ..Self::default() }
    }

    /// Parse from `key = value` text. Unknown keys are rejected (typos in
    /// sweep scripts should fail loudly).
    pub fn from_kv_text(text: &str) -> Result<Self> {
        let mut cfg = Self::default();
        let kv = parse_kv(text)?;
        for (k, v) in &kv {
            match k.as_str() {
                "rows" => cfg.topo.rows = parse_num(k, v)?,
                "pe_cols" => cfg.topo.pe_cols = parse_num(k, v)?,
                "mob_cols" => cfg.topo.mob_cols = parse_num(k, v)?,
                "fabric" => {
                    cfg.fabric = match v.as_str() {
                        "torus" => FabricKind::Torus,
                        "switched" => FabricKind::Switched,
                        other => bail!("unknown fabric '{other}' (torus|switched)"),
                    }
                }
                "hop_latency" => cfg.hop_latency = parse_num(k, v)?,
                "port_fifo" => cfg.port_fifo = parse_num(k, v)?,
                "l1_kib" => cfg.mem.l1_words = parse_num::<usize>(k, v)? * 1024 / 4,
                "l1_banks" => cfg.mem.l1_banks = parse_num(k, v)?,
                "l1_latency" => cfg.mem.l1_latency = parse_num(k, v)?,
                "ext_latency" => cfg.mem.ext_latency = parse_num(k, v)?,
                "ext_bw" => cfg.mem.ext_bw = parse_num(k, v)?,
                "dma_bw" => cfg.mem.dma_bw = parse_num(k, v)?,
                "ctx_bytes" => cfg.ctx_bytes = parse_num(k, v)?,
                "freq_mhz" => cfg.freq_mhz = parse_num(k, v)?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_kv_text(&text)
    }

    /// Sanity-check parameter combinations.
    pub fn validate(&self) -> Result<()> {
        if self.topo.rows == 0 || self.topo.pe_cols == 0 || self.topo.mob_cols == 0 {
            bail!("grid dimensions must be positive");
        }
        if self.mem.l1_banks == 0 || !self.mem.l1_banks.is_power_of_two() {
            bail!("l1_banks must be a positive power of two");
        }
        if self.mem.ext_bw == 0 {
            bail!("ext_bw must be positive");
        }
        if self.port_fifo == 0 {
            bail!("port_fifo must be at least 1");
        }
        if self.freq_mhz <= 0.0 {
            bail!("freq_mhz must be positive");
        }
        Ok(())
    }

    /// Peak packed MACs per cycle of this geometry (4 lanes per PE) —
    /// the throughput weight device classes and shard sizing share.
    pub fn peak_macs_per_cycle(&self) -> u64 {
        (4 * self.topo.rows * self.topo.pe_cols) as u64
    }

    /// The clock as integer MHz (rounded, at least 1) — the one
    /// conversion the fleet timeline, device classes and shard weights
    /// all share, so mixed-clock determinism has a single rounding rule.
    pub fn freq_mhz_u64(&self) -> u64 {
        (self.freq_mhz.round().max(1.0)) as u64
    }

    /// One-line summary for logs and bench headers.
    pub fn summary(&self) -> String {
        format!(
            "{}x{} PEs + {}x{} MOBs, {} fabric, L1 {} KiB, {} MHz",
            self.topo.rows,
            self.topo.pe_cols,
            self.topo.rows,
            self.topo.mob_cols,
            match self.fabric {
                FabricKind::Torus => "torus",
                FabricKind::Switched => "switched",
            },
            self.mem.l1_words * 4 / 1024,
            self.freq_mhz
        )
    }
}

/// A named **device class**: one hardware design point of the scalable
/// pathway — array geometry, clock, and the memory provisioning that
/// scales with it. Fleets are built from class rosters (big.LITTLE
/// style), the dispatcher costs work per `(model, class)`, and 2D GEMM
/// sharding sizes shards by class throughput, so the class is the unit
/// of heterogeneity everywhere above the simulator.
///
/// The canonical spelling is `RxC@MHZ` (e.g. `4x4@100`, the paper's
/// design point, or `8x4@200`, a tall fast array). PE columns are
/// capped at 4 by the per-row entry-link bandwidth (the FIG5 finding);
/// rows and clock scale freely, with L1 and context memory provisioned
/// proportionally to the row count.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceClass {
    /// Canonical name, e.g. `"4x4@100"`.
    pub name: String,
    /// Full architecture of one device of this class (`freq_mhz` kept
    /// in sync with [`Self::freq_mhz`]).
    pub arch: ArchConfig,
    /// Device clock in *integer* MHz — integral so cross-class cycle
    /// conversion on the fleet's reference timeline is exact (and fleet
    /// runs stay seed-deterministic).
    pub freq_mhz: u64,
}

impl DeviceClass {
    /// The paper's design point: 4×4 PEs at the 100 MHz edge clock.
    pub fn paper() -> Self {
        Self::parse("4x4@100").expect("the paper class always parses")
    }

    /// Wrap an existing [`ArchConfig`] as a class (the `--devices N`
    /// homogeneous-roster sugar). The clock is rounded to integer MHz.
    pub fn from_arch(arch: ArchConfig) -> Self {
        let freq_mhz = arch.freq_mhz_u64();
        let name = format!("{}x{}@{}", arch.topo.rows, arch.topo.pe_cols, freq_mhz);
        Self { name, arch, freq_mhz }
    }

    /// Parse a class spec `RxC[@MHZ]` (`@MHZ` defaults to the paper's
    /// 100). Rows scale the memory provisioning: L1 and context memory
    /// grow with `ceil(rows / 4)`, matching the FIG5 scaling rule that
    /// each row brings its own MOB pair and per-row program.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        let (geom, freq_mhz) = match spec.split_once('@') {
            Some((g, f)) => (
                g,
                f.parse::<u64>().map_err(|e| {
                    anyhow::anyhow!("device class '{spec}': bad clock '{f}': {e}")
                })?,
            ),
            None => (spec, 100),
        };
        let Some((r, c)) = geom.split_once('x') else {
            bail!("device class '{spec}': expected RxC[@MHZ], e.g. 4x4@100");
        };
        let rows = parse_num::<usize>("rows", r.trim())?;
        let pe_cols = parse_num::<usize>("pe_cols", c.trim())?;
        if rows == 0 || pe_cols == 0 {
            bail!("device class '{spec}': geometry must be positive");
        }
        if pe_cols > 4 {
            bail!(
                "device class '{spec}': more than 4 PE columns is unsupported — the \
                 per-row B entry links saturate at one word per cycle (the FIG5 \
                 finding); scale rows instead, e.g. {rows}x4"
            );
        }
        if freq_mhz == 0 {
            bail!("device class '{spec}': clock must be positive");
        }
        let mut arch = ArchConfig::default();
        arch.topo.rows = rows;
        arch.topo.pe_cols = pe_cols;
        let scale = rows.div_ceil(4).max(1);
        arch.mem.l1_words *= scale;
        arch.ctx_bytes *= scale;
        arch.freq_mhz = freq_mhz as f64;
        arch.validate()?;
        Ok(Self { name: format!("{rows}x{pe_cols}@{freq_mhz}"), arch, freq_mhz })
    }

    /// Parse a fleet roster spec `CLASS[:COUNT],…` — e.g.
    /// `4x4@100:3,8x4@200:1` is three paper devices plus one tall fast
    /// device. Counts default to 1; the result has one entry per device.
    pub fn parse_roster(spec: &str) -> Result<Vec<DeviceClass>> {
        let mut out = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (cls, count) = match part.rsplit_once(':') {
                Some((c, n)) => (
                    c,
                    n.trim().parse::<usize>().map_err(|e| {
                        anyhow::anyhow!("fleet spec '{part}': bad count '{n}': {e}")
                    })?,
                ),
                None => (part, 1),
            };
            if count == 0 {
                bail!("fleet spec '{part}': count must be at least 1");
            }
            let class = Self::parse(cls)?;
            for _ in 0..count {
                out.push(class.clone());
            }
        }
        if out.is_empty() {
            bail!("empty fleet spec '{spec}'");
        }
        Ok(out)
    }

    /// Peak MAC throughput at the device clock (MACs/cycle × MHz): the
    /// proportional weight 2D sharding and capacity reasoning use. A
    /// class with twice the PEs at twice the clock weighs 4×.
    pub fn throughput_weight(&self) -> u64 {
        self.arch.peak_macs_per_cycle() * self.freq_mhz
    }

    /// Words per device cycle this class can move over its torus entry
    /// links: one link per grid row, one 32-bit word per cycle each —
    /// the same per-row saturation bandwidth behind the FIG5
    /// pe_cols ≤ 4 cap. This is the serialization rate the KV-migration
    /// transfer cost model charges at each endpoint (source export and
    /// destination import, each at its own clock), so a tall class both
    /// computes *and* moves cache images faster.
    pub fn entry_link_words_per_cycle(&self) -> u64 {
        self.arch.topo.rows as u64
    }

    /// Deduplicate a roster into a class table plus a per-device index
    /// into it — the one definition of class identity (full structural
    /// equality) every fleet simulator shares, so per-class cost caches
    /// and KV budgets can never disagree on what "the same class" means.
    pub fn dedup_roster(roster: &[DeviceClass]) -> (Vec<DeviceClass>, Vec<usize>) {
        let mut classes: Vec<DeviceClass> = Vec::new();
        let mut index = Vec::with_capacity(roster.len());
        for c in roster {
            let id = match classes.iter().position(|x| x == c) {
                Some(i) => i,
                None => {
                    classes.push(c.clone());
                    classes.len() - 1
                }
            };
            index.push(id);
        }
        (classes, index)
    }

    /// Normalized supply voltage implied by the class clock: a linear
    /// DVFS model around the paper's 100 MHz / nominal-V design point
    /// (`V = 0.6 + 0.4·f/100`, floored at the 0.7 near-threshold
    /// limit). The paper class is exactly 1.0, a 200 MHz class runs at
    /// 1.4× nominal — the voltage cost of big silicon the energy model
    /// charges per device class.
    pub fn voltage_scale(&self) -> f64 {
        (0.6 + 0.4 * self.freq_mhz as f64 / 100.0).max(0.7)
    }

    /// Active-area scale versus the paper's 4×4 array (PE count ratio).
    pub fn area_scale(&self) -> f64 {
        (self.arch.topo.rows * self.arch.topo.pe_cols) as f64 / 16.0
    }

    /// Leakage-power multiplier for this class: leakage grows with
    /// active area and (sub-threshold, roughly linearly) with supply
    /// voltage — `area × V`. The paper class is 1.0, so homogeneous
    /// paper fleets charge exactly the flat per-device figure they
    /// always did.
    pub fn leakage_scale(&self) -> f64 {
        self.area_scale() * self.voltage_scale()
    }

    /// Dynamic-energy multiplier for this class: switching energy goes
    /// with `V²` (CV²f — the per-event counts already carry the f and
    /// the area). The paper class is 1.0.
    pub fn dynamic_scale(&self) -> f64 {
        let v = self.voltage_scale();
        v * v
    }
}

/// Parse `key = value` lines; `#` starts a comment; blank lines ignored.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
        };
        let key = k.trim().to_string();
        if out.contains_key(&key) {
            bail!("line {}: duplicate key '{key}'", lineno + 1);
        }
        out.insert(key, v.trim().to_string());
    }
    Ok(out)
}

fn parse_num<T: std::str::FromStr>(key: &str, v: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    v.parse::<T>()
        .map_err(|e| anyhow::anyhow!("config key '{key}': bad value '{v}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_system() {
        let c = ArchConfig::default();
        assert_eq!(c.topo.rows, 4);
        assert_eq!(c.topo.pe_cols, 4);
        assert_eq!(c.topo.mob_cols, 2);
        assert_eq!(c.ctx_bytes, 4096);
        assert_eq!(c.fabric, FabricKind::Torus);
    }

    #[test]
    fn parse_roundtrip() {
        let c = ArchConfig::from_kv_text(
            "rows = 8\npe_cols=8 # big array\nfabric = switched\nl1_kib = 64\nfreq_mhz = 200\n",
        )
        .unwrap();
        assert_eq!(c.topo.rows, 8);
        assert_eq!(c.topo.pe_cols, 8);
        assert_eq!(c.fabric, FabricKind::Switched);
        assert_eq!(c.mem.l1_words, 64 * 1024 / 4);
        assert_eq!(c.freq_mhz, 200.0);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ArchConfig::from_kv_text("bogus = 1").is_err());
    }

    #[test]
    fn bad_fabric_rejected() {
        assert!(ArchConfig::from_kv_text("fabric = crossbar").is_err());
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(ArchConfig::from_kv_text("rows = 1\nrows = 2").is_err());
    }

    #[test]
    fn validation_catches_bad_banks() {
        assert!(ArchConfig::from_kv_text("l1_banks = 3").is_err());
        assert!(ArchConfig::from_kv_text("l1_banks = 0").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let kv = parse_kv("# header\n\n a = 1 # trailing\n").unwrap();
        assert_eq!(kv.get("a").map(String::as_str), Some("1"));
    }

    #[test]
    fn summary_mentions_geometry() {
        let s = ArchConfig::default().summary();
        assert!(s.contains("4x4 PEs"));
        assert!(s.contains("torus"));
    }

    #[test]
    fn device_class_paper_matches_default_arch() {
        let c = DeviceClass::paper();
        assert_eq!(c.name, "4x4@100");
        assert_eq!(c.freq_mhz, 100);
        assert_eq!(c.arch, ArchConfig::default());
        assert_eq!(c.throughput_weight(), 64 * 100);
    }

    #[test]
    fn device_class_parse_scales_memory_with_rows() {
        let big = DeviceClass::parse("8x4@200").unwrap();
        assert_eq!(big.arch.topo.rows, 8);
        assert_eq!(big.arch.topo.pe_cols, 4);
        assert_eq!(big.freq_mhz, 200);
        assert_eq!(big.arch.freq_mhz, 200.0);
        let base = ArchConfig::default();
        assert_eq!(big.arch.mem.l1_words, 2 * base.mem.l1_words);
        assert_eq!(big.arch.ctx_bytes, 2 * base.ctx_bytes);
        // 2× PEs at 2× the clock: 4× the throughput weight.
        assert_eq!(big.throughput_weight(), 4 * DeviceClass::paper().throughput_weight());
        // The clock defaults to the paper's 100 MHz.
        assert_eq!(DeviceClass::parse("2x4").unwrap().freq_mhz, 100);
    }

    #[test]
    fn device_class_rejects_wide_arrays_and_garbage() {
        let err = DeviceClass::parse("8x8@200").unwrap_err().to_string();
        assert!(err.contains("PE columns"), "must explain the FIG5 cap: {err}");
        assert!(DeviceClass::parse("0x4@100").is_err());
        assert!(DeviceClass::parse("4x4@0").is_err());
        assert!(DeviceClass::parse("4@100").is_err());
        assert!(DeviceClass::parse("4x4@fast").is_err());
    }

    #[test]
    fn energy_scales_are_anchored_at_the_paper_class() {
        let paper = DeviceClass::paper();
        assert_eq!(paper.voltage_scale(), 1.0);
        assert_eq!(paper.area_scale(), 1.0);
        assert_eq!(paper.leakage_scale(), 1.0);
        assert_eq!(paper.dynamic_scale(), 1.0);
        let big = DeviceClass::parse("8x4@200").unwrap();
        assert!((big.voltage_scale() - 1.4).abs() < 1e-12);
        assert!((big.area_scale() - 2.0).abs() < 1e-12);
        assert!((big.leakage_scale() - 2.8).abs() < 1e-12);
        assert!((big.dynamic_scale() - 1.96).abs() < 1e-12);
        // The near-threshold floor kicks in for very slow classes.
        let slow = DeviceClass::parse("4x4@10").unwrap();
        assert!((slow.voltage_scale() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn entry_link_bandwidth_scales_with_rows() {
        // One word per row per cycle: the paper class moves 4
        // words/cycle, a tall 8-row class 8 — the asymmetry the
        // migration transfer model charges per endpoint.
        assert_eq!(DeviceClass::paper().entry_link_words_per_cycle(), 4);
        assert_eq!(DeviceClass::parse("8x4@200").unwrap().entry_link_words_per_cycle(), 8);
        assert_eq!(DeviceClass::parse("2x4").unwrap().entry_link_words_per_cycle(), 2);
    }

    #[test]
    fn roster_spec_expands_counts() {
        let roster = DeviceClass::parse_roster("4x4@100:3,8x4@200").unwrap();
        assert_eq!(roster.len(), 4);
        assert!(roster[..3].iter().all(|c| c.name == "4x4@100"));
        assert_eq!(roster[3].name, "8x4@200");
        assert!(DeviceClass::parse_roster("").is_err());
        assert!(DeviceClass::parse_roster("4x4@100:0").is_err());
        assert!(DeviceClass::parse_roster("4x4@100:x").is_err());
    }
}
