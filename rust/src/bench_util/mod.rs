//! Shared bench harness (no criterion in the vendored crate set).
//!
//! Benches here measure *simulated* quantities (cycles, energy) which
//! are deterministic — repetitions exist only for wall-clock simulation
//! throughput numbers. The harness provides warmup + repetition timing
//! and an aligned-column table printer that every `benches/tabN_*.rs`
//! uses so EXPERIMENTS.md can paste the output verbatim.

use std::time::Instant;

/// Wall-clock timing of `f`, with warmup. Returns (median_secs, runs).
pub fn time_median<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, usize) {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (times[times.len() / 2], reps.max(1))
}

/// Minimal aligned-column table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers used across benches.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn time_median_runs() {
        let mut n = 0;
        let (t, reps) = time_median(1, 3, || n += 1);
        assert_eq!(n, 4);
        assert_eq!(reps, 3);
        assert!(t >= 0.0);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
