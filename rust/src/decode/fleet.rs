//! Continuous batching: the stateful generation lifecycle on a device
//! fleet.
//!
//! Encoder serving is one-shot — a request is placed, runs once,
//! leaves. Generation is a **multi-step, stateful, preemptible**
//! lifecycle: a request prefills its prompt (emitting the first
//! token), then takes one decode step per further token, holding KV
//! pages the whole time. [`DeviceDecoder`] owns that lifecycle for one
//! device; [`DecodeFleetSim`] places generation requests across N
//! devices and advances the same deterministic discrete-event timeline
//! the encoder fleet uses.
//!
//! ## Iteration-level scheduling
//!
//! A device wakes whenever it is free and has work, and runs exactly
//! one **job** per wake:
//!
//! - a *prefill job* — every admissible waiting sequence of one model
//!   (preempted resumes first) prefills as one stacked causal forward;
//! - otherwise a *decode tick* — every running sequence advances one
//!   token, the projections/FFN stacked into one `B × d` GEMV per
//!   layer per site.
//!
//! Sequences therefore **join and leave the running batch at step
//!   boundaries**: an arrival never waits for the current batch to
//! finish its whole generation, only for the current tick — the
//! iteration-level batching lever (Orca, vLLM) that dominates decode
//! throughput. [`DecodeSchedule`] picks the interleaving: prefills
//! first (default — maximizes batch occupancy and TTFT fairness) or
//! decode first (drains the running batch before admitting — lower
//! inter-token jitter, serial admission).
//!
//! ## Memory pressure
//!
//! Admission and growth run against the device's [`PagedKvCache`]
//! budget. A sequence whose worst case can never fit is **rejected
//! with its reason**. When a decode tick needs pages the pool cannot
//! supply, the scheduler preempts the **most recently admitted**
//! running sequence (LIFO, the vLLM rule: the oldest sequence always
//! progresses, so the system cannot livelock), releasing its pages;
//! the victim re-queues and later *resumes* by re-prefilling its
//! prompt plus the tokens it already emitted — recomputation changes
//! timing, never outputs. Every decision depends only on simulated
//! stamps, so decode fleets are seed-deterministic end to end.

use super::engine::{mat_row, run_decode_tick, run_prefill_batch};
use super::kv::{AdmitError, KvConfig, KvMetrics, PagedKvCache};
use crate::cluster::{
    analytic_encoder_ref_cycles, per_device_energy, to_ref_cycles, DeviceEngine, DeviceMetrics,
    GenRequest, LatencyHistogram, ModelClass,
};
use crate::config::{ArchConfig, DeviceClass};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::gemm::{GemmPlan, OutputMode};
use crate::sim::Stats;
use crate::util::mat::MatF32;
use crate::xformer::{CgraEncoderReport, DecoderModel, EncoderQuant, XformerConfig};
use anyhow::Result;
use std::collections::VecDeque;

/// Prefill/decode interleaving policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeSchedule {
    /// Admit every admissible waiting sequence before each tick
    /// (default): highest batch occupancy, earliest TTFT for arrivals.
    PrefillFirst,
    /// Drain the running batch to empty before admitting anything new:
    /// no prefill ever interrupts decoding (lowest inter-token
    /// jitter), at the price of serial admission.
    DecodeFirst,
}

/// Decode-fleet configuration.
#[derive(Debug, Clone)]
pub struct DecodeFleetConfig {
    /// One device per entry (mixed rosters give big.LITTLE fleets).
    pub roster: Vec<DeviceClass>,
    /// Reference clock of the fleet timeline in integer MHz.
    pub ref_mhz: u64,
    /// Most sequences one device runs concurrently (the continuous
    /// batch cap; 1 = sequential per-request decode, the baseline arm
    /// of the FIG8 bench).
    pub max_running: usize,
    /// KV page size in words (pool provisioning per class is half of
    /// L1 — see [`KvConfig::for_class`]).
    pub page_words: usize,
    /// Override the per-device page count (tests force tiny pools to
    /// exercise preemption); `None` derives it from the device class.
    pub kv_pages: Option<usize>,
    pub schedule: DecodeSchedule,
}

impl Default for DecodeFleetConfig {
    fn default() -> Self {
        Self {
            roster: vec![DeviceClass::paper(); 4],
            ref_mhz: 100,
            max_running: 8,
            page_words: KvConfig::DEFAULT_PAGE_WORDS,
            kv_pages: None,
            schedule: DecodeSchedule::PrefillFirst,
        }
    }
}

impl DecodeFleetConfig {
    /// Homogeneous sugar: `n` devices of one class, reference clock =
    /// the class clock.
    pub fn uniform(n: usize, class: DeviceClass) -> Self {
        let ref_mhz = class.freq_mhz;
        Self { roster: vec![class; n], ref_mhz, ..Default::default() }
    }
}

/// One finished generation request.
#[derive(Debug, Clone, PartialEq)]
pub struct GenCompletion {
    pub id: u64,
    /// The emitted token rows (`max_new_tokens × d_model`) — row `t` is
    /// the `t`-th generated token's activation.
    pub tokens: MatF32,
    /// Arrival → first token (prefill completion).
    pub ttft_cycles: u64,
    /// Completion stamp of the last token.
    pub finish_cycle: u64,
    /// Times this sequence was preempted (and later resumed).
    pub preemptions: u64,
}

/// Aggregated metrics for one decode-fleet run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DecodeMetrics {
    /// Generation requests served to completion.
    pub completed: u64,
    /// Requests rejected at admission (KV can never fit / context
    /// overflow), with reasons in [`Self::rejections`].
    pub rejected: u64,
    /// `(request id, reason)` for every rejection.
    pub rejections: Vec<(u64, String)>,
    /// Tokens emitted across all sequences.
    pub tokens: u64,
    /// Time-to-first-token (arrival → prefill completion).
    pub ttft: LatencyHistogram,
    /// Inter-token latency (gap between consecutive token emissions of
    /// one sequence, including any preemption/resume gap).
    pub itl: LatencyHistogram,
    /// End-to-end latency (arrival → last token).
    pub e2e: LatencyHistogram,
    /// KV-pool occupancy in permille, sampled after every job.
    pub kv_occupancy_permille: LatencyHistogram,
    /// Sequences preempted to free KV pages.
    pub preemptions: u64,
    /// Prefill jobs executed (stacked prompt forwards).
    pub prefill_jobs: u64,
    /// Sequences per prefill job.
    pub prefill_batch: LatencyHistogram,
    /// Decode ticks executed.
    pub decode_ticks: u64,
    /// Running sequences per decode tick (the continuous-batch
    /// occupancy; `mean()` is the average).
    pub decode_batch: LatencyHistogram,
    /// Exact KV page-fill words across the fleet.
    pub kv_fill_words: u64,
    /// Exact KV gather (read) words across the fleet.
    pub kv_read_words: u64,
    /// Latest completion stamp.
    pub makespan_cycles: u64,
    /// Per-device counters (served = completed sequences).
    pub per_device: Vec<DeviceMetrics>,
    /// Merged simulator event counters.
    pub stats: Stats,
}

impl DecodeMetrics {
    /// Fleet decode throughput in tokens per second at `freq_mhz`.
    pub fn tokens_per_sec(&self, freq_mhz: f64) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.tokens as f64 / (self.makespan_cycles as f64 / (freq_mhz * 1e6))
    }

    /// Mean running-batch occupancy over decode ticks.
    pub fn mean_decode_occupancy(&self) -> f64 {
        self.decode_batch.mean()
    }

    /// Fleet energy with per-class leakage/voltage scaling (same
    /// accounting as the encoder fleet's `FleetMetrics::fleet_energy`).
    pub fn fleet_energy(&self, em: &EnergyModel, freq_mhz: f64) -> EnergyBreakdown {
        per_device_energy(&self.per_device, self.makespan_cycles, em, freq_mhz)
    }
}

/// Optimistic analytic cycle cost of **one decode step** (one token, one
/// sequence) on a geometry: the GEMV ideals of every per-layer site at
/// the model's midpoint context length. The decode-placement analog of
/// [`crate::cluster::analytic_encoder_cycles`].
pub fn analytic_decode_token_cycles(arch: &ArchConfig, cfg: &XformerConfig) -> u64 {
    let peak = arch.peak_macs_per_cycle();
    let ideal = |m: usize, k: usize, n: usize| -> u64 {
        GemmPlan::new(arch, m, k, n, OutputMode::Quant { shift: 0 })
            .map(|p| p.ideal_cycles())
            .unwrap_or_else(|_| ((m * k * n) as u64).div_ceil(peak).max(1))
    };
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let dh = cfg.d_head();
    let t = (cfg.seq / 2).max(1);
    let per_layer = 4 * ideal(1, d, d)
        + cfg.n_heads as u64 * (ideal(1, dh, t) + ideal(1, t, dh))
        + ideal(1, d, f)
        + ideal(1, f, d);
    (per_layer * cfg.n_layers as u64).max(1)
}

/// [`analytic_decode_token_cycles`] for a device class on the fleet's
/// reference timeline.
pub fn analytic_decode_token_ref_cycles(
    class: &DeviceClass,
    cfg: &XformerConfig,
    ref_mhz: u64,
) -> u64 {
    to_ref_cycles(analytic_decode_token_cycles(&class.arch, cfg), class.freq_mhz, ref_mhz)
        .max(1)
}

/// A sequence not currently running: a fresh arrival (`emitted` empty)
/// or a preempted one awaiting resume (`emitted` holds the tokens
/// already delivered; the resume prefill recomputes prompt + emitted
/// and re-emits nothing).
#[derive(Debug, Clone)]
struct PendingSeq {
    id: u64,
    model: usize,
    arrival: u64,
    prompt: MatF32,
    emitted: Vec<MatF32>,
    max_new: usize,
    ttft: Option<u64>,
    last_emit: u64,
    preemptions: u64,
}

impl PendingSeq {
    fn fresh(req: GenRequest) -> Self {
        Self {
            id: req.id,
            model: req.model,
            arrival: req.arrival_cycle,
            prompt: req.prompt,
            emitted: Vec::new(),
            max_new: req.max_new_tokens,
            ttft: None,
            last_emit: 0,
            preemptions: 0,
        }
    }

    /// Tokens the (re-)prefill must commit: prompt rows plus every
    /// already-emitted token (the feedback inputs).
    fn resident_tokens(&self) -> usize {
        self.prompt.rows + self.emitted.len()
    }

    /// The longest this sequence can ever grow.
    fn worst_tokens(&self) -> usize {
        self.prompt.rows + self.max_new - 1
    }

    /// The (re-)prefill input: prompt rows followed by the emitted
    /// rows (each emitted token is the next step's input).
    fn prefill_input(&self) -> MatF32 {
        let d = self.prompt.cols;
        let rows = self.resident_tokens();
        let mut x = MatF32::zeros(rows, d);
        x.data[..self.prompt.data.len()].copy_from_slice(&self.prompt.data);
        for (i, row) in self.emitted.iter().enumerate() {
            let at = (self.prompt.rows + i) * d;
            x.data[at..at + d].copy_from_slice(&row.data);
        }
        x
    }
}

/// A sequence in the running batch.
#[derive(Debug, Clone)]
struct RunSeq {
    id: u64,
    model: usize,
    /// Monotonic admission stamp — the LIFO preemption order.
    admit_order: u64,
    arrival: u64,
    prompt: MatF32,
    emitted: Vec<MatF32>,
    next_input: MatF32,
    remaining: usize,
    max_new: usize,
    ttft: u64,
    last_emit: u64,
    preemptions: u64,
}

/// Stack emitted `1 × d` rows into one `n × d` matrix.
fn stack_rows(rows: &[MatF32]) -> MatF32 {
    let cols = rows.first().map_or(0, |r| r.cols);
    let mut out = MatF32::zeros(rows.len(), cols);
    for (i, r) in rows.iter().enumerate() {
        out.data[i * cols..(i + 1) * cols].copy_from_slice(&r.data);
    }
    out
}

fn merge_report(total: &mut CgraEncoderReport, part: &CgraEncoderReport) {
    total.cycles += part.cycles;
    total.config_cycles += part.config_cycles;
    total.kernels += part.kernels;
    total.stacked_kernels += part.stacked_kernels;
    total.weight_reuse_words += part.weight_reuse_words;
    total.host_elems += part.host_elems;
    total.max_gemm_err = total.max_gemm_err.max(part.max_gemm_err);
}

/// Synthetic context key for a decode tick spanning several models: no
/// single model's context is resident afterwards, so back-to-back reuse
/// is only claimed for single-model jobs.
const MIXED_TICK_KEY: usize = usize::MAX;

/// One device's generation server: engine + paged KV + the waiting /
/// preempted / running sets, advanced one job per [`Self::step`].
pub struct DeviceDecoder {
    engine: DeviceEngine,
    kv: PagedKvCache,
    max_running: usize,
    schedule: DecodeSchedule,
    waiting: VecDeque<PendingSeq>,
    preempted: VecDeque<PendingSeq>,
    running: Vec<RunSeq>,
    admit_counter: u64,
}

impl DeviceDecoder {
    pub fn new(
        class: &DeviceClass,
        ref_mhz: u64,
        kv_cfg: KvConfig,
        max_running: usize,
        schedule: DecodeSchedule,
    ) -> Self {
        Self {
            engine: DeviceEngine::for_class(class, ref_mhz),
            kv: PagedKvCache::new(kv_cfg),
            max_running: max_running.max(1),
            schedule,
            waiting: VecDeque::new(),
            preempted: VecDeque::new(),
            running: Vec::new(),
            admit_counter: 0,
        }
    }

    /// Earliest reference cycle at which the device is free.
    pub fn free_at(&self) -> u64 {
        self.engine.free_at
    }

    /// Anything left to do (running, waiting or awaiting resume)?
    pub fn has_work(&self) -> bool {
        !self.running.is_empty() || !self.waiting.is_empty() || !self.preempted.is_empty()
    }

    /// Sequences currently in the running batch.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Sequences waiting (fresh + preempted).
    pub fn queued_len(&self) -> usize {
        self.waiting.len() + self.preempted.len()
    }

    pub fn engine(&self) -> &DeviceEngine {
        &self.engine
    }

    pub fn kv_metrics(&self) -> &KvMetrics {
        &self.kv.metrics
    }

    /// Resident-token capacity of this device's whole KV pool for a
    /// model shape (0 when one token is wider than a page) — what a
    /// capacity-aware placer checks before routing a request here.
    pub fn kv_capacity_tokens(&self, cfg: &XformerConfig) -> usize {
        self.kv.capacity_tokens(cfg.d_model, cfg.n_layers)
    }

    /// Accept a generation request, or reject it with the reason when
    /// its worst case can never be served (KV pool or context limit).
    pub fn submit(&mut self, req: GenRequest, cfg: &XformerConfig) -> Result<(), AdmitError> {
        assert!(req.max_new_tokens >= 1, "a generation request emits at least one token");
        assert!(
            req.prompt.rows >= 1 && req.prompt.cols == cfg.d_model,
            "prompt must be (≥1) × d_model"
        );
        let worst = req.prompt.rows + req.max_new_tokens - 1;
        if worst > cfg.seq {
            return Err(AdmitError::TooLarge { worst_tokens: worst, capacity_tokens: cfg.seq });
        }
        let capacity = self.kv.capacity_tokens(cfg.d_model, cfg.n_layers);
        if capacity == 0 {
            return Err(AdmitError::TokenTooWide {
                words_per_token: 2 * cfg.d_model * cfg.n_layers,
                page_words: self.kv.config().page_words,
            });
        }
        if worst > capacity {
            return Err(AdmitError::TooLarge {
                worst_tokens: worst,
                capacity_tokens: capacity,
            });
        }
        self.waiting.push_back(PendingSeq::fresh(req));
        Ok(())
    }

    /// Expected backlog on this device in reference cycles, costed per
    /// class (`token_cost`/`prefill_cost` are `[model][class]` tables;
    /// the decode-placement analog of the encoder fleet's SJF sum).
    pub fn expected_backlog(
        &self,
        class: usize,
        prefill_cost: &[Vec<u64>],
        token_cost: &[Vec<u64>],
    ) -> u64 {
        let pending: u64 = self
            .waiting
            .iter()
            .chain(self.preempted.iter())
            .map(|p| {
                // The (re-)prefill job itself emits one token, so only
                // max_new − emitted − 1 decode steps remain — the same
                // arithmetic `place` uses for an arriving request.
                prefill_cost[p.model][class].saturating_mul(p.resident_tokens() as u64)
                    + token_cost[p.model][class]
                        .saturating_mul(p.max_new.saturating_sub(p.emitted.len() + 1) as u64)
            })
            .sum();
        let running: u64 = self
            .running
            .iter()
            .map(|s| token_cost[s.model][class].saturating_mul(s.remaining as u64))
            .sum();
        pending.saturating_add(running)
    }

    /// Run one job at `now` (device must be free). Returns whether any
    /// state advanced — `false` only when there is nothing admissible
    /// and nothing running.
    pub fn step(
        &mut self,
        now: u64,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
    ) -> Result<bool> {
        debug_assert!(self.engine.free_at <= now, "step on a busy device");
        let admit_allowed = match self.schedule {
            DecodeSchedule::PrefillFirst => true,
            DecodeSchedule::DecodeFirst => self.running.is_empty(),
        };
        if admit_allowed {
            let admitted = self.admit_wave(models, metrics);
            if !admitted.is_empty() {
                self.run_prefill_job(now, admitted, models, quants, metrics, completions)?;
                return Ok(true);
            }
        }
        if self.running.is_empty() {
            return Ok(false);
        }
        let preempted_any = self.make_room(metrics);
        if self.running.is_empty() {
            return Ok(preempted_any);
        }
        self.run_tick_job(now, models, quants, metrics, completions)?;
        Ok(true)
    }

    /// Admit every admissible sequence of one model group: preempted
    /// resumes first (they are the oldest work), then fresh arrivals,
    /// FIFO within each, stopping at the batch cap, at the first
    /// capacity miss (head-of-line order is part of the determinism
    /// contract), or at a model change (one prefill job = one model).
    fn admit_wave(
        &mut self,
        models: &[DecoderModel],
        metrics: &mut DecodeMetrics,
    ) -> Vec<PendingSeq> {
        let mut admitted: Vec<PendingSeq> = Vec::new();
        loop {
            if self.running.len() + admitted.len() >= self.max_running {
                break;
            }
            let from_preempted = !self.preempted.is_empty();
            let Some((c_id, c_model, c_tokens, c_worst)) = ({
                let head = if from_preempted {
                    self.preempted.front()
                } else {
                    self.waiting.front()
                };
                head.map(|c| (c.id, c.model, c.resident_tokens(), c.worst_tokens()))
            }) else {
                break;
            };
            if admitted.first().is_some_and(|a| a.model != c_model) {
                break;
            }
            let cfg = &models[c_model].cfg;
            match self.kv.admit(c_id, cfg.d_model, cfg.n_layers, c_tokens, c_worst) {
                Ok(()) => {
                    let seq = if from_preempted {
                        self.preempted.pop_front()
                    } else {
                        self.waiting.pop_front()
                    }
                    .expect("peeked above");
                    admitted.push(seq);
                }
                Err(AdmitError::NoCapacity { .. }) => break,
                Err(e) => {
                    // Submit-time validation makes this unreachable;
                    // shed the request loudly rather than corrupting.
                    let seq = if from_preempted {
                        self.preempted.pop_front()
                    } else {
                        self.waiting.pop_front()
                    }
                    .expect("peeked above");
                    metrics.rejected += 1;
                    metrics.rejections.push((seq.id, e.to_string()));
                }
            }
        }
        admitted
    }

    /// Preempt (LIFO: highest admission stamp first) until every
    /// running sequence that needs a fresh page this tick can get one.
    fn make_room(&mut self, metrics: &mut DecodeMetrics) -> bool {
        let mut any = false;
        loop {
            let need =
                self.running.iter().filter(|s| self.kv.needs_page(s.id)).count();
            if need <= self.kv.free_pages() {
                break;
            }
            let victim = self
                .running
                .iter()
                .enumerate()
                .max_by_key(|(_, s)| s.admit_order)
                .map(|(i, _)| i)
                .expect("running is non-empty");
            let s = self.running.remove(victim);
            self.kv.release(s.id);
            metrics.preemptions += 1;
            any = true;
            self.preempted.push_back(PendingSeq {
                id: s.id,
                model: s.model,
                arrival: s.arrival,
                prompt: s.prompt,
                emitted: s.emitted,
                max_new: s.max_new,
                ttft: Some(s.ttft),
                last_emit: s.last_emit,
                preemptions: s.preemptions + 1,
            });
            if self.running.is_empty() {
                break;
            }
        }
        any
    }

    fn run_prefill_job(
        &mut self,
        now: u64,
        admitted: Vec<PendingSeq>,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
    ) -> Result<()> {
        let model_idx = admitted[0].model;
        let inputs: Vec<MatF32> = admitted.iter().map(|p| p.prefill_input()).collect();
        let pairs: Vec<(u64, &MatF32)> =
            admitted.iter().zip(&inputs).map(|(p, x)| (p.id, x)).collect();
        self.engine.sim.reset_stats();
        let (outs, report) = run_prefill_batch(
            &mut self.engine.sim,
            &models[model_idx],
            &quants[model_idx],
            &mut self.kv,
            &pairs,
        )?;
        drop(pairs);
        // Every prefill emits exactly one token: a fresh sequence's
        // first (the last prompt row's output), and — for a resume —
        // the *next* token, which the recompute produces as a free
        // byproduct (the last input row is the pending feedback row,
        // so the last output row is precisely what the next tick would
        // have computed).
        let finishing =
            admitted.iter().filter(|p| p.emitted.len() + 1 == p.max_new).count() as u64;
        let charged = self.engine.charge_run(model_idx, now, &report, finishing);
        let completion = now + charged;
        for (p, out) in admitted.into_iter().zip(outs) {
            let fresh = p.emitted.is_empty();
            let mut emitted = p.emitted;
            let ttft = match p.ttft {
                Some(t) => t,
                None => completion - p.arrival,
            };
            if fresh {
                metrics.ttft.record(completion - p.arrival);
            } else {
                // The resume-emitted token's gap spans the whole
                // preemption: honest client-visible inter-token time.
                metrics.itl.record(completion - p.last_emit);
            }
            metrics.tokens += 1;
            emitted.push(mat_row(&out, out.rows - 1));
            let last_emit = completion;
            let remaining = p.max_new - emitted.len();
            if remaining == 0 {
                self.kv.release(p.id);
                metrics.completed += 1;
                metrics.e2e.record(completion - p.arrival);
                completions.push(GenCompletion {
                    id: p.id,
                    tokens: stack_rows(&emitted),
                    ttft_cycles: ttft,
                    finish_cycle: completion,
                    preemptions: p.preemptions,
                });
            } else {
                let next_input = emitted.last().expect("prefill emitted a token").clone();
                self.running.push(RunSeq {
                    id: p.id,
                    model: p.model,
                    admit_order: self.admit_counter,
                    arrival: p.arrival,
                    prompt: p.prompt,
                    emitted,
                    next_input,
                    remaining,
                    max_new: p.max_new,
                    ttft,
                    last_emit,
                    preemptions: p.preemptions,
                });
                self.admit_counter += 1;
            }
        }
        metrics.prefill_jobs += 1;
        metrics.prefill_batch.record(inputs.len() as u64);
        metrics.kv_occupancy_permille.record(self.kv.occupancy_permille());
        metrics.makespan_cycles = metrics.makespan_cycles.max(completion);
        Ok(())
    }

    fn run_tick_job(
        &mut self,
        now: u64,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
    ) -> Result<()> {
        // Group the running batch by model (stable in admission order):
        // one stacked GEMV set per group, all groups one device job.
        let mut order: Vec<usize> = (0..self.running.len()).collect();
        order.sort_by_key(|&i| (self.running[i].model, self.running[i].admit_order));
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for &i in &order {
            let m = self.running[i].model;
            match groups.last_mut() {
                Some((gm, idxs)) if *gm == m => idxs.push(i),
                _ => groups.push((m, vec![i])),
            }
        }
        self.engine.sim.reset_stats();
        let mut report = CgraEncoderReport::default();
        let mut outs: Vec<(usize, MatF32)> = Vec::with_capacity(order.len());
        for (m, idxs) in &groups {
            let pairs: Vec<(u64, &MatF32)> = idxs
                .iter()
                .map(|&i| (self.running[i].id, &self.running[i].next_input))
                .collect();
            let (rows, part) = run_decode_tick(
                &mut self.engine.sim,
                &models[*m],
                &quants[*m],
                &mut self.kv,
                &pairs,
            )?;
            merge_report(&mut report, &part);
            for (&i, row) in idxs.iter().zip(rows) {
                outs.push((i, row));
            }
        }
        let finishing =
            outs.iter().filter(|(i, _)| self.running[*i].remaining == 1).count() as u64;
        let key = if groups.len() == 1 {
            groups[0].0
        } else {
            // A mixed tick reconfigures between its groups internally,
            // so neither a discount coming in nor one going out is
            // sound: clear the resident-context marker *before*
            // charging (two consecutive mixed ticks would otherwise
            // match on the sentinel and wrongly waive every group's
            // configuration cycles).
            self.engine.last_model = None;
            MIXED_TICK_KEY
        };
        let charged = self.engine.charge_run(key, now, &report, finishing);
        let completion = now + charged;
        for (i, row) in outs {
            let s = &mut self.running[i];
            metrics.tokens += 1;
            metrics.itl.record(completion - s.last_emit);
            s.last_emit = completion;
            s.emitted.push(row.clone());
            s.next_input = row;
            s.remaining -= 1;
        }
        let finished: Vec<usize> = self
            .running
            .iter()
            .enumerate()
            .filter(|(_, s)| s.remaining == 0)
            .map(|(i, _)| i)
            .collect();
        for &i in finished.iter().rev() {
            let s = self.running.remove(i);
            self.kv.release(s.id);
            metrics.completed += 1;
            metrics.e2e.record(completion - s.arrival);
            completions.push(GenCompletion {
                id: s.id,
                tokens: stack_rows(&s.emitted),
                ttft_cycles: s.ttft,
                finish_cycle: completion,
                preemptions: s.preemptions,
            });
        }
        metrics.decode_ticks += 1;
        metrics.decode_batch.record(order.len() as u64);
        metrics.kv_occupancy_permille.record(self.kv.occupancy_permille());
        metrics.makespan_cycles = metrics.makespan_cycles.max(completion);
        Ok(())
    }
}

/// N generation-serving devices behind a class-aware placer: the
/// decode-fleet discrete-event simulator.
pub struct DecodeFleetSim {
    pub cfg: DecodeFleetConfig,
    devices: Vec<DeviceDecoder>,
    device_classes: Vec<DeviceClass>,
    device_class: Vec<usize>,
    models: Vec<DecoderModel>,
    quants: Vec<EncoderQuant>,
    /// Analytic per-prompt-token prefill cost, `[model][class]`.
    prefill_cost: Vec<Vec<u64>>,
    /// Analytic per-token decode cost, `[model][class]`.
    token_cost: Vec<Vec<u64>>,
    ran: bool,
}

impl DecodeFleetSim {
    /// Build a decode fleet over a model catalog (weights seeded
    /// deterministically per class; static causal calibration per
    /// model).
    pub fn new(cfg: DecodeFleetConfig, classes: &[ModelClass], model_seed: u64) -> Self {
        assert!(!cfg.roster.is_empty(), "decode fleet needs at least one device");
        assert!(!classes.is_empty(), "decode fleet needs at least one model class");
        assert!(cfg.ref_mhz > 0, "reference clock must be positive");
        let (device_classes, device_class) = DeviceClass::dedup_roster(&cfg.roster);
        let devices: Vec<DeviceDecoder> = cfg
            .roster
            .iter()
            .map(|c| {
                let kv_cfg = match cfg.kv_pages {
                    Some(pages) => KvConfig::new(cfg.page_words, pages),
                    None => KvConfig::with_page_words(c, cfg.page_words),
                };
                DeviceDecoder::new(c, cfg.ref_mhz, kv_cfg, cfg.max_running, cfg.schedule)
            })
            .collect();
        let models: Vec<DecoderModel> = classes
            .iter()
            .enumerate()
            .map(|(i, c)| DecoderModel::new(c.cfg, model_seed + i as u64))
            .collect();
        let quants: Vec<EncoderQuant> = models
            .iter()
            .enumerate()
            .map(|(i, m)| {
                EncoderQuant::calibrate_causal_seeded(
                    m,
                    (model_seed + i as u64).wrapping_add(0xDEC0DE),
                )
            })
            .collect();
        let prefill_cost: Vec<Vec<u64>> = classes
            .iter()
            .map(|mc| {
                device_classes
                    .iter()
                    .map(|dc| {
                        (analytic_encoder_ref_cycles(dc, &mc.cfg, cfg.ref_mhz)
                            / mc.cfg.seq.max(1) as u64)
                            .max(1)
                    })
                    .collect()
            })
            .collect();
        let token_cost: Vec<Vec<u64>> = classes
            .iter()
            .map(|mc| {
                device_classes
                    .iter()
                    .map(|dc| analytic_decode_token_ref_cycles(dc, &mc.cfg, cfg.ref_mhz))
                    .collect()
            })
            .collect();
        Self {
            cfg,
            devices,
            device_classes,
            device_class,
            models,
            quants,
            prefill_cost,
            token_cost,
            ran: false,
        }
    }

    /// The served model catalog (index-aligned with request `model`).
    pub fn models(&self) -> &[DecoderModel] {
        &self.models
    }

    /// Place on the device with the least expected backlog in
    /// class-aware cycles (including this request's own cost on each
    /// candidate's class), ties to the lowest index. Devices whose KV
    /// pool could never hold the request's worst case are not
    /// candidates — on a big.LITTLE fleet a long generation routes to
    /// the big class instead of being rejected at a little device; a
    /// request no device can ever hold is rejected with the reason.
    fn place(&mut self, req: GenRequest, now: u64, metrics: &mut DecodeMetrics) {
        let cfg = self.models[req.model].cfg;
        let worst = req.prompt.rows + req.max_new_tokens.saturating_sub(1);
        let candidate = (0..self.devices.len())
            .filter(|&d| {
                let cap = self.devices[d].kv_capacity_tokens(&cfg);
                worst <= cap
            })
            .min_by_key(|&d| {
                let c = self.device_class[d];
                let own = self.prefill_cost[req.model][c]
                    .saturating_mul(req.prompt.rows as u64)
                    .saturating_add(
                        self.token_cost[req.model][c]
                            .saturating_mul(req.max_new_tokens.saturating_sub(1) as u64),
                    );
                let backlog =
                    self.devices[d].expected_backlog(c, &self.prefill_cost, &self.token_cost);
                self.devices[d].free_at().max(now).saturating_add(backlog).saturating_add(own)
            });
        let Some(d) = candidate else {
            let best_cap = (0..self.devices.len())
                .map(|d| self.devices[d].kv_capacity_tokens(&cfg))
                .max()
                .unwrap_or(0);
            metrics.rejected += 1;
            metrics.rejections.push((
                req.id,
                AdmitError::TooLarge { worst_tokens: worst, capacity_tokens: best_cap }
                    .to_string(),
            ));
            return;
        };
        let id = req.id;
        if let Err(e) = self.devices[d].submit(req, &cfg) {
            metrics.rejected += 1;
            metrics.rejections.push((id, e.to_string()));
        }
    }

    /// Run the fleet over a generation request stream to completion.
    /// Returns the aggregated metrics and every completion (outputs
    /// included — the join/leave bit-identity tests compare them to
    /// solo runs). Single-shot, like the encoder fleet.
    pub fn run(
        &mut self,
        mut requests: Vec<GenRequest>,
    ) -> Result<(DecodeMetrics, Vec<GenCompletion>)> {
        assert!(!self.ran, "DecodeFleetSim::run is single-shot; build a fresh fleet per run");
        self.ran = true;
        requests.sort_by_key(|r| (r.arrival_cycle, r.id));
        let mut arrivals = requests.into_iter().peekable();
        let mut metrics = DecodeMetrics::default();
        let mut completions: Vec<GenCompletion> = Vec::new();
        let mut now: u64 = 0;
        loop {
            while arrivals.peek().is_some_and(|r| r.arrival_cycle <= now) {
                let r = arrivals.next().expect("peeked");
                self.place(r, now, &mut metrics);
            }
            for d in 0..self.devices.len() {
                while self.devices[d].free_at() <= now && self.devices[d].has_work() {
                    let progressed = self.devices[d].step(
                        now,
                        &self.models,
                        &self.quants,
                        &mut metrics,
                        &mut completions,
                    )?;
                    if !progressed {
                        break;
                    }
                }
            }
            let mut next: Option<u64> = arrivals.peek().map(|r| r.arrival_cycle);
            for d in &self.devices {
                if d.has_work() && d.free_at() > now {
                    let t = d.free_at();
                    next = Some(next.map_or(t, |n| n.min(t)));
                }
            }
            match next {
                Some(t) => {
                    debug_assert!(t > now, "event horizon must advance");
                    now = t;
                }
                None => break,
            }
        }
        assert!(
            self.devices.iter().all(|d| !d.has_work()),
            "decode fleet ended with unserved work — scheduling invariant broken"
        );
        metrics.per_device = self
            .devices
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let e = d.engine();
                let class = &self.device_classes[self.device_class[i]];
                DeviceMetrics {
                    served: e.served,
                    busy_cycles: e.busy_cycles,
                    steals: 0,
                    stats: e.stats.clone(),
                    leakage_scale: class.leakage_scale(),
                    dynamic_scale: class.dynamic_scale(),
                }
            })
            .collect();
        for d in &self.devices {
            metrics.stats.merge(&d.engine().stats);
            metrics.kv_fill_words += d.kv_metrics().fill_words;
            metrics.kv_read_words += d.kv_metrics().read_words;
        }
        Ok((metrics, completions))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;

    fn tiny_classes() -> Vec<ModelClass> {
        vec![ModelClass {
            name: "gen-tiny",
            cfg: XformerConfig { n_layers: 1, seq: 8, d_model: 16, n_heads: 2, d_ff: 32 },
            weight: 1.0,
            sla_ms: 0.0,
            priority: 0,
        }]
    }

    fn gen_req(id: u64, prompt_rows: usize, max_new: usize, arrival: u64) -> GenRequest {
        let mut rng = XorShiftRng::new(100 + id);
        let mut prompt = MatF32::zeros(prompt_rows, 16);
        for v in &mut prompt.data {
            *v = rng.normal() * 0.5;
        }
        GenRequest { id, model: 0, prompt, max_new_tokens: max_new, arrival_cycle: arrival }
    }

    fn single_device_cfg() -> DecodeFleetConfig {
        DecodeFleetConfig {
            roster: vec![DeviceClass::paper()],
            ref_mhz: 100,
            max_running: 4,
            ..Default::default()
        }
    }

    #[test]
    fn serves_generation_stream_with_phase_metrics() {
        let classes = tiny_classes();
        let reqs = vec![gen_req(0, 3, 4, 0), gen_req(1, 2, 3, 1_000)];
        let mut fleet = DecodeFleetSim::new(single_device_cfg(), &classes, 42);
        let (m, done) = fleet.run(reqs).unwrap();
        assert_eq!(m.completed, 2);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.tokens, 7, "4 + 3 tokens emitted");
        assert_eq!(done.len(), 2);
        for c in &done {
            let want = if c.id == 0 { 4 } else { 3 };
            assert_eq!(c.tokens.rows, want);
            assert!(c.tokens.data.iter().all(|v| v.is_finite()));
        }
        assert_eq!(m.ttft.count(), 2);
        assert!(m.ttft.p50() > 0);
        assert_eq!(m.itl.count() as u64, m.tokens - 2, "every non-first token has an ITL");
        assert!(m.decode_ticks > 0 && m.prefill_jobs > 0);
        assert!(m.kv_fill_words > 0 && m.kv_read_words > 0);
        assert!(m.makespan_cycles > 0);
        assert!(m.tokens_per_sec(100.0) > 0.0);
        assert_eq!(m.per_device.len(), 1);
        assert_eq!(m.per_device[0].served, 2);
    }

    #[test]
    fn decode_fleet_is_seed_deterministic() {
        let classes = tiny_classes();
        let mk = || {
            let reqs =
                vec![gen_req(0, 3, 3, 0), gen_req(1, 4, 4, 500), gen_req(2, 2, 5, 500)];
            let mut fleet = DecodeFleetSim::new(single_device_cfg(), &classes, 42);
            fleet.run(reqs).unwrap()
        };
        let (m1, c1) = mk();
        let (m2, c2) = mk();
        assert_eq!(m1, m2, "decode metrics must be a pure function of the inputs");
        assert_eq!(c1, c2, "completions (outputs included) must be reproducible");
    }

    #[test]
    fn kv_pressure_preempts_and_still_completes_everything() {
        // 3 pages of 256 words; 32 words/token → 8 tokens/page. Three
        // sequences of worst case 7 tokens each need 1 page apiece at
        // first, but growth across the page boundary cannot happen —
        // so shrink pages instead: 64 words = 2 tokens per page, 3
        // sequences × up to 7 tokens ≫ 6 resident tokens → pressure.
        let classes = tiny_classes();
        let cfg = DecodeFleetConfig {
            roster: vec![DeviceClass::paper()],
            ref_mhz: 100,
            max_running: 4,
            page_words: 64,
            kv_pages: Some(3),
            ..Default::default()
        };
        let reqs = vec![gen_req(0, 2, 5, 0), gen_req(1, 2, 5, 0), gen_req(2, 2, 5, 0)];
        let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
        let (m, done) = fleet.run(reqs).unwrap();
        assert_eq!(m.completed, 3, "pressure must delay, never lose, sequences");
        assert!(m.preemptions > 0, "the tiny pool must force preemption");
        assert!(done.iter().any(|c| c.preemptions > 0));
        assert_eq!(m.tokens, 15);
        for c in &done {
            assert_eq!(c.tokens.rows, 5);
        }
    }

    #[test]
    fn impossible_requests_are_rejected_with_reasons() {
        let classes = tiny_classes();
        // Context limit is 8: prompt 6 + 4 new = worst 9 > 8.
        let reqs = vec![gen_req(0, 6, 4, 0), gen_req(1, 2, 2, 0)];
        let mut fleet = DecodeFleetSim::new(single_device_cfg(), &classes, 42);
        let (m, done) = fleet.run(reqs).unwrap();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.rejections.len(), 1);
        assert_eq!(m.rejections[0].0, 0);
        assert!(
            m.rejections[0].1.contains("never fit"),
            "reason must be printable: {}",
            m.rejections[0].1
        );
        assert_eq!(m.completed, 1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
    }

    #[test]
    fn placement_routes_kv_heavy_requests_to_the_big_class() {
        // wpt = 2·64·1 = 128 words/token; 192-word pages hold 1 token,
        // so the little class's pool (4096/192 = 21 pages) can never
        // hold a 22-token worst case while the big class (42 pages)
        // can. Capacity-aware placement must route there instead of
        // rejecting at the little device.
        let classes = vec![ModelClass {
            name: "kv-heavy",
            cfg: XformerConfig { n_layers: 1, seq: 32, d_model: 64, n_heads: 2, d_ff: 32 },
            weight: 1.0,
            sla_ms: 0.0,
            priority: 0,
        }];
        let roster = DeviceClass::parse_roster("4x4@100:1,8x4@200:1").unwrap();
        let cfg = DecodeFleetConfig {
            roster,
            ref_mhz: 100,
            max_running: 2,
            page_words: 192,
            ..Default::default()
        };
        let mut rng = XorShiftRng::new(7);
        let mut prompt = MatF32::zeros(10, 64);
        for v in &mut prompt.data {
            *v = rng.normal() * 0.5;
        }
        let reqs =
            vec![GenRequest { id: 0, model: 0, prompt, max_new_tokens: 13, arrival_cycle: 0 }];
        let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
        let (m, done) = fleet.run(reqs).unwrap();
        assert_eq!(m.rejected, 0, "the big class must absorb it: {:?}", m.rejections);
        assert_eq!(m.completed, 1);
        assert_eq!(done[0].tokens.rows, 13);
        assert_eq!(m.per_device[0].served, 0, "21 pages can never hold 22 tokens");
        assert_eq!(m.per_device[1].served, 1);
    }

    #[test]
    fn continuous_batching_outruns_sequential_decode() {
        // Four simultaneous generation requests on one device: the
        // continuous batch (max_running 4) coalesces their decode
        // steps into stacked GEMVs and must finish the work sooner
        // than strictly sequential per-request decode (max_running 1).
        let classes = tiny_classes();
        let mk = |max_running: usize| {
            let reqs: Vec<GenRequest> =
                (0..4).map(|i| gen_req(i, 3, 4, 0)).collect();
            let cfg = DecodeFleetConfig {
                roster: vec![DeviceClass::paper()],
                ref_mhz: 100,
                max_running,
                ..Default::default()
            };
            let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
            fleet.run(reqs).unwrap().0
        };
        let seq = mk(1);
        let cont = mk(4);
        assert_eq!(seq.completed, 4);
        assert_eq!(cont.completed, 4);
        assert!((seq.mean_decode_occupancy() - 1.0).abs() < 1e-9);
        assert!(cont.mean_decode_occupancy() > 1.0);
        assert!(
            cont.makespan_cycles < seq.makespan_cycles,
            "continuous batching must clear the burst sooner: {} vs {}",
            cont.makespan_cycles,
            seq.makespan_cycles
        );
        assert!(cont.tokens_per_sec(100.0) > seq.tokens_per_sec(100.0));
    }
}
