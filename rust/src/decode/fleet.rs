//! Continuous batching: the stateful generation lifecycle on a device
//! fleet.
//!
//! Encoder serving is one-shot — a request is placed, runs once,
//! leaves. Generation is a **multi-step, stateful, preemptible**
//! lifecycle: a request prefills its prompt (emitting the first
//! token), then takes one decode step per further token, holding KV
//! pages the whole time. [`DeviceDecoder`] owns that lifecycle for one
//! device; [`DecodeFleetSim`] places generation requests across N
//! devices and advances the same deterministic discrete-event timeline
//! the encoder fleet uses.
//!
//! ## Iteration-level scheduling
//!
//! A device wakes whenever it is free and has work, and runs exactly
//! one **job** per wake:
//!
//! - a *prefill job* — every admissible waiting sequence of one model
//!   (preempted resumes first) prefills as one stacked causal forward;
//! - otherwise a *decode tick* — every running sequence advances one
//!   token, the projections/FFN stacked into one `B × d` GEMV per
//!   layer per site.
//!
//! Sequences therefore **join and leave the running batch at step
//!   boundaries**: an arrival never waits for the current batch to
//! finish its whole generation, only for the current tick — the
//! iteration-level batching lever (Orca, vLLM) that dominates decode
//! throughput. [`DecodeSchedule`] picks the interleaving: prefills
//! first (default — maximizes batch occupancy and TTFT fairness) or
//! decode first (drains the running batch before admitting — lower
//! inter-token jitter, serial admission).
//!
//! ## Memory pressure
//!
//! Admission and growth run against the device's [`PagedKvCache`]
//! budget. A sequence whose worst case can never fit is **rejected
//! with its reason**. When a decode tick needs pages the pool cannot
//! supply, the scheduler preempts the **most recently admitted**
//! running sequence (LIFO, the vLLM rule: the oldest sequence always
//! progresses, so the system cannot livelock), releasing its pages;
//! the victim re-queues and later *resumes* by re-prefilling its
//! prompt plus the tokens it already emitted — recomputation changes
//! timing, never outputs. Every decision depends only on simulated
//! stamps, so decode fleets are seed-deterministic end to end.

use super::engine::{mat_row, run_decode_tick, run_prefill_batch};
use super::kv::{AdmitError, KvConfig, KvMetrics, KvSeqImage, PagedKvCache};
use crate::cluster::{
    analytic_encoder_cycles, analytic_encoder_ref_cycles, per_device_energy, to_ref_cycles,
    DeviceEngine, DeviceMetrics, GenRequest, LogHistogram, ModelClass, WakeCalendar,
};
use crate::cluster::threads::{replay_into, shard_ranges, ShardObs, PHASE_SERVE};
use crate::config::{ArchConfig, DeviceClass};
use crate::obs::{EventKind, ObsConfig, ObsSink, Observer, NO_SEQ};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::gemm::{GemmPlan, OutputMode};
use crate::sim::Stats;
use crate::util::mat::MatF32;
use crate::xformer::{CgraEncoderReport, DecoderModel, EncoderQuant, XformerConfig};
use anyhow::Result;
use std::collections::{BTreeSet, VecDeque};

/// Prefill/decode interleaving policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeSchedule {
    /// Admit every admissible waiting sequence before each tick
    /// (default): highest batch occupancy, earliest TTFT for arrivals.
    PrefillFirst,
    /// Drain the running batch to empty before admitting anything new:
    /// no prefill ever interrupts decoding (lowest inter-token
    /// jitter), at the price of serial admission.
    DecodeFirst,
    /// **Chunked prefill**: prompts prefill in fixed budgets of
    /// `chunk_tokens` rows per job, strictly alternating with decode
    /// ticks whenever both kinds of work exist. A long prompt can no
    /// longer monopolize the device for its whole prefill — the
    /// running batch's inter-token latency is bounded by one chunk
    /// plus one tick instead of by the longest arriving prompt (the
    /// Sarathi-style stall-free lever; the FIG8 bench asserts the p99
    /// ITL win over [`Self::PrefillFirst`]). Chunk outputs are
    /// bit-identical to one-shot prefill for any budget
    /// ([`super::engine::run_prefill_batch`]'s resume contract).
    Chunked { chunk_tokens: usize },
}

/// Decode-fleet configuration.
#[derive(Debug, Clone)]
pub struct DecodeFleetConfig {
    /// One device per entry (mixed rosters give big.LITTLE fleets).
    pub roster: Vec<DeviceClass>,
    /// Reference clock of the fleet timeline in integer MHz.
    pub ref_mhz: u64,
    /// Most sequences one device runs concurrently (the continuous
    /// batch cap; 1 = sequential per-request decode, the baseline arm
    /// of the FIG8 bench).
    pub max_running: usize,
    /// KV page size in words (pool provisioning per class is half of
    /// L1 — see [`KvConfig::for_class`]).
    pub page_words: usize,
    /// Override the per-device page count (tests force tiny pools to
    /// exercise preemption); `None` derives it from the device class.
    pub kv_pages: Option<usize>,
    pub schedule: DecodeSchedule,
    /// Live-sequence migration: an idle, empty device may pull a
    /// waiting **or running** sequence from a loaded peer when the
    /// class-aware finish estimate (transfer cost included) beats
    /// staying put. A running sequence moves with its KV pages —
    /// serialized over the torus entry links and charged to *both*
    /// devices' timelines — and resumes decoding without recompute.
    pub migrate: bool,
    /// Route every placement to this device index (capacity checks
    /// still apply). A debugging / experiment knob: crowding one
    /// device of a multi-device fleet makes migration (with
    /// [`Self::migrate`]) deterministic and observable — the CI trace
    /// smoke and `obs_props.rs` use it to force migration flow events.
    pub pin_device: Option<usize>,
    /// Charge every prefill/decode job its analytic cycle cost through
    /// the normal `charge_run` path instead of executing the GEMMs.
    /// Scheduling, KV paging, preemption and migration decisions are
    /// unchanged (token rows come out as zeros); the `sim_speed` bench
    /// uses it to drive ≥100k-request rosters through the event loop.
    pub timing_only: bool,
    /// Worker threads for [`DecodeFleetSim::run`] (default 1: the
    /// single-threaded calendar loop). With `threads > 1` and at least
    /// two devices, each epoch's service phase fans the ready devices
    /// out across contiguous roster shards on scoped worker threads;
    /// placement, migration and the event horizon stay on the
    /// coordinator. Metrics, completions and trace bytes are
    /// bit-identical to `threads == 1` for any value — more threads
    /// than devices clamps to one device per shard.
    pub threads: usize,
    /// Disaggregated prefill/decode serving (the DistServe/Splitwise
    /// pattern): the roster splits into prefill-role and decode-role
    /// devices — on a heterogeneous roster the classes cheapest at
    /// prefill take the prefill role, a uniform roster splits in half.
    /// Prefill devices run prompts only and park the finished prefill;
    /// a fleet hand-off pass then moves each parked sequence — KV image
    /// over the entry links, the migration transfer path — to the
    /// decode device with the earliest finish estimate, where it
    /// decodes without recompute. Supersedes [`Self::migrate`] (the
    /// hand-off *is* the migration path under this mode). Outputs stay
    /// bit-identical to the unified fleet (`disagg_props.rs`).
    pub disagg: bool,
    /// Arm the fleet-wide prefix cache with this token-block size:
    /// after every fresh prompt's prefill, its leading whole blocks
    /// are snapshotted (pages copied under a synthetic id) into the
    /// device's prefix store; a later prompt sharing the prefix
    /// bitwise is served by copying those pages instead of re-running
    /// prefill, and placement becomes prefix-affine. Armed only on
    /// devices that run fresh prefills (under [`Self::disagg`]: the
    /// prefill role), so decode pools are never diluted by cache
    /// pages. `None` (default) disables the cache.
    pub prefix_block_tokens: Option<usize>,
}

impl Default for DecodeFleetConfig {
    fn default() -> Self {
        Self {
            roster: vec![DeviceClass::paper(); 4],
            ref_mhz: 100,
            max_running: 8,
            page_words: KvConfig::DEFAULT_PAGE_WORDS,
            kv_pages: None,
            schedule: DecodeSchedule::PrefillFirst,
            migrate: false,
            pin_device: None,
            timing_only: false,
            threads: 1,
            disagg: false,
            prefix_block_tokens: None,
        }
    }
}

impl DecodeFleetConfig {
    /// Homogeneous sugar: `n` devices of one class, reference clock =
    /// the class clock.
    pub fn uniform(n: usize, class: DeviceClass) -> Self {
        let ref_mhz = class.freq_mhz;
        Self { roster: vec![class; n], ref_mhz, ..Default::default() }
    }
}

/// One finished generation request.
#[derive(Debug, Clone, PartialEq)]
pub struct GenCompletion {
    pub id: u64,
    /// The emitted token rows (`max_new_tokens × d_model`) — row `t` is
    /// the `t`-th generated token's activation.
    pub tokens: MatF32,
    /// Arrival → first token (prefill completion).
    pub ttft_cycles: u64,
    /// Completion stamp of the last token.
    pub finish_cycle: u64,
    /// Times this sequence was preempted (and later resumed).
    pub preemptions: u64,
    /// Times this sequence was migrated to another device.
    pub migrations: u64,
}

/// Aggregated metrics for one decode-fleet run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DecodeMetrics {
    /// Generation requests served to completion.
    pub completed: u64,
    /// Requests rejected at admission (KV can never fit / context
    /// overflow), with reasons in [`Self::rejections`].
    pub rejected: u64,
    /// `(request id, reason)` for every rejection.
    pub rejections: Vec<(u64, String)>,
    /// Tokens emitted across all sequences.
    pub tokens: u64,
    /// Time-to-first-token (arrival → prefill completion).
    pub ttft: LogHistogram,
    /// Inter-token latency (gap between consecutive token emissions of
    /// one sequence, including any preemption/resume gap).
    pub itl: LogHistogram,
    /// End-to-end latency (arrival → last token).
    pub e2e: LogHistogram,
    /// KV-pool occupancy in permille, sampled after every job.
    pub kv_occupancy_permille: LogHistogram,
    /// Sequences preempted to free KV pages.
    pub preemptions: u64,
    /// Sequences migrated across devices (waiting or running).
    pub migrations: u64,
    /// Words moved over the entry links by migrations (KV images for
    /// running sequences, activation rows for waiting ones).
    pub migrated_words: u64,
    /// Prefill jobs executed (stacked prompt forwards; chunk jobs
    /// count individually — each occupies the device once).
    pub prefill_jobs: u64,
    /// Prefill jobs that were *partial* chunks of a longer prompt
    /// (the chunked-prefill interleaving at work).
    pub prefill_chunks: u64,
    /// Sequences per prefill job.
    pub prefill_batch: LogHistogram,
    /// Decode ticks executed.
    pub decode_ticks: u64,
    /// Running sequences per decode tick (the continuous-batch
    /// occupancy; `mean()` is the average).
    pub decode_batch: LogHistogram,
    /// Exact KV page-fill words across the fleet.
    pub kv_fill_words: u64,
    /// Exact KV gather (read) words across the fleet.
    pub kv_read_words: u64,
    /// Prompts whose shared prefix was served from a prefix store
    /// (pages copied instead of re-running prefill).
    pub prefix_hits: u64,
    /// Prompt tokens served from prefix stores across all hits.
    pub prefix_hit_tokens: u64,
    /// KV words copied pool-internally by prefix-cache hits (never
    /// counted as attention fills or reads).
    pub prefix_copied_words: u64,
    /// Prefix-cache entries evicted to free pages for live sequences.
    pub prefix_evictions: u64,
    /// Disaggregated prefill→decode hand-offs executed.
    pub handoffs: u64,
    /// Words moved over the entry links by hand-offs (counted apart
    /// from [`Self::migrated_words`] — hand-off is phase routing, not
    /// load balancing).
    pub handoff_words: u64,
    /// Latest completion stamp.
    pub makespan_cycles: u64,
    /// Per-device counters (served = completed sequences).
    pub per_device: Vec<DeviceMetrics>,
    /// Merged simulator event counters.
    pub stats: Stats,
}

impl DecodeMetrics {
    /// Fleet decode throughput in tokens per second at `freq_mhz`.
    pub fn tokens_per_sec(&self, freq_mhz: f64) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.tokens as f64 / (self.makespan_cycles as f64 / (freq_mhz * 1e6))
    }

    /// Mean running-batch occupancy over decode ticks.
    pub fn mean_decode_occupancy(&self) -> f64 {
        self.decode_batch.mean()
    }

    /// Fleet energy with per-class leakage/voltage scaling (same
    /// accounting as the encoder fleet's `FleetMetrics::fleet_energy`).
    pub fn fleet_energy(&self, em: &EnergyModel, freq_mhz: f64) -> EnergyBreakdown {
        per_device_energy(&self.per_device, self.makespan_cycles, em, freq_mhz)
    }

    /// Fold a shard worker's run-aggregate counters into this one (the
    /// threaded backend's epoch-barrier merge). Order-sensitive fields
    /// — `rejections` — append in call order, so merging shards in
    /// shard order (contiguous ascending device ranges) reproduces the
    /// reference loop's device-ascending emission order exactly.
    /// Per-device rows are built once in `finalize`, never by shards.
    pub fn merge_run(&mut self, other: DecodeMetrics) {
        debug_assert!(other.per_device.is_empty(), "shard metrics carry no per-device rows");
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.rejections.extend(other.rejections);
        self.tokens += other.tokens;
        self.ttft.merge(&other.ttft);
        self.itl.merge(&other.itl);
        self.e2e.merge(&other.e2e);
        self.kv_occupancy_permille.merge(&other.kv_occupancy_permille);
        self.preemptions += other.preemptions;
        self.migrations += other.migrations;
        self.migrated_words += other.migrated_words;
        self.prefill_jobs += other.prefill_jobs;
        self.prefill_chunks += other.prefill_chunks;
        self.prefill_batch.merge(&other.prefill_batch);
        self.decode_ticks += other.decode_ticks;
        self.decode_batch.merge(&other.decode_batch);
        self.kv_fill_words += other.kv_fill_words;
        self.kv_read_words += other.kv_read_words;
        self.prefix_hits += other.prefix_hits;
        self.prefix_hit_tokens += other.prefix_hit_tokens;
        self.prefix_copied_words += other.prefix_copied_words;
        self.prefix_evictions += other.prefix_evictions;
        self.handoffs += other.handoffs;
        self.handoff_words += other.handoff_words;
        self.makespan_cycles = self.makespan_cycles.max(other.makespan_cycles);
        self.stats.merge(&other.stats);
    }
}

/// Optimistic analytic cycle cost of **one decode step** (one token, one
/// sequence) on a geometry: the GEMV ideals of every per-layer site at
/// the model's midpoint context length. The decode-placement analog of
/// [`crate::cluster::analytic_encoder_cycles`].
pub fn analytic_decode_token_cycles(arch: &ArchConfig, cfg: &XformerConfig) -> u64 {
    let peak = arch.peak_macs_per_cycle();
    let ideal = |m: usize, k: usize, n: usize| -> u64 {
        GemmPlan::new(arch, m, k, n, OutputMode::Quant { shift: 0 })
            .map(|p| p.ideal_cycles())
            .unwrap_or_else(|_| ((m * k * n) as u64).div_ceil(peak).max(1))
    };
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let dh = cfg.d_head();
    let t = (cfg.seq / 2).max(1);
    let per_layer = 4 * ideal(1, d, d)
        + cfg.n_heads as u64 * (ideal(1, dh, t) + ideal(1, t, dh))
        + ideal(1, d, f)
        + ideal(1, f, d);
    (per_layer * cfg.n_layers as u64).max(1)
}

/// [`analytic_decode_token_cycles`] for a device class on the fleet's
/// reference timeline.
pub fn analytic_decode_token_ref_cycles(
    class: &DeviceClass,
    cfg: &XformerConfig,
    ref_mhz: u64,
) -> u64 {
    to_ref_cycles(analytic_decode_token_cycles(&class.arch, cfg), class.freq_mhz, ref_mhz)
        .max(1)
}

/// A sequence not currently running: a fresh arrival (`emitted` empty)
/// or a preempted one awaiting resume (`emitted` holds the tokens
/// already delivered; the resume prefill recomputes prompt + emitted
/// and re-emits nothing).
#[derive(Debug, Clone)]
struct PendingSeq {
    id: u64,
    model: usize,
    arrival: u64,
    prompt: MatF32,
    emitted: Vec<MatF32>,
    max_new: usize,
    ttft: Option<u64>,
    last_emit: u64,
    preemptions: u64,
    migrations: u64,
    /// Leading rows of [`Self::prefill_input`] served from the prefix
    /// cache at admission (their K/V pages were copied in); the
    /// prefill job computes only the suffix from this offset. Reset to
    /// zero on preemption — the resume re-prefills from scratch.
    prefix_done: usize,
}

impl PendingSeq {
    fn fresh(req: GenRequest) -> Self {
        Self {
            id: req.id,
            model: req.model,
            arrival: req.arrival_cycle,
            prompt: req.prompt,
            emitted: Vec::new(),
            max_new: req.max_new_tokens,
            ttft: None,
            last_emit: 0,
            preemptions: 0,
            migrations: 0,
            prefix_done: 0,
        }
    }

    /// Tokens the (re-)prefill must commit: prompt rows plus every
    /// already-emitted token (the feedback inputs).
    fn resident_tokens(&self) -> usize {
        self.prompt.rows + self.emitted.len()
    }

    /// The longest this sequence can ever grow.
    fn worst_tokens(&self) -> usize {
        self.prompt.rows + self.max_new - 1
    }

    /// The (re-)prefill input: prompt rows followed by the emitted
    /// rows (each emitted token is the next step's input).
    fn prefill_input(&self) -> MatF32 {
        let d = self.prompt.cols;
        let rows = self.resident_tokens();
        let mut x = MatF32::zeros(rows, d);
        x.data[..self.prompt.data.len()].copy_from_slice(&self.prompt.data);
        for (i, row) in self.emitted.iter().enumerate() {
            let at = (self.prompt.rows + i) * d;
            x.data[at..at + d].copy_from_slice(&row.data);
        }
        x
    }

    /// The rows the prefill job must actually compute: the full
    /// (re-)prefill input minus any prefix-cache-served leading rows
    /// (their pages are already filled, so the engine resumes at the
    /// offset exactly like a later chunk — always ≥ 1 row, because a
    /// hit never covers the whole prompt).
    fn prefill_suffix_input(&self) -> MatF32 {
        let x = self.prefill_input();
        if self.prefix_done == 0 {
            return x;
        }
        let d = x.cols;
        MatF32::from_slice(x.rows - self.prefix_done, d, &x.data[self.prefix_done * d..])
    }
}

/// A sequence in the running batch.
#[derive(Debug, Clone)]
struct RunSeq {
    id: u64,
    model: usize,
    /// Monotonic admission stamp — the LIFO preemption order.
    admit_order: u64,
    arrival: u64,
    prompt: MatF32,
    emitted: Vec<MatF32>,
    next_input: MatF32,
    remaining: usize,
    max_new: usize,
    ttft: u64,
    last_emit: u64,
    preemptions: u64,
    migrations: u64,
}

/// A prompt mid-chunked-prefill: admitted in the KV cache with `done`
/// of its `input` rows committed and filled by earlier chunks.
#[derive(Debug, Clone)]
struct ChunkState {
    seq: PendingSeq,
    /// The full (re-)prefill input (prompt + emitted feedback rows).
    input: MatF32,
    /// Rows already prefilled.
    done: usize,
}

/// Stack emitted `1 × d` rows into one `n × d` matrix.
fn stack_rows(rows: &[MatF32]) -> MatF32 {
    let cols = rows.first().map_or(0, |r| r.cols);
    let mut out = MatF32::zeros(rows.len(), cols);
    for (i, r) in rows.iter().enumerate() {
        out.data[i * cols..(i + 1) * cols].copy_from_slice(&r.data);
    }
    out
}

fn merge_report(total: &mut CgraEncoderReport, part: &CgraEncoderReport) {
    total.cycles += part.cycles;
    total.config_cycles += part.config_cycles;
    total.kernels += part.kernels;
    total.stacked_kernels += part.stacked_kernels;
    total.weight_reuse_words += part.weight_reuse_words;
    total.host_elems += part.host_elems;
    total.max_gemm_err = total.max_gemm_err.max(part.max_gemm_err);
}

/// Synthetic context key for a decode tick spanning several models: no
/// single model's context is resident afterwards, so back-to-back reuse
/// is only claimed for single-model jobs.
const MIXED_TICK_KEY: usize = usize::MAX;

/// Synthetic KV sequence ids for prefix-cache entries live above this
/// base so they can never collide with request ids (the CLI and every
/// workload generator number requests from zero upward).
const PREFIX_SEQ_BASE: u64 = 1 << 62;

/// One cached shared prefix, resident in the device's KV pool under a
/// synthetic sequence id.
#[derive(Debug, Clone)]
struct PrefixEntry {
    /// Chained per-block FNV-1a hashes over the prefix rows' bit
    /// patterns: `hashes[j]` covers blocks `0..=j` (radix-style), so a
    /// depth-`j` candidate compares one word — but a match certifies
    /// nothing about shallower depths under collision, which is why
    /// [`DeviceDecoder::best_prefix_match`] re-verifies bitwise.
    hashes: Vec<u64>,
    /// Synthetic KV sequence id holding the copied pages.
    seq: u64,
    /// The prefix rows themselves — the bitwise verification that
    /// turns a hash match into a guaranteed (not merely probable) hit.
    rows: MatF32,
    model: usize,
    /// LRU stamp from the device's prefix clock.
    last_use: u64,
}

/// Chained per-block FNV-1a hash of a prompt's leading
/// `blocks · block` rows: one running hash over every value's bit
/// pattern, seeded by the model index and snapshotted at each block
/// boundary — `out[j]` identifies the whole prefix through block `j`.
fn prefix_chain(model: usize, prompt: &MatF32, block: usize, blocks: usize) -> Vec<u64> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ (model as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let d = prompt.cols;
    let mut out = Vec::with_capacity(blocks);
    for b in 0..blocks {
        for v in &prompt.data[b * block * d..(b + 1) * block * d] {
            h ^= u64::from(v.to_bits());
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        out.push(h);
    }
    out
}

/// Per-model analytic device-cycle costs for a timing-only device
/// ([`DecodeFleetConfig::timing_only`]): jobs synthesize their
/// [`CgraEncoderReport`] from these instead of executing GEMMs.
#[derive(Debug, Clone)]
struct SynthCost {
    /// Device cycles to prefill one prompt row, per model.
    prefill_row: Vec<u64>,
    /// Device cycles per decode token (one sequence), per model.
    token: Vec<u64>,
}

/// One device's generation server: engine + paged KV + the waiting /
/// preempted / running sets, advanced one job per [`Self::step`].
pub struct DeviceDecoder {
    engine: DeviceEngine,
    kv: PagedKvCache,
    max_running: usize,
    schedule: DecodeSchedule,
    waiting: VecDeque<PendingSeq>,
    preempted: VecDeque<PendingSeq>,
    running: Vec<RunSeq>,
    /// The prompt currently mid-chunked-prefill (at most one; only the
    /// `Chunked` schedule populates it).
    chunking: Option<ChunkState>,
    /// Alternation marker for `Chunked`: true when the last job was a
    /// prefill chunk, so the next wake (with decode work present)
    /// takes a decode tick.
    last_was_prefill: bool,
    /// `(model, per-token ref cycles)` measured from the most recent
    /// single-model decode tick — the fleet harvests it into its
    /// per-class token-rate cache.
    last_tick_obs: Option<(usize, u64)>,
    /// `(model, per-prompt-row ref cycles)` measured from the most
    /// recent prefill job or chunk — the prefill analog of
    /// [`Self::last_tick_obs`], harvested into the fleet's
    /// per-(model, class) prefill-rate cache.
    last_prefill_obs: Option<(usize, u64)>,
    /// Analytic cost table for timing-only runs; `None` executes jobs
    /// for real.
    synth: Option<SynthCost>,
    /// Disaggregation role: this device runs prefills only — its
    /// "running" sequences are finished prefills parked for hand-off
    /// to a decode device ([`DecodeFleetSim`]'s hand-off pass); it
    /// never ticks them and sizes admission by prompt, not worst case.
    prefill_only: bool,
    /// Prefix-cache block size in tokens; `None` disarms the cache on
    /// this device.
    prefix_block: Option<usize>,
    /// Cached shared prefixes, each holding pool pages under a
    /// synthetic id above [`PREFIX_SEQ_BASE`].
    prefix_store: Vec<PrefixEntry>,
    /// Next synthetic id offset above [`PREFIX_SEQ_BASE`].
    prefix_next_id: u64,
    /// Monotonic LRU clock for [`PrefixEntry::last_use`].
    prefix_clock: u64,
    admit_counter: u64,
}

impl DeviceDecoder {
    pub fn new(
        class: &DeviceClass,
        ref_mhz: u64,
        kv_cfg: KvConfig,
        max_running: usize,
        schedule: DecodeSchedule,
    ) -> Self {
        Self {
            engine: DeviceEngine::for_class(class, ref_mhz),
            kv: PagedKvCache::new(kv_cfg),
            max_running: max_running.max(1),
            schedule,
            waiting: VecDeque::new(),
            preempted: VecDeque::new(),
            running: Vec::new(),
            chunking: None,
            last_was_prefill: false,
            last_tick_obs: None,
            last_prefill_obs: None,
            synth: None,
            prefill_only: false,
            prefix_block: None,
            prefix_store: Vec::new(),
            prefix_next_id: 0,
            prefix_clock: 0,
            admit_counter: 0,
        }
    }

    /// Earliest reference cycle at which the device is free.
    pub fn free_at(&self) -> u64 {
        self.engine.free_at
    }

    /// Anything left to do (running, mid-chunk, waiting or awaiting
    /// resume)?
    pub fn has_work(&self) -> bool {
        !self.running.is_empty()
            || self.chunking.is_some()
            || !self.waiting.is_empty()
            || !self.preempted.is_empty()
    }

    /// Sequences currently in the running batch.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Sequences waiting (fresh + preempted + mid-chunk).
    pub fn queued_len(&self) -> usize {
        self.waiting.len() + self.preempted.len() + usize::from(self.chunking.is_some())
    }

    /// Take the per-token cost observed by the most recent
    /// single-model decode tick, if any (`(model, ref cycles per
    /// token)`) — the fleet's measured-rate harvest point.
    pub fn take_tick_observation(&mut self) -> Option<(usize, u64)> {
        self.last_tick_obs.take()
    }

    /// Take the per-prompt-row cost observed by the most recent
    /// prefill job or chunk, if any (`(model, ref cycles per row)`).
    pub fn take_prefill_observation(&mut self) -> Option<(usize, u64)> {
        self.last_prefill_obs.take()
    }

    /// Reference-cycle work this device performs until its **newest
    /// running** sequence (the LIFO migration candidate) emits its
    /// last token: the candidate's own remaining ticks plus each
    /// co-runner's share of those ticks — a co-runner contributes cost
    /// only while it is still active, i.e. for `min(its remaining, the
    /// candidate's remaining)` ticks. Waiting/preempted/mid-chunk
    /// backlog is *not* counted: it is served after (or interleaved
    /// with, never blocking) the candidate, so charging it to the
    /// stay-estimate made the old migration planner pull sequences off
    /// devices that would have finished them sooner locally.
    pub fn newest_running_backlog(&self, class: usize, token_cost: &[Vec<u64>]) -> Option<u64> {
        let cand = self.running.iter().max_by_key(|s| s.admit_order)?;
        let mut work = token_cost[cand.model][class].saturating_mul(cand.remaining as u64);
        for s in &self.running {
            if s.id == cand.id {
                continue;
            }
            let share = s.remaining.min(cand.remaining) as u64;
            work = work.saturating_add(token_cost[s.model][class].saturating_mul(share));
        }
        Some(work)
    }

    pub fn engine(&self) -> &DeviceEngine {
        &self.engine
    }

    pub fn kv_metrics(&self) -> &KvMetrics {
        &self.kv.metrics
    }

    /// Resident-token capacity of this device's whole KV pool for a
    /// model shape (0 when one token is wider than a page) — what a
    /// capacity-aware placer checks before routing a request here.
    pub fn kv_capacity_tokens(&self, cfg: &XformerConfig) -> usize {
        self.kv.capacity_tokens(cfg.d_model, cfg.n_layers)
    }

    /// Accept a generation request, or reject it with the reason when
    /// its worst case can never be served (KV pool or context limit).
    pub fn submit(&mut self, req: GenRequest, cfg: &XformerConfig) -> Result<(), AdmitError> {
        assert!(req.max_new_tokens >= 1, "a generation request emits at least one token");
        assert!(
            req.prompt.rows >= 1 && req.prompt.cols == cfg.d_model,
            "prompt must be (≥1) × d_model"
        );
        let worst = req.prompt.rows + req.max_new_tokens - 1;
        if worst > cfg.seq {
            return Err(AdmitError::TooLarge { worst_tokens: worst, capacity_tokens: cfg.seq });
        }
        let capacity = self.kv.capacity_tokens(cfg.d_model, cfg.n_layers);
        if capacity == 0 {
            return Err(AdmitError::TokenTooWide {
                words_per_token: 2 * cfg.d_model * cfg.n_layers,
                page_words: self.kv.config().page_words,
            });
        }
        // A prefill-only device holds at most the prompt rows — decode
        // growth happens after the hand-off, sized by the placer
        // against decode-role capacity.
        let need = if self.prefill_only { req.prompt.rows } else { worst };
        if need > capacity {
            return Err(AdmitError::TooLarge {
                worst_tokens: need,
                capacity_tokens: capacity,
            });
        }
        self.waiting.push_back(PendingSeq::fresh(req));
        Ok(())
    }

    /// Expected backlog on this device in reference cycles, costed per
    /// class (`token_cost`/`prefill_cost` are `[model][class]` tables;
    /// the decode-placement analog of the encoder fleet's SJF sum).
    pub fn expected_backlog(
        &self,
        class: usize,
        prefill_cost: &[Vec<u64>],
        token_cost: &[Vec<u64>],
    ) -> u64 {
        let pending: u64 = self
            .waiting
            .iter()
            .chain(self.preempted.iter())
            .map(|p| {
                // The (re-)prefill job itself emits one token, so only
                // max_new − emitted − 1 decode steps remain — the same
                // arithmetic `place` uses for an arriving request.
                prefill_cost[p.model][class].saturating_mul(p.resident_tokens() as u64)
                    + token_cost[p.model][class]
                        .saturating_mul(p.max_new.saturating_sub(p.emitted.len() + 1) as u64)
            })
            .sum();
        let running: u64 = self
            .running
            .iter()
            .map(|s| token_cost[s.model][class].saturating_mul(s.remaining as u64))
            .sum();
        let chunking: u64 = self
            .chunking
            .as_ref()
            .map(|c| {
                prefill_cost[c.seq.model][class]
                    .saturating_mul((c.input.rows - c.done) as u64)
                    .saturating_add(token_cost[c.seq.model][class].saturating_mul(
                        c.seq.max_new.saturating_sub(c.seq.emitted.len() + 1) as u64,
                    ))
            })
            .unwrap_or(0);
        pending.saturating_add(running).saturating_add(chunking)
    }

    /// Run one job at `now` (device must be free). Returns whether any
    /// state advanced — `false` only when there is nothing admissible
    /// and nothing running. `obs` (with `dev`, this device's fleet
    /// index) is append-only: it never influences the job taken. It is
    /// any [`ObsSink`] — the fleet's [`Observer`] on the
    /// single-threaded paths, a worker-local buffer
    /// ([`crate::cluster::ShardObs`]) under the threaded backend.
    #[allow(clippy::too_many_arguments)]
    pub fn step<O: ObsSink>(
        &mut self,
        now: u64,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
        obs: &mut O,
        dev: usize,
    ) -> Result<bool> {
        debug_assert!(self.engine.free_at <= now, "step on a busy device");
        let admit_allowed = match self.schedule {
            DecodeSchedule::PrefillFirst => true,
            DecodeSchedule::DecodeFirst => self.running.is_empty(),
            DecodeSchedule::Chunked { chunk_tokens } => {
                return self.step_chunked(
                    now,
                    chunk_tokens,
                    models,
                    quants,
                    metrics,
                    completions,
                    obs,
                    dev,
                )
            }
        };
        if admit_allowed {
            let admitted = self.admit_wave(now, models, metrics, obs, dev);
            if !admitted.is_empty() {
                self.run_prefill_job(
                    now, admitted, models, quants, metrics, completions, obs, dev,
                )?;
                return Ok(true);
            }
        }
        // A prefill-only device never ticks: finished prefills park in
        // `running` until the fleet's hand-off pass moves them.
        if self.prefill_only || self.running.is_empty() {
            return Ok(false);
        }
        let preempted_any = self.make_room(now, metrics, obs, dev);
        if self.running.is_empty() {
            return Ok(preempted_any);
        }
        self.run_tick_job(now, models, quants, metrics, completions, obs, dev)?;
        Ok(true)
    }

    /// Pop the next queue head (preempted resumes first — they are the
    /// oldest work; FIFO within each queue) after admitting it to the
    /// KV cache with `commit_of(head)` committed tokens. Returns `None`
    /// on an empty queue, on a capacity miss (head-of-line blocking is
    /// part of the determinism contract), or when the head's model
    /// fails `model_filter`; a head that fails admission for any other
    /// reason is shed loudly with its printable reason (submit-time
    /// validation makes that unreachable) and the next head is tried.
    /// Shared by the stacked admit wave and the chunked scheduler so
    /// their admission/rejection semantics can never drift.
    #[allow(clippy::too_many_arguments)]
    fn pop_admitted_head<O: ObsSink>(
        &mut self,
        now: u64,
        commit_of: impl Fn(&PendingSeq) -> usize,
        model_filter: Option<usize>,
        models: &[DecoderModel],
        metrics: &mut DecodeMetrics,
        obs: &mut O,
        dev: usize,
    ) -> Option<PendingSeq> {
        loop {
            let from_preempted = !self.preempted.is_empty();
            let (c_id, c_model, c_tokens, c_worst) = {
                let head = if from_preempted {
                    self.preempted.front()
                } else {
                    self.waiting.front()
                }?;
                // A prefill-only device only ever holds the resident
                // rows; decode growth happens after the hand-off.
                let worst = if self.prefill_only {
                    head.resident_tokens()
                } else {
                    head.worst_tokens()
                };
                (head.id, head.model, commit_of(head), worst)
            };
            if model_filter.is_some_and(|m| m != c_model) {
                return None;
            }
            let cfg = &models[c_model].cfg;
            match self.kv.admit(c_id, cfg.d_model, cfg.n_layers, c_tokens, c_worst) {
                Ok(()) => {
                    if obs.enabled() {
                        obs.record(now, dev, c_id, EventKind::KvAdmit { tokens: c_tokens });
                        if from_preempted {
                            obs.record(now, dev, c_id, EventKind::Resume);
                        }
                    }
                    let mut seq = if from_preempted {
                        self.preempted.pop_front()
                    } else {
                        self.waiting.pop_front()
                    }
                    .expect("peeked above");
                    self.try_prefix_hit(&mut seq, c_tokens, now, metrics, obs, dev);
                    return Some(seq);
                }
                Err(AdmitError::NoCapacity { .. }) => {
                    // Pages held by cold prefix-cache entries must
                    // never block live work: evict LRU-first and
                    // retry; give up only when nothing is left to
                    // evict (the usual wait-or-preempt cue).
                    if self.evict_one_prefix(metrics) {
                        continue;
                    }
                    return None;
                }
                Err(e) => {
                    let seq = if from_preempted {
                        self.preempted.pop_front()
                    } else {
                        self.waiting.pop_front()
                    }
                    .expect("peeked above");
                    metrics.rejected += 1;
                    if obs.enabled() {
                        obs.record(now, dev, seq.id, EventKind::Reject { reason: e.to_string() });
                    }
                    metrics.rejections.push((seq.id, e.to_string()));
                }
            }
        }
    }

    /// Admit every admissible sequence of one model group: preempted
    /// resumes first, FIFO within each queue, stopping at the batch
    /// cap, at the first capacity miss, or at a model change (one
    /// prefill job = one model).
    fn admit_wave<O: ObsSink>(
        &mut self,
        now: u64,
        models: &[DecoderModel],
        metrics: &mut DecodeMetrics,
        obs: &mut O,
        dev: usize,
    ) -> Vec<PendingSeq> {
        let mut admitted: Vec<PendingSeq> = Vec::new();
        while self.running.len() + admitted.len() < self.max_running {
            let filter = admitted.first().map(|a| a.model);
            let Some(seq) = self.pop_admitted_head(
                now,
                |p| p.resident_tokens(),
                filter,
                models,
                metrics,
                obs,
                dev,
            ) else {
                break;
            };
            admitted.push(seq);
        }
        admitted
    }

    /// Preempt (LIFO: highest admission stamp first) until every
    /// running sequence that needs a fresh page this tick can get one.
    fn make_room<O: ObsSink>(
        &mut self,
        now: u64,
        metrics: &mut DecodeMetrics,
        obs: &mut O,
        dev: usize,
    ) -> bool {
        let mut any = false;
        loop {
            let need =
                self.running.iter().filter(|s| self.kv.needs_page(s.id)).count();
            if need <= self.kv.free_pages() {
                break;
            }
            // Cold prefix-cache entries go before live sequences do.
            if self.evict_one_prefix(metrics) {
                continue;
            }
            let victim = self
                .running
                .iter()
                .enumerate()
                .max_by_key(|(_, s)| s.admit_order)
                .map(|(i, _)| i)
                .expect("running is non-empty");
            let s = self.running.remove(victim);
            self.kv.release(s.id);
            metrics.preemptions += 1;
            obs.record(now, dev, s.id, EventKind::Preempt);
            any = true;
            self.preempted.push_back(PendingSeq {
                id: s.id,
                model: s.model,
                arrival: s.arrival,
                prompt: s.prompt,
                emitted: s.emitted,
                max_new: s.max_new,
                ttft: Some(s.ttft),
                last_emit: s.last_emit,
                preemptions: s.preemptions + 1,
                migrations: s.migrations,
                prefix_done: 0,
            });
            if self.running.is_empty() {
                break;
            }
        }
        any
    }

    /// Deepest cached prefix matching this prompt: for each same-model
    /// entry, walk candidate depths deepest-first, accepting depth `j`
    /// only when the chained hash at `j` matches **and** the stored
    /// rows equal the prompt's leading rows bit for bit — a chained
    /// hash match at `j` certifies nothing about shallower depths
    /// under collision, and the bitwise check makes a false hit
    /// impossible rather than merely unlikely. Returns `(store index,
    /// matched tokens)`; ties keep the first (lowest-index) entry, so
    /// the scan is deterministic.
    fn best_prefix_match(
        &self,
        model: usize,
        chain: &[u64],
        prompt: &MatF32,
        block: usize,
    ) -> Option<(usize, usize)> {
        let d = prompt.cols;
        let mut best: Option<(usize, usize)> = None;
        for (i, e) in self.prefix_store.iter().enumerate() {
            if e.model != model {
                continue;
            }
            for j in (1..=chain.len().min(e.hashes.len())).rev() {
                let words = j * block * d;
                let bitwise_eq = || {
                    e.rows.data[..words]
                        .iter()
                        .zip(&prompt.data[..words])
                        .all(|(a, b)| a.to_bits() == b.to_bits())
                };
                if chain[j - 1] == e.hashes[j - 1] && bitwise_eq() {
                    let tokens = j * block;
                    if tokens > best.map_or(0, |(_, t)| t) {
                        best = Some((i, tokens));
                    }
                    break;
                }
            }
        }
        best
    }

    /// Serve a freshly admitted prompt's shared prefix from this
    /// device's prefix store: the deepest bitwise-verified match
    /// copies its K/V pages into the new sequence (capped at
    /// `committed`, the tokens admission just committed, and at one
    /// row short of the prompt — the prefill job always computes at
    /// least one row, whose output is the first token), and
    /// `prefix_done` tells the job to start at the offset. A hit is
    /// bit-identical to recomputing by [`run_prefill_batch`]'s resume
    /// contract: a page filled by the copy reads exactly like a page
    /// filled by an earlier chunk.
    fn try_prefix_hit<O: ObsSink>(
        &mut self,
        seq: &mut PendingSeq,
        committed: usize,
        now: u64,
        metrics: &mut DecodeMetrics,
        obs: &mut O,
        dev: usize,
    ) {
        let Some(block) = self.prefix_block else { return };
        let blocks = seq.prompt.rows / block;
        if !seq.emitted.is_empty() || blocks == 0 {
            return;
        }
        let chain = prefix_chain(seq.model, &seq.prompt, block, blocks);
        let Some((idx, matched)) = self.best_prefix_match(seq.model, &chain, &seq.prompt, block)
        else {
            return;
        };
        let k = matched.min(seq.prompt.rows - 1).min(committed);
        if k == 0 {
            return;
        }
        let entry_seq = self.prefix_store[idx].seq;
        self.prefix_store[idx].last_use = self.prefix_clock;
        self.prefix_clock += 1;
        let words = self.kv.copy_prefix(seq.id, entry_seq, k);
        seq.prefix_done = k;
        metrics.prefix_hits += 1;
        metrics.prefix_hit_tokens += k as u64;
        metrics.prefix_copied_words += words;
        if obs.enabled() {
            obs.record(now, dev, seq.id, EventKind::PrefixHit { tokens: k });
        }
    }

    /// After a *fresh* prompt finishes its prefill (and before its
    /// pages can be released), snapshot its leading whole blocks into
    /// the prefix store if the pool has slack: a later prompt sharing
    /// the prefix copies these pages instead of recomputing them.
    /// Inserts never evict — live sequences always outrank cache
    /// entries — and an already-cached prefix is not duplicated.
    fn maybe_cache_prefix(&mut self, p: &PendingSeq, n_layers: usize) {
        let Some(block) = self.prefix_block else { return };
        let blocks = p.prompt.rows / block;
        if !p.emitted.is_empty() || blocks == 0 {
            return;
        }
        let tokens = blocks * block;
        let d = p.prompt.cols;
        let chain = prefix_chain(p.model, &p.prompt, block, blocks);
        if self.prefix_store.iter().any(|e| {
            e.model == p.model
                && e.hashes.len() >= blocks
                && e.hashes[blocks - 1] == chain[blocks - 1]
        }) {
            return;
        }
        if !self.kv.can_admit(d, n_layers, tokens) {
            return;
        }
        let sid = PREFIX_SEQ_BASE + self.prefix_next_id;
        self.prefix_next_id += 1;
        self.kv.admit(sid, d, n_layers, tokens, tokens).expect("can_admit checked");
        self.kv.copy_prefix(sid, p.id, tokens);
        let rows = MatF32::from_slice(tokens, d, &p.prompt.data[..tokens * d]);
        let last_use = self.prefix_clock;
        self.prefix_clock += 1;
        self.prefix_store.push(PrefixEntry {
            hashes: chain,
            seq: sid,
            rows,
            model: p.model,
            last_use,
        });
    }

    /// Drop the least-recently-used prefix-cache entry, returning its
    /// pages to the pool. `false` when the store is empty.
    /// Deterministic: LRU stamps come from a per-device counter, so
    /// the minimum is unique.
    fn evict_one_prefix(&mut self, metrics: &mut DecodeMetrics) -> bool {
        let Some(idx) = self
            .prefix_store
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_use)
            .map(|(i, _)| i)
        else {
            return false;
        };
        let e = self.prefix_store.remove(idx);
        self.kv.release(e.seq);
        metrics.prefix_evictions += 1;
        true
    }

    #[allow(clippy::too_many_arguments)]
    fn run_prefill_job<O: ObsSink>(
        &mut self,
        now: u64,
        admitted: Vec<PendingSeq>,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
        obs: &mut O,
        dev: usize,
    ) -> Result<()> {
        let model_idx = admitted[0].model;
        let n_layers = models[model_idx].cfg.n_layers;
        // Prefix-cache hits shrink the job to the uncached suffix: the
        // copied pages read exactly like pages an earlier chunk filled,
        // so the engine's offset-resume path recomputes nothing.
        let inputs: Vec<MatF32> = admitted.iter().map(|p| p.prefill_suffix_input()).collect();
        let total_rows: u64 = inputs.iter().map(|x| x.rows as u64).sum();
        self.engine.sim.reset_stats();
        let (outs, report) = if self.synth.is_some() {
            // Timing-only: the pages were committed at admission and a
            // real prefill only *fills* them, so skipping it leaves KV
            // paging (and thus preemption/migration) unchanged.
            let per = self.synth.as_ref().expect("checked").prefill_row[model_idx];
            let d = models[model_idx].cfg.d_model;
            let outs: Vec<MatF32> = inputs.iter().map(|x| MatF32::zeros(x.rows, d)).collect();
            let report = CgraEncoderReport {
                cycles: per.saturating_mul(total_rows),
                config_cycles: per / 4 + 1,
                ..Default::default()
            };
            (outs, report)
        } else {
            let pairs: Vec<(u64, &MatF32)> =
                admitted.iter().zip(&inputs).map(|(p, x)| (p.id, x)).collect();
            run_prefill_batch(
                &mut self.engine.sim,
                &models[model_idx],
                &quants[model_idx],
                &mut self.kv,
                &pairs,
            )?
        };
        // Every prefill emits exactly one token: a fresh sequence's
        // first (the last prompt row's output), and — for a resume —
        // the *next* token, which the recompute produces as a free
        // byproduct (the last input row is the pending feedback row,
        // so the last output row is precisely what the next tick would
        // have computed).
        let finishing =
            admitted.iter().filter(|p| p.emitted.len() + 1 == p.max_new).count() as u64;
        let charged = self.engine.charge_run(model_idx, now, &report, finishing);
        let completion = now + charged;
        // Measured prefill rate: this job prefilled `total_rows` prompt
        // rows in `charged` reference cycles — the per-row rate the
        // fleet's per-(model, class) prefill cache replaces its
        // analytic seed with on first observation.
        self.last_prefill_obs = Some((model_idx, (charged / total_rows.max(1)).max(1)));
        if obs.enabled() {
            let batch = admitted.len();
            let rows: usize = inputs.iter().map(|x| x.rows).sum();
            obs.record(
                now,
                dev,
                NO_SEQ,
                EventKind::Prefill {
                    model: model_idx,
                    batch,
                    rows,
                    chunk: false,
                    tokens: batch,
                    dur: charged,
                },
            );
            if obs.kernels_on() {
                obs.kernel(
                    format!("d{dev}_m{model_idx}_b{batch}"),
                    "prefill",
                    self.engine.sim.stats.clone(),
                );
            }
        }
        for (p, out) in admitted.into_iter().zip(outs) {
            self.finish_prefilled_seq(
                p,
                &out,
                completion,
                n_layers,
                metrics,
                completions,
                obs,
                dev,
            );
        }
        metrics.prefill_jobs += 1;
        metrics.prefill_batch.record(inputs.len() as u64);
        let permille = self.kv.occupancy_permille();
        metrics.kv_occupancy_permille.record(permille);
        obs.record(completion, dev, NO_SEQ, EventKind::KvOccupancy { permille });
        metrics.makespan_cycles = metrics.makespan_cycles.max(completion);
        Ok(())
    }

    /// Book the single token a completed (re-)prefill emits — a fresh
    /// sequence's first (TTFT), a resume's next (ITL spanning the whole
    /// preemption) — and move the sequence into the running batch, or
    /// complete it. Shared by the stacked prefill job and the *final*
    /// chunk of a chunked prefill so the two paths can never drift.
    #[allow(clippy::too_many_arguments)]
    fn finish_prefilled_seq<O: ObsSink>(
        &mut self,
        p: PendingSeq,
        out: &MatF32,
        completion: u64,
        n_layers: usize,
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
        obs: &mut O,
        dev: usize,
    ) {
        // Snapshot the freshly filled prefix into the cache before the
        // sequence can complete and release its pages.
        self.maybe_cache_prefix(&p, n_layers);
        let fresh = p.emitted.is_empty();
        let mut emitted = p.emitted;
        let ttft = match p.ttft {
            Some(t) => t,
            None => completion - p.arrival,
        };
        if fresh {
            metrics.ttft.record(completion - p.arrival);
        } else {
            // The resume-emitted token's gap spans the whole
            // preemption: honest client-visible inter-token time.
            metrics.itl.record(completion - p.last_emit);
        }
        metrics.tokens += 1;
        emitted.push(mat_row(out, out.rows - 1));
        let last_emit = completion;
        let remaining = p.max_new - emitted.len();
        if remaining == 0 {
            self.kv.release(p.id);
            metrics.completed += 1;
            metrics.e2e.record(completion - p.arrival);
            let latency = completion - p.arrival;
            obs.record(completion, dev, p.id, EventKind::Complete { latency });
            completions.push(GenCompletion {
                id: p.id,
                tokens: stack_rows(&emitted),
                ttft_cycles: ttft,
                finish_cycle: completion,
                preemptions: p.preemptions,
                migrations: p.migrations,
            });
        } else {
            let next_input = emitted.last().expect("prefill emitted a token").clone();
            self.running.push(RunSeq {
                id: p.id,
                model: p.model,
                admit_order: self.admit_counter,
                arrival: p.arrival,
                prompt: p.prompt,
                emitted,
                next_input,
                remaining,
                max_new: p.max_new,
                ttft,
                last_emit,
                preemptions: p.preemptions,
                migrations: p.migrations,
            });
            self.admit_counter += 1;
        }
    }

    /// One job under the `Chunked` schedule: a fixed-budget prefill
    /// chunk or a decode tick, strictly alternating whenever both
    /// kinds of work exist — a long prompt costs the running batch at
    /// most one chunk of ITL per tick instead of its whole prefill.
    #[allow(clippy::too_many_arguments)]
    fn step_chunked<O: ObsSink>(
        &mut self,
        now: u64,
        chunk_tokens: usize,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
        obs: &mut O,
        dev: usize,
    ) -> Result<bool> {
        let budget = chunk_tokens.max(1);
        let want_prefill =
            self.chunking.is_some() || !self.waiting.is_empty() || !self.preempted.is_empty();
        let want_decode = !self.prefill_only && !self.running.is_empty();
        let prefill_turn = want_prefill && !(want_decode && self.last_was_prefill);
        let chunk_ran = prefill_turn
            && self.run_chunk_job(now, budget, models, quants, metrics, completions, obs, dev)?;
        if chunk_ran {
            self.last_was_prefill = true;
            return Ok(true);
        }
        if want_decode {
            let preempted_any = self.make_room(now, metrics, obs, dev);
            if self.running.is_empty() {
                return Ok(preempted_any);
            }
            self.run_tick_job(now, models, quants, metrics, completions, obs, dev)?;
            self.last_was_prefill = false;
            return Ok(true);
        }
        Ok(false)
    }

    /// Run (or start) one fixed-budget prefill chunk. Returns whether a
    /// chunk actually ran — `false` when nothing is waiting or the KV
    /// pool cannot host the next chunk yet (ticks and completions must
    /// free pages first; the admission capacity check at submit time
    /// guarantees eventual progress).
    #[allow(clippy::too_many_arguments)]
    fn run_chunk_job<O: ObsSink>(
        &mut self,
        now: u64,
        budget: usize,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
        obs: &mut O,
        dev: usize,
    ) -> Result<bool> {
        if self.chunking.is_none() {
            // The chunking prompt will join the running batch when its
            // final chunk lands, so it counts against the batch cap.
            if self.running.len() >= self.max_running {
                return Ok(false);
            }
            let Some(seq) = self.pop_admitted_head(
                now,
                |p| p.resident_tokens().min(budget),
                None,
                models,
                metrics,
                obs,
                dev,
            ) else {
                return Ok(false);
            };
            let input = seq.prefill_input();
            // A prefix hit at admission pre-fills the leading tokens;
            // the first chunk starts at the offset.
            let done = seq.prefix_done;
            self.chunking = Some(ChunkState { seq, input, done });
        }
        let (chunk_id, chunk_done, total_rows) = {
            let st = self.chunking.as_ref().expect("set above");
            (st.seq.id, st.done, st.input.rows)
        };
        if self.kv.len(chunk_id) == chunk_done {
            // Between chunks — or a prefix hit covered the whole first
            // commit — so the next budget of rows must commit now.
            let rows = (total_rows - chunk_done).min(budget);
            loop {
                match self.kv.commit_tokens(chunk_id, rows) {
                    Ok(_) => break,
                    Err(AdmitError::NoCapacity { .. }) => {
                        if self.evict_one_prefix(metrics) {
                            continue;
                        }
                        // Mid-prompt chunk stalled on KV pressure: pages
                        // must free before the next chunk can commit. One
                        // instant per blocked attempt (re-emitted if the
                        // device is revisited while still blocked) —
                        // initial-admission blocking stays plain queue
                        // wait and emits nothing here.
                        if obs.enabled() {
                            obs.record(now, dev, chunk_id, EventKind::ChunkWait);
                        }
                        return Ok(false);
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
        let st = self.chunking.take().expect("set above");
        let model_idx = st.seq.model;
        // Committed minus already-prefilled = this chunk's rows.
        let rows = self.kv.len(st.seq.id) - st.done;
        let d = st.input.cols;
        let chunk =
            MatF32::from_slice(rows, d, &st.input.data[st.done * d..(st.done + rows) * d]);
        self.engine.sim.reset_stats();
        let (outs, report) = if self.synth.is_some() {
            let per = self.synth.as_ref().expect("checked").prefill_row[model_idx];
            let report = CgraEncoderReport {
                cycles: per.saturating_mul(rows as u64),
                config_cycles: per / 4 + 1,
                ..Default::default()
            };
            (vec![MatF32::zeros(rows, d)], report)
        } else {
            run_prefill_batch(
                &mut self.engine.sim,
                &models[model_idx],
                &quants[model_idx],
                &mut self.kv,
                &[(st.seq.id, &chunk)],
            )?
        };
        let done_after = st.done + rows;
        let is_final = done_after == st.input.rows;
        let finishing = u64::from(is_final && st.seq.emitted.len() + 1 == st.seq.max_new);
        let charged = self.engine.charge_run(model_idx, now, &report, finishing);
        let completion = now + charged;
        self.last_prefill_obs = Some((model_idx, (charged / (rows as u64).max(1)).max(1)));
        metrics.prefill_jobs += 1;
        if !is_final {
            metrics.prefill_chunks += 1;
        }
        metrics.prefill_batch.record(1);
        let permille = self.kv.occupancy_permille();
        metrics.kv_occupancy_permille.record(permille);
        metrics.makespan_cycles = metrics.makespan_cycles.max(completion);
        if obs.enabled() {
            obs.record(
                now,
                dev,
                st.seq.id,
                EventKind::Prefill {
                    model: model_idx,
                    batch: 1,
                    rows,
                    chunk: !is_final,
                    tokens: usize::from(is_final),
                    dur: charged,
                },
            );
            obs.record(completion, dev, NO_SEQ, EventKind::KvOccupancy { permille });
            if obs.kernels_on() {
                obs.kernel(
                    format!("d{dev}_m{model_idx}_chunk"),
                    "chunk",
                    self.engine.sim.stats.clone(),
                );
            }
        }
        if is_final {
            let out = outs.into_iter().next().expect("one sequence");
            let n_layers = models[model_idx].cfg.n_layers;
            self.finish_prefilled_seq(
                st.seq,
                &out,
                completion,
                n_layers,
                metrics,
                completions,
                obs,
                dev,
            );
        } else {
            self.chunking = Some(ChunkState { done: done_after, ..st });
        }
        Ok(true)
    }

    /// Youngest migratable pending sequence, viewed (`(id, model,
    /// prefill rows, remaining decode tokens, worst tokens)`) — the
    /// migration planner's probe. Waiting tail first, then preempted;
    /// the mid-chunk prompt never migrates (its pages are mid-fill).
    fn peek_pending_tail(&self) -> Option<(u64, usize, usize, usize, usize)> {
        let p = self.waiting.back().or_else(|| self.preempted.back())?;
        Some((
            p.id,
            p.model,
            p.resident_tokens(),
            p.max_new.saturating_sub(p.emitted.len() + 1),
            p.worst_tokens(),
        ))
    }

    /// Remove the sequence [`Self::peek_pending_tail`] reported.
    fn take_pending_tail(&mut self) -> Option<PendingSeq> {
        self.waiting.pop_back().or_else(|| self.preempted.pop_back())
    }

    /// Re-queue a migrated-in pending sequence (fresh arrivals wait,
    /// preempted ones resume first — the admission order the owner
    /// would have used).
    fn push_pending(&mut self, p: PendingSeq) {
        if p.emitted.is_empty() {
            self.waiting.push_back(p);
        } else {
            self.preempted.push_back(p);
        }
    }

    /// `(id, model, remaining tokens, resident KV tokens, worst
    /// tokens)` of the running sequence LIFO migration would move.
    fn peek_newest_running(&self) -> Option<(u64, usize, usize, usize, usize)> {
        self.running.iter().max_by_key(|s| s.admit_order).map(|s| {
            (s.id, s.model, s.remaining, self.kv.len(s.id), s.prompt.rows + s.max_new - 1)
        })
    }

    /// Export the most recently admitted running sequence together
    /// with its serialized KV image, releasing its pages here. The
    /// image is taken *before* the release, so a failed hand-off could
    /// always be re-admitted — the fleet checks the destination first.
    fn export_newest_running(&mut self) -> Option<(RunSeq, KvSeqImage)> {
        let idx = self
            .running
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| s.admit_order)
            .map(|(i, _)| i)?;
        let s = self.running.remove(idx);
        let image = self.kv.export_seq(s.id).expect("running sequence is resident");
        self.kv.release(s.id);
        Some((s, image))
    }

    /// Import a migrated running sequence: pages re-admitted from the
    /// image (bit-exact), decode continues here with **no recompute**.
    fn import_running(&mut self, mut s: RunSeq, image: &KvSeqImage, worst: usize) {
        self.kv
            .import_seq(s.id, image, worst)
            .expect("the migration planner checked capacity before moving");
        s.admit_order = self.admit_counter;
        self.admit_counter += 1;
        self.running.push(s);
    }

    /// Occupy this device's timeline with a migration transfer
    /// (serialization at the source, deserialization at the target),
    /// starting no earlier than `earliest`. Returns the transfer's
    /// completion stamp.
    fn charge_transfer(&mut self, earliest: u64, ref_cycles: u64) -> u64 {
        let start = self.engine.free_at.max(earliest);
        self.engine.free_at = start + ref_cycles;
        self.engine.busy_cycles += ref_cycles;
        self.engine.free_at
    }

    #[allow(clippy::too_many_arguments)]
    fn run_tick_job<O: ObsSink>(
        &mut self,
        now: u64,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
        obs: &mut O,
        dev: usize,
    ) -> Result<()> {
        // Group the running batch by model (stable in admission order):
        // one stacked GEMV set per group, all groups one device job.
        let mut order: Vec<usize> = (0..self.running.len()).collect();
        order.sort_by_key(|&i| (self.running[i].model, self.running[i].admit_order));
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for &i in &order {
            let m = self.running[i].model;
            match groups.last_mut() {
                Some((gm, idxs)) if *gm == m => idxs.push(i),
                _ => groups.push((m, vec![i])),
            }
        }
        self.engine.sim.reset_stats();
        let mut report = CgraEncoderReport::default();
        let mut outs: Vec<(usize, MatF32)> = Vec::with_capacity(order.len());
        if self.synth.is_some() {
            // Timing-only tick: commit each sequence's token slot (the
            // page-allocation side effect a real tick has — preemption
            // pressure must be identical), skip the GEMVs.
            for (m, idxs) in &groups {
                let per = self.synth.as_ref().expect("checked").token[*m];
                let d = models[*m].cfg.d_model;
                for &i in idxs {
                    let id = self.running[i].id;
                    self.kv.begin_token(id)?;
                    outs.push((i, MatF32::zeros(1, d)));
                }
                let part = CgraEncoderReport {
                    cycles: per.saturating_mul(idxs.len() as u64),
                    config_cycles: per / 4 + 1,
                    ..Default::default()
                };
                merge_report(&mut report, &part);
            }
        } else {
            for (m, idxs) in &groups {
                let pairs: Vec<(u64, &MatF32)> = idxs
                    .iter()
                    .map(|&i| (self.running[i].id, &self.running[i].next_input))
                    .collect();
                let (rows, part) = run_decode_tick(
                    &mut self.engine.sim,
                    &models[*m],
                    &quants[*m],
                    &mut self.kv,
                    &pairs,
                )?;
                merge_report(&mut report, &part);
                for (&i, row) in idxs.iter().zip(rows) {
                    outs.push((i, row));
                }
            }
        }
        let finishing =
            outs.iter().filter(|(i, _)| self.running[*i].remaining == 1).count() as u64;
        let key = if groups.len() == 1 {
            groups[0].0
        } else {
            // A mixed tick reconfigures between its groups internally,
            // so neither a discount coming in nor one going out is
            // sound: clear the resident-context marker *before*
            // charging (two consecutive mixed ticks would otherwise
            // match on the sentinel and wrongly waive every group's
            // configuration cycles).
            self.engine.last_model = None;
            MIXED_TICK_KEY
        };
        let charged = self.engine.charge_run(key, now, &report, finishing);
        let completion = now + charged;
        // Measured decode rate: a single-model tick of B sequences cost
        // `charged` reference cycles — `charged / B` per token is what
        // the fleet's per-(model, class) cache replaces its analytic
        // seed with on first observation.
        self.last_tick_obs = if groups.len() == 1 {
            Some((groups[0].0, (charged / order.len() as u64).max(1)))
        } else {
            None
        };
        for (i, row) in outs {
            let s = &mut self.running[i];
            metrics.tokens += 1;
            metrics.itl.record(completion - s.last_emit);
            s.last_emit = completion;
            s.emitted.push(row.clone());
            s.next_input = row;
            s.remaining -= 1;
        }
        let finished: Vec<usize> = self
            .running
            .iter()
            .enumerate()
            .filter(|(_, s)| s.remaining == 0)
            .map(|(i, _)| i)
            .collect();
        for &i in finished.iter().rev() {
            let s = self.running.remove(i);
            self.kv.release(s.id);
            metrics.completed += 1;
            metrics.e2e.record(completion - s.arrival);
            if obs.enabled() {
                let latency = completion - s.arrival;
                obs.record(completion, dev, s.id, EventKind::Complete { latency });
            }
            completions.push(GenCompletion {
                id: s.id,
                tokens: stack_rows(&s.emitted),
                ttft_cycles: s.ttft,
                finish_cycle: completion,
                preemptions: s.preemptions,
                migrations: s.migrations,
            });
        }
        metrics.decode_ticks += 1;
        metrics.decode_batch.record(order.len() as u64);
        let permille = self.kv.occupancy_permille();
        metrics.kv_occupancy_permille.record(permille);
        metrics.makespan_cycles = metrics.makespan_cycles.max(completion);
        if obs.enabled() {
            let batch = order.len();
            obs.record(now, dev, NO_SEQ, EventKind::DecodeTick { batch, dur: charged });
            obs.record(completion, dev, NO_SEQ, EventKind::KvOccupancy { permille });
            if obs.kernels_on() {
                obs.kernel(
                    format!("d{dev}_tick_b{batch}"),
                    "decode",
                    self.engine.sim.stats.clone(),
                );
            }
        }
        Ok(())
    }
}

/// N generation-serving devices behind a class-aware placer: the
/// decode-fleet discrete-event simulator.
pub struct DecodeFleetSim {
    pub cfg: DecodeFleetConfig,
    devices: Vec<DeviceDecoder>,
    device_classes: Vec<DeviceClass>,
    device_class: Vec<usize>,
    models: Vec<DecoderModel>,
    quants: Vec<EncoderQuant>,
    /// Per-prompt-token prefill cost, `[model][class]`: the analytic
    /// encoder seed until the first *measured* prefill of that model
    /// on that class replaces it — the same observed-cost rule as
    /// [`Self::token_cost`] (placement used to trust the analytic
    /// prefill seed forever while decode rates were measured, skewing
    /// prefill-heavy placements).
    prefill_cost: Vec<Vec<u64>>,
    /// Which `prefill_cost` slots (`model · n_classes + class`) hold a
    /// measured rate.
    prefill_observed: Vec<bool>,
    /// Per-token decode cost, `[model][class]`: the analytic GEMV
    /// ideal at the midpoint context until the first *measured* tick
    /// of that model on that class replaces it (the encoder fleet's
    /// observed-cost rule, applied to decode placement).
    token_cost: Vec<Vec<u64>>,
    /// Which `token_cost` slots (`model · n_classes + class`) hold a
    /// measured rate.
    token_observed: Vec<bool>,
    ran: bool,
    /// Indexed wake-up queue for [`Self::run`]'s event loop (lazy
    /// invalidation — see [`WakeCalendar`]). [`Self::run_reference`]
    /// never consults it; `place`/migration maintain it either way.
    cal: WakeCalendar,
    /// Free devices with work: the only devices the calendar loop's
    /// service phase visits, in ascending index (BTreeSet order) to
    /// match the reference scan.
    ready: BTreeSet<usize>,
    /// Passive event/series/kernel recorder. Disabled by default; the
    /// simulator never reads it back, so enabling it cannot change a
    /// single scheduling decision (asserted by `obs_props`).
    obs: Observer,
}

impl DecodeFleetSim {
    /// Build a decode fleet over a model catalog (weights seeded
    /// deterministically per class; static causal calibration per
    /// model).
    pub fn new(cfg: DecodeFleetConfig, classes: &[ModelClass], model_seed: u64) -> Self {
        assert!(!cfg.roster.is_empty(), "decode fleet needs at least one device");
        assert!(!classes.is_empty(), "decode fleet needs at least one model class");
        assert!(cfg.ref_mhz > 0, "reference clock must be positive");
        let (device_classes, device_class) = DeviceClass::dedup_roster(&cfg.roster);
        let mut devices: Vec<DeviceDecoder> = cfg
            .roster
            .iter()
            .map(|c| {
                let kv_cfg = match cfg.kv_pages {
                    Some(pages) => KvConfig::new(cfg.page_words, pages),
                    None => KvConfig::with_page_words(c, cfg.page_words),
                };
                let mut dev =
                    DeviceDecoder::new(c, cfg.ref_mhz, kv_cfg, cfg.max_running, cfg.schedule);
                if cfg.timing_only {
                    dev.synth = Some(SynthCost {
                        prefill_row: classes
                            .iter()
                            .map(|mc| {
                                (analytic_encoder_cycles(&c.arch, &mc.cfg)
                                    / mc.cfg.seq.max(1) as u64)
                                    .max(1)
                            })
                            .collect(),
                        token: classes
                            .iter()
                            .map(|mc| analytic_decode_token_cycles(&c.arch, &mc.cfg))
                            .collect(),
                    });
                }
                dev
            })
            .collect();
        let models: Vec<DecoderModel> = classes
            .iter()
            .enumerate()
            .map(|(i, c)| DecoderModel::new(c.cfg, model_seed + i as u64))
            .collect();
        let quants: Vec<EncoderQuant> = models
            .iter()
            .enumerate()
            .map(|(i, m)| {
                EncoderQuant::calibrate_causal_seeded(
                    m,
                    (model_seed + i as u64).wrapping_add(0xDEC0DE),
                )
            })
            .collect();
        let prefill_cost: Vec<Vec<u64>> = classes
            .iter()
            .map(|mc| {
                device_classes
                    .iter()
                    .map(|dc| {
                        (analytic_encoder_ref_cycles(dc, &mc.cfg, cfg.ref_mhz)
                            / mc.cfg.seq.max(1) as u64)
                            .max(1)
                    })
                    .collect()
            })
            .collect();
        let token_cost: Vec<Vec<u64>> = classes
            .iter()
            .map(|mc| {
                device_classes
                    .iter()
                    .map(|dc| analytic_decode_token_ref_cycles(dc, &mc.cfg, cfg.ref_mhz))
                    .collect()
            })
            .collect();
        let token_observed = vec![false; classes.len() * device_classes.len()];
        let prefill_observed = vec![false; classes.len() * device_classes.len()];
        if let Some(b) = cfg.prefix_block_tokens {
            assert!(b > 0, "prefix block must be at least one token");
        }
        // Disaggregation roles: the class with the cheapest summed
        // analytic prefill cost runs prefill-only (the paper's fast
        // class — wide arrays burn through prompt GEMMs), every other
        // class holds KV and decodes. A uniform roster has no cost
        // signal, so the front half prefills — both splits are pure
        // functions of the roster, hence deterministic.
        let prefill_role: Vec<bool> = if cfg.disagg {
            assert!(
                cfg.roster.len() >= 2,
                "disaggregation needs at least one prefill and one decode device"
            );
            let class_cost: Vec<u64> = (0..device_classes.len())
                .map(|c| prefill_cost.iter().map(|row| row[c]).sum())
                .collect();
            let min = *class_cost.iter().min().expect("at least one class");
            if class_cost.iter().any(|&c| c != min) {
                device_class.iter().map(|&c| class_cost[c] == min).collect()
            } else {
                let n_prefill = (cfg.roster.len() / 2).max(1);
                (0..cfg.roster.len()).map(|d| d < n_prefill).collect()
            }
        } else {
            vec![false; cfg.roster.len()]
        };
        for (d, dev) in devices.iter_mut().enumerate() {
            dev.prefill_only = prefill_role[d];
            // The prefix cache lives where fresh prefills run: every
            // device in unified mode, prefill-only devices under
            // disaggregation (decode pools stay reserved for live KV so
            // hand-offs can always land).
            dev.prefix_block = if cfg.disagg && !prefill_role[d] {
                None
            } else {
                cfg.prefix_block_tokens
            };
        }
        Self {
            cfg,
            devices,
            device_classes,
            device_class,
            models,
            quants,
            prefill_cost,
            prefill_observed,
            token_cost,
            token_observed,
            ran: false,
            cal: WakeCalendar::new(),
            ready: BTreeSet::new(),
            obs: Observer::disabled(),
        }
    }

    /// Arm the observer before [`Self::run`]. Observation is strictly
    /// one-way: the recorded events, series and kernel rows never feed
    /// back into placement, admission or scheduling.
    pub fn enable_obs(&mut self, obs_cfg: &ObsConfig) {
        let names: Vec<String> = self
            .cfg
            .roster
            .iter()
            .enumerate()
            .map(|(d, c)| format!("dev{d} {}", c.name))
            .collect();
        self.obs = Observer::new(obs_cfg, names);
    }

    /// The observer (trace/series/kernel accessors live there).
    pub fn obs(&self) -> &Observer {
        &self.obs
    }

    /// Mutable observer access — used by the CLI to arm streaming trace
    /// output before [`Self::run`].
    pub fn obs_mut(&mut self) -> &mut Observer {
        &mut self.obs
    }

    /// The served model catalog (index-aligned with request `model`).
    pub fn models(&self) -> &[DecoderModel] {
        &self.models
    }

    /// Expected per-token decode cost of `model` on device-class index
    /// `class`, reference cycles: the measured tokens-per-cycle rate
    /// once one tick of that model has completed on that class, the
    /// analytic midpoint-GEMV seed before.
    pub fn expected_token_cost(&self, model: usize, class: usize) -> u64 {
        self.token_cost[model][class]
    }

    /// Whether `(model, class)` has had its analytic seed replaced by
    /// a measured rate.
    pub fn token_cost_observed(&self, model: usize, class: usize) -> bool {
        self.token_observed[model * self.device_classes.len() + class]
    }

    /// Record a measured per-token decode cost: the **first**
    /// observation replaces the analytic seed (later ticks are
    /// ignored, so placement estimates stay stable and deterministic —
    /// the same rule as the encoder fleet's SJF cost cache).
    fn observe_token_cost(&mut self, model: usize, class: usize, per_token: u64) {
        let slot = model * self.device_classes.len() + class;
        if !self.token_observed[slot] {
            self.token_cost[model][class] = per_token.max(1);
            self.token_observed[slot] = true;
        }
    }

    /// Expected per-prompt-row prefill cost of `model` on device-class
    /// index `class`, reference cycles: measured once one prefill of
    /// that model has completed on that class, the analytic encoder
    /// seed before.
    pub fn expected_prefill_cost(&self, model: usize, class: usize) -> u64 {
        self.prefill_cost[model][class]
    }

    /// Whether `(model, class)` has had its analytic prefill seed
    /// replaced by a measured rate.
    pub fn prefill_cost_observed(&self, model: usize, class: usize) -> bool {
        self.prefill_observed[model * self.device_classes.len() + class]
    }

    /// Record a measured per-prompt-row prefill cost — first
    /// observation wins, like [`Self::observe_token_cost`].
    fn observe_prefill_cost(&mut self, model: usize, class: usize, per_row: u64) {
        let slot = model * self.device_classes.len() + class;
        if !self.prefill_observed[slot] {
            self.prefill_cost[model][class] = per_row.max(1);
            self.prefill_observed[slot] = true;
        }
    }

    /// Place on the device with the least expected backlog in
    /// class-aware cycles (including this request's own cost on each
    /// candidate's class), ties to the lowest index. Devices whose KV
    /// pool could never hold the request's worst case are not
    /// candidates — on a big.LITTLE fleet a long generation routes to
    /// the big class instead of being rejected at a little device; a
    /// request no device can ever hold is rejected with the reason.
    fn place(&mut self, req: GenRequest, now: u64, metrics: &mut DecodeMetrics) {
        let cfg = self.models[req.model].cfg;
        let worst = req.prompt.rows + req.max_new_tokens.saturating_sub(1);
        // Prefix affinity: hash the prompt's whole blocks once, so the
        // backlog scan can credit devices already holding the prefix
        // with the rows they would not recompute.
        let chain = match self.cfg.prefix_block_tokens {
            Some(b) if req.prompt.rows / b > 0 => {
                prefix_chain(req.model, &req.prompt, b, req.prompt.rows / b)
            }
            _ => Vec::new(),
        };
        // Under disaggregation arrivals land on prefill devices (sized
        // for resident prompt rows), but the *decode* pool must be able
        // to host the worst case after the hand-off.
        let decode_cap = if self.cfg.disagg {
            (0..self.devices.len())
                .filter(|&d| !self.devices[d].prefill_only)
                .map(|d| self.devices[d].kv_capacity_tokens(&cfg))
                .max()
                .unwrap_or(0)
        } else {
            usize::MAX
        };
        // A pinned device bypasses the least-backlog scan (but never
        // the capacity filter): every request lands on one device, the
        // deterministic way to provoke crowding — and migrations — in
        // smoke runs and tests.
        let candidate = match self.cfg.pin_device {
            Some(p) if p < self.devices.len() => {
                let cap = self.devices[p].kv_capacity_tokens(&cfg);
                (worst <= cap).then_some(p)
            }
            _ if self.cfg.disagg && worst > decode_cap => None,
            _ => (0..self.devices.len())
                .filter(|&d| {
                    let cap = self.devices[d].kv_capacity_tokens(&cfg);
                    if self.cfg.disagg {
                        self.devices[d].prefill_only && req.prompt.rows <= cap
                    } else {
                        worst <= cap
                    }
                })
                .min_by_key(|&d| {
                    let c = self.device_class[d];
                    let matched = if chain.is_empty() {
                        0
                    } else {
                        let b = self.cfg.prefix_block_tokens.expect("chain nonempty");
                        self.devices[d]
                            .best_prefix_match(req.model, &chain, &req.prompt, b)
                            .map_or(0, |(_, t)| t.min(req.prompt.rows - 1))
                    } as u64;
                    let own = self.prefill_cost[req.model][c]
                        .saturating_mul(req.prompt.rows as u64 - matched)
                        .saturating_add(
                            self.token_cost[req.model][c]
                                .saturating_mul(req.max_new_tokens.saturating_sub(1) as u64),
                        );
                    let backlog =
                        self.devices[d].expected_backlog(c, &self.prefill_cost, &self.token_cost);
                    self.devices[d].free_at().max(now).saturating_add(backlog).saturating_add(own)
                }),
        };
        let Some(d) = candidate else {
            let best_cap = (0..self.devices.len())
                .filter(|&d| !self.cfg.disagg || !self.devices[d].prefill_only)
                .map(|d| self.devices[d].kv_capacity_tokens(&cfg))
                .max()
                .unwrap_or(0);
            metrics.rejected += 1;
            let reason = AdmitError::TooLarge { worst_tokens: worst, capacity_tokens: best_cap }
                .to_string();
            if self.obs.enabled() {
                self.obs.record(now, 0, req.id, EventKind::Reject { reason: reason.clone() });
            }
            metrics.rejections.push((req.id, reason));
            return;
        };
        let id = req.id;
        let model = req.model;
        if let Err(e) = self.devices[d].submit(req, &cfg) {
            metrics.rejected += 1;
            let reason = e.to_string();
            if self.obs.enabled() {
                self.obs.record(now, d, id, EventKind::Reject { reason: reason.clone() });
            }
            metrics.rejections.push((id, reason));
        } else {
            // Work arrived: a free device becomes serviceable now; a
            // busy one must be woken at its completion even if its
            // calendar entry was discarded while it sat workless.
            if self.devices[d].free_at() <= now {
                self.ready.insert(d);
            } else {
                self.cal.push(self.devices[d].free_at(), d);
            }
            if self.obs.enabled() {
                self.obs.record(now, d, id, EventKind::Arrival { model });
            }
        }
    }

    /// Transfer time for `words` over one endpoint's torus entry links
    /// at its class clock, on the reference timeline. Serialization at
    /// the source and deserialization at the destination are charged
    /// separately, each at that endpoint's own link rate and clock.
    fn transfer_ref_cycles(&self, class: usize, words: u64) -> u64 {
        let c = &self.device_classes[class];
        let dev = words.div_ceil(c.entry_link_words_per_cycle().max(1)).max(1);
        to_ref_cycles(dev, c.freq_mhz, self.cfg.ref_mhz).max(1)
    }

    /// One migration pass at `now`: idle, empty devices pull the
    /// youngest waiting — or, failing that, the most recently admitted
    /// running — sequence from a loaded peer whenever the class-aware
    /// finish estimate (remaining prefill + decode cycles at the
    /// candidate classes, transfer cost priced in) **strictly** beats
    /// staying put. Deterministic: candidates are scanned in a fixed
    /// order and the largest improvement wins (ties to the lowest
    /// destination, then source, pending before running); each
    /// sequence moves at most once per pass, so a pass terminates.
    fn rebalance(&mut self, now: u64, metrics: &mut DecodeMetrics) {
        if self.devices.len() < 2 {
            return;
        }
        let mut moved: BTreeSet<u64> = BTreeSet::new();
        loop {
            // The *pending-candidate* stay-estimate (a queued sequence
            // finishes after the whole backlog ahead of it) depends
            // only on the source, so compute it once per device per
            // pass iteration rather than once per pair. Running
            // candidates use a per-sequence estimate instead — see
            // below.
            let stay: Vec<u64> = (0..self.devices.len())
                .map(|src| {
                    self.devices[src].free_at().max(now).saturating_add(
                        self.devices[src].expected_backlog(
                            self.device_class[src],
                            &self.prefill_cost,
                            &self.token_cost,
                        ),
                    )
                })
                .collect();
            // (gain, dst, src, running-kind)
            let mut best: Option<(u64, usize, usize, bool)> = None;
            for dst in 0..self.devices.len() {
                if self.devices[dst].free_at() > now || self.devices[dst].has_work() {
                    continue;
                }
                let c_dst = self.device_class[dst];
                for src in 0..self.devices.len() {
                    if src == dst {
                        continue;
                    }
                    let stay_finish = stay[src];
                    // The hand-off is causal: serialization starts
                    // only after the source's in-flight job drains
                    // (its state — emission stamps included — is not
                    // consistent before that), and the destination
                    // deserializes only after serialization completes.
                    let c_src = self.device_class[src];
                    let src_ready = self.devices[src].free_at().max(now);
                    // Pending candidate: only activation rows move.
                    if let Some((id, model, rows, rem, worst)) =
                        self.devices[src].peek_pending_tail()
                    {
                        let cfgm = &self.models[model].cfg;
                        if !moved.contains(&id)
                            && worst <= self.devices[dst].kv_capacity_tokens(cfgm)
                        {
                            let words = (rows * cfgm.d_model) as u64;
                            let own = self.prefill_cost[model][c_dst]
                                .saturating_mul(rows as u64)
                                .saturating_add(
                                    self.token_cost[model][c_dst].saturating_mul(rem as u64),
                                );
                            let move_finish = src_ready
                                .saturating_add(self.transfer_ref_cycles(c_src, words))
                                .saturating_add(self.transfer_ref_cycles(c_dst, words))
                                .saturating_add(own);
                            let gain = stay_finish.saturating_sub(move_finish);
                            if gain > best.map_or(0, |b| b.0) {
                                best = Some((gain, dst, src, false));
                            }
                        }
                    }
                    // Running candidate: the KV image moves with it —
                    // decode resumes on the destination, no recompute.
                    // Its stay-estimate is **per-sequence**: the
                    // candidate's own remaining ticks plus the
                    // co-runners' share of them
                    // ([`DeviceDecoder::newest_running_backlog`]) —
                    // not the whole-device backlog, which charged the
                    // candidate for waiting prefills and for co-runner
                    // tokens emitted after it would already be done,
                    // and so migrated sequences their source would
                    // have finished sooner.
                    if let Some((id, model, rem, kv_len, worst)) =
                        self.devices[src].peek_newest_running()
                    {
                        let cfgm = &self.models[model].cfg;
                        if !moved.contains(&id)
                            && self.devices[dst].running_len() < self.cfg.max_running
                            && self.devices[dst].kv.can_host(
                                id,
                                cfgm.d_model,
                                cfgm.n_layers,
                                kv_len,
                                worst,
                            )
                        {
                            let stay_finish = src_ready.saturating_add(
                                self.devices[src]
                                    .newest_running_backlog(c_src, &self.token_cost)
                                    .expect("peeked a running sequence"),
                            );
                            let words = (kv_len * 2 * cfgm.d_model * cfgm.n_layers) as u64;
                            let own =
                                self.token_cost[model][c_dst].saturating_mul(rem as u64);
                            let move_finish = src_ready
                                .saturating_add(self.transfer_ref_cycles(c_src, words))
                                .saturating_add(self.transfer_ref_cycles(c_dst, words))
                                .saturating_add(own);
                            let gain = stay_finish.saturating_sub(move_finish);
                            if gain > best.map_or(0, |b| b.0) {
                                best = Some((gain, dst, src, true));
                            }
                        }
                    }
                }
            }
            let Some((_, dst, src, running)) = best else { break };
            let id = self.execute_migration(dst, src, running, now, metrics);
            moved.insert(id);
        }
    }

    /// Move one sequence `src → dst`: the source serializes after its
    /// in-flight job drains, the destination deserializes after the
    /// serialization lands (so a migrated *running* sequence can never
    /// take a tick on the destination before the state it carries —
    /// emission stamps included — exists), then re-admit. Returns the
    /// migrated sequence's id.
    fn execute_migration(
        &mut self,
        dst: usize,
        src: usize,
        running: bool,
        now: u64,
        metrics: &mut DecodeMetrics,
    ) -> u64 {
        let (c_src, c_dst) = (self.device_class[src], self.device_class[dst]);
        let (id, words) = if running {
            let (mut s, image) =
                self.devices[src].export_newest_running().expect("planner saw a candidate");
            let words = image.word_count();
            let worst = s.prompt.rows + s.max_new - 1;
            s.migrations += 1;
            let id = s.id;
            self.devices[dst].import_running(s, &image, worst);
            (id, words)
        } else {
            let mut p =
                self.devices[src].take_pending_tail().expect("planner saw a candidate");
            let words = (p.resident_tokens() * self.models[p.model].cfg.d_model) as u64;
            p.migrations += 1;
            let id = p.id;
            self.devices[dst].push_pending(p);
            (id, words)
        };
        let xfer_src = self.transfer_ref_cycles(c_src, words);
        let xfer_dst = self.transfer_ref_cycles(c_dst, words);
        // Span starts mirror `charge_transfer`'s `free_at.max(earliest)`
        // rule, read *before* each charge mutates the clocks.
        let src_start = self.devices[src].free_at().max(now);
        let handoff = self.devices[src].charge_transfer(now, xfer_src);
        let dst_start = self.devices[dst].free_at().max(handoff);
        self.devices[dst].charge_transfer(handoff, xfer_dst);
        metrics.migrations += 1;
        metrics.migrated_words += words;
        // Both endpoints' timelines now carry the transfer: re-index
        // their wake-ups (the destination went from idle-empty to
        // busy-with-work; the source's completion moved later).
        for x in [src, dst] {
            debug_assert!(self.devices[x].free_at() > now, "a transfer occupies the timeline");
            self.ready.remove(&x);
            if self.devices[x].has_work() {
                self.cal.push(self.devices[x].free_at(), x);
            }
        }
        if self.obs.enabled() {
            self.obs.record(
                src_start,
                src,
                id,
                EventKind::MigrateOut { dst, words, dur: xfer_src },
            );
            self.obs.record(
                dst_start,
                dst,
                id,
                EventKind::MigrateIn { src, words, dur: xfer_dst },
            );
        }
        id
    }

    /// One disaggregated hand-off pass at `now`: every sequence whose
    /// prefill just finished on a prefill-only device moves — KV image
    /// and all, charged at both endpoints' entry-link rates exactly
    /// like a migration — to the decode device with the best
    /// class-aware finish estimate. Unlike `rebalance` this is not an
    /// optimization: prefill devices never decode, so the pass drains
    /// *every* ready sequence (each iteration moves one, and moved
    /// sequences land on decode devices, so it terminates).
    /// Deterministic: fixed scan order, strict-improvement tie-break
    /// to the lowest destination index.
    fn disagg_handoff(&mut self, now: u64, metrics: &mut DecodeMetrics) {
        loop {
            let mut best: Option<(u64, usize, usize)> = None;
            for src in 0..self.devices.len() {
                if !self.devices[src].prefill_only {
                    continue;
                }
                let Some((id, model, rem, kv_len, worst)) =
                    self.devices[src].peek_newest_running()
                else {
                    continue;
                };
                let cfgm = &self.models[model].cfg;
                for dst in 0..self.devices.len() {
                    if self.devices[dst].prefill_only
                        || self.devices[dst].running_len() >= self.cfg.max_running
                        || !self.devices[dst].kv.can_host(
                            id,
                            cfgm.d_model,
                            cfgm.n_layers,
                            kv_len,
                            worst,
                        )
                    {
                        continue;
                    }
                    let c_dst = self.device_class[dst];
                    let est = self.devices[dst]
                        .free_at()
                        .max(now)
                        .saturating_add(self.devices[dst].expected_backlog(
                            c_dst,
                            &self.prefill_cost,
                            &self.token_cost,
                        ))
                        .saturating_add(
                            self.token_cost[model][c_dst].saturating_mul(rem as u64),
                        );
                    let better = match best {
                        None => true,
                        Some((b, _, _)) => est < b,
                    };
                    if better {
                        best = Some((est, dst, src));
                    }
                }
            }
            let Some((_, dst, src)) = best else { break };
            self.execute_handoff(dst, src, now, metrics);
        }
    }

    /// Move the newest prefilled sequence from prefill device `src` to
    /// decode device `dst`: the same export/import path as
    /// [`Self::execute_migration`] (bit-exact KV image, serialization
    /// and deserialization each charged at that endpoint's entry-link
    /// rate and clock), booked as a hand-off instead of a migration.
    fn execute_handoff(&mut self, dst: usize, src: usize, now: u64, metrics: &mut DecodeMetrics) {
        let (c_src, c_dst) = (self.device_class[src], self.device_class[dst]);
        let (mut s, image) =
            self.devices[src].export_newest_running().expect("planner saw a candidate");
        let words = image.word_count();
        let worst = s.prompt.rows + s.max_new - 1;
        s.migrations += 1;
        let id = s.id;
        self.devices[dst].import_running(s, &image, worst);
        let xfer_src = self.transfer_ref_cycles(c_src, words);
        let xfer_dst = self.transfer_ref_cycles(c_dst, words);
        // Span starts mirror `charge_transfer`'s `free_at.max(earliest)`
        // rule, read *before* each charge mutates the clocks.
        let src_start = self.devices[src].free_at().max(now);
        let handoff = self.devices[src].charge_transfer(now, xfer_src);
        let dst_start = self.devices[dst].free_at().max(handoff);
        self.devices[dst].charge_transfer(handoff, xfer_dst);
        metrics.handoffs += 1;
        metrics.handoff_words += words;
        for x in [src, dst] {
            debug_assert!(self.devices[x].free_at() > now, "a transfer occupies the timeline");
            self.ready.remove(&x);
            if self.devices[x].has_work() {
                self.cal.push(self.devices[x].free_at(), x);
            }
        }
        if self.obs.enabled() {
            self.obs.record(
                src_start,
                src,
                id,
                EventKind::HandoffOut { dst, words, dur: xfer_src },
            );
            self.obs.record(
                dst_start,
                dst,
                id,
                EventKind::HandoffIn { src, words, dur: xfer_dst },
            );
        }
    }

    /// Step `d` while it is free and has work, harvesting the
    /// measured-rate observations after every job — the one service
    /// body both event loops share, so job accounting and the
    /// observed-cost rules can never drift between them.
    fn drain_device(
        &mut self,
        d: usize,
        now: u64,
        metrics: &mut DecodeMetrics,
        completions: &mut Vec<GenCompletion>,
    ) -> Result<()> {
        while self.devices[d].free_at() <= now && self.devices[d].has_work() {
            let progressed = self.devices[d].step(
                now,
                &self.models,
                &self.quants,
                metrics,
                completions,
                &mut self.obs,
                d,
            )?;
            if let Some((model, per_token)) = self.devices[d].take_tick_observation() {
                let class = self.device_class[d];
                self.observe_token_cost(model, class, per_token);
            }
            if let Some((model, per_row)) = self.devices[d].take_prefill_observation() {
                let class = self.device_class[d];
                self.observe_prefill_cost(model, class, per_row);
            }
            if !progressed {
                break;
            }
        }
        Ok(())
    }

    /// Index work submitted before `run` (tests craft crowded devices
    /// by calling `submit` directly): free devices with work become
    /// ready, busy ones get a wake-up entry.
    fn seed_wakeups(&mut self, now: u64) {
        for d in 0..self.devices.len() {
            if !self.devices[d].has_work() {
                continue;
            }
            if self.devices[d].free_at() <= now {
                self.ready.insert(d);
            } else {
                self.cal.push(self.devices[d].free_at(), d);
            }
        }
    }

    /// Run the fleet over a generation request stream to completion,
    /// finding each next event through the indexed [`WakeCalendar`]
    /// instead of an O(D) roster scan per iteration. Returns the
    /// aggregated metrics and every completion (outputs included — the
    /// join/leave bit-identity tests compare them to solo runs).
    /// Single-shot, like the encoder fleet.
    ///
    /// Scheduling semantics are bit-identical to
    /// [`Self::run_reference`] (the conformance oracle): the calendar
    /// only finds the minimum wake-up *time*, and same-cycle devices
    /// are still served in ascending index. `tests/calendar_props.rs`
    /// pins the equivalence per seed — metrics, completions and trace
    /// bytes.
    pub fn run(
        &mut self,
        mut requests: Vec<GenRequest>,
    ) -> Result<(DecodeMetrics, Vec<GenCompletion>)> {
        if self.cfg.threads > 1 && self.cfg.roster.len() > 1 {
            return self.run_threaded(requests);
        }
        assert!(!self.ran, "DecodeFleetSim::run is single-shot; build a fresh fleet per run");
        self.ran = true;
        requests.sort_by_key(|r| (r.arrival_cycle, r.id));
        let mut arrivals = requests.into_iter().peekable();
        let mut metrics = DecodeMetrics::default();
        let mut completions: Vec<GenCompletion> = Vec::new();
        let mut now: u64 = 0;
        let mut ready_snapshot: Vec<usize> = Vec::new();
        self.seed_wakeups(now);
        loop {
            // 1. Admit every request that has arrived by `now`
            // (`place` files the target device as ready or indexes its
            // completion).
            while arrivals.peek().is_some_and(|r| r.arrival_cycle <= now) {
                let r = arrivals.next().expect("peeked");
                self.place(r, now, &mut metrics);
            }
            // 2. Serve every free device with work, ascending index
            // like the reference scan (devices not in `ready` are busy
            // or workless — the scan body is a no-op for them). A
            // device still free-with-work afterwards is admission-
            // blocked; it stays ready and is re-tried at the next
            // event, exactly as the full scan would.
            ready_snapshot.clear();
            ready_snapshot.extend(self.ready.iter().copied());
            for &d in &ready_snapshot {
                self.drain_device(d, now, &mut metrics, &mut completions)?;
                if self.devices[d].free_at() > now {
                    self.ready.remove(&d);
                    if self.devices[d].has_work() {
                        self.cal.push(self.devices[d].free_at(), d);
                    }
                } else if !self.devices[d].has_work() {
                    self.ready.remove(&d);
                }
            }
            if self.cfg.disagg {
                // Under disaggregation this pass *is* the migration
                // path — prefilled sequences must leave their prefill
                // device to decode — so it supersedes the rebalance.
                self.disagg_handoff(now, &mut metrics);
            } else if self.cfg.migrate {
                // Migrated-in work starts after its transfer lands
                // (free_at > now), so no re-stepping at this instant;
                // `execute_migration` re-indexes both endpoints.
                self.rebalance(now, &mut metrics);
            }
            // 3. Advance to the next event: the next arrival or the
            // earliest completion of a busy device *with work* — the
            // same horizon the reference scan computes, found in
            // O(log D). Entries whose stamp or workload went stale are
            // discarded on the way; any state change that makes such a
            // device relevant again (`place`, migration, a busy
            // transition) re-indexes it.
            let mut next: Option<u64> = arrivals.peek().map(|r| r.arrival_cycle);
            let devices = &self.devices;
            if let Some((t, _)) = self.cal.earliest_valid(|at, d| {
                at > now && devices[d].free_at() == at && devices[d].has_work()
            }) {
                next = Some(next.map_or(t, |n| n.min(t)));
            }
            match next {
                Some(t) => {
                    debug_assert!(t > now, "event horizon must advance");
                    now = t;
                    let devices = &self.devices;
                    let ready = &mut self.ready;
                    self.cal.pop_until(now, |_, d| {
                        if devices[d].free_at() <= now && devices[d].has_work() {
                            ready.insert(d);
                        }
                    });
                }
                None => break,
            }
        }
        Ok((self.finalize(metrics), completions))
    }

    /// The threaded backend ([`DecodeFleetConfig::threads`] > 1): the
    /// same epoch structure as [`Self::run`], with the service phase
    /// fanned out across contiguous roster shards on scoped worker
    /// threads.
    ///
    /// Placement, migration and the event horizon are inherently
    /// cross-device, so they stay on the coordinator. The per-epoch
    /// drain of ready devices is embarrassingly parallel because
    /// [`DeviceDecoder::step`] touches only device-local state — it
    /// never reads the fleet's measured-rate tables (only `place` and
    /// `rebalance` do, and both run outside the fan-out). Each worker
    /// drains its shard's due devices in ascending index into
    /// worker-local metrics / completions / observation buffers and
    /// logs its per-job measured-rate harvests; the barrier settles
    /// workers in shard order — shards are contiguous ascending device
    /// ranges, so shard-order concatenation *is* the reference loop's
    /// device-ascending epoch order — which makes metrics, completions,
    /// rejection order, first-observation-wins rate updates and trace
    /// bytes bit-identical to `threads == 1` for any thread count
    /// (pinned by `tests/calendar_props.rs`).
    fn run_threaded(
        &mut self,
        mut requests: Vec<GenRequest>,
    ) -> Result<(DecodeMetrics, Vec<GenCompletion>)> {
        assert!(!self.ran, "DecodeFleetSim::run is single-shot; build a fresh fleet per run");
        self.ran = true;
        requests.sort_by_key(|r| (r.arrival_cycle, r.id));
        let ranges = shard_ranges(self.devices.len(), self.cfg.threads);
        let mut shard_of = vec![0usize; self.devices.len()];
        for (s, r) in ranges.iter().enumerate() {
            for d in r.clone() {
                shard_of[d] = s;
            }
        }
        let mut workers: Vec<DecodeEpochWorker> =
            ranges.iter().map(|_| DecodeEpochWorker::new(&self.obs)).collect();
        let mut arrivals = requests.into_iter().peekable();
        let mut metrics = DecodeMetrics::default();
        let mut completions: Vec<GenCompletion> = Vec::new();
        let mut now: u64 = 0;
        let mut ready_snapshot: Vec<usize> = Vec::new();
        self.seed_wakeups(now);
        loop {
            // 1. Admit — coordinator-side: placement reads every
            // device's backlog and the measured-rate tables.
            while arrivals.peek().is_some_and(|r| r.arrival_cycle <= now) {
                let r = arrivals.next().expect("peeked");
                self.place(r, now, &mut metrics);
            }
            // 2. Serve every free device with work. Fewer than two due
            // shards run inline — spawning a lone worker only adds
            // latency; both branches are bit-exact, so the choice needs
            // no thread-count invariance.
            ready_snapshot.clear();
            ready_snapshot.extend(self.ready.iter().copied());
            for w in &mut workers {
                w.due.clear();
            }
            let mut due_shards = 0usize;
            for &d in &ready_snapshot {
                let w = &mut workers[shard_of[d]];
                if w.due.is_empty() {
                    due_shards += 1;
                }
                w.due.push(d);
            }
            if due_shards >= 2 {
                let models: &[DecoderModel] = &self.models;
                let quants: &[EncoderQuant] = &self.quants;
                let mut slices: Vec<&mut [DeviceDecoder]> = Vec::with_capacity(ranges.len());
                let mut rest: &mut [DeviceDecoder] = &mut self.devices;
                let mut off = 0usize;
                for r in &ranges {
                    let (head, tail) = rest.split_at_mut(r.end - off);
                    slices.push(head);
                    rest = tail;
                    off = r.end;
                }
                std::thread::scope(|s| {
                    for ((range, slice), w) in
                        ranges.iter().zip(slices).zip(workers.iter_mut())
                    {
                        if w.due.is_empty() {
                            continue;
                        }
                        let base = range.start;
                        s.spawn(move || w.run_epoch(base, slice, now, models, quants));
                    }
                });
                // Barrier: settle every worker in shard order — shards
                // are contiguous ascending device ranges, so this *is*
                // the reference's ascending-device epoch order.
                for w in workers.iter_mut() {
                    if let Some(e) = w.err.take() {
                        return Err(e);
                    }
                    metrics.merge_run(std::mem::take(&mut w.metrics));
                    completions.append(&mut w.completions);
                    for (d, model, is_prefill, per) in w.cost_log.drain(..) {
                        let class = self.device_class[d];
                        if is_prefill {
                            self.observe_prefill_cost(model, class, per);
                        } else {
                            self.observe_token_cost(model, class, per);
                        }
                    }
                    replay_into(&mut self.obs, w.obs.buf.drain(..));
                }
            } else {
                for &d in &ready_snapshot {
                    self.drain_device(d, now, &mut metrics, &mut completions)?;
                }
            }
            // Post-serve re-index (identical effect to `run`'s
            // interleaved form: draining one device never changes
            // another's state, and the calendar orders by stamp, not
            // push order).
            for &d in &ready_snapshot {
                if self.devices[d].free_at() > now {
                    self.ready.remove(&d);
                    if self.devices[d].has_work() {
                        self.cal.push(self.devices[d].free_at(), d);
                    }
                } else if !self.devices[d].has_work() {
                    self.ready.remove(&d);
                }
            }
            if self.cfg.disagg {
                // After the barrier, so the hand-off planner sees
                // exactly the rate tables the reference pass would.
                self.disagg_handoff(now, &mut metrics);
            } else if self.cfg.migrate {
                // After the barrier, so this pass sees exactly the
                // rate tables the reference pass would — identical to
                // `run`'s placement of the rebalance after all drains.
                self.rebalance(now, &mut metrics);
            }
            // 3. Advance — identical to `run`.
            let mut next: Option<u64> = arrivals.peek().map(|r| r.arrival_cycle);
            let devices = &self.devices;
            if let Some((t, _)) = self.cal.earliest_valid(|at, d| {
                at > now && devices[d].free_at() == at && devices[d].has_work()
            }) {
                next = Some(next.map_or(t, |n| n.min(t)));
            }
            match next {
                Some(t) => {
                    debug_assert!(t > now, "event horizon must advance");
                    now = t;
                    let devices = &self.devices;
                    let ready = &mut self.ready;
                    self.cal.pop_until(now, |_, d| {
                        if devices[d].free_at() <= now && devices[d].has_work() {
                            ready.insert(d);
                        }
                    });
                }
                None => break,
            }
        }
        Ok((self.finalize(metrics), completions))
    }

    /// The pre-calendar event loop, kept verbatim as the **conformance
    /// oracle**: every iteration scans the whole roster for
    /// serviceable devices and for the next event — O(D) per event,
    /// obviously correct. [`Self::run`] must stay bit-identical to
    /// this loop (metrics, completions *and* obs trace bytes per
    /// seed); any future backend (e.g. a DAM-style threaded loop) is
    /// held to the same oracle. Shares [`Self::drain_device`] (and
    /// through it every job path) with the calendar loop, so per-job
    /// accounting cannot drift — only the event-finding strategy
    /// differs.
    pub fn run_reference(
        &mut self,
        mut requests: Vec<GenRequest>,
    ) -> Result<(DecodeMetrics, Vec<GenCompletion>)> {
        assert!(!self.ran, "DecodeFleetSim::run is single-shot; build a fresh fleet per run");
        self.ran = true;
        requests.sort_by_key(|r| (r.arrival_cycle, r.id));
        let mut arrivals = requests.into_iter().peekable();
        let mut metrics = DecodeMetrics::default();
        let mut completions: Vec<GenCompletion> = Vec::new();
        let mut now: u64 = 0;
        loop {
            while arrivals.peek().is_some_and(|r| r.arrival_cycle <= now) {
                let r = arrivals.next().expect("peeked");
                self.place(r, now, &mut metrics);
            }
            for d in 0..self.devices.len() {
                self.drain_device(d, now, &mut metrics, &mut completions)?;
            }
            if self.cfg.disagg {
                // Hand-offs land with free_at > now at both endpoints,
                // so no re-stepping at this instant.
                self.disagg_handoff(now, &mut metrics);
            } else if self.cfg.migrate {
                // Migrated-in work starts after its transfer lands
                // (free_at > now), so no re-stepping at this instant.
                self.rebalance(now, &mut metrics);
            }
            let mut next: Option<u64> = arrivals.peek().map(|r| r.arrival_cycle);
            for d in &self.devices {
                if d.has_work() && d.free_at() > now {
                    let t = d.free_at();
                    next = Some(next.map_or(t, |n| n.min(t)));
                }
            }
            match next {
                Some(t) => {
                    debug_assert!(t > now, "event horizon must advance");
                    now = t;
                }
                None => break,
            }
        }
        Ok((self.finalize(metrics), completions))
    }

    /// Per-device metrics, merged stats and the observer's final flush
    /// — everything both event loops share after their last event.
    fn finalize(&mut self, mut metrics: DecodeMetrics) -> DecodeMetrics {
        assert!(
            self.devices.iter().all(|d| !d.has_work()),
            "decode fleet ended with unserved work — scheduling invariant broken"
        );
        metrics.per_device = self
            .devices
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let e = d.engine();
                let class = &self.device_classes[self.device_class[i]];
                DeviceMetrics {
                    served: e.served,
                    busy_cycles: e.busy_cycles,
                    steals: 0,
                    stats: e.stats.clone(),
                    leakage_scale: class.leakage_scale(),
                    dynamic_scale: class.dynamic_scale(),
                }
            })
            .collect();
        for d in &self.devices {
            metrics.stats.merge(&d.engine().stats);
            metrics.kv_fill_words += d.kv_metrics().fill_words;
            metrics.kv_read_words += d.kv_metrics().read_words;
        }
        self.obs.finish(metrics.makespan_cycles);
        metrics
    }
}

/// Per-shard worker state for [`DecodeFleetSim::run_threaded`]'s
/// lockstep epochs, reused across epochs so the steady state allocates
/// nothing beyond what the jobs themselves allocate.
struct DecodeEpochWorker {
    /// Global indices of this shard's ready devices this epoch,
    /// ascending (filled from the coordinator's `ready` snapshot).
    due: Vec<usize>,
    /// Worker-local observation buffer, replayed into the fleet
    /// observer at the barrier.
    obs: ShardObs,
    /// Run-aggregate counters this shard produced this epoch.
    metrics: DecodeMetrics,
    /// Completions this shard produced this epoch (ascending device,
    /// then per-device emission order — the reference order).
    completions: Vec<GenCompletion>,
    /// Measured-rate harvests in emission order: `(device, model,
    /// is_prefill, ref cycles per token/row)`. Applied
    /// first-observation-wins at the barrier, in shard order — the
    /// order the reference drain applies them in.
    cost_log: Vec<(usize, usize, bool, u64)>,
    /// First job error, if any (aborts the run at the barrier).
    err: Option<anyhow::Error>,
}

impl DecodeEpochWorker {
    fn new(obs: &Observer) -> Self {
        Self {
            due: Vec::new(),
            obs: ShardObs::mirroring(obs),
            metrics: DecodeMetrics::default(),
            completions: Vec::new(),
            cost_log: Vec::new(),
            err: None,
        }
    }

    /// Drain every due device of this worker's shard at `now` —
    /// [`DecodeFleetSim::drain_device`]'s body against worker-local
    /// sinks, with the measured-rate harvest logged instead of applied
    /// (the tables are coordinator state; the barrier applies the log
    /// in reference order). `slice` holds the shard's devices, `base`
    /// its first global index.
    fn run_epoch(
        &mut self,
        base: usize,
        slice: &mut [DeviceDecoder],
        now: u64,
        models: &[DecoderModel],
        quants: &[EncoderQuant],
    ) {
        for &d in &self.due {
            self.obs.set_ctx(now, PHASE_SERVE, d as u64);
            let dev = &mut slice[d - base];
            while dev.free_at() <= now && dev.has_work() {
                let progressed = match dev.step(
                    now,
                    models,
                    quants,
                    &mut self.metrics,
                    &mut self.completions,
                    &mut self.obs,
                    d,
                ) {
                    Ok(p) => p,
                    Err(e) => {
                        self.err = Some(e);
                        return;
                    }
                };
                if let Some((model, per_token)) = dev.take_tick_observation() {
                    self.cost_log.push((d, model, false, per_token));
                }
                if let Some((model, per_row)) = dev.take_prefill_observation() {
                    self.cost_log.push((d, model, true, per_row));
                }
                if !progressed {
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;

    fn tiny_classes() -> Vec<ModelClass> {
        vec![ModelClass {
            name: "gen-tiny",
            cfg: XformerConfig { n_layers: 1, seq: 8, d_model: 16, n_heads: 2, d_ff: 32 },
            weight: 1.0,
            sla_ms: 0.0,
            priority: 0,
        }]
    }

    fn gen_req(id: u64, prompt_rows: usize, max_new: usize, arrival: u64) -> GenRequest {
        let mut rng = XorShiftRng::new(100 + id);
        let mut prompt = MatF32::zeros(prompt_rows, 16);
        for v in &mut prompt.data {
            *v = rng.normal() * 0.5;
        }
        GenRequest { id, model: 0, prompt, max_new_tokens: max_new, arrival_cycle: arrival }
    }

    fn single_device_cfg() -> DecodeFleetConfig {
        DecodeFleetConfig {
            roster: vec![DeviceClass::paper()],
            ref_mhz: 100,
            max_running: 4,
            ..Default::default()
        }
    }

    #[test]
    fn serves_generation_stream_with_phase_metrics() {
        let classes = tiny_classes();
        let reqs = vec![gen_req(0, 3, 4, 0), gen_req(1, 2, 3, 1_000)];
        let mut fleet = DecodeFleetSim::new(single_device_cfg(), &classes, 42);
        let (m, done) = fleet.run(reqs).unwrap();
        assert_eq!(m.completed, 2);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.tokens, 7, "4 + 3 tokens emitted");
        assert_eq!(done.len(), 2);
        for c in &done {
            let want = if c.id == 0 { 4 } else { 3 };
            assert_eq!(c.tokens.rows, want);
            assert!(c.tokens.data.iter().all(|v| v.is_finite()));
        }
        assert_eq!(m.ttft.count(), 2);
        assert!(m.ttft.p50() > 0);
        assert_eq!(m.itl.count() as u64, m.tokens - 2, "every non-first token has an ITL");
        assert!(m.decode_ticks > 0 && m.prefill_jobs > 0);
        assert!(m.kv_fill_words > 0 && m.kv_read_words > 0);
        assert!(m.makespan_cycles > 0);
        assert!(m.tokens_per_sec(100.0) > 0.0);
        assert_eq!(m.per_device.len(), 1);
        assert_eq!(m.per_device[0].served, 2);
    }

    #[test]
    fn decode_fleet_is_seed_deterministic() {
        let classes = tiny_classes();
        let mk = || {
            let reqs =
                vec![gen_req(0, 3, 3, 0), gen_req(1, 4, 4, 500), gen_req(2, 2, 5, 500)];
            let mut fleet = DecodeFleetSim::new(single_device_cfg(), &classes, 42);
            fleet.run(reqs).unwrap()
        };
        let (m1, c1) = mk();
        let (m2, c2) = mk();
        assert_eq!(m1, m2, "decode metrics must be a pure function of the inputs");
        assert_eq!(c1, c2, "completions (outputs included) must be reproducible");
    }

    #[test]
    fn kv_pressure_preempts_and_still_completes_everything() {
        // 3 pages of 256 words; 32 words/token → 8 tokens/page. Three
        // sequences of worst case 7 tokens each need 1 page apiece at
        // first, but growth across the page boundary cannot happen —
        // so shrink pages instead: 64 words = 2 tokens per page, 3
        // sequences × up to 7 tokens ≫ 6 resident tokens → pressure.
        let classes = tiny_classes();
        let cfg = DecodeFleetConfig {
            roster: vec![DeviceClass::paper()],
            ref_mhz: 100,
            max_running: 4,
            page_words: 64,
            kv_pages: Some(3),
            ..Default::default()
        };
        let reqs = vec![gen_req(0, 2, 5, 0), gen_req(1, 2, 5, 0), gen_req(2, 2, 5, 0)];
        let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
        let (m, done) = fleet.run(reqs).unwrap();
        assert_eq!(m.completed, 3, "pressure must delay, never lose, sequences");
        assert!(m.preemptions > 0, "the tiny pool must force preemption");
        assert!(done.iter().any(|c| c.preemptions > 0));
        assert_eq!(m.tokens, 15);
        for c in &done {
            assert_eq!(c.tokens.rows, 5);
        }
    }

    #[test]
    fn impossible_requests_are_rejected_with_reasons() {
        let classes = tiny_classes();
        // Context limit is 8: prompt 6 + 4 new = worst 9 > 8.
        let reqs = vec![gen_req(0, 6, 4, 0), gen_req(1, 2, 2, 0)];
        let mut fleet = DecodeFleetSim::new(single_device_cfg(), &classes, 42);
        let (m, done) = fleet.run(reqs).unwrap();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.rejections.len(), 1);
        assert_eq!(m.rejections[0].0, 0);
        assert!(
            m.rejections[0].1.contains("never fit"),
            "reason must be printable: {}",
            m.rejections[0].1
        );
        assert_eq!(m.completed, 1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
    }

    #[test]
    fn placement_routes_kv_heavy_requests_to_the_big_class() {
        // wpt = 2·64·1 = 128 words/token; 192-word pages hold 1 token,
        // so the little class's pool (4096/192 = 21 pages) can never
        // hold a 22-token worst case while the big class (42 pages)
        // can. Capacity-aware placement must route there instead of
        // rejecting at the little device.
        let classes = vec![ModelClass {
            name: "kv-heavy",
            cfg: XformerConfig { n_layers: 1, seq: 32, d_model: 64, n_heads: 2, d_ff: 32 },
            weight: 1.0,
            sla_ms: 0.0,
            priority: 0,
        }];
        let roster = DeviceClass::parse_roster("4x4@100:1,8x4@200:1").unwrap();
        let cfg = DecodeFleetConfig {
            roster,
            ref_mhz: 100,
            max_running: 2,
            page_words: 192,
            ..Default::default()
        };
        let mut rng = XorShiftRng::new(7);
        let mut prompt = MatF32::zeros(10, 64);
        for v in &mut prompt.data {
            *v = rng.normal() * 0.5;
        }
        let reqs =
            vec![GenRequest { id: 0, model: 0, prompt, max_new_tokens: 13, arrival_cycle: 0 }];
        let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
        let (m, done) = fleet.run(reqs).unwrap();
        assert_eq!(m.rejected, 0, "the big class must absorb it: {:?}", m.rejections);
        assert_eq!(m.completed, 1);
        assert_eq!(done[0].tokens.rows, 13);
        assert_eq!(m.per_device[0].served, 0, "21 pages can never hold 22 tokens");
        assert_eq!(m.per_device[1].served, 1);
    }

    fn long_classes() -> Vec<ModelClass> {
        vec![ModelClass {
            name: "gen-long",
            cfg: XformerConfig { n_layers: 1, seq: 32, d_model: 16, n_heads: 2, d_ff: 32 },
            weight: 1.0,
            sla_ms: 0.0,
            priority: 0,
        }]
    }

    #[test]
    fn chunked_prefill_bounds_itl_and_stays_output_exact() {
        // Three short sequences decode while a 24-row prompt arrives
        // mid-flight. Under PrefillFirst the long prefill runs as one
        // job and the running batch's worst inter-token gap spans it;
        // under Chunked{8} it runs as budgeted chunks between ticks.
        let classes = long_classes();
        let mk = |schedule: DecodeSchedule| {
            let mut reqs: Vec<GenRequest> =
                (0..3).map(|i| gen_req(i, 2, 10, 0)).collect();
            reqs.push(gen_req(3, 24, 2, 1));
            let cfg = DecodeFleetConfig {
                roster: vec![DeviceClass::paper()],
                ref_mhz: 100,
                max_running: 4,
                schedule,
                ..Default::default()
            };
            let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
            fleet.run(reqs).unwrap()
        };
        let (mp, mut cp) = mk(DecodeSchedule::PrefillFirst);
        let (mc, mut cc) = mk(DecodeSchedule::Chunked { chunk_tokens: 8 });
        assert_eq!(mp.completed, 4);
        assert_eq!(mc.completed, 4);
        assert_eq!(mc.prefill_chunks, 2, "a 24-row prompt at budget 8 has 2 partial chunks");
        assert!(
            mc.itl.max() < mp.itl.max(),
            "chunking must shrink the worst inter-token gap: {} vs {}",
            mc.itl.max(),
            mp.itl.max()
        );
        // Chunk schedules change timing only — outputs are bit-exact.
        cp.sort_by_key(|c| c.id);
        cc.sort_by_key(|c| c.id);
        for (a, b) in cp.iter().zip(&cc) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens.data, b.tokens.data, "sequence {} perturbed by chunking", a.id);
        }
    }

    #[test]
    fn migration_rescues_a_crowded_device_and_stays_output_exact() {
        // Four sequences are force-submitted to device 0 of a two-device
        // fleet, bypassing the placer — the scenario migration exists
        // for: estimates drifted and one device ended up crowded while
        // its twin idles. With migration on, rebalance must move work
        // to the idle device (the stay-estimate carries the whole
        // crowd's backlog, the move-estimate one sequence plus a
        // transfer) — a *running* sequence travels with its KV image
        // and resumes without recompute — and every completion stays
        // bit-identical to the no-migration run.
        let classes = tiny_classes();
        let cfg_model = classes[0].cfg;
        let mk = |migrate: bool| {
            let cfg = DecodeFleetConfig {
                roster: vec![DeviceClass::paper(); 2],
                ref_mhz: 100,
                max_running: 4,
                migrate,
                ..Default::default()
            };
            let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
            for i in 0..4 {
                fleet.devices[0].submit(gen_req(i, 3, 6, 0), &cfg_model).unwrap();
            }
            fleet.run(Vec::new()).unwrap()
        };
        let (m0, mut c0) = mk(false);
        let (m1, mut c1) = mk(true);
        assert_eq!(m0.completed, 4);
        assert_eq!(m0.migrations, 0);
        assert_eq!(m0.migrated_words, 0);
        assert_eq!(m1.completed, 4);
        assert!(m1.migrations > 0, "the idle twin must pull work off the crowded device");
        assert!(m1.migrated_words > 0);
        assert!(c1.iter().any(|c| c.migrations > 0));
        assert!(
            m1.per_device.iter().all(|d| d.served > 0),
            "migration must spread completions across both devices: {:?}",
            m1.per_device.iter().map(|d| d.served).collect::<Vec<_>>()
        );
        c0.sort_by_key(|c| c.id);
        c1.sort_by_key(|c| c.id);
        for (a, b) in c0.iter().zip(&c1) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens.data, b.tokens.data,
                "sequence {} perturbed by migration",
                a.id
            );
        }
    }

    #[test]
    fn first_decode_tick_replaces_the_analytic_token_seed() {
        let classes = tiny_classes();
        let mut fleet = DecodeFleetSim::new(single_device_cfg(), &classes, 42);
        let analytic = fleet.expected_token_cost(0, 0);
        assert!(!fleet.token_cost_observed(0, 0));
        let (m, _) = fleet.run(vec![gen_req(0, 3, 4, 0)]).unwrap();
        assert_eq!(m.completed, 1);
        assert!(fleet.token_cost_observed(0, 0), "one tick must flip the slot to measured");
        assert!(
            fleet.expected_token_cost(0, 0) > analytic,
            "the measured charge (fills, drains, attention) must exceed the GEMV ideal: \
             {} vs {analytic}",
            fleet.expected_token_cost(0, 0)
        );
    }

    #[test]
    fn measured_token_rates_drive_placement_over_analytic_seeds() {
        let classes = tiny_classes();
        let roster = DeviceClass::parse_roster("4x4@100:1,8x4@200:1").unwrap();
        let mk = || {
            DecodeFleetSim::new(
                DecodeFleetConfig {
                    roster: roster.clone(),
                    ref_mhz: 100,
                    max_running: 4,
                    ..Default::default()
                },
                &classes,
                42,
            )
        };
        let fleet = mk();
        let (c_little, c_big) = (fleet.device_class[0], fleet.device_class[1]);
        assert!(
            fleet.expected_token_cost(0, c_little) >= fleet.expected_token_cost(0, c_big),
            "analytic seeds rank the big class at or below the little class per token"
        );
        // A slow-analytic class that *measures* fast must win a
        // token-dominated placement after one observation…
        let mut fleet = mk();
        fleet.observe_token_cost(0, c_little, 1);
        fleet.observe_token_cost(0, c_big, 1_000_000);
        let mut metrics = DecodeMetrics::default();
        fleet.place(gen_req(0, 1, 8, 0), 0, &mut metrics);
        assert_eq!(fleet.devices[0].queued_len(), 1, "measured-fast little class must win");
        assert_eq!(fleet.devices[1].queued_len(), 0);
        // …and symmetrically for the big class.
        let mut fleet = mk();
        fleet.observe_token_cost(0, c_little, 1_000_000);
        fleet.observe_token_cost(0, c_big, 1);
        let mut metrics = DecodeMetrics::default();
        fleet.place(gen_req(1, 1, 8, 0), 0, &mut metrics);
        assert_eq!(fleet.devices[1].queued_len(), 1, "measured-fast big class must win");
        // Only the *first* observation replaces the seed.
        let mut fleet = mk();
        fleet.observe_token_cost(0, c_little, 7);
        fleet.observe_token_cost(0, c_little, 9);
        assert_eq!(fleet.expected_token_cost(0, c_little), 7);
        assert!(fleet.token_cost_observed(0, c_little));
    }

    #[test]
    fn migration_planner_prices_the_candidate_not_the_whole_backlog() {
        // Device 0 runs a long sequence A (12 ticks left) beside a
        // short one B (2 ticks left); device 1 idles. LIFO migration
        // would move B. Pricing B's stay time by the *whole* running
        // backlog (A's 12 ticks included) claims a gain of 10 transfer
        // units; B's honest per-sequence finish — its 2 ticks plus A's
        // share of them — exactly matches the move cost, so the gain
        // is zero and the strict-improvement bar must keep B home.
        let classes = long_classes();
        let cfg_model = classes[0].cfg;
        let mk = |migrate: bool| {
            let cfg = DecodeFleetConfig {
                roster: vec![DeviceClass::paper(); 2],
                ref_mhz: 100,
                max_running: 4,
                migrate,
                ..Default::default()
            };
            let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
            // Pin the per-token rate to one B-sized transfer leg so the
            // two estimators land on opposite sides of the strict-gain
            // bar (first-observation-wins blocks the measured
            // override). B's KV image at the t=0 rebalance is its 2
            // prompt rows: kv_len · 2 (K and V) · d_model · n_layers.
            let words = (2 * 2 * cfg_model.d_model * cfg_model.n_layers) as u64;
            let x = fleet.transfer_ref_cycles(0, words);
            fleet.observe_token_cost(0, 0, x);
            fleet.devices[0].submit(gen_req(0, 2, 13, 0), &cfg_model).unwrap();
            fleet.devices[0].submit(gen_req(1, 2, 3, 0), &cfg_model).unwrap();
            fleet.run(Vec::new()).unwrap()
        };
        let (m0, c0) = mk(false);
        let (m1, c1) = mk(true);
        assert_eq!(m1.completed, 2);
        assert_eq!(
            m1.migrations, 0,
            "zero per-sequence gain must not clear the strict-improvement bar"
        );
        assert_eq!(m0, m1, "a no-migration plan leaves the timeline untouched");
        assert_eq!(c0, c1);
    }

    #[test]
    fn first_prefill_replaces_the_analytic_prefill_seed() {
        let classes = tiny_classes();
        let mut fleet = DecodeFleetSim::new(single_device_cfg(), &classes, 42);
        let analytic = fleet.expected_prefill_cost(0, 0);
        assert!(!fleet.prefill_cost_observed(0, 0));
        let (m, _) = fleet.run(vec![gen_req(0, 3, 4, 0)]).unwrap();
        assert_eq!(m.completed, 1);
        assert!(fleet.prefill_cost_observed(0, 0), "one prefill must flip the slot to measured");
        assert!(
            fleet.expected_prefill_cost(0, 0) > analytic,
            "the measured per-row charge (fills, config, drains) must exceed the \
             compute-only ideal: {} vs {analytic}",
            fleet.expected_prefill_cost(0, 0)
        );
    }

    #[test]
    fn measured_prefill_rates_drive_placement_over_analytic_seeds() {
        let classes = tiny_classes();
        let roster = DeviceClass::parse_roster("4x4@100:1,8x4@200:1").unwrap();
        let mk = || {
            DecodeFleetSim::new(
                DecodeFleetConfig {
                    roster: roster.clone(),
                    ref_mhz: 100,
                    max_running: 4,
                    ..Default::default()
                },
                &classes,
                42,
            )
        };
        let fleet = mk();
        let (c_little, c_big) = (fleet.device_class[0], fleet.device_class[1]);
        assert!(
            fleet.expected_prefill_cost(0, c_little) >= fleet.expected_prefill_cost(0, c_big),
            "analytic seeds rank the big class at or below the little class per row"
        );
        // A prefill-dominated request (7 prompt rows, 1 token) must
        // follow the measured rate once one prefill has landed…
        let mut fleet = mk();
        fleet.observe_prefill_cost(0, c_little, 1);
        fleet.observe_prefill_cost(0, c_big, 1_000_000);
        let mut metrics = DecodeMetrics::default();
        fleet.place(gen_req(0, 7, 1, 0), 0, &mut metrics);
        assert_eq!(fleet.devices[0].queued_len(), 1, "measured-fast little class must win");
        assert_eq!(fleet.devices[1].queued_len(), 0);
        // …and symmetrically for the big class.
        let mut fleet = mk();
        fleet.observe_prefill_cost(0, c_little, 1_000_000);
        fleet.observe_prefill_cost(0, c_big, 1);
        let mut metrics = DecodeMetrics::default();
        fleet.place(gen_req(1, 7, 1, 0), 0, &mut metrics);
        assert_eq!(fleet.devices[1].queued_len(), 1, "measured-fast big class must win");
        // Only the *first* observation replaces the seed.
        let mut fleet = mk();
        fleet.observe_prefill_cost(0, c_little, 7);
        fleet.observe_prefill_cost(0, c_little, 9);
        assert_eq!(fleet.expected_prefill_cost(0, c_little), 7);
        assert!(fleet.prefill_cost_observed(0, c_little));
    }

    #[test]
    fn continuous_batching_outruns_sequential_decode() {
        // Four simultaneous generation requests on one device: the
        // continuous batch (max_running 4) coalesces their decode
        // steps into stacked GEMVs and must finish the work sooner
        // than strictly sequential per-request decode (max_running 1).
        let classes = tiny_classes();
        let mk = |max_running: usize| {
            let reqs: Vec<GenRequest> =
                (0..4).map(|i| gen_req(i, 3, 4, 0)).collect();
            let cfg = DecodeFleetConfig {
                roster: vec![DeviceClass::paper()],
                ref_mhz: 100,
                max_running,
                ..Default::default()
            };
            let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
            fleet.run(reqs).unwrap().0
        };
        let seq = mk(1);
        let cont = mk(4);
        assert_eq!(seq.completed, 4);
        assert_eq!(cont.completed, 4);
        assert!((seq.mean_decode_occupancy() - 1.0).abs() < 1e-9);
        assert!(cont.mean_decode_occupancy() > 1.0);
        assert!(
            cont.makespan_cycles < seq.makespan_cycles,
            "continuous batching must clear the burst sooner: {} vs {}",
            cont.makespan_cycles,
            seq.makespan_cycles
        );
        assert!(cont.tokens_per_sec(100.0) > seq.tokens_per_sec(100.0));
    }

    #[test]
    fn disaggregated_handoff_stays_output_exact() {
        // Two uniform devices: under disaggregation the front half
        // (device 0) runs prefill-only and every sequence hands off to
        // device 1 for decode. The token streams must stay bit-
        // identical to the unified run — the hand-off rides the same
        // export/import image path the migration suite already pins.
        let classes = tiny_classes();
        let mk = |disagg: bool| {
            let reqs: Vec<GenRequest> = (0..4).map(|i| gen_req(i, 3, 5, i * 100)).collect();
            let cfg = DecodeFleetConfig {
                roster: vec![DeviceClass::paper(); 2],
                ref_mhz: 100,
                max_running: 4,
                disagg,
                ..Default::default()
            };
            let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
            fleet.run(reqs).unwrap()
        };
        let (m0, mut c0) = mk(false);
        let (m1, mut c1) = mk(true);
        assert_eq!(m0.completed, 4);
        assert_eq!(m0.handoffs, 0);
        assert_eq!(m1.completed, 4);
        assert_eq!(m1.handoffs, 4, "every sequence must hand off exactly once");
        assert!(m1.handoff_words > 0);
        assert!(c1.iter().all(|c| c.migrations > 0), "hand-offs book as moves per sequence");
        c0.sort_by_key(|c| c.id);
        c1.sort_by_key(|c| c.id);
        for (a, b) in c0.iter().zip(&c1) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens.data, b.tokens.data,
                "sequence {} perturbed by disaggregated hand-off",
                a.id
            );
        }
    }

    #[test]
    fn prefix_cache_serves_repeats_bit_identically() {
        // Request 1 repeats request 0's prompt after the first prefill
        // finished, so the cache serves its leading blocks; request 2
        // is unrelated and must miss. Outputs must match the cold run
        // bit for bit — a hit copies pages the engine then reads
        // exactly like chunk-filled ones.
        let classes = tiny_classes();
        let shared = gen_req(0, 4, 3, 0).prompt;
        let mk = |block: Option<usize>| {
            let mut repeat = gen_req(1, 4, 3, 1_000_000);
            repeat.prompt = shared.clone();
            let reqs = vec![gen_req(0, 4, 3, 0), repeat, gen_req(2, 4, 3, 2_000_000)];
            let cfg = DecodeFleetConfig {
                roster: vec![DeviceClass::paper()],
                ref_mhz: 100,
                max_running: 4,
                prefix_block_tokens: block,
                ..Default::default()
            };
            let mut fleet = DecodeFleetSim::new(cfg, &classes, 42);
            fleet.run(reqs).unwrap()
        };
        let (mc, mut cc) = mk(None);
        let (mh, mut ch) = mk(Some(2));
        assert_eq!(mc.completed, 3);
        assert_eq!(mc.prefix_hits, 0);
        assert_eq!(mh.completed, 3);
        assert_eq!(mh.prefix_hits, 1, "only the repeat may hit");
        assert_eq!(
            mh.prefix_hit_tokens, 3,
            "both whole blocks match but the last prompt row must still compute"
        );
        assert!(mh.prefix_copied_words > 0);
        cc.sort_by_key(|c| c.id);
        ch.sort_by_key(|c| c.id);
        for (a, b) in cc.iter().zip(&ch) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens.data, b.tokens.data,
                "sequence {} perturbed by a prefix-cache hit",
                a.id
            );
        }
    }
}
