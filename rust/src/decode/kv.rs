//! Paged KV cache: fixed-size pages of on-chip K/V residency with
//! per-sequence page tables and exact word accounting.
//!
//! Decode steps are GEMV-shaped and memory-bound: the dominant traffic
//! is reading every cached K/V row once per step per layer. What bounds
//! *concurrency* on an edge device is therefore KV **residency** — how
//! many sequences' caches fit on chip at once. This module models that
//! the way modern serving stacks do (vLLM's PagedAttention): the KV
//! arena is a pool of fixed-size pages (`page_words` 32-bit words
//! each), a sequence owns a page *table* (an ordered list of page
//! frames), and tokens map to (page, slot) by simple division — no
//! per-sequence contiguity, no fragmentation beyond the final partial
//! page.
//!
//! ## Budget
//!
//! The pool is provisioned from the device class's scratchpad: **half
//! of L1** is reserved for KV pages ([`KvConfig::for_class`]), so an
//! `8x4` class — whose L1 scales with its row count — holds twice the
//! resident tokens of the paper's `4x4`. One token of one sequence
//! costs `2 · d_model · n_layers` words (K and V rows across every
//! layer), giving `tokens_per_page = page_words / words_per_token`
//! per-sequence page geometry; models of different shapes coexist in
//! one pool because pages are raw words.
//!
//! ## Contract
//!
//! Admission and growth are **checked, never silent**: a sequence that
//! could never fit is rejected with a typed reason
//! ([`AdmitError::TooLarge`]), one that merely cannot fit *now* reports
//! [`AdmitError::NoCapacity`] (the scheduler's cue to wait or preempt),
//! and every write is bounds-checked against the owning table — a bug
//! cannot corrupt another sequence's pages. Fills and reads are counted
//! exactly ([`KvMetrics`]: `2·d_model` words per token-layer fill,
//! `2·d_model·len` words per per-layer gather), which is what the
//! decode metrics and the FIG8 bench report as KV traffic.

use crate::config::DeviceClass;
use crate::util::mat::MatF32;
use std::collections::BTreeMap;
use std::fmt;

/// Pool geometry: page size in 32-bit words and the page count of the
/// device's KV budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvConfig {
    /// Words per page (fixed for the pool; raw words, so models of
    /// different shapes share one pool).
    pub page_words: usize,
    /// Pages in the pool (the device budget).
    pub total_pages: usize,
}

impl KvConfig {
    /// Default page size: 1 KiWord = 16 resident tokens of the tiny
    /// edge class (d_model 32, 1 layer) per page.
    pub const DEFAULT_PAGE_WORDS: usize = 1024;

    pub fn new(page_words: usize, total_pages: usize) -> Self {
        assert!(page_words > 0 && total_pages > 0, "KV pool must be non-empty");
        Self { page_words, total_pages }
    }

    /// The budget formula: **half of the class's L1 words** are
    /// reserved for KV pages, split into [`Self::DEFAULT_PAGE_WORDS`]
    /// pages. Row-scaled classes therefore hold proportionally more
    /// resident sequences — the memory lever that makes big.LITTLE
    /// decode placement interesting.
    pub fn for_class(class: &DeviceClass) -> Self {
        Self::with_page_words(class, Self::DEFAULT_PAGE_WORDS)
    }

    /// [`Self::for_class`] with an explicit page size.
    pub fn with_page_words(class: &DeviceClass, page_words: usize) -> Self {
        let budget = class.arch.mem.l1_words / 2;
        let page_words = page_words.max(1);
        Self { page_words, total_pages: (budget / page_words).max(1) }
    }

    /// Total pool capacity in words.
    pub fn budget_words(&self) -> usize {
        self.page_words * self.total_pages
    }
}

/// Why a sequence could not be admitted or grown. Every variant carries
/// the numbers behind the decision — reject-with-reason, never a bare
/// boolean.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    /// The sequence's worst-case length can never fit the pool, even
    /// empty. Reject the request.
    TooLarge { worst_tokens: usize, capacity_tokens: usize },
    /// Not enough free pages right now. Wait for a release, or preempt.
    NoCapacity { needed_pages: usize, free_pages: usize },
    /// One token of this model is wider than a page.
    TokenTooWide { words_per_token: usize, page_words: usize },
    /// The sequence id is already resident.
    AlreadyAdmitted { seq: u64 },
    /// The sequence id is not resident (stale handle).
    Unknown { seq: u64 },
    /// A serialized KV image's token count does not match its header —
    /// the import is refused before any allocation, so the destination
    /// pool (and the source it was exported from) stay intact.
    CorruptImage { expected_words: usize, got_words: usize },
    /// One token of a serialized KV image carries the wrong number of
    /// K or V words. Validated **per tensor**: an image whose K is
    /// truncated and whose V is padded by the same amount has a
    /// perfectly matching total and must still be refused — a total-
    /// only check imports it silently and decodes garbage.
    CorruptTensor { token: usize, expected_words: usize, got_k_words: usize, got_v_words: usize },
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::TooLarge { worst_tokens, capacity_tokens } => write!(
                f,
                "sequence can never fit: worst case {worst_tokens} tokens vs pool \
                 capacity {capacity_tokens}"
            ),
            Self::NoCapacity { needed_pages, free_pages } => {
                write!(f, "no capacity: needs {needed_pages} pages, {free_pages} free")
            }
            Self::TokenTooWide { words_per_token, page_words } => write!(
                f,
                "one token ({words_per_token} words) exceeds the page size ({page_words})"
            ),
            Self::AlreadyAdmitted { seq } => write!(f, "sequence {seq} already admitted"),
            Self::Unknown { seq } => write!(f, "sequence {seq} not resident"),
            Self::CorruptImage { expected_words, got_words } => write!(
                f,
                "corrupt KV image: header promises {expected_words} words, payload has \
                 {got_words}"
            ),
            Self::CorruptTensor { token, expected_words, got_k_words, got_v_words } => write!(
                f,
                "corrupt KV image: token {token} must carry {expected_words} K and \
                 {expected_words} V words, has {got_k_words} K / {got_v_words} V"
            ),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Exact traffic and lifecycle counters for one pool.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct KvMetrics {
    /// Words written into pages (K/V fills): `2·d_model` per
    /// token-layer write.
    pub fill_words: u64,
    /// Words gathered out of pages for attention: `2·d_model·len` per
    /// per-layer read.
    pub read_words: u64,
    /// Sequences admitted (including re-admissions after preemption
    /// and migration imports).
    pub admitted: u64,
    /// Sequences released (completion or preemption).
    pub released: u64,
    /// Pages returned to the free list by releases.
    pub freed_pages: u64,
    /// Words serialized out of this pool by [`PagedKvCache::export_seq`]
    /// (migration traffic — counted separately from `read_words`, which
    /// stays the attention-gather figure; an export must never look
    /// like phantom attention reads).
    pub export_words: u64,
    /// Words deserialized into this pool by
    /// [`PagedKvCache::import_seq`] (counted separately from
    /// `fill_words` for the same reason). Conservation invariant: a
    /// migration's `export_words` on the source equals its
    /// `import_words` on the destination exactly.
    pub import_words: u64,
}

/// One token of a serialized sequence: its K and V rows across every
/// layer, as **separate tensors** (`n_layers · d_model` words each,
/// layer-major). Keeping K and V structurally apart is what lets
/// [`PagedKvCache::import_seq`] validate them apart — a truncated K
/// padded back to size by extra V words can never masquerade as a
/// well-formed token, and a producer physically cannot emit the
/// swapped interleaved layout the old flat-word image allowed.
#[derive(Debug, Clone, PartialEq)]
pub struct KvTokenImage {
    /// K rows, layer 0 first: `n_layers · d_model` words.
    pub k: Vec<f32>,
    /// V rows, same layout.
    pub v: Vec<f32>,
}

/// A serialized resident sequence: everything another device's pool
/// needs to re-admit it with its cache intact. The payload is the
/// exact dequantized K/V activations (token-major, page padding
/// dropped), so a migrated sequence decodes **bit-identically** on the
/// destination — whatever its class or page geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct KvSeqImage {
    pub d_model: usize,
    pub n_layers: usize,
    /// Committed tokens at export time.
    pub len: usize,
    /// One [`KvTokenImage`] per committed token, in token order.
    pub tokens: Vec<KvTokenImage>,
}

impl KvSeqImage {
    /// Words this image moves over a transfer link (the actual payload,
    /// so a corrupt image is priced at what it really carries).
    pub fn word_count(&self) -> u64 {
        self.tokens.iter().map(|t| (t.k.len() + t.v.len()) as u64).sum()
    }

    /// Structural validation against the header: the token count and
    /// **each token's K and V tensor lengths** must match the shape.
    /// This is the import gate — checking only the total word count
    /// lets a truncated-K/padded-V (or otherwise re-balanced) payload
    /// through silently.
    pub fn validate(&self) -> Result<(), AdmitError> {
        let per_tensor = self.d_model * self.n_layers;
        if self.tokens.len() != self.len {
            return Err(AdmitError::CorruptImage {
                expected_words: self.len * 2 * per_tensor,
                got_words: self.word_count() as usize,
            });
        }
        for (t, tok) in self.tokens.iter().enumerate() {
            if tok.k.len() != per_tensor || tok.v.len() != per_tensor {
                return Err(AdmitError::CorruptTensor {
                    token: t,
                    expected_words: per_tensor,
                    got_k_words: tok.k.len(),
                    got_v_words: tok.v.len(),
                });
            }
        }
        Ok(())
    }
}

/// One resident sequence: shape, page table, committed length.
#[derive(Debug, Clone)]
struct SeqKv {
    d_model: usize,
    n_layers: usize,
    tokens_per_page: usize,
    /// Ordered page frames; token `t` lives in `pages[t / tokens_per_page]`.
    pages: Vec<usize>,
    /// Tokens committed (slots reserved; rows may still be being
    /// written by the in-flight job).
    len: usize,
}

impl SeqKv {
    fn words_per_token(&self) -> usize {
        2 * self.d_model * self.n_layers
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.tokens_per_page)
    }
}

/// The paged pool: frames, free list, per-sequence tables.
pub struct PagedKvCache {
    cfg: KvConfig,
    /// Page frames (each `page_words` f32 slots; the cache stores the
    /// exact dequantized K/V activations, so decode numerics are
    /// bit-identical to prefill).
    frames: Vec<Vec<f32>>,
    /// Free frame ids, kept sorted descending so `pop()` hands out the
    /// lowest id first — allocation order is deterministic.
    free: Vec<usize>,
    seqs: BTreeMap<u64, SeqKv>,
    pub metrics: KvMetrics,
}

impl PagedKvCache {
    pub fn new(cfg: KvConfig) -> Self {
        Self {
            frames: vec![vec![0.0; cfg.page_words]; cfg.total_pages],
            free: (0..cfg.total_pages).rev().collect(),
            seqs: BTreeMap::new(),
            cfg,
            metrics: KvMetrics::default(),
        }
    }

    pub fn config(&self) -> KvConfig {
        self.cfg
    }

    /// Resident-token capacity of the whole pool for a model shape.
    pub fn capacity_tokens(&self, d_model: usize, n_layers: usize) -> usize {
        let wpt = 2 * d_model * n_layers;
        if wpt == 0 || wpt > self.cfg.page_words {
            return 0;
        }
        (self.cfg.page_words / wpt) * self.cfg.total_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.cfg.total_pages - self.free.len()
    }

    /// Pool occupancy in permille (0..=1000) — recorded per decode tick
    /// into the KV-occupancy histogram.
    pub fn occupancy_permille(&self) -> u64 {
        (self.used_pages() as u64 * 1000) / self.cfg.total_pages as u64
    }

    /// Committed token count of a resident sequence (0 if absent).
    pub fn len(&self, seq: u64) -> usize {
        self.seqs.get(&seq).map_or(0, |s| s.len)
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Whether growing `seq` by one token would need a fresh page.
    pub fn needs_page(&self, seq: u64) -> bool {
        self.seqs
            .get(&seq)
            .is_some_and(|s| s.pages_for(s.len + 1) > s.pages.len())
    }

    /// Admit a sequence: reserve pages for its `prompt_tokens` and
    /// commit those slots. `worst_tokens` is the longest the sequence
    /// can ever grow (prompt + new tokens − 1); a worst case beyond the
    /// *empty-pool* capacity is rejected outright ([`AdmitError::
    /// TooLarge`]) — everything admitted is guaranteed completable once
    /// its peers drain, which is what makes LIFO preemption safe.
    pub fn admit(
        &mut self,
        seq: u64,
        d_model: usize,
        n_layers: usize,
        prompt_tokens: usize,
        worst_tokens: usize,
    ) -> Result<(), AdmitError> {
        assert!(prompt_tokens > 0, "a sequence starts with at least one token");
        let wpt = 2 * d_model * n_layers;
        if wpt > self.cfg.page_words {
            return Err(AdmitError::TokenTooWide {
                words_per_token: wpt,
                page_words: self.cfg.page_words,
            });
        }
        if self.seqs.contains_key(&seq) {
            return Err(AdmitError::AlreadyAdmitted { seq });
        }
        let tokens_per_page = self.cfg.page_words / wpt;
        let capacity = tokens_per_page * self.cfg.total_pages;
        if worst_tokens.max(prompt_tokens) > capacity {
            return Err(AdmitError::TooLarge {
                worst_tokens: worst_tokens.max(prompt_tokens),
                capacity_tokens: capacity,
            });
        }
        let needed = prompt_tokens.div_ceil(tokens_per_page);
        if needed > self.free.len() {
            return Err(AdmitError::NoCapacity {
                needed_pages: needed,
                free_pages: self.free.len(),
            });
        }
        let pages: Vec<usize> =
            (0..needed).map(|_| self.free.pop().expect("checked above")).collect();
        self.seqs.insert(
            seq,
            SeqKv { d_model, n_layers, tokens_per_page, pages, len: prompt_tokens },
        );
        self.metrics.admitted += 1;
        Ok(())
    }

    /// Whether [`Self::admit`] would currently succeed for this shape.
    pub fn can_admit(&self, d_model: usize, n_layers: usize, prompt_tokens: usize) -> bool {
        let wpt = 2 * d_model * n_layers;
        if wpt == 0 || wpt > self.cfg.page_words || prompt_tokens == 0 {
            return false;
        }
        let tpp = self.cfg.page_words / wpt;
        prompt_tokens.div_ceil(tpp) <= self.free.len()
    }

    /// Commit one more token slot for `seq`, allocating a page when the
    /// current tail page is full. Returns the token index to write.
    /// [`AdmitError::NoCapacity`] means the scheduler must free pages
    /// (preempt) before this sequence can take its next step.
    pub fn begin_token(&mut self, seq: u64) -> Result<usize, AdmitError> {
        let free_now = self.free.len();
        let s = self.seqs.get_mut(&seq).ok_or(AdmitError::Unknown { seq })?;
        if s.pages_for(s.len + 1) > s.pages.len() {
            if free_now == 0 {
                return Err(AdmitError::NoCapacity { needed_pages: 1, free_pages: 0 });
            }
            let frame = self.free.pop().expect("checked above");
            s.pages.push(frame);
        }
        let token = s.len;
        s.len += 1;
        Ok(token)
    }

    /// Commit `n` more token slots for `seq` in one **all-or-nothing**
    /// step (the chunked-prefill grow path): either every page the
    /// growth needs is allocated and the committed length advances by
    /// `n`, or the cache is left untouched and the exact shortfall is
    /// reported. Returns the first newly committed token index.
    pub fn commit_tokens(&mut self, seq: u64, n: usize) -> Result<usize, AdmitError> {
        assert!(n > 0, "committing zero tokens is a scheduling bug");
        let needed = {
            let s = self.seqs.get(&seq).ok_or(AdmitError::Unknown { seq })?;
            s.pages_for(s.len + n).saturating_sub(s.pages.len())
        };
        if needed > self.free.len() {
            return Err(AdmitError::NoCapacity {
                needed_pages: needed,
                free_pages: self.free.len(),
            });
        }
        let frames: Vec<usize> =
            (0..needed).map(|_| self.free.pop().expect("checked above")).collect();
        let s = self.seqs.get_mut(&seq).expect("checked above");
        s.pages.extend(frames);
        let first = s.len;
        s.len += n;
        Ok(first)
    }

    /// Write one layer's K and V rows for a committed token. Panics on
    /// out-of-table writes — a scheduling bug must never silently
    /// corrupt a neighbour's pages.
    pub fn write_token_layer(
        &mut self,
        seq: u64,
        token: usize,
        layer: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let s = self.seqs.get(&seq).expect("sequence must be resident");
        assert!(token < s.len, "token {token} beyond committed length {}", s.len);
        assert!(layer < s.n_layers, "layer {layer} out of range");
        assert_eq!(k.len(), s.d_model, "K row width mismatch");
        assert_eq!(v.len(), s.d_model, "V row width mismatch");
        let frame = s.pages[token / s.tokens_per_page];
        let base = (token % s.tokens_per_page) * s.words_per_token() + layer * 2 * s.d_model;
        let d = s.d_model;
        let buf = &mut self.frames[frame];
        buf[base..base + d].copy_from_slice(k);
        buf[base + d..base + 2 * d].copy_from_slice(v);
        self.metrics.fill_words += 2 * d as u64;
    }

    /// Write a whole prompt's K/V for one layer (token rows `0..k.rows`).
    pub fn write_prompt_layer(&mut self, seq: u64, layer: usize, k: &MatF32, v: &MatF32) {
        self.write_rows_layer(seq, 0, layer, k, v);
    }

    /// Write a contiguous run of token rows for one layer starting at
    /// token `first` (the chunked-prefill fill path: chunk `c` writes
    /// its rows at the offset earlier chunks committed).
    pub fn write_rows_layer(
        &mut self,
        seq: u64,
        first: usize,
        layer: usize,
        k: &MatF32,
        v: &MatF32,
    ) {
        assert_eq!(k.rows, v.rows, "K/V row count mismatch");
        for t in 0..k.rows {
            self.write_token_layer(seq, first + t, layer, k.row(t), v.row(t));
        }
    }

    /// Gather one layer's cached K and V (`len × d_model` each) for
    /// attention, counting the read traffic exactly.
    pub fn read_layer(&mut self, seq: u64, layer: usize) -> (MatF32, MatF32) {
        let s = self.seqs.get(&seq).expect("sequence must be resident");
        let d = s.d_model;
        let mut k = MatF32::zeros(s.len, d);
        let mut v = MatF32::zeros(s.len, d);
        for t in 0..s.len {
            let frame = s.pages[t / s.tokens_per_page];
            let base = (t % s.tokens_per_page) * s.words_per_token() + layer * 2 * d;
            let buf = &self.frames[frame];
            k.data[t * d..(t + 1) * d].copy_from_slice(&buf[base..base + d]);
            v.data[t * d..(t + 1) * d].copy_from_slice(&buf[base + d..base + 2 * d]);
        }
        self.metrics.read_words += (2 * d * s.len) as u64;
        (k, v)
    }

    /// Release a sequence (completion or preemption), returning its
    /// pages to the free list. Returns the page count freed.
    pub fn release(&mut self, seq: u64) -> usize {
        let Some(s) = self.seqs.remove(&seq) else { return 0 };
        let n = s.pages.len();
        self.free.extend(s.pages);
        // Keep the free list sorted descending so the next allocation
        // is still the lowest id (deterministic reuse).
        self.free.sort_unstable_by(|a, b| b.cmp(a));
        self.metrics.released += 1;
        self.metrics.freed_pages += n as u64;
        n
    }

    /// Serialize a resident sequence's cache into a [`KvSeqImage`]
    /// (migration export). **Non-destructive**: the sequence stays
    /// resident and readable — the migration protocol only calls
    /// [`Self::release`] after the destination's import has succeeded,
    /// so a mid-import failure leaves the source intact. The words
    /// moved are counted in [`KvMetrics::export_words`], never in the
    /// attention-read figure.
    pub fn export_seq(&mut self, seq: u64) -> Result<KvSeqImage, AdmitError> {
        let s = self.seqs.get(&seq).ok_or(AdmitError::Unknown { seq })?;
        let (d, wpt) = (s.d_model, s.words_per_token());
        let mut tokens = Vec::with_capacity(s.len);
        for t in 0..s.len {
            let frame = s.pages[t / s.tokens_per_page];
            let base = (t % s.tokens_per_page) * wpt;
            let mut k = Vec::with_capacity(s.n_layers * d);
            let mut v = Vec::with_capacity(s.n_layers * d);
            for li in 0..s.n_layers {
                let off = base + li * 2 * d;
                k.extend_from_slice(&self.frames[frame][off..off + d]);
                v.extend_from_slice(&self.frames[frame][off + d..off + 2 * d]);
            }
            tokens.push(KvTokenImage { k, v });
        }
        self.metrics.export_words += (s.len * wpt) as u64;
        Ok(KvSeqImage { d_model: s.d_model, n_layers: s.n_layers, len: s.len, tokens })
    }

    /// Re-admit an exported sequence into this pool (migration
    /// import): allocate pages for `image.len` tokens, copy the K/V
    /// words in, and commit the length — **all-or-nothing**. Every
    /// check (malformed image — token count *and* each token's K/V
    /// tensor lengths via [`KvSeqImage::validate`] — token wider than
    /// a page, worst case beyond the pool, duplicate id, not enough
    /// free pages) happens before any allocation, so a failed import
    /// changes nothing here and nothing at the source. `worst_tokens`
    /// is the same growth bound [`Self::admit`] takes. Words land in
    /// [`KvMetrics::import_words`], never in the prefill-fill figure.
    pub fn import_seq(
        &mut self,
        seq: u64,
        image: &KvSeqImage,
        worst_tokens: usize,
    ) -> Result<(), AdmitError> {
        image.validate()?;
        self.admit(seq, image.d_model, image.n_layers, image.len, worst_tokens)?;
        let s = self.seqs.get(&seq).expect("just admitted");
        let d = s.d_model;
        let wpt = s.words_per_token();
        let (tpp, pages) = (s.tokens_per_page, s.pages.clone());
        for (t, tok) in image.tokens.iter().enumerate() {
            let frame = pages[t / tpp];
            let base = (t % tpp) * wpt;
            for li in 0..image.n_layers {
                let off = base + li * 2 * d;
                self.frames[frame][off..off + d].copy_from_slice(&tok.k[li * d..(li + 1) * d]);
                self.frames[frame][off + d..off + 2 * d]
                    .copy_from_slice(&tok.v[li * d..(li + 1) * d]);
            }
        }
        self.metrics.import_words += (image.len * wpt) as u64;
        Ok(())
    }

    /// Whether a sequence of this shape, with `len` resident tokens
    /// and growth bound `worst_tokens`, could be admitted under id
    /// `seq` right now — the same checks [`Self::admit`] performs
    /// (token width, worst-case fit, duplicate id, free pages). This
    /// is the **one** feasibility predicate: the migration planner
    /// consults it before an image even exists, and
    /// [`Self::can_import`] delegates to it, so planner and import can
    /// never drift on admission semantics.
    pub fn can_host(
        &self,
        seq: u64,
        d_model: usize,
        n_layers: usize,
        len: usize,
        worst_tokens: usize,
    ) -> bool {
        let wpt = 2 * d_model * n_layers;
        if len == 0 || wpt == 0 || wpt > self.cfg.page_words || self.seqs.contains_key(&seq) {
            return false;
        }
        let tpp = self.cfg.page_words / wpt;
        worst_tokens.max(len) <= tpp * self.cfg.total_pages
            && len.div_ceil(tpp) <= self.free.len()
    }

    /// Whether [`Self::import_seq`] would succeed right now for this
    /// image under `worst_tokens` — full structural validation
    /// ([`KvSeqImage::validate`]) plus every [`Self::can_host`] check,
    /// so a caller may import unconditionally after a `true`.
    pub fn can_import(&self, seq: u64, image: &KvSeqImage, worst_tokens: usize) -> bool {
        image.validate().is_ok()
            && self.can_host(seq, image.d_model, image.n_layers, image.len, worst_tokens)
    }

    /// Copy the first `tokens` tokens' K/V words from resident
    /// sequence `src` into resident sequence `dst` (the prefix-cache
    /// serve path: a repeated prompt's shared prefix is filled from
    /// already-computed pages instead of re-running prefill). Both
    /// sequences must share a model shape and have at least `tokens`
    /// committed; panics otherwise — a bad prefix copy is a scheduling
    /// bug, never silent corruption. Returns the words copied. The
    /// copy is a pool-internal move and is deliberately **not**
    /// counted as attention fills or reads ([`KvMetrics`] stays the
    /// compute-traffic figure); the fleet books it as prefix-copy
    /// traffic.
    pub fn copy_prefix(&mut self, dst: u64, src: u64, tokens: usize) -> u64 {
        if tokens == 0 {
            return 0;
        }
        assert_ne!(dst, src, "prefix copy onto itself");
        let s = self.seqs.get(&src).expect("prefix source must be resident");
        let d = self.seqs.get(&dst).expect("prefix destination must be resident");
        assert_eq!(
            (s.d_model, s.n_layers),
            (d.d_model, d.n_layers),
            "prefix copy across model shapes"
        );
        assert!(s.len >= tokens, "source holds {} tokens, copy wants {tokens}", s.len);
        assert!(d.len >= tokens, "destination committed {} tokens, copy wants {tokens}", d.len);
        let wpt = s.words_per_token();
        let (src_pages, src_tpp) = (s.pages.clone(), s.tokens_per_page);
        let (dst_pages, dst_tpp) = (d.pages.clone(), d.tokens_per_page);
        let mut row = vec![0.0f32; wpt];
        for t in 0..tokens {
            let sb = (t % src_tpp) * wpt;
            let db = (t % dst_tpp) * wpt;
            row.copy_from_slice(&self.frames[src_pages[t / src_tpp]][sb..sb + wpt]);
            self.frames[dst_pages[t / dst_tpp]][db..db + wpt].copy_from_slice(&row);
        }
        (tokens * wpt) as u64
    }

    /// Structural-invariant check (test/debug aid; panics with the
    /// violated invariant): every frame is owned exactly once — by the
    /// free list or by one sequence's table — page tables are exactly
    /// dense (precisely the pages the committed length needs), and the
    /// free list holds no duplicates or out-of-range frames.
    pub fn check_invariants(&self) {
        let mut owners = vec![0u32; self.cfg.total_pages];
        for &f in &self.free {
            assert!(f < self.cfg.total_pages, "free-list frame {f} out of range");
            owners[f] += 1;
        }
        for (id, s) in &self.seqs {
            assert!(s.len > 0, "sequence {id} resident with zero committed tokens");
            assert_eq!(
                s.pages.len(),
                s.pages_for(s.len),
                "sequence {id}: page table not dense ({} pages for {} tokens)",
                s.pages.len(),
                s.len
            );
            for &f in &s.pages {
                assert!(f < self.cfg.total_pages, "sequence {id} frame {f} out of range");
                owners[f] += 1;
            }
        }
        for (f, &n) in owners.iter().enumerate() {
            assert_eq!(n, 1, "frame {f} owned {n} times (must be exactly once)");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pool() -> PagedKvCache {
        // 4 pages × 256 words; d_model 16, 1 layer → 32 words/token →
        // 8 tokens per page, 32-token pool capacity.
        PagedKvCache::new(KvConfig::new(256, 4))
    }

    fn row(d: usize, fill: f32) -> Vec<f32> {
        vec![fill; d]
    }

    #[test]
    fn admit_write_read_roundtrip() {
        let mut kv = tiny_pool();
        kv.admit(7, 16, 1, 3, 10).unwrap();
        assert_eq!(kv.len(7), 3);
        assert_eq!(kv.used_pages(), 1);
        for t in 0..3 {
            kv.write_token_layer(7, t, 0, &row(16, t as f32), &row(16, -(t as f32)));
        }
        let (k, v) = kv.read_layer(7, 0);
        assert_eq!((k.rows, k.cols), (3, 16));
        assert_eq!(k.at(2, 5), 2.0);
        assert_eq!(v.at(1, 0), -1.0);
        assert_eq!(kv.metrics.fill_words, 3 * 32);
        assert_eq!(kv.metrics.read_words, 3 * 32);
    }

    #[test]
    fn growth_allocates_pages_on_boundaries() {
        let mut kv = tiny_pool();
        kv.admit(1, 16, 1, 8, 20).unwrap(); // exactly one full page
        assert_eq!(kv.used_pages(), 1);
        assert!(kv.needs_page(1));
        let t = kv.begin_token(1).unwrap();
        assert_eq!(t, 8);
        assert_eq!(kv.used_pages(), 2, "crossing the boundary takes a page");
        for _ in 9..16 {
            kv.begin_token(1).unwrap();
        }
        assert_eq!(kv.used_pages(), 2, "within-page growth allocates nothing");
    }

    #[test]
    fn rejects_carry_reasons() {
        let mut kv = tiny_pool();
        // Worst case beyond the whole pool (capacity 32 tokens).
        match kv.admit(1, 16, 1, 4, 33) {
            Err(AdmitError::TooLarge { worst_tokens: 33, capacity_tokens: 32 }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // A token wider than a page.
        match kv.admit(1, 256, 1, 1, 1) {
            Err(AdmitError::TokenTooWide { .. }) => {}
            other => panic!("expected TokenTooWide, got {other:?}"),
        }
        // Pool full right now: NoCapacity, not TooLarge.
        kv.admit(1, 16, 1, 24, 24).unwrap(); // 3 pages
        match kv.admit(2, 16, 1, 9, 9) {
            Err(AdmitError::NoCapacity { needed_pages: 2, free_pages: 1 }) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        assert!(!kv.can_admit(16, 1, 9));
        assert!(kv.can_admit(16, 1, 8));
        // Double admission is a typed error too.
        match kv.admit(1, 16, 1, 1, 1) {
            Err(AdmitError::AlreadyAdmitted { seq: 1 }) => {}
            other => panic!("expected AlreadyAdmitted, got {other:?}"),
        }
        let msg = AdmitError::NoCapacity { needed_pages: 2, free_pages: 1 }.to_string();
        assert!(msg.contains("2 pages"), "reasons must be printable: {msg}");
    }

    #[test]
    fn begin_token_reports_exhaustion() {
        let mut kv = tiny_pool();
        kv.admit(1, 16, 1, 24, 32).unwrap(); // 3 of 4 pages
        kv.admit(2, 16, 1, 8, 16).unwrap(); // the last page
        // Sequence 2 wants a new page: none free.
        assert!(kv.needs_page(2));
        match kv.begin_token(2) {
            Err(AdmitError::NoCapacity { needed_pages: 1, free_pages: 0 }) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        // Releasing sequence 1 unblocks it.
        assert_eq!(kv.release(1), 3);
        assert_eq!(kv.begin_token(2).unwrap(), 8);
        assert_eq!(kv.metrics.released, 1);
        assert_eq!(kv.metrics.freed_pages, 3);
    }

    #[test]
    fn release_reuses_lowest_frames_deterministically() {
        let mut kv = tiny_pool();
        kv.admit(1, 16, 1, 8, 8).unwrap(); // frame 0
        kv.admit(2, 16, 1, 8, 8).unwrap(); // frame 1
        kv.release(1);
        kv.admit(3, 16, 1, 8, 8).unwrap(); // must take frame 0 again
        kv.write_token_layer(3, 0, 0, &row(16, 9.0), &row(16, 9.0));
        let (k2, _) = kv.read_layer(2, 0);
        assert!(
            k2.data.iter().all(|&x| x == 0.0),
            "a reused frame must never alias a live sequence"
        );
        let (k3, _) = kv.read_layer(3, 0);
        assert_eq!(k3.at(0, 0), 9.0);
    }

    #[test]
    fn pool_budget_scales_with_device_class() {
        let little = KvConfig::for_class(&DeviceClass::paper());
        let big = KvConfig::for_class(&DeviceClass::parse("8x4@200").unwrap());
        assert_eq!(little.page_words, KvConfig::DEFAULT_PAGE_WORDS);
        assert_eq!(
            big.total_pages,
            2 * little.total_pages,
            "row-scaled L1 doubles the KV budget"
        );
        // Paper class: 32 KiB L1 = 8192 words; half = 4096 words = 4 pages.
        assert_eq!(little.total_pages, 4);
    }

    #[test]
    fn commit_tokens_is_all_or_nothing() {
        let mut kv = tiny_pool(); // 8 tokens/page, 4 pages
        kv.admit(1, 16, 1, 6, 30).unwrap(); // 1 page, 2 slack slots
        // Growing by 10 needs ceil(16/8) = 2 pages: fits (3 free).
        assert_eq!(kv.commit_tokens(1, 10).unwrap(), 6);
        assert_eq!(kv.len(1), 16);
        assert_eq!(kv.used_pages(), 2);
        kv.check_invariants();
        // Growing by 17 → 33 tokens needs 5 pages total, 3 more than
        // held; only 2 free: refused exactly, nothing committed.
        match kv.commit_tokens(1, 17) {
            Err(AdmitError::NoCapacity { needed_pages: 3, free_pages: 2 }) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        assert_eq!(kv.len(1), 16, "failed grow must not commit");
        assert_eq!(kv.used_pages(), 2, "failed grow must not allocate");
        kv.check_invariants();
        assert!(matches!(kv.commit_tokens(9, 1), Err(AdmitError::Unknown { seq: 9 })));
    }

    #[test]
    fn export_import_roundtrip_conserves_words_bitwise() {
        let mut src = tiny_pool();
        src.admit(3, 16, 1, 5, 12).unwrap();
        for t in 0..5 {
            src.write_token_layer(3, t, 0, &row(16, t as f32), &row(16, 10.0 + t as f32));
        }
        let fills_before = src.metrics.fill_words;
        let reads_before = src.metrics.read_words;
        let image = src.export_seq(3).unwrap();
        assert_eq!(image.len, 5);
        assert_eq!(image.word_count(), 5 * 32);
        assert_eq!(src.metrics.export_words, 5 * 32);
        assert_eq!(src.metrics.fill_words, fills_before, "export must not fake fills");
        assert_eq!(src.metrics.read_words, reads_before, "export must not fake reads");
        assert_eq!(src.len(3), 5, "export is non-destructive");
        // Import into a pool of a *different* page geometry.
        let mut dst = PagedKvCache::new(KvConfig::new(128, 8)); // 4 tokens/page
        dst.import_seq(3, &image, 12).unwrap();
        assert_eq!(dst.len(3), 5);
        assert_eq!(dst.metrics.import_words, 5 * 32);
        assert_eq!(dst.metrics.fill_words, 0, "import must not fake fills");
        dst.check_invariants();
        let (ks, vs) = src.read_layer(3, 0);
        let (kd, vd) = dst.read_layer(3, 0);
        assert_eq!(ks.data, kd.data, "K rows must survive migration bit for bit");
        assert_eq!(vs.data, vd.data, "V rows must survive migration bit for bit");
    }

    #[test]
    fn failed_import_changes_neither_side() {
        let mut src = tiny_pool();
        src.admit(1, 16, 1, 4, 8).unwrap();
        for t in 0..4 {
            src.write_token_layer(1, t, 0, &row(16, 1.0), &row(16, 2.0));
        }
        let image = src.export_seq(1).unwrap();
        // Destination too full: 1 page of 1 free needed vs a pool
        // packed by another sequence.
        let mut dst = PagedKvCache::new(KvConfig::new(256, 2));
        dst.admit(9, 16, 1, 16, 16).unwrap(); // both pages
        match dst.import_seq(1, &image, 8) {
            Err(AdmitError::NoCapacity { .. }) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        assert_eq!(dst.len(1), 0, "failed import must not leave a stub");
        assert_eq!(dst.metrics.import_words, 0);
        dst.check_invariants();
        assert_eq!(src.len(1), 4, "source stays intact on import failure");
        // A corrupt image is refused before any allocation: a missing
        // token trips the count check…
        let mut bad = image.clone();
        bad.tokens.pop();
        let mut fresh = tiny_pool();
        match fresh.import_seq(1, &bad, 8) {
            Err(AdmitError::CorruptImage { expected_words, got_words }) => {
                assert_eq!(expected_words, 4 * 32);
                assert_eq!(got_words, 3 * 32);
            }
            other => panic!("expected CorruptImage, got {other:?}"),
        }
        assert!(fresh.is_empty());
        // …and a short tensor trips the per-token check.
        let mut bad = image.clone();
        bad.tokens[2].v.pop();
        match fresh.import_seq(1, &bad, 8) {
            Err(AdmitError::CorruptTensor {
                token: 2,
                expected_words: 16,
                got_k_words: 16,
                got_v_words: 15,
            }) => {}
            other => panic!("expected CorruptTensor, got {other:?}"),
        }
        assert!(fresh.is_empty());
        let msg = AdmitError::CorruptImage { expected_words: 2, got_words: 1 }.to_string();
        assert!(msg.contains("corrupt KV image"), "reason must be printable: {msg}");
    }

    #[test]
    fn matching_total_with_skewed_tensors_is_refused() {
        // The regression the total-only check missed: truncate a
        // token's K by one row and pad its V by the same amount — the
        // image's total word count is untouched, but the payload is
        // garbage. Per-tensor validation must refuse it, and the pool
        // must stay byte-identical to before the attempt.
        let mut src = tiny_pool();
        src.admit(1, 16, 1, 4, 8).unwrap();
        for t in 0..4 {
            src.write_token_layer(1, t, 0, &row(16, 1.0 + t as f32), &row(16, -2.0));
        }
        let good = src.export_seq(1).unwrap();
        let total = good.word_count();
        let mut skewed = good.clone();
        skewed.tokens[1].k.truncate(skewed.tokens[1].k.len() - 16);
        skewed.tokens[1].v.extend(vec![7.5f32; 16]);
        assert_eq!(skewed.word_count(), total, "the forgery matches the total exactly");
        let mut dst = tiny_pool();
        assert!(!dst.can_import(1, &skewed, 8));
        match dst.import_seq(1, &skewed, 8) {
            Err(AdmitError::CorruptTensor {
                token: 1,
                expected_words: 16,
                got_k_words: 0,
                got_v_words: 32,
            }) => {}
            other => panic!("expected CorruptTensor, got {other:?}"),
        }
        assert!(dst.is_empty(), "refused import must not leave a stub");
        assert_eq!(dst.metrics.import_words, 0);
        dst.check_invariants();
        // Swapping K and V payloads is the same forgery when their
        // sizes differ (multi-row truncation); equal-size swaps are
        // structurally impossible to mislabel now that the image keeps
        // the tensors apart — the fields *are* the layout.
        assert!(dst.can_import(1, &good, 8), "the honest image still imports");
        dst.import_seq(1, &good, 8).unwrap();
        let (ks, vs) = src.read_layer(1, 0);
        let (kd, vd) = dst.read_layer(1, 0);
        assert_eq!(ks.data, kd.data);
        assert_eq!(vs.data, vd.data);
    }

    #[test]
    fn copy_prefix_clones_leading_tokens_without_faking_traffic() {
        let mut kv = tiny_pool();
        kv.admit(1, 16, 1, 5, 8).unwrap();
        for t in 0..5 {
            kv.write_token_layer(1, t, 0, &row(16, t as f32), &row(16, 100.0 + t as f32));
        }
        kv.admit(2, 16, 1, 5, 8).unwrap();
        let (fills, reads) = (kv.metrics.fill_words, kv.metrics.read_words);
        let copied = kv.copy_prefix(2, 1, 3);
        assert_eq!(copied, 3 * 32);
        assert_eq!(kv.metrics.fill_words, fills, "a prefix copy is not an attention fill");
        assert_eq!(kv.metrics.read_words, reads, "a prefix copy is not an attention read");
        kv.write_token_layer(2, 3, 0, &row(16, 50.0), &row(16, 51.0));
        kv.write_token_layer(2, 4, 0, &row(16, 60.0), &row(16, 61.0));
        let (k1, v1) = kv.read_layer(1, 0);
        let (k2, v2) = kv.read_layer(2, 0);
        assert_eq!(&k1.data[..3 * 16], &k2.data[..3 * 16], "prefix K must be bit-identical");
        assert_eq!(&v1.data[..3 * 16], &v2.data[..3 * 16], "prefix V must be bit-identical");
        assert_eq!(k2.at(3, 0), 50.0, "suffix stays the destination's own");
        kv.check_invariants();
    }

    #[test]
    fn multi_layer_layout_keeps_layers_separate() {
        let mut kv = PagedKvCache::new(KvConfig::new(512, 2));
        kv.admit(5, 16, 2, 2, 4).unwrap(); // 64 words/token, 8 tokens/page
        kv.write_token_layer(5, 0, 0, &row(16, 1.0), &row(16, 2.0));
        kv.write_token_layer(5, 0, 1, &row(16, 3.0), &row(16, 4.0));
        let (k0, v0) = kv.read_layer(5, 0);
        let (k1, v1) = kv.read_layer(5, 1);
        assert_eq!(k0.at(0, 0), 1.0);
        assert_eq!(v0.at(0, 0), 2.0);
        assert_eq!(k1.at(0, 0), 3.0);
        assert_eq!(v1.at(0, 0), 4.0);
    }
}
