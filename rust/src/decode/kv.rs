//! Paged KV cache: fixed-size pages of on-chip K/V residency with
//! per-sequence page tables and exact word accounting.
//!
//! Decode steps are GEMV-shaped and memory-bound: the dominant traffic
//! is reading every cached K/V row once per step per layer. What bounds
//! *concurrency* on an edge device is therefore KV **residency** — how
//! many sequences' caches fit on chip at once. This module models that
//! the way modern serving stacks do (vLLM's PagedAttention): the KV
//! arena is a pool of fixed-size pages (`page_words` 32-bit words
//! each), a sequence owns a page *table* (an ordered list of page
//! frames), and tokens map to (page, slot) by simple division — no
//! per-sequence contiguity, no fragmentation beyond the final partial
//! page.
//!
//! ## Budget
//!
//! The pool is provisioned from the device class's scratchpad: **half
//! of L1** is reserved for KV pages ([`KvConfig::for_class`]), so an
//! `8x4` class — whose L1 scales with its row count — holds twice the
//! resident tokens of the paper's `4x4`. One token of one sequence
//! costs `2 · d_model · n_layers` words (K and V rows across every
//! layer), giving `tokens_per_page = page_words / words_per_token`
//! per-sequence page geometry; models of different shapes coexist in
//! one pool because pages are raw words.
//!
//! ## Contract
//!
//! Admission and growth are **checked, never silent**: a sequence that
//! could never fit is rejected with a typed reason
//! ([`AdmitError::TooLarge`]), one that merely cannot fit *now* reports
//! [`AdmitError::NoCapacity`] (the scheduler's cue to wait or preempt),
//! and every write is bounds-checked against the owning table — a bug
//! cannot corrupt another sequence's pages. Fills and reads are counted
//! exactly ([`KvMetrics`]: `2·d_model` words per token-layer fill,
//! `2·d_model·len` words per per-layer gather), which is what the
//! decode metrics and the FIG8 bench report as KV traffic.

use crate::config::DeviceClass;
use crate::util::mat::MatF32;
use std::collections::BTreeMap;
use std::fmt;

/// Pool geometry: page size in 32-bit words and the page count of the
/// device's KV budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvConfig {
    /// Words per page (fixed for the pool; raw words, so models of
    /// different shapes share one pool).
    pub page_words: usize,
    /// Pages in the pool (the device budget).
    pub total_pages: usize,
}

impl KvConfig {
    /// Default page size: 1 KiWord = 16 resident tokens of the tiny
    /// edge class (d_model 32, 1 layer) per page.
    pub const DEFAULT_PAGE_WORDS: usize = 1024;

    pub fn new(page_words: usize, total_pages: usize) -> Self {
        assert!(page_words > 0 && total_pages > 0, "KV pool must be non-empty");
        Self { page_words, total_pages }
    }

    /// The budget formula: **half of the class's L1 words** are
    /// reserved for KV pages, split into [`Self::DEFAULT_PAGE_WORDS`]
    /// pages. Row-scaled classes therefore hold proportionally more
    /// resident sequences — the memory lever that makes big.LITTLE
    /// decode placement interesting.
    pub fn for_class(class: &DeviceClass) -> Self {
        Self::with_page_words(class, Self::DEFAULT_PAGE_WORDS)
    }

    /// [`Self::for_class`] with an explicit page size.
    pub fn with_page_words(class: &DeviceClass, page_words: usize) -> Self {
        let budget = class.arch.mem.l1_words / 2;
        let page_words = page_words.max(1);
        Self { page_words, total_pages: (budget / page_words).max(1) }
    }

    /// Total pool capacity in words.
    pub fn budget_words(&self) -> usize {
        self.page_words * self.total_pages
    }
}

/// Why a sequence could not be admitted or grown. Every variant carries
/// the numbers behind the decision — reject-with-reason, never a bare
/// boolean.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    /// The sequence's worst-case length can never fit the pool, even
    /// empty. Reject the request.
    TooLarge { worst_tokens: usize, capacity_tokens: usize },
    /// Not enough free pages right now. Wait for a release, or preempt.
    NoCapacity { needed_pages: usize, free_pages: usize },
    /// One token of this model is wider than a page.
    TokenTooWide { words_per_token: usize, page_words: usize },
    /// The sequence id is already resident.
    AlreadyAdmitted { seq: u64 },
    /// The sequence id is not resident (stale handle).
    Unknown { seq: u64 },
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::TooLarge { worst_tokens, capacity_tokens } => write!(
                f,
                "sequence can never fit: worst case {worst_tokens} tokens vs pool \
                 capacity {capacity_tokens}"
            ),
            Self::NoCapacity { needed_pages, free_pages } => {
                write!(f, "no capacity: needs {needed_pages} pages, {free_pages} free")
            }
            Self::TokenTooWide { words_per_token, page_words } => write!(
                f,
                "one token ({words_per_token} words) exceeds the page size ({page_words})"
            ),
            Self::AlreadyAdmitted { seq } => write!(f, "sequence {seq} already admitted"),
            Self::Unknown { seq } => write!(f, "sequence {seq} not resident"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Exact traffic and lifecycle counters for one pool.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct KvMetrics {
    /// Words written into pages (K/V fills): `2·d_model` per
    /// token-layer write.
    pub fill_words: u64,
    /// Words gathered out of pages for attention: `2·d_model·len` per
    /// per-layer read.
    pub read_words: u64,
    /// Sequences admitted (including re-admissions after preemption).
    pub admitted: u64,
    /// Sequences released (completion or preemption).
    pub released: u64,
    /// Pages returned to the free list by releases.
    pub freed_pages: u64,
}

/// One resident sequence: shape, page table, committed length.
#[derive(Debug, Clone)]
struct SeqKv {
    d_model: usize,
    n_layers: usize,
    tokens_per_page: usize,
    /// Ordered page frames; token `t` lives in `pages[t / tokens_per_page]`.
    pages: Vec<usize>,
    /// Tokens committed (slots reserved; rows may still be being
    /// written by the in-flight job).
    len: usize,
}

impl SeqKv {
    fn words_per_token(&self) -> usize {
        2 * self.d_model * self.n_layers
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.tokens_per_page)
    }
}

/// The paged pool: frames, free list, per-sequence tables.
pub struct PagedKvCache {
    cfg: KvConfig,
    /// Page frames (each `page_words` f32 slots; the cache stores the
    /// exact dequantized K/V activations, so decode numerics are
    /// bit-identical to prefill).
    frames: Vec<Vec<f32>>,
    /// Free frame ids, kept sorted descending so `pop()` hands out the
    /// lowest id first — allocation order is deterministic.
    free: Vec<usize>,
    seqs: BTreeMap<u64, SeqKv>,
    pub metrics: KvMetrics,
}

impl PagedKvCache {
    pub fn new(cfg: KvConfig) -> Self {
        Self {
            frames: vec![vec![0.0; cfg.page_words]; cfg.total_pages],
            free: (0..cfg.total_pages).rev().collect(),
            seqs: BTreeMap::new(),
            cfg,
            metrics: KvMetrics::default(),
        }
    }

    pub fn config(&self) -> KvConfig {
        self.cfg
    }

    /// Resident-token capacity of the whole pool for a model shape.
    pub fn capacity_tokens(&self, d_model: usize, n_layers: usize) -> usize {
        let wpt = 2 * d_model * n_layers;
        if wpt == 0 || wpt > self.cfg.page_words {
            return 0;
        }
        (self.cfg.page_words / wpt) * self.cfg.total_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.cfg.total_pages - self.free.len()
    }

    /// Pool occupancy in permille (0..=1000) — recorded per decode tick
    /// into the KV-occupancy histogram.
    pub fn occupancy_permille(&self) -> u64 {
        (self.used_pages() as u64 * 1000) / self.cfg.total_pages as u64
    }

    /// Committed token count of a resident sequence (0 if absent).
    pub fn len(&self, seq: u64) -> usize {
        self.seqs.get(&seq).map_or(0, |s| s.len)
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Whether growing `seq` by one token would need a fresh page.
    pub fn needs_page(&self, seq: u64) -> bool {
        self.seqs
            .get(&seq)
            .is_some_and(|s| s.pages_for(s.len + 1) > s.pages.len())
    }

    /// Admit a sequence: reserve pages for its `prompt_tokens` and
    /// commit those slots. `worst_tokens` is the longest the sequence
    /// can ever grow (prompt + new tokens − 1); a worst case beyond the
    /// *empty-pool* capacity is rejected outright ([`AdmitError::
    /// TooLarge`]) — everything admitted is guaranteed completable once
    /// its peers drain, which is what makes LIFO preemption safe.
    pub fn admit(
        &mut self,
        seq: u64,
        d_model: usize,
        n_layers: usize,
        prompt_tokens: usize,
        worst_tokens: usize,
    ) -> Result<(), AdmitError> {
        assert!(prompt_tokens > 0, "a sequence starts with at least one token");
        let wpt = 2 * d_model * n_layers;
        if wpt > self.cfg.page_words {
            return Err(AdmitError::TokenTooWide {
                words_per_token: wpt,
                page_words: self.cfg.page_words,
            });
        }
        if self.seqs.contains_key(&seq) {
            return Err(AdmitError::AlreadyAdmitted { seq });
        }
        let tokens_per_page = self.cfg.page_words / wpt;
        let capacity = tokens_per_page * self.cfg.total_pages;
        if worst_tokens.max(prompt_tokens) > capacity {
            return Err(AdmitError::TooLarge {
                worst_tokens: worst_tokens.max(prompt_tokens),
                capacity_tokens: capacity,
            });
        }
        let needed = prompt_tokens.div_ceil(tokens_per_page);
        if needed > self.free.len() {
            return Err(AdmitError::NoCapacity {
                needed_pages: needed,
                free_pages: self.free.len(),
            });
        }
        let pages: Vec<usize> =
            (0..needed).map(|_| self.free.pop().expect("checked above")).collect();
        self.seqs.insert(
            seq,
            SeqKv { d_model, n_layers, tokens_per_page, pages, len: prompt_tokens },
        );
        self.metrics.admitted += 1;
        Ok(())
    }

    /// Whether [`Self::admit`] would currently succeed for this shape.
    pub fn can_admit(&self, d_model: usize, n_layers: usize, prompt_tokens: usize) -> bool {
        let wpt = 2 * d_model * n_layers;
        if wpt == 0 || wpt > self.cfg.page_words || prompt_tokens == 0 {
            return false;
        }
        let tpp = self.cfg.page_words / wpt;
        prompt_tokens.div_ceil(tpp) <= self.free.len()
    }

    /// Commit one more token slot for `seq`, allocating a page when the
    /// current tail page is full. Returns the token index to write.
    /// [`AdmitError::NoCapacity`] means the scheduler must free pages
    /// (preempt) before this sequence can take its next step.
    pub fn begin_token(&mut self, seq: u64) -> Result<usize, AdmitError> {
        let free_now = self.free.len();
        let s = self.seqs.get_mut(&seq).ok_or(AdmitError::Unknown { seq })?;
        if s.pages_for(s.len + 1) > s.pages.len() {
            if free_now == 0 {
                return Err(AdmitError::NoCapacity { needed_pages: 1, free_pages: 0 });
            }
            let frame = self.free.pop().expect("checked above");
            s.pages.push(frame);
        }
        let token = s.len;
        s.len += 1;
        Ok(token)
    }

    /// Write one layer's K and V rows for a committed token. Panics on
    /// out-of-table writes — a scheduling bug must never silently
    /// corrupt a neighbour's pages.
    pub fn write_token_layer(
        &mut self,
        seq: u64,
        token: usize,
        layer: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let s = self.seqs.get(&seq).expect("sequence must be resident");
        assert!(token < s.len, "token {token} beyond committed length {}", s.len);
        assert!(layer < s.n_layers, "layer {layer} out of range");
        assert_eq!(k.len(), s.d_model, "K row width mismatch");
        assert_eq!(v.len(), s.d_model, "V row width mismatch");
        let frame = s.pages[token / s.tokens_per_page];
        let base = (token % s.tokens_per_page) * s.words_per_token() + layer * 2 * s.d_model;
        let d = s.d_model;
        let buf = &mut self.frames[frame];
        buf[base..base + d].copy_from_slice(k);
        buf[base + d..base + 2 * d].copy_from_slice(v);
        self.metrics.fill_words += 2 * d as u64;
    }

    /// Write a whole prompt's K/V for one layer (token rows `0..k.rows`).
    pub fn write_prompt_layer(&mut self, seq: u64, layer: usize, k: &MatF32, v: &MatF32) {
        assert_eq!(k.rows, v.rows, "K/V row count mismatch");
        for t in 0..k.rows {
            self.write_token_layer(seq, t, layer, k.row(t), v.row(t));
        }
    }

    /// Gather one layer's cached K and V (`len × d_model` each) for
    /// attention, counting the read traffic exactly.
    pub fn read_layer(&mut self, seq: u64, layer: usize) -> (MatF32, MatF32) {
        let s = self.seqs.get(&seq).expect("sequence must be resident");
        let d = s.d_model;
        let mut k = MatF32::zeros(s.len, d);
        let mut v = MatF32::zeros(s.len, d);
        for t in 0..s.len {
            let frame = s.pages[t / s.tokens_per_page];
            let base = (t % s.tokens_per_page) * s.words_per_token() + layer * 2 * d;
            let buf = &self.frames[frame];
            k.data[t * d..(t + 1) * d].copy_from_slice(&buf[base..base + d]);
            v.data[t * d..(t + 1) * d].copy_from_slice(&buf[base + d..base + 2 * d]);
        }
        self.metrics.read_words += (2 * d * s.len) as u64;
        (k, v)
    }

    /// Release a sequence (completion or preemption), returning its
    /// pages to the free list. Returns the page count freed.
    pub fn release(&mut self, seq: u64) -> usize {
        let Some(s) = self.seqs.remove(&seq) else { return 0 };
        let n = s.pages.len();
        self.free.extend(s.pages);
        // Keep the free list sorted descending so the next allocation
        // is still the lowest id (deterministic reuse).
        self.free.sort_unstable_by(|a, b| b.cmp(a));
        self.metrics.released += 1;
        self.metrics.freed_pages += n as u64;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pool() -> PagedKvCache {
        // 4 pages × 256 words; d_model 16, 1 layer → 32 words/token →
        // 8 tokens per page, 32-token pool capacity.
        PagedKvCache::new(KvConfig::new(256, 4))
    }

    fn row(d: usize, fill: f32) -> Vec<f32> {
        vec![fill; d]
    }

    #[test]
    fn admit_write_read_roundtrip() {
        let mut kv = tiny_pool();
        kv.admit(7, 16, 1, 3, 10).unwrap();
        assert_eq!(kv.len(7), 3);
        assert_eq!(kv.used_pages(), 1);
        for t in 0..3 {
            kv.write_token_layer(7, t, 0, &row(16, t as f32), &row(16, -(t as f32)));
        }
        let (k, v) = kv.read_layer(7, 0);
        assert_eq!((k.rows, k.cols), (3, 16));
        assert_eq!(k.at(2, 5), 2.0);
        assert_eq!(v.at(1, 0), -1.0);
        assert_eq!(kv.metrics.fill_words, 3 * 32);
        assert_eq!(kv.metrics.read_words, 3 * 32);
    }

    #[test]
    fn growth_allocates_pages_on_boundaries() {
        let mut kv = tiny_pool();
        kv.admit(1, 16, 1, 8, 20).unwrap(); // exactly one full page
        assert_eq!(kv.used_pages(), 1);
        assert!(kv.needs_page(1));
        let t = kv.begin_token(1).unwrap();
        assert_eq!(t, 8);
        assert_eq!(kv.used_pages(), 2, "crossing the boundary takes a page");
        for _ in 9..16 {
            kv.begin_token(1).unwrap();
        }
        assert_eq!(kv.used_pages(), 2, "within-page growth allocates nothing");
    }

    #[test]
    fn rejects_carry_reasons() {
        let mut kv = tiny_pool();
        // Worst case beyond the whole pool (capacity 32 tokens).
        match kv.admit(1, 16, 1, 4, 33) {
            Err(AdmitError::TooLarge { worst_tokens: 33, capacity_tokens: 32 }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // A token wider than a page.
        match kv.admit(1, 256, 1, 1, 1) {
            Err(AdmitError::TokenTooWide { .. }) => {}
            other => panic!("expected TokenTooWide, got {other:?}"),
        }
        // Pool full right now: NoCapacity, not TooLarge.
        kv.admit(1, 16, 1, 24, 24).unwrap(); // 3 pages
        match kv.admit(2, 16, 1, 9, 9) {
            Err(AdmitError::NoCapacity { needed_pages: 2, free_pages: 1 }) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        assert!(!kv.can_admit(16, 1, 9));
        assert!(kv.can_admit(16, 1, 8));
        // Double admission is a typed error too.
        match kv.admit(1, 16, 1, 1, 1) {
            Err(AdmitError::AlreadyAdmitted { seq: 1 }) => {}
            other => panic!("expected AlreadyAdmitted, got {other:?}"),
        }
        let msg = AdmitError::NoCapacity { needed_pages: 2, free_pages: 1 }.to_string();
        assert!(msg.contains("2 pages"), "reasons must be printable: {msg}");
    }

    #[test]
    fn begin_token_reports_exhaustion() {
        let mut kv = tiny_pool();
        kv.admit(1, 16, 1, 24, 32).unwrap(); // 3 of 4 pages
        kv.admit(2, 16, 1, 8, 16).unwrap(); // the last page
        // Sequence 2 wants a new page: none free.
        assert!(kv.needs_page(2));
        match kv.begin_token(2) {
            Err(AdmitError::NoCapacity { needed_pages: 1, free_pages: 0 }) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        // Releasing sequence 1 unblocks it.
        assert_eq!(kv.release(1), 3);
        assert_eq!(kv.begin_token(2).unwrap(), 8);
        assert_eq!(kv.metrics.released, 1);
        assert_eq!(kv.metrics.freed_pages, 3);
    }

    #[test]
    fn release_reuses_lowest_frames_deterministically() {
        let mut kv = tiny_pool();
        kv.admit(1, 16, 1, 8, 8).unwrap(); // frame 0
        kv.admit(2, 16, 1, 8, 8).unwrap(); // frame 1
        kv.release(1);
        kv.admit(3, 16, 1, 8, 8).unwrap(); // must take frame 0 again
        kv.write_token_layer(3, 0, 0, &row(16, 9.0), &row(16, 9.0));
        let (k2, _) = kv.read_layer(2, 0);
        assert!(
            k2.data.iter().all(|&x| x == 0.0),
            "a reused frame must never alias a live sequence"
        );
        let (k3, _) = kv.read_layer(3, 0);
        assert_eq!(k3.at(0, 0), 9.0);
    }

    #[test]
    fn pool_budget_scales_with_device_class() {
        let little = KvConfig::for_class(&DeviceClass::paper());
        let big = KvConfig::for_class(&DeviceClass::parse("8x4@200").unwrap());
        assert_eq!(little.page_words, KvConfig::DEFAULT_PAGE_WORDS);
        assert_eq!(
            big.total_pages,
            2 * little.total_pages,
            "row-scaled L1 doubles the KV budget"
        );
        // Paper class: 32 KiB L1 = 8192 words; half = 4096 words = 4 pages.
        assert_eq!(little.total_pages, 4);
    }

    #[test]
    fn multi_layer_layout_keeps_layers_separate() {
        let mut kv = PagedKvCache::new(KvConfig::new(512, 2));
        kv.admit(5, 16, 2, 2, 4).unwrap(); // 64 words/token, 8 tokens/page
        kv.write_token_layer(5, 0, 0, &row(16, 1.0), &row(16, 2.0));
        kv.write_token_layer(5, 0, 1, &row(16, 3.0), &row(16, 4.0));
        let (k0, v0) = kv.read_layer(5, 0);
        let (k1, v1) = kv.read_layer(5, 1);
        assert_eq!(k0.at(0, 0), 1.0);
        assert_eq!(v0.at(0, 0), 2.0);
        assert_eq!(k1.at(0, 0), 3.0);
        assert_eq!(v1.at(0, 0), 4.0);
    }
}
