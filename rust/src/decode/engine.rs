//! Quantized prefill and decode-step execution on the CGRA.
//!
//! Two kernels-level entry points implement the generation dataflow:
//!
//! - [`run_prefill_batch`] — the **prompt phase**: a causal forward
//!   over each sequence's full prompt, with every projection/FFN GEMM
//!   stacked across the batch exactly like the encoder's batched path
//!   (weights streamed once), causal masking in the per-sequence
//!   attention, and the dequantized K/V activations of every layer
//!   written into the sequence's pages of the [`PagedKvCache`].
//! - [`run_decode_tick`] — one **generation step** for a batch of
//!   running sequences: each contributes a single activation row, the
//!   projections and FFN run as one stacked GEMV per site (`B × d`
//!   rows — the continuous-batching kernel shape), and attention runs
//!   each new Q row against that sequence's cached K/V (gathered from
//!   its pages, with the read traffic counted exactly).
//!
//! ## Exactness contract
//!
//! Both paths use the *static causal calibration*
//! ([`EncoderQuant::calibrate_causal`]): every scale and requant shift
//! is a per-(model, layer, site) constant, every per-row operation is
//! row-independent, and causal attention over the cache sees exactly
//! the rows a full forward's masked softmax would weight non-zero. As
//! a consequence token-by-token decode is **bit-identical** to the
//! one-shot causal forward of the same rows — regardless of the
//! prefill/decode split point, of which batch a row rode in, and of
//! which device class executed it. `rust/tests/decode_props.rs` pins
//! this down over random shapes, seeds and split points.

use super::kv::{AdmitError, PagedKvCache};
use crate::sim::CgraSim;
use crate::util::mat::MatF32;
use crate::xformer::decoder::{causal_mask, DecoderModel};
use crate::xformer::run::cgra_matmul_f32_calibrated;
use crate::xformer::{quantize_with, CgraEncoderReport, EncoderQuant};
use anyhow::{ensure, Result};

/// Copy row `r` of `m` as a standalone `1 × cols` matrix (the decode
/// step's input/output currency).
pub fn mat_row(m: &MatF32, r: usize) -> MatF32 {
    MatF32::from_slice(1, m.cols, m.row(r))
}

/// Causal prefill over a batch of prompts (one stacked job), resumable
/// from an arbitrary token offset — the chunked-prefill kernel.
///
/// `seqs` pairs each chunk of prompt rows (`p × d_model`, `1 ≤ p`) with
/// its KV-cache sequence id. The sequence must be committed to exactly
/// `offset + p` tokens, where `offset = kv.len(id) − p` is the number
/// of rows earlier chunks already filled ([`PagedKvCache::admit`] for
/// the first chunk, [`PagedKvCache::commit_tokens`] for growth); a
/// whole-prompt prefill is simply the `offset = 0` case. A resumed
/// chunk's attention gathers the cached K/V of its prefix from the
/// pages (the same read path — and the same exact dequantized values —
/// a decode tick uses) and masks causally at the chunk's base offset,
/// so **any chunk schedule produces bit-identical hidden states to the
/// one-shot causal forward** of the same rows. The prefix cache leans
/// on the same contract: pages pre-filled by
/// [`PagedKvCache::copy_prefix`] read exactly like pages an earlier
/// chunk filled, so a cache hit that skips the leading rows is
/// indistinguishable — bit for bit — from having computed them.
/// Returns each sequence's chunk hidden-state matrix (`p × d_model`;
/// for the *final* chunk the last row is the first generated token)
/// plus the kernel accounting report.
pub fn run_prefill_batch(
    sim: &mut CgraSim,
    model: &DecoderModel,
    quant: &EncoderQuant,
    kv: &mut PagedKvCache,
    seqs: &[(u64, &MatF32)],
) -> Result<(Vec<MatF32>, CgraEncoderReport)> {
    ensure!(!seqs.is_empty(), "prefill batch needs at least one sequence");
    let cfg = &model.cfg;
    ensure!(
        quant.layers.len() == model.params.layers.len(),
        "calibration does not match the model's layer count"
    );
    for (id, x) in seqs {
        ensure!(x.cols == cfg.d_model, "prompt width must be d_model");
        ensure!(
            x.rows >= 1 && kv.len(*id) <= cfg.seq,
            "chunk rows must be ≥ 1 and committed tokens within the context limit {}",
            cfg.seq
        );
        ensure!(
            kv.len(*id) >= x.rows,
            "sequence {id} must be committed to its chunk offset plus the chunk's rows"
        );
    }
    // Token offset of each chunk's first row (0 = whole-prompt prefill).
    let offs: Vec<usize> = seqs.iter().map(|(id, x)| kv.len(*id) - x.rows).collect();
    let b = seqs.len();
    let dh = cfg.d_head();
    let att_scale = 1.0 / (dh as f32).sqrt();
    let total_rows: u64 = seqs.iter().map(|(_, x)| x.rows as u64).sum();
    let mut report = CgraEncoderReport::default();
    let mut hs: Vec<MatF32> = seqs.iter().map(|(_, x)| (*x).clone()).collect();
    for (li, (layer, lq)) in model.params.layers.iter().zip(&quant.layers).enumerate() {
        let ln1: Vec<MatF32> = hs
            .iter()
            .map(|h| h.layernorm_rows(&layer.ln1_gamma, &layer.ln1_beta, 1e-5))
            .collect();
        report.host_elems += total_rows * cfg.d_model as u64 * 6;
        let refs: Vec<&MatF32> = ln1.iter().collect();
        let q = cgra_matmul_f32_calibrated(sim, &refs, &lq.wq_q, &lq.q, &mut report)?;
        let k = cgra_matmul_f32_calibrated(sim, &refs, &lq.wk_q, &lq.k, &mut report)?;
        let v = cgra_matmul_f32_calibrated(sim, &refs, &lq.wv_q, &lq.v, &mut report)?;
        // Page fills: the exact dequantized K/V activations land in the
        // sequence's pages at the chunk's token offset.
        for (r, (id, _)) in seqs.iter().enumerate() {
            kv.write_rows_layer(*id, offs[r], li, &k[r], &v[r]);
        }
        let mut ctxs: Vec<MatF32> =
            hs.iter().map(|h| MatF32::zeros(h.rows, cfg.d_model)).collect();
        for r in 0..b {
            let s_r = hs[r].rows;
            let off = offs[r];
            // A resumed chunk attends to its cached prefix as well: the
            // gather (the decode tick's read path, traffic counted) is
            // the exact dequantized rows the one-shot forward computes.
            let gathered;
            let (k_att, v_att): (&MatF32, &MatF32) = if off == 0 {
                (&k[r], &v[r])
            } else {
                gathered = kv.read_layer(seqs[r].0, li);
                (&gathered.0, &gathered.1)
            };
            for hd in 0..cfg.n_heads {
                let lo = hd * dh;
                let (qh, kh, vh) = (
                    q[r].col_slice(lo, dh),
                    k_att.col_slice(lo, dh),
                    v_att.col_slice(lo, dh),
                );
                let kht_q = quantize_with(&kh.transpose(), lq.scores.w_scale);
                let mut scores =
                    cgra_matmul_f32_calibrated(sim, &[&qh], &kht_q, &lq.scores, &mut report)?
                        .pop()
                        .expect("one block");
                for val in &mut scores.data {
                    *val *= att_scale;
                }
                causal_mask(&mut scores, off);
                let probs = scores.softmax_rows();
                report.host_elems += (s_r * (off + s_r)) as u64 * 5;
                let vh_q = quantize_with(&vh, lq.attn_v.w_scale);
                let out =
                    cgra_matmul_f32_calibrated(sim, &[&probs], &vh_q, &lq.attn_v, &mut report)?
                        .pop()
                        .expect("one block");
                ctxs[r].set_col_slice(lo, &out);
            }
        }
        let refs: Vec<&MatF32> = ctxs.iter().collect();
        let attn = cgra_matmul_f32_calibrated(sim, &refs, &lq.wo_q, &lq.o, &mut report)?;
        let x1: Vec<MatF32> = hs.iter().zip(&attn).map(|(h, a)| h.add(a)).collect();
        report.host_elems += total_rows * cfg.d_model as u64;
        let ln2: Vec<MatF32> = x1
            .iter()
            .map(|x| x.layernorm_rows(&layer.ln2_gamma, &layer.ln2_beta, 1e-5))
            .collect();
        report.host_elems += total_rows * cfg.d_model as u64 * 6;
        let refs: Vec<&MatF32> = ln2.iter().collect();
        let ff1: Vec<MatF32> =
            cgra_matmul_f32_calibrated(sim, &refs, &lq.w1_q, &lq.ff1, &mut report)?
                .into_iter()
                .map(|m| m.gelu())
                .collect();
        report.host_elems += total_rows * cfg.d_ff as u64 * 8;
        let refs: Vec<&MatF32> = ff1.iter().collect();
        let ff2 = cgra_matmul_f32_calibrated(sim, &refs, &lq.w2_q, &lq.ff2, &mut report)?;
        hs = x1.iter().zip(&ff2).map(|(x, f)| x.add(f)).collect();
        report.host_elems += total_rows * cfg.d_model as u64;
    }
    Ok((hs, report))
}

/// One continuous-batching decode step for a batch of running
/// sequences of the same model.
///
/// Each entry pairs a resident sequence id with its next input row
/// (`1 × d_model` — the previous step's output, or the last prompt
/// hidden row right after prefill). Commits one token slot per
/// sequence (the caller must have ensured page capacity, preempting if
/// needed), runs every projection/FFN site as one stacked `B × d`
/// GEMV, and attends each sequence's new row against its own cached
/// K/V. Returns the per-sequence output rows in input order.
pub fn run_decode_tick(
    sim: &mut CgraSim,
    model: &DecoderModel,
    quant: &EncoderQuant,
    kv: &mut PagedKvCache,
    seqs: &[(u64, &MatF32)],
) -> Result<(Vec<MatF32>, CgraEncoderReport)> {
    ensure!(!seqs.is_empty(), "decode tick needs at least one sequence");
    let cfg = &model.cfg;
    ensure!(
        quant.layers.len() == model.params.layers.len(),
        "calibration does not match the model's layer count"
    );
    for (i, (id, x)) in seqs.iter().enumerate() {
        ensure!(
            x.rows == 1 && x.cols == cfg.d_model,
            "decode input must be a single 1×d_model row"
        );
        ensure!(kv.len(*id) >= 1, "sequence {id} is not resident in the KV cache");
        ensure!(
            kv.len(*id) < cfg.seq,
            "sequence {id} is already at the context limit ({})",
            cfg.seq
        );
        ensure!(
            seqs[..i].iter().all(|(other, _)| other != id),
            "sequence {id} appears twice in one tick"
        );
    }
    // All-or-nothing slot commit: page capacity is checked for the
    // whole batch *before* any slot is taken, so a capacity miss
    // leaves every sequence's cache untouched — the scheduler can
    // preempt and retry without a half-committed (and never-written)
    // token slot corrupting later attention reads.
    let need = seqs.iter().filter(|(id, _)| kv.needs_page(*id)).count();
    let free = kv.free_pages();
    if need > free {
        return Err(AdmitError::NoCapacity { needed_pages: need, free_pages: free }.into());
    }
    let b = seqs.len();
    let mut tokens = Vec::with_capacity(b);
    for (id, _) in seqs {
        tokens.push(kv.begin_token(*id)?);
    }
    let dh = cfg.d_head();
    let att_scale = 1.0 / (dh as f32).sqrt();
    let mut report = CgraEncoderReport::default();
    let mut hs: Vec<MatF32> = seqs.iter().map(|(_, x)| (*x).clone()).collect();
    for (li, (layer, lq)) in model.params.layers.iter().zip(&quant.layers).enumerate() {
        let ln1: Vec<MatF32> = hs
            .iter()
            .map(|h| h.layernorm_rows(&layer.ln1_gamma, &layer.ln1_beta, 1e-5))
            .collect();
        report.host_elems += (b * cfg.d_model) as u64 * 6;
        let refs: Vec<&MatF32> = ln1.iter().collect();
        // The continuous-batching shape: one stacked B×d GEMV per
        // projection site across every running sequence.
        let q = cgra_matmul_f32_calibrated(sim, &refs, &lq.wq_q, &lq.q, &mut report)?;
        let k = cgra_matmul_f32_calibrated(sim, &refs, &lq.wk_q, &lq.k, &mut report)?;
        let v = cgra_matmul_f32_calibrated(sim, &refs, &lq.wv_q, &lq.v, &mut report)?;
        let mut ctxs: Vec<MatF32> = (0..b).map(|_| MatF32::zeros(1, cfg.d_model)).collect();
        for (r, (id, _)) in seqs.iter().enumerate() {
            kv.write_token_layer(*id, tokens[r], li, k[r].row(0), v[r].row(0));
            let (k_full, v_full) = kv.read_layer(*id, li);
            for hd in 0..cfg.n_heads {
                let lo = hd * dh;
                let q_row = q[r].col_slice(lo, dh);
                let kht_q =
                    quantize_with(&k_full.col_slice(lo, dh).transpose(), lq.scores.w_scale);
                let mut scores =
                    cgra_matmul_f32_calibrated(sim, &[&q_row], &kht_q, &lq.scores, &mut report)?
                        .pop()
                        .expect("one block");
                for val in &mut scores.data {
                    *val *= att_scale;
                }
                // No mask needed: the cache holds exactly the visible
                // positions 0..=t for this row.
                let probs = scores.softmax_rows();
                report.host_elems += scores_len(&probs) * 5;
                let vh_q = quantize_with(&v_full.col_slice(lo, dh), lq.attn_v.w_scale);
                let out =
                    cgra_matmul_f32_calibrated(sim, &[&probs], &vh_q, &lq.attn_v, &mut report)?
                        .pop()
                        .expect("one block");
                ctxs[r].set_col_slice(lo, &out);
            }
        }
        let refs: Vec<&MatF32> = ctxs.iter().collect();
        let attn = cgra_matmul_f32_calibrated(sim, &refs, &lq.wo_q, &lq.o, &mut report)?;
        let x1: Vec<MatF32> = hs.iter().zip(&attn).map(|(h, a)| h.add(a)).collect();
        let ln2: Vec<MatF32> = x1
            .iter()
            .map(|x| x.layernorm_rows(&layer.ln2_gamma, &layer.ln2_beta, 1e-5))
            .collect();
        report.host_elems += (b * cfg.d_model) as u64 * 7;
        let refs: Vec<&MatF32> = ln2.iter().collect();
        let ff1: Vec<MatF32> =
            cgra_matmul_f32_calibrated(sim, &refs, &lq.w1_q, &lq.ff1, &mut report)?
                .into_iter()
                .map(|m| m.gelu())
                .collect();
        report.host_elems += (b * cfg.d_ff) as u64 * 8;
        let refs: Vec<&MatF32> = ff1.iter().collect();
        let ff2 = cgra_matmul_f32_calibrated(sim, &refs, &lq.w2_q, &lq.ff2, &mut report)?;
        hs = x1.iter().zip(&ff2).map(|(x, f)| x.add(f)).collect();
        report.host_elems += (b * cfg.d_model) as u64;
    }
    Ok((hs, report))
}

fn scores_len(probs: &MatF32) -> u64 {
    (probs.rows * probs.cols) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::decode::kv::KvConfig;
    use crate::util::rng::XorShiftRng;
    use crate::xformer::XformerConfig;

    fn cfg() -> XformerConfig {
        XformerConfig { n_layers: 1, seq: 8, d_model: 16, n_heads: 2, d_ff: 32 }
    }

    fn input(rows: usize, cols: usize, seed: u64) -> MatF32 {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(rows, cols);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        x
    }

    fn pool() -> PagedKvCache {
        PagedKvCache::new(KvConfig::new(256, 8))
    }

    #[test]
    fn split_decode_is_bit_identical_to_one_shot_prefill() {
        let c = cfg();
        let model = DecoderModel::new(c, 42);
        let quant = EncoderQuant::calibrate_causal_seeded(&model, 5);
        let x = input(8, c.d_model, 9);

        // One-shot: the whole sequence as a single prefill.
        let mut sim = CgraSim::new(ArchConfig::default());
        let mut kv = pool();
        kv.admit(1, c.d_model, c.n_layers, 8, 8).unwrap();
        let (full, _) = run_prefill_batch(&mut sim, &model, &quant, &mut kv, &[(1, &x)]).unwrap();

        // Split: prefill 5 rows, then 3 teacher-forced decode steps.
        let mut sim2 = CgraSim::new(ArchConfig::default());
        let mut kv2 = pool();
        let p = 5usize;
        let mut prefix = MatF32::zeros(p, c.d_model);
        prefix.data.copy_from_slice(&x.data[..p * c.d_model]);
        kv2.admit(1, c.d_model, c.n_layers, p, 8).unwrap();
        let (pre, _) =
            run_prefill_batch(&mut sim2, &model, &quant, &mut kv2, &[(1, &prefix)]).unwrap();
        for r in 0..p {
            assert_eq!(pre[0].row(r), full[0].row(r), "prefill row {r} diverged");
        }
        for t in p..8 {
            let row = mat_row(&x, t);
            let (out, _) =
                run_decode_tick(&mut sim2, &model, &quant, &mut kv2, &[(1, &row)]).unwrap();
            assert_eq!(out[0].row(0), full[0].row(t), "decode step at {t} diverged");
        }
        assert_eq!(kv2.len(1), 8);
        assert!(kv2.metrics.read_words > 0, "decode must read the cache");
    }

    #[test]
    fn chunked_prefill_matches_one_shot_bitwise() {
        // An uneven chunk schedule (3 + 1 + 4 rows) must reproduce the
        // one-shot causal prefill's hidden states exactly, chunk by
        // chunk — the kernel-level contract the fleet's Chunked
        // schedule and the migration_props suite build on.
        let c = cfg();
        let model = DecoderModel::new(c, 17);
        let quant = EncoderQuant::calibrate_causal_seeded(&model, 2);
        let x = input(8, c.d_model, 31);
        let mut sim = CgraSim::new(ArchConfig::default());
        let mut kv = pool();
        kv.admit(1, c.d_model, c.n_layers, 8, 8).unwrap();
        let (full, _) = run_prefill_batch(&mut sim, &model, &quant, &mut kv, &[(1, &x)]).unwrap();

        let mut sim2 = CgraSim::new(ArchConfig::default());
        let mut kv2 = pool();
        kv2.admit(1, c.d_model, c.n_layers, 3, 8).unwrap();
        let mut done = 0usize;
        for rows in [3usize, 1, 4] {
            if done > 0 {
                assert_eq!(kv2.commit_tokens(1, rows).unwrap(), done);
            }
            let chunk = MatF32::from_slice(
                rows,
                c.d_model,
                &x.data[done * c.d_model..(done + rows) * c.d_model],
            );
            let (out, _) =
                run_prefill_batch(&mut sim2, &model, &quant, &mut kv2, &[(1, &chunk)]).unwrap();
            for r in 0..rows {
                assert_eq!(out[0].row(r), full[0].row(done + r), "row {} diverged", done + r);
            }
            done += rows;
        }
        assert_eq!(kv2.len(1), 8);
        assert!(kv2.metrics.read_words > 0, "resumed chunks must gather their prefix");
    }

    #[test]
    fn stacked_tick_matches_solo_ticks_bitwise() {
        // Two sequences share a tick: each output must equal the same
        // sequence stepped alone — the join/leave neutrality at the
        // kernel level.
        let c = cfg();
        let model = DecoderModel::new(c, 7);
        let quant = EncoderQuant::calibrate_causal_seeded(&model, 3);
        let xa = input(3, c.d_model, 11);
        let xb = input(5, c.d_model, 13);

        let run_pair = |together: bool| -> (MatF32, MatF32) {
            let mut sim = CgraSim::new(ArchConfig::default());
            let mut kv = pool();
            kv.admit(1, c.d_model, c.n_layers, 3, 4).unwrap();
            kv.admit(2, c.d_model, c.n_layers, 5, 6).unwrap();
            let (pre, _) = run_prefill_batch(
                &mut sim,
                &model,
                &quant,
                &mut kv,
                &[(1, &xa), (2, &xb)],
            )
            .unwrap();
            let ra = mat_row(&pre[0], 2);
            let rb = mat_row(&pre[1], 4);
            if together {
                let (out, rep) =
                    run_decode_tick(&mut sim, &model, &quant, &mut kv, &[(1, &ra), (2, &rb)])
                        .unwrap();
                assert!(rep.stacked_kernels > 0, "shared ticks must stack the GEMVs");
                (out[0].clone(), out[1].clone())
            } else {
                let (oa, _) =
                    run_decode_tick(&mut sim, &model, &quant, &mut kv, &[(1, &ra)]).unwrap();
                let (ob, _) =
                    run_decode_tick(&mut sim, &model, &quant, &mut kv, &[(2, &rb)]).unwrap();
                (oa[0].clone(), ob[0].clone())
            }
        };
        let (a1, b1) = run_pair(true);
        let (a2, b2) = run_pair(false);
        assert_eq!(a1.data, a2.data, "sequence 1 perturbed by sharing a tick");
        assert_eq!(b1.data, b2.data, "sequence 2 perturbed by sharing a tick");
    }

    #[test]
    fn tick_capacity_miss_is_all_or_nothing() {
        // Two resident sequences, pool sized so only one can grow: the
        // tick must fail *without* committing either sequence's slot,
        // and succeed cleanly once pages are freed.
        let c = cfg();
        let model = DecoderModel::new(c, 3);
        let quant = EncoderQuant::calibrate_causal_seeded(&model, 3);
        let mut sim = CgraSim::new(ArchConfig::default());
        // 64-word pages hold 2 tokens of this shape; 2 pages total.
        let mut kv = PagedKvCache::new(KvConfig::new(64, 2));
        let xa = input(2, c.d_model, 21);
        let xb = input(2, c.d_model, 22);
        kv.admit(1, c.d_model, c.n_layers, 2, 4).unwrap();
        kv.admit(2, c.d_model, c.n_layers, 2, 4).unwrap();
        run_prefill_batch(&mut sim, &model, &quant, &mut kv, &[(1, &xa), (2, &xb)]).unwrap();
        let ra = mat_row(&xa, 1);
        let rb = mat_row(&xb, 1);
        // Both full (2 tokens = 1 page each), zero free pages: growing
        // either needs a page, so the shared tick must refuse whole.
        let err = run_decode_tick(&mut sim, &model, &quant, &mut kv, &[(1, &ra), (2, &rb)])
            .unwrap_err();
        assert!(err.to_string().contains("no capacity"), "typed reason: {err}");
        assert_eq!(kv.len(1), 2, "failed tick must not commit sequence 1's slot");
        assert_eq!(kv.len(2), 2, "failed tick must not commit sequence 2's slot");
        // Freeing one sequence unblocks the other exactly.
        kv.release(2);
        let (out, _) =
            run_decode_tick(&mut sim, &model, &quant, &mut kv, &[(1, &ra)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(kv.len(1), 3);
    }

    #[test]
    fn tick_rejects_context_overflow_and_foreign_rows() {
        let c = cfg();
        let model = DecoderModel::new(c, 1);
        let quant = EncoderQuant::calibrate_causal_seeded(&model, 1);
        let mut sim = CgraSim::new(ArchConfig::default());
        let mut kv = pool();
        let x = input(8, c.d_model, 2);
        kv.admit(1, c.d_model, c.n_layers, 8, 8).unwrap();
        run_prefill_batch(&mut sim, &model, &quant, &mut kv, &[(1, &x)]).unwrap();
        let row = mat_row(&x, 7);
        // At the context limit: one more step must be refused.
        assert!(run_decode_tick(&mut sim, &model, &quant, &mut kv, &[(1, &row)]).is_err());
        // A multi-row "step" is malformed.
        assert!(run_decode_tick(&mut sim, &model, &quant, &mut kv, &[(1, &x)]).is_err());
    }
}
