//! Autoregressive decoding: generation serving with a paged KV cache
//! and continuous batching.
//!
//! Everything the stack served before this subsystem was encoder-style
//! one-shot inference — one request, one stacked forward, done. Real
//! edge traffic (assistants, translation, speech) is *generation*:
//! per-step GEMVs against a growing K/V history, where cache residency
//! and iteration-level batching — not one big GEMM — dominate latency
//! and memory traffic (the levers the Full Stack Transformer-inference
//! survey and EdgeTran identify as binding on edge platforms). Three
//! layers implement it:
//!
//! - [`kv`] — the **paged KV cache**: fixed-size pages from a
//!   per-device budget derived from the class's L1 provisioning (half
//!   of L1; row-scaled classes hold proportionally more), per-sequence
//!   page tables, exact fill/read word accounting, and typed
//!   reject-with-reason admission — never silent corruption.
//! - [`engine`] — the quantized **prefill** (stacked causal forward
//!   over the prompt, K/V written to pages) and **decode tick** (one
//!   stacked `B × d` GEMV per site across every running sequence, each
//!   new row attending to its own cached K/V). Under the static causal
//!   calibration both are bit-identical, token for token, to a
//!   one-shot causal forward — the paged cache changes timing and
//!   traffic, never results.
//! - [`fleet`] — **continuous batching**: [`fleet::DeviceDecoder`]
//!   (per-device waiting/running/preempted lifecycle, LIFO preemption
//!   under KV pressure, prefill/decode interleaving policy — including
//!   **chunked prefill**, which runs long prompts as fixed budgets of
//!   rows alternated with decode ticks so one big arrival cannot
//!   stall the running batch's inter-token latency) and
//!   [`fleet::DecodeFleetSim`] (class-aware placement over N devices,
//!   deterministic event loop, per-phase metrics: TTFT, inter-token
//!   latency, KV occupancy, preemption/migration/reject counters).
//!   With migration enabled, an idle device pulls a waiting or
//!   *running* sequence from a loaded peer — the KV pages travel as a
//!   serialized image ([`kv::KvSeqImage`]) over the torus entry links,
//!   charged to both endpoints' timelines, and decode resumes on the
//!   destination with no recompute.
//!
//!   With **disaggregation** ([`fleet::DecodeFleetConfig::disagg`]),
//!   the fleet specializes by phase instead: the cheapest-prefill
//!   class runs prefill only and every freshly prefilled sequence
//!   hands its KV image off to a decode device over the same
//!   entry-link-charged transfer path. The **fleet-wide prefix cache**
//!   ([`fleet::DecodeFleetConfig::prefix_block_tokens`]) hashes prompt
//!   token-blocks radix-style, re-verifies candidate matches bitwise,
//!   and serves shared prefixes by copying already-filled KV pages —
//!   placement is prefix-affine, so repeats route to devices already
//!   holding the prefix.
//!
//! Every path — chunk schedules, migrations, preemption/resume, batch
//! composition, device class, disaggregated hand-off, prefix-cache
//! hits — is **bit-identical** to one-shot causal prefill;
//! `rust/tests/decode_props.rs`, `rust/tests/migration_props.rs` and
//! `rust/tests/disagg_props.rs` pin the contract. The CLI serves this
//! path as `cluster --workload decode` (`--chunk-tokens`, `--migrate`,
//! `--disagg`, `--prefix-block`); the FIG8 bench charts tokens/sec and
//! TTFT against concurrent sequences, asserts the chunked-prefill p99
//! ITL win and the prefix-cache TTFT win at high shared-prefix rates.
//!
//! The fleet carries [`crate::obs`] hooks (arm with
//! [`fleet::DecodeFleetSim::enable_obs`]): every admission, chunk,
//! tick, preemption, migration and completion lands in the event trace
//! and windowed series. Observation is strictly one-way — tracing on
//! vs off is bit-identical, pinned by `rust/tests/obs_props.rs`.

pub mod engine;
pub mod fleet;
pub mod kv;

pub use engine::{mat_row, run_decode_tick, run_prefill_batch};
pub use fleet::{
    analytic_decode_token_cycles, analytic_decode_token_ref_cycles, DecodeFleetConfig,
    DecodeFleetSim, DecodeMetrics, DecodeSchedule, DeviceDecoder, GenCompletion,
};
pub use kv::{AdmitError, KvConfig, KvMetrics, KvSeqImage, KvTokenImage, PagedKvCache};
