//! Counting global allocator for memory profiling (`alloc-profile`
//! feature).
//!
//! Wraps [`std::alloc::System`] and keeps three atomic counters: total
//! allocation calls, live bytes, and the high-water mark of live bytes.
//! The counters use `Relaxed` ordering — they are statistics, not
//! synchronization — so the overhead per allocation is two or three
//! uncontended atomic RMWs. That is cheap enough to leave on for a
//! whole benchmark run, but it is still *not* free: the `sim_speed`
//! bench therefore measures allocations in a separate un-timed pass so
//! the throughput numbers stay comparable to non-profiled builds.
//!
//! Usage (wired up in `lib.rs` when the feature is on):
//!
//! ```ignore
//! alloc_profile::reset();
//! run_workload();
//! let snap = alloc_profile::snapshot();
//! eprintln!("peak {} B over {} allocs", snap.peak_bytes, snap.allocs);
//! ```
//!
//! `peak_bytes` is the peak of *live* bytes since the last `reset()`,
//! counted from the live total at reset time (reset does not pretend
//! previously-allocated memory is free — it re-bases the peak at the
//! current live level, so a snapshot brackets exactly the workload
//! between the two calls).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Allocation calls since process start (monotonic; `reset()` re-bases
/// the *reported* count, not this counter).
static ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Currently-live heap bytes routed through this allocator.
static LIVE: AtomicU64 = AtomicU64::new(0);
/// High-water mark of `LIVE` since the last `reset()`.
static PEAK: AtomicU64 = AtomicU64::new(0);
/// `ALLOCS` value captured at the last `reset()`.
static ALLOCS_BASE: AtomicU64 = AtomicU64::new(0);

/// A point-in-time reading of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// Allocation calls since the last [`reset`].
    pub allocs: u64,
    /// Live heap bytes right now.
    pub live_bytes: u64,
    /// Peak live heap bytes since the last [`reset`].
    pub peak_bytes: u64,
}

/// Re-base the counters: the peak restarts at the current live level
/// and the allocation count restarts at zero.
pub fn reset() {
    ALLOCS_BASE.store(ALLOCS.load(Relaxed), Relaxed);
    PEAK.store(LIVE.load(Relaxed), Relaxed);
}

/// Read the counters (cheap; three relaxed loads).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: ALLOCS.load(Relaxed).saturating_sub(ALLOCS_BASE.load(Relaxed)),
        live_bytes: LIVE.load(Relaxed),
        peak_bytes: PEAK.load(Relaxed),
    }
}

#[inline]
fn on_alloc(size: u64) {
    ALLOCS.fetch_add(1, Relaxed);
    let live = LIVE.fetch_add(size, Relaxed) + size;
    PEAK.fetch_max(live, Relaxed);
}

#[inline]
fn on_dealloc(size: u64) {
    LIVE.fetch_sub(size, Relaxed);
}

/// The counting allocator. Install as `#[global_allocator]` (done in
/// `lib.rs` behind the `alloc-profile` feature).
pub struct CountingAlloc;

// SAFETY: every method delegates directly to `System`; the counters are
// pure bookkeeping and never affect the returned pointers or layouts.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size() as u64);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        on_dealloc(layout.size() as u64);
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_alloc(layout.size() as u64);
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            // Count a realloc as one call; live bytes move by the delta.
            ALLOCS.fetch_add(1, Relaxed);
            let old = layout.size() as u64;
            let new = new_size as u64;
            if new >= old {
                let live = LIVE.fetch_add(new - old, Relaxed) + (new - old);
                PEAK.fetch_max(live, Relaxed);
            } else {
                LIVE.fetch_sub(old - new, Relaxed);
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test, not several: the counters are process-global, so
    // parallel tests calling reset() would race each other's
    // assertions. Everything here tolerates background allocation from
    // other test threads.
    #[test]
    fn counters_track_a_vec_roundtrip() {
        reset();
        let before = snapshot();
        let v = vec![1u8; 1 << 16];
        assert_eq!(v.len(), 1 << 16);
        let during = snapshot();
        assert!(during.allocs > before.allocs, "alloc call not counted");
        // While the vec is alive, live bytes — and therefore the peak
        // observed at its allocation — include its 64 KiB, no matter
        // what other threads allocate or free around us.
        assert!(
            during.peak_bytes >= 1 << 16,
            "peak missed the vec: before={before:?} during={during:?}"
        );
        drop(v);
        let after = snapshot();
        assert!(after.peak_bytes >= during.peak_bytes, "peak must be sticky");
    }
}
