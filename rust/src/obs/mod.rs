//! Fleet observability: deterministic structured tracing, windowed
//! time-series metrics, and mergeable log-bucket latency histograms.
//!
//! Three layers, all purely observational:
//!
//! - [`trace`] — every fleet event (arrival, batch-form, prefill
//!   chunk, decode tick, preempt/resume, steal, KV admit/reject,
//!   migration export/import, completion) as `(ref_cycle, device,
//!   seq, kind)`, rendered to Chrome/Perfetto trace-event JSON with
//!   one track per device and flow arrows following a sequence across
//!   migrations.
//! - [`series`] — the same event stream folded into fixed ref-cycle
//!   windows: tokens/sec, queue depth, KV occupancy, busy fraction,
//!   steal/preempt/migration rates per window, rendered as CSV.
//! - [`hist`] — [`LogHistogram`], the O(buckets) mergeable replacement
//!   for the Vec-backed latency percentile stores.
//!
//! The non-negotiable invariant: observation never feeds back into
//! simulation. [`Observer`] is append-only and nothing in the
//! scheduling path reads it, so a run with tracing enabled produces
//! bit-identical tokens and metrics to the same seed with tracing
//! off, and the rendered trace bytes are a pure function of the seed
//! (`rust/tests/obs_props.rs` pins all three properties).

pub mod hist;
pub mod series;
pub mod trace;

pub use hist::LogHistogram;
pub use series::MetricsSeries;
pub use trace::{render_chrome_json, EventKind, ObsEvent, NO_SEQ};

use crate::sim::Stats;
use crate::trace::TraceLog;

/// Which observation layers to enable. Default: everything off — the
/// fleet simulators embed a disabled `Observer` with near-zero
/// overhead (one branch per hook).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsConfig {
    /// Record structured events and render Chrome/Perfetto JSON.
    pub trace: bool,
    /// Fold events into windows of this many ref cycles.
    pub window_cycles: Option<u64>,
    /// Record per-kernel stats rows (phase-tagged `TraceLog` CSV).
    pub kernels: bool,
}

impl ObsConfig {
    /// Everything on (trace + series at `window` cycles + kernel CSV).
    pub fn full(window: u64) -> Self {
        Self { trace: true, window_cycles: Some(window), kernels: true }
    }

    pub fn any_enabled(&self) -> bool {
        self.trace || self.window_cycles.is_some() || self.kernels
    }
}

/// Destination for fleet observation hooks. The simulators' serve
/// paths are generic over this so one body can feed either the real
/// [`Observer`] (single-threaded loops) or a per-shard replay buffer
/// (`cluster::threads::ShardObs`, threaded backend) — which is how the
/// threaded loops keep trace bytes identical: workers buffer, the
/// coordinator replays into the one true `Observer` in reference
/// order.
pub trait ObsSink {
    /// Is any layer recording? (Callers gate event construction.)
    fn enabled(&self) -> bool;
    /// Is the per-kernel CSV layer recording? (Callers gate label
    /// formatting.)
    fn kernels_on(&self) -> bool;
    /// Record one structured event.
    fn record(&mut self, cycle: u64, device: usize, seq: u64, kind: EventKind);
    /// Record a per-kernel stats row under a lifecycle phase.
    fn kernel(&mut self, label: String, phase: &'static str, stats: Stats);
}

impl ObsSink for Observer {
    #[inline]
    fn enabled(&self) -> bool {
        Observer::enabled(self)
    }

    #[inline]
    fn kernels_on(&self) -> bool {
        Observer::kernels_on(self)
    }

    #[inline]
    fn record(&mut self, cycle: u64, device: usize, seq: u64, kind: EventKind) {
        Observer::record(self, cycle, device, seq, kind);
    }

    #[inline]
    fn kernel(&mut self, label: String, phase: &'static str, stats: Stats) {
        Observer::kernel(self, label, phase, stats);
    }
}

/// Append-only sink for fleet events. Embedded (disabled) in
/// `FleetSim` / `DecodeFleetSim`; enable with their `enable_obs`
/// before `run()`.
#[derive(Debug, Clone, Default)]
pub struct Observer {
    events: Option<Vec<ObsEvent>>,
    series: Option<MetricsSeries>,
    kernels: Option<TraceLog>,
    device_names: Vec<String>,
}

impl Observer {
    /// Build an observer for `device_names.len()` devices.
    pub fn new(cfg: &ObsConfig, device_names: Vec<String>) -> Self {
        let n = device_names.len();
        Self {
            events: if cfg.trace { Some(Vec::new()) } else { None },
            series: cfg.window_cycles.map(|w| MetricsSeries::new(w, n)),
            kernels: if cfg.kernels { Some(TraceLog::new()) } else { None },
            device_names,
        }
    }

    /// Disabled observer (what the simulators embed by default).
    pub fn disabled() -> Self {
        Self::default()
    }

    /// Is any layer recording?
    #[inline]
    pub fn enabled(&self) -> bool {
        self.events.is_some() || self.series.is_some() || self.kernels.is_some()
    }

    /// Is the per-kernel CSV layer recording? (Callers gate label
    /// formatting on this.)
    #[inline]
    pub fn kernels_on(&self) -> bool {
        self.kernels.is_some()
    }

    /// Record one structured event.
    #[inline]
    pub fn record(&mut self, cycle: u64, device: usize, seq: u64, kind: EventKind) {
        if let Some(series) = self.series.as_mut() {
            series.feed(cycle, device, &kind);
        }
        if let Some(events) = self.events.as_mut() {
            events.push(ObsEvent { cycle, device, seq, kind });
        }
    }

    /// Record a per-kernel stats row under a lifecycle phase
    /// (`"encoder"`, `"prefill"`, `"chunk"`, `"decode"`).
    #[inline]
    pub fn kernel(&mut self, label: impl Into<String>, phase: &str, stats: Stats) {
        if let Some(log) = self.kernels.as_mut() {
            log.record_phase(label, phase, stats);
        }
    }

    /// Close the run: extend the series timeline to the makespan.
    pub fn finish(&mut self, makespan: u64) {
        if let Some(series) = self.series.as_mut() {
            series.finish(makespan);
        }
    }

    /// Number of structured events recorded so far.
    pub fn event_count(&self) -> usize {
        self.events.as_ref().map_or(0, Vec::len)
    }

    /// Recorded events (empty slice when tracing is off).
    pub fn events(&self) -> &[ObsEvent] {
        self.events.as_deref().unwrap_or(&[])
    }

    /// Render the Chrome/Perfetto trace JSON (None when tracing off).
    pub fn trace_json(&self) -> Option<String> {
        self.events.as_ref().map(|ev| render_chrome_json(ev, &self.device_names))
    }

    /// Render the windowed-metrics CSV (None when the series is off).
    pub fn series_csv(&self) -> Option<String> {
        self.series.as_ref().map(MetricsSeries::to_csv)
    }

    /// Render the phase-tagged per-kernel CSV (None when off).
    pub fn kernel_csv(&self) -> Option<String> {
        self.kernels.as_ref().map(TraceLog::to_csv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_observer_records_nothing() {
        let mut obs = Observer::disabled();
        assert!(!obs.enabled());
        obs.record(10, 0, 1, EventKind::Arrival { model: 0 });
        obs.kernel("k", "encoder", Stats::default());
        assert_eq!(obs.event_count(), 0);
        assert!(obs.trace_json().is_none());
        assert!(obs.series_csv().is_none());
        assert!(obs.kernel_csv().is_none());
    }

    #[test]
    fn full_observer_renders_all_layers() {
        let mut obs = Observer::new(&ObsConfig::full(100), vec!["d0".into()]);
        assert!(obs.enabled());
        assert!(obs.kernels_on());
        obs.record(10, 0, 1, EventKind::Arrival { model: 0 });
        obs.record(20, 0, 1, EventKind::DecodeTick { batch: 1, dur: 30 });
        obs.kernel("tick", "decode", Stats { cycles: 30, ..Default::default() });
        obs.finish(250);
        assert_eq!(obs.event_count(), 2);
        let json = obs.trace_json().unwrap();
        assert!(json.contains("decode_tick"));
        let csv = obs.series_csv().unwrap();
        assert_eq!(csv.lines().count(), 1 + 3); // header + windows 0..=2
        let kcsv = obs.kernel_csv().unwrap();
        assert!(kcsv.lines().nth(1).unwrap().starts_with("tick,decode,30,"));
    }
}
