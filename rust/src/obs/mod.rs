//! Fleet observability: deterministic structured tracing, windowed
//! time-series metrics, mergeable log-bucket latency histograms, and
//! per-request latency anatomy with fleet-level audit reports.
//!
//! Five layers, all purely observational:
//!
//! - [`trace`] — every fleet event (arrival, batch-form hold, prefill
//!   chunk, decode tick, preempt/resume, steal, KV admit/reject,
//!   migration export/import, completion) as `(ref_cycle, device,
//!   seq, kind)`, rendered to Chrome/Perfetto trace-event JSON with
//!   one track per device and flow arrows following a sequence across
//!   migrations.
//! - [`series`] — the same event stream folded into fixed ref-cycle
//!   windows: tokens/sec, queue depth, KV occupancy, busy and hold
//!   fractions, steal/preempt/migration rates per window, rendered as
//!   CSV.
//! - [`hist`] — [`LogHistogram`], the O(buckets) mergeable replacement
//!   for the Vec-backed latency percentile stores.
//! - [`anatomy`] — per-request causal span decomposition: each
//!   completed request's e2e latency split into queue-wait / hold /
//!   prefill / chunk-stall / decode / preempt-stall / migration
//!   components that sum bit-exactly to the recorded latency.
//! - [`audit`] — the fleet-level blame report built on [`anatomy`]:
//!   component shares, per-class and per-device component histograms,
//!   SLA-miss windows, worst offenders; deterministic JSON/CSV.
//!
//! The non-negotiable invariant: observation never feeds back into
//! simulation. [`Observer`] is append-only and nothing in the
//! scheduling path reads it, so a run with tracing enabled produces
//! bit-identical tokens and metrics to the same seed with tracing
//! off, and the rendered trace/audit bytes are a pure function of the
//! seed (`rust/tests/obs_props.rs` and `rust/tests/anatomy_props.rs`
//! pin these properties).

pub mod anatomy;
pub mod audit;
pub mod hist;
pub mod series;
pub mod trace;

pub use anatomy::{decompose, Components, RequestAnatomy, COMPONENT_NAMES, N_COMPONENTS};
pub use audit::{AuditConfig, AuditReport};
pub use hist::LogHistogram;
pub use series::MetricsSeries;
pub use trace::{render_chrome_json, EventKind, ObsEvent, NO_SEQ};

use crate::sim::Stats;
use crate::trace::TraceLog;
use std::io;

/// Which observation layers to enable. Default: everything off — the
/// fleet simulators embed a disabled `Observer` with near-zero
/// overhead (one branch per hook).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsConfig {
    /// Record structured events and render Chrome/Perfetto JSON.
    pub trace: bool,
    /// Fold events into windows of this many ref cycles.
    pub window_cycles: Option<u64>,
    /// Record per-kernel stats rows (phase-tagged `TraceLog` CSV).
    pub kernels: bool,
    /// Append per-request anatomy span tracks to the trace JSON
    /// (implies event retention even without `trace`).
    pub spans: bool,
    /// Retain events for the audit report (implies event retention).
    pub audit: bool,
}

impl ObsConfig {
    /// The classic three layers on (trace + series at `window` cycles
    /// + kernel CSV). Anatomy spans and audit stay off — arm them
    /// explicitly via the `spans` / `audit` fields.
    pub fn full(window: u64) -> Self {
        Self {
            trace: true,
            window_cycles: Some(window),
            kernels: true,
            spans: false,
            audit: false,
        }
    }

    pub fn any_enabled(&self) -> bool {
        self.trace || self.window_cycles.is_some() || self.kernels || self.spans || self.audit
    }
}

/// Destination for fleet observation hooks. The simulators' serve
/// paths are generic over this so one body can feed either the real
/// [`Observer`] (single-threaded loops) or a per-shard replay buffer
/// (`cluster::threads::ShardObs`, threaded backend) — which is how the
/// threaded loops keep trace bytes identical: workers buffer, the
/// coordinator replays into the one true `Observer` in reference
/// order.
pub trait ObsSink {
    /// Is any layer recording? (Callers gate event construction.)
    fn enabled(&self) -> bool;
    /// Is the per-kernel CSV layer recording? (Callers gate label
    /// formatting.)
    fn kernels_on(&self) -> bool;
    /// Record one structured event.
    fn record(&mut self, cycle: u64, device: usize, seq: u64, kind: EventKind);
    /// Record a per-kernel stats row under a lifecycle phase.
    fn kernel(&mut self, label: String, phase: &'static str, stats: Stats);
}

impl ObsSink for Observer {
    #[inline]
    fn enabled(&self) -> bool {
        Observer::enabled(self)
    }

    #[inline]
    fn kernels_on(&self) -> bool {
        Observer::kernels_on(self)
    }

    #[inline]
    fn record(&mut self, cycle: u64, device: usize, seq: u64, kind: EventKind) {
        Observer::record(self, cycle, device, seq, kind);
    }

    #[inline]
    fn kernel(&mut self, label: String, phase: &'static str, stats: Stats) {
        Observer::kernel(self, label, phase, stats);
    }
}

/// Append-only sink for fleet events. Embedded (disabled) in
/// `FleetSim` / `DecodeFleetSim`; enable with their `enable_obs`
/// before `run()`.
#[derive(Default)]
pub struct Observer {
    events: Option<Vec<ObsEvent>>,
    series: Option<MetricsSeries>,
    kernels: Option<TraceLog>,
    device_names: Vec<String>,
    trace_on: bool,
    spans_on: bool,
    audit_on: bool,
    /// Structured events recorded (retained or streamed).
    n_events: usize,
    /// Spill-to-writer trace sink: header written on arm, one chunk
    /// per event, spans + footer on [`Observer::finish`].
    stream: Option<Box<dyn io::Write + Send>>,
    /// True once [`Observer::stream_trace_to`] armed (outlives the
    /// writer handle, which `finish` consumes).
    streaming: bool,
    /// First streaming I/O error, surfaced via
    /// [`Observer::stream_error`].
    stream_err: Option<String>,
    /// Reusable per-event render buffer for the streaming path.
    scratch: String,
}

impl Clone for Observer {
    fn clone(&self) -> Self {
        Self {
            events: self.events.clone(),
            series: self.series.clone(),
            kernels: self.kernels.clone(),
            device_names: self.device_names.clone(),
            trace_on: self.trace_on,
            spans_on: self.spans_on,
            audit_on: self.audit_on,
            n_events: self.n_events,
            // Writer handles cannot be duplicated; a clone observes
            // the same retained state but does not stream.
            stream: None,
            streaming: self.streaming,
            stream_err: self.stream_err.clone(),
            scratch: String::new(),
        }
    }
}

impl std::fmt::Debug for Observer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Observer")
            .field("events", &self.events.as_ref().map(Vec::len))
            .field("series", &self.series.is_some())
            .field("kernels", &self.kernels.is_some())
            .field("trace_on", &self.trace_on)
            .field("spans_on", &self.spans_on)
            .field("audit_on", &self.audit_on)
            .field("n_events", &self.n_events)
            .field("streaming", &self.streaming)
            .field("stream_err", &self.stream_err)
            .finish_non_exhaustive()
    }
}

impl Observer {
    /// Build an observer for `device_names.len()` devices.
    pub fn new(cfg: &ObsConfig, device_names: Vec<String>) -> Self {
        let n = device_names.len();
        let retain = cfg.trace || cfg.spans || cfg.audit;
        Self {
            events: if retain { Some(Vec::new()) } else { None },
            series: cfg.window_cycles.map(|w| MetricsSeries::new(w, n)),
            kernels: if cfg.kernels { Some(TraceLog::new()) } else { None },
            device_names,
            trace_on: cfg.trace,
            spans_on: cfg.spans,
            audit_on: cfg.audit,
            n_events: 0,
            stream: None,
            streaming: false,
            stream_err: None,
            scratch: String::new(),
        }
    }

    /// Disabled observer (what the simulators embed by default).
    pub fn disabled() -> Self {
        Self::default()
    }

    /// Switch the trace layer to spill-to-writer mode: the JSON header
    /// is written immediately, each event streams out as it is
    /// recorded, and [`Observer::finish`] appends the anatomy spans
    /// (if armed) plus the footer and flushes. Output bytes are
    /// identical to the in-memory [`Observer::trace_json`] render by
    /// construction (both compose the same header / per-event / footer
    /// fragments). Events are no longer retained unless the spans or
    /// audit layers still need them.
    pub fn stream_trace_to(&mut self, mut writer: Box<dyn io::Write + Send>) {
        let header = trace::render_trace_header(&self.device_names);
        if let Err(e) = writer.write_all(header.as_bytes()) {
            self.stream_err = Some(e.to_string());
            self.streaming = true;
            return;
        }
        self.stream = Some(writer);
        self.streaming = true;
        self.trace_on = true;
        if !(self.spans_on || self.audit_on) {
            self.events = None;
        } else if self.events.is_none() {
            self.events = Some(Vec::new());
        }
    }

    /// True once streaming was armed (whether or not the writer is
    /// still live); `trace_json` returns None in this mode.
    pub fn is_streaming(&self) -> bool {
        self.streaming
    }

    /// First I/O error hit by the streaming writer, if any.
    pub fn stream_error(&self) -> Option<&str> {
        self.stream_err.as_deref()
    }

    /// Is any layer recording?
    #[inline]
    pub fn enabled(&self) -> bool {
        self.events.is_some()
            || self.series.is_some()
            || self.kernels.is_some()
            || self.stream.is_some()
    }

    /// Is the per-kernel CSV layer recording? (Callers gate label
    /// formatting on this.)
    #[inline]
    pub fn kernels_on(&self) -> bool {
        self.kernels.is_some()
    }

    /// Record one structured event.
    #[inline]
    pub fn record(&mut self, cycle: u64, device: usize, seq: u64, kind: EventKind) {
        if !self.enabled() {
            return;
        }
        if let Some(series) = self.series.as_mut() {
            series.feed(cycle, device, &kind);
        }
        if self.stream.is_some() {
            let ev = ObsEvent { cycle, device, seq, kind: kind.clone() };
            self.scratch.clear();
            trace::render_trace_event(&ev, &mut self.scratch);
            let res = {
                let w = self.stream.as_mut().expect("checked");
                w.write_all(self.scratch.as_bytes())
            };
            if let Err(e) = res {
                self.stream_err = Some(e.to_string());
                self.stream = None;
            }
            self.n_events += 1;
            if let Some(events) = self.events.as_mut() {
                events.push(ObsEvent { cycle, device, seq, kind });
            }
            return;
        }
        if let Some(events) = self.events.as_mut() {
            events.push(ObsEvent { cycle, device, seq, kind });
            self.n_events += 1;
        }
    }

    /// Record a per-kernel stats row under a lifecycle phase
    /// (`"encoder"`, `"prefill"`, `"chunk"`, `"decode"`).
    #[inline]
    pub fn kernel(&mut self, label: impl Into<String>, phase: &str, stats: Stats) {
        if let Some(log) = self.kernels.as_mut() {
            log.record_phase(label, phase, stats);
        }
    }

    /// Close the run: extend the series timeline to the makespan and,
    /// in streaming mode, append the span tracks + footer and flush.
    pub fn finish(&mut self, makespan: u64) {
        if let Some(series) = self.series.as_mut() {
            series.finish(makespan);
        }
        if let Some(mut w) = self.stream.take() {
            let mut tail = String::new();
            if self.spans_on {
                let anatomies = anatomy::decompose(self.events());
                trace::render_anatomy_spans(&anatomies, &mut tail);
            }
            tail.push_str(trace::TRACE_FOOTER);
            let res = w.write_all(tail.as_bytes()).and_then(|_| w.flush());
            if let Err(e) = res {
                if self.stream_err.is_none() {
                    self.stream_err = Some(e.to_string());
                }
            }
        }
    }

    /// Number of structured events recorded so far (retained or
    /// streamed).
    pub fn event_count(&self) -> usize {
        self.n_events
    }

    /// Recorded events (empty slice when no layer retains them).
    pub fn events(&self) -> &[ObsEvent] {
        self.events.as_deref().unwrap_or(&[])
    }

    /// Render the Chrome/Perfetto trace JSON: the device-track events
    /// (when `trace` is on) followed by the per-request anatomy span
    /// tracks (when `spans` is on). None when both layers are off or
    /// the trace was streamed out instead.
    pub fn trace_json(&self) -> Option<String> {
        if self.streaming || !(self.trace_on || self.spans_on) {
            return None;
        }
        let events = self.events.as_ref()?;
        let mut out = trace::render_trace_header(&self.device_names);
        out.reserve(events.len() * 96);
        if self.trace_on {
            for e in events {
                trace::render_trace_event(e, &mut out);
            }
        }
        if self.spans_on {
            let anatomies = anatomy::decompose(events);
            trace::render_anatomy_spans(&anatomies, &mut out);
        }
        out.push_str(trace::TRACE_FOOTER);
        Some(out)
    }

    /// Per-request causal decomposition of the retained event stream
    /// (None unless the spans or audit layer retained events).
    pub fn anatomy(&self) -> Option<Vec<RequestAnatomy>> {
        if !(self.spans_on || self.audit_on) {
            return None;
        }
        self.events.as_ref().map(|ev| anatomy::decompose(ev))
    }

    /// Build the fleet audit report (None unless the audit layer is
    /// armed).
    pub fn audit_report(&self, cfg: &AuditConfig) -> Option<AuditReport> {
        if !self.audit_on {
            return None;
        }
        let events = self.events.as_ref()?;
        let anatomies = anatomy::decompose(events);
        Some(AuditReport::build(&anatomies, &self.device_names, cfg))
    }

    /// Render the audit report as deterministic JSON (None unless the
    /// audit layer is armed).
    pub fn audit_json(&self, cfg: &AuditConfig) -> Option<String> {
        self.audit_report(cfg).map(|r| r.to_json())
    }

    /// Render the audit report's per-window blame table as CSV (None
    /// unless the audit layer is armed).
    pub fn audit_csv(&self, cfg: &AuditConfig) -> Option<String> {
        self.audit_report(cfg).map(|r| r.to_csv())
    }

    /// Render the windowed-metrics CSV (None when the series is off).
    pub fn series_csv(&self) -> Option<String> {
        self.series.as_ref().map(MetricsSeries::to_csv)
    }

    /// Render the phase-tagged per-kernel CSV (None when off).
    pub fn kernel_csv(&self) -> Option<String> {
        self.kernels.as_ref().map(TraceLog::to_csv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn disabled_observer_records_nothing() {
        let mut obs = Observer::disabled();
        assert!(!obs.enabled());
        obs.record(10, 0, 1, EventKind::Arrival { model: 0 });
        obs.kernel("k", "encoder", Stats::default());
        assert_eq!(obs.event_count(), 0);
        assert!(obs.trace_json().is_none());
        assert!(obs.series_csv().is_none());
        assert!(obs.kernel_csv().is_none());
        assert!(obs.anatomy().is_none());
    }

    #[test]
    fn full_observer_renders_all_layers() {
        let mut obs = Observer::new(&ObsConfig::full(100), vec!["d0".into()]);
        assert!(obs.enabled());
        assert!(obs.kernels_on());
        obs.record(10, 0, 1, EventKind::Arrival { model: 0 });
        obs.record(20, 0, 1, EventKind::DecodeTick { batch: 1, dur: 30 });
        obs.kernel("tick", "decode", Stats { cycles: 30, ..Default::default() });
        obs.finish(250);
        assert_eq!(obs.event_count(), 2);
        let json = obs.trace_json().unwrap();
        assert!(json.contains("decode_tick"));
        let csv = obs.series_csv().unwrap();
        assert_eq!(csv.lines().count(), 1 + 3); // header + windows 0..=2
        let kcsv = obs.kernel_csv().unwrap();
        assert!(kcsv.lines().nth(1).unwrap().starts_with("tick,decode,30,"));
    }

    #[test]
    fn full_config_leaves_spans_and_audit_off() {
        let cfg = ObsConfig::full(64);
        assert!(!cfg.spans && !cfg.audit);
        let obs = Observer::new(&cfg, vec!["d0".into()]);
        assert!(obs.anatomy().is_none());
        assert!(obs.audit_json(&AuditConfig::new(64, vec![None])).is_none());
    }

    /// Shared Vec writer so the test can inspect streamed bytes after
    /// the boxed handle is consumed.
    #[derive(Clone)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl std::io::Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn streamed_trace_is_byte_identical_to_in_memory_render() {
        let events = vec![
            (0u64, 0usize, 1u64, EventKind::Arrival { model: 0 }),
            (4, 0, NO_SEQ, EventKind::Serve { model: 0, batch: 1, dur: 6 }),
            (10, 0, 1, EventKind::Complete { latency: 10 }),
            (12, 0, NO_SEQ, EventKind::QueueDepth { depth: 0 }),
        ];
        let cfg = ObsConfig { trace: true, ..Default::default() };

        let mut mem = Observer::new(&cfg, vec!["d0".into()]);
        for (c, d, s, k) in &events {
            mem.record(*c, *d, *s, k.clone());
        }
        mem.finish(12);
        let expect = mem.trace_json().unwrap();

        let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
        let mut streamed = Observer::new(&cfg, vec!["d0".into()]);
        streamed.stream_trace_to(Box::new(buf.clone()));
        // Trace-only streaming drops retention entirely.
        assert!(streamed.events().is_empty());
        for (c, d, s, k) in &events {
            streamed.record(*c, *d, *s, k.clone());
        }
        streamed.finish(12);
        assert!(streamed.stream_error().is_none());
        assert!(streamed.trace_json().is_none(), "streamed trace must not re-render");
        assert_eq!(streamed.event_count(), events.len());
        let got = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        assert_eq!(got, expect);
    }

    #[test]
    fn streamed_trace_with_spans_matches_in_memory_span_render() {
        let events = vec![
            (0u64, 0usize, 1u64, EventKind::Arrival { model: 0 }),
            (4, 0, NO_SEQ, EventKind::Serve { model: 0, batch: 1, dur: 6 }),
            (10, 0, 1, EventKind::Complete { latency: 10 }),
        ];
        let cfg = ObsConfig { trace: true, spans: true, ..Default::default() };

        let mut mem = Observer::new(&cfg, vec!["d0".into()]);
        for (c, d, s, k) in &events {
            mem.record(*c, *d, *s, k.clone());
        }
        mem.finish(10);
        let expect = mem.trace_json().unwrap();
        assert!(expect.contains("\"cat\":\"anatomy\""));

        let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
        let mut streamed = Observer::new(&cfg, vec!["d0".into()]);
        streamed.stream_trace_to(Box::new(buf.clone()));
        for (c, d, s, k) in &events {
            streamed.record(*c, *d, *s, k.clone());
        }
        streamed.finish(10);
        assert!(streamed.stream_error().is_none());
        let got = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        assert_eq!(got, expect);
    }
}
