//! Mergeable log-bucket latency histograms.
//!
//! `LogHistogram` replaces the full `Vec<u64>` sample stores that
//! `FleetMetrics` / `DecodeMetrics` used to carry: memory is O(buckets)
//! instead of O(samples), merging two histograms is exact (bucket counts
//! add element-wise), and percentile queries carry a bounded relative
//! error of [`LogHistogram::MAX_RELATIVE_ERROR`].
//!
//! Bucketing is HdrHistogram-style base-2 with [`SUB_BITS`] sub-bucket
//! bits per octave: values below `2^SUB_BITS` land in exact unit-width
//! buckets, larger values share `2^SUB_BITS` buckets per power of two,
//! so a bucket spanning `[lo, lo + w)` always has `w <= lo / 2^SUB_BITS`
//! and the midpoint representative is within `lo / 2^(SUB_BITS+1)` of
//! every member. Count, sum (hence mean), min, and max are tracked
//! exactly, so single-sample histograms and p0/p100 stay exact and the
//! derived `PartialEq` still witnesses run determinism: identical
//! sample multisets always produce identical histograms.
//!
//! With `--features exact-hist` (or under `cfg(test)`) each histogram
//! additionally shadows the exact sorted sample vector, exposed via
//! [`LogHistogram::exact_percentile`] for conformance comparisons. The
//! shadow is never consulted by `percentile()`, so enabling the feature
//! cannot change any reported metric.

/// Sub-bucket resolution bits: `2^SUB_BITS` buckets per octave.
const SUB_BITS: u32 = 8;
/// Number of exact unit buckets (values `< SUB` index directly).
const SUB: u64 = 1 << SUB_BITS;

/// Log-bucket histogram over `u64` samples with exact merge and
/// bounded-relative-error percentiles. Drop-in for the old Vec-backed
/// `LatencyHistogram` API (`record` / `count` / `mean` / `max` /
/// `percentile` / `p50` / `p95` / `p99`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LogHistogram {
    /// Bucket counts, grown lazily; the last element is always nonzero
    /// (so equal sample sets give equal vectors regardless of record
    /// vs merge history).
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
    /// Exact sorted shadow for conformance tests only; never read by
    /// `percentile()` so feature builds stay bit-identical.
    #[cfg(any(test, feature = "exact-hist"))]
    exact: Vec<u64>,
}

impl LogHistogram {
    /// Worst-case relative error of `percentile()` vs the exact
    /// nearest-rank answer: half a bucket width over the bucket floor,
    /// `1 / 2^(SUB_BITS+1)`.
    pub const MAX_RELATIVE_ERROR: f64 = 1.0 / (1u64 << (SUB_BITS + 1)) as f64;

    pub fn new() -> Self {
        Self::default()
    }

    fn index(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let m = 63 - v.leading_zeros(); // top bit position, >= SUB_BITS
        let mantissa = (v >> (m - SUB_BITS)) as usize; // in [SUB, 2*SUB)
        (((m - SUB_BITS + 1) as usize) << SUB_BITS) + (mantissa - SUB as usize)
    }

    /// Midpoint of the bucket's value range (exact for unit buckets).
    fn representative(i: usize) -> u64 {
        if i < SUB as usize {
            return i as u64;
        }
        let octave = (i >> SUB_BITS) as u32 + SUB_BITS - 1; // top bit position
        let offset = (i as u64) & (SUB - 1);
        let width = 1u64 << (octave - SUB_BITS);
        ((SUB + offset) << (octave - SUB_BITS)) + width / 2
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        let i = Self::index(v);
        if i >= self.counts.len() {
            self.counts.resize(i + 1, 0);
        }
        self.counts[i] += 1;
        if self.total == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.total += 1;
        self.sum += u128::from(v);
        #[cfg(any(test, feature = "exact-hist"))]
        {
            let at = self.exact.partition_point(|&x| x <= v);
            self.exact.insert(at, v);
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.total as usize
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Exact arithmetic mean (sum is tracked exactly in u128).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Exact smallest sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact largest sample (0 when empty).
    pub fn max(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.max
        }
    }

    /// Nearest-rank percentile (same semantics as the exact
    /// `LatencyHistogram`), answered from bucket counts: the result is
    /// the representative of the bucket holding the rank-th sample,
    /// clamped to the exact observed `[min, max]`, so the relative
    /// error vs the exact answer is at most [`Self::MAX_RELATIVE_ERROR`].
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil() as u64;
        let rank = rank.clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// Exact merge: bucket counts add element-wise, scalars combine
    /// losslessly. Associative and commutative — merging per-device
    /// histograms in any order gives the identical fleet histogram.
    pub fn merge(&mut self, other: &Self) {
        if other.total == 0 {
            return;
        }
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (a, &b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        if self.total == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.total += other.total;
        self.sum += other.sum;
        #[cfg(any(test, feature = "exact-hist"))]
        {
            for &v in &other.exact {
                let at = self.exact.partition_point(|&x| x <= v);
                self.exact.insert(at, v);
            }
        }
    }

    /// Exact nearest-rank percentile from the shadow sample vector.
    /// Test/conformance only; `percentile()` never consults this, so
    /// the feature cannot perturb reported metrics.
    #[cfg(any(test, feature = "exact-hist"))]
    pub fn exact_percentile(&self, p: f64) -> u64 {
        if self.exact.is_empty() {
            return 0;
        }
        let rank = ((p / 100.0) * self.exact.len() as f64).ceil() as usize;
        let rank = rank.clamp(1, self.exact.len());
        self.exact[rank - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 5, 100, 255] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 255);
        assert_eq!(h.p50(), 5);
        assert_eq!(h.percentile(100.0), 255);
        assert_eq!(h.mean(), 361.0 / 5.0);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.is_empty());
        assert_eq!(h.p50(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn large_values_stay_within_relative_error() {
        let mut h = LogHistogram::new();
        // Deterministic LCG spanning several octaves.
        let mut x = 0x2545_f491_4f6c_dd1du64;
        for _ in 0..2000 {
            x = x.wrapping_mul(2_862_933_555_777_941_757).wrapping_add(3_037_000_493);
            h.record(x >> 34); // values up to 2^30
        }
        for q in [1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            let approx = h.percentile(q);
            let exact = h.exact_percentile(q);
            let bound = exact as f64 * LogHistogram::MAX_RELATIVE_ERROR;
            assert!(
                (approx.abs_diff(exact)) as f64 <= bound,
                "p{q}: approx {approx} vs exact {exact} (bound {bound})"
            );
        }
    }

    #[test]
    fn single_sample_percentiles_are_exact() {
        let mut h = LogHistogram::new();
        h.record(123_456_789);
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.percentile(q), 123_456_789);
        }
    }

    #[test]
    fn merge_is_exact_and_associative() {
        let mk = |vals: &[u64]| {
            let mut h = LogHistogram::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let a = mk(&[1, 500, 70_000]);
        let b = mk(&[2, 2, 9_999_999]);
        let c = mk(&[300]);
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        let direct = mk(&[1, 500, 70_000, 2, 2, 9_999_999, 300]);
        assert_eq!(ab_c, a_bc);
        assert_eq!(ab_c.counts, direct.counts);
        assert_eq!(ab_c.total, direct.total);
        assert_eq!(ab_c.sum, direct.sum);
        assert_eq!(ab_c.min, direct.min);
        assert_eq!(ab_c.max, direct.max);
    }

    #[test]
    fn index_and_representative_are_consistent() {
        for &v in &[0u64, 1, 255, 256, 257, 511, 512, 1 << 20, (1 << 40) + 12345, u64::MAX >> 1] {
            let i = LogHistogram::index(v);
            let r = LogHistogram::representative(i);
            // The representative must land in the same bucket.
            assert_eq!(LogHistogram::index(r), i, "v={v} i={i} r={r}");
            if v >= SUB {
                let err = r.abs_diff(v) as f64 / v as f64;
                assert!(err <= LogHistogram::MAX_RELATIVE_ERROR, "v={v} r={r} err={err}");
            } else {
                assert_eq!(r, v);
            }
        }
    }
}
