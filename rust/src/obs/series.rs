//! Windowed time-series metrics: fold the event stream into fixed
//! ref-cycle windows so a run becomes plottable curves instead of one
//! end-of-run aggregate.
//!
//! Each window row counts arrivals / completions / tokens / steals /
//! preemptions / migrations / drops / rejects, accumulates device busy
//! cycles (work spans are split exactly across window boundaries), and
//! samples the fleet-wide queue depth and mean KV occupancy from the
//! latest per-device gauge values. Rendering carries gauges forward
//! through empty windows, so the CSV always has one row per window
//! from cycle 0 to the makespan.

use super::trace::EventKind;
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Default, Clone, PartialEq)]
struct WindowRow {
    arrivals: u64,
    completions: u64,
    tokens: u64,
    steals: u64,
    preemptions: u64,
    migrations: u64,
    drops: u64,
    rejects: u64,
    /// Batch-formation hold cycles (devices parked on a partial batch),
    /// split exactly across window boundaries like busy cycles.
    hold_cycles: u64,
    busy_cycles: u64,
    /// Prefix-cache hits (prompts served partly from cached KV pages).
    prefix_hits: u64,
    /// Disaggregated prefill→decode hand-offs (counted at the source).
    handoffs: u64,
    /// Fleet-wide queued requests at the last sample in this window.
    queue_depth: Option<u64>,
    /// Mean per-device KV occupancy permille at the last sample.
    kv_permille: Option<u64>,
}

/// Fixed-cadence windowed metrics accumulator. Fed from
/// [`super::Observer::record`]; purely observational.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSeries {
    window_cycles: u64,
    n_devices: usize,
    rows: BTreeMap<u64, WindowRow>,
    /// Latest queue-depth gauge per device.
    cur_queue: Vec<u64>,
    /// Latest KV-occupancy gauge per device.
    cur_kv: Vec<u64>,
    makespan: u64,
}

impl MetricsSeries {
    pub fn new(window_cycles: u64, n_devices: usize) -> Self {
        Self {
            window_cycles: window_cycles.max(1),
            n_devices: n_devices.max(1),
            rows: BTreeMap::new(),
            cur_queue: vec![0; n_devices.max(1)],
            cur_kv: vec![0; n_devices.max(1)],
            makespan: 0,
        }
    }

    /// Window size in ref cycles.
    pub fn window_cycles(&self) -> u64 {
        self.window_cycles
    }

    fn row(&mut self, cycle: u64) -> &mut WindowRow {
        let w = cycle / self.window_cycles;
        self.rows.entry(w).or_default()
    }

    /// Split a work span `[start, start + dur)` across window
    /// boundaries, crediting each window its exact busy-cycle share.
    fn add_busy(&mut self, start: u64, dur: u64) {
        let end = start.saturating_add(dur);
        let mut t = start;
        while t < end {
            let w = t / self.window_cycles;
            let window_end = (w + 1).saturating_mul(self.window_cycles);
            let take = end.min(window_end) - t;
            self.rows.entry(w).or_default().busy_cycles += take;
            t += take;
        }
    }

    /// Split a hold span `[start, start + dur)` across window
    /// boundaries, mirroring [`Self::add_busy`].
    fn add_hold(&mut self, start: u64, dur: u64) {
        let end = start.saturating_add(dur);
        let mut t = start;
        while t < end {
            let w = t / self.window_cycles;
            let window_end = (w + 1).saturating_mul(self.window_cycles);
            let take = end.min(window_end) - t;
            self.rows.entry(w).or_default().hold_cycles += take;
            t += take;
        }
    }

    /// Fold one event into its window.
    pub fn feed(&mut self, cycle: u64, device: usize, kind: &EventKind) {
        self.makespan = self.makespan.max(cycle);
        match kind {
            EventKind::Arrival { .. } => self.row(cycle).arrivals += 1,
            EventKind::Reject { .. } => self.row(cycle).rejects += 1,
            EventKind::Drop => self.row(cycle).drops += 1,
            EventKind::Steal { .. } => self.row(cycle).steals += 1,
            EventKind::Preempt => self.row(cycle).preemptions += 1,
            EventKind::Complete { .. } => self.row(cycle).completions += 1,
            EventKind::Serve { dur, .. } => self.add_busy(cycle, *dur),
            EventKind::Prefill { tokens, dur, .. } => {
                self.row(cycle).tokens += *tokens as u64;
                self.add_busy(cycle, *dur);
            }
            EventKind::DecodeTick { batch, dur } => {
                self.row(cycle).tokens += *batch as u64;
                self.add_busy(cycle, *dur);
            }
            EventKind::MigrateOut { dur, .. } => {
                self.row(cycle).migrations += 1;
                self.add_busy(cycle, *dur);
            }
            EventKind::MigrateIn { dur, .. } => self.add_busy(cycle, *dur),
            EventKind::QueueDepth { depth } => {
                debug_assert!(
                    device < self.cur_queue.len(),
                    "queue-depth gauge for device {device} of {} — dropped",
                    self.cur_queue.len()
                );
                if device < self.cur_queue.len() {
                    self.cur_queue[device] = *depth as u64;
                }
                let total: u64 = self.cur_queue.iter().sum();
                self.row(cycle).queue_depth = Some(total);
            }
            EventKind::KvOccupancy { permille } => {
                debug_assert!(
                    device < self.cur_kv.len(),
                    "KV-occupancy gauge for device {device} of {} — dropped",
                    self.cur_kv.len()
                );
                if device < self.cur_kv.len() {
                    self.cur_kv[device] = *permille;
                }
                // Round half-up: truncation biased the fleet mean low
                // by up to one permille per device.
                let n = self.cur_kv.len() as u64;
                let mean = (self.cur_kv.iter().sum::<u64>() + n / 2) / n;
                self.row(cycle).kv_permille = Some(mean);
            }
            EventKind::Hold { dur } => self.add_hold(cycle, *dur),
            EventKind::HandoffOut { dur, .. } => {
                self.row(cycle).handoffs += 1;
                self.add_busy(cycle, *dur);
            }
            EventKind::HandoffIn { dur, .. } => self.add_busy(cycle, *dur),
            EventKind::PrefixHit { .. } => self.row(cycle).prefix_hits += 1,
            EventKind::Resume | EventKind::KvAdmit { .. } | EventKind::ChunkWait => {}
        }
    }

    /// Extend the timeline to the run makespan so trailing idle
    /// windows render.
    pub fn finish(&mut self, makespan: u64) {
        self.makespan = self.makespan.max(makespan);
    }

    /// Render one CSV row per window, gauges carried forward through
    /// windows with no samples.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "window,start_cycle,arrivals,completions,tokens,steals,preemptions,\
             migrations,drops,rejects,hold_permille,busy_permille,queue_depth,\
             kv_occupancy_permille,prefix_hits,handoffs\n",
        );
        let last = self.makespan / self.window_cycles;
        let span = self.window_cycles * self.n_devices as u64;
        let empty = WindowRow::default();
        let mut queue = 0u64;
        let mut kv = 0u64;
        for w in 0..=last {
            let row = self.rows.get(&w).unwrap_or(&empty);
            queue = row.queue_depth.unwrap_or(queue);
            kv = row.kv_permille.unwrap_or(kv);
            let hold_permille = row.hold_cycles.saturating_mul(1000) / span;
            let busy_permille = row.busy_cycles.saturating_mul(1000) / span;
            let _ = writeln!(
                out,
                "{w},{},{},{},{},{},{},{},{},{},{hold_permille},{busy_permille},{queue},{kv},{},{}",
                w * self.window_cycles,
                row.arrivals,
                row.completions,
                row.tokens,
                row.steals,
                row.preemptions,
                row.migrations,
                row.drops,
                row.rejects,
                row.prefix_hits,
                row.handoffs,
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_split_exactly_across_windows() {
        let mut s = MetricsSeries::new(100, 2);
        // 250-cycle span starting at 50: 50 in w0, 100 in w1, 100 in w2.
        s.feed(50, 0, &EventKind::Serve { model: 0, batch: 1, dur: 250 });
        s.finish(300);
        let csv = s.to_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 4); // windows 0..=3
        // busy_permille over window*devices = 100*2 = 200 cycles.
        assert!(rows[0].ends_with(",250,0,0,0,0"), "w0: {}", rows[0]);
        assert!(rows[1].ends_with(",500,0,0,0,0"), "w1: {}", rows[1]);
        assert!(rows[2].ends_with(",500,0,0,0,0"), "w2: {}", rows[2]);
        assert!(rows[3].ends_with(",0,0,0,0,0"), "w3: {}", rows[3]);
    }

    #[test]
    fn gauges_carry_forward_through_empty_windows() {
        let mut s = MetricsSeries::new(10, 1);
        s.feed(5, 0, &EventKind::QueueDepth { depth: 3 });
        s.feed(5, 0, &EventKind::KvOccupancy { permille: 700 });
        s.finish(35);
        let csv = s.to_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.ends_with(",3,700,0,0"), "row: {r}");
        }
    }

    #[test]
    fn kv_mean_rounds_half_up_instead_of_truncating() {
        let mut s = MetricsSeries::new(10, 2);
        s.feed(5, 0, &EventKind::KvOccupancy { permille: 700 });
        s.feed(6, 1, &EventKind::KvOccupancy { permille: 301 });
        s.finish(9);
        let csv = s.to_csv();
        let row = csv.lines().nth(1).expect("one window");
        // (700 + 301) / 2 = 500.5 → 501; integer truncation said 500.
        assert!(row.ends_with(",501,0,0"), "row: {row}");
    }

    #[test]
    fn hold_spans_split_and_render_their_own_column() {
        let mut s = MetricsSeries::new(100, 1);
        // 150-cycle hold starting at 50: 50 in w0, 100 in w1. Retroactive
        // emission (event timestamp = hold start) is exactly how the
        // encoder records it at serve time.
        s.feed(50, 0, &EventKind::Hold { dur: 150 });
        s.finish(200);
        let csv = s.to_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 3);
        // hold_permille over 100 window cycles × 1 device.
        assert!(rows[0].ends_with(",500,0,0,0,0,0"), "w0: {}", rows[0]);
        assert!(rows[1].ends_with(",1000,0,0,0,0,0"), "w1: {}", rows[1]);
        assert!(rows[2].ends_with(",0,0,0,0,0,0"), "w2: {}", rows[2]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "gauge for device")]
    fn out_of_range_gauge_device_panics_in_debug() {
        let mut s = MetricsSeries::new(10, 2);
        s.feed(5, 2, &EventKind::QueueDepth { depth: 1 });
    }

    #[test]
    fn prefix_hits_and_handoffs_get_their_own_columns() {
        let mut s = MetricsSeries::new(100, 2);
        s.feed(10, 0, &EventKind::PrefixHit { tokens: 8 });
        s.feed(20, 0, &EventKind::HandoffOut { dst: 1, words: 64, dur: 30 });
        s.feed(50, 1, &EventKind::HandoffIn { src: 0, words: 64, dur: 10 });
        s.finish(150);
        let csv = s.to_csv();
        assert!(csv.starts_with("window,"));
        assert!(csv.lines().next().expect("header").ends_with(",prefix_hits,handoffs"));
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 2);
        // Both hand-off spans are busy time: (30 + 10) * 1000 / 200.
        assert!(rows[0].ends_with(",200,0,0,1,1"), "w0: {}", rows[0]);
        assert!(rows[1].ends_with(",0,0,0,0,0"), "w1: {}", rows[1]);
    }

    #[test]
    fn counters_land_in_their_window() {
        let mut s = MetricsSeries::new(100, 1);
        s.feed(10, 0, &EventKind::Arrival { model: 0 });
        s.feed(110, 0, &EventKind::DecodeTick { batch: 4, dur: 5 });
        s.feed(120, 0, &EventKind::Complete { latency: 110 });
        s.feed(250, 0, &EventKind::Preempt);
        s.finish(250);
        let csv = s.to_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 3);
        assert!(rows[0].starts_with("0,0,1,0,0,"), "w0: {}", rows[0]);
        assert!(rows[1].starts_with("1,100,0,1,4,"), "w1: {}", rows[1]);
        assert!(rows[2].starts_with("2,200,0,0,0,0,1,"), "w2: {}", rows[2]);
    }
}
