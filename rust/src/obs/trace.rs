//! Structured fleet events and the Chrome/Perfetto trace renderer.
//!
//! Every fleet-visible scheduling decision is recorded as one
//! [`ObsEvent`] — `(ref_cycle, device, seq, kind)` — in deterministic
//! simulation order. The renderer turns the event stream into Chrome
//! trace-event JSON (the format `chrome://tracing` and
//! <https://ui.perfetto.dev> both open): one track (`tid`) per device,
//! duration events for work spans, instants for scheduling decisions,
//! counters for queue depth / KV occupancy, and flow arrows (`ph:"s"` /
//! `ph:"f"`) that follow a sequence from its source device to its
//! destination across a live KV migration.
//!
//! The JSON is built by hand (integer-only, fixed field order, no
//! serde, no maps) so a fixed seed renders to byte-identical output —
//! the property `obs_props.rs` and the CI smoke run pin. The render is
//! split into a header / per-event / footer triple shared by the
//! in-memory [`render_chrome_json`] and the [`Observer`]'s streaming
//! spill-to-writer mode, so the two outputs are byte-identical by
//! construction.
//!
//! [`Observer`]: super::Observer
//!
//! With `--spans`, [`render_anatomy_spans`] appends one nested async
//! track per completed request (Chrome `ph:"b"`/`ph:"e"`, grouped by
//! request id under `cat:"anatomy"`): the request's e2e latency as the
//! parent span, its causal components ([`super::anatomy`]) as child
//! spans, and a flow arrow linking the request row to the device track
//! that completed it.

use super::anatomy::{RequestAnatomy, COMPONENT_NAMES};

/// Sentinel sequence id for device-scoped events (queue depth, steal,
/// batch-level spans) that do not belong to one sequence.
pub const NO_SEQ: u64 = u64::MAX;

/// What happened. Payload fields are the numbers a profile reader
/// actually wants next to the event; everything is in ref cycles.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Request entered a device queue (encoder dispatch or decode
    /// placement).
    Arrival { model: usize },
    /// Decode placement refused the sequence (deterministic reason
    /// string from the KV admission check).
    Reject { reason: String },
    /// Encoder batch served: `dur` is the charged span on the device
    /// timeline (context reuse already applied).
    Serve { model: usize, batch: usize, dur: u64 },
    /// One request finished; `latency` is arrival-to-completion in ref
    /// cycles.
    Complete { latency: u64 },
    /// Request dropped by a bounded queue on overflow.
    Drop,
    /// Thief device `device` pulled `requests` queued requests from
    /// `victim`.
    Steal { victim: usize, requests: usize },
    /// Prefill work span: a whole-prompt job (`chunk: false`) or one
    /// Sarathi chunk (`chunk: true`); `rows` is the row count fed to
    /// the kernel, `tokens` the tokens emitted by this job.
    Prefill { model: usize, batch: usize, rows: usize, chunk: bool, tokens: usize, dur: u64 },
    /// One continuous-batching decode tick over `batch` running
    /// sequences (one token each).
    DecodeTick { batch: usize, dur: u64 },
    /// Sequence preempted (KV pages shed) to make room.
    Preempt,
    /// Previously preempted sequence re-admitted.
    Resume,
    /// KV admission succeeded with a budget of `tokens` tokens.
    KvAdmit { tokens: usize },
    /// Migration source span: serializing + exporting `words` KV words
    /// towards `dst`. Opens a flow arrow keyed by the sequence id.
    MigrateOut { dst: usize, words: u64, dur: u64 },
    /// Migration destination span: importing `words` KV words from
    /// `src`. Closes the flow arrow.
    MigrateIn { src: usize, words: u64, dur: u64 },
    /// Queue-depth counter sample for the device.
    QueueDepth { depth: usize },
    /// KV occupancy counter sample (permille of capacity).
    KvOccupancy { permille: u64 },
    /// Batch-formation hold span: the device parked on a partial batch
    /// waiting for it to fill (PR 2's hold-for-fill). Emitted
    /// retroactively when the held batch finally serves — `cycle` is
    /// the hold *start* and `dur` its length, ending exactly at the
    /// serve's start cycle.
    Hold { dur: u64 },
    /// Chunked prefill blocked: the mid-prompt chunk could not commit
    /// its next KV rows on this visit (pages must free first). One
    /// instant per blocked attempt, carrying the stalled sequence id.
    ChunkWait,
    /// Disaggregated hand-off source span: a freshly prefilled
    /// sequence's KV image (`words` words) serializing towards decode
    /// device `dst`. Opens a flow arrow keyed by the sequence id.
    HandoffOut { dst: usize, words: u64, dur: u64 },
    /// Disaggregated hand-off destination span: importing `words` KV
    /// words from prefill device `src`. Closes the flow arrow.
    HandoffIn { src: usize, words: u64, dur: u64 },
    /// Prefix-cache hit: `tokens` leading prompt tokens were served by
    /// copying cached KV pages instead of re-running prefill.
    PrefixHit { tokens: usize },
}

/// One structured fleet event on the reference-clock timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsEvent {
    /// Ref-cycle timestamp (span start for duration events).
    pub cycle: u64,
    /// Device index (track).
    pub device: usize,
    /// Sequence / request id, or [`NO_SEQ`].
    pub seq: u64,
    pub kind: EventKind,
}

fn escape_json(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

fn push_common(out: &mut String, name: &str, cat: &str, ph: char, cycle: u64, device: usize) {
    out.push_str("{\"name\":\"");
    out.push_str(name);
    out.push_str("\",\"cat\":\"");
    out.push_str(cat);
    out.push_str("\",\"ph\":\"");
    out.push(ph);
    out.push_str("\",\"ts\":");
    out.push_str(&cycle.to_string());
    out.push_str(",\"pid\":0,\"tid\":");
    out.push_str(&device.to_string());
}

/// Opening bytes of the trace JSON: the display header, the process
/// meta record, and one thread-name meta per device track. Shared by
/// [`render_chrome_json`] and the streaming writer.
pub(crate) fn render_trace_header(device_names: &[String]) -> String {
    let mut out = String::with_capacity(256 + device_names.len() * 64);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    out.push_str(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\
         \"args\":{\"name\":\"cgra-edge fleet\"}}",
    );
    for (d, name) in device_names.iter().enumerate() {
        out.push_str(",\n{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":");
        out.push_str(&d.to_string());
        out.push_str(",\"args\":{\"name\":\"");
        escape_json(name, &mut out);
        out.push_str("\"}}");
    }
    out
}

/// Closing bytes of the trace JSON.
pub(crate) const TRACE_FOOTER: &str = "\n]}\n";

/// Render one event — including its leading `,\n` record separator —
/// onto `out`. Shared by [`render_chrome_json`] and the streaming
/// writer so the two paths cannot drift by a byte.
pub(crate) fn render_trace_event(e: &ObsEvent, out: &mut String) {
    out.push_str(",\n");
    let seq = e.seq;
    match &e.kind {
        EventKind::Arrival { model } => {
            push_common(out, "arrival", "queue", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"model\":");
            out.push_str(&model.to_string());
            out.push_str("}}");
        }
        EventKind::Reject { reason } => {
            push_common(out, "reject", "queue", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"reason\":\"");
            escape_json(reason, out);
            out.push_str("\"}}");
        }
        EventKind::Serve { model, batch, dur } => {
            push_common(out, "serve", "encoder", 'X', e.cycle, e.device);
            out.push_str(",\"dur\":");
            out.push_str(&dur.to_string());
            out.push_str(",\"args\":{\"model\":");
            out.push_str(&model.to_string());
            out.push_str(",\"batch\":");
            out.push_str(&batch.to_string());
            out.push_str("}}");
        }
        EventKind::Complete { latency } => {
            push_common(out, "complete", "lifecycle", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"latency\":");
            out.push_str(&latency.to_string());
            out.push_str("}}");
        }
        EventKind::Drop => {
            push_common(out, "drop", "queue", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str("}}");
        }
        EventKind::Steal { victim, requests } => {
            push_common(out, "steal", "queue", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"victim\":");
            out.push_str(&victim.to_string());
            out.push_str(",\"requests\":");
            out.push_str(&requests.to_string());
            out.push_str("}}");
        }
        EventKind::Prefill { model, batch, rows, chunk, tokens, dur } => {
            let name = if *chunk { "prefill_chunk" } else { "prefill" };
            push_common(out, name, "decode", 'X', e.cycle, e.device);
            out.push_str(",\"dur\":");
            out.push_str(&dur.to_string());
            out.push_str(",\"args\":{\"model\":");
            out.push_str(&model.to_string());
            out.push_str(",\"batch\":");
            out.push_str(&batch.to_string());
            out.push_str(",\"rows\":");
            out.push_str(&rows.to_string());
            out.push_str(",\"tokens\":");
            out.push_str(&tokens.to_string());
            out.push_str("}}");
        }
        EventKind::DecodeTick { batch, dur } => {
            push_common(out, "decode_tick", "decode", 'X', e.cycle, e.device);
            out.push_str(",\"dur\":");
            out.push_str(&dur.to_string());
            out.push_str(",\"args\":{\"batch\":");
            out.push_str(&batch.to_string());
            out.push_str("}}");
        }
        EventKind::Preempt => {
            push_common(out, "preempt", "kv", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str("}}");
        }
        EventKind::Resume => {
            push_common(out, "resume", "kv", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str("}}");
        }
        EventKind::KvAdmit { tokens } => {
            push_common(out, "kv_admit", "kv", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"tokens\":");
            out.push_str(&tokens.to_string());
            out.push_str("}}");
        }
        EventKind::MigrateOut { dst, words, dur } => {
            push_common(out, "migrate_out", "migrate", 'X', e.cycle, e.device);
            out.push_str(",\"dur\":");
            out.push_str(&dur.to_string());
            out.push_str(",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"dst\":");
            out.push_str(&dst.to_string());
            out.push_str(",\"words\":");
            out.push_str(&words.to_string());
            out.push_str("}},\n");
            // Flow arrow: opens at the source span, keyed by seq id.
            push_common(out, "migrate", "migrate", 's', e.cycle, e.device);
            out.push_str(",\"id\":");
            out.push_str(&seq.to_string());
            out.push('}');
        }
        EventKind::MigrateIn { src, words, dur } => {
            push_common(out, "migrate_in", "migrate", 'X', e.cycle, e.device);
            out.push_str(",\"dur\":");
            out.push_str(&dur.to_string());
            out.push_str(",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"src\":");
            out.push_str(&src.to_string());
            out.push_str(",\"words\":");
            out.push_str(&words.to_string());
            out.push_str("}},\n");
            // Close the flow arrow on the destination span.
            push_common(out, "migrate", "migrate", 'f', e.cycle, e.device);
            out.push_str(",\"bp\":\"e\",\"id\":");
            out.push_str(&seq.to_string());
            out.push('}');
        }
        EventKind::QueueDepth { depth } => {
            out.push_str("{\"name\":\"queue_depth[");
            out.push_str(&e.device.to_string());
            out.push_str("]\",\"ph\":\"C\",\"ts\":");
            out.push_str(&e.cycle.to_string());
            out.push_str(",\"pid\":0,\"args\":{\"depth\":");
            out.push_str(&depth.to_string());
            out.push_str("}}");
        }
        EventKind::KvOccupancy { permille } => {
            out.push_str("{\"name\":\"kv_permille[");
            out.push_str(&e.device.to_string());
            out.push_str("]\",\"ph\":\"C\",\"ts\":");
            out.push_str(&e.cycle.to_string());
            out.push_str(",\"pid\":0,\"args\":{\"permille\":");
            out.push_str(&permille.to_string());
            out.push_str("}}");
        }
        EventKind::Hold { dur } => {
            push_common(out, "hold", "queue", 'X', e.cycle, e.device);
            out.push_str(",\"dur\":");
            out.push_str(&dur.to_string());
            out.push_str(",\"args\":{}}");
        }
        EventKind::ChunkWait => {
            push_common(out, "chunk_wait", "kv", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str("}}");
        }
        EventKind::HandoffOut { dst, words, dur } => {
            push_common(out, "handoff_out", "handoff", 'X', e.cycle, e.device);
            out.push_str(",\"dur\":");
            out.push_str(&dur.to_string());
            out.push_str(",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"dst\":");
            out.push_str(&dst.to_string());
            out.push_str(",\"words\":");
            out.push_str(&words.to_string());
            out.push_str("}},\n");
            // Flow arrow: opens at the prefill-side span, keyed by seq.
            push_common(out, "handoff", "handoff", 's', e.cycle, e.device);
            out.push_str(",\"id\":");
            out.push_str(&seq.to_string());
            out.push('}');
        }
        EventKind::HandoffIn { src, words, dur } => {
            push_common(out, "handoff_in", "handoff", 'X', e.cycle, e.device);
            out.push_str(",\"dur\":");
            out.push_str(&dur.to_string());
            out.push_str(",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"src\":");
            out.push_str(&src.to_string());
            out.push_str(",\"words\":");
            out.push_str(&words.to_string());
            out.push_str("}},\n");
            // Close the flow arrow on the decode-side span.
            push_common(out, "handoff", "handoff", 'f', e.cycle, e.device);
            out.push_str(",\"bp\":\"e\",\"id\":");
            out.push_str(&seq.to_string());
            out.push('}');
        }
        EventKind::PrefixHit { tokens } => {
            push_common(out, "prefix_hit", "kv", 'i', e.cycle, e.device);
            out.push_str(",\"s\":\"t\",\"args\":{\"seq\":");
            out.push_str(&seq.to_string());
            out.push_str(",\"tokens\":");
            out.push_str(&tokens.to_string());
            out.push_str("}}");
        }
    }
}

/// Render the event stream as Chrome trace-event JSON. `device_names`
/// label the per-device tracks (index = `tid`). Timestamps are ref
/// cycles rendered as the format's microsecond field: 1 "µs" in the
/// viewer = 1 ref cycle.
pub fn render_chrome_json(events: &[ObsEvent], device_names: &[String]) -> String {
    let mut out = render_trace_header(device_names);
    out.reserve(events.len() * 96);
    for e in events {
        render_trace_event(e, &mut out);
    }
    out.push_str(TRACE_FOOTER);
    out
}

/// Async-event common prefix: like [`push_common`] plus the async
/// grouping id (Chrome nests `b`/`e` pairs sharing `(cat, id)`).
fn push_async(out: &mut String, name: &str, ph: char, cycle: u64, device: usize, id: u64) {
    push_common(out, name, "anatomy", ph, cycle, device);
    out.push_str(",\"id\":");
    out.push_str(&id.to_string());
}

/// Append the per-request anatomy span tracks (each record with its
/// leading `,\n` separator, so the caller can splice this between the
/// device-track events and [`TRACE_FOOTER`]). One nested async row per
/// completed request: the e2e parent span, one child span per causal
/// segment, and an `anatomy` flow arrow tying the request row to the
/// device track that completed it.
pub fn render_anatomy_spans(anatomies: &[RequestAnatomy], out: &mut String) {
    for r in anatomies {
        out.push_str(",\n");
        push_async(out, "request", 'b', r.arrival, r.device, r.id);
        out.push_str(",\"args\":{\"seq\":");
        out.push_str(&r.id.to_string());
        out.push_str(",\"model\":");
        out.push_str(&r.model.to_string());
        out.push_str(",\"latency\":");
        out.push_str(&r.latency.to_string());
        out.push_str("}}");
        for seg in &r.segments {
            let name = COMPONENT_NAMES[seg.component];
            out.push_str(",\n");
            push_async(out, name, 'b', seg.start, r.device, r.id);
            out.push('}');
            out.push_str(",\n");
            push_async(out, name, 'e', seg.end, r.device, r.id);
            out.push('}');
        }
        out.push_str(",\n");
        push_async(out, "request", 'e', r.completion, r.device, r.id);
        out.push('}');
        // Flow arrow: request anatomy row -> completing device track.
        out.push_str(",\n");
        push_common(out, "anatomy", "anatomy", 's', r.arrival, r.device);
        out.push_str(",\"id\":");
        out.push_str(&r.id.to_string());
        out.push('}');
        out.push_str(",\n");
        push_common(out, "anatomy", "anatomy", 'f', r.completion, r.device);
        out.push_str(",\"bp\":\"e\",\"id\":");
        out.push_str(&r.id.to_string());
        out.push('}');
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_balanced(json: &str) {
        // Every rendered set must be valid JSON as a whole: cheap
        // structural check — balanced braces/brackets outside strings.
        let mut depth = 0i64;
        let mut in_str = false;
        let mut esc = false;
        for c in json.chars() {
            if esc {
                esc = false;
                continue;
            }
            match c {
                '\\' if in_str => esc = true,
                '"' => in_str = !in_str,
                '{' | '[' if !in_str => depth += 1,
                '}' | ']' if !in_str => depth -= 1,
                _ => {}
            }
        }
        assert_eq!(depth, 0, "unbalanced JSON");
        assert!(!in_str);
    }

    #[test]
    fn renderer_is_deterministic_and_emits_flows() {
        let events = vec![
            ObsEvent { cycle: 0, device: 0, seq: 7, kind: EventKind::Arrival { model: 1 } },
            ObsEvent {
                cycle: 5,
                device: 0,
                seq: 7,
                kind: EventKind::MigrateOut { dst: 1, words: 64, dur: 8 },
            },
            ObsEvent {
                cycle: 13,
                device: 1,
                seq: 7,
                kind: EventKind::MigrateIn { src: 0, words: 64, dur: 4 },
            },
            ObsEvent { cycle: 20, device: 1, seq: 7, kind: EventKind::Complete { latency: 20 } },
        ];
        let names = vec!["dev0".to_string(), "dev1".to_string()];
        let a = render_chrome_json(&events, &names);
        let b = render_chrome_json(&events, &names);
        assert_eq!(a, b);
        assert!(a.contains("\"ph\":\"s\""), "missing flow start");
        assert!(a.contains("\"ph\":\"f\""), "missing flow finish");
        assert!(a.contains("\"thread_name\""));
        assert_balanced(&a);
    }

    #[test]
    fn reason_strings_are_escaped() {
        let events = vec![ObsEvent {
            cycle: 1,
            device: 0,
            seq: 3,
            kind: EventKind::Reject { reason: "needs \"quotes\"\n".to_string() },
        }];
        let json = render_chrome_json(&events, &["d".to_string()]);
        assert!(json.contains("needs \\\"quotes\\\"\\n"));
    }

    #[test]
    fn hold_and_chunk_wait_render_on_device_tracks() {
        let events = vec![
            ObsEvent { cycle: 10, device: 2, seq: NO_SEQ, kind: EventKind::Hold { dur: 40 } },
            ObsEvent { cycle: 55, device: 1, seq: 9, kind: EventKind::ChunkWait },
        ];
        let names = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let json = render_chrome_json(&events, &names);
        assert!(json.contains("\"name\":\"hold\",\"cat\":\"queue\",\"ph\":\"X\",\"ts\":10"));
        assert!(json.contains("\"dur\":40"));
        assert!(json.contains("\"name\":\"chunk_wait\",\"cat\":\"kv\",\"ph\":\"i\",\"ts\":55"));
        assert_balanced(&json);
    }

    #[test]
    fn handoff_and_prefix_hit_render_with_flows() {
        let events = vec![
            ObsEvent { cycle: 3, device: 0, seq: 5, kind: EventKind::PrefixHit { tokens: 12 } },
            ObsEvent {
                cycle: 9,
                device: 0,
                seq: 5,
                kind: EventKind::HandoffOut { dst: 1, words: 96, dur: 6 },
            },
            ObsEvent {
                cycle: 15,
                device: 1,
                seq: 5,
                kind: EventKind::HandoffIn { src: 0, words: 96, dur: 3 },
            },
        ];
        let names = vec!["p".to_string(), "d".to_string()];
        let json = render_chrome_json(&events, &names);
        assert_eq!(json, render_chrome_json(&events, &names));
        assert!(json.contains("\"name\":\"prefix_hit\",\"cat\":\"kv\",\"ph\":\"i\",\"ts\":3"));
        assert!(json.contains("\"tokens\":12"));
        assert!(json.contains("\"name\":\"handoff_out\",\"cat\":\"handoff\",\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"handoff_in\",\"cat\":\"handoff\",\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"handoff\",\"cat\":\"handoff\",\"ph\":\"s\""));
        assert!(json.contains("\"name\":\"handoff\",\"cat\":\"handoff\",\"ph\":\"f\""));
        assert_balanced(&json);
    }

    #[test]
    fn split_render_matches_monolithic_render() {
        let events = vec![
            ObsEvent { cycle: 0, device: 0, seq: 1, kind: EventKind::Arrival { model: 0 } },
            ObsEvent {
                cycle: 4,
                device: 0,
                seq: NO_SEQ,
                kind: EventKind::Serve { model: 0, batch: 1, dur: 6 },
            },
            ObsEvent { cycle: 10, device: 0, seq: 1, kind: EventKind::Complete { latency: 10 } },
        ];
        let names = vec!["dev0 4x4@100".to_string()];
        let mut split = render_trace_header(&names);
        for e in &events {
            render_trace_event(e, &mut split);
        }
        split.push_str(TRACE_FOOTER);
        assert_eq!(split, render_chrome_json(&events, &names));
    }

    #[test]
    fn anatomy_spans_nest_and_balance() {
        use super::super::anatomy::{AnatomySegment, Components, RequestAnatomy};
        let r = RequestAnatomy {
            id: 3,
            model: 1,
            arrival: 100,
            completion: 160,
            latency: 60,
            device: 0,
            segments: vec![
                AnatomySegment { start: 100, end: 120, component: 0 },
                AnatomySegment { start: 120, end: 160, component: 2 },
            ],
            comps: Components::default(),
        };
        let mut out = render_trace_header(&["d0".to_string()]);
        render_anatomy_spans(&[r], &mut out);
        out.push_str(TRACE_FOOTER);
        assert!(out.contains("\"name\":\"request\",\"cat\":\"anatomy\",\"ph\":\"b\""));
        assert!(out.contains("\"name\":\"queue_wait\",\"cat\":\"anatomy\",\"ph\":\"b\""));
        assert!(out.contains("\"name\":\"prefill_exec\",\"cat\":\"anatomy\",\"ph\":\"e\""));
        assert!(out.contains("\"name\":\"anatomy\",\"cat\":\"anatomy\",\"ph\":\"s\""));
        assert_balanced(&out);
    }
}
