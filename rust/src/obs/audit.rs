//! Fleet-level blame report built on [`super::anatomy`]: which latency
//! component owns the fleet's cycles, per model class and per device,
//! per metrics window — and which windows missed SLA.
//!
//! Everything is integer arithmetic over the deterministic anatomy
//! output, and the JSON/CSV renderers are hand-built with fixed field
//! order, so report bytes are a pure function of the event stream:
//! identical for a fixed seed across `--threads N`
//! (`rust/tests/anatomy_props.rs` pins this).

use super::anatomy::{comp, RequestAnatomy, COMPONENT_NAMES, N_COMPONENTS};
use super::hist::LogHistogram;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Audit parameters. SLA budgets are per model class, in ref cycles
/// (`None` = class has no SLA).
#[derive(Debug, Clone, PartialEq)]
pub struct AuditConfig {
    /// Window size in ref cycles (completions bucket by completion
    /// cycle / window).
    pub window_cycles: u64,
    /// Per-class e2e budget in ref cycles; a completion whose latency
    /// exceeds its class budget is an SLA miss.
    pub sla_cycles_by_class: Vec<Option<u64>>,
    /// How many worst-latency requests to list.
    pub worst_k: usize,
}

impl AuditConfig {
    pub fn new(window_cycles: u64, sla_cycles_by_class: Vec<Option<u64>>) -> Self {
        Self { window_cycles: window_cycles.max(1), sla_cycles_by_class, worst_k: 10 }
    }
}

/// Per-component histograms for one grouping key (class or device).
#[derive(Debug, Clone, Default)]
pub struct ComponentHists {
    pub completions: u64,
    pub hists: [LogHistogram; N_COMPONENTS],
}

impl ComponentHists {
    fn record(&mut self, comps: &[u64; N_COMPONENTS]) {
        self.completions += 1;
        for (h, &v) in self.hists.iter_mut().zip(comps) {
            h.record(v);
        }
    }
}

/// One audit window: completions bucketed by completion cycle.
#[derive(Debug, Clone, Default)]
pub struct WindowBlame {
    pub completions: u64,
    pub sla_misses: u64,
    pub latency_sum: u64,
    /// Cycle totals per component across this window's completions.
    pub comp_totals: [u64; N_COMPONENTS],
}

impl WindowBlame {
    /// Dominant component (ties broken toward the lower index, i.e.
    /// the earlier lifecycle stage).
    pub fn top_component(&self) -> usize {
        let mut best = 0;
        for (i, &v) in self.comp_totals.iter().enumerate() {
            if v > self.comp_totals[best] {
                best = i;
            }
        }
        best
    }
}

/// One worst-offender row.
#[derive(Debug, Clone)]
pub struct WorstRequest {
    pub id: u64,
    pub model: usize,
    pub device: usize,
    pub completion: u64,
    pub latency: u64,
    pub sla_miss: bool,
    pub top_component: usize,
}

/// The full fleet audit: critical-path blame + SLA-miss accounting.
#[derive(Debug, Clone)]
pub struct AuditReport {
    pub window_cycles: u64,
    pub completions: u64,
    pub sla_misses: u64,
    pub latency_sum: u64,
    /// Fleet-wide cycle totals per component.
    pub comp_totals: [u64; N_COMPONENTS],
    pub per_class: BTreeMap<usize, ComponentHists>,
    pub per_device: BTreeMap<usize, ComponentHists>,
    pub windows: BTreeMap<u64, WindowBlame>,
    pub worst: Vec<WorstRequest>,
    device_names: Vec<String>,
}

impl AuditReport {
    /// Aggregate the per-request anatomies into the fleet report.
    pub fn build(
        anatomies: &[RequestAnatomy],
        device_names: &[String],
        cfg: &AuditConfig,
    ) -> Self {
        let window = cfg.window_cycles.max(1);
        let mut report = Self {
            window_cycles: window,
            completions: 0,
            sla_misses: 0,
            latency_sum: 0,
            comp_totals: [0; N_COMPONENTS],
            per_class: BTreeMap::new(),
            per_device: BTreeMap::new(),
            windows: BTreeMap::new(),
            worst: Vec::new(),
            device_names: device_names.to_vec(),
        };
        for r in anatomies {
            let miss = cfg
                .sla_cycles_by_class
                .get(r.model)
                .copied()
                .flatten()
                .is_some_and(|budget| r.latency > budget);
            report.completions += 1;
            report.latency_sum += r.latency;
            if miss {
                report.sla_misses += 1;
            }
            for (t, &v) in report.comp_totals.iter_mut().zip(&r.comps.0) {
                *t += v;
            }
            report.per_class.entry(r.model).or_default().record(&r.comps.0);
            report.per_device.entry(r.device).or_default().record(&r.comps.0);
            let w = report.windows.entry(r.completion / window).or_default();
            w.completions += 1;
            w.latency_sum += r.latency;
            if miss {
                w.sla_misses += 1;
            }
            for (t, &v) in w.comp_totals.iter_mut().zip(&r.comps.0) {
                *t += v;
            }
        }
        // Worst offenders: by latency descending, id ascending on ties
        // — a total, deterministic order.
        let mut order: Vec<usize> = (0..anatomies.len()).collect();
        order.sort_by_key(|&i| (std::cmp::Reverse(anatomies[i].latency), anatomies[i].id));
        for &i in order.iter().take(cfg.worst_k) {
            let r = &anatomies[i];
            let miss = cfg
                .sla_cycles_by_class
                .get(r.model)
                .copied()
                .flatten()
                .is_some_and(|budget| r.latency > budget);
            let mut top = 0;
            for (c, &v) in r.comps.0.iter().enumerate() {
                if v > r.comps.0[top] {
                    top = c;
                }
            }
            report.worst.push(WorstRequest {
                id: r.id,
                model: r.model,
                device: r.device,
                completion: r.completion,
                latency: r.latency,
                sla_miss: miss,
                top_component: top,
            });
        }
        report
    }

    /// Share of the fleet latency sum owned by component `c`, in
    /// permille (0 when nothing completed).
    pub fn share_permille(&self, c: usize) -> u64 {
        if self.latency_sum == 0 {
            0
        } else {
            // u64 cycle sums can exceed u64::MAX / 1000 on long runs;
            // widen for the scaled division.
            ((self.comp_totals[c] as u128 * 1000) / self.latency_sum as u128) as u64
        }
    }

    fn push_hist_group(out: &mut String, g: &ComponentHists) {
        out.push_str("\"completions\":");
        let _ = write!(out, "{}", g.completions);
        out.push_str(",\"components\":[");
        for (c, h) in g.hists.iter().enumerate() {
            if c > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"p50\":{},\"p99\":{},\"max\":{}}}",
                COMPONENT_NAMES[c],
                h.p50(),
                h.p99(),
                h.max()
            );
        }
        out.push(']');
    }

    /// Deterministic hand-built JSON (fixed field order, integers
    /// only).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\"schema\":\"cgra-audit-v1\"");
        let _ = write!(
            out,
            ",\"window_cycles\":{},\"completions\":{},\"sla_misses\":{},\"latency_sum\":{}",
            self.window_cycles, self.completions, self.sla_misses, self.latency_sum
        );
        out.push_str(",\"components\":[");
        for c in 0..N_COMPONENTS {
            if c > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"total_cycles\":{},\"share_permille\":{}}}",
                COMPONENT_NAMES[c],
                self.comp_totals[c],
                self.share_permille(c)
            );
        }
        out.push_str("],\"per_class\":[");
        for (i, (class, g)) in self.per_class.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{{\"class\":{class},");
            Self::push_hist_group(&mut out, g);
            out.push('}');
        }
        out.push_str("],\"per_device\":[");
        for (i, (dev, g)) in self.per_device.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{{\"device\":{dev},\"name\":\"");
            if let Some(name) = self.device_names.get(*dev) {
                // Device names are `devN RxC@MHZ [class]` strings built
                // by enable_obs — no JSON-special characters — but
                // escape defensively anyway.
                for ch in name.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        c if (c as u32) >= 0x20 => out.push(c),
                        _ => {}
                    }
                }
            }
            out.push_str("\",");
            Self::push_hist_group(&mut out, g);
            out.push('}');
        }
        out.push_str("],\"windows\":[");
        for (i, (w, b)) in self.windows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"window\":{w},\"start_cycle\":{},\"completions\":{},\"sla_misses\":{},\
                 \"flagged\":{},\"top_component\":\"{}\",\"latency_sum\":{},\"components\":[",
                w * self.window_cycles,
                b.completions,
                b.sla_misses,
                b.sla_misses > 0,
                COMPONENT_NAMES[b.top_component()],
                b.latency_sum
            );
            for (c, &v) in b.comp_totals.iter().enumerate() {
                if c > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{v}");
            }
            out.push_str("]}");
        }
        out.push_str("],\"worst\":[");
        for (i, r) in self.worst.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"seq\":{},\"model\":{},\"device\":{},\"completion\":{},\"latency\":{},\
                 \"sla_miss\":{},\"top_component\":\"{}\"}}",
                r.id,
                r.model,
                r.device,
                r.completion,
                r.latency,
                r.sla_miss,
                COMPONENT_NAMES[r.top_component]
            );
        }
        out.push_str("]}\n");
        out
    }

    /// Per-window blame table as CSV (one row per window that saw a
    /// completion).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("window,start_cycle,completions,sla_misses,flagged,top_component");
        for name in COMPONENT_NAMES {
            out.push(',');
            out.push_str(name);
        }
        out.push('\n');
        for (w, b) in &self.windows {
            let _ = write!(
                out,
                "{w},{},{},{},{},{}",
                w * self.window_cycles,
                b.completions,
                b.sla_misses,
                u64::from(b.sla_misses > 0),
                COMPONENT_NAMES[b.top_component()]
            );
            for &v in &b.comp_totals {
                let _ = write!(out, ",{v}");
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::anatomy::{AnatomySegment, Components};

    fn req(
        id: u64,
        model: usize,
        device: usize,
        completion: u64,
        latency: u64,
        comps: [u64; N_COMPONENTS],
    ) -> RequestAnatomy {
        RequestAnatomy {
            id,
            model,
            arrival: completion - latency,
            completion,
            latency,
            device,
            segments: vec![AnatomySegment {
                start: completion - latency,
                end: completion,
                component: comp::QUEUE_WAIT,
            }],
            comps: Components(comps),
        }
    }

    #[test]
    fn report_aggregates_shares_and_flags_sla_windows() {
        let mut c1 = [0u64; N_COMPONENTS];
        c1[comp::QUEUE_WAIT] = 30;
        c1[comp::PREFILL_EXEC] = 70;
        let mut c2 = [0u64; N_COMPONENTS];
        c2[comp::MIGRATION] = 150;
        c2[comp::DECODE_EXEC] = 50;
        let anat = vec![req(1, 0, 0, 90, 100, c1), req(2, 1, 1, 250, 200, c2)];
        let cfg = AuditConfig::new(100, vec![Some(120), Some(120)]);
        let names = vec!["dev0".to_string(), "dev1".to_string()];
        let r = AuditReport::build(&anat, &names, &cfg);
        assert_eq!(r.completions, 2);
        assert_eq!(r.sla_misses, 1); // request 2 blew its 120-cycle budget
        assert_eq!(r.latency_sum, 300);
        assert_eq!(r.share_permille(comp::MIGRATION), 500);
        assert_eq!(r.windows.len(), 2);
        assert_eq!(r.windows[&0].sla_misses, 0);
        assert_eq!(r.windows[&2].sla_misses, 1);
        assert_eq!(r.windows[&2].top_component(), comp::MIGRATION);
        // Worst list: request 2 (latency 200) first.
        assert_eq!(r.worst[0].id, 2);
        assert!(r.worst[0].sla_miss);
        assert_eq!(r.worst[0].top_component, comp::MIGRATION);
    }

    #[test]
    fn json_and_csv_are_deterministic_and_well_formed() {
        let mut c = [0u64; N_COMPONENTS];
        c[comp::DECODE_EXEC] = 40;
        c[comp::DECODE_STALL] = 10;
        let anat = vec![req(5, 0, 0, 50, 50, c)];
        let cfg = AuditConfig::new(64, vec![None]);
        let names = vec!["dev0".to_string()];
        let r = AuditReport::build(&anat, &names, &cfg);
        let a = r.to_json();
        let b = AuditReport::build(&anat, &names, &cfg).to_json();
        assert_eq!(a, b);
        assert!(a.starts_with("{\"schema\":\"cgra-audit-v1\""));
        assert!(a.contains("\"top_component\":\"decode_exec\""));
        // Balanced braces outside strings.
        let mut depth = 0i64;
        let mut in_str = false;
        let mut esc = false;
        for ch in a.chars() {
            if esc {
                esc = false;
                continue;
            }
            match ch {
                '\\' if in_str => esc = true,
                '"' => in_str = !in_str,
                '{' | '[' if !in_str => depth += 1,
                '}' | ']' if !in_str => depth -= 1,
                _ => {}
            }
        }
        assert_eq!(depth, 0);
        let csv = r.to_csv();
        assert!(csv.starts_with("window,start_cycle,completions,sla_misses,flagged,top_component,queue_wait,"));
        assert_eq!(csv.lines().count(), 2);
    }
}
