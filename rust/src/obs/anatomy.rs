//! Per-request causal latency anatomy: split each completed request's
//! end-to-end latency into named components, derived deterministically
//! from the structured event stream alone.
//!
//! The load-bearing invariant is **exactness by construction**: for
//! every completed request, [`decompose`] partitions the half-open
//! cycle range `[arrival, completion)` into contiguous
//! [`AnatomySegment`]s — no gaps, no overlaps — so the component
//! totals sum bit-exactly to the recorded e2e latency
//! (`completion − arrival`, with `arrival := completion − latency`
//! taken from the `Complete` event itself). Event matching (which
//! serve span, which tick, which chunk a request rode) only decides
//! how cycles are *labeled*; a mismatch can mislabel a bucket but can
//! never break the sum. `rust/tests/anatomy_props.rs` pins the sum
//! over random rosters, schedules, chunking, preemption, and
//! migration.
//!
//! Components (index = position in [`COMPONENT_NAMES`]):
//!
//! 0. `queue_wait` — arrival to first causal activity (admission gap).
//! 1. `hold` — batch-formation hold: the device was parked on a
//!    partial batch containing this request (encoder hold-for-fill).
//! 2. `prefill_exec` — encoder serve span or decode prefill/chunk
//!    execution.
//! 3. `chunk_stall` — waiting between prefill chunks (budget or KV
//!    pressure).
//! 4. `decode_exec` — decode-tick execution while running.
//! 5. `decode_stall` — running but waiting for the next tick (the
//!    ISSUE's eight components plus this one: continuous batching
//!    interleaves chunks between ticks, and lumping that wait into
//!    chunk-stall would blame the wrong mechanism).
//! 6. `preempt_stall` — preempted (pages shed) until re-prefilled.
//! 7. `migration` — live KV transfer: source export start to
//!    destination import end.
//! 8. `steal` — work-stealing relocation. Always zero in the current
//!    encoder (a stolen batch is served at the same cycle it is
//!    stolen), kept as a named bucket so the report schema is stable
//!    if relocation ever gains a cost.
//! 9. `handoff` — disaggregated prefill→decode hand-off: source export
//!    start to destination import end, the KV-image transfer between
//!    phase-specialized devices (distinct from `migration`, which is a
//!    load-balancing move).

use super::trace::{EventKind, ObsEvent, NO_SEQ};
use std::collections::BTreeMap;

/// Number of anatomy components.
pub const N_COMPONENTS: usize = 10;

/// Component names, index-aligned with [`Components`].
pub const COMPONENT_NAMES: [&str; N_COMPONENTS] = [
    "queue_wait",
    "hold",
    "prefill_exec",
    "chunk_stall",
    "decode_exec",
    "decode_stall",
    "preempt_stall",
    "migration",
    "steal",
    "handoff",
];

/// Component indices, by name.
pub mod comp {
    pub const QUEUE_WAIT: usize = 0;
    pub const HOLD: usize = 1;
    pub const PREFILL_EXEC: usize = 2;
    pub const CHUNK_STALL: usize = 3;
    pub const DECODE_EXEC: usize = 4;
    pub const DECODE_STALL: usize = 5;
    pub const PREEMPT_STALL: usize = 6;
    pub const MIGRATION: usize = 7;
    pub const STEAL: usize = 8;
    pub const HANDOFF: usize = 9;
}

/// Per-component cycle totals for one request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Components(pub [u64; N_COMPONENTS]);

impl Components {
    /// Total cycles across all components — bit-exactly the request's
    /// e2e latency.
    pub fn sum(&self) -> u64 {
        self.0.iter().sum()
    }
}

/// One labeled slice of a request's `[arrival, completion)` timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AnatomySegment {
    pub start: u64,
    /// Exclusive end cycle.
    pub end: u64,
    /// Index into [`COMPONENT_NAMES`].
    pub component: usize,
}

/// The causal decomposition of one completed request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestAnatomy {
    /// Request / sequence id.
    pub id: u64,
    /// Model class index.
    pub model: usize,
    /// Derived arrival cycle (`completion − latency`).
    pub arrival: u64,
    /// Completion cycle (the `Complete` event's timestamp).
    pub completion: u64,
    /// Recorded e2e latency from the `Complete` event.
    pub latency: u64,
    /// Device that completed the request.
    pub device: usize,
    /// Exact contiguous partition of `[arrival, completion)`.
    pub segments: Vec<AnatomySegment>,
    /// Per-component cycle totals (sums of `segments` by label).
    pub comps: Components,
}

/// Sequence lifecycle phase, used only to pick gap labels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Queued,
    Prefilling,
    Decoding,
    Preempted,
}

#[derive(Debug)]
struct SeqState {
    model: usize,
    phase: Phase,
    /// Raw labeled activity intervals `(start, end, component)`; may
    /// be future-dated or overlapping — the assembly pass clamps.
    intervals: Vec<(u64, u64, usize)>,
    /// Gap-label breakpoints `(cycle, component)`: unassigned time at
    /// or after `cycle` is labeled `component` until the next mark.
    marks: Vec<(u64, usize)>,
    /// Source-side start of an in-flight migration.
    migrate_src: Option<u64>,
    /// Source-side start of an in-flight disaggregated hand-off.
    handoff_src: Option<u64>,
}

impl SeqState {
    fn new() -> Self {
        Self {
            model: 0,
            phase: Phase::Queued,
            intervals: Vec::new(),
            marks: Vec::new(),
            migrate_src: None,
            handoff_src: None,
        }
    }
}

#[derive(Debug, Default)]
struct DevState {
    /// Last encoder serve span `(start, end, model)`.
    last_serve: Option<(u64, u64, usize)>,
    /// Batch-formation hold attached to `last_serve`.
    serve_hold: Option<(u64, u64)>,
    /// Hold span awaiting its serve (retroactive emission: the Hold
    /// event immediately precedes its Serve in stream order).
    pending_hold: Option<(u64, u64)>,
    /// KV admissions `(cycle, seq)` not yet claimed by a stacked
    /// prefill job on this device.
    admits: Vec<(u64, u64)>,
    /// Last stacked prefill span `(cycle, end)` (for admits recorded
    /// after the job event at the same cycle).
    last_batch_prefill: Option<(u64, u64)>,
    /// Sequences currently in the running decode batch here.
    decoding: Vec<u64>,
}

impl DevState {
    fn drop_decoding(&mut self, seq: u64) {
        self.decoding.retain(|&s| s != seq);
    }
}

/// Fill `[from, to)` with gap segments, switching labels at `marks`
/// breakpoints (sorted by cycle; default label `queue_wait`).
fn fill_gap(segments: &mut Vec<AnatomySegment>, marks: &[(u64, usize)], from: u64, to: u64) {
    let mut t = from;
    while t < to {
        let mut label = comp::QUEUE_WAIT;
        let mut next = to;
        for &(mc, ml) in marks {
            if mc <= t {
                label = ml;
            } else {
                next = next.min(mc);
                break;
            }
        }
        segments.push(AnatomySegment { start: t, end: next, component: label });
        t = next;
    }
}

/// Assemble one request's exact partition from its raw intervals and
/// gap marks.
fn assemble(
    id: u64,
    model: usize,
    completion: u64,
    latency: u64,
    device: usize,
    mut intervals: Vec<(u64, u64, usize)>,
    mut marks: Vec<(u64, usize)>,
) -> RequestAnatomy {
    let arrival = completion.saturating_sub(latency);
    intervals.sort_by_key(|&(s, e, _)| (s, e));
    marks.sort_by_key(|&(c, _)| c);
    let mut segments: Vec<AnatomySegment> = Vec::new();
    let mut prev = arrival;
    for &(s, e, c) in &intervals {
        let start = s.max(prev).min(completion);
        let end = e.min(completion).max(start);
        if start > prev {
            fill_gap(&mut segments, &marks, prev, start);
        }
        if end > start {
            segments.push(AnatomySegment { start, end, component: c });
        }
        prev = prev.max(end);
    }
    if prev < completion {
        fill_gap(&mut segments, &marks, prev, completion);
    }
    // Merge adjacent same-label segments so span tracks stay compact.
    let mut merged: Vec<AnatomySegment> = Vec::with_capacity(segments.len());
    for seg in segments {
        match merged.last_mut() {
            Some(last) if last.component == seg.component && last.end == seg.start => {
                last.end = seg.end;
            }
            _ => merged.push(seg),
        }
    }
    let mut comps = Components::default();
    for seg in &merged {
        comps.0[seg.component] += seg.end - seg.start;
    }
    debug_assert_eq!(
        comps.sum(),
        latency,
        "anatomy components must sum to e2e latency for seq {id}"
    );
    RequestAnatomy { id, model, arrival, completion, latency, device, segments: merged, comps }
}

/// Decompose the event stream into per-request anatomies, sorted by
/// `(completion, id)`. Purely a function of the stream: byte-for-byte
/// identical events (the PR 6/8 thread-identity contract) give
/// identical anatomies.
pub fn decompose(events: &[ObsEvent]) -> Vec<RequestAnatomy> {
    let mut seqs: BTreeMap<u64, SeqState> = BTreeMap::new();
    let mut devs: BTreeMap<usize, DevState> = BTreeMap::new();
    let mut out: Vec<RequestAnatomy> = Vec::new();

    for e in events {
        match &e.kind {
            EventKind::Arrival { model } => {
                seqs.entry(e.seq).or_insert_with(SeqState::new).model = *model;
            }
            EventKind::Hold { dur } => {
                devs.entry(e.device).or_default().pending_hold = Some((e.cycle, e.cycle + dur));
            }
            EventKind::Serve { model, dur, .. } => {
                let dev = devs.entry(e.device).or_default();
                dev.serve_hold =
                    dev.pending_hold.take().filter(|&(_, hold_end)| hold_end == e.cycle);
                dev.last_serve = Some((e.cycle, e.cycle + dur, *model));
            }
            EventKind::KvAdmit { .. } => {
                let st = seqs.entry(e.seq).or_insert_with(SeqState::new);
                st.phase = Phase::Prefilling;
                let dev = devs.entry(e.device).or_default();
                match dev.last_batch_prefill {
                    // Admission recorded after the stacked job event at
                    // the same cycle: attach directly.
                    Some((c, end)) if c == e.cycle => {
                        st.intervals.push((c, end, comp::PREFILL_EXEC));
                        st.marks.push((end, comp::DECODE_STALL));
                        st.phase = Phase::Decoding;
                        dev.decoding.push(e.seq);
                    }
                    _ => dev.admits.push((e.cycle, e.seq)),
                }
            }
            EventKind::Resume => {
                let st = seqs.entry(e.seq).or_insert_with(SeqState::new);
                st.phase = Phase::Prefilling;
                st.marks.push((e.cycle, comp::PREEMPT_STALL));
            }
            EventKind::Prefill { dur, chunk, .. } if e.seq != NO_SEQ => {
                // Per-sequence chunk of a chunked prefill.
                let st = seqs.entry(e.seq).or_insert_with(SeqState::new);
                st.intervals.push((e.cycle, e.cycle + dur, comp::PREFILL_EXEC));
                if *chunk {
                    st.phase = Phase::Prefilling;
                    st.marks.push((e.cycle + dur, comp::CHUNK_STALL));
                } else {
                    st.phase = Phase::Decoding;
                    st.marks.push((e.cycle + dur, comp::DECODE_STALL));
                    devs.entry(e.device).or_default().decoding.push(e.seq);
                }
            }
            EventKind::Prefill { dur, .. } => {
                // Stacked whole-prompt job: members are the admissions
                // recorded at this cycle on this device.
                let dev = devs.entry(e.device).or_default();
                let end = e.cycle + dur;
                dev.last_batch_prefill = Some((e.cycle, end));
                let mut members = Vec::new();
                dev.admits.retain(|&(c, s)| {
                    if c == e.cycle {
                        members.push(s);
                        false
                    } else {
                        true
                    }
                });
                for s in members {
                    dev.decoding.push(s);
                    let st = seqs.entry(s).or_insert_with(SeqState::new);
                    st.intervals.push((e.cycle, end, comp::PREFILL_EXEC));
                    st.marks.push((end, comp::DECODE_STALL));
                    st.phase = Phase::Decoding;
                }
            }
            EventKind::DecodeTick { dur, .. } => {
                let dev = devs.entry(e.device).or_default();
                let end = e.cycle + dur;
                for &s in &dev.decoding {
                    if let Some(st) = seqs.get_mut(&s) {
                        st.intervals.push((e.cycle, end, comp::DECODE_EXEC));
                        st.marks.push((end, comp::DECODE_STALL));
                    }
                }
            }
            EventKind::Preempt => {
                let st = seqs.entry(e.seq).or_insert_with(SeqState::new);
                st.phase = Phase::Preempted;
                st.marks.push((e.cycle, comp::PREEMPT_STALL));
                devs.entry(e.device).or_default().drop_decoding(e.seq);
            }
            EventKind::MigrateOut { .. } => {
                let st = seqs.entry(e.seq).or_insert_with(SeqState::new);
                st.migrate_src = Some(e.cycle);
                devs.entry(e.device).or_default().drop_decoding(e.seq);
            }
            EventKind::MigrateIn { dur, .. } => {
                let st = seqs.entry(e.seq).or_insert_with(SeqState::new);
                let start = st.migrate_src.take().unwrap_or(e.cycle);
                let end = e.cycle + dur;
                st.intervals.push((start, end, comp::MIGRATION));
                let after = match st.phase {
                    Phase::Decoding => comp::DECODE_STALL,
                    Phase::Preempted => comp::PREEMPT_STALL,
                    Phase::Prefilling => comp::CHUNK_STALL,
                    Phase::Queued => comp::QUEUE_WAIT,
                };
                st.marks.push((end, after));
                if st.phase == Phase::Decoding {
                    devs.entry(e.device).or_default().decoding.push(e.seq);
                }
            }
            EventKind::HandoffOut { .. } => {
                let st = seqs.entry(e.seq).or_insert_with(SeqState::new);
                st.handoff_src = Some(e.cycle);
                devs.entry(e.device).or_default().drop_decoding(e.seq);
            }
            EventKind::HandoffIn { dur, .. } => {
                let st = seqs.entry(e.seq).or_insert_with(SeqState::new);
                let start = st.handoff_src.take().unwrap_or(e.cycle);
                let end = e.cycle + dur;
                st.intervals.push((start, end, comp::HANDOFF));
                let after = match st.phase {
                    Phase::Decoding => comp::DECODE_STALL,
                    Phase::Preempted => comp::PREEMPT_STALL,
                    Phase::Prefilling => comp::CHUNK_STALL,
                    Phase::Queued => comp::QUEUE_WAIT,
                };
                st.marks.push((end, after));
                if st.phase == Phase::Decoding {
                    devs.entry(e.device).or_default().decoding.push(e.seq);
                }
            }
            EventKind::Complete { latency } => {
                let mut st = seqs.remove(&e.seq).unwrap_or_else(SeqState::new);
                let dev = devs.entry(e.device).or_default();
                dev.drop_decoding(e.seq);
                // Encoder path: the serve whose span ends exactly at
                // this completion carried the request (Complete records
                // immediately follow their Serve in stream order).
                if let Some((s, end, model)) = dev.last_serve {
                    if end == e.cycle {
                        if let Some((hs, he)) = dev.serve_hold {
                            st.intervals.push((hs, he, comp::HOLD));
                        }
                        st.intervals.push((s, end, comp::PREFILL_EXEC));
                        st.model = model;
                    }
                }
                out.push(assemble(
                    e.seq,
                    st.model,
                    e.cycle,
                    *latency,
                    e.device,
                    st.intervals,
                    st.marks,
                ));
            }
            EventKind::Reject { .. }
            | EventKind::Drop
            | EventKind::Steal { .. }
            | EventKind::ChunkWait
            | EventKind::PrefixHit { .. }
            | EventKind::QueueDepth { .. }
            | EventKind::KvOccupancy { .. } => {}
        }
    }

    out.sort_by_key(|r| (r.completion, r.id));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: u64, device: usize, seq: u64, kind: EventKind) -> ObsEvent {
        ObsEvent { cycle, device, seq, kind }
    }

    #[test]
    fn encoder_batch_with_hold_decomposes_exactly() {
        // Request 1 arrives at 0, request 2 at 30; device holds the
        // partial batch from 10 to 50, serves [50, 110), both complete
        // at 110.
        let events = vec![
            ev(0, 0, 1, EventKind::Arrival { model: 2 }),
            ev(30, 0, 2, EventKind::Arrival { model: 2 }),
            ev(10, 0, NO_SEQ, EventKind::Hold { dur: 40 }),
            ev(50, 0, NO_SEQ, EventKind::Serve { model: 2, batch: 2, dur: 60 }),
            ev(110, 0, 1, EventKind::Complete { latency: 110 }),
            ev(110, 0, 2, EventKind::Complete { latency: 80 }),
        ];
        let anat = decompose(&events);
        assert_eq!(anat.len(), 2);
        let r1 = &anat[0];
        assert_eq!((r1.id, r1.model, r1.arrival, r1.latency), (1, 2, 0, 110));
        assert_eq!(r1.comps.sum(), 110);
        assert_eq!(r1.comps.0[comp::QUEUE_WAIT], 10);
        assert_eq!(r1.comps.0[comp::HOLD], 40);
        assert_eq!(r1.comps.0[comp::PREFILL_EXEC], 60);
        let r2 = &anat[1];
        assert_eq!(r2.comps.sum(), 80);
        // Hold clamps to r2's own arrival at 30: 50 − 30 = 20.
        assert_eq!(r2.comps.0[comp::QUEUE_WAIT], 0);
        assert_eq!(r2.comps.0[comp::HOLD], 20);
        assert_eq!(r2.comps.0[comp::PREFILL_EXEC], 60);
    }

    #[test]
    fn decode_lifecycle_with_preemption_decomposes_exactly() {
        // Admit at 10, stacked prefill [10, 40), ticks [40, 50) and
        // [55, 65), preempt at 65, resume + re-prefill [90, 100),
        // final tick [100, 110), complete at 110.
        let events = vec![
            ev(0, 1, 7, EventKind::Arrival { model: 0 }),
            ev(10, 1, 7, EventKind::KvAdmit { tokens: 8 }),
            ev(10, 1, NO_SEQ, EventKind::Prefill {
                model: 0,
                batch: 1,
                rows: 8,
                chunk: false,
                tokens: 1,
                dur: 30,
            }),
            ev(40, 1, NO_SEQ, EventKind::DecodeTick { batch: 1, dur: 10 }),
            ev(55, 1, NO_SEQ, EventKind::DecodeTick { batch: 1, dur: 10 }),
            ev(65, 1, 7, EventKind::Preempt),
            ev(90, 1, 7, EventKind::KvAdmit { tokens: 8 }),
            ev(90, 1, 7, EventKind::Resume),
            ev(90, 1, NO_SEQ, EventKind::Prefill {
                model: 0,
                batch: 1,
                rows: 8,
                chunk: false,
                tokens: 1,
                dur: 10,
            }),
            ev(100, 1, NO_SEQ, EventKind::DecodeTick { batch: 1, dur: 10 }),
            ev(110, 1, 7, EventKind::Complete { latency: 110 }),
        ];
        let anat = decompose(&events);
        assert_eq!(anat.len(), 1);
        let r = &anat[0];
        assert_eq!(r.comps.sum(), 110);
        assert_eq!(r.comps.0[comp::QUEUE_WAIT], 10);
        assert_eq!(r.comps.0[comp::PREFILL_EXEC], 40); // 30 + 10
        assert_eq!(r.comps.0[comp::DECODE_EXEC], 30); // 3 ticks
        assert_eq!(r.comps.0[comp::DECODE_STALL], 5); // 50..55
        assert_eq!(r.comps.0[comp::PREEMPT_STALL], 25); // 65..90
    }

    #[test]
    fn chunked_prefill_with_migration_decomposes_exactly() {
        // Chunks [5, 15) and [30, 40) with a chunk-stall between,
        // migration [40, 60), final chunk [60, 70), tick [70, 80).
        let events = vec![
            ev(0, 0, 3, EventKind::Arrival { model: 1 }),
            ev(5, 0, 3, EventKind::KvAdmit { tokens: 4 }),
            ev(5, 0, 3, EventKind::Prefill {
                model: 1,
                batch: 1,
                rows: 2,
                chunk: true,
                tokens: 0,
                dur: 10,
            }),
            ev(20, 0, 3, EventKind::ChunkWait),
            ev(30, 0, 3, EventKind::Prefill {
                model: 1,
                batch: 1,
                rows: 2,
                chunk: true,
                tokens: 0,
                dur: 10,
            }),
            ev(40, 0, 3, EventKind::MigrateOut { dst: 1, words: 128, dur: 12 }),
            ev(52, 1, 3, EventKind::MigrateIn { src: 0, words: 128, dur: 8 }),
            ev(60, 1, 3, EventKind::Prefill {
                model: 1,
                batch: 1,
                rows: 1,
                chunk: false,
                tokens: 1,
                dur: 10,
            }),
            ev(70, 1, NO_SEQ, EventKind::DecodeTick { batch: 1, dur: 10 }),
            ev(80, 1, 3, EventKind::Complete { latency: 80 }),
        ];
        let anat = decompose(&events);
        assert_eq!(anat.len(), 1);
        let r = &anat[0];
        assert_eq!(r.comps.sum(), 80);
        assert_eq!(r.device, 1);
        assert_eq!(r.comps.0[comp::QUEUE_WAIT], 5);
        assert_eq!(r.comps.0[comp::PREFILL_EXEC], 30);
        assert_eq!(r.comps.0[comp::CHUNK_STALL], 15); // 15..30
        assert_eq!(r.comps.0[comp::MIGRATION], 20); // 40..60
        assert_eq!(r.comps.0[comp::DECODE_EXEC], 10);
        assert_eq!(r.comps.0[comp::DECODE_STALL], 0);
    }

    #[test]
    fn disaggregated_handoff_decomposes_exactly() {
        // Prefill [10, 40) on device 0 (prefill-only), hand-off
        // [40, 60) to device 1, ticks [60, 70) and [75, 85) there.
        let events = vec![
            ev(0, 0, 4, EventKind::Arrival { model: 0 }),
            ev(10, 0, 4, EventKind::KvAdmit { tokens: 6 }),
            ev(10, 0, NO_SEQ, EventKind::Prefill {
                model: 0,
                batch: 1,
                rows: 6,
                chunk: false,
                tokens: 1,
                dur: 30,
            }),
            ev(40, 0, 4, EventKind::HandoffOut { dst: 1, words: 192, dur: 12 }),
            ev(52, 1, 4, EventKind::HandoffIn { src: 0, words: 192, dur: 8 }),
            ev(60, 1, NO_SEQ, EventKind::DecodeTick { batch: 1, dur: 10 }),
            ev(75, 1, NO_SEQ, EventKind::DecodeTick { batch: 1, dur: 10 }),
            ev(85, 1, 4, EventKind::Complete { latency: 85 }),
        ];
        let anat = decompose(&events);
        assert_eq!(anat.len(), 1);
        let r = &anat[0];
        assert_eq!(r.comps.sum(), 85);
        assert_eq!(r.device, 1);
        assert_eq!(r.comps.0[comp::QUEUE_WAIT], 10);
        assert_eq!(r.comps.0[comp::PREFILL_EXEC], 30);
        assert_eq!(r.comps.0[comp::HANDOFF], 20); // 40..60
        assert_eq!(r.comps.0[comp::DECODE_EXEC], 20);
        assert_eq!(r.comps.0[comp::DECODE_STALL], 5); // 70..75
        assert_eq!(r.comps.0[comp::MIGRATION], 0);
    }

    #[test]
    fn segments_partition_the_latency_range_contiguously() {
        let events = vec![
            ev(0, 0, 1, EventKind::Arrival { model: 0 }),
            ev(10, 0, NO_SEQ, EventKind::Serve { model: 0, batch: 1, dur: 20 }),
            ev(30, 0, 1, EventKind::Complete { latency: 30 }),
        ];
        let anat = decompose(&events);
        let r = &anat[0];
        assert_eq!(r.segments.first().unwrap().start, r.arrival);
        assert_eq!(r.segments.last().unwrap().end, r.completion);
        for pair in r.segments.windows(2) {
            assert_eq!(pair[0].end, pair[1].start, "segments must be contiguous");
        }
    }
}
