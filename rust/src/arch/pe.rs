//! Processing-element model (§III-B1).
//!
//! A PE is single-issue and fully pipelined: one instruction per cycle
//! when operands and output latches are available, otherwise it stalls
//! (and the stall reason is counted — the TAB4/FIG4 metrics come straight
//! from these counters).
//!
//! Datapath: a 16-entry word register file, 16 `i32` accumulators (one
//! 4×4 int8 output sub-tile in the GEMM mapping), a 4-lane packed int8
//! MAC, and a scalar int/fp32 ALU. Port reads may carry *riders* (latch
//! and/or forward) and MAC slots may carry a network *take* — the
//! switchless routing of §III-C compiled into the context.

use crate::interconnect::fabric::Fabric;
use crate::isa::{AluOp, Dir, Dst, PeInstr, PeProgram, Rider, Src, Take};
use crate::sim::stats::Stats;
use crate::util::quant::{dot4, f32_to_word, requant_shift, word_to_f32};

/// Word registers per PE.
pub const NUM_REGS: usize = 16;
/// Accumulators per PE (4×4 output sub-tile).
pub const NUM_ACCS: usize = 16;

/// Program phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Prologue,
    Body,
    TileEpilogue,
    Epilogue,
    Halted,
}

/// Why the PE could not issue this cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallKind {
    None,
    Operand,
    Output,
    LoadPending,
}

/// One processing element.
#[derive(Debug, Clone)]
pub struct Pe {
    /// Flat node id in the combined grid.
    pub node: usize,
    pub(crate) regs: [u32; NUM_REGS],
    pub(crate) accs: [i32; NUM_ACCS],
    /// Per-register scoreboard: cycle at which the register's pending
    /// load value becomes readable.
    reg_ready: [u64; NUM_REGS],
    program: PeProgram,
    phase: Phase,
    pc: usize,
    /// Body iteration within the current tile.
    iter: u32,
    /// Tile index.
    tile: u32,
    /// Last cycle's stall diagnosis (for tracing / FIG4).
    pub last_stall: StallKind,
}

impl Pe {
    /// Create a halted PE at a grid node.
    pub fn new(node: usize) -> Self {
        Self {
            node,
            regs: [0; NUM_REGS],
            accs: [0; NUM_ACCS],
            reg_ready: [0; NUM_REGS],
            program: PeProgram::idle(),
            phase: Phase::Halted,
            pc: 0,
            iter: 0,
            tile: 0,
            last_stall: StallKind::None,
        }
    }

    /// Load a program and reset execution state (context distribution).
    pub fn load_program(&mut self, program: PeProgram) {
        self.program = program;
        self.regs = [0; NUM_REGS];
        self.accs = [0; NUM_ACCS];
        self.reg_ready = [0; NUM_REGS];
        self.pc = 0;
        self.iter = 0;
        self.tile = 0;
        self.phase = Phase::Prologue;
        self.last_stall = StallKind::None;
        self.advance_phase_if_needed();
    }

    /// Is the PE done?
    pub fn halted(&self) -> bool {
        self.phase == Phase::Halted
    }

    /// Read an accumulator (tests / drain checks).
    pub fn acc(&self, i: usize) -> i32 {
        self.accs[i]
    }

    /// One-line execution-state summary (deadlock diagnosis).
    pub fn debug_state(&self) -> String {
        let instr = self.cur_slice().get(self.pc).map(|i| format!("{i:?}"));
        format!(
            "{:?} pc={} iter={} tile={} stall={:?} instr={}",
            self.phase,
            self.pc,
            self.iter,
            self.tile,
            self.last_stall,
            instr.unwrap_or_else(|| "-".into())
        )
    }

    fn cur_slice(&self) -> &[PeInstr] {
        match self.phase {
            Phase::Prologue => &self.program.prologue,
            Phase::Body => &self.program.body,
            Phase::TileEpilogue => &self.program.tile_epilogue,
            Phase::Epilogue => &self.program.epilogue,
            Phase::Halted => &[],
        }
    }

    /// Skip over empty phases / exhausted loops.
    fn advance_phase_if_needed(&mut self) {
        loop {
            match self.phase {
                Phase::Prologue => {
                    if self.pc < self.program.prologue.len() {
                        return;
                    }
                    self.phase = Phase::Body;
                    self.pc = 0;
                    self.iter = 0;
                    self.tile = 0;
                }
                Phase::Body => {
                    if self.tile >= self.program.tiles {
                        self.phase = Phase::Epilogue;
                        self.pc = 0;
                        continue;
                    }
                    if self.iter < self.program.trip && self.pc < self.program.body.len() {
                        return;
                    }
                    self.phase = Phase::TileEpilogue;
                    self.pc = 0;
                }
                Phase::TileEpilogue => {
                    if self.pc < self.program.tile_epilogue.len() {
                        return;
                    }
                    self.tile += 1;
                    self.iter = 0;
                    self.pc = 0;
                    self.phase = Phase::Body;
                }
                Phase::Epilogue => {
                    if self.pc < self.program.epilogue.len() {
                        return;
                    }
                    self.phase = Phase::Halted;
                }
                Phase::Halted => return,
            }
        }
    }

    fn step_pc(&mut self) {
        self.pc += 1;
        if self.phase == Phase::Body && self.pc >= self.program.body.len() {
            self.iter += 1;
            self.pc = 0;
        }
        self.advance_phase_if_needed();
    }

    /// Is `src` readable this cycle?
    fn src_ready(&self, src: Src, fabric: &Fabric, cycle: u64) -> Option<StallKind> {
        match src {
            Src::Reg(r) => {
                if self.reg_ready[r as usize] > cycle {
                    Some(StallKind::LoadPending)
                } else {
                    None
                }
            }
            Src::Port(d) => {
                if fabric.port_ready(self.node, d) {
                    None
                } else {
                    Some(StallKind::Operand)
                }
            }
            Src::Imm(_) => None,
        }
    }

    /// Read `src` (consuming a port word), applying the rider.
    fn read_src(
        &mut self,
        src: Src,
        rider: Rider,
        fabric: &mut Fabric,
        cycle: u64,
        stats: &mut Stats,
    ) -> u32 {
        match src {
            Src::Reg(r) => {
                stats.pe_reg_reads += 1;
                self.regs[r as usize]
            }
            Src::Imm(v) => v as i32 as u32,
            Src::Port(d) => {
                let w = fabric.port_take(self.node, d).expect("checked by src_ready");
                if let Some(r) = rider.latch {
                    self.regs[r as usize] = w;
                    stats.pe_reg_writes += 1;
                }
                if let Some(fd) = rider.fwd {
                    let ok = fabric.send(self.node, fd, w, cycle, stats);
                    debug_assert!(ok, "rider fwd checked in outputs_ready");
                }
                w
            }
        }
    }

    fn exec_take(&mut self, take: &Take, fabric: &mut Fabric, cycle: u64, stats: &mut Stats) {
        let w = fabric.port_take(self.node, take.port).expect("checked before issue");
        if let Some(r) = take.latch {
            self.regs[r as usize] = w;
            stats.pe_reg_writes += 1;
        }
        if let Some(fd) = take.fwd {
            let ok = fabric.send(self.node, fd, w, cycle, stats);
            debug_assert!(ok, "take fwd checked before issue");
        }
    }

    /// All output latches this instruction needs, including riders/takes.
    fn out_dirs(ins: &PeInstr, dirs: &mut Vec<Dir>) {
        dirs.clear();
        let mut push_rider = |r: &Rider, dirs: &mut Vec<Dir>| {
            if let Some(d) = r.fwd {
                dirs.push(d);
            }
        };
        match ins {
            PeInstr::MacP { ra, rb, take, .. } => {
                push_rider(ra, dirs);
                push_rider(rb, dirs);
                if let Some(t) = take {
                    if let Some(d) = t.fwd {
                        dirs.push(d);
                    }
                }
            }
            PeInstr::Alu { dst, ra, rb, .. } => {
                push_rider(ra, dirs);
                push_rider(rb, dirs);
                if let Dst::Port(d) = dst {
                    dirs.push(*d);
                }
            }
            PeInstr::Mov { dst, ra, .. } => {
                push_rider(ra, dirs);
                if let Dst::Port(d) = dst {
                    dirs.push(*d);
                }
            }
            PeInstr::AccOut { dst, .. } | PeInstr::AccOutQ { dst, .. } => {
                if let Dst::Port(d) = dst {
                    dirs.push(*d);
                }
            }
            _ => {}
        }
    }

    fn write_dst(
        &mut self,
        dst: Dst,
        value: u32,
        fabric: &mut Fabric,
        cycle: u64,
        stats: &mut Stats,
    ) {
        match dst {
            Dst::Reg(r) => {
                self.regs[r as usize] = value;
                stats.pe_reg_writes += 1;
            }
            Dst::Port(d) => {
                let ok = fabric.send(self.node, d, value, cycle, stats);
                debug_assert!(ok, "dst port checked in outputs_ready");
            }
            Dst::Null => {}
        }
    }

    /// Execute one cycle. Returns `true` if an instruction issued.
    pub fn tick(
        &mut self,
        fabric: &mut Fabric,
        mem: &mut crate::arch::mem::MemSystem,
        cycle: u64,
        stats: &mut Stats,
    ) -> bool {
        if self.halted() {
            stats.pe_halted_cycles += 1;
            self.last_stall = StallKind::None;
            return false;
        }
        let ins = self.cur_slice()[self.pc];

        // ---- readiness checks (no side effects) ----
        let srcs: [(Option<Src>, Rider); 2] = match ins {
            PeInstr::MacP { a, ra, b, rb, .. } => [(Some(a), ra), (Some(b), rb)],
            PeInstr::Alu { a, ra, b, rb, .. } => [(Some(a), ra), (Some(b), rb)],
            PeInstr::Mov { a, ra, .. } => [(Some(a), ra), (None, Rider::NONE)],
            PeInstr::LoadW { addr_reg, .. } => {
                [(Some(Src::Reg(addr_reg)), Rider::NONE), (None, Rider::NONE)]
            }
            PeInstr::StoreW { src, addr_reg, .. } => {
                [(Some(Src::Reg(src)), Rider::NONE), (Some(Src::Reg(addr_reg)), Rider::NONE)]
            }
            _ => [(None, Rider::NONE), (None, Rider::NONE)],
        };
        for (s, _) in srcs.iter() {
            if let Some(s) = s {
                if let Some(kind) = self.src_ready(*s, fabric, cycle) {
                    match kind {
                        StallKind::Operand => stats.pe_stall_operand += 1,
                        StallKind::LoadPending => stats.pe_stall_load += 1,
                        _ => {}
                    }
                    self.last_stall = kind;
                    return false;
                }
            }
        }
        // Take rider: word must be present.
        if let PeInstr::MacP { take: Some(t), .. } = &ins {
            if !fabric.port_ready(self.node, t.port) {
                stats.pe_stall_operand += 1;
                self.last_stall = StallKind::Operand;
                return false;
            }
        }
        let mut dirs = Vec::with_capacity(3);
        Self::out_dirs(&ins, &mut dirs);
        for d in &dirs {
            if !fabric.can_send(self.node, *d, cycle) {
                stats.pe_stall_output += 1;
                self.last_stall = StallKind::Output;
                return false;
            }
        }
        self.last_stall = StallKind::None;

        // ---- execute ----
        match ins {
            PeInstr::Nop => {
                stats.pe_nop += 1;
            }
            PeInstr::MacP { d, a, ra, b, rb, take } => {
                let av = self.read_src(a, ra, fabric, cycle, stats);
                let bv = self.read_src(b, rb, fabric, cycle, stats);
                self.accs[d as usize] = self.accs[d as usize].wrapping_add(dot4(av, bv));
                if let Some(t) = take {
                    self.exec_take(&t, fabric, cycle, stats);
                }
                stats.pe_macp += 1;
                stats.pe_acc_access += 1;
            }
            PeInstr::Alu { op, dst, a, ra, b, rb } => {
                let av = self.read_src(a, ra, fabric, cycle, stats);
                let bv = self.read_src(b, rb, fabric, cycle, stats);
                let r = alu_exec(op, av, bv);
                self.write_dst(dst, r, fabric, cycle, stats);
                stats.pe_alu += 1;
            }
            PeInstr::Mov { dst, a, ra } => {
                let av = self.read_src(a, ra, fabric, cycle, stats);
                self.write_dst(dst, av, fabric, cycle, stats);
                stats.pe_mov += 1;
            }
            PeInstr::AccClr { d } => {
                self.accs[d as usize] = 0;
                stats.pe_acc_access += 1;
            }
            PeInstr::AccOut { d, dst, clear } => {
                let v = self.accs[d as usize] as u32;
                if clear {
                    self.accs[d as usize] = 0;
                }
                self.write_dst(dst, v, fabric, cycle, stats);
                stats.pe_acc_access += 1;
            }
            PeInstr::AccOutQ { d, shift, dst, clear } => {
                let base = d as usize;
                let mut bytes = [0u8; 4];
                for (i, b) in bytes.iter_mut().enumerate() {
                    *b = requant_shift(self.accs[base + i], shift) as u8;
                    if clear {
                        self.accs[base + i] = 0;
                    }
                }
                self.write_dst(dst, u32::from_le_bytes(bytes), fabric, cycle, stats);
                stats.pe_acc_access += 4;
            }
            PeInstr::LoadW { dst, space, addr_reg, post_inc } => {
                let addr = self.regs[addr_reg as usize];
                let (value, ready) = mem.read(space, addr, cycle, stats);
                self.regs[dst as usize] = value;
                self.reg_ready[dst as usize] = ready;
                self.regs[addr_reg as usize] = (addr as i64 + post_inc as i64) as u32;
                stats.pe_loads += 1;
                stats.pe_reg_reads += 1;
                stats.pe_reg_writes += 2;
            }
            PeInstr::StoreW { src, space, addr_reg, post_inc } => {
                let addr = self.regs[addr_reg as usize];
                mem.write(space, addr, self.regs[src as usize], cycle, stats);
                self.regs[addr_reg as usize] = (addr as i64 + post_inc as i64) as u32;
                stats.pe_loads += 1; // direct memory op (ablation metric)
                stats.pe_reg_reads += 2;
                stats.pe_reg_writes += 1;
            }
            PeInstr::Halt => {
                self.phase = Phase::Halted;
                return true;
            }
        }
        self.step_pc();
        true
    }
}

/// Scalar ALU semantics. Integer ops wrap; float ops are IEEE-754 on the
/// word's bits.
fn alu_exec(op: AluOp, a: u32, b: u32) -> u32 {
    let (ai, bi) = (a as i32, b as i32);
    match op {
        AluOp::AddI => ai.wrapping_add(bi) as u32,
        AluOp::SubI => ai.wrapping_sub(bi) as u32,
        AluOp::MulI => ai.wrapping_mul(bi) as u32,
        AluOp::MaxI => ai.max(bi) as u32,
        AluOp::MinI => ai.min(bi) as u32,
        AluOp::ShrI => (ai >> (bi & 31)) as u32,
        AluOp::AndI => a & b,
        AluOp::OrI => a | b,
        AluOp::XorI => a ^ b,
        AluOp::AddF => f32_to_word(word_to_f32(a) + word_to_f32(b)),
        AluOp::SubF => f32_to_word(word_to_f32(a) - word_to_f32(b)),
        AluOp::MulF => f32_to_word(word_to_f32(a) * word_to_f32(b)),
        AluOp::MaxF => f32_to_word(word_to_f32(a).max(word_to_f32(b))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::mem::{MemParams, MemSystem};
    use crate::interconnect::fabric::FabricKind;
    use crate::interconnect::topology::Topology;
    use crate::isa::MemSpace;
    use crate::util::quant::pack4;

    fn rig() -> (Fabric, MemSystem, Stats) {
        (
            Fabric::new(FabricKind::Torus, Topology::default(), 0),
            MemSystem::new(MemParams::default(), 1024),
            Stats::default(),
        )
    }

    fn run_alone(
        pe: &mut Pe,
        fabric: &mut Fabric,
        mem: &mut MemSystem,
        stats: &mut Stats,
        max: u64,
    ) {
        let mut cycle = 0;
        while !pe.halted() && cycle < max {
            pe.tick(fabric, mem, cycle, stats);
            fabric.commit(cycle, stats);
            cycle += 1;
        }
        assert!(pe.halted(), "PE did not halt within {max} cycles");
    }

    fn single_tile(body: Vec<PeInstr>, trip: u32) -> PeProgram {
        PeProgram {
            prologue: vec![],
            body,
            trip,
            tile_epilogue: vec![],
            tiles: 1,
            epilogue: vec![],
        }
    }

    #[test]
    fn macp_from_registers() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let mut pe = Pe::new(t.pe(0, 0));
        pe.load_program(single_tile(
            vec![PeInstr::MacP {
                d: 0,
                a: Src::Reg(0),
                ra: Rider::NONE,
                b: Src::Reg(0),
                rb: Rider::NONE,
                take: None,
            }],
            3,
        ));
        pe.regs[0] = pack4([2, 3, 4, 5]);
        run_alone(&mut pe, &mut f, &mut m, &mut s, 100);
        // dot4(x,x) = 4+9+16+25 = 54, three iterations.
        assert_eq!(pe.acc(0), 3 * 54);
        assert_eq!(s.pe_macp, 3);
    }

    #[test]
    fn take_rider_latches_and_forwards() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let node = t.pe(1, 1);
        let mut pe = Pe::new(node);
        pe.load_program(single_tile(
            vec![PeInstr::MacP {
                d: 0,
                a: Src::Reg(0),
                ra: Rider::NONE,
                b: Src::Reg(1),
                rb: Rider::NONE,
                take: Some(Take { port: Dir::East, latch: Some(5), fwd: Some(Dir::West) }),
            }],
            1,
        ));
        // Put a word in the east in-port.
        let east = t.node_id(t.neighbor(t.coord(node), Dir::East));
        f.send(east, Dir::West, 0xBEEF, 0, &mut s);
        f.commit(0, &mut s);
        assert!(pe.tick(&mut f, &mut m, 1, &mut s));
        assert_eq!(pe.regs[5], 0xBEEF);
        f.commit(1, &mut s);
        let west = t.node_id(t.neighbor(t.coord(node), Dir::West));
        assert_eq!(f.port_take(west, Dir::East), Some(0xBEEF));
    }

    #[test]
    fn take_missing_word_stalls() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let mut pe = Pe::new(t.pe(0, 0));
        pe.load_program(single_tile(
            vec![PeInstr::MacP {
                d: 0,
                a: Src::Reg(0),
                ra: Rider::NONE,
                b: Src::Reg(1),
                rb: Rider::NONE,
                take: Some(Take::latch(Dir::East, 2)),
            }],
            1,
        ));
        assert!(!pe.tick(&mut f, &mut m, 0, &mut s));
        assert_eq!(pe.last_stall, StallKind::Operand);
    }

    #[test]
    fn tile_loop_runs_body_then_epilogue_per_tile() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let mut pe = Pe::new(t.pe(0, 0));
        pe.load_program(PeProgram {
            prologue: vec![],
            body: vec![PeInstr::MacP {
                d: 0,
                a: Src::Reg(0),
                ra: Rider::NONE,
                b: Src::Reg(0),
                rb: Rider::NONE,
                take: None,
            }],
            trip: 2,
            tile_epilogue: vec![PeInstr::AccOut { d: 0, dst: Dst::Reg(7), clear: true }],
            tiles: 3,
            epilogue: vec![PeInstr::Halt],
        });
        pe.regs[0] = pack4([1, 1, 1, 1]); // dot4 = 4 per MAC
        run_alone(&mut pe, &mut f, &mut m, &mut s, 100);
        assert_eq!(s.pe_macp, 6, "2 MACs × 3 tiles");
        // Each tile drained 2 MACs × 4 = 8 and cleared.
        assert_eq!(pe.regs[7], 8);
        assert_eq!(pe.acc(0), 0, "cleared by AccOut");
    }

    #[test]
    fn stalls_on_missing_operand() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let mut pe = Pe::new(t.pe(0, 0));
        pe.load_program(single_tile(
            vec![PeInstr::Mov { dst: Dst::Null, a: Src::Port(Dir::North), ra: Rider::NONE }],
            1,
        ));
        assert!(!pe.tick(&mut f, &mut m, 0, &mut s));
        assert_eq!(s.pe_stall_operand, 1);
        assert_eq!(pe.last_stall, StallKind::Operand);
        assert!(!pe.halted());
    }

    #[test]
    fn stalls_on_full_output() {
        let (_, mut m, mut s) = rig();
        let t = Topology::default();
        // Depth-1 FIFO so the second send saturates the downstream port.
        let mut f = Fabric::with_fifo(FabricKind::Torus, t, 0, 1);
        let node = t.pe(0, 0);
        let mut pe = Pe::new(node);
        pe.load_program(single_tile(
            vec![PeInstr::AccOut { d: 0, dst: Dst::Port(Dir::East), clear: false }],
            3,
        ));
        assert!(pe.tick(&mut f, &mut m, 0, &mut s));
        f.commit(0, &mut s);
        assert!(pe.tick(&mut f, &mut m, 1, &mut s));
        f.commit(1, &mut s);
        // Neighbour latch and staging both full now.
        assert!(!pe.tick(&mut f, &mut m, 2, &mut s));
        assert_eq!(pe.last_stall, StallKind::Output);
        assert!(s.pe_stall_output >= 1);
    }

    #[test]
    fn accoutq_packs_saturates_and_clears() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let mut pe = Pe::new(t.pe(0, 0));
        pe.load_program(PeProgram {
            prologue: vec![],
            body: vec![],
            trip: 0,
            tile_epilogue: vec![],
            tiles: 0,
            epilogue: vec![
                PeInstr::AccOutQ { d: 0, shift: 0, dst: Dst::Reg(5), clear: true },
                PeInstr::Halt,
            ],
        });
        pe.accs[0] = 1000;
        pe.accs[1] = -1000;
        pe.accs[2] = 5;
        pe.accs[3] = -5;
        run_alone(&mut pe, &mut f, &mut m, &mut s, 10);
        let bytes = pe.regs[5].to_le_bytes();
        assert_eq!(bytes[0] as i8, 127);
        assert_eq!(bytes[1] as i8, -128);
        assert_eq!(bytes[2] as i8, 5);
        assert_eq!(bytes[3] as i8, -5);
        assert_eq!(pe.acc(0), 0);
        assert_eq!(pe.acc(3), 0);
    }

    #[test]
    fn loadw_scoreboard_stalls_consumer() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let mut pe = Pe::new(t.pe(0, 0));
        {
            let mut s2 = Stats::default();
            m.write(MemSpace::L1, 3, 99, 0, &mut s2);
            m.reset_timing();
        }
        pe.load_program(PeProgram {
            prologue: vec![
                PeInstr::Alu {
                    op: AluOp::AddI,
                    dst: Dst::Reg(0),
                    a: Src::Imm(3),
                    ra: Rider::NONE,
                    b: Src::Imm(0),
                    rb: Rider::NONE,
                },
                PeInstr::LoadW { dst: 1, space: MemSpace::L1, addr_reg: 0, post_inc: 1 },
                PeInstr::Alu {
                    op: AluOp::AddI,
                    dst: Dst::Reg(2),
                    a: Src::Reg(1),
                    ra: Rider::NONE,
                    b: Src::Imm(1),
                    rb: Rider::NONE,
                },
            ],
            body: vec![],
            trip: 0,
            tile_epilogue: vec![],
            tiles: 0,
            epilogue: vec![PeInstr::Halt],
        });
        run_alone(&mut pe, &mut f, &mut m, &mut s, 50);
        assert_eq!(pe.regs[2], 100);
        assert_eq!(pe.regs[0], 4, "post-increment applied");
        assert!(s.pe_stall_load >= 1, "consumer must stall on L1 latency");
    }

    #[test]
    fn storew_writes_memory() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let mut pe = Pe::new(t.pe(0, 0));
        pe.load_program(PeProgram {
            prologue: vec![
                PeInstr::Alu {
                    op: AluOp::AddI,
                    dst: Dst::Reg(0),
                    a: Src::Imm(20),
                    ra: Rider::NONE,
                    b: Src::Imm(0),
                    rb: Rider::NONE,
                },
                PeInstr::Alu {
                    op: AluOp::AddI,
                    dst: Dst::Reg(1),
                    a: Src::Imm(1234),
                    ra: Rider::NONE,
                    b: Src::Imm(0),
                    rb: Rider::NONE,
                },
                PeInstr::StoreW { src: 1, space: MemSpace::L1, addr_reg: 0, post_inc: 2 },
            ],
            body: vec![],
            trip: 0,
            tile_epilogue: vec![],
            tiles: 0,
            epilogue: vec![PeInstr::Halt],
        });
        run_alone(&mut pe, &mut f, &mut m, &mut s, 20);
        assert_eq!(m.host_read_l1(20, 1), vec![1234]);
        assert_eq!(pe.regs[0], 22);
    }

    #[test]
    fn trip_zero_body_skipped() {
        let (mut f, mut m, mut s) = rig();
        let t = Topology::default();
        let mut pe = Pe::new(t.pe(0, 0));
        pe.load_program(PeProgram {
            prologue: vec![],
            body: vec![PeInstr::MacP {
                d: 0,
                a: Src::Reg(0),
                ra: Rider::NONE,
                b: Src::Reg(0),
                rb: Rider::NONE,
                take: None,
            }],
            trip: 0,
            tile_epilogue: vec![],
            tiles: 1,
            epilogue: vec![PeInstr::Halt],
        });
        run_alone(&mut pe, &mut f, &mut m, &mut s, 10);
        assert_eq!(s.pe_macp, 0);
    }

    #[test]
    fn alu_float_ops() {
        assert_eq!(word_to_f32(alu_exec(AluOp::AddF, f32_to_word(1.5), f32_to_word(2.25))), 3.75);
        assert_eq!(word_to_f32(alu_exec(AluOp::MulF, f32_to_word(-2.0), f32_to_word(4.0))), -8.0);
        assert_eq!(word_to_f32(alu_exec(AluOp::MaxF, f32_to_word(-2.0), f32_to_word(4.0))), 4.0);
    }

    #[test]
    fn alu_int_ops_wrap() {
        assert_eq!(alu_exec(AluOp::AddI, i32::MAX as u32, 1) as i32, i32::MIN);
        assert_eq!(alu_exec(AluOp::ShrI, (-8i32) as u32, 1) as i32, -4);
        assert_eq!(alu_exec(AluOp::MinI, (-3i32) as u32, 2) as i32, -3);
    }

    #[test]
    fn empty_program_halts_immediately() {
        let mut pe = Pe::new(0);
        pe.load_program(PeProgram::idle());
        assert!(pe.halted());
    }
}
