//! Context memory + memory controller (§III-A, Fig. 1).
//!
//! The CGRA subsystem holds a 4 KiB context memory; the memory controller
//! "retrieves and interprets configuration data from the Context Memory,
//! distributing instructions across each PE and MOB". We model that as a
//! capacity check (kernels whose encoded context exceeds the budget are
//! rejected — a *real* constraint the GEMM mapper designs against) plus a
//! configuration-time cost proportional to the context size.

use crate::isa::{encode::encode_context, KernelContext};
use crate::sim::stats::Stats;
use anyhow::{bail, Result};

/// Default context-memory capacity (the paper's 4 KiB).
pub const DEFAULT_CTX_BYTES: usize = 4096;

/// Context memory + distribution engine.
#[derive(Debug, Clone)]
pub struct ContextMemory {
    /// Capacity in bytes.
    pub capacity: usize,
    /// Decode/distribution bandwidth in bytes per cycle (the controller
    /// reads the context stream and shifts it into the array's
    /// configuration chains).
    pub decode_bw: usize,
    /// Encoded bytes of the currently-loaded kernel.
    loaded_bytes: usize,
}

impl ContextMemory {
    /// Context memory with the paper's 4 KiB capacity.
    pub fn new() -> Self {
        Self { capacity: DEFAULT_CTX_BYTES, decode_bw: 4, loaded_bytes: 0 }
    }

    /// Custom capacity (array-scaling studies, FIG5).
    pub fn with_capacity(capacity: usize) -> Self {
        Self { capacity, ..Self::new() }
    }

    /// Validate and "load" a kernel context. Returns the configuration
    /// time in cycles and accounts the decoded bytes.
    pub fn load(&mut self, ctx: &KernelContext, stats: &mut Stats) -> Result<u64> {
        let bytes = encode_context(ctx).len();
        if bytes > self.capacity {
            bail!(
                "kernel '{}' context is {bytes} B, exceeds the {} B context memory",
                ctx.name,
                self.capacity
            );
        }
        self.loaded_bytes = bytes;
        stats.ctx_bytes += bytes as u64;
        stats.kernels += 1;
        let cycles = (bytes as u64).div_ceil(self.decode_bw as u64);
        stats.config_cycles += cycles;
        Ok(cycles)
    }

    /// Bytes of the currently loaded context.
    pub fn loaded_bytes(&self) -> usize {
        self.loaded_bytes
    }
}

impl Default for ContextMemory {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{PeInstr, PeProgram};

    fn ctx_with_instrs(n: usize) -> KernelContext {
        KernelContext {
            pe_programs: vec![PeProgram {
                prologue: vec![],
                body: vec![PeInstr::Nop; n],
                trip: 1,
                tile_epilogue: vec![],
                tiles: 1,
                epilogue: vec![],
            }],
            mob_programs: vec![],
            name: "t".into(),
        }
    }

    #[test]
    fn small_context_loads() {
        let mut cm = ContextMemory::new();
        let mut s = Stats::default();
        let cycles = cm.load(&ctx_with_instrs(10), &mut s).unwrap();
        assert!(cycles > 0);
        assert_eq!(s.kernels, 1);
        assert!(s.ctx_bytes > 0);
        assert!(cm.loaded_bytes() > 0);
    }

    #[test]
    fn oversized_context_rejected() {
        let mut cm = ContextMemory::new();
        let mut s = Stats::default();
        // 4 KiB / 6 B per instr ≈ 682 instructions; 800 must overflow.
        let err = cm.load(&ctx_with_instrs(800), &mut s).unwrap_err();
        assert!(err.to_string().contains("exceeds"));
        assert_eq!(s.kernels, 0);
    }

    #[test]
    fn config_time_scales_with_size() {
        let mut cm = ContextMemory::new();
        let mut s = Stats::default();
        let c1 = cm.load(&ctx_with_instrs(10), &mut s).unwrap();
        let c2 = cm.load(&ctx_with_instrs(100), &mut s).unwrap();
        assert!(c2 > c1);
    }

    #[test]
    fn paper_capacity_is_default() {
        assert_eq!(ContextMemory::new().capacity, 4096);
    }
}
