//! Structural component models of the CGRA integrated system (Fig. 1 +
//! Fig. 2): processing elements, memory-operation blocks, the shared
//! L1 / external-memory hierarchy, and the context memory + memory
//! controller that configure the array before each kernel launch.

pub mod context;
pub mod mem;
pub mod mob;
pub mod pe;

pub use context::ContextMemory;
pub use mem::MemSystem;
pub use mob::Mob;
pub use pe::Pe;
