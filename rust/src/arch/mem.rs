//! Memory hierarchy: shared L1 scratchpad + external memory + DMA engine.
//!
//! Functional state and timing are decoupled: data moves at *issue* time
//! (so numerics are exact and simple), while the timing model hands back a
//! `ready_at` cycle from per-bank / per-channel reservation calendars.
//! Generated programs separate produce/consume with fences, so
//! functional-at-issue never observes a stale value (DESIGN.md §5.2).
//!
//! - **L1**: software-managed scratchpad (the "shared L1 memory" of
//!   Fig. 1), banked word-interleaved, fixed access latency, one access
//!   per bank per cycle.
//! - **External memory**: single channel, `ext_bw` words/cycle peak,
//!   `ext_latency` cycles. This is the expensive boundary TAB2 counts.
//! - **DMA engine**: bulk Ext↔L1 staging used by the block-wise GEMM plan
//!   to realize the paper's data-reuse claim.

use crate::isa::MemSpace;
use crate::sim::stats::Stats;
use anyhow::{bail, Result};
use std::collections::VecDeque;

/// Timing + functional parameters of the memory system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemParams {
    /// L1 capacity in 32-bit words.
    pub l1_words: usize,
    /// Number of L1 banks (word-interleaved).
    pub l1_banks: usize,
    /// L1 access latency in cycles.
    pub l1_latency: u64,
    /// External memory latency in cycles.
    pub ext_latency: u64,
    /// External memory peak bandwidth in words/cycle.
    pub ext_bw: u64,
    /// DMA engine bandwidth in words/cycle (additionally bounded by
    /// `ext_bw` since DMA crosses the external boundary).
    pub dma_bw: u64,
}

impl Default for MemParams {
    fn default() -> Self {
        Self {
            l1_words: 32 * 1024 / 4, // 32 KiB
            l1_banks: 8,
            l1_latency: 2,
            ext_latency: 20,
            ext_bw: 4,
            dma_bw: 4,
        }
    }
}

/// An in-flight DMA job.
#[derive(Debug, Clone, Copy)]
struct DmaJob {
    words_left: u64,
    /// Cycle the whole job completes (data already moved functionally).
    done_at: u64,
}

/// The memory system: functional arrays + reservation calendars.
#[derive(Debug, Clone)]
pub struct MemSystem {
    pub params: MemParams,
    l1: Vec<u32>,
    ext: Vec<u32>,
    /// Per-bank next-free cycle.
    bank_next: Vec<u64>,
    /// External channel next-free slot, in units of (1/ext_bw) cycles.
    ext_next_slot: u64,
    /// Queue of DMA jobs (served in order, one at a time).
    dma_jobs: VecDeque<DmaJob>,
    /// Cycle the DMA engine frees up.
    dma_free_at: u64,
    /// Same-cycle L1 read-coalescing memo: multiple units reading the
    /// same word in the same cycle share one bank access (a read-multicast
    /// port — this is how the row MOBs all stream the shared B panel
    /// without serializing on a bank; DESIGN.md §5.2).
    coalesce_cycle: u64,
    coalesce: Vec<(u32, u64)>,
}

impl MemSystem {
    /// Build with `ext_words` of external memory.
    pub fn new(params: MemParams, ext_words: usize) -> Self {
        Self {
            params,
            l1: vec![0; params.l1_words],
            ext: vec![0; ext_words],
            bank_next: vec![0; params.l1_banks],
            ext_next_slot: 0,
            dma_jobs: VecDeque::new(),
            dma_free_at: 0,
            coalesce_cycle: u64::MAX,
            coalesce: Vec::new(),
        }
    }

    // ---------- host (testbench / coordinator) functional access ----------

    /// Host write into external memory (grows it if needed). Host access
    /// happens between kernels and is not timed.
    pub fn host_write_ext(&mut self, addr: u32, data: &[u32]) {
        let end = addr as usize + data.len();
        if end > self.ext.len() {
            self.ext.resize(end, 0);
        }
        self.ext[addr as usize..end].copy_from_slice(data);
    }

    /// Host read from external memory.
    pub fn host_read_ext(&self, addr: u32, len: usize) -> Vec<u32> {
        let end = addr as usize + len;
        assert!(end <= self.ext.len(), "host read past end of ext memory");
        self.ext[addr as usize..end].to_vec()
    }

    /// Host write into the L1 scratchpad (untimed; used by tests and the
    /// TAB4 ablation where both variants start from pre-staged panels).
    pub fn host_write_l1(&mut self, addr: u32, data: &[u32]) {
        let end = addr as usize + data.len();
        assert!(end <= self.l1.len(), "host write past end of L1");
        self.l1[addr as usize..end].copy_from_slice(data);
    }

    /// Host read from the L1 scratchpad (used by tests).
    pub fn host_read_l1(&self, addr: u32, len: usize) -> Vec<u32> {
        let end = addr as usize + len;
        assert!(end <= self.l1.len(), "host read past end of L1");
        self.l1[addr as usize..end].to_vec()
    }

    /// External memory size in words.
    pub fn ext_len(&self) -> usize {
        self.ext.len()
    }

    // ---------- timed word access (MOB streams, PE direct loads) ----------

    /// Timed word read: returns `(value, ready_at)`.
    pub fn read(
        &mut self,
        space: MemSpace,
        addr: u32,
        cycle: u64,
        stats: &mut Stats,
    ) -> (u32, u64) {
        match space {
            MemSpace::L1 => {
                let a = addr as usize;
                assert!(a < self.l1.len(), "L1 read OOB: {addr:#x}");
                // Same-cycle same-address reads coalesce into one bank
                // access (read multicast).
                if self.coalesce_cycle != cycle {
                    self.coalesce_cycle = cycle;
                    self.coalesce.clear();
                }
                if let Some(&(_, ready)) = self.coalesce.iter().find(|&&(ca, _)| ca == addr) {
                    return (self.l1[a], ready);
                }
                let ready = self.l1_slot(a, cycle, stats);
                self.coalesce.push((addr, ready));
                stats.l1_reads += 1;
                (self.l1[a], ready)
            }
            MemSpace::Ext => {
                let a = addr as usize;
                assert!(a < self.ext.len(), "ext read OOB: {addr:#x}");
                let ready = self.ext_slot(cycle, stats);
                stats.ext_reads += 1;
                (self.ext[a], ready)
            }
        }
    }

    /// Timed word write: returns the cycle the write retires.
    pub fn write(
        &mut self,
        space: MemSpace,
        addr: u32,
        value: u32,
        cycle: u64,
        stats: &mut Stats,
    ) -> u64 {
        match space {
            MemSpace::L1 => {
                let a = addr as usize;
                assert!(a < self.l1.len(), "L1 write OOB: {addr:#x}");
                let ready = self.l1_slot(a, cycle, stats);
                self.l1[a] = value;
                stats.l1_writes += 1;
                ready
            }
            MemSpace::Ext => {
                let a = addr as usize;
                if a >= self.ext.len() {
                    self.ext.resize(a + 1, 0);
                }
                let ready = self.ext_slot(cycle, stats);
                self.ext[a] = value;
                stats.ext_writes += 1;
                ready
            }
        }
    }

    fn l1_slot(&mut self, addr: usize, cycle: u64, stats: &mut Stats) -> u64 {
        let bank = addr % self.params.l1_banks;
        let slot = self.bank_next[bank].max(cycle);
        if slot > cycle {
            stats.l1_bank_conflicts += slot - cycle;
        }
        self.bank_next[bank] = slot + 1;
        slot + self.params.l1_latency
    }

    fn ext_slot(&mut self, cycle: u64, stats: &mut Stats) -> u64 {
        let bw = self.params.ext_bw;
        let slot = self.ext_next_slot.max(cycle * bw);
        if slot > cycle * bw {
            stats.ext_queue_cycles += slot / bw - cycle;
        }
        self.ext_next_slot = slot + 1;
        slot / bw + self.params.ext_latency
    }

    // ---------- DMA ----------

    /// Enqueue a bulk copy. Data moves functionally *now*; the returned
    /// cycle is when the transfer completes architecturally.
    pub fn dma(
        &mut self,
        ext_base: u32,
        l1_base: u32,
        count: u32,
        to_l1: bool,
        cycle: u64,
        stats: &mut Stats,
    ) -> Result<u64> {
        let (eb, lb, n) = (ext_base as usize, l1_base as usize, count as usize);
        if lb + n > self.l1.len() {
            bail!("DMA overruns L1: base {lb} + {n} > {}", self.l1.len());
        }
        if to_l1 {
            if eb + n > self.ext.len() {
                bail!("DMA reads past end of ext memory");
            }
            self.l1[lb..lb + n].copy_from_slice(&self.ext[eb..eb + n]);
        } else {
            if eb + n > self.ext.len() {
                self.ext.resize(eb + n, 0);
            }
            self.ext[eb..eb + n].copy_from_slice(&self.l1[lb..lb + n]);
        }
        // Timing: serialized on the DMA engine, bounded by min(dma_bw, ext_bw).
        let bw = self.params.dma_bw.min(self.params.ext_bw).max(1);
        let start = self.dma_free_at.max(cycle);
        let done = start + (count as u64).div_ceil(bw) + self.params.ext_latency;
        self.dma_free_at = done;
        self.dma_jobs.push_back(DmaJob { words_left: count as u64, done_at: done });
        // Boundary + scratchpad traffic accounting.
        if to_l1 {
            stats.ext_reads += count as u64;
            stats.l1_writes += count as u64;
        } else {
            stats.l1_reads += count as u64;
            stats.ext_writes += count as u64;
        }
        stats.dma_words += count as u64;
        Ok(done)
    }

    /// Is any DMA job still in flight at `cycle`? (MOB `Fence` polls this.)
    pub fn dma_busy(&mut self, cycle: u64) -> bool {
        while let Some(front) = self.dma_jobs.front() {
            if front.done_at <= cycle {
                self.dma_jobs.pop_front();
            } else {
                return true;
            }
        }
        false
    }

    /// Reset timing calendars (between kernels); functional contents stay.
    pub fn reset_timing(&mut self) {
        self.bank_next.iter_mut().for_each(|v| *v = 0);
        self.ext_next_slot = 0;
        self.dma_jobs.clear();
        self.dma_free_at = 0;
        self.coalesce_cycle = u64::MAX;
        self.coalesce.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> MemSystem {
        MemSystem::new(MemParams::default(), 4096)
    }

    #[test]
    fn host_roundtrip() {
        let mut m = sys();
        m.host_write_ext(100, &[1, 2, 3]);
        assert_eq!(m.host_read_ext(100, 3), vec![1, 2, 3]);
    }

    #[test]
    fn host_write_grows_ext() {
        let mut m = sys();
        m.host_write_ext(10_000, &[9]);
        assert_eq!(m.host_read_ext(10_000, 1), vec![9]);
    }

    #[test]
    fn l1_read_latency() {
        let mut m = sys();
        let mut s = Stats::default();
        m.write(MemSpace::L1, 0, 42, 0, &mut s);
        let mut s2 = Stats::default();
        let mut m2 = sys();
        m2.reset_timing();
        m2.write(MemSpace::L1, 0, 42, 0, &mut s2);
        m2.reset_timing();
        let (v, ready) = m2.read(MemSpace::L1, 0, 10, &mut s2);
        assert_eq!(v, 42);
        assert_eq!(ready, 10 + m2.params.l1_latency);
    }

    #[test]
    fn l1_bank_conflict_detected() {
        let mut m = sys();
        let mut s = Stats::default();
        let banks = m.params.l1_banks as u32;
        // Two same-cycle accesses to the same bank: second is delayed.
        let (_, r1) = m.read(MemSpace::L1, 0, 5, &mut s);
        let (_, r2) = m.read(MemSpace::L1, banks, 5, &mut s);
        assert_eq!(r1, 5 + m.params.l1_latency);
        assert_eq!(r2, 6 + m.params.l1_latency);
        assert_eq!(s.l1_bank_conflicts, 1);
        // Different banks: no conflict.
        let (_, r3) = m.read(MemSpace::L1, 1, 5, &mut s);
        assert_eq!(r3, 5 + m.params.l1_latency);
    }

    #[test]
    fn ext_bandwidth_limits_issue() {
        let mut m = sys();
        let mut s = Stats::default();
        m.host_write_ext(0, &[0; 64]);
        let bw = m.params.ext_bw;
        let lat = m.params.ext_latency;
        // First `bw` accesses in cycle 0 are on time; the next spills.
        for i in 0..bw {
            let (_, r) = m.read(MemSpace::Ext, i as u32, 0, &mut s);
            assert_eq!(r, lat, "access {i}");
        }
        let (_, r) = m.read(MemSpace::Ext, bw as u32, 0, &mut s);
        assert_eq!(r, 1 + lat);
        assert!(s.ext_queue_cycles >= 1);
    }

    #[test]
    fn ext_traffic_counted() {
        let mut m = sys();
        let mut s = Stats::default();
        m.host_write_ext(0, &[1, 2, 3, 4]);
        m.read(MemSpace::Ext, 0, 0, &mut s);
        m.write(MemSpace::Ext, 9, 7, 0, &mut s);
        assert_eq!(s.ext_reads, 1);
        assert_eq!(s.ext_writes, 1);
    }

    #[test]
    fn dma_moves_data_and_counts_boundary() {
        let mut m = sys();
        let mut s = Stats::default();
        m.host_write_ext(0, &[10, 20, 30, 40]);
        let done = m.dma(0, 100, 4, true, 0, &mut s).unwrap();
        assert_eq!(m.host_read_l1(100, 4), vec![10, 20, 30, 40]);
        assert!(done > 0);
        assert_eq!(s.ext_reads, 4);
        assert_eq!(s.l1_writes, 4);
        assert_eq!(s.dma_words, 4);
        // Busy until done, free after.
        assert!(m.dma_busy(done - 1));
        assert!(!m.dma_busy(done));
    }

    #[test]
    fn dma_l1_to_ext() {
        let mut m = sys();
        let mut s = Stats::default();
        m.host_write_ext(0, &[1, 2]);
        m.dma(0, 0, 2, true, 0, &mut s).unwrap();
        m.dma(500, 0, 2, false, 0, &mut s).unwrap();
        assert_eq!(m.host_read_ext(500, 2), vec![1, 2]);
    }

    #[test]
    fn dma_overrun_errors() {
        let mut m = sys();
        let mut s = Stats::default();
        let l1 = m.params.l1_words as u32;
        assert!(m.dma(0, l1 - 1, 2, true, 0, &mut s).is_err());
    }

    #[test]
    fn dma_jobs_serialize() {
        let mut m = sys();
        let mut s = Stats::default();
        m.host_write_ext(0, &[0; 256]);
        let d1 = m.dma(0, 0, 128, true, 0, &mut s).unwrap();
        let d2 = m.dma(128, 128, 128, true, 0, &mut s).unwrap();
        assert!(d2 > d1);
    }
}
