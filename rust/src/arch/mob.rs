//! Memory-operation block model (§III-B2).
//!
//! A MOB executes stream descriptors decoupled from PE execution: it
//! issues up to one address per cycle, keeps up to [`MAX_OUTSTANDING`]
//! requests in flight (the "data can be prefetched … without disrupting
//! ongoing computations" claim), and delivers response words in stream
//! order into the fabric. STORE streams absorb words from an input port;
//! DMA descriptors stage panels between external memory and L1; LOOP
//! descriptors (two nestable levels, per-level address steps) let one
//! compact program sweep a whole blocked GEMM.

use crate::arch::mem::MemSystem;
use crate::interconnect::fabric::Fabric;
use crate::isa::{Dir, DirMode, MobOp, MobProgram};
use crate::sim::stats::Stats;
use std::collections::VecDeque;

/// Maximum in-flight load requests per MOB (double-buffered line buffer).
pub const MAX_OUTSTANDING: usize = 8;

/// One active loop level.
#[derive(Debug, Clone, Copy)]
struct LoopFrame {
    /// pc of the `Loop` descriptor that opened this frame.
    pc: usize,
    /// Window re-executions still owed after the current one.
    remaining: u32,
    /// Current iteration index (0 on the first pass — frames are pushed
    /// with iter = 1 since pass 0 runs before the Loop op is reached).
    iter: i64,
}

/// A pending load response: the word, when it is ready, and how many
/// emissions remain (broadcast replication for the switched baseline).
#[derive(Debug, Clone, Copy)]
struct Resp {
    ready: u64,
    word: u32,
    emits_left: u8,
}

/// One memory-operation block.
#[derive(Debug, Clone)]
pub struct Mob {
    /// Flat node id in the combined grid.
    pub node: usize,
    ops: Vec<MobOp>,
    /// pcs of all `Loop` descriptors (for static step-level binding).
    loop_pcs: Vec<usize>,
    pc: usize,
    /// Words issued for the current LOAD descriptor (sub-stream A for
    /// `LoadDual`).
    issued: u32,
    /// Words absorbed for the current STORE descriptor (sub-stream B
    /// issue counter for `LoadDual`).
    absorbed: u32,
    /// Position within the `[a_per, b_per]` burst pattern (`LoadDual`).
    burst_pos: u8,
    /// Emitted-word counter for `DirMode::Rotate` (persists across
    /// descriptors so rotation stays aligned with the route table).
    emit_idx: u64,
    /// In-order load response queue.
    resp: VecDeque<Resp>,
    /// Active loop frames, outermost first.
    loops: Vec<LoopFrame>,
    /// DMA completion cycle when blocked on a `Dma` descriptor.
    dma_done_at: Option<u64>,
    /// Waiting at a `Barrier` descriptor for the engine to release.
    at_barrier: bool,
    halted: bool,
}

impl Mob {
    /// Create a halted MOB at a grid node.
    pub fn new(node: usize) -> Self {
        Self {
            node,
            ops: Vec::new(),
            loop_pcs: Vec::new(),
            pc: 0,
            issued: 0,
            absorbed: 0,
            burst_pos: 0,
            emit_idx: 0,
            resp: VecDeque::new(),
            loops: Vec::new(),
            dma_done_at: None,
            at_barrier: false,
            halted: true,
        }
    }

    /// Load a program and reset stream state (context distribution).
    pub fn load_program(&mut self, program: MobProgram) {
        self.ops = program.ops;
        self.loop_pcs = self
            .ops
            .iter()
            .enumerate()
            .filter(|(_, op)| matches!(op, MobOp::Loop { .. }))
            .map(|(i, _)| i)
            .collect();
        self.pc = 0;
        self.issued = 0;
        self.absorbed = 0;
        self.emit_idx = 0;
        self.resp.clear();
        self.loops.clear();
        self.dma_done_at = None;
        self.at_barrier = false;
        self.halted = self.ops.is_empty();
    }

    /// Is this MOB parked at a [`MobOp::Barrier`]?
    pub fn waiting_at_barrier(&self) -> bool {
        self.at_barrier
    }

    /// Engine-side release of a global barrier (all MOBs rendezvoused).
    pub fn release_barrier(&mut self) {
        debug_assert!(self.at_barrier);
        self.at_barrier = false;
        self.advance();
    }

    /// One-line execution-state summary (deadlock diagnosis).
    pub fn debug_state(&self) -> String {
        let op = self.ops.get(self.pc).map(|o| format!("{o:?}"));
        format!(
            "{}pc={} issued={} absorbed={} resp={} loops={:?} op={}",
            if self.halted { "HALT " } else if self.at_barrier { "BARRIER " } else { "" },
            self.pc,
            self.issued,
            self.absorbed,
            self.resp.len(),
            self.loops.iter().map(|f| (f.pc, f.iter)).collect::<Vec<_>>(),
            op.unwrap_or_else(|| "-".into())
        )
    }

    /// Is the MOB done?
    pub fn halted(&self) -> bool {
        self.halted
    }

    /// Does the `Loop` descriptor at `loop_pc` enclose the op at `pc`?
    fn loop_encloses(&self, loop_pc: usize, pc: usize) -> bool {
        match self.ops[loop_pc] {
            MobOp::Loop { start, .. } => (start as usize) <= pc && pc < loop_pc,
            _ => false,
        }
    }

    /// Loop-level address offset for the op at the current pc with the
    /// given per-level steps. Levels bind *statically*: `steps[0]` is the
    /// innermost *enclosing* `Loop` descriptor, `steps[1]` the next one
    /// out — sibling loops (whose window does not contain the op) are
    /// skipped, and an op sees the right step even when an inner loop's
    /// frame is not currently on the stack.
    fn loop_offset(&self, steps: &[i32; 2]) -> i64 {
        let mut off = 0i64;
        for frame in &self.loops {
            if !self.loop_encloses(frame.pc, self.pc) {
                continue;
            }
            // Level = number of enclosing loops strictly inner to this one.
            let level = self
                .loop_pcs
                .iter()
                .filter(|&&p| p < frame.pc && self.loop_encloses(p, self.pc))
                .count();
            if level < 2 {
                off += steps[level] as i64 * frame.iter;
            }
        }
        off
    }

    fn advance(&mut self) {
        self.pc += 1;
        self.issued = 0;
        self.absorbed = 0;
        self.burst_pos = 0;
        if self.pc >= self.ops.len() {
            self.halted = true;
        }
    }

    /// Output port for the next emission under a direction mode.
    fn emit_dir(&self, dir: DirMode) -> Dir {
        match dir {
            DirMode::Fixed(d) => d,
            DirMode::Rotate => Dir::ALL[(self.emit_idx % 4) as usize],
        }
    }

    /// Execute one cycle.
    pub fn tick(
        &mut self,
        fabric: &mut Fabric,
        mem: &mut MemSystem,
        cycle: u64,
        stats: &mut Stats,
    ) {
        if self.halted || self.at_barrier {
            return;
        }
        let op = self.ops[self.pc];
        match op {
            MobOp::Load { space, base, stride, count, dir, replicate, steps } => {
                let mut progressed = false;
                // Deliver one emission of the head response if ready.
                if let Some(front) = self.resp.front().copied() {
                    if front.ready <= cycle {
                        let d = self.emit_dir(dir);
                        if fabric.can_send(self.node, d, cycle) {
                            let ok = fabric.send(self.node, d, front.word, cycle, stats);
                            debug_assert!(ok);
                            self.emit_idx += 1;
                            stats.mob_load_words += 1;
                            let front = self.resp.front_mut().unwrap();
                            front.emits_left -= 1;
                            if front.emits_left == 0 {
                                self.resp.pop_front();
                            }
                            progressed = true;
                        } else {
                            stats.mob_stall_fabric += 1;
                            progressed = true; // diagnosed; don't double-count
                        }
                    }
                }
                // Issue the next address (pipelined with delivery).
                if self.issued < count && self.resp.len() < MAX_OUTSTANDING {
                    let addr = (base as i64
                        + self.loop_offset(&steps)
                        + self.issued as i64 * stride as i64) as u32;
                    let (value, ready) = mem.read(space, addr, cycle, stats);
                    self.resp.push_back(Resp {
                        ready,
                        word: value,
                        emits_left: replicate.max(1),
                    });
                    self.issued += 1;
                    stats.mob_agu_ops += 1;
                    progressed = true;
                }
                if !progressed && !self.resp.is_empty() {
                    stats.mob_stall_mem += 1;
                }
                if self.issued == count && self.resp.is_empty() {
                    self.advance();
                }
            }
            MobOp::LoadDual {
                space,
                a_base,
                a_stride,
                a_count,
                a_per,
                b_base,
                b_stride,
                b_count,
                b_per,
                dir,
                a_steps,
                b_steps,
            } => {
                let mut progressed = false;
                // Deliver the head response if ready (single emission;
                // LoadDual streams never replicate).
                if let Some(&Resp { ready, word, .. }) = self.resp.front() {
                    if ready <= cycle {
                        if fabric.can_send(self.node, dir, cycle) {
                            let ok = fabric.send(self.node, dir, word, cycle, stats);
                            debug_assert!(ok);
                            self.resp.pop_front();
                            self.emit_idx += 1;
                            stats.mob_load_words += 1;
                            progressed = true;
                        } else {
                            stats.mob_stall_fabric += 1;
                            progressed = true;
                        }
                    }
                }
                // Issue the next address following the burst pattern.
                let a_left = a_count - self.issued;
                let b_left = b_count - self.absorbed;
                if (a_left > 0 || b_left > 0) && self.resp.len() < MAX_OUTSTANDING {
                    let period = (a_per + b_per).max(1);
                    let take_a = if a_left == 0 {
                        false
                    } else if b_left == 0 {
                        true
                    } else {
                        self.burst_pos < a_per
                    };
                    let addr = if take_a {
                        (a_base as i64
                            + self.loop_offset(&a_steps)
                            + self.issued as i64 * a_stride as i64) as u32
                    } else {
                        (b_base as i64
                            + self.loop_offset(&b_steps)
                            + self.absorbed as i64 * b_stride as i64) as u32
                    };
                    let (value, ready) = mem.read(space, addr, cycle, stats);
                    self.resp.push_back(Resp { ready, word: value, emits_left: 1 });
                    if take_a {
                        self.issued += 1;
                    } else {
                        self.absorbed += 1;
                    }
                    self.burst_pos = (self.burst_pos + 1) % period;
                    stats.mob_agu_ops += 1;
                    progressed = true;
                }
                if !progressed && !self.resp.is_empty() {
                    stats.mob_stall_mem += 1;
                }
                if self.issued == a_count && self.absorbed == b_count && self.resp.is_empty() {
                    self.advance();
                }
            }
            MobOp::Store { space, base, stride, count, dir, steps } => {
                if self.absorbed < count {
                    if let Some(word) = fabric.port_take(self.node, dir) {
                        let addr = (base as i64
                            + self.loop_offset(&steps)
                            + self.absorbed as i64 * stride as i64)
                            as u32;
                        mem.write(space, addr, word, cycle, stats);
                        self.absorbed += 1;
                        stats.mob_store_words += 1;
                        stats.mob_agu_ops += 1;
                    }
                }
                if self.absorbed == count {
                    self.advance();
                }
            }
            MobOp::Dma { ext_base, l1_base, count, to_l1, ext_steps, l1_steps } => {
                match self.dma_done_at {
                    None => {
                        let eb = (ext_base as i64 + self.loop_offset(&ext_steps)) as u32;
                        let lb = (l1_base as i64 + self.loop_offset(&l1_steps)) as u32;
                        let done = mem
                            .dma(eb, lb, count, to_l1, cycle, stats)
                            .expect("DMA descriptor validated at context load");
                        self.dma_done_at = Some(done);
                    }
                    Some(done) => {
                        if cycle >= done {
                            self.dma_done_at = None;
                            self.advance();
                        } else {
                            stats.mob_stall_mem += 1;
                        }
                    }
                }
            }
            MobOp::Loop { start, extra } => {
                match self.loops.last_mut() {
                    Some(top) if top.pc == self.pc => {
                        if top.remaining > 0 {
                            top.remaining -= 1;
                            top.iter += 1;
                            self.pc = start as usize;
                            self.issued = 0;
                            self.absorbed = 0;
                        } else {
                            self.loops.pop();
                            self.advance();
                        }
                    }
                    _ => {
                        if extra == 0 {
                            self.advance();
                        } else {
                            self.loops.push(LoopFrame {
                                pc: self.pc,
                                remaining: extra - 1,
                                iter: 1,
                            });
                            self.pc = start as usize;
                            self.issued = 0;
                            self.absorbed = 0;
                        }
                    }
                }
            }
            MobOp::Fence => {
                if self.resp.is_empty() && !mem.dma_busy(cycle) {
                    self.advance();
                } else {
                    stats.mob_stall_mem += 1;
                }
            }
            MobOp::Barrier => {
                self.at_barrier = true;
            }
            MobOp::Halt => {
                self.halted = true;
            }
        }
    }

    #[cfg(test)]
    fn resp_len(&self) -> usize {
        self.resp.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::mem::MemParams;
    use crate::interconnect::fabric::FabricKind;
    use crate::interconnect::topology::Topology;
    use crate::isa::MemSpace;

    fn rig() -> (Topology, Fabric, MemSystem, Stats) {
        let t = Topology::default();
        (
            t,
            Fabric::new(FabricKind::Torus, t, 0),
            MemSystem::new(MemParams::default(), 4096),
            Stats::default(),
        )
    }

    fn fill_l1(m: &mut MemSystem, base: u32, vals: &[u32]) {
        let mut s = Stats::default();
        for (i, &v) in vals.iter().enumerate() {
            m.write(MemSpace::L1, base + i as u32, v, 0, &mut s);
        }
        m.reset_timing();
    }

    fn run_and_drain(
        mob: &mut Mob,
        fabric: &mut Fabric,
        mem: &mut MemSystem,
        stats: &mut Stats,
        drain: (usize, Dir),
        max: u64,
    ) -> Vec<u32> {
        let mut out = Vec::new();
        let mut cycle = 0;
        while cycle < max {
            mob.tick(fabric, mem, cycle, stats);
            fabric.commit(cycle, stats);
            if let Some(w) = fabric.port_take(drain.0, drain.1) {
                out.push(w);
            }
            if mob.halted() && fabric.quiescent() {
                break;
            }
            cycle += 1;
        }
        assert!(mob.halted(), "MOB did not halt in {max} cycles");
        out
    }

    #[test]
    fn load_streams_in_order() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(0, 1);
        let mut mob = Mob::new(node);
        fill_l1(&mut m, 0, &[5, 6, 7, 8]);
        mob.load_program(MobProgram {
            ops: vec![MobOp::load(MemSpace::L1, 0, 1, 4, Dir::East), MobOp::Halt],
        });
        let out = run_and_drain(&mut mob, &mut f, &mut m, &mut s, (t.pe(0, 0), Dir::West), 100);
        assert_eq!(out, vec![5, 6, 7, 8]);
        assert_eq!(s.mob_load_words, 4);
        assert_eq!(s.l1_reads, 4);
    }

    #[test]
    fn load_strided_addresses() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(1, 1);
        let mut mob = Mob::new(node);
        fill_l1(&mut m, 0, &[0, 10, 20, 30, 40, 50, 60, 70]);
        mob.load_program(MobProgram {
            ops: vec![MobOp::load(MemSpace::L1, 1, 2, 3, Dir::East)],
        });
        let out = run_and_drain(&mut mob, &mut f, &mut m, &mut s, (t.pe(1, 0), Dir::West), 100);
        assert_eq!(out, vec![10, 30, 50]);
    }

    #[test]
    fn store_absorbs_words() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(0, 1);
        let mut mob = Mob::new(node);
        mob.load_program(MobProgram {
            ops: vec![MobOp::store(MemSpace::L1, 50, 1, 2, Dir::East)],
        });
        let pe0 = t.pe(0, 0);
        let mut cycle = 0u64;
        let mut sent = 0;
        while !mob.halted() && cycle < 100 {
            if sent < 2 && f.can_send(pe0, Dir::West, cycle) {
                f.send(pe0, Dir::West, 111 + sent, cycle, &mut s);
                sent += 1;
            }
            mob.tick(&mut f, &mut m, cycle, &mut s);
            f.commit(cycle, &mut s);
            cycle += 1;
        }
        assert!(mob.halted());
        assert_eq!(m.host_read_l1(50, 2), vec![111, 112]);
        assert_eq!(s.mob_store_words, 2);
    }

    #[test]
    fn single_loop_with_steps() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(2, 1);
        let mut mob = Mob::new(node);
        fill_l1(&mut m, 0, &[100, 101, 102, 103, 104, 105]);
        // Window = [load 2 words]; 3 passes, base step 2 per iteration.
        mob.load_program(MobProgram {
            ops: vec![
                MobOp::Load {
                    space: MemSpace::L1,
                    base: 0,
                    stride: 1,
                    count: 2,
                    dir: DirMode::Fixed(Dir::East),
                    replicate: 1,
                    steps: [2, 0],
                },
                MobOp::Loop { start: 0, extra: 2 },
            ],
        });
        let out = run_and_drain(&mut mob, &mut f, &mut m, &mut s, (t.pe(2, 0), Dir::West), 200);
        assert_eq!(out, vec![100, 101, 102, 103, 104, 105]);
    }

    #[test]
    fn nested_loops_two_level_steps() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(2, 1);
        let mut mob = Mob::new(node);
        let vals: Vec<u32> = (0..12).collect();
        fill_l1(&mut m, 0, &vals);
        // inner: load 1 word, step 1 per inner iter (3 inner iters);
        // outer: step 6 per outer iter (2 outer iters).
        // Expect offsets: 0,1,2, 6,7,8.
        mob.load_program(MobProgram {
            ops: vec![
                MobOp::Load {
                    space: MemSpace::L1,
                    base: 0,
                    stride: 0,
                    count: 1,
                    dir: DirMode::Fixed(Dir::East),
                    replicate: 1,
                    steps: [1, 6],
                },
                MobOp::Loop { start: 0, extra: 2 },
                MobOp::Loop { start: 0, extra: 1 },
            ],
        });
        let out = run_and_drain(&mut mob, &mut f, &mut m, &mut s, (t.pe(2, 0), Dir::West), 300);
        assert_eq!(out, vec![0, 1, 2, 6, 7, 8]);
    }

    #[test]
    fn replicate_emits_copies() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(0, 1);
        let mut mob = Mob::new(node);
        fill_l1(&mut m, 0, &[9]);
        mob.load_program(MobProgram {
            ops: vec![MobOp::Load {
                space: MemSpace::L1,
                base: 0,
                stride: 1,
                count: 1,
                dir: DirMode::Fixed(Dir::East),
                replicate: 3,
                steps: [0, 0],
            }],
        });
        let out = run_and_drain(&mut mob, &mut f, &mut m, &mut s, (t.pe(0, 0), Dir::West), 100);
        assert_eq!(out, vec![9, 9, 9]);
        assert_eq!(s.l1_reads, 1, "broadcast reads memory once");
        assert_eq!(s.mob_load_words, 3);
    }

    #[test]
    fn rotate_cycles_directions() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(1, 0); // column 4
        let mut mob = Mob::new(node);
        fill_l1(&mut m, 0, &[1, 2, 3, 4]);
        mob.load_program(MobProgram {
            ops: vec![MobOp::Load {
                space: MemSpace::L1,
                base: 0,
                stride: 1,
                count: 4,
                dir: DirMode::Rotate,
                replicate: 1,
                steps: [0, 0],
            }],
        });
        // Run; each word goes out a different port (N, E, S, W).
        for cycle in 0..50 {
            mob.tick(&mut f, &mut m, cycle, &mut s);
            f.commit(cycle, &mut s);
            if mob.halted() {
                break;
            }
        }
        assert!(mob.halted());
        let c = t.coord(node);
        let nb = |d: Dir| t.node_id(t.neighbor(c, d));
        assert_eq!(f.port_take(nb(Dir::North), Dir::South), Some(1));
        assert_eq!(f.port_take(nb(Dir::East), Dir::West), Some(2));
        assert_eq!(f.port_take(nb(Dir::South), Dir::North), Some(3));
        assert_eq!(f.port_take(nb(Dir::West), Dir::East), Some(4));
    }

    #[test]
    fn dma_then_fence_then_load() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(3, 1);
        let mut mob = Mob::new(node);
        m.host_write_ext(0, &[42, 43]);
        mob.load_program(MobProgram {
            ops: vec![
                MobOp::dma(0, 8, 2, true),
                MobOp::Fence,
                MobOp::load(MemSpace::L1, 8, 1, 2, Dir::East),
            ],
        });
        let out = run_and_drain(&mut mob, &mut f, &mut m, &mut s, (t.pe(3, 0), Dir::West), 200);
        assert_eq!(out, vec![42, 43]);
        assert_eq!(s.dma_words, 2);
        assert!(s.mob_stall_mem > 0, "must have waited for DMA latency");
    }

    #[test]
    fn dma_with_loop_steps() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(0, 1);
        let mut mob = Mob::new(node);
        m.host_write_ext(0, &[1, 2, 3, 4]);
        // Two iterations: DMA ext[2i..2i+2] → L1[0..2], then stream it.
        mob.load_program(MobProgram {
            ops: vec![
                MobOp::Dma {
                    ext_base: 0,
                    l1_base: 0,
                    count: 2,
                    to_l1: true,
                    ext_steps: [2, 0],
                    l1_steps: [0, 0],
                },
                MobOp::Fence,
                MobOp::load(MemSpace::L1, 0, 1, 2, Dir::East),
                MobOp::Loop { start: 0, extra: 1 },
            ],
        });
        let out = run_and_drain(&mut mob, &mut f, &mut m, &mut s, (t.pe(0, 0), Dir::West), 500);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn backpressure_counts_fabric_stall() {
        let (t, _, mut m, mut s) = rig();
        // Depth-1 FIFO so an undrained consumer backs the stream up fast.
        let mut f = Fabric::with_fifo(FabricKind::Torus, t, 0, 1);
        let node = t.mob(0, 1);
        let mut mob = Mob::new(node);
        fill_l1(&mut m, 0, &[0, 1, 2, 3]);
        mob.load_program(MobProgram {
            ops: vec![MobOp::load(MemSpace::L1, 0, 1, 4, Dir::East)],
        });
        for cycle in 0..30 {
            mob.tick(&mut f, &mut m, cycle, &mut s);
            f.commit(cycle, &mut s);
        }
        assert!(!mob.halted());
        assert!(s.mob_stall_fabric > 0);
        let _ = t;
    }

    #[test]
    fn outstanding_limit_respected() {
        let (t, mut f, mut m, mut s) = rig();
        let node = t.mob(0, 0);
        let mut mob = Mob::new(node);
        m.host_write_ext(0, &[7; 64]);
        mob.load_program(MobProgram {
            ops: vec![MobOp::load(MemSpace::Ext, 0, 1, 64, Dir::West)],
        });
        for cycle in 0..10 {
            mob.tick(&mut f, &mut m, cycle, &mut s);
            f.commit(cycle, &mut s);
        }
        assert!(mob.resp_len() <= MAX_OUTSTANDING);
        let _ = t;
    }

    #[test]
    fn empty_program_halts() {
        let mut mob = Mob::new(0);
        mob.load_program(MobProgram::idle());
        assert!(mob.halted());
    }
}
