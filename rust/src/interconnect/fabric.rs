//! Word transport: switchless torus vs switched NoC.
//!
//! Both fabrics expose the same interface to the simulation engine:
//! nodes *send* a word out of a port and *take* words from input-port
//! latches. The difference is what happens in between:
//!
//! - [`FabricKind::Torus`]: the out-port is wired to the neighbour's
//!   in-port. One cycle, link energy only, 1-deep latch backpressure.
//! - [`FabricKind::Switched`]: the out-port index selects a *route table*
//!   entry `(dst_node, dst_port)`; the word becomes a packet that
//!   traverses `hop_latency` cycles of router pipeline per XY hop, with
//!   per-directed-link serialization (1 word/cycle) and per-hop router +
//!   link energy. This is the conventional NoC the paper's §III-C argues
//!   removing.

use super::topology::{Coord, Topology};
use crate::isa::Dir;
use crate::sim::stats::Stats;

/// Which transport model to simulate (TAB3 compares the two).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FabricKind {
    /// The paper's switchless mesh torus.
    #[default]
    Torus,
    /// Conventional switched mesh NoC baseline.
    Switched,
}

/// Per-node routing configuration for the switched fabric: out-port index
/// → (destination node, destination input port). Loaded as part of the
/// kernel context (a circuit-switched NoC configuration).
#[derive(Debug, Clone, Default)]
pub struct RouteTable {
    /// Indexed by node id, then by out-port index.
    pub entries: Vec<[Option<(usize, Dir)>; 4]>,
}

impl RouteTable {
    /// Empty table for `n` nodes.
    pub fn new(n: usize) -> Self {
        Self { entries: vec![[None; 4]; n] }
    }

    /// Set the route for `(node, out_dir)`.
    pub fn set(&mut self, node: usize, out: Dir, dst: usize, dst_port: Dir) {
        self.entries[node][out.idx()] = Some((dst, dst_port));
    }

    /// Look up the route for `(node, out_dir)`.
    pub fn get(&self, node: usize, out: Dir) -> Option<(usize, Dir)> {
        self.entries.get(node).and_then(|e| e[out.idx()])
    }
}

/// An in-flight packet on the switched fabric.
#[derive(Debug, Clone, Copy)]
struct Packet {
    word: u32,
    dst: usize,
    dst_port: Dir,
    /// Cycle at which the packet pops out of the last router.
    ready_at: u64,
    /// Injection sequence number: delivery into a given (dst, port) is
    /// in sequence order (per-stream packets share a path, so this is
    /// also arrival order — required for the elastic stream contract).
    seq: u64,
}

/// Default input-port FIFO depth. Real elastic CGRAs put small FIFOs on
/// network inputs (cf. Ultra-Elastic CGRAs [16]); depth ≥ 4 is what
/// absorbs the opposed skews of the east-bound A and west-bound B
/// streams so the GEMM schedule sustains one MAC/PE/cycle (a 1-deep
/// latch costs ~2.4× in steady-state throughput — see EXPERIMENTS.md).
pub const DEFAULT_PORT_FIFO: usize = 4;

/// Unified fabric: input FIFOs + (for switched) packet state.
#[derive(Debug, Clone)]
pub struct Fabric {
    pub kind: FabricKind,
    pub topo: Topology,
    /// Router pipeline depth per hop (switched only).
    pub hop_latency: u64,
    /// Input FIFO depth per port.
    pub fifo_depth: usize,
    /// Per-node, per-direction input FIFOs.
    in_ports: Vec<[std::collections::VecDeque<u32>; 4]>,
    /// Torus: per-node, per-direction staged output words.
    staged: Vec<[Option<u32>; 4]>,
    /// Switched: per-directed-link earliest-free cycle, indexed
    /// `node * 4 + dir` (the link leaving `node` in `dir`).
    link_free: Vec<u64>,
    /// Switched: per-node injection port earliest-free cycle.
    inject_free: Vec<u64>,
    /// Switched: packets in flight, in injection order.
    inflight: Vec<Packet>,
    /// Switched: next injection sequence number.
    next_seq: u64,
    /// Switched routing configuration.
    pub routes: RouteTable,
}

impl Fabric {
    /// Build a fabric over a topology with the default port-FIFO depth.
    pub fn new(kind: FabricKind, topo: Topology, hop_latency: u64) -> Self {
        Self::with_fifo(kind, topo, hop_latency, DEFAULT_PORT_FIFO)
    }

    /// Build with an explicit input-FIFO depth (ablations).
    pub fn with_fifo(
        kind: FabricKind,
        topo: Topology,
        hop_latency: u64,
        fifo_depth: usize,
    ) -> Self {
        let n = topo.nodes();
        assert!(fifo_depth >= 1);
        Self {
            kind,
            topo,
            hop_latency,
            fifo_depth,
            in_ports: vec![Default::default(); n],
            staged: vec![[None; 4]; n],
            link_free: vec![0; n * 4],
            inject_free: vec![0; n],
            inflight: Vec::new(),
            next_seq: 0,
            routes: RouteTable::new(n),
        }
    }

    /// Is the input FIFO `(node, dir)` holding a word?
    #[inline]
    pub fn port_ready(&self, node: usize, dir: Dir) -> bool {
        !self.in_ports[node][dir.idx()].is_empty()
    }

    /// Peek at the input FIFO head without consuming.
    #[inline]
    pub fn port_peek(&self, node: usize, dir: Dir) -> Option<u32> {
        self.in_ports[node][dir.idx()].front().copied()
    }

    /// Consume the head word in input FIFO `(node, dir)`.
    #[inline]
    pub fn port_take(&mut self, node: usize, dir: Dir) -> Option<u32> {
        self.in_ports[node][dir.idx()].pop_front()
    }

    /// Can `node` send a word out of `dir` this cycle?
    pub fn can_send(&self, node: usize, dir: Dir, cycle: u64) -> bool {
        match self.kind {
            FabricKind::Torus => self.staged[node][dir.idx()].is_none(),
            FabricKind::Switched => {
                self.routes.get(node, dir).is_some() && self.inject_free[node] <= cycle
            }
        }
    }

    /// Send a word out of `(node, dir)`. Caller must have checked
    /// [`Fabric::can_send`]; returns `false` (and does nothing) otherwise.
    pub fn send(
        &mut self,
        node: usize,
        dir: Dir,
        word: u32,
        cycle: u64,
        stats: &mut Stats,
    ) -> bool {
        if !self.can_send(node, dir, cycle) {
            return false;
        }
        match self.kind {
            FabricKind::Torus => {
                self.staged[node][dir.idx()] = Some(word);
                true
            }
            FabricKind::Switched => {
                let (dst, dst_port) = self.routes.get(node, dir).expect("checked by can_send");
                let src_c = self.topo.coord(node);
                let dst_c = self.topo.coord(dst);
                let path = self.topo.xy_path(src_c, dst_c);
                // Reserve the injection port and each directed link in
                // order; every reservation also costs a router traversal.
                self.inject_free[node] = cycle + 1;
                let mut t = cycle;
                let mut prev = src_c;
                for &step in &path {
                    let out_dir = dir_between(&self.topo, prev, step);
                    let link = self.topo.node_id(prev) * 4 + out_dir.idx();
                    t = t.max(self.link_free[link]);
                    self.link_free[link] = t + 1;
                    t += self.hop_latency;
                    prev = step;
                    stats.noc_router_traversals += 1;
                    stats.noc_link_hops += 1;
                }
                stats.noc_packets += 1;
                let seq = self.next_seq;
                self.next_seq += 1;
                self.inflight.push(Packet { word, dst, dst_port, ready_at: t, seq });
                true
            }
        }
    }

    /// End-of-cycle commit: move words across links / deliver due packets.
    pub fn commit(&mut self, cycle: u64, stats: &mut Stats) {
        match self.kind {
            FabricKind::Torus => {
                for node in 0..self.topo.nodes() {
                    for dir in Dir::ALL {
                        if self.staged[node][dir.idx()].is_none() {
                            continue;
                        }
                        let nb = self.topo.neighbor(self.topo.coord(node), dir);
                        let nb_id = self.topo.node_id(nb);
                        let in_slot = dir.opposite().idx();
                        if self.in_ports[nb_id][in_slot].len() < self.fifo_depth {
                            let w = self.staged[node][dir.idx()].take().unwrap();
                            self.in_ports[nb_id][in_slot].push_back(w);
                            stats.torus_hops += 1;
                        } else {
                            stats.torus_backpressure_cycles += 1;
                        }
                    }
                }
            }
            FabricKind::Switched => {
                // Deliver in injection-sequence order per (dst, port):
                // packets of one stream share a path, so sequence order
                // is arrival order, and a blocked earlier packet must
                // block later ones for the same FIFO (no overtaking).
                self.inflight.sort_unstable_by_key(|p| p.seq);
                let mut blocked: Vec<(usize, usize)> = Vec::new();
                let mut keep: Vec<Packet> = Vec::with_capacity(self.inflight.len());
                for p in std::mem::take(&mut self.inflight) {
                    let key = (p.dst, p.dst_port.idx());
                    if blocked.contains(&key) {
                        keep.push(p);
                        continue;
                    }
                    if p.ready_at <= cycle {
                        if self.in_ports[p.dst][key.1].len() < self.fifo_depth {
                            self.in_ports[p.dst][key.1].push_back(p.word);
                        } else {
                            stats.noc_eject_contention_cycles += 1;
                            blocked.push(key);
                            keep.push(p);
                        }
                    } else {
                        blocked.push(key);
                        keep.push(p);
                    }
                }
                self.inflight = keep;
            }
        }
    }

    /// True when no word is buffered anywhere (used by kernel-completion
    /// and fence checks).
    pub fn quiescent(&self) -> bool {
        self.inflight.is_empty()
            && self.in_ports.iter().all(|p| p.iter().all(|f| f.is_empty()))
            && self.staged.iter().all(|p| p.iter().all(Option::is_none))
    }

    /// Reset transient state between kernels (route table survives until
    /// the next context load).
    pub fn reset(&mut self) {
        for p in &mut self.in_ports {
            p.iter_mut().for_each(|f| f.clear());
        }
        for p in &mut self.staged {
            *p = [None; 4];
        }
        self.link_free.iter_mut().for_each(|v| *v = 0);
        self.inject_free.iter_mut().for_each(|v| *v = 0);
        self.inflight.clear();
        self.next_seq = 0;
    }
}

/// Direction that moves one torus hop from `a` to adjacent coordinate `b`.
fn dir_between(topo: &Topology, a: Coord, b: Coord) -> Dir {
    for d in Dir::ALL {
        if topo.neighbor(a, d) == b {
            return d;
        }
    }
    panic!("coordinates not adjacent: {a:?} {b:?}");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::default()
    }

    #[test]
    fn torus_single_hop_delivery() {
        let t = topo();
        let mut f = Fabric::new(FabricKind::Torus, t, 0);
        let mut s = Stats::default();
        let src = t.pe(0, 0);
        assert!(f.send(src, Dir::East, 0xABCD, 0, &mut s));
        f.commit(0, &mut s);
        let dst = t.pe(0, 1);
        assert_eq!(f.port_take(dst, Dir::West), Some(0xABCD));
        assert_eq!(s.torus_hops, 1);
    }

    #[test]
    fn torus_backpressure_blocks_second_word() {
        let t = topo();
        // Depth-1 FIFO isolates the latch-level backpressure protocol.
        let mut f = Fabric::with_fifo(FabricKind::Torus, t, 0, 1);
        let mut s = Stats::default();
        let src = t.pe(0, 0);
        assert!(f.send(src, Dir::East, 1, 0, &mut s));
        f.commit(0, &mut s);
        // Receiver hasn't consumed; second word stages but can't move.
        assert!(f.send(src, Dir::East, 2, 1, &mut s));
        f.commit(1, &mut s);
        assert_eq!(s.torus_backpressure_cycles, 1);
        // Third send must fail: staging latch still full.
        assert!(!f.can_send(src, Dir::East, 2));
        // Consume, then the staged word moves on the next commit.
        let dst = t.pe(0, 1);
        assert_eq!(f.port_take(dst, Dir::West), Some(1));
        f.commit(2, &mut s);
        assert_eq!(f.port_take(dst, Dir::West), Some(2));
    }

    #[test]
    fn torus_wraparound_mob_to_pe0() {
        // The GEMM A-stream path: MOB(r, last) sends east, wraps to PE(r,0).
        let t = topo();
        let mut f = Fabric::new(FabricKind::Torus, t, 0);
        let mut s = Stats::default();
        let mob = t.mob(2, 1); // column 5
        assert!(f.send(mob, Dir::East, 7, 0, &mut s));
        f.commit(0, &mut s);
        assert_eq!(f.port_take(t.pe(2, 0), Dir::West), Some(7));
    }

    #[test]
    fn switched_requires_route() {
        let t = topo();
        let mut f = Fabric::new(FabricKind::Switched, t, 3);
        assert!(!f.can_send(t.pe(0, 0), Dir::East, 0));
    }

    #[test]
    fn switched_delivers_after_hop_latency() {
        let t = topo();
        let mut f = Fabric::new(FabricKind::Switched, t, 3);
        let mut s = Stats::default();
        let src = t.mob(0, 1);
        let dst = t.pe(0, 2);
        f.routes.set(src, Dir::East, dst, Dir::West);
        assert!(f.send(src, Dir::East, 9, 0, &mut s));
        // Distance col 5 → col 2 is 3 hops; 3 cycles each → ready at 9.
        for cyc in 0..9 {
            f.commit(cyc, &mut s);
            assert!(!f.port_ready(dst, Dir::West), "too early at {cyc}");
        }
        f.commit(9, &mut s);
        assert_eq!(f.port_take(dst, Dir::West), Some(9));
        assert_eq!(s.noc_router_traversals, 3);
        assert_eq!(s.noc_packets, 1);
    }

    #[test]
    fn switched_injection_is_serialized() {
        let t = topo();
        let mut f = Fabric::new(FabricKind::Switched, t, 1);
        let mut s = Stats::default();
        let src = t.mob(0, 0);
        f.routes.set(src, Dir::West, t.pe(0, 3), Dir::East);
        assert!(f.send(src, Dir::West, 1, 0, &mut s));
        // Same cycle: injection port busy.
        assert!(!f.can_send(src, Dir::West, 0));
        assert!(f.can_send(src, Dir::West, 1));
    }

    #[test]
    fn switched_link_contention_serializes() {
        // Two packets sharing the first link: second is delayed.
        let t = topo();
        let mut f = Fabric::new(FabricKind::Switched, t, 1);
        let mut s = Stats::default();
        let src = t.mob(1, 1);
        f.routes.set(src, Dir::East, t.pe(1, 0), Dir::West);
        f.routes.set(src, Dir::North, t.pe(1, 1), Dir::West);
        // Both routes' XY paths start on the same east link out of src
        // (wraparound east to col 0 is 1 hop; to col 1 is 2 hops east).
        assert!(f.send(src, Dir::East, 11, 0, &mut s));
        assert!(f.send(src, Dir::North, 22, 1, &mut s));
        f.commit(1, &mut s);
        assert!(f.port_ready(t.pe(1, 0), Dir::West));
        // Second packet: first link free at cycle 1, traverse → 2; second
        // link (0,E) → traverse → ready at 3; without contention it would
        // have been ready at cycle 1 + 2 hops = 3 anyway, so check the
        // contention via the shared-link calendar instead: a third packet
        // on the same first link sent at cycle 1 is pushed to slot 2.
        f.commit(2, &mut s);
        assert!(!f.port_ready(t.pe(1, 1), Dir::West));
        f.commit(3, &mut s);
        assert!(f.port_ready(t.pe(1, 1), Dir::West));
    }

    #[test]
    fn quiescent_after_drain() {
        let t = topo();
        let mut f = Fabric::new(FabricKind::Torus, t, 0);
        let mut s = Stats::default();
        assert!(f.quiescent());
        f.send(t.pe(0, 0), Dir::East, 5, 0, &mut s);
        assert!(!f.quiescent());
        f.commit(0, &mut s);
        assert!(!f.quiescent());
        f.port_take(t.pe(0, 1), Dir::West);
        assert!(f.quiescent());
    }

    #[test]
    fn reset_clears_state() {
        let t = topo();
        let mut f = Fabric::new(FabricKind::Torus, t, 0);
        let mut s = Stats::default();
        f.send(t.pe(0, 0), Dir::East, 5, 0, &mut s);
        f.commit(0, &mut s);
        f.reset();
        assert!(f.quiescent());
    }
}
