//! Grid geometry: the heterogeneous array of Fig. 2.
//!
//! The combined grid has `rows` rows and `pe_cols + mob_cols` columns;
//! columns `[0, pe_cols)` hold PEs, columns `[pe_cols, pe_cols+mob_cols)`
//! hold MOBs. The torus wraps both dimensions, so MOB column
//! `pe_cols + mob_cols - 1` is the *west* neighbour (via wraparound) of PE
//! column 0 — this adjacency is what lets the block-wise GEMM dataflow be
//! entirely nearest-neighbour (DESIGN.md §2).

use crate::isa::Dir;

/// Node coordinate in the combined grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Coord {
    pub r: usize,
    pub c: usize,
}

impl Coord {
    pub fn new(r: usize, c: usize) -> Self {
        Self { r, c }
    }
}

/// What occupies a grid cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// Processing element (arithmetic).
    Pe,
    /// Memory operation block (LOAD/STORE).
    Mob,
}

/// Grid geometry + torus neighbour math. Default is the paper's 4×4 PE
/// array with a 4×2 MOB array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub rows: usize,
    pub pe_cols: usize,
    pub mob_cols: usize,
}

impl Default for Topology {
    fn default() -> Self {
        Self { rows: 4, pe_cols: 4, mob_cols: 2 }
    }
}

impl Topology {
    pub fn new(rows: usize, pe_cols: usize, mob_cols: usize) -> Self {
        assert!(rows > 0 && pe_cols > 0 && mob_cols > 0);
        Self { rows, pe_cols, mob_cols }
    }

    /// Total columns in the combined grid.
    #[inline]
    pub fn cols(&self) -> usize {
        self.pe_cols + self.mob_cols
    }

    /// Total nodes.
    #[inline]
    pub fn nodes(&self) -> usize {
        self.rows * self.cols()
    }

    /// Number of PEs.
    #[inline]
    pub fn num_pes(&self) -> usize {
        self.rows * self.pe_cols
    }

    /// Number of MOBs.
    #[inline]
    pub fn num_mobs(&self) -> usize {
        self.rows * self.mob_cols
    }

    /// Flat node index of a coordinate (row-major over the combined grid).
    #[inline]
    pub fn node_id(&self, c: Coord) -> usize {
        debug_assert!(c.r < self.rows && c.c < self.cols());
        c.r * self.cols() + c.c
    }

    /// Coordinate of a flat node index.
    #[inline]
    pub fn coord(&self, id: usize) -> Coord {
        debug_assert!(id < self.nodes());
        Coord { r: id / self.cols(), c: id % self.cols() }
    }

    /// Kind of the node at a coordinate.
    #[inline]
    pub fn kind(&self, c: Coord) -> NodeKind {
        if c.c < self.pe_cols {
            NodeKind::Pe
        } else {
            NodeKind::Mob
        }
    }

    /// Flat node id of PE (r, c) where `c < pe_cols`.
    #[inline]
    pub fn pe(&self, r: usize, c: usize) -> usize {
        debug_assert!(c < self.pe_cols);
        self.node_id(Coord::new(r, c))
    }

    /// Flat node id of MOB (r, m) where `m < mob_cols` (m = 0 is the
    /// column adjacent to the PE array's east edge).
    #[inline]
    pub fn mob(&self, r: usize, m: usize) -> usize {
        debug_assert!(m < self.mob_cols);
        self.node_id(Coord::new(r, self.pe_cols + m))
    }

    /// Dense PE index (row-major over the PE sub-array) of a PE node id.
    #[inline]
    pub fn pe_index(&self, id: usize) -> usize {
        let c = self.coord(id);
        debug_assert!(matches!(self.kind(c), NodeKind::Pe));
        c.r * self.pe_cols + c.c
    }

    /// Dense MOB index (row-major over the MOB sub-array) of a MOB node id.
    #[inline]
    pub fn mob_index(&self, id: usize) -> usize {
        let c = self.coord(id);
        debug_assert!(matches!(self.kind(c), NodeKind::Mob));
        c.r * self.mob_cols + (c.c - self.pe_cols)
    }

    /// Torus neighbour of `c` in direction `d` (always exists: the grid
    /// wraps both ways — this is the "mesh torus" of §III-C).
    pub fn neighbor(&self, c: Coord, d: Dir) -> Coord {
        let (rows, cols) = (self.rows, self.cols());
        match d {
            Dir::North => Coord::new((c.r + rows - 1) % rows, c.c),
            Dir::South => Coord::new((c.r + 1) % rows, c.c),
            Dir::East => Coord::new(c.r, (c.c + 1) % cols),
            Dir::West => Coord::new(c.r, (c.c + cols - 1) % cols),
        }
    }

    /// Minimal torus hop distance between two coordinates (used by the
    /// switched baseline's latency/energy model: XY routing takes this
    /// many router traversals).
    pub fn hop_distance(&self, a: Coord, b: Coord) -> usize {
        let wrap = |d: usize, n: usize| d.min(n - d);
        let dr = wrap((a.r as isize - b.r as isize).unsigned_abs(), self.rows);
        let dc = wrap((a.c as isize - b.c as isize).unsigned_abs(), self.cols());
        dr + dc
    }

    /// The XY-routing path (exclusive of `a`, inclusive of `b`): first
    /// along the row (shorter wrap direction), then along the column.
    /// Used by the switched fabric to charge per-link contention.
    pub fn xy_path(&self, a: Coord, b: Coord) -> Vec<Coord> {
        let mut path = Vec::new();
        let mut cur = a;
        let cols = self.cols();
        // Column-wise (east/west) first.
        while cur.c != b.c {
            let east = (b.c + cols - cur.c) % cols;
            let west = (cur.c + cols - b.c) % cols;
            let d = if east <= west { Dir::East } else { Dir::West };
            cur = self.neighbor(cur, d);
            path.push(cur);
        }
        // Then row-wise (north/south).
        let rows = self.rows;
        while cur.r != b.r {
            let south = (b.r + rows - cur.r) % rows;
            let north = (cur.r + rows - b.r) % rows;
            let d = if south <= north { Dir::South } else { Dir::North };
            cur = self.neighbor(cur, d);
            path.push(cur);
        }
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, prop_check, PropConfig};

    #[test]
    fn default_is_paper_geometry() {
        let t = Topology::default();
        assert_eq!(t.num_pes(), 16);
        assert_eq!(t.num_mobs(), 8);
        assert_eq!(t.nodes(), 24);
        assert_eq!(t.cols(), 6);
    }

    #[test]
    fn node_id_coord_roundtrip() {
        let t = Topology::default();
        for id in 0..t.nodes() {
            assert_eq!(t.node_id(t.coord(id)), id);
        }
    }

    #[test]
    fn kinds_partition_grid() {
        let t = Topology::default();
        let pes = (0..t.nodes())
            .filter(|&id| matches!(t.kind(t.coord(id)), NodeKind::Pe))
            .count();
        assert_eq!(pes, 16);
    }

    #[test]
    fn fig2_adjacency_mob_west_wraparound() {
        // FIG2 structural check: the last MOB column is the west
        // neighbour (via wraparound) of PE column 0, and MOB column 0 is
        // the east neighbour of the PE array's last column.
        let t = Topology::default();
        let pe00 = Coord::new(0, 0);
        let west = t.neighbor(pe00, Dir::West);
        assert_eq!(west, Coord::new(0, 5));
        assert!(matches!(t.kind(west), NodeKind::Mob));
        let pe03 = Coord::new(0, 3);
        let east = t.neighbor(pe03, Dir::East);
        assert_eq!(east, Coord::new(0, 4));
        assert!(matches!(t.kind(east), NodeKind::Mob));
    }

    #[test]
    fn torus_wraps_rows() {
        let t = Topology::default();
        assert_eq!(t.neighbor(Coord::new(0, 2), Dir::North), Coord::new(3, 2));
        assert_eq!(t.neighbor(Coord::new(3, 2), Dir::South), Coord::new(0, 2));
    }

    #[test]
    fn prop_neighbor_is_invertible() {
        prop_check("torus neighbour invertible", PropConfig::default(), |rng| {
            let t = Topology::new(rng.range(2, 9), rng.range(2, 9), rng.range(1, 4));
            let c = Coord::new(rng.range(0, t.rows), rng.range(0, t.cols()));
            for d in Dir::ALL {
                let n = t.neighbor(c, d);
                let back = t.neighbor(n, d.opposite());
                if back != c {
                    return ensure(false, || format!("{t:?} {c:?} {d}"));
                }
            }
            ensure(true, String::new)
        });
    }

    #[test]
    fn prop_hop_distance_symmetric_and_triangle() {
        prop_check("hop distance metric", PropConfig::default(), |rng| {
            let t = Topology::new(rng.range(2, 9), rng.range(2, 9), rng.range(1, 4));
            let p = Coord::new(rng.range(0, t.rows), rng.range(0, t.cols()));
            let q = Coord::new(rng.range(0, t.rows), rng.range(0, t.cols()));
            let z = Coord::new(rng.range(0, t.rows), rng.range(0, t.cols()));
            let d = |a, b| t.hop_distance(a, b);
            if d(p, q) != d(q, p) {
                return ensure(false, || format!("asym {p:?} {q:?}"));
            }
            if d(p, q) + d(q, z) < d(p, z) {
                return ensure(false, || format!("triangle {p:?} {q:?} {z:?}"));
            }
            ensure(d(p, p) == 0, || "identity".into())
        });
    }

    #[test]
    fn prop_xy_path_length_matches_distance() {
        prop_check("xy path length == hop distance", PropConfig::default(), |rng| {
            let t = Topology::new(rng.range(2, 9), rng.range(2, 9), rng.range(1, 4));
            let a = Coord::new(rng.range(0, t.rows), rng.range(0, t.cols()));
            let b = Coord::new(rng.range(0, t.rows), rng.range(0, t.cols()));
            let path = t.xy_path(a, b);
            if path.len() != t.hop_distance(a, b) {
                return ensure(false, || {
                    format!("{a:?}->{b:?}: {} vs {}", path.len(), t.hop_distance(a, b))
                });
            }
            if a != b && path.last() != Some(&b) {
                return ensure(false, || "path must end at destination".into());
            }
            ensure(true, String::new)
        });
    }

    #[test]
    fn xy_path_steps_are_adjacent() {
        let t = Topology::default();
        let a = Coord::new(0, 0);
        let b = Coord::new(3, 5);
        let path = t.xy_path(a, b);
        let mut prev = a;
        for &step in &path {
            assert_eq!(t.hop_distance(prev, step), 1);
            prev = step;
        }
    }
}
