//! Interconnect models.
//!
//! [`topology`] defines the combined PE+MOB grid geometry (Fig. 2) and
//! torus neighbour math. [`fabric`] implements the two word-transport
//! models compared in TAB3:
//!
//! - **Switchless mesh torus** (the paper's contribution, §III-C): output
//!   latches wired directly to neighbour input latches; a hop costs one
//!   cycle and link energy only. Multi-hop routes exist only as compiled
//!   pass-through *riders* in PE instructions — there is no router.
//! - **Switched mesh NoC** (the conventional baseline the paper argues
//!   against): every word is a routed unicast packet traversing
//!   `hop_latency`-cycle routers with XY routing; broadcast words must be
//!   replicated per consumer; each router hop costs router + link energy.

pub mod fabric;
pub mod topology;

pub use fabric::{Fabric, FabricKind, RouteTable};
pub use topology::{Coord, NodeKind, Topology};
