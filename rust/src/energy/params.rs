//! Per-event energy parameters.
//!
//! Defaults are 22 nm-class values consistent with published
//! ultra-low-power CGRA numbers (TRANSPIRE [12], NP-CGRA [6] report
//! sub-pJ ALU ops and low-pJ memory accesses at similar nodes). Absolute
//! values are *calibratable* — `from_kv_text` lets benches sweep them —
//! and EXPERIMENTS.md reports which conclusions are ratio-driven.

use anyhow::{bail, Result};

/// Per-event energies (picojoules) + leakage (microwatts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyParams {
    /// Packed 4-lane int8 MAC (4 multiplies + 4 adds).
    pub pe_macp_pj: f64,
    /// Scalar 32-bit ALU op (int or fp32-lite).
    pub pe_alu_pj: f64,
    /// Register-file access (read or write).
    pub pe_reg_pj: f64,
    /// Accumulator access.
    pub pe_acc_pj: f64,
    /// Mov/route issue slot.
    pub pe_mov_pj: f64,
    /// Switchless torus link hop (neighbour latch-to-latch, 32-bit).
    pub torus_hop_pj: f64,
    /// Switched NoC: link traversal component.
    pub noc_link_pj: f64,
    /// Switched NoC: router traversal (buffer + arbitration + crossbar).
    pub noc_router_pj: f64,
    /// L1 scratchpad access per 32-bit word.
    pub l1_access_pj: f64,
    /// External memory access per 32-bit word.
    pub ext_access_pj: f64,
    /// MOB address-generation + issue per word.
    pub mob_agu_pj: f64,
    /// Context decode/distribution per byte.
    pub ctx_byte_pj: f64,
    /// Array-total leakage power in microwatts.
    pub leakage_uw: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            pe_macp_pj: 1.2,
            pe_alu_pj: 0.9,
            pe_reg_pj: 0.10,
            pe_acc_pj: 0.12,
            pe_mov_pj: 0.25,
            torus_hop_pj: 0.15,
            noc_link_pj: 0.30,
            noc_router_pj: 0.60,
            l1_access_pj: 1.5,
            ext_access_pj: 8.0,
            mob_agu_pj: 0.30,
            ctx_byte_pj: 0.20,
            leakage_uw: 18.0,
        }
    }
}

impl EnergyParams {
    /// Parse overrides from `key = value` text (same format as
    /// [`crate::config::ArchConfig::from_kv_text`]).
    pub fn from_kv_text(text: &str) -> Result<Self> {
        let mut p = Self::default();
        for (k, v) in crate::config::parse_kv(text)? {
            let val: f64 = v
                .parse()
                .map_err(|e| anyhow::anyhow!("energy key '{k}': bad value '{v}': {e}"))?;
            match k.as_str() {
                "pe_macp_pj" => p.pe_macp_pj = val,
                "pe_alu_pj" => p.pe_alu_pj = val,
                "pe_reg_pj" => p.pe_reg_pj = val,
                "pe_acc_pj" => p.pe_acc_pj = val,
                "pe_mov_pj" => p.pe_mov_pj = val,
                "torus_hop_pj" => p.torus_hop_pj = val,
                "noc_link_pj" => p.noc_link_pj = val,
                "noc_router_pj" => p.noc_router_pj = val,
                "l1_access_pj" => p.l1_access_pj = val,
                "ext_access_pj" => p.ext_access_pj = val,
                "mob_agu_pj" => p.mob_agu_pj = val,
                "ctx_byte_pj" => p.ctx_byte_pj = val,
                "leakage_uw" => p.leakage_uw = val,
                other => bail!("unknown energy key '{other}'"),
            }
        }
        p.validate()?;
        Ok(p)
    }

    /// All parameters must be non-negative.
    pub fn validate(&self) -> Result<()> {
        let all = [
            self.pe_macp_pj,
            self.pe_alu_pj,
            self.pe_reg_pj,
            self.pe_acc_pj,
            self.pe_mov_pj,
            self.torus_hop_pj,
            self.noc_link_pj,
            self.noc_router_pj,
            self.l1_access_pj,
            self.ext_access_pj,
            self.mob_agu_pj,
            self.ctx_byte_pj,
            self.leakage_uw,
        ];
        if all.iter().any(|v| !v.is_finite() || *v < 0.0) {
            bail!("energy parameters must be finite and non-negative");
        }
        Ok(())
    }

    /// Scale all dynamic energies by a factor (voltage/tech scaling
    /// studies; leakage scales separately in practice, kept simple here).
    pub fn scaled(&self, dynamic_factor: f64, leakage_factor: f64) -> Self {
        Self {
            pe_macp_pj: self.pe_macp_pj * dynamic_factor,
            pe_alu_pj: self.pe_alu_pj * dynamic_factor,
            pe_reg_pj: self.pe_reg_pj * dynamic_factor,
            pe_acc_pj: self.pe_acc_pj * dynamic_factor,
            pe_mov_pj: self.pe_mov_pj * dynamic_factor,
            torus_hop_pj: self.torus_hop_pj * dynamic_factor,
            noc_link_pj: self.noc_link_pj * dynamic_factor,
            noc_router_pj: self.noc_router_pj * dynamic_factor,
            l1_access_pj: self.l1_access_pj * dynamic_factor,
            ext_access_pj: self.ext_access_pj * dynamic_factor,
            mob_agu_pj: self.mob_agu_pj * dynamic_factor,
            ctx_byte_pj: self.ctx_byte_pj * dynamic_factor,
            leakage_uw: self.leakage_uw * leakage_factor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        EnergyParams::default().validate().unwrap();
    }

    #[test]
    fn kv_overrides_apply() {
        let p = EnergyParams::from_kv_text("pe_macp_pj = 2.5\nleakage_uw = 30").unwrap();
        assert_eq!(p.pe_macp_pj, 2.5);
        assert_eq!(p.leakage_uw, 30.0);
        assert_eq!(p.pe_alu_pj, EnergyParams::default().pe_alu_pj);
    }

    #[test]
    fn negative_rejected() {
        assert!(EnergyParams::from_kv_text("pe_macp_pj = -1").is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(EnergyParams::from_kv_text("bogus = 1").is_err());
    }

    #[test]
    fn scaled_applies_factors() {
        let p = EnergyParams::default().scaled(0.5, 2.0);
        assert_eq!(p.pe_macp_pj, EnergyParams::default().pe_macp_pj * 0.5);
        assert_eq!(p.leakage_uw, EnergyParams::default().leakage_uw * 2.0);
    }
}
