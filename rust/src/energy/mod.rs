//! Energy and power model (§IV-B2, "ultra-low-power").
//!
//! Energy is a dot product of the [`Stats`] event vector with per-event
//! energies, plus leakage × time. Default per-event values are
//! 22 nm-class numbers in the range published for TRANSPIRE-class
//! ultra-low-power CGRAs (DESIGN.md §5.3); everything is a parameter so
//! TAB6 can report sensitivity sweeps. **Ratios** (switched/switchless
//! hop, ext/L1 access) drive the paper-shape conclusions, not absolute
//! picojoules.

pub mod params;

pub use params::EnergyParams;

use crate::sim::stats::Stats;

/// Energy breakdown in picojoules, by component group.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub compute_pj: f64,
    pub regfile_pj: f64,
    pub interconnect_pj: f64,
    pub l1_pj: f64,
    pub ext_mem_pj: f64,
    pub mob_pj: f64,
    pub config_pj: f64,
    pub leakage_pj: f64,
}

impl EnergyBreakdown {
    /// Total energy in picojoules.
    pub fn total_pj(&self) -> f64 {
        self.compute_pj
            + self.regfile_pj
            + self.interconnect_pj
            + self.l1_pj
            + self.ext_mem_pj
            + self.mob_pj
            + self.config_pj
            + self.leakage_pj
    }

    /// Total energy in microjoules.
    pub fn total_uj(&self) -> f64 {
        self.total_pj() / 1e6
    }

    /// Field-wise accumulation — summing per-device breakdowns into a
    /// fleet total (each device may carry its own class scaling).
    pub fn accumulate(&mut self, other: &EnergyBreakdown) {
        self.compute_pj += other.compute_pj;
        self.regfile_pj += other.regfile_pj;
        self.interconnect_pj += other.interconnect_pj;
        self.l1_pj += other.l1_pj;
        self.ext_mem_pj += other.ext_mem_pj;
        self.mob_pj += other.mob_pj;
        self.config_pj += other.config_pj;
        self.leakage_pj += other.leakage_pj;
    }
}

/// Energy model: evaluates a [`Stats`] vector.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub params: EnergyParams,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self { params: EnergyParams::default() }
    }
}

impl EnergyModel {
    pub fn new(params: EnergyParams) -> Self {
        Self { params }
    }

    /// Evaluate the energy of a run at a clock frequency (MHz). Frequency
    /// enters only through leakage (leakage power × wall time).
    pub fn evaluate(&self, stats: &Stats, freq_mhz: f64) -> EnergyBreakdown {
        let p = &self.params;
        let total_cycles = stats.cycles + stats.config_cycles;
        let seconds = total_cycles as f64 / (freq_mhz * 1e6);
        EnergyBreakdown {
            compute_pj: stats.pe_macp as f64 * p.pe_macp_pj
                + stats.pe_alu as f64 * p.pe_alu_pj
                + stats.pe_mov as f64 * p.pe_mov_pj
                + stats.pe_acc_access as f64 * p.pe_acc_pj,
            regfile_pj: (stats.pe_reg_reads + stats.pe_reg_writes) as f64 * p.pe_reg_pj,
            interconnect_pj: stats.torus_hops as f64 * p.torus_hop_pj
                + stats.noc_link_hops as f64 * p.noc_link_pj
                + stats.noc_router_traversals as f64 * p.noc_router_pj,
            l1_pj: (stats.l1_reads + stats.l1_writes) as f64 * p.l1_access_pj,
            ext_mem_pj: (stats.ext_reads + stats.ext_writes) as f64 * p.ext_access_pj,
            mob_pj: stats.mob_agu_ops as f64 * p.mob_agu_pj,
            config_pj: stats.ctx_bytes as f64 * p.ctx_byte_pj,
            leakage_pj: p.leakage_uw * seconds * 1e6, // µW × s = µJ → pJ: ×1e6
        }
    }

    /// Average power in milliwatts over the run at `freq_mhz`.
    pub fn avg_power_mw(&self, stats: &Stats, freq_mhz: f64) -> f64 {
        let total_cycles = stats.cycles + stats.config_cycles;
        if total_cycles == 0 {
            return 0.0;
        }
        let seconds = total_cycles as f64 / (freq_mhz * 1e6);
        let pj = self.evaluate(stats, freq_mhz).total_pj();
        (pj / 1e12) / seconds * 1e3
    }

    /// Energy efficiency in int8 GOPS/W (2 ops per MAC: mul + add).
    pub fn gops_per_watt(&self, stats: &Stats, freq_mhz: f64) -> f64 {
        let pj = self.evaluate(stats, freq_mhz).total_pj();
        if pj == 0.0 {
            return 0.0;
        }
        let ops = (stats.macs() * 2) as f64;
        // ops / (pj * 1e-12 J) = ops/J; GOPS/W = ops/J / 1e9.
        ops / (pj * 1e-12) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_stats() -> Stats {
        Stats {
            cycles: 1000,
            pe_macp: 16_000,
            pe_reg_reads: 32_000,
            pe_reg_writes: 8_000,
            pe_acc_access: 16_000,
            torus_hops: 5_000,
            l1_reads: 5_000,
            ext_reads: 500,
            mob_agu_ops: 5_000,
            ctx_bytes: 512,
            ..Default::default()
        }
    }

    #[test]
    fn total_is_sum_of_parts() {
        let m = EnergyModel::default();
        let b = m.evaluate(&busy_stats(), 100.0);
        let sum = b.compute_pj
            + b.regfile_pj
            + b.interconnect_pj
            + b.l1_pj
            + b.ext_mem_pj
            + b.mob_pj
            + b.config_pj
            + b.leakage_pj;
        assert!((b.total_pj() - sum).abs() < 1e-9);
        assert!(b.total_pj() > 0.0);
    }

    #[test]
    fn energy_monotone_in_events() {
        let m = EnergyModel::default();
        let s1 = busy_stats();
        let mut s2 = s1.clone();
        s2.pe_macp *= 2;
        assert!(
            m.evaluate(&s2, 100.0).total_pj() > m.evaluate(&s1, 100.0).total_pj(),
            "more MACs must cost more energy"
        );
    }

    #[test]
    fn leakage_dominates_at_low_frequency() {
        // Same work at lower frequency takes longer wall time → more
        // leakage energy; dynamic part unchanged.
        let m = EnergyModel::default();
        let s = busy_stats();
        let lo = m.evaluate(&s, 10.0);
        let hi = m.evaluate(&s, 1000.0);
        assert!(lo.leakage_pj > hi.leakage_pj * 50.0);
        assert!((lo.compute_pj - hi.compute_pj).abs() < 1e-9);
    }

    #[test]
    fn power_scales_roughly_with_frequency() {
        let m = EnergyModel::default();
        let s = busy_stats();
        let p100 = m.avg_power_mw(&s, 100.0);
        let p200 = m.avg_power_mw(&s, 200.0);
        // Dynamic part doubles with frequency; leakage constant.
        assert!(p200 > p100 * 1.5 && p200 < p100 * 2.5, "{p100} {p200}");
    }

    #[test]
    fn zero_stats_zero_power() {
        let m = EnergyModel::default();
        assert_eq!(m.avg_power_mw(&Stats::default(), 100.0), 0.0);
        assert_eq!(m.gops_per_watt(&Stats::default(), 100.0), 0.0);
    }

    #[test]
    fn switched_hop_costs_more_than_torus() {
        // The claim-C3 premise must hold in the default parameters.
        let p = EnergyParams::default();
        assert!(p.noc_link_pj + p.noc_router_pj > 2.0 * p.torus_hop_pj);
    }

    #[test]
    fn ext_access_costs_more_than_l1() {
        let p = EnergyParams::default();
        assert!(p.ext_access_pj > 3.0 * p.l1_access_pj);
    }
}
