//! Execute a transformer encoder with every GEMM on the simulated CGRA.
//!
//! Each matmul is symmetrically quantized to int8, executed bit-exactly
//! on the array (requantized output, shift calibrated from the exact
//! accumulator range — deployment would calibrate offline), and
//! dequantized on the host. Softmax / LayerNorm / GELU / residuals run on
//! the host in float, exactly as the paper's system splits the work.

use super::model::{EncoderModel, LayerParams};
use crate::gemm::{run_gemm, GemmPlan, OutputMode};
use crate::sim::CgraSim;
use crate::util::mat::MatF32;
use anyhow::Result;

/// Accumulated accounting for one encoder run on the CGRA.
#[derive(Debug, Clone, Default)]
pub struct CgraEncoderReport {
    /// Total array execution cycles across all GEMM kernels.
    pub cycles: u64,
    /// Total configuration (context distribution) cycles.
    pub config_cycles: u64,
    /// Number of GEMM kernels launched.
    pub kernels: u64,
    /// Host-side element-wise operation count (softmax/LN/GELU/residual
    /// elements; costed by the scalar GPP model in benches).
    pub host_elems: u64,
    /// Worst observed quantization error vs the float reference of any
    /// single GEMM (diagnostic).
    pub max_gemm_err: f32,
}

/// One float GEMM executed on the CGRA via int8 quantization. Returns the
/// dequantized result.
pub fn cgra_matmul_f32(
    sim: &mut CgraSim,
    x: &MatF32,
    w: &MatF32,
    report: &mut CgraEncoderReport,
) -> Result<MatF32> {
    let (qx, sx) = x.quantize();
    let (qw, sw) = w.quantize();
    // Calibrate the requant shift from the exact accumulator range (the
    // host oracle is bit-identical to the array's int math).
    let acc = qx.matmul(&qw);
    let amax = acc.data.iter().map(|v| v.unsigned_abs()).max().unwrap_or(1).max(1);
    let mut shift = 0u8;
    while (amax >> shift) > 127 {
        shift += 1;
    }
    let plan = GemmPlan::new(&sim.cfg, x.rows, x.cols, w.cols, OutputMode::Quant { shift })?;
    let run = run_gemm(sim, &qx, &qw, &plan)?;
    report.cycles += run.outcome.cycles;
    report.config_cycles += run.outcome.config_cycles;
    report.kernels += 1;
    let out = run.c_i8.expect("quant mode").dequant(sx * sw * (1u32 << shift) as f32);
    let err = out.max_abs_diff(&x.matmul(w));
    if err > report.max_gemm_err {
        report.max_gemm_err = err;
    }
    Ok(out)
}

/// Multi-head attention with all five GEMM groups on the CGRA.
fn attention_cgra(
    sim: &mut CgraSim,
    model: &EncoderModel,
    layer: &LayerParams,
    x: &MatF32,
    report: &mut CgraEncoderReport,
) -> Result<MatF32> {
    let cfg = &model.cfg;
    let (s, dh) = (cfg.seq, cfg.d_head());
    let q = cgra_matmul_f32(sim, x, &layer.wq, report)?;
    let k = cgra_matmul_f32(sim, x, &layer.wk, report)?;
    let v = cgra_matmul_f32(sim, x, &layer.wv, report)?;
    let mut ctx = MatF32::zeros(s, cfg.d_model);
    let scale = 1.0 / (dh as f32).sqrt();
    for h in 0..cfg.n_heads {
        let lo = h * dh;
        let slice = |m: &MatF32| {
            let mut out = MatF32::zeros(s, dh);
            for r in 0..s {
                for c in 0..dh {
                    *out.at_mut(r, c) = m.at(r, lo + c);
                }
            }
            out
        };
        let (qh, kh, vh) = (slice(&q), slice(&k), slice(&v));
        let mut scores = cgra_matmul_f32(sim, &qh, &kh.transpose(), report)?;
        for val in &mut scores.data {
            *val *= scale;
        }
        let probs = scores.softmax_rows();
        report.host_elems += (s * s) as u64 * 5; // softmax ≈ 5 ops/elem
        let out = cgra_matmul_f32(sim, &probs, &vh, report)?;
        for r in 0..s {
            for c in 0..dh {
                *ctx.at_mut(r, lo + c) = out.at(r, c);
            }
        }
    }
    cgra_matmul_f32(sim, &ctx, &layer.wo, report)
}

/// Full encoder forward pass on the CGRA. Returns the float output and
/// the accounting report.
pub fn run_encoder_on_cgra(
    sim: &mut CgraSim,
    model: &EncoderModel,
    x: &MatF32,
) -> Result<(MatF32, CgraEncoderReport)> {
    let mut report = CgraEncoderReport::default();
    let cfg = &model.cfg;
    let mut h = x.clone();
    for layer in &model.params.layers {
        let ln1 = h.layernorm_rows(&layer.ln1_gamma, &layer.ln1_beta, 1e-5);
        report.host_elems += (cfg.seq * cfg.d_model) as u64 * 6;
        let attn = attention_cgra(sim, model, layer, &ln1, &mut report)?;
        let x1 = h.add(&attn);
        report.host_elems += (cfg.seq * cfg.d_model) as u64;
        let ln2 = x1.layernorm_rows(&layer.ln2_gamma, &layer.ln2_beta, 1e-5);
        report.host_elems += (cfg.seq * cfg.d_model) as u64 * 6;
        let ff1 = cgra_matmul_f32(sim, &ln2, &layer.w1, &mut report)?.gelu();
        report.host_elems += (cfg.seq * cfg.d_ff) as u64 * 8; // gelu ≈ 8 ops
        let ff2 = cgra_matmul_f32(sim, &ff1, &layer.w2, &mut report)?;
        h = x1.add(&ff2);
        report.host_elems += (cfg.seq * cfg.d_model) as u64;
    }
    Ok((h, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::util::rng::XorShiftRng;
    use crate::xformer::model::XformerConfig;

    fn input(cfg: &XformerConfig, seed: u64) -> MatF32 {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(cfg.seq, cfg.d_model);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        x
    }

    #[test]
    fn single_gemm_quantized_close_to_float() {
        let mut sim = CgraSim::new(ArchConfig::default());
        let mut rng = XorShiftRng::new(11);
        let mut x = MatF32::zeros(16, 32);
        let mut w = MatF32::zeros(32, 16);
        for v in &mut x.data {
            *v = rng.normal();
        }
        for v in &mut w.data {
            *v = rng.normal() * 0.2;
        }
        let mut rep = CgraEncoderReport::default();
        let got = cgra_matmul_f32(&mut sim, &x, &w, &mut rep).unwrap();
        let want = x.matmul(&w);
        // Error bound: relative to the output magnitude; int8 symmetric
        // quantization of both operands gives ~1-2% of amax.
        let tol = want.abs_max() * 0.05 + 1e-3;
        assert!(got.max_abs_diff(&want) < tol, "{} vs tol {tol}", got.max_abs_diff(&want));
        assert!(rep.cycles > 0);
        assert_eq!(rep.kernels, 1);
    }

    #[test]
    fn encoder_cgra_close_to_float_reference() {
        // A 1-layer tiny encoder: the CGRA int8 path must track the float
        // reference within accumulated quantization noise.
        let cfg = XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
        let model = EncoderModel::new(cfg, 42);
        let x = input(&cfg, 1);
        let want = model.forward_f32(&x).unwrap();
        let mut sim = CgraSim::new(ArchConfig::default());
        let (got, rep) = run_encoder_on_cgra(&mut sim, &model, &x).unwrap();
        let tol = want.abs_max() * 0.12 + 0.05;
        let err = got.max_abs_diff(&want);
        assert!(err < tol, "int8 path diverged: err {err} vs tol {tol}");
        // 4 proj + 2 per head × 2 heads + 2 FFN = 10 kernels per layer.
        assert_eq!(rep.kernels, 10);
        assert!(rep.cycles > 0 && rep.config_cycles > 0);
        assert!(rep.host_elems > 0);
    }

    #[test]
    fn report_scales_with_layers() {
        let mk = |layers| {
            let cfg = XformerConfig { n_layers: layers, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
            let model = EncoderModel::new(cfg, 42);
            let x = input(&cfg, 1);
            let mut sim = CgraSim::new(ArchConfig::default());
            run_encoder_on_cgra(&mut sim, &model, &x).unwrap().1
        };
        let r1 = mk(1);
        let r2 = mk(2);
        assert_eq!(r2.kernels, 2 * r1.kernels);
        assert!(r2.cycles > r1.cycles);
    }
}
