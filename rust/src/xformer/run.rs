//! Execute a transformer encoder with every GEMM on the simulated CGRA.
//!
//! Each matmul is symmetrically quantized to int8, executed bit-exactly
//! on the array (requantized output, shift calibrated from the exact
//! accumulator range — deployment would calibrate offline), and
//! dequantized on the host. Softmax / LayerNorm / GELU / residuals run on
//! the host in float, exactly as the paper's system splits the work.

use super::calib::{quantize_with, EncoderQuant, GemmQuant};
use super::model::{EncoderModel, LayerParams};
use crate::gemm::{run_gemm, BatchedGemm, GemmPlan, OutputMode};
use crate::sim::CgraSim;
use crate::util::mat::{MatF32, MatI8};
use anyhow::{ensure, Result};

/// Accumulated accounting for one encoder run on the CGRA.
#[derive(Debug, Clone, Default)]
pub struct CgraEncoderReport {
    /// Total array execution cycles across all GEMM kernels.
    pub cycles: u64,
    /// Total configuration (context distribution) cycles.
    pub config_cycles: u64,
    /// Number of GEMM kernels launched.
    pub kernels: u64,
    /// Kernels that executed as stacked multi-request batches.
    pub stacked_kernels: u64,
    /// Predicted external-memory words avoided by streaming shared
    /// weights once per stacked kernel instead of once per request.
    pub weight_reuse_words: u64,
    /// Host-side element-wise operation count (softmax/LN/GELU/residual
    /// elements; costed by the scalar GPP model in benches).
    pub host_elems: u64,
    /// Worst observed quantization error vs the float reference of any
    /// single GEMM (diagnostic; maintained by the dynamic-calibration
    /// path only — the statically-calibrated batched path skips the
    /// reference GEMM to keep host work off the serving hot path).
    pub max_gemm_err: f32,
}

/// One float GEMM executed on the CGRA via int8 quantization. Returns the
/// dequantized result.
pub fn cgra_matmul_f32(
    sim: &mut CgraSim,
    x: &MatF32,
    w: &MatF32,
    report: &mut CgraEncoderReport,
) -> Result<MatF32> {
    let (qx, sx) = x.quantize();
    let (qw, sw) = w.quantize();
    // Calibrate the requant shift from the exact accumulator range (the
    // host oracle is bit-identical to the array's int math).
    let acc = qx.matmul(&qw);
    let amax = acc.data.iter().map(|v| v.unsigned_abs()).max().unwrap_or(1).max(1);
    let mut shift = 0u8;
    while (amax >> shift) > 127 {
        shift += 1;
    }
    let plan = GemmPlan::new(&sim.cfg, x.rows, x.cols, w.cols, OutputMode::Quant { shift })?;
    let run = run_gemm(sim, &qx, &qw, &plan)?;
    report.cycles += run.outcome.cycles;
    report.config_cycles += run.outcome.config_cycles;
    report.kernels += 1;
    let out = run.c_i8.expect("quant mode").dequant(sx * sw * (1u32 << shift) as f32);
    let err = out.max_abs_diff(&x.matmul(w));
    if err > report.max_gemm_err {
        report.max_gemm_err = err;
    }
    Ok(out)
}

/// Multi-head attention with all five GEMM groups on the CGRA.
fn attention_cgra(
    sim: &mut CgraSim,
    model: &EncoderModel,
    layer: &LayerParams,
    x: &MatF32,
    report: &mut CgraEncoderReport,
) -> Result<MatF32> {
    let cfg = &model.cfg;
    let (s, dh) = (cfg.seq, cfg.d_head());
    let q = cgra_matmul_f32(sim, x, &layer.wq, report)?;
    let k = cgra_matmul_f32(sim, x, &layer.wk, report)?;
    let v = cgra_matmul_f32(sim, x, &layer.wv, report)?;
    let mut ctx = MatF32::zeros(s, cfg.d_model);
    let scale = 1.0 / (dh as f32).sqrt();
    for h in 0..cfg.n_heads {
        let lo = h * dh;
        let (qh, kh, vh) = (q.col_slice(lo, dh), k.col_slice(lo, dh), v.col_slice(lo, dh));
        let mut scores = cgra_matmul_f32(sim, &qh, &kh.transpose(), report)?;
        for val in &mut scores.data {
            *val *= scale;
        }
        let probs = scores.softmax_rows();
        report.host_elems += (s * s) as u64 * 5; // softmax ≈ 5 ops/elem
        let out = cgra_matmul_f32(sim, &probs, &vh, report)?;
        ctx.set_col_slice(lo, &out);
    }
    cgra_matmul_f32(sim, &ctx, &layer.wo, report)
}

/// One statically-calibrated GEMM over a batch of activation blocks
/// sharing the pre-quantized B operand `qw` (a static weight from
/// [`super::calib::LayerQuant`], or a per-request K/V activation
/// quantized with the site's `w_scale`): quantize every block with the
/// site's fixed scale, execute one stacked kernel (B streamed once),
/// dequantize each block. With a single block this is the per-request
/// path; because every scale and shift comes from `spec`, the int8
/// output of a block is bit-identical whichever batch it rides in.
pub fn cgra_matmul_f32_calibrated(
    sim: &mut CgraSim,
    xs: &[&MatF32],
    qw: &MatI8,
    spec: &GemmQuant,
    report: &mut CgraEncoderReport,
) -> Result<Vec<MatF32>> {
    ensure!(!xs.is_empty(), "batched GEMM needs at least one activation block");
    let blocks: Vec<MatI8> = xs.iter().map(|x| quantize_with(x, spec.x_scale)).collect();
    let rows: Vec<usize> = xs.iter().map(|x| x.rows).collect();
    let output = OutputMode::Quant { shift: spec.shift };
    let bg = BatchedGemm::new(&sim.cfg, &rows, qw.rows, qw.cols, output)?;
    let refs: Vec<&MatI8> = blocks.iter().collect();
    let run = bg.run(sim, &refs, qw)?;
    report.cycles += run.outcome.cycles;
    report.config_cycles += run.outcome.config_cycles;
    report.kernels += 1;
    if xs.len() > 1 {
        report.stacked_kernels += 1;
        report.weight_reuse_words += bg.weight_reuse_words();
    }
    // No float-reference diagnostic here: an extra host GEMM per block
    // would double host compute on the batched serving hot path. The
    // dynamic path keeps `max_gemm_err`; accuracy of this path is
    // covered by its encoder-level test.
    Ok(run.blocks.iter().map(|c| c.dequant(spec.dequant_scale())).collect())
}

/// Batched encoder forward pass: every projection and FFN GEMM runs as
/// one stacked `(B·seq) × d_model` kernel across the batch (weights
/// streamed and the context configured once), while the attention score
/// and context GEMMs — and softmax — stay strictly per-sequence, so no
/// request ever attends across the batch. Host float ops (LayerNorm,
/// softmax, GELU, residuals) are computed per request.
///
/// With the shared static calibration `quant`, the outputs are
/// **bit-identical** to running every input through this function alone
/// (`rust/tests/batching_props.rs` pins the property).
pub fn run_encoder_batch(
    sim: &mut CgraSim,
    model: &EncoderModel,
    quant: &EncoderQuant,
    inputs: &[&MatF32],
) -> Result<(Vec<MatF32>, CgraEncoderReport)> {
    ensure!(!inputs.is_empty(), "encoder batch needs at least one input");
    let cfg = &model.cfg;
    for x in inputs {
        ensure!(x.rows == cfg.seq && x.cols == cfg.d_model, "input must be seq×d_model");
    }
    ensure!(
        quant.layers.len() == model.params.layers.len(),
        "calibration does not match the model's layer count"
    );
    let b = inputs.len();
    let (s, dh) = (cfg.seq, cfg.d_head());
    let att_scale = 1.0 / (dh as f32).sqrt();
    let mut report = CgraEncoderReport::default();
    let mut hs: Vec<MatF32> = inputs.iter().map(|x| (*x).clone()).collect();
    for (layer, lq) in model.params.layers.iter().zip(&quant.layers) {
        let ln1: Vec<MatF32> = hs
            .iter()
            .map(|h| h.layernorm_rows(&layer.ln1_gamma, &layer.ln1_beta, 1e-5))
            .collect();
        report.host_elems += (b * s * cfg.d_model) as u64 * 6;
        let refs: Vec<&MatF32> = ln1.iter().collect();
        let q = cgra_matmul_f32_calibrated(sim, &refs, &lq.wq_q, &lq.q, &mut report)?;
        let k = cgra_matmul_f32_calibrated(sim, &refs, &lq.wk_q, &lq.k, &mut report)?;
        let v = cgra_matmul_f32_calibrated(sim, &refs, &lq.wv_q, &lq.v, &mut report)?;
        let mut ctxs: Vec<MatF32> = (0..b).map(|_| MatF32::zeros(s, cfg.d_model)).collect();
        for r in 0..b {
            for hd in 0..cfg.n_heads {
                let lo = hd * dh;
                let (qh, kh, vh) = (
                    q[r].col_slice(lo, dh),
                    k[r].col_slice(lo, dh),
                    v[r].col_slice(lo, dh),
                );
                // K^T and V are per-request activations: quantized at
                // serve time with the site's calibrated w_scale.
                let kht_q = quantize_with(&kh.transpose(), lq.scores.w_scale);
                let mut scores =
                    cgra_matmul_f32_calibrated(sim, &[&qh], &kht_q, &lq.scores, &mut report)?
                        .pop()
                        .expect("one block");
                for val in &mut scores.data {
                    *val *= att_scale;
                }
                let probs = scores.softmax_rows();
                report.host_elems += (s * s) as u64 * 5;
                let vh_q = quantize_with(&vh, lq.attn_v.w_scale);
                let out =
                    cgra_matmul_f32_calibrated(sim, &[&probs], &vh_q, &lq.attn_v, &mut report)?
                        .pop()
                        .expect("one block");
                ctxs[r].set_col_slice(lo, &out);
            }
        }
        let refs: Vec<&MatF32> = ctxs.iter().collect();
        let attn = cgra_matmul_f32_calibrated(sim, &refs, &lq.wo_q, &lq.o, &mut report)?;
        let x1: Vec<MatF32> = hs.iter().zip(&attn).map(|(h, a)| h.add(a)).collect();
        report.host_elems += (b * s * cfg.d_model) as u64;
        let ln2: Vec<MatF32> = x1
            .iter()
            .map(|x| x.layernorm_rows(&layer.ln2_gamma, &layer.ln2_beta, 1e-5))
            .collect();
        report.host_elems += (b * s * cfg.d_model) as u64 * 6;
        let refs: Vec<&MatF32> = ln2.iter().collect();
        let ff1: Vec<MatF32> =
            cgra_matmul_f32_calibrated(sim, &refs, &lq.w1_q, &lq.ff1, &mut report)?
                .into_iter()
                .map(|m| m.gelu())
                .collect();
        report.host_elems += (b * s * cfg.d_ff) as u64 * 8;
        let refs: Vec<&MatF32> = ff1.iter().collect();
        let ff2 = cgra_matmul_f32_calibrated(sim, &refs, &lq.w2_q, &lq.ff2, &mut report)?;
        hs = x1.iter().zip(&ff2).map(|(x, f)| x.add(f)).collect();
        report.host_elems += (b * s * cfg.d_model) as u64;
    }
    Ok((hs, report))
}

/// Full encoder forward pass on the CGRA. Returns the float output and
/// the accounting report.
pub fn run_encoder_on_cgra(
    sim: &mut CgraSim,
    model: &EncoderModel,
    x: &MatF32,
) -> Result<(MatF32, CgraEncoderReport)> {
    let mut report = CgraEncoderReport::default();
    let cfg = &model.cfg;
    let mut h = x.clone();
    for layer in &model.params.layers {
        let ln1 = h.layernorm_rows(&layer.ln1_gamma, &layer.ln1_beta, 1e-5);
        report.host_elems += (cfg.seq * cfg.d_model) as u64 * 6;
        let attn = attention_cgra(sim, model, layer, &ln1, &mut report)?;
        let x1 = h.add(&attn);
        report.host_elems += (cfg.seq * cfg.d_model) as u64;
        let ln2 = x1.layernorm_rows(&layer.ln2_gamma, &layer.ln2_beta, 1e-5);
        report.host_elems += (cfg.seq * cfg.d_model) as u64 * 6;
        let ff1 = cgra_matmul_f32(sim, &ln2, &layer.w1, &mut report)?.gelu();
        report.host_elems += (cfg.seq * cfg.d_ff) as u64 * 8; // gelu ≈ 8 ops
        let ff2 = cgra_matmul_f32(sim, &ff1, &layer.w2, &mut report)?;
        h = x1.add(&ff2);
        report.host_elems += (cfg.seq * cfg.d_model) as u64;
    }
    Ok((h, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::util::rng::XorShiftRng;
    use crate::xformer::model::XformerConfig;

    fn input(cfg: &XformerConfig, seed: u64) -> MatF32 {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(cfg.seq, cfg.d_model);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        x
    }

    #[test]
    fn single_gemm_quantized_close_to_float() {
        let mut sim = CgraSim::new(ArchConfig::default());
        let mut rng = XorShiftRng::new(11);
        let mut x = MatF32::zeros(16, 32);
        let mut w = MatF32::zeros(32, 16);
        for v in &mut x.data {
            *v = rng.normal();
        }
        for v in &mut w.data {
            *v = rng.normal() * 0.2;
        }
        let mut rep = CgraEncoderReport::default();
        let got = cgra_matmul_f32(&mut sim, &x, &w, &mut rep).unwrap();
        let want = x.matmul(&w);
        // Error bound: relative to the output magnitude; int8 symmetric
        // quantization of both operands gives ~1-2% of amax.
        let tol = want.abs_max() * 0.05 + 1e-3;
        assert!(got.max_abs_diff(&want) < tol, "{} vs tol {tol}", got.max_abs_diff(&want));
        assert!(rep.cycles > 0);
        assert_eq!(rep.kernels, 1);
    }

    #[test]
    fn encoder_cgra_close_to_float_reference() {
        // A 1-layer tiny encoder: the CGRA int8 path must track the float
        // reference within accumulated quantization noise.
        let cfg = XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
        let model = EncoderModel::new(cfg, 42);
        let x = input(&cfg, 1);
        let want = model.forward_f32(&x).unwrap();
        let mut sim = CgraSim::new(ArchConfig::default());
        let (got, rep) = run_encoder_on_cgra(&mut sim, &model, &x).unwrap();
        let tol = want.abs_max() * 0.12 + 0.05;
        let err = got.max_abs_diff(&want);
        assert!(err < tol, "int8 path diverged: err {err} vs tol {tol}");
        // 4 proj + 2 per head × 2 heads + 2 FFN = 10 kernels per layer.
        assert_eq!(rep.kernels, 10);
        assert!(rep.cycles > 0 && rep.config_cycles > 0);
        assert!(rep.host_elems > 0);
    }

    #[test]
    fn batched_encoder_bit_identical_to_singletons() {
        use crate::xformer::calib::EncoderQuant;
        let cfg = XformerConfig { n_layers: 1, seq: 12, d_model: 32, n_heads: 2, d_ff: 32 };
        let model = EncoderModel::new(cfg, 42);
        let quant = EncoderQuant::calibrate_seeded(&model, 1);
        let inputs: Vec<MatF32> = (0..3).map(|i| input(&cfg, 10 + i)).collect();
        let refs: Vec<&MatF32> = inputs.iter().collect();
        let mut sim = CgraSim::new(ArchConfig::default());
        let (batched, rep) = run_encoder_batch(&mut sim, &model, &quant, &refs).unwrap();
        assert!(rep.stacked_kernels > 0, "projections/FFN must run stacked");
        assert!(rep.weight_reuse_words > 0);
        for (i, x) in inputs.iter().enumerate() {
            let mut solo = CgraSim::new(ArchConfig::default());
            let (single, _) = run_encoder_batch(&mut solo, &model, &quant, &[x]).unwrap();
            assert_eq!(
                batched[i].data, single[0].data,
                "batched output {i} must be bit-identical to its solo run"
            );
        }
    }

    #[test]
    fn batched_encoder_close_to_float_reference() {
        use crate::xformer::calib::EncoderQuant;
        let cfg = XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
        let model = EncoderModel::new(cfg, 42);
        let quant = EncoderQuant::calibrate_seeded(&model, 9);
        let x = input(&cfg, 1);
        let want = model.forward_f32(&x).unwrap();
        let mut sim = CgraSim::new(ArchConfig::default());
        let (got, rep) = run_encoder_batch(&mut sim, &model, &quant, &[&x]).unwrap();
        // Static calibration (on a *different* seeded input) saturates
        // out-of-range activations, so the tolerance is wider than the
        // per-request dynamic path's — the exactness contract for this
        // path is bit-identity across batch formations, not float
        // tracking (see batching_props.rs).
        let tol = want.abs_max() * 0.3 + 0.15;
        let err = got[0].max_abs_diff(&want);
        assert!(err < tol, "calibrated int8 path diverged: err {err} vs tol {tol}");
        assert_eq!(rep.kernels, 10);
        assert_eq!(rep.stacked_kernels, 0, "a singleton batch stacks nothing");
        assert_eq!(rep.weight_reuse_words, 0);
    }

    #[test]
    fn batched_encoder_amortizes_kernels_and_cycles() {
        use crate::xformer::calib::EncoderQuant;
        let cfg = XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
        let model = EncoderModel::new(cfg, 42);
        let quant = EncoderQuant::calibrate_seeded(&model, 2);
        let inputs: Vec<MatF32> = (0..4).map(|i| input(&cfg, 20 + i)).collect();
        let refs: Vec<&MatF32> = inputs.iter().collect();
        let mut sim_b = CgraSim::new(ArchConfig::default());
        let (_, rep_b) = run_encoder_batch(&mut sim_b, &model, &quant, &refs).unwrap();
        let mut solo_cycles = 0u64;
        let mut solo_kernels = 0u64;
        let mut solo_ext = 0u64;
        for x in &inputs {
            let mut sim = CgraSim::new(ArchConfig::default());
            let (_, rep) = run_encoder_batch(&mut sim, &model, &quant, &[x]).unwrap();
            solo_cycles += rep.cycles + rep.config_cycles;
            solo_kernels += rep.kernels;
            solo_ext += sim.stats.ext_words();
        }
        assert!(rep_b.kernels < solo_kernels, "stacking must launch fewer kernels");
        assert!(
            rep_b.cycles + rep_b.config_cycles < solo_cycles,
            "stacking must cost fewer cycles: {} vs {solo_cycles}",
            rep_b.cycles + rep_b.config_cycles
        );
        assert!(
            sim_b.stats.ext_words() < solo_ext,
            "stacking must cut external traffic: {} vs {solo_ext}",
            sim_b.stats.ext_words()
        );
    }

    #[test]
    fn report_scales_with_layers() {
        let mk = |layers| {
            let cfg =
                XformerConfig { n_layers: layers, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
            let model = EncoderModel::new(cfg, 42);
            let x = input(&cfg, 1);
            let mut sim = CgraSim::new(ArchConfig::default());
            run_encoder_on_cgra(&mut sim, &model, &x).unwrap().1
        };
        let r1 = mk(1);
        let r2 = mk(2);
        assert_eq!(r2.kernels, 2 * r1.kernels);
        assert!(r2.cycles > r1.cycles);
    }
}
