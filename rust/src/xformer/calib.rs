//! Static (offline) quantization calibration for serving.
//!
//! The dynamic path ([`super::run::cgra_matmul_f32`]) calibrates every
//! GEMM's activation scale and requant shift from the request it is
//! serving. That is fine for one request, but it makes the int8 output a
//! function of *which* requests share a kernel: a stacked batch sees the
//! whole batch's activation range, so batched and per-request runs would
//! requantize differently. Deployments solve this the standard way —
//! calibrate once, offline, per model — and that is what this module
//! implements: one [`GemmQuant`] (activation scale, weight scale,
//! requant shift) per GEMM site per layer, computed from a
//! representative input by mirroring the serving dataflow on the host.
//!
//! Because every scale and shift is fixed per (model, layer, site), the
//! quantized operands and the requant epilogue are *batch-invariant*:
//! the stacked GEMM's row-blocks are bit-identical to per-request runs
//! (the property `rust/tests/batching_props.rs` pins down). Activations
//! outside the calibrated range saturate symmetrically at ±127, exactly
//! like the hardware's clamping quantizer.

use super::decoder::{causal_mask, DecoderModel};
use super::model::EncoderModel;
use crate::util::mat::{MatF32, MatI8, MatI32};
use crate::util::quant::requant_shift;
use crate::util::rng::XorShiftRng;

/// Quantization parameters for one GEMM site.
#[derive(Debug, Clone, Copy)]
pub struct GemmQuant {
    /// Activation (A-operand) scale: `x ≈ q · x_scale`.
    pub x_scale: f32,
    /// B-operand scale (weights, or K/V activations for the attention
    /// score and context GEMMs).
    pub w_scale: f32,
    /// Requant right-shift applied to the int32 accumulators by the
    /// array's ACCOUT epilogue.
    pub shift: u8,
}

impl GemmQuant {
    /// Scale that maps the requantized int8 output back to float.
    pub fn dequant_scale(&self) -> f32 {
        self.x_scale * self.w_scale * (1u32 << self.shift) as f32
    }
}

/// Per-layer site parameters, one per GEMM group of the encoder layer.
/// The per-head score and context GEMMs share one site each (all heads
/// of a layer use the same parameters).
#[derive(Debug, Clone)]
pub struct LayerQuant {
    pub q: GemmQuant,
    pub k: GemmQuant,
    pub v: GemmQuant,
    pub scores: GemmQuant,
    pub attn_v: GemmQuant,
    pub o: GemmQuant,
    pub ff1: GemmQuant,
    pub ff2: GemmQuant,
    /// The six static weight matrices pre-quantized with their site's
    /// `w_scale` (weights are fixed per model, so quantizing them per
    /// serve call would repeat an O(K·N) host pass with an identical
    /// result every time). The score/context GEMMs' B operands are
    /// per-request activations and are quantized at serve time.
    pub wq_q: MatI8,
    pub wk_q: MatI8,
    pub wv_q: MatI8,
    pub wo_q: MatI8,
    pub w1_q: MatI8,
    pub w2_q: MatI8,
}

/// Static calibration for a whole encoder (index-aligned with the
/// model's layers).
#[derive(Debug, Clone)]
pub struct EncoderQuant {
    pub layers: Vec<LayerQuant>,
}

/// Quantize with a fixed scale, saturating symmetrically at ±127 (the
/// same clamping quantizer as [`MatF32::quantize`], but with the scale
/// supplied instead of derived from this tensor).
pub fn quantize_with(x: &MatF32, scale: f32) -> MatI8 {
    debug_assert!(scale > 0.0, "quantization scale must be positive");
    MatI8 {
        rows: x.rows,
        cols: x.cols,
        data: x
            .data
            .iter()
            .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
            .collect(),
    }
}

/// Calibrate one site shared by several (x, w) pairs (the per-head GEMMs
/// of one layer): scales from the max range over all pairs, shift from
/// the max exact accumulator, outputs fed forward through the same
/// requant+dequant the array applies.
fn site(pairs: &[(&MatF32, &MatF32)]) -> (GemmQuant, Vec<MatF32>) {
    let amax_x = pairs.iter().fold(0.0f32, |m, (x, _)| m.max(x.abs_max())).max(1e-8);
    let amax_w = pairs.iter().fold(0.0f32, |m, (_, w)| m.max(w.abs_max())).max(1e-8);
    let x_scale = amax_x / 127.0;
    let w_scale = amax_w / 127.0;
    let accs: Vec<MatI32> = pairs
        .iter()
        .map(|(x, w)| quantize_with(x, x_scale).matmul(&quantize_with(w, w_scale)))
        .collect();
    let amax_acc = accs
        .iter()
        .flat_map(|a| a.data.iter())
        .map(|v| v.unsigned_abs())
        .max()
        .unwrap_or(1)
        .max(1);
    let mut shift = 0u8;
    while (amax_acc >> shift) > 127 {
        shift += 1;
    }
    let spec = GemmQuant { x_scale, w_scale, shift };
    let outs = accs
        .iter()
        .map(|acc| {
            MatI8 {
                rows: acc.rows,
                cols: acc.cols,
                data: acc.data.iter().map(|&v| requant_shift(v, shift)).collect(),
            }
            .dequant(spec.dequant_scale())
        })
        .collect();
    (spec, outs)
}

/// Single-pair convenience wrapper around [`site`].
fn site1(x: &MatF32, w: &MatF32) -> (GemmQuant, MatF32) {
    let (spec, mut outs) = site(&[(x, w)]);
    (spec, outs.pop().expect("one site output"))
}

impl EncoderQuant {
    /// Calibrate from a representative input by mirroring the serving
    /// path on the host: at every GEMM site quantize with the observed
    /// range, compute the exact int32 accumulators, choose the smallest
    /// shift that fits int8, and feed the requantized-then-dequantized
    /// result forward (so downstream sites see serve-time statistics,
    /// not the float reference).
    pub fn calibrate(model: &EncoderModel, x_cal: &MatF32) -> Self {
        Self::calibrate_impl(&model.cfg, &model.params.layers, x_cal, false)
    }

    /// Causal-attention calibration for a [`DecoderModel`]: identical
    /// to [`Self::calibrate`] except the score matrices are causally
    /// masked before softmax, so every site sees the statistics the
    /// prefill/decode serving paths will produce. The representative
    /// input is a full-context (`cfg.seq`) sequence; shorter serve-time
    /// prefixes reuse the same fixed scales (that fixedness is what
    /// makes cached decode bit-identical to one-shot prefill).
    pub fn calibrate_causal(model: &DecoderModel, x_cal: &MatF32) -> Self {
        Self::calibrate_impl(&model.cfg, &model.params.layers, x_cal, true)
    }

    fn calibrate_impl(
        cfg: &crate::xformer::XformerConfig,
        model_layers: &[super::model::LayerParams],
        x_cal: &MatF32,
        causal: bool,
    ) -> Self {
        let (s, dh) = (x_cal.rows, cfg.d_head());
        let att_scale = 1.0 / (dh as f32).sqrt();
        let mut h = x_cal.clone();
        let mut layers = Vec::with_capacity(model_layers.len());
        for layer in model_layers {
            let ln1 = h.layernorm_rows(&layer.ln1_gamma, &layer.ln1_beta, 1e-5);
            let (q_spec, q) = site1(&ln1, &layer.wq);
            let (k_spec, k) = site1(&ln1, &layer.wk);
            let (v_spec, v) = site1(&ln1, &layer.wv);

            let mut qh = Vec::with_capacity(cfg.n_heads);
            let mut kht = Vec::with_capacity(cfg.n_heads);
            let mut vh = Vec::with_capacity(cfg.n_heads);
            for hd in 0..cfg.n_heads {
                let lo = hd * dh;
                qh.push(q.col_slice(lo, dh));
                kht.push(k.col_slice(lo, dh).transpose());
                vh.push(v.col_slice(lo, dh));
            }
            let score_pairs: Vec<(&MatF32, &MatF32)> =
                qh.iter().zip(&kht).map(|(a, b)| (a, b)).collect();
            let (scores_spec, scores) = site(&score_pairs);
            let probs: Vec<MatF32> = scores
                .into_iter()
                .map(|mut sc| {
                    for val in &mut sc.data {
                        *val *= att_scale;
                    }
                    if causal {
                        causal_mask(&mut sc, 0);
                    }
                    sc.softmax_rows()
                })
                .collect();
            let av_pairs: Vec<(&MatF32, &MatF32)> =
                probs.iter().zip(&vh).map(|(a, b)| (a, b)).collect();
            let (attn_spec, head_outs) = site(&av_pairs);
            let mut ctx = MatF32::zeros(s, cfg.d_model);
            for (hd, out) in head_outs.iter().enumerate() {
                ctx.set_col_slice(hd * dh, out);
            }
            let (o_spec, attn) = site1(&ctx, &layer.wo);
            let x1 = h.add(&attn);
            let ln2 = x1.layernorm_rows(&layer.ln2_gamma, &layer.ln2_beta, 1e-5);
            let (ff1_spec, f1) = site1(&ln2, &layer.w1);
            let f1g = f1.gelu();
            let (ff2_spec, f2) = site1(&f1g, &layer.w2);
            h = x1.add(&f2);
            layers.push(LayerQuant {
                q: q_spec,
                k: k_spec,
                v: v_spec,
                scores: scores_spec,
                attn_v: attn_spec,
                o: o_spec,
                ff1: ff1_spec,
                ff2: ff2_spec,
                wq_q: quantize_with(&layer.wq, q_spec.w_scale),
                wk_q: quantize_with(&layer.wk, k_spec.w_scale),
                wv_q: quantize_with(&layer.wv, v_spec.w_scale),
                wo_q: quantize_with(&layer.wo, o_spec.w_scale),
                w1_q: quantize_with(&layer.w1, ff1_spec.w_scale),
                w2_q: quantize_with(&layer.w2, ff2_spec.w_scale),
            });
        }
        Self { layers }
    }

    /// Calibrate with a deterministic synthetic input drawn from `seed`
    /// (the same activation distribution the workload generator and the
    /// encoder tests use), so a `(model, seed)` pair fully determines
    /// the serving numerics.
    pub fn calibrate_seeded(model: &EncoderModel, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(model.cfg.seq, model.cfg.d_model);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        Self::calibrate(model, &x)
    }

    /// [`Self::calibrate_causal`] with a deterministic synthetic
    /// full-context input drawn from `seed` (the decoder-side analog of
    /// [`Self::calibrate_seeded`]).
    pub fn calibrate_causal_seeded(model: &DecoderModel, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(model.cfg.seq, model.cfg.d_model);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        Self::calibrate_causal(model, &x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::xformer::XformerConfig;

    fn tiny() -> EncoderModel {
        EncoderModel::new(
            XformerConfig { n_layers: 2, seq: 8, d_model: 16, n_heads: 2, d_ff: 32 },
            7,
        )
    }

    #[test]
    fn calibration_covers_every_layer_with_sane_specs() {
        let model = tiny();
        let quant = EncoderQuant::calibrate_seeded(&model, 11);
        assert_eq!(quant.layers.len(), 2);
        for lq in &quant.layers {
            for spec in [lq.q, lq.k, lq.v, lq.scores, lq.attn_v, lq.o, lq.ff1, lq.ff2] {
                assert!(spec.x_scale > 0.0 && spec.w_scale > 0.0);
                assert!(spec.shift < 32);
                assert!(spec.dequant_scale() > 0.0);
            }
        }
    }

    #[test]
    fn calibration_is_deterministic_per_seed() {
        let model = tiny();
        let a = EncoderQuant::calibrate_seeded(&model, 3);
        let b = EncoderQuant::calibrate_seeded(&model, 3);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.q.x_scale, lb.q.x_scale);
            assert_eq!(la.ff2.shift, lb.ff2.shift);
            assert_eq!(la.scores.w_scale, lb.scores.w_scale);
        }
    }

    #[test]
    fn causal_calibration_is_deterministic_and_differs_from_bidirectional() {
        use crate::xformer::decoder::DecoderModel;
        let cfg = XformerConfig { n_layers: 2, seq: 8, d_model: 16, n_heads: 2, d_ff: 32 };
        let dec = DecoderModel::new(cfg, 7);
        let a = EncoderQuant::calibrate_causal_seeded(&dec, 11);
        let b = EncoderQuant::calibrate_causal_seeded(&dec, 11);
        assert_eq!(a.layers.len(), 2);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.q.x_scale, lb.q.x_scale);
            assert_eq!(la.attn_v.x_scale, lb.attn_v.x_scale);
            assert_eq!(la.ff2.shift, lb.ff2.shift);
        }
        // Masking reshapes the attention-probability statistics, so at
        // least the attention-context site must calibrate differently
        // from the bidirectional pass over the same weights and input.
        let enc = EncoderModel::new(cfg, 7);
        let bidi = EncoderQuant::calibrate_seeded(&enc, 11);
        assert!(
            a.layers
                .iter()
                .zip(&bidi.layers)
                .any(|(ca, cb)| ca.attn_v.x_scale != cb.attn_v.x_scale
                    || ca.o.x_scale != cb.o.x_scale
                    || ca.ff1.x_scale != cb.ff1.x_scale),
            "causal calibration must not be identical to bidirectional"
        );
    }

    #[test]
    fn quantize_with_saturates_out_of_range() {
        let m = MatF32::from_slice(1, 3, &[0.5, 10.0, -10.0]);
        let q = quantize_with(&m, 1.0 / 127.0);
        assert_eq!(q.data[1], 127, "over-range must clamp high");
        assert_eq!(q.data[2], -127, "over-range must clamp low");
        assert_eq!(q.data[0], 64, "in-range rounds normally");
    }

    #[test]
    fn fixed_scale_matches_dynamic_quantize_at_own_range() {
        let m = MatF32::from_slice(2, 2, &[0.25, -1.0, 0.75, 1.0]);
        let (q_dyn, scale) = m.quantize();
        let q_fix = quantize_with(&m, scale);
        assert_eq!(q_dyn.data, q_fix.data);
    }
}
