//! Transformer workloads (§IV-B): the encoder layer lowered to the GEMM
//! sequence the CGRA accelerates, with host-side softmax / LayerNorm /
//! GELU (the paper's system accelerates GEMM; everything else runs on the
//! host CPU of Fig. 1 and is costed by the scalar GPP model).
//!
//! Quantization scheme: symmetric per-tensor int8 for every GEMM operand
//! (weights offline, activations per layer), exact int32 accumulation on
//! the array, float dequantization on the host between ops. The float
//! reference path ([`model::EncoderModel::forward_f32`]) is the oracle
//! the quantized CGRA path is compared against (and itself matches the
//! AOT-compiled JAX model via the runtime, FIG-E2E).
//!
//! Two serving paths exist: [`run::run_encoder_on_cgra`] calibrates each
//! GEMM dynamically from the request it serves (the single-request
//! reference), while [`run::run_encoder_batch`] uses the static
//! per-model calibration in [`calib`] so same-model requests can stack
//! into one `(B·seq) × d_model` GEMM per projection/FFN site with
//! bit-identical per-request outputs (attention stays per-sequence).
//!
//! Generation workloads add a third shape: [`decoder::DecoderModel`] is
//! the causal (decoder-only) float reference, calibrated statically via
//! [`calib::EncoderQuant::calibrate_causal`]; the quantized prefill and
//! KV-cached decode-step paths live in [`crate::decode`].

pub mod calib;
pub mod decoder;
pub mod model;
pub mod run;

pub use calib::{quantize_with, EncoderQuant, GemmQuant, LayerQuant};
pub use decoder::{causal_mask, DecoderModel};
pub use model::{EncoderModel, EncoderParams, XformerConfig};
pub use run::{
    cgra_matmul_f32_calibrated, run_encoder_batch, run_encoder_on_cgra, CgraEncoderReport,
};
