//! Transformer workloads (§IV-B): the encoder layer lowered to the GEMM
//! sequence the CGRA accelerates, with host-side softmax / LayerNorm /
//! GELU (the paper's system accelerates GEMM; everything else runs on the
//! host CPU of Fig. 1 and is costed by the scalar GPP model).
//!
//! Quantization scheme: symmetric per-tensor int8 for every GEMM operand
//! (weights offline, activations per layer), exact int32 accumulation on
//! the array, float dequantization on the host between ops. The float
//! reference path ([`model::EncoderModel::forward_f32`]) is the oracle
//! the quantized CGRA path is compared against (and itself matches the
//! AOT-compiled JAX model via the runtime, FIG-E2E).

pub mod model;
pub mod run;

pub use model::{EncoderModel, EncoderParams, XformerConfig};
pub use run::{run_encoder_on_cgra, CgraEncoderReport};
