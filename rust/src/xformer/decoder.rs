//! Decoder-only transformer (autoregressive generation): the float
//! reference for the decode subsystem.
//!
//! A decoder layer is the encoder layer of [`super::model`] with
//! **causal** self-attention: position `i` attends to positions
//! `0..=i` only. The parameter layout is identical ([`EncoderParams`]),
//! so the same weight blobs, initialization and calibration machinery
//! serve both; what changes is the attention mask — and, downstream,
//! the serving shape: prefill runs the whole prompt as one causal
//! forward (a stacked GEMM job), while each decode step runs a single
//! new row against the cached K/V of everything before it
//! ([`crate::decode`]).
//!
//! Unlike the encoder reference, the causal forward accepts **any**
//! row count up to the configured context length: a prefix of a
//! sequence is itself a valid input, and — because every per-row
//! operation (LayerNorm, residual, GELU, the calibrated GEMM
//! row-blocks) is row-independent and causal attention never looks
//! ahead — the outputs for rows `0..p` of a length-`n` forward are
//! bit-identical to a length-`p` forward over the same prefix. That
//! prefix property is what makes KV-cached decode exact rather than
//! approximate (`rust/tests/decode_props.rs` pins it down on the
//! quantized path).

use super::model::{EncoderParams, LayerParams, XformerConfig};
use crate::util::mat::MatF32;
use anyhow::{ensure, Result};

/// Mask the strict upper triangle of a square-ish score matrix to
/// `-inf`: row `i` (query position `base + i`) may only see key columns
/// `0..=base + i`. `base` offsets the query rows inside the key axis
/// (0 for a full forward; the prompt length for a decode suffix).
pub fn causal_mask(scores: &mut MatF32, base: usize) {
    for r in 0..scores.rows {
        let visible = base + r + 1;
        for c in visible..scores.cols {
            *scores.at_mut(r, c) = f32::NEG_INFINITY;
        }
    }
}

/// The float decoder (reference path for generation workloads).
#[derive(Debug, Clone)]
pub struct DecoderModel {
    pub cfg: XformerConfig,
    pub params: EncoderParams,
}

impl DecoderModel {
    /// Deterministic init from a seed — the same Xavier-ish scheme (and
    /// therefore the same weights for the same seed) as the encoder.
    pub fn new(cfg: XformerConfig, seed: u64) -> Self {
        Self { cfg, params: EncoderParams::init(&cfg, seed) }
    }

    /// Causal multi-head self-attention over `x` (`s × d_model`, any
    /// `s ≥ 1`).
    pub fn attention_causal_f32(&self, layer: &LayerParams, x: &MatF32) -> MatF32 {
        let cfg = &self.cfg;
        let (s, dh) = (x.rows, cfg.d_head());
        let q = x.matmul(&layer.wq);
        let k = x.matmul(&layer.wk);
        let v = x.matmul(&layer.wv);
        let mut ctx = MatF32::zeros(s, cfg.d_model);
        let scale = 1.0 / (dh as f32).sqrt();
        for h in 0..cfg.n_heads {
            let lo = h * dh;
            let (qh, kh, vh) = (q.col_slice(lo, dh), k.col_slice(lo, dh), v.col_slice(lo, dh));
            let mut scores = qh.matmul(&kh.transpose());
            for val in &mut scores.data {
                *val *= scale;
            }
            causal_mask(&mut scores, 0);
            let probs = scores.softmax_rows();
            let out = probs.matmul(&vh);
            ctx.set_col_slice(lo, &out);
        }
        ctx.matmul(&layer.wo)
    }

    /// One decoder layer (pre-LN residual structure, causal attention).
    pub fn layer_causal_f32(&self, layer: &LayerParams, x: &MatF32) -> MatF32 {
        let ln1 = x.layernorm_rows(&layer.ln1_gamma, &layer.ln1_beta, 1e-5);
        let attn = self.attention_causal_f32(layer, &ln1);
        let x1 = x.add(&attn);
        let ln2 = x1.layernorm_rows(&layer.ln2_gamma, &layer.ln2_beta, 1e-5);
        let ff = ln2.matmul(&layer.w1).gelu().matmul(&layer.w2);
        x1.add(&ff)
    }

    /// Full causal forward pass in float over any `s × d_model` input
    /// with `1 ≤ s ≤ cfg.seq` (`cfg.seq` is the context limit, not a
    /// fixed shape as in the encoder).
    pub fn forward_causal_f32(&self, x: &MatF32) -> Result<MatF32> {
        ensure!(x.cols == self.cfg.d_model, "input width must be d_model");
        ensure!(
            x.rows >= 1 && x.rows <= self.cfg.seq,
            "input rows must be in 1..={} (the context limit)",
            self.cfg.seq
        );
        let mut h = x.clone();
        for layer in &self.params.layers {
            h = self.layer_causal_f32(layer, &h);
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;
    use crate::xformer::model::EncoderModel;

    fn cfg() -> XformerConfig {
        XformerConfig { n_layers: 2, seq: 12, d_model: 16, n_heads: 2, d_ff: 32 }
    }

    fn input(rows: usize, cols: usize, seed: u64) -> MatF32 {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(rows, cols);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        x
    }

    #[test]
    fn causal_mask_blocks_future_columns() {
        let mut s = MatF32::zeros(2, 4);
        causal_mask(&mut s, 0);
        assert_eq!(s.at(0, 0), 0.0);
        assert_eq!(s.at(0, 1), f32::NEG_INFINITY);
        assert_eq!(s.at(1, 1), 0.0);
        assert_eq!(s.at(1, 2), f32::NEG_INFINITY);
        // A decode row at base 3 sees all four cached columns.
        let mut d = MatF32::zeros(1, 4);
        causal_mask(&mut d, 3);
        assert!(d.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prefix_rows_are_bit_identical() {
        // The causal forward over a prefix equals the same rows of the
        // full forward — the property KV caching relies on.
        let m = DecoderModel::new(cfg(), 7);
        let x = input(10, 16, 3);
        let full = m.forward_causal_f32(&x).unwrap();
        for p in 1..=10usize {
            let mut prefix = MatF32::zeros(p, 16);
            prefix.data.copy_from_slice(&x.data[..p * 16]);
            let got = m.forward_causal_f32(&prefix).unwrap();
            for r in 0..p {
                assert_eq!(got.row(r), full.row(r), "prefix {p} row {r} diverged");
            }
        }
    }

    #[test]
    fn masking_changes_results_vs_bidirectional() {
        // Same weights as an encoder (same seed/init): all rows except
        // the last must differ, since they can no longer see the future
        // (the last row sees everything either way, but its inputs in
        // deeper layers differ too for n_layers > 1).
        let c = cfg();
        let dec = DecoderModel::new(c, 7);
        let enc = EncoderModel::new(XformerConfig { seq: 8, ..c }, 7);
        let x = input(8, 16, 5);
        let causal = dec.forward_causal_f32(&x).unwrap();
        let bidi = enc.forward_f32(&x).unwrap();
        assert!(causal.max_abs_diff(&bidi) > 1e-4);
    }

    #[test]
    fn rejects_out_of_range_shapes() {
        let m = DecoderModel::new(cfg(), 1);
        assert!(m.forward_causal_f32(&MatF32::zeros(13, 16)).is_err(), "beyond context");
        assert!(m.forward_causal_f32(&MatF32::zeros(4, 8)).is_err(), "wrong width");
    }

    #[test]
    fn same_seed_shares_weights_with_encoder() {
        let c = cfg();
        let dec = DecoderModel::new(c, 42);
        let enc = EncoderModel::new(c, 42);
        assert_eq!(dec.params.layers[0].wq.data, enc.params.layers[0].wq.data);
        assert_eq!(dec.params.layers[1].w2.data, enc.params.layers[1].w2.data);
    }
}
