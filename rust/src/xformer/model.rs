//! Host-side transformer encoder model (float reference + parameters).
//!
//! Mirrors `python/compile/model.py` operation-for-operation so the rust
//! float path, the CGRA int8 path and the AOT-compiled JAX artifact can
//! be cross-checked three ways.

use crate::util::mat::MatF32;
use crate::util::rng::XorShiftRng;
use anyhow::{ensure, Result};

/// Encoder hyper-parameters (a tiny edge-class encoder by default).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XformerConfig {
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub seq: usize,
}

impl Default for XformerConfig {
    fn default() -> Self {
        Self { d_model: 64, n_heads: 4, d_ff: 128, n_layers: 2, seq: 32 }
    }
}

impl XformerConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (weights only; biases omitted in this
    /// model, as in the JAX artifact).
    pub fn param_count(&self) -> usize {
        // Per layer: Wq, Wk, Wv, Wo (d×d each) + W1 (d×ff) + W2 (ff×d)
        // + 2 LayerNorm scale/shift pairs.
        self.n_layers * (4 * self.d_model * self.d_model
            + 2 * self.d_model * self.d_ff
            + 4 * self.d_model)
    }

    /// GEMM MAC count for one forward pass (the CGRA-accelerated part).
    pub fn gemm_macs(&self) -> u64 {
        let (s, d, f) = (self.seq as u64, self.d_model as u64, self.d_ff as u64);
        let h = self.n_heads as u64;
        let dh = d / h;
        let per_layer = 4 * s * d * d // Q,K,V,O projections
            + h * (s * s * dh) * 2 // scores + context
            + 2 * s * d * f; // FFN
        per_layer * self.n_layers as u64
    }
}

/// One encoder layer's weights.
#[derive(Debug, Clone)]
pub struct LayerParams {
    pub wq: MatF32,
    pub wk: MatF32,
    pub wv: MatF32,
    pub wo: MatF32,
    pub w1: MatF32,
    pub w2: MatF32,
    pub ln1_gamma: Vec<f32>,
    pub ln1_beta: Vec<f32>,
    pub ln2_gamma: Vec<f32>,
    pub ln2_beta: Vec<f32>,
}

/// All model weights.
#[derive(Debug, Clone)]
pub struct EncoderParams {
    pub layers: Vec<LayerParams>,
}

impl EncoderParams {
    /// Xavier-ish random initialization from a seed (deterministic; the
    /// same seed reproduces the model across runs and matches the
    /// AOT-export path which loads these weights from the manifest).
    pub fn init(cfg: &XformerConfig, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let mut mat = |rows: usize, cols: usize| {
            let scale = (2.0 / (rows + cols) as f32).sqrt();
            let mut m = MatF32::zeros(rows, cols);
            for v in &mut m.data {
                *v = rng.normal() * scale;
            }
            m
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerParams {
                wq: mat(cfg.d_model, cfg.d_model),
                wk: mat(cfg.d_model, cfg.d_model),
                wv: mat(cfg.d_model, cfg.d_model),
                wo: mat(cfg.d_model, cfg.d_model),
                w1: mat(cfg.d_model, cfg.d_ff),
                w2: mat(cfg.d_ff, cfg.d_model),
                ln1_gamma: vec![1.0; cfg.d_model],
                ln1_beta: vec![0.0; cfg.d_model],
                ln2_gamma: vec![1.0; cfg.d_model],
                ln2_beta: vec![0.0; cfg.d_model],
            })
            .collect();
        Self { layers }
    }
}

impl EncoderParams {
    /// Load from the AOT export's flat f32 blob (manifest order per
    /// layer: ln1_gamma, ln1_beta, wq, wk, wv, wo, ln2_gamma, ln2_beta,
    /// w1, w2 — the contract shared with `python/compile/model.py`).
    pub fn from_blob(cfg: &XformerConfig, blob: &[f32]) -> Result<Self> {
        let mut off = 0usize;
        let mut take = |n: usize| -> Result<Vec<f32>> {
            ensure!(off + n <= blob.len(), "param blob too short at offset {off}");
            let v = blob[off..off + n].to_vec();
            off += n;
            Ok(v)
        };
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            let ln1_gamma = take(d)?;
            let ln1_beta = take(d)?;
            let wq = MatF32 { rows: d, cols: d, data: take(d * d)? };
            let wk = MatF32 { rows: d, cols: d, data: take(d * d)? };
            let wv = MatF32 { rows: d, cols: d, data: take(d * d)? };
            let wo = MatF32 { rows: d, cols: d, data: take(d * d)? };
            let ln2_gamma = take(d)?;
            let ln2_beta = take(d)?;
            let w1 = MatF32 { rows: d, cols: f, data: take(d * f)? };
            let w2 = MatF32 { rows: f, cols: d, data: take(f * d)? };
            layers.push(LayerParams {
                wq,
                wk,
                wv,
                wo,
                w1,
                w2,
                ln1_gamma,
                ln1_beta,
                ln2_gamma,
                ln2_beta,
            });
        }
        ensure!(off == blob.len(), "param blob has {} trailing words", blob.len() - off);
        Ok(Self { layers })
    }
}

/// The float encoder (reference path).
#[derive(Debug, Clone)]
pub struct EncoderModel {
    pub cfg: XformerConfig,
    pub params: EncoderParams,
}

impl EncoderModel {
    pub fn new(cfg: XformerConfig, seed: u64) -> Self {
        Self { cfg, params: EncoderParams::init(&cfg, seed) }
    }

    /// Build from the AOT artifact's parameter blob.
    pub fn from_blob(cfg: XformerConfig, blob: &[f32]) -> Result<Self> {
        Ok(Self { cfg, params: EncoderParams::from_blob(&cfg, blob)? })
    }

    /// Multi-head self-attention in float (reference).
    pub fn attention_f32(&self, layer: &LayerParams, x: &MatF32) -> MatF32 {
        let cfg = &self.cfg;
        let (s, dh) = (cfg.seq, cfg.d_head());
        let q = x.matmul(&layer.wq);
        let k = x.matmul(&layer.wk);
        let v = x.matmul(&layer.wv);
        let mut ctx = MatF32::zeros(s, cfg.d_model);
        let scale = 1.0 / (dh as f32).sqrt();
        for h in 0..cfg.n_heads {
            let lo = h * dh;
            let (qh, kh, vh) = (q.col_slice(lo, dh), k.col_slice(lo, dh), v.col_slice(lo, dh));
            let mut scores = qh.matmul(&kh.transpose());
            for v in &mut scores.data {
                *v *= scale;
            }
            let probs = scores.softmax_rows();
            let out = probs.matmul(&vh);
            ctx.set_col_slice(lo, &out);
        }
        ctx.matmul(&layer.wo)
    }

    /// One encoder layer (pre-LN residual structure).
    pub fn layer_f32(&self, layer: &LayerParams, x: &MatF32) -> MatF32 {
        let ln1 = x.layernorm_rows(&layer.ln1_gamma, &layer.ln1_beta, 1e-5);
        let attn = self.attention_f32(layer, &ln1);
        let x1 = x.add(&attn);
        let ln2 = x1.layernorm_rows(&layer.ln2_gamma, &layer.ln2_beta, 1e-5);
        let ff = ln2.matmul(&layer.w1).gelu().matmul(&layer.w2);
        x1.add(&ff)
    }

    /// Full forward pass in float.
    pub fn forward_f32(&self, x: &MatF32) -> Result<MatF32> {
        ensure!(
            x.rows == self.cfg.seq && x.cols == self.cfg.d_model,
            "input must be seq×d_model"
        );
        let mut h = x.clone();
        for layer in &self.params.layers {
            h = self.layer_f32(layer, &h);
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(cfg: &XformerConfig, seed: u64) -> MatF32 {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(cfg.seq, cfg.d_model);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        x
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let cfg = XformerConfig::default();
        let m = EncoderModel::new(cfg, 7);
        let x = input(&cfg, 9);
        let y1 = m.forward_f32(&x).unwrap();
        let y2 = m.forward_f32(&x).unwrap();
        assert_eq!(y1.rows, cfg.seq);
        assert_eq!(y1.cols, cfg.d_model);
        assert_eq!(y1.max_abs_diff(&y2), 0.0);
        assert!(y1.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn different_seeds_differ() {
        let cfg = XformerConfig::default();
        let x = input(&cfg, 9);
        let y1 = EncoderModel::new(cfg, 1).forward_f32(&x).unwrap();
        let y2 = EncoderModel::new(cfg, 2).forward_f32(&x).unwrap();
        assert!(y1.max_abs_diff(&y2) > 1e-3);
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // With Wv = I and Wo = I, each attention output row lies in the
        // convex hull of the value rows — check max bound.
        let cfg = XformerConfig { n_layers: 1, ..Default::default() };
        let mut m = EncoderModel::new(cfg, 3);
        let d = cfg.d_model;
        let mut eye = MatF32::zeros(d, d);
        for i in 0..d {
            *eye.at_mut(i, i) = 1.0;
        }
        m.params.layers[0].wv = eye.clone();
        m.params.layers[0].wo = eye;
        let x = input(&cfg, 5);
        let out = m.attention_f32(&m.params.layers[0].clone(), &x);
        let xmax = x.abs_max();
        assert!(out.abs_max() <= xmax + 1e-4);
    }

    #[test]
    fn param_count_matches_layout() {
        let cfg = XformerConfig::default();
        let p = EncoderParams::init(&cfg, 1);
        let counted: usize = p
            .layers
            .iter()
            .map(|l| {
                l.wq.data.len()
                    + l.wk.data.len()
                    + l.wv.data.len()
                    + l.wo.data.len()
                    + l.w1.data.len()
                    + l.w2.data.len()
                    + l.ln1_gamma.len()
                    + l.ln1_beta.len()
                    + l.ln2_gamma.len()
                    + l.ln2_beta.len()
            })
            .sum();
        assert_eq!(counted, cfg.param_count());
    }

    #[test]
    fn gemm_macs_positive_and_scales() {
        let small = XformerConfig::default().gemm_macs();
        let big = XformerConfig { d_model: 128, d_ff: 256, ..Default::default() }.gemm_macs();
        assert!(big > 3 * small);
    }
}
