//! Minimal property-testing harness (no `proptest` in the vendored crate
//! set). A property is a closure over a seeded [`XorShiftRng`]; the harness
//! runs it for `cases` seeds and reports the first failing seed so a
//! failure is reproducible with `prop_check_seed`.

use super::rng::XorShiftRng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Number of random cases to execute.
    pub cases: u64,
    /// Base seed; case `i` uses seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 64, base_seed: 0xC64A_ED6E }
    }
}

/// Outcome of a single property case.
pub enum CaseResult {
    /// Property held.
    Ok,
    /// Property failed with a description of the counterexample.
    Fail(String),
    /// Case was vacuous (generated inputs outside the property's domain);
    /// does not count towards the case budget.
    Discard,
}

/// Run `prop` for `cfg.cases` seeded cases; panic with the failing seed and
/// message on the first failure.
///
/// ```no_run
/// // (no_run: doctest binaries lack the xla_extension rpath)
/// use cgra_edge::util::prop::{prop_check, PropConfig, CaseResult};
/// prop_check("addition commutes", PropConfig::default(), |rng| {
///     let (a, b) = (rng.next_u32() as u64, rng.next_u32() as u64);
///     if a + b == b + a { CaseResult::Ok } else { CaseResult::Fail(format!("{a} {b}")) }
/// });
/// ```
pub fn prop_check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut XorShiftRng) -> CaseResult,
{
    let mut executed = 0u64;
    let mut attempts = 0u64;
    let max_attempts = cfg.cases * 16;
    while executed < cfg.cases {
        if attempts >= max_attempts {
            panic!(
                "property '{name}': too many discards ({attempts} attempts for {executed} cases)"
            );
        }
        let seed = cfg.base_seed.wrapping_add(attempts);
        attempts += 1;
        let mut rng = XorShiftRng::new(seed);
        match prop(&mut rng) {
            CaseResult::Ok => executed += 1,
            CaseResult::Discard => {}
            CaseResult::Fail(msg) => {
                panic!("property '{name}' failed (seed {seed:#x}): {msg}")
            }
        }
    }
}

/// Re-run a single case of a property at a known seed (for debugging a
/// failure reported by [`prop_check`]).
pub fn prop_check_seed<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut XorShiftRng) -> CaseResult,
{
    let mut rng = XorShiftRng::new(seed);
    match prop(&mut rng) {
        CaseResult::Ok | CaseResult::Discard => {}
        CaseResult::Fail(msg) => panic!("property '{name}' failed (seed {seed:#x}): {msg}"),
    }
}

/// Convenience: build a [`CaseResult`] from a boolean condition.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> CaseResult {
    if cond {
        CaseResult::Ok
    } else {
        CaseResult::Fail(msg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check("trivial", PropConfig { cases: 32, base_seed: 1 }, |_| {
            count += 1;
            CaseResult::Ok
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        prop_check("always-fails", PropConfig::default(), |_| {
            CaseResult::Fail("nope".into())
        });
    }

    #[test]
    fn discards_do_not_count() {
        let mut executed = 0;
        let mut calls = 0;
        prop_check("half-discard", PropConfig { cases: 16, base_seed: 5 }, |rng| {
            calls += 1;
            if rng.next_u64() % 2 == 0 {
                CaseResult::Discard
            } else {
                executed += 1;
                CaseResult::Ok
            }
        });
        assert_eq!(executed, 16);
        assert!(calls > 16);
    }

    #[test]
    #[should_panic(expected = "too many discards")]
    fn all_discards_panics() {
        prop_check("all-discard", PropConfig { cases: 4, base_seed: 2 }, |_| {
            CaseResult::Discard
        });
    }

    #[test]
    fn ensure_builds_results() {
        assert!(matches!(ensure(true, || "x".into()), CaseResult::Ok));
        assert!(matches!(ensure(false, || "x".into()), CaseResult::Fail(_)));
    }
}
