//! Deterministic xorshift* PRNG.
//!
//! Used everywhere randomness is needed (workload generation, property
//! tests, request arrival processes) so every experiment in EXPERIMENTS.md
//! is reproducible from a printed seed.

/// xorshift64* generator. Not cryptographic; fast, well-distributed enough
/// for workload synthesis and property testing.
#[derive(Debug, Clone)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift bounded sampling (Lemire); slight modulo bias is
        // irrelevant at our bounds (<2^32).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform i8 over the full range.
    pub fn i8(&mut self) -> i8 {
        self.next_u32() as i8
    }

    /// Uniform i8 in `[-bound, bound]` (inclusive); used for quantized
    /// weights where full-scale values would saturate accumulators in
    /// hand-written expectation tests.
    pub fn i8_bounded(&mut self, bound: i8) -> i8 {
        debug_assert!(bound > 0);
        let span = 2 * bound as i64 + 1;
        (self.below(span as u64) as i64 - bound as i64) as i8
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Approximately standard-normal f32 (sum of 12 uniforms minus 6 —
    /// Irwin–Hall; adequate for synthetic activations/weights).
    pub fn normal(&mut self) -> f32 {
        let mut acc = 0.0f32;
        for _ in 0..12 {
            acc += self.f32();
        }
        acc - 6.0
    }

    /// Exponentially-distributed f64 with the given rate (for Poisson
    /// request arrival processes in the coordinator benches).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        // Avoid ln(0).
        -(1.0 - u).max(f64::MIN_POSITIVE).ln() / rate
    }

    /// Fill a slice with uniform i8 values in `[-bound, bound]`.
    pub fn fill_i8(&mut self, buf: &mut [i8], bound: i8) {
        for v in buf.iter_mut() {
            *v = self.i8_bounded(bound);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShiftRng::new(7);
        let mut b = XorShiftRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShiftRng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShiftRng::new(42);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive_exclusive() {
        let mut r = XorShiftRng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(5, 8);
            assert!((5..8).contains(&v));
            seen_lo |= v == 5;
            seen_hi |= v == 7;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn i8_bounded_within_bounds() {
        let mut r = XorShiftRng::new(9);
        let mut min = i8::MAX;
        let mut max = i8::MIN;
        for _ in 0..10_000 {
            let v = r.i8_bounded(5);
            assert!((-5..=5).contains(&v));
            min = min.min(v);
            max = max.max(v);
        }
        assert_eq!(min, -5);
        assert_eq!(max, 5);
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = XorShiftRng::new(11);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_rough_moments() {
        let mut r = XorShiftRng::new(13);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exp_positive_mean_close() {
        let mut r = XorShiftRng::new(17);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.exp(2.0);
            assert!(v >= 0.0);
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }
}
