//! Host-side dense matrix containers and reference kernels.
//!
//! These are the *oracles*: the cycle-level CGRA simulation must match
//! `MatI8::matmul` bit-exactly (int8 × int8 → int32 accumulation), and the
//! quantized transformer path is checked against `MatF32` math.

use std::fmt;

/// Row-major `i8` matrix (activations/weights in the quantized edge path).
#[derive(Clone, PartialEq, Eq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

/// Row-major `i32` matrix (accumulator domain).
#[derive(Clone, PartialEq, Eq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

/// Row-major `f32` matrix (host float domain).
#[derive(Clone, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

macro_rules! common_impl {
    ($ty:ident, $elem:ty, $zero:expr) => {
        impl $ty {
            /// All-zero matrix.
            pub fn zeros(rows: usize, cols: usize) -> Self {
                Self { rows, cols, data: vec![$zero; rows * cols] }
            }

            /// Build from a row-major slice; panics on size mismatch.
            pub fn from_slice(rows: usize, cols: usize, data: &[$elem]) -> Self {
                assert_eq!(data.len(), rows * cols, "shape mismatch");
                Self { rows, cols, data: data.to_vec() }
            }

            /// Element accessor.
            #[inline]
            pub fn at(&self, r: usize, c: usize) -> $elem {
                debug_assert!(r < self.rows && c < self.cols);
                self.data[r * self.cols + c]
            }

            /// Mutable element accessor.
            #[inline]
            pub fn at_mut(&mut self, r: usize, c: usize) -> &mut $elem {
                debug_assert!(r < self.rows && c < self.cols);
                &mut self.data[r * self.cols + c]
            }

            /// Row slice.
            #[inline]
            pub fn row(&self, r: usize) -> &[$elem] {
                &self.data[r * self.cols..(r + 1) * self.cols]
            }

            /// Transposed copy.
            pub fn transpose(&self) -> Self {
                let mut t = Self::zeros(self.cols, self.rows);
                for r in 0..self.rows {
                    for c in 0..self.cols {
                        *t.at_mut(c, r) = self.at(r, c);
                    }
                }
                t
            }
        }

        impl fmt::Debug for $ty {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                writeln!(f, "{}[{}x{}]", stringify!($ty), self.rows, self.cols)?;
                let show_r = self.rows.min(8);
                let show_c = self.cols.min(8);
                for r in 0..show_r {
                    write!(f, "  ")?;
                    for c in 0..show_c {
                        write!(f, "{:?} ", self.at(r, c))?;
                    }
                    writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
                }
                if self.rows > show_r {
                    writeln!(f, "  …")?;
                }
                Ok(())
            }
        }
    };
}

common_impl!(MatI8, i8, 0i8);
common_impl!(MatI32, i32, 0i32);
common_impl!(MatF32, f32, 0.0f32);

impl MatI8 {
    /// Reference int8 GEMM: `C = A·B` with i32 accumulation. This is the
    /// bit-exact oracle the CGRA simulation is tested against (FIG3).
    pub fn matmul(&self, b: &MatI8) -> MatI32 {
        assert_eq!(self.cols, b.rows, "inner dims must agree");
        let mut c = MatI32::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k) as i32;
                if a == 0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for j in 0..b.cols {
                    crow[j] += a * brow[j] as i32;
                }
            }
        }
        c
    }

    /// Widen to f32 with a dequantization scale.
    pub fn dequant(&self, scale: f32) -> MatF32 {
        MatF32 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f32 * scale).collect(),
        }
    }
}

impl MatI32 {
    /// Dequantize an accumulator matrix with the product of input scales.
    pub fn dequant(&self, scale: f32) -> MatF32 {
        MatF32 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f32 * scale).collect(),
        }
    }

    /// Requantize accumulators back to i8 with a scale (saturating).
    pub fn requant(&self, scale: f32) -> MatI8 {
        MatI8 {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .map(|&v| {
                    let q = (v as f32 * scale).round();
                    q.clamp(i8::MIN as f32, i8::MAX as f32) as i8
                })
                .collect(),
        }
    }
}

impl MatF32 {
    /// Reference f32 GEMM.
    pub fn matmul(&self, b: &MatF32) -> MatF32 {
        assert_eq!(self.cols, b.rows, "inner dims must agree");
        let mut c = MatF32::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                let brow = b.row(k);
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for j in 0..b.cols {
                    crow[j] += a * brow[j];
                }
            }
        }
        c
    }

    /// Element-wise addition.
    pub fn add(&self, other: &MatF32) -> MatF32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        MatF32 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    /// Add a row vector (bias broadcast).
    pub fn add_bias(&self, bias: &[f32]) -> MatF32 {
        assert_eq!(bias.len(), self.cols);
        let mut out = self.clone();
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(r, c) += bias[c];
            }
        }
        out
    }

    /// Row-wise softmax (reference for the host-executed attention step).
    pub fn softmax_rows(&self) -> MatF32 {
        let mut out = MatF32::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for c in 0..self.cols {
                let e = (row[c] - m).exp();
                *out.at_mut(r, c) = e;
                denom += e;
            }
            for c in 0..self.cols {
                *out.at_mut(r, c) /= denom;
            }
        }
        out
    }

    /// Row-wise LayerNorm with learned scale/shift.
    pub fn layernorm_rows(&self, gamma: &[f32], beta: &[f32], eps: f32) -> MatF32 {
        assert_eq!(gamma.len(), self.cols);
        assert_eq!(beta.len(), self.cols);
        let mut out = MatF32::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            let mean = row.iter().sum::<f32>() / self.cols as f32;
            let var =
                row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / self.cols as f32;
            let inv = 1.0 / (var + eps).sqrt();
            for c in 0..self.cols {
                *out.at_mut(r, c) = (row[c] - mean) * inv * gamma[c] + beta[c];
            }
        }
        out
    }

    /// Element-wise GELU (tanh approximation, as in the JAX model).
    pub fn gelu(&self) -> MatF32 {
        MatF32 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| gelu_scalar(x)).collect(),
        }
    }

    /// Max absolute value (for symmetric quantization calibration).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Symmetric per-tensor quantization to i8; returns (matrix, scale)
    /// such that `data ≈ q * scale`.
    pub fn quantize(&self) -> (MatI8, f32) {
        let amax = self.abs_max().max(1e-8);
        let scale = amax / 127.0;
        let q = MatI8 {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
                .collect(),
        };
        (q, scale)
    }

    /// Copy of columns `[lo, lo + width)` — attention-head slicing,
    /// shared by the float reference, both CGRA serving paths and the
    /// quantization calibration so they can never disagree on layout.
    pub fn col_slice(&self, lo: usize, width: usize) -> MatF32 {
        assert!(lo + width <= self.cols, "column slice out of range");
        let mut out = MatF32::zeros(self.rows, width);
        for r in 0..self.rows {
            out.data[r * width..(r + 1) * width]
                .copy_from_slice(&self.data[r * self.cols + lo..r * self.cols + lo + width]);
        }
        out
    }

    /// Inverse of [`Self::col_slice`]: write `src` into columns
    /// `[lo, lo + src.cols)` (attention-head scatter; same single
    /// definition shared by every path that reassembles head outputs).
    pub fn set_col_slice(&mut self, lo: usize, src: &MatF32) {
        assert_eq!(src.rows, self.rows, "column scatter row mismatch");
        assert!(lo + src.cols <= self.cols, "column scatter out of range");
        for r in 0..self.rows {
            self.data[r * self.cols + lo..r * self.cols + lo + src.cols]
                .copy_from_slice(&src.data[r * src.cols..(r + 1) * src.cols]);
        }
    }

    /// Max absolute element-wise difference to another matrix.
    pub fn max_abs_diff(&self, other: &MatF32) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

/// GELU with the tanh approximation used by the JAX model
/// (`0.5x(1+tanh(√(2/π)(x+0.044715x³)))`).
pub fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;

    #[test]
    fn i8_matmul_small_exact() {
        // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = MatI8::from_slice(2, 2, &[1, 2, 3, 4]);
        let b = MatI8::from_slice(2, 2, &[5, 6, 7, 8]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19, 22, 43, 50]);
    }

    #[test]
    fn i8_matmul_identity() {
        let mut id = MatI8::zeros(3, 3);
        for i in 0..3 {
            *id.at_mut(i, i) = 1;
        }
        let a = MatI8::from_slice(3, 3, &[1, -2, 3, 4, 5, -6, 7, 8, 9]);
        let c = a.matmul(&id);
        assert_eq!(c.data, a.data.iter().map(|&v| v as i32).collect::<Vec<_>>());
    }

    #[test]
    fn i8_matmul_negative_saturating_free() {
        // Extreme values must not overflow i32: 128 terms of 127*127.
        let a = MatI8::from_slice(1, 128, &[127; 128]);
        let b = MatI8::from_slice(128, 1, &[127; 128]);
        let c = a.matmul(&b);
        assert_eq!(c.data[0], 127 * 127 * 128);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = MatI8::from_slice(2, 3, &[1, 2, 3, 4, 5, 6]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), 6);
    }

    #[test]
    fn f32_matmul_matches_i8_on_small_ints() {
        let mut rng = XorShiftRng::new(21);
        let mut a8 = MatI8::zeros(5, 7);
        let mut b8 = MatI8::zeros(7, 3);
        rng.fill_i8(&mut a8.data, 9);
        rng.fill_i8(&mut b8.data, 9);
        let cf = a8.dequant(1.0).matmul(&b8.dequant(1.0));
        let ci = a8.matmul(&b8);
        for (x, y) in cf.data.iter().zip(&ci.data) {
            assert_eq!(*x, *y as f32);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let m = MatF32::from_slice(2, 3, &[1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let s = m.softmax_rows();
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        // Monotone: larger logits → larger probabilities.
        assert!(s.at(0, 2) > s.at(0, 1) && s.at(0, 1) > s.at(0, 0));
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let m = MatF32::from_slice(1, 2, &[1000.0, 1001.0]);
        let s = m.softmax_rows();
        assert!(s.data.iter().all(|v| v.is_finite()));
        assert!((s.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let m = MatF32::from_slice(1, 4, &[1.0, 2.0, 3.0, 4.0]);
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let out = m.layernorm_rows(&g, &b, 1e-5);
        let mean: f32 = out.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = out.row(0).iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn col_slice_copies_the_right_columns() {
        let m = MatF32::from_slice(2, 4, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let s = m.col_slice(1, 2);
        assert_eq!((s.rows, s.cols), (2, 2));
        assert_eq!(s.data, vec![1.0, 2.0, 5.0, 6.0]);
        // Scatter round-trip: writing the slice back reproduces m.
        let mut back = MatF32::zeros(2, 4);
        back.set_col_slice(0, &m.col_slice(0, 1));
        back.set_col_slice(1, &s);
        back.set_col_slice(3, &m.col_slice(3, 1));
        assert_eq!(back.data, vec![0.0, 1.0, 2.0, 0.0, 4.0, 5.0, 6.0, 0.0]);
    }

    #[test]
    fn quantize_dequantize_bounded_error() {
        let mut rng = XorShiftRng::new(31);
        let data: Vec<f32> = (0..64).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let m = MatF32::from_slice(8, 8, &data);
        let (q, scale) = m.quantize();
        let back = q.dequant(scale);
        // Error bounded by half a quantization step.
        assert!(m.max_abs_diff(&back) <= scale * 0.5 + 1e-6);
    }

    #[test]
    fn gelu_known_values() {
        assert!(gelu_scalar(0.0).abs() < 1e-7);
        assert!((gelu_scalar(100.0) - 100.0).abs() < 1e-3);
        assert!(gelu_scalar(-100.0).abs() < 1e-3);
        // gelu(1) ≈ 0.8412
        assert!((gelu_scalar(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn requant_saturates() {
        let m = MatI32::from_slice(1, 2, &[100_000, -100_000]);
        let q = m.requant(0.01);
        assert_eq!(q.data, vec![127, -128]);
    }
}
