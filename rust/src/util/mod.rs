//! Small self-contained utilities: deterministic RNG, property-test
//! harness, host-side matrix helpers, fixed-point/quantization math.
//!
//! The build environment vendors no `rand`/`proptest`, so these are
//! hand-rolled and deliberately tiny but well-tested.

pub mod mat;
pub mod prop;
pub mod quant;
pub mod rng;

pub use mat::{MatI8, MatI32, MatF32};
pub use prop::{prop_check, PropConfig};
pub use rng::XorShiftRng;
