//! Packed-word and quantization helpers.
//!
//! The CGRA datapath is 32-bit; the packed int8 mode carries four lanes per
//! word (paper §III-B1: "dot-product by incorporating additions and
//! multiplications on packed data"). These helpers define the bit-level
//! packing used by the ISA, the MOBs and the host-side data marshalling —
//! one definition, used everywhere, tested here.

/// Pack four i8 lanes into a little-endian u32 word (lane 0 = low byte).
#[inline]
pub fn pack4(lanes: [i8; 4]) -> u32 {
    u32::from_le_bytes([
        lanes[0] as u8,
        lanes[1] as u8,
        lanes[2] as u8,
        lanes[3] as u8,
    ])
}

/// Unpack a u32 word into four i8 lanes.
#[inline]
pub fn unpack4(word: u32) -> [i8; 4] {
    let b = word.to_le_bytes();
    [b[0] as i8, b[1] as i8, b[2] as i8, b[3] as i8]
}

/// 4-lane signed dot product with i32 accumulation — the PE's packed MAC
/// primitive. `dot4(a, b) = Σ a[i]·b[i]`.
#[inline]
pub fn dot4(a: u32, b: u32) -> i32 {
    let av = unpack4(a);
    let bv = unpack4(b);
    av.iter()
        .zip(bv.iter())
        .map(|(&x, &y)| x as i32 * y as i32)
        .sum()
}

/// Pack a slice of i8 into u32 words, zero-padding the tail lane-wise.
pub fn pack_slice(src: &[i8]) -> Vec<u32> {
    src.chunks(4)
        .map(|ch| {
            let mut lanes = [0i8; 4];
            lanes[..ch.len()].copy_from_slice(ch);
            pack4(lanes)
        })
        .collect()
}

/// Unpack u32 words into i8 values, truncated to `len`.
pub fn unpack_slice(words: &[u32], len: usize) -> Vec<i8> {
    let mut out = Vec::with_capacity(len);
    'outer: for &w in words {
        for lane in unpack4(w) {
            if out.len() == len {
                break 'outer;
            }
            out.push(lane);
        }
    }
    assert_eq!(out.len(), len, "not enough words to unpack {len} values");
    out
}

/// f32 <-> u32 bit transmutation for carrying floats over the 32-bit fabric.
#[inline]
pub fn f32_to_word(v: f32) -> u32 {
    v.to_bits()
}

/// See [`f32_to_word`].
#[inline]
pub fn word_to_f32(w: u32) -> f32 {
    f32::from_bits(w)
}

/// Saturating i32 → i8 requantization with a power-of-two right shift and
/// round-to-nearest-even-free rounding (round-half-away, matching the
/// hardware's cheap rounder). Used by the PE's ACCOUT-requant mode.
#[inline]
pub fn requant_shift(acc: i32, shift: u8) -> i8 {
    if shift == 0 {
        return acc.clamp(i8::MIN as i32, i8::MAX as i32) as i8;
    }
    let half = 1i64 << (shift - 1);
    let v = ((acc as i64 + if acc >= 0 { half } else { -half }) >> shift) as i32;
    v.clamp(i8::MIN as i32, i8::MAX as i32) as i8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, prop_check, PropConfig};

    #[test]
    fn pack_unpack_roundtrip() {
        let lanes = [-128i8, -1, 0, 127];
        assert_eq!(unpack4(pack4(lanes)), lanes);
    }

    #[test]
    fn dot4_known() {
        let a = pack4([1, 2, 3, 4]);
        let b = pack4([5, 6, 7, 8]);
        assert_eq!(dot4(a, b), 5 + 12 + 21 + 32);
    }

    #[test]
    fn dot4_extremes_no_overflow() {
        let a = pack4([-128; 4]);
        let b = pack4([-128; 4]);
        assert_eq!(dot4(a, b), 4 * 128 * 128);
        let b = pack4([127; 4]);
        assert_eq!(dot4(a, b), 4 * -128 * 127);
    }

    #[test]
    fn pack_slice_pads_tail() {
        let words = pack_slice(&[1, 2, 3, 4, 5]);
        assert_eq!(words.len(), 2);
        assert_eq!(unpack4(words[1]), [5, 0, 0, 0]);
    }

    #[test]
    fn unpack_slice_truncates() {
        let words = pack_slice(&[1, 2, 3, 4, 5]);
        assert_eq!(unpack_slice(&words, 5), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn f32_word_roundtrip() {
        for v in [0.0f32, -1.5, f32::MAX, f32::MIN_POSITIVE, 3.14159] {
            assert_eq!(word_to_f32(f32_to_word(v)), v);
        }
    }

    #[test]
    fn requant_shift_zero_is_clamp() {
        assert_eq!(requant_shift(300, 0), 127);
        assert_eq!(requant_shift(-300, 0), -128);
        assert_eq!(requant_shift(5, 0), 5);
    }

    #[test]
    fn requant_shift_rounds_half_away() {
        assert_eq!(requant_shift(3, 1), 2); // 1.5 → 2
        assert_eq!(requant_shift(-3, 1), -2); // -1.5 → -2
        assert_eq!(requant_shift(2, 1), 1);
        assert_eq!(requant_shift(100, 3), 13); // 12.5 → 13
    }

    #[test]
    fn prop_pack_roundtrip_random() {
        prop_check("pack4 roundtrip", PropConfig::default(), |rng| {
            let lanes = [rng.i8(), rng.i8(), rng.i8(), rng.i8()];
            ensure(unpack4(pack4(lanes)) == lanes, || format!("{lanes:?}"))
        });
    }

    #[test]
    fn prop_dot4_matches_scalar() {
        prop_check("dot4 == scalar dot", PropConfig::default(), |rng| {
            let a = [rng.i8(), rng.i8(), rng.i8(), rng.i8()];
            let b = [rng.i8(), rng.i8(), rng.i8(), rng.i8()];
            let expect: i32 =
                a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
            ensure(dot4(pack4(a), pack4(b)) == expect, || format!("{a:?} {b:?}"))
        });
    }

    #[test]
    fn prop_pack_slice_roundtrip() {
        prop_check("pack_slice roundtrip", PropConfig::default(), |rng| {
            let len = rng.range(1, 64);
            let mut v = vec![0i8; len];
            rng.fill_i8(&mut v, 127);
            let words = pack_slice(&v);
            ensure(unpack_slice(&words, len) == v, || format!("len {len}"))
        });
    }
}
