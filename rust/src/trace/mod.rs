//! Run traces: CSV emission of per-kernel statistics for offline
//! inspection (the "waveform lite" of this simulator).
//!
//! Each row is tagged with a generation-lifecycle `phase` so one CSV
//! covers the whole serving pipeline: `encoder` (batch encoder
//! kernels), `prefill` (whole-prompt decode prefill), `chunk`
//! (Sarathi-style chunked prefill jobs), `decode` (continuous-batching
//! decode ticks). The legacy [`TraceLog::record`] keeps tagging rows
//! as `encoder`.

use crate::sim::Stats;
use std::fmt::Write as _;

/// Accumulates one row per kernel / phase and renders CSV.
#[derive(Debug, Default, Clone)]
pub struct TraceLog {
    rows: Vec<(String, String, Stats)>,
}

impl TraceLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a labelled stats snapshot (typically a per-kernel delta)
    /// under the default `encoder` phase.
    pub fn record(&mut self, label: impl Into<String>, stats: Stats) {
        self.record_phase(label, "encoder", stats);
    }

    /// Record a labelled stats snapshot under an explicit lifecycle
    /// phase (`encoder` / `prefill` / `chunk` / `decode`).
    pub fn record_phase(
        &mut self,
        label: impl Into<String>,
        phase: impl Into<String>,
        stats: Stats,
    ) {
        self.rows.push((label.into(), phase.into(), stats));
    }

    /// Number of recorded rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Is the log empty?
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as CSV (header + one row per record).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "label,phase,cycles,config_cycles,macp,pe_stall_operand,pe_stall_output,\
             mob_load_words,mob_store_words,torus_hops,noc_router_traversals,\
             l1_reads,l1_writes,ext_reads,ext_writes,dma_words\n",
        );
        for (label, phase, s) in &self.rows {
            let _ = writeln!(
                out,
                "{label},{phase},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                s.cycles,
                s.config_cycles,
                s.pe_macp,
                s.pe_stall_operand,
                s.pe_stall_output,
                s.mob_load_words,
                s.mob_store_words,
                s.torus_hops,
                s.noc_router_traversals,
                s.l1_reads,
                s.l1_writes,
                s.ext_reads,
                s.ext_writes,
                s.dma_words,
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = TraceLog::new();
        log.record("k0", Stats { cycles: 10, pe_macp: 5, ..Default::default() });
        log.record("k1", Stats { cycles: 20, ..Default::default() });
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().next().unwrap().starts_with("label,phase,cycles,"));
        assert!(csv.lines().nth(1).unwrap().starts_with("k0,encoder,10,"));
        assert_eq!(log.len(), 2);
        assert!(!log.is_empty());
    }

    #[test]
    fn explicit_phases_tag_rows() {
        let mut log = TraceLog::new();
        log.record_phase("tick", "decode", Stats { cycles: 7, ..Default::default() });
        log.record_phase("chunk0", "chunk", Stats { cycles: 9, ..Default::default() });
        let csv = log.to_csv();
        assert!(csv.lines().nth(1).unwrap().starts_with("tick,decode,7,"));
        assert!(csv.lines().nth(2).unwrap().starts_with("chunk0,chunk,9,"));
    }
}
