//! Run traces: CSV emission of per-kernel statistics for offline
//! inspection (the "waveform lite" of this simulator).

use crate::sim::Stats;
use std::fmt::Write as _;

/// Accumulates one row per kernel / phase and renders CSV.
#[derive(Debug, Default, Clone)]
pub struct TraceLog {
    rows: Vec<(String, Stats)>,
}

impl TraceLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a labelled stats snapshot (typically a per-kernel delta).
    pub fn record(&mut self, label: impl Into<String>, stats: Stats) {
        self.rows.push((label.into(), stats));
    }

    /// Number of recorded rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Is the log empty?
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as CSV (header + one row per record).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "label,cycles,config_cycles,macp,pe_stall_operand,pe_stall_output,\
             mob_load_words,mob_store_words,torus_hops,noc_router_traversals,\
             l1_reads,l1_writes,ext_reads,ext_writes,dma_words\n",
        );
        for (label, s) in &self.rows {
            let _ = writeln!(
                out,
                "{label},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                s.cycles,
                s.config_cycles,
                s.pe_macp,
                s.pe_stall_operand,
                s.pe_stall_output,
                s.mob_load_words,
                s.mob_store_words,
                s.torus_hops,
                s.noc_router_traversals,
                s.l1_reads,
                s.l1_writes,
                s.ext_reads,
                s.ext_writes,
                s.dma_words,
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = TraceLog::new();
        log.record("k0", Stats { cycles: 10, pe_macp: 5, ..Default::default() });
        log.record("k1", Stats { cycles: 20, ..Default::default() });
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().starts_with("k0,10,"));
        assert_eq!(log.len(), 2);
        assert!(!log.is_empty());
    }
}
