//! Kernel-context generation for blocked GEMM.
//!
//! The schedule is *elastic*: programs only fix the **order** of port
//! reads/writes per link; blocking port semantics self-synchronize the
//! timing. The steady state is one packed MAC per PE per cycle.
//!
//! ## Dual-feed dataflow (the paper's torus, DESIGN.md §2)
//!
//! PE(r,c) owns the 4×4 output sub-tile at rows `i0+4r..+4`, cols
//! `j0+4c..+4`. Per k-chunk each PE performs 16 packed MACs (4 a-words ×
//! 4 B lanes) over 16 slots; slot `4g+i` is MAC `(rr=g, lane=i)`.
//!
//! - **East wire** (MOB(r,0) → PE3 → … → PE0): interleaved stream
//!   `[a_g, b(g,col3), b(g,col2)]` per lane group g. The A word is read
//!   at slot `4g` and rider-forwarded west; PE3 latches its own B word
//!   (take at `4g+1`) and relays PE2's (`4g+2`); PE2 latches at `4g+2`.
//! - **West wire** (MOB(r,1) → PE0 → PE1): `[b(g,col0), b(g,col1)]`;
//!   PE0 latches its own at slot `4g` (take rider alongside the A-read)
//!   and relays PE1's at `4g+1`; PE1 latches at `4g+3`.
//! - B words are prefetched one chunk ahead into the inactive register
//!   bank (the body is a two-chunk unroll so banks swap statically).
//! - **C drain**: per tile each PE requantizes its sub-tile west;
//!   eastern PEs' words are pass-forwarded, giving column-ascending wire
//!   order into MOB(r,1)'s store windows.
//!
//! Every dependency in this schedule points the same way as the skew of
//! the data it needs (A west-bound defines skews PE3=0 … PE0=3; both B
//! relays are satisfiable with equality at those skews), so there are no
//! steady-state bubbles — unlike the single-feed relay, which couples
//! opposed skews and sustains only ≈0.45 of peak (EXPERIMENTS.md §Perf).
//!
//! ## Single feed
//!
//! One west-bound B stream from MOB(r,0) with in-row relays; used by the
//! PanelB strategy (in-place panel re-staging breaks dual-feed's
//! cross-tile prefetch continuity), the switched-NoC baseline (with
//! route tables and A broadcast by replication) and narrow arrays.

use super::plan::{FeedKind, GemmPlan, MapVariant, OutputMode, Strategy, DUAL_SLACK_WORDS};
use crate::interconnect::fabric::RouteTable;
use crate::interconnect::topology::Topology;
use crate::isa::{
    AluOp, Dir, DirMode, Dst, KernelContext, MemSpace, MobOp, MobProgram, PeInstr, PeProgram,
    Rider, Src, Take,
};
use anyhow::{bail, ensure, Result};

// Register allocation (PE register file, 16 entries).
const A_REG: u8 = 0; // r0..r3: current a-words
const B_BANK0: u8 = 4; // r4..r7: B bank 0
const B_BANK1: u8 = 8; // r8..r11: B bank 1
const ADDR_A: u8 = 12; // PeLoad: A pointer
const ADDR_B: u8 = 13; // PeLoad: B pointer
const ADDR_C: u8 = 14; // PeLoad: C pointer
const TMP: u8 = 15; // PeLoad: requant staging

/// Build the kernel context (and route tables for the switched variant).
pub fn build_context(plan: &GemmPlan) -> Result<(KernelContext, Option<RouteTable>)> {
    let topo = Topology::new(plan.rows, plan.pe_cols, 2);
    let mut ctx = KernelContext {
        pe_programs: Vec::with_capacity(topo.num_pes()),
        mob_programs: Vec::with_capacity(topo.num_mobs()),
        name: format!(
            "gemm{}x{}x{}-{:?}-{:?}-{:?}",
            plan.m, plan.k, plan.n, plan.strategy, plan.variant, plan.feed
        ),
    };

    match plan.variant {
        MapVariant::Torus | MapVariant::Switched => {
            for _r in 0..plan.rows {
                for c in 0..plan.pe_cols {
                    ctx.pe_programs.push(match plan.feed {
                        FeedKind::Dual => pe_program_dual(plan, c),
                        FeedKind::Single => pe_program_single(plan, c),
                    });
                }
            }
            for r in 0..plan.rows {
                match plan.feed {
                    FeedKind::Dual => {
                        ctx.mob_programs.push(mob_east_dual(plan, r));
                        ctx.mob_programs.push(mob_west_dual(plan, r));
                    }
                    FeedKind::Single => {
                        ctx.mob_programs.push(mob_b_single(plan, r));
                        ctx.mob_programs.push(mob_a_single(plan, r));
                    }
                }
            }
        }
        MapVariant::PeLoad => {
            if plan.tiles() != 1 {
                bail!("PeLoad ablation supports a single tile-block only");
            }
            for r in 0..plan.rows {
                for c in 0..plan.pe_cols {
                    ctx.pe_programs.push(pe_program_peload(plan, r, c)?);
                }
            }
            for _ in 0..topo.num_mobs() {
                ctx.mob_programs.push(MobProgram::idle());
            }
        }
    }
    validate_barrier_counts(&ctx)?;

    let routes = match plan.variant {
        MapVariant::Switched => Some(build_routes(plan, &topo)),
        _ => None,
    };
    Ok((ctx, routes))
}

/// Each MOB must emit the same dynamic number of `Barrier` descriptors,
/// or the global rendezvous deadlocks.
fn validate_barrier_counts(ctx: &KernelContext) -> Result<()> {
    fn dynamic_barriers(ops: &[MobOp]) -> u64 {
        fn count(ops: &[MobOp], lo: usize, hi: usize) -> u64 {
            let mut total = 0u64;
            let mut i = lo;
            while i < hi {
                match ops[i] {
                    MobOp::Barrier => total += 1,
                    MobOp::Loop { start, extra } => {
                        total += extra as u64 * count(ops, start as usize, i);
                    }
                    _ => {}
                }
                i += 1;
            }
            total
        }
        count(ops, 0, ops.len())
    }
    let counts: Vec<u64> = ctx.mob_programs.iter().map(|m| dynamic_barriers(&m.ops)).collect();
    if let Some(&first) = counts.first() {
        ensure!(
            counts.iter().all(|&c| c == first),
            "mapper bug: unequal barrier counts across MOBs: {counts:?}"
        );
    }
    Ok(())
}

/// Per-tile C drain: own sub-tile west, then pass-forward the eastern
/// PEs' drains (wire order = column ascending). Shared by both feeds.
fn drain_epilogue(plan: &GemmPlan, c: usize) -> Vec<PeInstr> {
    let cols = plan.pe_cols;
    let own_words = match plan.output {
        OutputMode::Quant { .. } => 4,
        OutputMode::Raw => 16,
    };
    let mut epi = Vec::with_capacity(own_words * (cols - c));
    match plan.output {
        OutputMode::Quant { shift } => {
            for rr in 0..4u8 {
                epi.push(PeInstr::AccOutQ {
                    d: 4 * rr,
                    shift,
                    dst: Dst::Port(Dir::West),
                    clear: true,
                });
            }
        }
        OutputMode::Raw => {
            for d in 0..16u8 {
                epi.push(PeInstr::AccOut { d, dst: Dst::Port(Dir::West), clear: true });
            }
        }
    }
    for _ in 0..own_words * (cols - 1 - c) {
        epi.push(PeInstr::Mov {
            dst: Dst::Port(Dir::West),
            a: Src::Port(Dir::East),
            ra: Rider::NONE,
        });
    }
    epi
}

// ====================================================================
// Dual feed (paper torus, pe_cols == 4)
// ====================================================================

/// PE program for the dual-feed mapping, parameterised by grid column.
fn pe_program_dual(plan: &GemmPlan, c: usize) -> PeProgram {
    debug_assert_eq!(plan.pe_cols, 4);
    let chunk_pairs = plan.chunks() / 2;

    // Prologue: latch chunk 0's B lanes into bank 0 (+ relay the
    // neighbour half's words).
    let mut prologue = Vec::new();
    for g in 0..4u8 {
        match c {
            3 => {
                prologue.push(PeInstr::Mov {
                    dst: Dst::Reg(B_BANK0 + g),
                    a: Src::Port(Dir::East),
                    ra: Rider::NONE,
                });
                prologue.push(PeInstr::Mov {
                    dst: Dst::Port(Dir::West),
                    a: Src::Port(Dir::East),
                    ra: Rider::NONE,
                });
            }
            2 => prologue.push(PeInstr::Mov {
                dst: Dst::Reg(B_BANK0 + g),
                a: Src::Port(Dir::East),
                ra: Rider::NONE,
            }),
            1 => prologue.push(PeInstr::Mov {
                dst: Dst::Reg(B_BANK0 + g),
                a: Src::Port(Dir::West),
                ra: Rider::NONE,
            }),
            0 => {
                prologue.push(PeInstr::Mov {
                    dst: Dst::Reg(B_BANK0 + g),
                    a: Src::Port(Dir::West),
                    ra: Rider::NONE,
                });
                prologue.push(PeInstr::Mov {
                    dst: Dst::Port(Dir::East),
                    a: Src::Port(Dir::West),
                    ra: Rider::NONE,
                });
            }
            _ => unreachable!(),
        }
    }

    // Body: two unrolled chunks (banks swap). A arrives on the EAST port
    // (west-bound stream) at slot 4g; takes are per-column as derived in
    // the module docs.
    let mut body = Vec::with_capacity(32);
    for parity in 0..2u8 {
        let cur = if parity == 0 { B_BANK0 } else { B_BANK1 };
        let pre = if parity == 0 { B_BANK1 } else { B_BANK0 };
        for g in 0..4u8 {
            for i in 0..4u8 {
                let (a, ra) = if i == 0 {
                    let fwd = if c > 0 { Some(Dir::West) } else { None };
                    (Src::Port(Dir::East), Rider { latch: Some(A_REG + g), fwd })
                } else {
                    (Src::Reg(A_REG + g), Rider::NONE)
                };
                let take = match (c, i) {
                    (3, 1) => Some(Take { port: Dir::East, latch: Some(pre + g), fwd: None }),
                    (3, 2) => Some(Take { port: Dir::East, latch: None, fwd: Some(Dir::West) }),
                    (2, 2) => Some(Take { port: Dir::East, latch: Some(pre + g), fwd: None }),
                    (1, 3) => Some(Take { port: Dir::West, latch: Some(pre + g), fwd: None }),
                    (0, 0) => Some(Take { port: Dir::West, latch: Some(pre + g), fwd: None }),
                    (0, 1) => Some(Take { port: Dir::West, latch: None, fwd: Some(Dir::East) }),
                    _ => None,
                };
                body.push(PeInstr::MacP {
                    d: g * 4 + i,
                    a,
                    ra,
                    b: Src::Reg(cur + i),
                    rb: Rider::NONE,
                    take,
                });
            }
        }
    }

    PeProgram {
        prologue,
        body,
        trip: chunk_pairs as u32,
        tile_epilogue: drain_epilogue(plan, c),
        tiles: plan.tiles() as u32,
        epilogue: vec![PeInstr::Halt],
    }
}

/// Split `total` words into `parts` DMA slices: (offset, count) for `i`.
fn slice(total: usize, parts: usize, i: usize) -> (u32, u32) {
    let base_size = total / parts;
    let rem = total % parts;
    let off = i * base_size + i.min(rem);
    let cnt = base_size + usize::from(i < rem);
    (off as u32, cnt as u32)
}

/// East MOB (grid column `pe_cols`): interleaved A + east-half-B stream,
/// west-bound into PE3.
fn mob_east_dual(plan: &GemmPlan, r: usize) -> MobProgram {
    let kp = plan.kp as u32;
    let rows = plan.rows;
    let half = plan.half_panel_words() as u32; // 2·kp for pe_cols = 4
    let (n_it, n_jt) = (plan.n_it as u32, plan.n_jt as u32);
    let mut ops = Vec::new();
    match plan.strategy {
        Strategy::WholeB => {
            // The DMA engine is serial, so slicing the region across
            // rows buys nothing; a single staging DMA keeps the other
            // rows' programs identical (context dedup).
            if r == 0 && !plan.prestaged {
                let whole = (plan.n_jt * plan.half_panel_words() + DUAL_SLACK_WORDS) as u32;
                ops.push(MobOp::Dma {
                    ext_base: plan.b_east_ext,
                    l1_base: plan.b_east_l1,
                    count: whole,
                    to_l1: true,
                    ext_steps: [0, 0],
                    l1_steps: [0, 0],
                });
            }
            if !plan.prestaged {
                ops.push(MobOp::Barrier);
            }
            // One-time preamble: chunk 0 of panel 0 (PE prologue fill).
            ops.push(MobOp::load(MemSpace::L1, plan.b_east_l1, 1, 8, Dir::West));
            // it outer: stage this row-group's A slice, then jt inner.
            let it_start = ops.len() as u16;
            if !plan.prestaged {
                ops.push(MobOp::Dma {
                    ext_base: plan.a_ext + (r as u32) * kp,
                    l1_base: plan.a_slice_l1(r),
                    count: kp,
                    to_l1: true,
                    ext_steps: [(rows as u32 * kp) as i32, 0],
                    l1_steps: [0, 0],
                });
                ops.push(MobOp::Fence);
            }
            let jt_start = ops.len() as u16;
            ops.push(MobOp::LoadDual {
                space: MemSpace::L1,
                a_base: plan.a_slice_l1(r),
                a_stride: 1,
                a_count: kp,
                a_per: 1,
                b_base: plan.b_east_l1 + 8,
                b_stride: 1,
                b_count: 2 * kp,
                b_per: 2,
                dir: Dir::West,
                a_steps: [0, 0],
                b_steps: [half as i32, 0],
            });
            ops.push(MobOp::Loop { start: jt_start, extra: n_jt - 1 });
            ops.push(MobOp::Loop { start: it_start, extra: n_it - 1 });
        }
        Strategy::NaiveExt => {
            ops.push(MobOp::load(MemSpace::Ext, plan.b_east_ext, 1, 8, Dir::West));
            let jt_start = ops.len() as u16;
            ops.push(MobOp::LoadDual {
                space: MemSpace::Ext,
                a_base: plan.a_ext + (r as u32) * kp,
                a_stride: 1,
                a_count: kp,
                a_per: 1,
                b_base: plan.b_east_ext + 8,
                b_stride: 1,
                b_count: 2 * kp,
                b_per: 2,
                dir: Dir::West,
                a_steps: [0, (rows as u32 * kp) as i32],
                b_steps: [half as i32, 0],
            });
            ops.push(MobOp::Loop { start: jt_start, extra: n_jt - 1 });
            ops.push(MobOp::Loop { start: jt_start, extra: n_it - 1 });
        }
        Strategy::PanelB => unreachable!("PanelB uses the single feed"),
    }
    ops.push(MobOp::Halt);
    MobProgram { ops }
}

/// West MOB (grid column `pe_cols + 1`): west-half-B stream east-bound
/// into PE0, plus the C-store windows (absorbing the drain on its east
/// input).
fn mob_west_dual(plan: &GemmPlan, r: usize) -> MobProgram {
    let kp = plan.kp as u32;
    let rows = plan.rows;
    let c_cols = plan.pe_cols;
    let half = plan.half_panel_words() as u32;
    let (n_it, n_jt) = (plan.n_it as u32, plan.n_jt as u32);
    let crw = plan.c_row_words() as i32;

    // Store windows. Loop order is it-outer/jt-inner for both dual
    // strategies: steps[0] = jt (step pe_cols words across), steps[1] =
    // it (step 4·rows rows down).
    let store_steps = [c_cols as i32, rows as i32 * crw * 4];
    let push_stores = |ops: &mut Vec<MobOp>| match plan.output {
        OutputMode::Quant { .. } => {
            for c in 0..c_cols {
                ops.push(MobOp::Store {
                    space: MemSpace::Ext,
                    base: plan.c_ext + (4 * r as u32) * crw as u32 + c as u32,
                    stride: crw,
                    count: 4,
                    dir: Dir::East,
                    steps: store_steps,
                });
            }
        }
        OutputMode::Raw => {
            for c in 0..c_cols {
                for rr in 0..4 {
                    ops.push(MobOp::Store {
                        space: MemSpace::Ext,
                        base: plan.c_ext + ((4 * r + rr) as u32) * crw as u32 + (4 * c) as u32,
                        stride: 1,
                        count: 4,
                        dir: Dir::East,
                        steps: store_steps,
                    });
                }
            }
        }
    };

    let mut ops = Vec::new();
    let (space, region) = match plan.strategy {
        Strategy::WholeB => (MemSpace::L1, plan.b_west_l1),
        Strategy::NaiveExt => (MemSpace::Ext, plan.b_west_ext),
        Strategy::PanelB => unreachable!("PanelB uses the single feed"),
    };
    if plan.strategy == Strategy::WholeB && !plan.prestaged {
        if r == 0 {
            let whole = (plan.n_jt * plan.half_panel_words() + DUAL_SLACK_WORDS) as u32;
            ops.push(MobOp::Dma {
                ext_base: plan.b_west_ext,
                l1_base: plan.b_west_l1,
                count: whole,
                to_l1: true,
                ext_steps: [0, 0],
                l1_steps: [0, 0],
            });
        }
        ops.push(MobOp::Barrier);
    }
    // One-time preamble: chunk 0 of panel 0.
    ops.push(MobOp::load(space, region, 1, 8, Dir::East));
    let tile_start = ops.len() as u16;
    // Mid: this tile's chunks 1..chunks.
    ops.push(MobOp::Load {
        space,
        base: region + 8,
        stride: 1,
        count: 2 * kp - 8,
        dir: DirMode::Fixed(Dir::East),
        replicate: 1,
        steps: [half as i32, 0],
    });
    // Next8: the following tile's chunk 0 (slack copy at the region end
    // keeps i-tile-boundary overruns valid). Emitted BEFORE the stores so
    // the PEs' final-chunk prefetch never deadlocks against the drain.
    ops.push(MobOp::Load {
        space,
        base: region + half,
        stride: 1,
        count: 8,
        dir: DirMode::Fixed(Dir::East),
        replicate: 1,
        steps: [half as i32, 0],
    });
    push_stores(&mut ops);
    ops.push(MobOp::Loop { start: tile_start, extra: n_jt - 1 });
    ops.push(MobOp::Loop { start: tile_start, extra: n_it - 1 });
    ops.push(MobOp::Halt);
    MobProgram { ops }
}

// ====================================================================
// Single feed (PanelB, switched baseline, narrow arrays)
// ====================================================================

/// PE program for the single-feed mapping, parameterised by grid column.
fn pe_program_single(plan: &GemmPlan, c: usize) -> PeProgram {
    let cols = plan.pe_cols;
    let last_col = c == cols - 1;
    let chunk_pairs = plan.chunks() / 2;

    // Prologue: column-ascending emission → `c` pass-throughs then the
    // own latch, per lane.
    let mut prologue = Vec::with_capacity(4 * (c + 1));
    for cc in 0..4u8 {
        for _ in 0..c {
            prologue.push(PeInstr::Mov {
                dst: Dst::Port(Dir::West),
                a: Src::Port(Dir::East),
                ra: Rider::NONE,
            });
        }
        prologue.push(PeInstr::Mov {
            dst: Dst::Reg(B_BANK0 + cc),
            a: Src::Port(Dir::East),
            ra: Rider::NONE,
        });
    }

    // Body: takes at slot `4cc + p + (3-c)` (group-aligned, skewed later
    // for western columns). This relay couples the east-bound A skew
    // with the west-bound B relay and sustains ≈0.45 of peak — accepted
    // for the variants that need it (see module docs).
    let mut body = Vec::with_capacity(32);
    for parity in 0..2u8 {
        let cur = if parity == 0 { B_BANK0 } else { B_BANK1 };
        let pre = if parity == 0 { B_BANK1 } else { B_BANK0 };
        let mut takes: [Option<Take>; 16] = [None; 16];
        for cc in 0..4usize {
            for p in 0..=c {
                let slot = 4 * cc + p + (3 - c);
                debug_assert!(slot < 16 && takes[slot].is_none());
                takes[slot] = Some(if p == c {
                    Take { port: Dir::East, latch: Some(pre + cc as u8), fwd: None }
                } else {
                    Take { port: Dir::East, latch: None, fwd: Some(Dir::West) }
                });
            }
        }
        for s in 0..16usize {
            let rr = (s / 4) as u8;
            let cc = (s % 4) as u8;
            let (a, ra) = if cc == 0 {
                let fwd = if !last_col && plan.variant == MapVariant::Torus {
                    Some(Dir::East)
                } else {
                    None
                };
                (Src::Port(Dir::West), Rider { latch: Some(A_REG + rr), fwd })
            } else {
                (Src::Reg(A_REG + rr), Rider::NONE)
            };
            body.push(PeInstr::MacP {
                d: rr * 4 + cc,
                a,
                ra,
                b: Src::Reg(cur + cc),
                rb: Rider::NONE,
                take: takes[s],
            });
        }
    }

    PeProgram {
        prologue,
        body,
        trip: chunk_pairs as u32,
        tile_epilogue: drain_epilogue(plan, c),
        tiles: plan.tiles() as u32,
        epilogue: vec![PeInstr::Halt],
    }
}

/// Single-feed B-stream MOB (grid column `pe_cols`, sends west).
fn mob_b_single(plan: &GemmPlan, r: usize) -> MobProgram {
    let c_cols = plan.pe_cols;
    let kp = plan.kp;
    let panel = c_cols * kp;
    let stream_words = panel as u32;
    let dummy = (4 * c_cols) as u32;
    let (n_it, n_jt) = (plan.n_it as u32, plan.n_jt as u32);
    let mut ops = Vec::new();
    match plan.strategy {
        Strategy::WholeB => {
            if r == 0 {
                ops.push(MobOp::Dma {
                    ext_base: plan.b_ext,
                    l1_base: plan.b_l1,
                    count: (plan.n_jt * panel) as u32,
                    to_l1: true,
                    ext_steps: [0, 0],
                    l1_steps: [0, 0],
                });
            }
            ops.push(MobOp::Barrier);
            let load_pc = ops.len() as u16;
            ops.push(MobOp::Load {
                space: MemSpace::L1,
                base: plan.b_l1,
                stride: 1,
                count: stream_words,
                dir: DirMode::Fixed(Dir::West),
                replicate: 1,
                steps: [panel as i32, 0],
            });
            ops.push(MobOp::Loop { start: load_pc, extra: n_jt - 1 });
            ops.push(MobOp::Loop { start: load_pc, extra: n_it - 1 });
            ops.push(MobOp::load(MemSpace::L1, plan.b_l1, 1, dummy, Dir::West));
        }
        Strategy::PanelB => {
            // Per jt: stage the panel, stream it n_it times, then — still
            // before the end-of-panel barrier — deliver the *next* jt's
            // first chunk straight from external memory, so the PEs'
            // cross-tile prefetch can complete and drain (otherwise the
            // last tile of each jt deadlocks against the barrier). The
            // packed B region carries a slack copy of panel 0's first
            // chunk at its end for the final wrap (written by
            // `stage_operands`).
            // One-time preamble: panel 0's first chunk from ext (the PE
            // prologues consume it before any panel is staged).
            ops.push(MobOp::load(MemSpace::Ext, plan.b_ext, 1, dummy, Dir::West));
            let jt_start = ops.len() as u16;
            if r == 0 {
                ops.push(MobOp::Dma {
                    ext_base: plan.b_ext,
                    l1_base: plan.b_l1,
                    count: panel as u32,
                    to_l1: true,
                    ext_steps: [panel as i32, 0],
                    l1_steps: [0, 0],
                });
            }
            ops.push(MobOp::Barrier);
            // First tile of the jt: chunk 0 was already delivered (by the
            // previous jt's ext-prefetch, or the preamble for jt 0).
            ops.push(MobOp::Load {
                space: MemSpace::L1,
                base: plan.b_l1 + dummy,
                stride: 1,
                count: stream_words - dummy,
                dir: DirMode::Fixed(Dir::West),
                replicate: 1,
                steps: [0, 0],
            });
            if n_it > 1 {
                let load_pc = ops.len() as u16;
                ops.push(MobOp::Load {
                    space: MemSpace::L1,
                    base: plan.b_l1,
                    stride: 1,
                    count: stream_words,
                    dir: DirMode::Fixed(Dir::West),
                    replicate: 1,
                    steps: [0, 0],
                });
                if n_it > 2 {
                    ops.push(MobOp::Loop { start: load_pc, extra: n_it - 2 });
                }
            }
            // Next panel's first chunk, from ext (valid before the
            // re-stage; the slack copy handles the last jt's wrap).
            ops.push(MobOp::Load {
                space: MemSpace::Ext,
                base: plan.b_ext + panel as u32,
                stride: 1,
                count: dummy,
                dir: DirMode::Fixed(Dir::West),
                replicate: 1,
                steps: [panel as i32, 0],
            });
            ops.push(MobOp::Barrier);
            ops.push(MobOp::Loop { start: jt_start, extra: n_jt - 1 });
        }
        Strategy::NaiveExt => {
            ops.push(MobOp::Load {
                space: MemSpace::Ext,
                base: plan.b_ext,
                stride: 1,
                count: stream_words,
                dir: DirMode::Fixed(Dir::West),
                replicate: 1,
                steps: [panel as i32, 0],
            });
            ops.push(MobOp::Loop { start: 0, extra: n_jt - 1 });
            ops.push(MobOp::Loop { start: 0, extra: n_it - 1 });
            ops.push(MobOp::load(MemSpace::Ext, plan.b_ext, 1, dummy, Dir::West));
        }
    }
    ops.push(MobOp::Halt);
    MobProgram { ops }
}

/// Single-feed A-stream + C-store MOB (grid column `pe_cols + 1`).
fn mob_a_single(plan: &GemmPlan, r: usize) -> MobProgram {
    let kp = plan.kp as u32;
    let rows = plan.rows;
    let c_cols = plan.pe_cols;
    let (n_it, n_jt) = (plan.n_it as u32, plan.n_jt as u32);
    let crw = plan.c_row_words() as i32;
    let a_slice_ext = plan.a_ext + (r as u32) * kp;
    let a_slice_l1 = plan.a_slice_l1(r);

    // Switched NoC: the MOB unicasts each a-word to every PE column
    // (replicate + rotate through the route-table slots).
    let (a_dir, a_rep) = match plan.variant {
        MapVariant::Switched => (DirMode::Rotate, c_cols as u8),
        _ => (DirMode::Fixed(Dir::East), 1),
    };
    let a_load = |space: MemSpace, base: u32, steps: [i32; 2]| MobOp::Load {
        space,
        base,
        stride: 1,
        count: kp,
        dir: a_dir,
        replicate: a_rep,
        steps,
    };
    let store_ops = |ops: &mut Vec<MobOp>, steps: [i32; 2]| match plan.output {
        OutputMode::Quant { .. } => {
            for c in 0..c_cols {
                ops.push(MobOp::Store {
                    space: MemSpace::Ext,
                    base: plan.c_ext + (4 * r as u32) * crw as u32 + c as u32,
                    stride: crw,
                    count: 4,
                    dir: Dir::East,
                    steps,
                });
            }
        }
        OutputMode::Raw => {
            for c in 0..c_cols {
                for rr in 0..4 {
                    ops.push(MobOp::Store {
                        space: MemSpace::Ext,
                        base: plan.c_ext
                            + ((4 * r + rr) as u32) * crw as u32
                            + (4 * c) as u32,
                        stride: 1,
                        count: 4,
                        dir: Dir::East,
                        steps,
                    });
                }
            }
        }
    };

    let mut ops = Vec::new();
    match plan.strategy {
        Strategy::WholeB => {
            ops.push(MobOp::Barrier);
            let it_start = ops.len() as u16;
            ops.push(MobOp::Dma {
                ext_base: a_slice_ext,
                l1_base: a_slice_l1,
                count: kp,
                to_l1: true,
                ext_steps: [(rows as u32 * kp) as i32, 0],
                l1_steps: [0, 0],
            });
            ops.push(MobOp::Fence);
            let jt_start = ops.len() as u16;
            ops.push(a_load(MemSpace::L1, a_slice_l1, [0, 0]));
            store_ops(&mut ops, [c_cols as i32, rows as i32 * crw * 4]);
            ops.push(MobOp::Loop { start: jt_start, extra: n_jt - 1 });
            ops.push(MobOp::Loop { start: it_start, extra: n_it - 1 });
        }
        Strategy::PanelB => {
            ops.push(MobOp::Barrier);
            let it_start = ops.len() as u16;
            ops.push(MobOp::Dma {
                ext_base: a_slice_ext,
                l1_base: a_slice_l1,
                count: kp,
                to_l1: true,
                ext_steps: [(rows as u32 * kp) as i32, 0],
                l1_steps: [0, 0],
            });
            ops.push(MobOp::Fence);
            ops.push(a_load(MemSpace::L1, a_slice_l1, [0, 0]));
            store_ops(&mut ops, [rows as i32 * crw * 4, c_cols as i32]);
            ops.push(MobOp::Loop { start: it_start, extra: n_it - 1 });
            ops.push(MobOp::Barrier);
            ops.push(MobOp::Loop { start: 0, extra: n_jt - 1 });
        }
        Strategy::NaiveExt => {
            ops.push(a_load(MemSpace::Ext, a_slice_ext, [0, (rows as u32 * kp) as i32]));
            store_ops(&mut ops, [c_cols as i32, rows as i32 * crw * 4]);
            ops.push(MobOp::Loop { start: 0, extra: n_jt - 1 });
            ops.push(MobOp::Loop { start: 0, extra: n_it - 1 });
        }
    }
    ops.push(MobOp::Halt);
    MobProgram { ops }
}

// ====================================================================
// No-MOB ablation (TAB4)
// ====================================================================

/// PE program for the no-MOB ablation: inline L1 loads + direct stores.
fn pe_program_peload(plan: &GemmPlan, r: usize, c: usize) -> Result<PeProgram> {
    let OutputMode::Quant { shift } = plan.output else {
        bail!("PeLoad ablation supports quantized output only");
    };
    let c_cols = plan.pe_cols;
    let crw = plan.c_row_words() as i32;
    let a_base = plan.a_slice_l1(r) as i64;
    // Single-layout B panel: word (t, cc, col) at `t*4C + cc*C + col`.
    let b_base = plan.b_l1 as i64 + c as i64;
    let c_base = plan.c_ext as i64 + (4 * r) as i64 * crw as i64 + c as i64;
    for (name, v) in [("a", a_base), ("b", b_base), ("c", c_base + 3 * crw as i64)] {
        ensure!(v <= i16::MAX as i64, "PeLoad {name} base {v} exceeds immediate range");
    }

    let imm = |v: i64| Src::Imm(v as i16);
    let set = |reg: u8, v: i64| PeInstr::Alu {
        op: AluOp::AddI,
        dst: Dst::Reg(reg),
        a: imm(v),
        ra: Rider::NONE,
        b: Src::Imm(0),
        rb: Rider::NONE,
    };
    let prologue = vec![set(ADDR_A, a_base), set(ADDR_B, b_base), set(ADDR_C, c_base)];

    let mut body = Vec::with_capacity(24);
    for rr in 0..4u8 {
        body.push(PeInstr::LoadW {
            dst: A_REG + rr,
            space: MemSpace::L1,
            addr_reg: ADDR_A,
            post_inc: 1,
        });
    }
    for cc in 0..4u8 {
        body.push(PeInstr::LoadW {
            dst: B_BANK0 + cc,
            space: MemSpace::L1,
            addr_reg: ADDR_B,
            post_inc: c_cols as i16,
        });
    }
    for s in 0..16usize {
        let rr = (s / 4) as u8;
        let cc = (s % 4) as u8;
        body.push(PeInstr::MacP {
            d: rr * 4 + cc,
            a: Src::Reg(A_REG + rr),
            ra: Rider::NONE,
            b: Src::Reg(B_BANK0 + cc),
            rb: Rider::NONE,
            take: None,
        });
    }

    let mut tile_epilogue = Vec::with_capacity(8);
    for rr in 0..4u8 {
        tile_epilogue.push(PeInstr::AccOutQ {
            d: 4 * rr,
            shift,
            dst: Dst::Reg(TMP),
            clear: true,
        });
        tile_epilogue.push(PeInstr::StoreW {
            src: TMP,
            space: MemSpace::Ext,
            addr_reg: ADDR_C,
            post_inc: crw as i16,
        });
    }

    Ok(PeProgram {
        prologue,
        body,
        trip: plan.chunks() as u32,
        tile_epilogue,
        tiles: 1,
        epilogue: vec![PeInstr::Halt],
    })
}

/// Route tables for the switched NoC: every statically-used link becomes
/// a configured unicast route.
fn build_routes(plan: &GemmPlan, topo: &Topology) -> RouteTable {
    let mut rt = RouteTable::new(topo.nodes());
    let c_cols = plan.pe_cols;
    for r in 0..plan.rows {
        let b_mob = topo.mob(r, 0);
        let a_mob = topo.mob(r, 1);
        rt.set(b_mob, Dir::West, topo.pe(r, c_cols - 1), Dir::East);
        for (slot, c) in Dir::ALL.iter().zip(0..c_cols) {
            rt.set(a_mob, *slot, topo.pe(r, c), Dir::West);
        }
        for c in 0..c_cols {
            let dst = if c == 0 { (a_mob, Dir::East) } else { (topo.pe(r, c - 1), Dir::East) };
            rt.set(topo.pe(r, c), Dir::West, dst.0, dst.1);
        }
    }
    rt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;

    fn plan(m: usize, k: usize, n: usize) -> GemmPlan {
        GemmPlan::new(&ArchConfig::default(), m, k, n, OutputMode::Quant { shift: 6 }).unwrap()
    }

    #[test]
    fn pe_programs_dedupe_by_column() {
        let p = plan(64, 64, 64);
        let (ctx, _) = build_context(&p).unwrap();
        let mut uniq = std::collections::HashSet::new();
        for prog in &ctx.pe_programs {
            uniq.insert(format!("{prog:?}"));
        }
        assert_eq!(uniq.len(), 4, "rows share programs; one per column");
    }

    #[test]
    fn dual_body_full_mac_coverage() {
        let p = plan(16, 16, 16);
        assert_eq!(p.feed, FeedKind::Dual);
        let (ctx, _) = build_context(&p).unwrap();
        for prog in &ctx.pe_programs {
            assert_eq!(prog.body.len(), 32);
            for half in prog.body.chunks(16) {
                let mut seen = [false; 16];
                for ins in half {
                    if let PeInstr::MacP { d, .. } = ins {
                        assert!(!seen[*d as usize]);
                        seen[*d as usize] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s));
            }
        }
    }

    #[test]
    fn dual_take_budget_per_column() {
        let p = plan(16, 16, 16);
        let (ctx, _) = build_context(&p).unwrap();
        // Per chunk: PE3 absorbs 2 east words (own + relay), PE2 one,
        // PE1 one (west), PE0 two (west own + relay). ×4 lanes ×2 chunks.
        let takes = |c: usize| {
            ctx.pe_programs[c]
                .body
                .iter()
                .filter(|i| matches!(i, PeInstr::MacP { take: Some(_), .. }))
                .count()
        };
        assert_eq!(takes(0), 16);
        assert_eq!(takes(1), 8);
        assert_eq!(takes(2), 8);
        assert_eq!(takes(3), 16);
    }

    #[test]
    fn single_feed_selected_for_panel_b() {
        let p = plan(256, 128, 256);
        assert_eq!(p.strategy, Strategy::PanelB);
        assert_eq!(p.feed, FeedKind::Single);
        build_context(&p).unwrap();
    }

    #[test]
    fn barrier_counts_validated() {
        for (m, k, n) in [(64, 64, 64), (256, 128, 256), (16, 16, 16)] {
            build_context(&plan(m, k, n)).unwrap();
        }
    }

    #[test]
    fn switched_routes_cover_all_senders() {
        let p = GemmPlan::for_variant(
            &ArchConfig::default(),
            32,
            16,
            32,
            OutputMode::Quant { shift: 6 },
            MapVariant::Switched,
        )
        .unwrap();
        let (_, routes) = build_context(&p).unwrap();
        let rt = routes.unwrap();
        let topo = Topology::new(4, 4, 2);
        for r in 0..4 {
            assert!(rt.get(topo.mob(r, 0), Dir::West).is_some());
            for d in Dir::ALL {
                assert!(rt.get(topo.mob(r, 1), d).is_some(), "a-MOB slot {d}");
            }
            for c in 0..4 {
                assert!(rt.get(topo.pe(r, c), Dir::West).is_some());
            }
        }
    }

    #[test]
    fn peload_multi_tile_rejected() {
        let p = GemmPlan::for_variant(
            &ArchConfig::default(),
            64,
            16,
            16,
            OutputMode::Quant { shift: 6 },
            MapVariant::PeLoad,
        )
        .unwrap();
        assert!(build_context(&p).is_err());
    }

    #[test]
    fn slice_partitions_exactly() {
        for total in [16usize, 17, 100, 3] {
            for parts in [1usize, 3, 4] {
                let mut covered = 0u32;
                for i in 0..parts {
                    let (off, cnt) = slice(total, parts, i);
                    assert_eq!(off, covered);
                    covered += cnt;
                }
                assert_eq!(covered as usize, total);
            }
        }
    }
}
