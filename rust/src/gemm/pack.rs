//! Host-side operand packing into the CGRA stream layouts.
//!
//! Real deployments pack weights offline (as cuBLAS/XNNPACK do); here the
//! host CPU of Fig. 1 does it for both operands. The layouts are chosen
//! so every MOB stream is a *unit-stride* L1 read:
//!
//! **A layout** (per i-tile panel of `4·rows × kp`): row-group-major;
//! within row-group `r` (4 matrix rows), word `(t, rr)` at offset
//! `t*4 + rr` is packed `A[i0+4r+rr][4t..4t+4]`. The a-MOB of grid row
//! `r` streams its row-group sequentially.
//!
//! **B layout** (per j-tile panel of `4·pe_cols × kp`, transposed):
//! word `(t, cc, c)` at offset `t*4*C + cc*C + c` is packed
//! `B[4t..4t+4][j0+4c+cc]` — exactly the emission order of the b-stream
//! (k-chunk major, then lane `cc`, then PE column *ascending*: the
//! west-most PE's word leads, so at every hop the pass-through forwards
//! precede the PE's own latch — the ordering that makes the elastic
//! schedule deadlock- and bubble-free, see `mapper`). One sequential
//! read per row MOB.
//!
//! **C layout**: natural row-major over the padded `mp × np` output
//! (int8-packed words in quant mode, one word per element in raw mode) —
//! C leaves the array in standard layout, no host unpacking beyond
//! removing padding.

use super::plan::GemmPlan;
use crate::util::mat::MatI8;
use crate::util::quant::pack4;

/// Element of padded A at (i, k), zero outside bounds.
#[inline]
fn a_at(a: &MatI8, i: usize, k: usize) -> i8 {
    if i < a.rows && k < a.cols {
        a.at(i, k)
    } else {
        0
    }
}

/// Element of padded B at (k, j), zero outside bounds.
#[inline]
fn b_at(b: &MatI8, k: usize, j: usize) -> i8 {
    if k < b.rows && j < b.cols {
        b.at(k, j)
    } else {
        0
    }
}

/// Pack A (M×K) into the per-i-tile stream layout. Output length:
/// `n_it * rows * kp` words.
pub fn pack_a(a: &MatI8, plan: &GemmPlan) -> Vec<u32> {
    let (rows, kp) = (plan.rows, plan.kp);
    let chunks = plan.chunks();
    let mut out = Vec::with_capacity(plan.n_it * rows * kp);
    for it in 0..plan.n_it {
        let i0 = it * 4 * rows;
        for r in 0..rows {
            for t in 0..chunks {
                for rr in 0..4 {
                    let i = i0 + 4 * r + rr;
                    out.push(pack4([
                        a_at(a, i, 4 * t),
                        a_at(a, i, 4 * t + 1),
                        a_at(a, i, 4 * t + 2),
                        a_at(a, i, 4 * t + 3),
                    ]));
                }
            }
        }
    }
    out
}

/// Pack B (K×N) into the per-j-tile transposed stream layout. Output
/// length: `n_jt * pe_cols * kp + 4 * pe_cols` words (one chunk of slack
/// — a copy of panel 0's first chunk — appended for the PanelB
/// cross-panel prefetch wrap).
pub fn pack_b(b: &MatI8, plan: &GemmPlan) -> Vec<u32> {
    let c_cols = plan.pe_cols;
    let chunks = plan.chunks();
    let mut out = Vec::with_capacity(plan.n_jt * c_cols * plan.kp + 4 * c_cols);
    for jt in 0..plan.n_jt {
        let j0 = jt * 4 * c_cols;
        for t in 0..chunks {
            for cc in 0..4 {
                for c in 0..c_cols {
                    let j = j0 + 4 * c + cc;
                    out.push(pack4([
                        b_at(b, 4 * t, j),
                        b_at(b, 4 * t + 1, j),
                        b_at(b, 4 * t + 2, j),
                        b_at(b, 4 * t + 3, j),
                    ]));
                }
            }
        }
    }
    let slack: Vec<u32> = out[..(4 * c_cols).min(out.len())].to_vec();
    out.extend_from_slice(&slack);
    out
}

/// Pack one half of B for the dual feed. `east = true` packs the lanes
/// of the eastern PE columns in consumption order `[own-of-outermost,
/// relay…]` — per (j-tile, chunk, lane): columns `C-1, C-2, …, C/2` for
/// east, `0, 1, …, C/2-1` for west. A copy of panel 0's first chunk is
/// appended as slack so cross-tile prefetch overruns at i-tile boundaries
/// read valid data (see `plan::DUAL_SLACK_WORDS`).
pub fn pack_b_half(b: &MatI8, plan: &GemmPlan, east: bool) -> Vec<u32> {
    let c_cols = plan.pe_cols;
    let half = (c_cols / 2).max(1);
    let chunks = plan.chunks();
    let cols: Vec<usize> = if east {
        // East-most first (its own word leads each group).
        (c_cols - half..c_cols).rev().collect()
    } else {
        (0..half).collect()
    };
    let mut out =
        Vec::with_capacity(plan.n_jt * half * plan.kp + crate::gemm::plan::DUAL_SLACK_WORDS);
    for jt in 0..plan.n_jt {
        let j0 = jt * 4 * c_cols;
        for t in 0..chunks {
            for cc in 0..4 {
                for &c in &cols {
                    let j = j0 + 4 * c + cc;
                    out.push(pack4([
                        b_at(b, 4 * t, j),
                        b_at(b, 4 * t + 1, j),
                        b_at(b, 4 * t + 2, j),
                        b_at(b, 4 * t + 3, j),
                    ]));
                }
            }
        }
    }
    // Slack: copy of panel 0's first chunk (the i-tile-boundary prefetch
    // target).
    let slack: Vec<u32> = out[..crate::gemm::plan::DUAL_SLACK_WORDS.min(out.len())].to_vec();
    out.extend_from_slice(&slack);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::gemm::plan::OutputMode;
    use crate::util::quant::unpack4;

    fn plan(m: usize, k: usize, n: usize) -> GemmPlan {
        GemmPlan::new(&ArchConfig::default(), m, k, n, OutputMode::Quant { shift: 6 }).unwrap()
    }

    #[test]
    fn pack_a_sizes() {
        let p = plan(16, 16, 16);
        let a = MatI8::zeros(16, 16);
        assert_eq!(pack_a(&a, &p).len(), p.n_it * p.rows * p.kp);
    }

    #[test]
    fn pack_a_layout_spot_checks() {
        let p = plan(16, 16, 16);
        let mut a = MatI8::zeros(16, 16);
        for i in 0..16 {
            for k in 0..16 {
                *a.at_mut(i, k) = (i * 16 + k) as i8;
            }
        }
        let w = pack_a(&a, &p);
        // Row-group 0, chunk 0, rr 0 = A[0][0..4].
        assert_eq!(unpack4(w[0]), [0, 1, 2, 3]);
        // Row-group 0, chunk 0, rr 2 = A[2][0..4].
        assert_eq!(unpack4(w[2]), [32, 33, 34, 35]);
        // Row-group 1 (rows 4..8) starts at offset kp = 16 words.
        assert_eq!(unpack4(w[16]), [64, 65, 66, 67]);
        // Row-group 0, chunk 1, rr 0 = A[0][4..8].
        assert_eq!(unpack4(w[4]), [4, 5, 6, 7]);
    }

    #[test]
    fn pack_a_pads_with_zeros() {
        let p = plan(3, 5, 16); // mp=16, kp=8
        let mut a = MatI8::zeros(3, 5);
        a.data.iter_mut().for_each(|v| *v = 1);
        let w = pack_a(&a, &p);
        // Row 3 (padding) chunk 0 rr 3 must be zero.
        assert_eq!(unpack4(w[3]), [0, 0, 0, 0]);
        // Row 0 chunk 1 = A[0][4..8]: only k=4 in bounds.
        assert_eq!(unpack4(w[4]), [1, 0, 0, 0]);
    }

    #[test]
    fn pack_b_layout_emission_order() {
        let p = plan(16, 8, 16);
        let mut b = MatI8::zeros(8, 16);
        for k in 0..8 {
            for j in 0..16 {
                *b.at_mut(k, j) = (k * 16 + j) as i8;
            }
        }
        let w = pack_b(&b, &p);
        // First word: chunk 0, cc 0, c = 0 (west-most) → column j = 0,
        // packed B[0..4][0].
        assert_eq!(unpack4(w[0]), [0, 16, 32, 48]);
        // Fourth word: chunk 0, cc 0, c = 3 → column 12.
        assert_eq!(unpack4(w[3]), [12, 28, 44, 60]);
        // Fifth word: chunk 0, cc 1, c = 0 → column 1.
        assert_eq!(unpack4(w[4]), [1, 17, 33, 49]);
        // Chunk 1 starts at 16 words: cc 0, c 0 → B[4..8][0].
        assert_eq!(unpack4(w[16]), [64, 80, 96, 112]);
    }

    #[test]
    fn pack_b_sizes_multi_tile() {
        let p = plan(16, 16, 64);
        let b = MatI8::zeros(16, 64);
        // Panel words plus one chunk of wrap slack.
        assert_eq!(pack_b(&b, &p).len(), p.n_jt * p.pe_cols * p.kp + 4 * p.pe_cols);
    }

    #[test]
    fn pack_b_half_covers_all_columns() {
        let p = plan(16, 8, 16);
        let mut b = MatI8::zeros(8, 16);
        for k in 0..8 {
            for j in 0..16 {
                *b.at_mut(k, j) = (k * 16 + j) as i8;
            }
        }
        let east = pack_b_half(&b, &p, true);
        let west = pack_b_half(&b, &p, false);
        use crate::gemm::plan::DUAL_SLACK_WORDS;
        assert_eq!(east.len(), 2 * p.kp + DUAL_SLACK_WORDS);
        assert_eq!(west.len(), 2 * p.kp + DUAL_SLACK_WORDS);
        // East order per group: column 3 (own of PE3) then column 2.
        assert_eq!(unpack4(east[0]), [12, 28, 44, 60]); // B[0..4][12]
        assert_eq!(unpack4(east[1]), [8, 24, 40, 56]); // B[0..4][8]
        // West order: column 0 then column 1.
        assert_eq!(unpack4(west[0]), [0, 16, 32, 48]);
        assert_eq!(unpack4(west[1]), [4, 20, 36, 52]);
        // Slack is a copy of the first chunk's 8 words.
        assert_eq!(&east[east.len() - DUAL_SLACK_WORDS..], &east[..DUAL_SLACK_WORDS]);
    }

    #[test]
    fn prop_pack_preserves_all_elements() {
        use crate::util::prop::{ensure, prop_check, PropConfig};
        let cfg = PropConfig { cases: 16, base_seed: 9 };
        prop_check("pack_a/pack_b are permutations with padding", cfg, |rng| {
            let m = rng.range(1, 33);
            let k = rng.range(1, 33);
            let n = rng.range(1, 33);
            let p = plan(m, k, n);
            let mut a = MatI8::zeros(m, k);
            rng.fill_i8(&mut a.data, 127);
            let aw = pack_a(&a, &p);
            // Sum of absolute values must be preserved (padding adds 0s).
            let sum_in: i64 = a.data.iter().map(|&v| (v as i64).abs()).sum();
            let sum_out: i64 = aw
                .iter()
                .flat_map(|&w| unpack4(w))
                .map(|v| (v as i64).abs())
                .sum();
            ensure(sum_in == sum_out, || format!("m={m} k={k}: {sum_in} != {sum_out}"))
        });
    }
}
