//! True batch GEMM: one stacked `(Σmᵢ) × K × N` kernel over row-blocks
//! that share the B operand.
//!
//! Serving workloads multiply many small activation matrices against the
//! *same* weight matrix. Running them as separate kernels re-streams B
//! (and re-pays context configuration, DMA staging and fill/drain) once
//! per request. Stacking the activations into one tall A matrix turns
//! the whole batch into a single blocked GEMM: B crosses the external
//! boundary once, the context is distributed once, and the steady-state
//! MAC pipeline amortizes its fill across every block — the
//! batching-driven weight-reuse lever the edge-serving literature
//! (EdgeTran; Kim et al. 2023) identifies as the dominant throughput
//! and energy win.
//!
//! Numerical contract: the int8 GEMM is row-wise independent and the
//! simulated kernel is bit-exact against [`MatI8::matmul`] for every
//! plan, so each unstacked block is **bit-identical** to running that
//! block as its own GEMM with the same requant shift. The encoder-level
//! batching in [`crate::xformer::run::run_encoder_batch`] builds on
//! exactly this property.

use super::plan::{GemmPlan, OutputMode};
use super::run_gemm;
use crate::config::ArchConfig;
use crate::sim::{CgraSim, SimOutcome};
use crate::util::mat::MatI8;
use anyhow::{ensure, Result};

/// Stack row-blocks that share a column count into one tall matrix.
pub fn stack_i8(blocks: &[&MatI8]) -> MatI8 {
    assert!(!blocks.is_empty(), "stack needs at least one block");
    let cols = blocks[0].cols;
    assert!(blocks.iter().all(|b| b.cols == cols), "all blocks must share the column count");
    let rows = blocks.iter().map(|b| b.rows).sum();
    let mut out = MatI8::zeros(rows, cols);
    let mut off = 0usize;
    for b in blocks {
        out.data[off..off + b.data.len()].copy_from_slice(&b.data);
        off += b.data.len();
    }
    out
}

/// Split a stacked matrix back into its row-blocks.
pub fn unstack_i8(stacked: &MatI8, block_rows: &[usize]) -> Vec<MatI8> {
    assert_eq!(
        stacked.rows,
        block_rows.iter().sum::<usize>(),
        "stacked rows must match the block partition"
    );
    let mut out = Vec::with_capacity(block_rows.len());
    let mut row = 0usize;
    for &m in block_rows {
        let mut blk = MatI8::zeros(m, stacked.cols);
        blk.data
            .copy_from_slice(&stacked.data[row * stacked.cols..(row + m) * stacked.cols]);
        out.push(blk);
        row += m;
    }
    out
}

/// Result of a batched GEMM: the shared kernel outcome plus the
/// per-block outputs (bit-identical to per-block runs).
pub struct BatchedGemmRun {
    pub outcome: SimOutcome,
    pub blocks: Vec<MatI8>,
}

/// A planned stacked GEMM over same-K/N row-blocks.
pub struct BatchedGemm {
    /// Row count of each stacked block, in stacking order.
    block_rows: Vec<usize>,
    pub k: usize,
    pub n: usize,
    /// The single plan covering the whole stack.
    pub plan: GemmPlan,
}

impl BatchedGemm {
    /// Plan one `(Σ block_rows) × k × n` GEMM. Requantized output only:
    /// the raw-accumulator mode is single-tile and cannot stack.
    pub fn new(
        cfg: &ArchConfig,
        block_rows: &[usize],
        k: usize,
        n: usize,
        output: OutputMode,
    ) -> Result<Self> {
        ensure!(!block_rows.is_empty(), "batched GEMM needs at least one block");
        ensure!(block_rows.iter().all(|&m| m > 0), "block rows must be positive");
        ensure!(
            matches!(output, OutputMode::Quant { .. }),
            "batched GEMM requires requantized output (Raw is single-tile only)"
        );
        let m_total: usize = block_rows.iter().sum();
        let plan = GemmPlan::new(cfg, m_total, k, n, output)?;
        Ok(Self { block_rows: block_rows.to_vec(), k, n, plan })
    }

    /// Number of stacked blocks.
    pub fn batch(&self) -> usize {
        self.block_rows.len()
    }

    /// Total stacked rows.
    pub fn stacked_rows(&self) -> usize {
        self.block_rows.iter().sum()
    }

    /// Predicted external-memory words saved versus running every block
    /// as its own GEMM: the packed B panel (`pe_cols · kp` words per
    /// j-tile) crosses the external boundary once instead of once per
    /// block.
    pub fn weight_reuse_words(&self) -> u64 {
        let b_words = (self.plan.pe_cols * self.plan.kp * self.plan.n_jt) as u64;
        (self.batch() as u64 - 1) * b_words
    }

    /// Stack the A blocks, execute the single kernel, unstack C.
    pub fn run(&self, sim: &mut CgraSim, blocks: &[&MatI8], b: &MatI8) -> Result<BatchedGemmRun> {
        ensure!(blocks.len() == self.batch(), "block count mismatch with plan");
        for (blk, &m) in blocks.iter().zip(&self.block_rows) {
            ensure!(blk.rows == m && blk.cols == self.k, "A block shape mismatch with plan");
        }
        let a = stack_i8(blocks);
        let run = run_gemm(sim, &a, b, &self.plan)?;
        let c = run.c_i8.expect("batched GEMM plans quantized output");
        Ok(BatchedGemmRun { outcome: run.outcome, blocks: unstack_i8(&c, &self.block_rows) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::oracle_quant;
    use crate::util::prop::{ensure as prop_ensure, prop_check, PropConfig};
    use crate::util::rng::XorShiftRng;

    fn random_mat(rng: &mut XorShiftRng, rows: usize, cols: usize, bound: i8) -> MatI8 {
        let mut m = MatI8::zeros(rows, cols);
        rng.fill_i8(&mut m.data, bound);
        m
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let a = MatI8::from_slice(2, 3, &[1, 2, 3, 4, 5, 6]);
        let b = MatI8::from_slice(1, 3, &[7, 8, 9]);
        let s = stack_i8(&[&a, &b]);
        assert_eq!(s.rows, 3);
        let back = unstack_i8(&s, &[2, 1]);
        assert_eq!(back[0], a);
        assert_eq!(back[1], b);
    }

    #[test]
    fn batched_blocks_bit_identical_to_separate_runs() {
        let mut rng = XorShiftRng::new(0xBA7C);
        let cfg = ArchConfig::default();
        let (k, n, shift) = (24, 32, 6u8);
        let rows = [10usize, 3, 16];
        let blocks: Vec<MatI8> = rows.iter().map(|&m| random_mat(&mut rng, m, k, 12)).collect();
        let w = random_mat(&mut rng, k, n, 12);

        let bg = BatchedGemm::new(&cfg, &rows, k, n, OutputMode::Quant { shift }).unwrap();
        let refs: Vec<&MatI8> = blocks.iter().collect();
        let mut sim = CgraSim::new(cfg.clone());
        let run = bg.run(&mut sim, &refs, &w).unwrap();

        for (blk, got) in blocks.iter().zip(&run.blocks) {
            let mut solo = CgraSim::new(cfg.clone());
            let plan = GemmPlan::new(&cfg, blk.rows, k, n, OutputMode::Quant { shift }).unwrap();
            let want = run_gemm(&mut solo, blk, &w, &plan).unwrap().c_i8.unwrap();
            assert_eq!(got, &want, "stacked block diverged from its solo run");
            assert_eq!(got, &oracle_quant(blk, &w, shift), "and from the host oracle");
        }
    }

    #[test]
    fn batched_streams_weights_once() {
        let mut rng = XorShiftRng::new(0xBA7D);
        let cfg = ArchConfig::default();
        let (k, n, shift) = (32, 32, 6u8);
        let rows = [16usize, 16, 16, 16];
        let blocks: Vec<MatI8> = rows.iter().map(|&m| random_mat(&mut rng, m, k, 10)).collect();
        let w = random_mat(&mut rng, k, n, 10);

        let bg = BatchedGemm::new(&cfg, &rows, k, n, OutputMode::Quant { shift }).unwrap();
        assert!(bg.weight_reuse_words() > 0);
        let refs: Vec<&MatI8> = blocks.iter().collect();
        let mut sim_b = CgraSim::new(cfg.clone());
        bg.run(&mut sim_b, &refs, &w).unwrap();

        let mut solo_words = 0u64;
        for blk in &blocks {
            let mut sim = CgraSim::new(cfg.clone());
            let plan = GemmPlan::new(&cfg, blk.rows, k, n, OutputMode::Quant { shift }).unwrap();
            run_gemm(&mut sim, blk, &w, &plan).unwrap();
            solo_words += sim.stats.ext_words();
        }
        assert!(
            sim_b.stats.ext_words() < solo_words,
            "stacking must cut external traffic: {} vs {}",
            sim_b.stats.ext_words(),
            solo_words
        );
    }

    #[test]
    fn raw_output_rejected() {
        let cfg = ArchConfig::default();
        assert!(BatchedGemm::new(&cfg, &[8, 8], 16, 16, OutputMode::Raw).is_err());
        assert!(BatchedGemm::new(&cfg, &[], 16, 16, OutputMode::Quant { shift: 6 }).is_err());
        assert!(BatchedGemm::new(&cfg, &[4, 0], 16, 16, OutputMode::Quant { shift: 6 }).is_err());
    }

    #[test]
    fn prop_batched_random_partitions_exact() {
        prop_check(
            "batched GEMM == per-block GEMM over random partitions",
            PropConfig { cases: 5, base_seed: 0xBA7C_ED },
            |rng| {
                let batch = rng.range(1, 5);
                let rows: Vec<usize> = (0..batch).map(|_| rng.range(1, 13)).collect();
                let k = rng.range(1, 33);
                let n = rng.range(1, 25);
                let cfg = ArchConfig::default();
                let blocks: Vec<MatI8> = rows
                    .iter()
                    .map(|&m| {
                        let mut b = MatI8::zeros(m, k);
                        rng.fill_i8(&mut b.data, 20);
                        b
                    })
                    .collect();
                let mut w = MatI8::zeros(k, n);
                rng.fill_i8(&mut w.data, 20);
                let bg =
                    BatchedGemm::new(&cfg, &rows, k, n, OutputMode::Quant { shift: 6 }).unwrap();
                let refs: Vec<&MatI8> = blocks.iter().collect();
                let mut sim = CgraSim::new(cfg.clone());
                let run = bg.run(&mut sim, &refs, &w).unwrap();
                for (blk, got) in blocks.iter().zip(&run.blocks) {
                    if got != &oracle_quant(blk, &w, 6) {
                        return crate::util::prop::CaseResult::Fail(format!(
                            "block {}x{k}x{n} of batch {batch} diverged",
                            blk.rows
                        ));
                    }
                }
                prop_ensure(true, String::new)
            },
        );
    }
}
