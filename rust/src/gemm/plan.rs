//! GEMM tiling plan: geometry, padding, L1 allocation, loop structure.
//!
//! A plan fixes everything the packer and mapper need:
//!
//! - Padded dims `mp × kp × np`: `mp` to a multiple of the tile height
//!   (4·rows), `np` to the tile width (4·pe_cols), `kp` to a multiple of
//!   8 (the PE body is a two-chunk unrolled loop over packed-4 k-chunks).
//! - Loop strategy (§IV-A1 "increased data reuse"):
//!   [`Strategy::WholeB`] keeps all of B^T resident in L1 (B crosses the
//!   external boundary once, A once); [`Strategy::PanelB`] stages one
//!   j-tile panel of B at a time (B once, A once per j-tile);
//!   [`Strategy::NaiveExt`] is the TAB2 baseline with no staging at all.
//! - The *feed* ([`FeedKind`]): the paper-geometry torus uses the
//!   **dual-feed** mapping — the B panel split into east/west halves,
//!   each streamed from its adjacent MOB column, with the A stream
//!   interleaved on the east wire. This keeps every relay chain pointing
//!   the same way as the data it depends on and sustains one MAC per PE
//!   per cycle (the single-feed relay couples opposed skews and tops out
//!   at ≈0.45 of peak — EXPERIMENTS.md §Perf). PanelB re-stages panels
//!   in place, which breaks dual-feed's cross-tile prefetch continuity,
//!   so it (and the switched/no-MOB variants) use the single feed.

use crate::config::ArchConfig;
use anyhow::{bail, Result};

/// Which hardware variant a plan targets (determines feed and layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapVariant {
    /// The paper's switchless mesh torus.
    Torus,
    /// Switched mesh-NoC baseline (TAB3).
    Switched,
    /// No-MOB ablation: PEs load operands themselves (TAB4).
    PeLoad,
}

/// Output handling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputMode {
    /// Requantize accumulators to int8 with a right-shift (the standard
    /// quantized-inference path; multi-tile capable).
    Quant { shift: u8 },
    /// Emit raw i32 accumulators (single tile-block only — used for
    /// attention score matrices that go to the host for softmax).
    Raw,
}

/// Data-reuse strategy (TAB2's comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// All of packed B^T resident in L1 for the whole GEMM.
    WholeB,
    /// One j-tile panel of B^T staged per outer iteration.
    PanelB,
    /// No staging: streams read external memory directly (baseline).
    NaiveExt,
}

/// How B reaches the PE rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedKind {
    /// B split across both MOB columns; A interleaved on the east wire.
    Dual,
    /// Single west-bound B stream with in-row relay (baseline mapping,
    /// also used by the switched and no-MOB variants).
    Single,
}

/// Words of slack after each dual-feed B half-region, pre-filled with a
/// copy of panel 0's first chunk so cross-tile prefetch overruns read
/// valid data at i-tile boundaries.
pub const DUAL_SLACK_WORDS: usize = 8;

/// A complete tiling plan. All addresses are 32-bit word addresses.
#[derive(Debug, Clone)]
pub struct GemmPlan {
    // Logical dims.
    pub m: usize,
    pub k: usize,
    pub n: usize,
    // Padded dims.
    pub mp: usize,
    pub kp: usize,
    pub np: usize,
    // Array geometry.
    pub rows: usize,
    pub pe_cols: usize,
    // Tile counts.
    pub n_it: usize,
    pub n_jt: usize,
    pub output: OutputMode,
    pub strategy: Strategy,
    pub variant: MapVariant,
    pub feed: FeedKind,
    /// Host pre-stages all panels in L1 and the kernel skips DMA/barriers
    /// (TAB4 fairness: both the MOB-streaming and PE-load arms start from
    /// staged data). Requires a single i-tile and `WholeB` residency.
    pub prestaged: bool,
    // External layout (word addresses).
    pub a_ext: u32,
    /// Single-feed packed B (also used by PeLoad).
    pub b_ext: u32,
    /// Dual-feed east-half B region (lanes for the eastern PE columns).
    pub b_east_ext: u32,
    /// Dual-feed west-half B region.
    pub b_west_ext: u32,
    pub c_ext: u32,
    // L1 layout (word addresses).
    pub a_l1: u32,
    pub b_l1: u32,
    pub b_east_l1: u32,
    pub b_west_l1: u32,
}

impl GemmPlan {
    /// Plan for the paper's torus with an auto-chosen reuse strategy.
    pub fn new(cfg: &ArchConfig, m: usize, k: usize, n: usize, output: OutputMode) -> Result<Self> {
        Self::build(cfg, m, k, n, output, None, MapVariant::Torus)
    }

    /// Plan for an explicit hardware variant.
    pub fn for_variant(
        cfg: &ArchConfig,
        m: usize,
        k: usize,
        n: usize,
        output: OutputMode,
        variant: MapVariant,
    ) -> Result<Self> {
        Self::build(cfg, m, k, n, output, None, variant)
    }

    /// Plan with a forced strategy (benches / TAB2 baseline).
    pub fn new_with_strategy(
        cfg: &ArchConfig,
        m: usize,
        k: usize,
        n: usize,
        output: OutputMode,
        strategy: Strategy,
    ) -> Result<Self> {
        Self::build(cfg, m, k, n, output, Some(strategy), MapVariant::Torus)
    }

    fn build(
        cfg: &ArchConfig,
        m: usize,
        k: usize,
        n: usize,
        output: OutputMode,
        forced: Option<Strategy>,
        variant: MapVariant,
    ) -> Result<Self> {
        if m == 0 || k == 0 || n == 0 {
            bail!("GEMM dims must be positive");
        }
        let rows = cfg.topo.rows;
        let pe_cols = cfg.topo.pe_cols;
        if pe_cols > 4 {
            bail!(
                "stream mapping supports up to 4 PE columns: the per-row entry \
                 links saturate (wider arrays need more MOB columns — the FIG5 \
                 finding); scale rows instead, e.g. the {rows}x4 device class"
            );
        }
        let mt = 4 * rows;
        let nt = 4 * pe_cols;
        let mp = m.div_ceil(mt) * mt;
        let np = n.div_ceil(nt) * nt;
        let kp = k.div_ceil(8) * 8;
        let n_it = mp / mt;
        let n_jt = np / nt;
        if matches!(output, OutputMode::Raw) && (n_it != 1 || n_jt != 1) {
            bail!(
                "Raw output supports a single tile-block only \
                 (m ≤ {mt}, n ≤ {nt}); requested {m}×{n}"
            );
        }

        // L1 budget check / strategy choice. The +1 staggers each
        // row-group's A slice to a different bank (slices at multiples of
        // kp would all start on bank 0 and the four a-streams would
        // collide every cycle).
        let a_panel = rows * (kp + 1);
        let b_panel = pe_cols * kp; // per j-tile (both halves combined)
        let b_whole = n_jt * b_panel;
        let l1 = cfg.mem.l1_words;
        let dual_slack = 2 * DUAL_SLACK_WORDS; // one per half-region
        let strategy = match forced {
            Some(s) => s,
            None => {
                if a_panel + b_whole + dual_slack <= l1 {
                    Strategy::WholeB
                } else if a_panel + b_panel <= l1 {
                    Strategy::PanelB
                } else {
                    bail!(
                        "K = {k} too large: A panel ({a_panel} w) + B panel ({b_panel} w) \
                         exceed L1 ({l1} w)"
                    )
                }
            }
        };
        if matches!(strategy, Strategy::WholeB) && a_panel + b_whole + dual_slack > l1 {
            bail!(
                "WholeB strategy does not fit L1 ({} w needed, {l1} available)",
                a_panel + b_whole
            );
        }
        if matches!(strategy, Strategy::PanelB) && a_panel + b_panel > l1 {
            bail!("PanelB strategy does not fit L1");
        }

        // Feed choice: dual needs the paper geometry (4 PE columns, even
        // split) and cross-tile stream continuity (not PanelB's in-place
        // re-staging), and only the torus mapping implements it.
        let feed = if variant == MapVariant::Torus
            && pe_cols == 4
            && !matches!(strategy, Strategy::PanelB)
        {
            FeedKind::Dual
        } else {
            FeedKind::Single
        };

        // External layout: A panels | B (single layout) | B east | B west | C.
        // Only the regions the feed uses get written, but reserving both
        // keeps addresses independent of late feed changes.
        let a_words = n_it * rows * kp;
        // Single-layout B carries one chunk of slack for the PanelB wrap.
        let b_words = n_jt * pe_cols * kp + 4 * pe_cols;
        let half_words = n_jt * (pe_cols / 2).max(1) * kp + DUAL_SLACK_WORDS;
        let a_ext = 0u32;
        let b_ext = a_words as u32;
        let b_east_ext = b_ext + b_words as u32;
        let b_west_ext = b_east_ext + half_words as u32;
        let c_ext = b_west_ext + half_words as u32;

        // L1 layout.
        let a_l1 = 0u32;
        let b_l1 = a_panel as u32;
        let (b_east_l1, b_west_l1) = match strategy {
            Strategy::WholeB => {
                let east = a_panel as u32;
                let west = east + (n_jt * (pe_cols / 2).max(1) * kp + DUAL_SLACK_WORDS) as u32;
                (east, west)
            }
            _ => {
                // PanelB never uses dual; NaiveExt streams straight from
                // external memory, so the L1 halves are unused.
                (b_l1, b_l1)
            }
        };

        Ok(Self {
            m,
            k,
            n,
            mp,
            kp,
            np,
            rows,
            pe_cols,
            n_it,
            n_jt,
            output,
            strategy,
            variant,
            feed,
            a_ext,
            b_ext,
            b_east_ext,
            b_west_ext,
            c_ext,
            a_l1,
            b_l1,
            b_east_l1,
            b_west_l1,
            prestaged: false,
        })
    }

    /// Switch to host-prestaged mode (see the `prestaged` field).
    pub fn with_prestaged(mut self) -> Result<Self> {
        if self.n_it != 1 || !matches!(self.strategy, Strategy::WholeB) {
            bail!("prestaged mode requires a single i-tile and WholeB residency");
        }
        self.prestaged = true;
        Ok(self)
    }

    /// Packed-4 k-chunks.
    pub fn chunks(&self) -> usize {
        self.kp / 4
    }

    /// L1 stride between row-group A slices (bank-staggered, see
    /// [`GemmPlan`] construction).
    pub fn a_slice_stride(&self) -> u32 {
        self.kp as u32 + 1
    }

    /// L1 address of row-group `r`'s A slice.
    pub fn a_slice_l1(&self, r: usize) -> u32 {
        self.a_l1 + r as u32 * self.a_slice_stride()
    }

    /// Words per j-tile panel *half* (dual feed).
    pub fn half_panel_words(&self) -> usize {
        (self.pe_cols / 2).max(1) * self.kp
    }

    /// Total tiles.
    pub fn tiles(&self) -> usize {
        self.n_it * self.n_jt
    }

    /// Words of C in external memory (padded).
    pub fn c_ext_words(&self) -> usize {
        match self.output {
            OutputMode::Quant { .. } => self.mp * self.np / 4,
            OutputMode::Raw => self.mp * self.np,
        }
    }

    /// C row stride in words.
    pub fn c_row_words(&self) -> usize {
        match self.output {
            OutputMode::Quant { .. } => self.np / 4,
            OutputMode::Raw => self.np,
        }
    }

    /// Useful MAC operations (unpadded).
    pub fn macs(&self) -> u64 {
        (self.m * self.k * self.n) as u64
    }

    /// Ideal steady-state cycles: one packed MAC per PE per cycle over
    /// the padded volume.
    pub fn ideal_cycles(&self) -> u64 {
        (self.mp * self.kp * self.np) as u64 / (4 * self.rows * self.pe_cols) as u64
    }

    /// Simulation cycle budget (generous multiple of ideal + fixed
    /// overhead for fills, drains and DMA).
    pub fn max_cycles(&self) -> u64 {
        40 * self.ideal_cycles() + 2_000_000
    }

    /// Predicted external-memory traffic in words (the TAB2 analytical
    /// line printed next to the simulator's measured counters).
    pub fn predicted_ext_words(&self) -> u64 {
        let a = (self.rows * self.kp * self.n_it) as u64;
        let b = (self.pe_cols * self.kp * self.n_jt) as u64;
        let c = self.c_ext_words() as u64;
        match self.strategy {
            Strategy::WholeB => a + b + c,
            Strategy::PanelB => a * self.n_jt as u64 + b + c,
            // Without staging there is no multicast reuse: every row MOB
            // re-fetches its B stream from external memory.
            Strategy::NaiveExt => {
                a * self.n_jt as u64 + b * (self.n_it * self.rows) as u64 + c
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn padding_to_tile_multiples() {
        let p = GemmPlan::new(&cfg(), 10, 12, 22, OutputMode::Quant { shift: 6 }).unwrap();
        assert_eq!(p.mp, 16);
        assert_eq!(p.kp, 16);
        assert_eq!(p.np, 32);
        assert_eq!(p.n_it, 1);
        assert_eq!(p.n_jt, 2);
    }

    #[test]
    fn small_problem_chooses_whole_b_dual() {
        let p = GemmPlan::new(&cfg(), 64, 64, 64, OutputMode::Quant { shift: 6 }).unwrap();
        assert_eq!(p.strategy, Strategy::WholeB);
        assert_eq!(p.feed, FeedKind::Dual);
    }

    #[test]
    fn large_problem_falls_back_to_panel_b_single() {
        let p = GemmPlan::new(&cfg(), 256, 256, 256, OutputMode::Quant { shift: 6 }).unwrap();
        assert_eq!(p.strategy, Strategy::PanelB);
        assert_eq!(p.feed, FeedKind::Single);
    }

    #[test]
    fn switched_uses_single_feed() {
        let p = GemmPlan::for_variant(
            &cfg(),
            32,
            32,
            32,
            OutputMode::Quant { shift: 6 },
            MapVariant::Switched,
        )
        .unwrap();
        assert_eq!(p.feed, FeedKind::Single);
    }

    #[test]
    fn naive_keeps_dual_feed() {
        let p = GemmPlan::new_with_strategy(
            &cfg(),
            64,
            32,
            64,
            OutputMode::Quant { shift: 6 },
            Strategy::NaiveExt,
        )
        .unwrap();
        assert_eq!(p.feed, FeedKind::Dual);
    }

    #[test]
    fn oversized_k_rejected() {
        let err = GemmPlan::new(&cfg(), 16, 8192, 16, OutputMode::Quant { shift: 6 }).unwrap_err();
        assert!(err.to_string().contains("too large"));
    }

    #[test]
    fn raw_multi_tile_rejected() {
        assert!(GemmPlan::new(&cfg(), 32, 16, 16, OutputMode::Raw).is_err());
        assert!(GemmPlan::new(&cfg(), 16, 16, 16, OutputMode::Raw).is_ok());
    }

    #[test]
    fn ext_layout_is_disjoint_and_ordered() {
        let p = GemmPlan::new(&cfg(), 48, 32, 64, OutputMode::Quant { shift: 6 }).unwrap();
        assert!(p.a_ext < p.b_ext);
        assert!(p.b_ext < p.b_east_ext);
        assert!(p.b_east_ext < p.b_west_ext);
        assert!(p.b_west_ext < p.c_ext);
        let half = p.n_jt * p.half_panel_words() + DUAL_SLACK_WORDS;
        assert_eq!((p.b_west_ext - p.b_east_ext) as usize, half);
    }

    #[test]
    fn predicted_traffic_ordering() {
        let mk = |s| {
            GemmPlan::new_with_strategy(&cfg(), 128, 64, 128, OutputMode::Quant { shift: 6 }, s)
                .unwrap()
                .predicted_ext_words()
        };
        let whole = mk(Strategy::WholeB);
        let panel = mk(Strategy::PanelB);
        let naive = mk(Strategy::NaiveExt);
        assert!(whole <= panel);
        assert!(panel < naive);
    }

    #[test]
    fn ideal_cycles_matches_hand_calc() {
        // 16×16×16 on 16 PEs × 4 lanes: 4096 MACs / 64 per cycle = 64.
        let p = GemmPlan::new(&cfg(), 16, 16, 16, OutputMode::Quant { shift: 6 }).unwrap();
        assert_eq!(p.ideal_cycles(), 64);
    }

    #[test]
    fn narrow_array_uses_single_feed() {
        let mut c = cfg();
        c.topo.pe_cols = 2;
        let p = GemmPlan::new(&c, 16, 16, 16, OutputMode::Quant { shift: 6 }).unwrap();
        assert_eq!(p.feed, FeedKind::Single);
    }
}
