//! Block-wise GEMM execution (paper §IV-A).
//!
//! The pipeline is: [`plan`] a tiling for the array geometry, hardware
//! variant and L1 budget, [`pack`] the operands into the CGRA's stream
//! layouts, [`mapper`] generate the kernel context (PE programs + MOB
//! stream programs + optional switched-NoC route tables), then execute on
//! [`crate::sim::CgraSim`] and unpack C.
//!
//! Numerical contract (FIG3): the simulated int8×int8→int32 GEMM is
//! **bit-exact** against [`crate::util::mat::MatI8::matmul`]; the
//! requantized path matches [`crate::util::quant::requant_shift`] applied
//! to the exact accumulators — for every strategy, feed and variant.

pub mod batch;
pub mod mapper;
pub mod pack;
pub mod plan;

pub use batch::{stack_i8, unstack_i8, BatchedGemm, BatchedGemmRun};
pub use mapper::build_context;
pub use plan::{FeedKind, GemmPlan, MapVariant, OutputMode, Strategy};

use crate::sim::{CgraSim, SimOutcome};
use crate::util::mat::{MatI32, MatI8};
use crate::util::quant::unpack_slice;
use anyhow::{ensure, Result};

/// Result of a GEMM run on the simulator.
pub struct GemmRun {
    pub outcome: SimOutcome,
    /// Output in int8 (requantized mode).
    pub c_i8: Option<MatI8>,
    /// Output in raw i32 accumulators (raw mode).
    pub c_i32: Option<MatI32>,
}

/// Stage packed operands into the simulator's external memory (and, for
/// the no-MOB ablation, pre-stage L1 — both TAB4 arms start from staged
/// panels so the comparison isolates stream decoupling).
pub fn stage_operands(sim: &mut CgraSim, a: &MatI8, b: &MatI8, plan: &GemmPlan) {
    let a_words = pack::pack_a(a, plan);
    sim.host_write_ext(plan.a_ext, &a_words);
    match plan.feed {
        FeedKind::Dual => {
            let east = pack::pack_b_half(b, plan, true);
            let west = pack::pack_b_half(b, plan, false);
            sim.host_write_ext(plan.b_east_ext, &east);
            sim.host_write_ext(plan.b_west_ext, &west);
            if plan.prestaged {
                for r in 0..plan.rows {
                    let kp = plan.kp;
                    sim.mem.host_write_l1(plan.a_slice_l1(r), &a_words[r * kp..(r + 1) * kp]);
                }
                sim.mem.host_write_l1(plan.b_east_l1, &east);
                sim.mem.host_write_l1(plan.b_west_l1, &west);
            }
        }
        FeedKind::Single => {
            let b_words = pack::pack_b(b, plan);
            sim.host_write_ext(plan.b_ext, &b_words);
            if plan.variant == MapVariant::PeLoad {
                // Honour the bank-staggered A slice layout.
                for r in 0..plan.rows {
                    let kp = plan.kp;
                    sim.mem.host_write_l1(plan.a_slice_l1(r), &a_words[r * kp..(r + 1) * kp]);
                }
                sim.mem.host_write_l1(plan.b_l1, &b_words);
            }
        }
    }
    // Zero the C region (stores fill it; padding rows stay zero).
    sim.host_write_ext(plan.c_ext, &vec![0u32; plan.c_ext_words()]);
}

/// Plan, pack, execute and unpack a full GEMM `C = A·B` on the simulator.
///
/// `a` is M×K, `b` is K×N, both int8. The output mode and hardware
/// variant come from the plan.
pub fn run_gemm(sim: &mut CgraSim, a: &MatI8, b: &MatI8, plan: &GemmPlan) -> Result<GemmRun> {
    ensure!(a.rows == plan.m && a.cols == plan.k, "A shape mismatch with plan");
    ensure!(b.rows == plan.k && b.cols == plan.n, "B shape mismatch with plan");

    stage_operands(sim, a, b, plan);
    let (ctx, routes) = build_context(plan)?;
    let outcome = sim.execute(&ctx, routes, plan.max_cycles())?;
    let run = match plan.output {
        OutputMode::Quant { .. } => {
            let words = sim.host_read_ext(plan.c_ext, plan.c_ext_words());
            let flat = unpack_slice(&words, plan.mp * plan.np);
            let mut c = MatI8::zeros(plan.m, plan.n);
            for r in 0..plan.m {
                c.data[r * plan.n..(r + 1) * plan.n]
                    .copy_from_slice(&flat[r * plan.np..r * plan.np + plan.n]);
            }
            GemmRun { outcome, c_i8: Some(c), c_i32: None }
        }
        OutputMode::Raw => {
            let words = sim.host_read_ext(plan.c_ext, plan.c_ext_words());
            let mut c = MatI32::zeros(plan.m, plan.n);
            for r in 0..plan.m {
                for col in 0..plan.n {
                    c.data[r * plan.n + col] = words[r * plan.np + col] as i32;
                }
            }
            GemmRun { outcome, c_i8: None, c_i32: Some(c) }
        }
    };
    Ok(run)
}

/// Host oracle for the requantized output (exact reference the simulator
/// must match bit-for-bit).
pub fn oracle_quant(a: &MatI8, b: &MatI8, shift: u8) -> MatI8 {
    let acc = a.matmul(b);
    MatI8 {
        rows: acc.rows,
        cols: acc.cols,
        data: acc
            .data
            .iter()
            .map(|&v| crate::util::quant::requant_shift(v, shift))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::util::rng::XorShiftRng;

    fn random_mat(rng: &mut XorShiftRng, rows: usize, cols: usize, bound: i8) -> MatI8 {
        let mut m = MatI8::zeros(rows, cols);
        rng.fill_i8(&mut m.data, bound);
        m
    }

    /// Raw-i32 drains quadruple the epilogue length; the context
    /// legitimately exceeds the paper's 4 KiB (EXPERIMENTS.md) — raw-mode
    /// and no-MOB workloads configure 8 KiB.
    fn big_ctx_cfg() -> ArchConfig {
        ArchConfig { ctx_bytes: 8192, ..ArchConfig::default() }
    }

    /// The FIG3 core check: simulated blocked GEMM == host oracle,
    /// bit-exact.
    #[test]
    fn gemm_exact_vs_oracle_single_tile() {
        let mut rng = XorShiftRng::new(0xF16_3);
        let mut sim = CgraSim::new(ArchConfig::default());
        let (m, k, n) = (16, 16, 16);
        let a = random_mat(&mut rng, m, k, 8);
        let b = random_mat(&mut rng, k, n, 8);
        let plan = GemmPlan::new(&sim.cfg, m, k, n, OutputMode::Quant { shift: 6 }).unwrap();
        assert_eq!(plan.feed, FeedKind::Dual);
        let run = run_gemm(&mut sim, &a, &b, &plan).unwrap();
        assert_eq!(run.c_i8.unwrap(), oracle_quant(&a, &b, 6));
    }

    #[test]
    fn gemm_exact_vs_oracle_multi_tile() {
        let mut rng = XorShiftRng::new(0xF16_4);
        let mut sim = CgraSim::new(ArchConfig::default());
        let (m, k, n) = (48, 32, 64);
        let a = random_mat(&mut rng, m, k, 10);
        let b = random_mat(&mut rng, k, n, 10);
        let plan = GemmPlan::new(&sim.cfg, m, k, n, OutputMode::Quant { shift: 7 }).unwrap();
        let run = run_gemm(&mut sim, &a, &b, &plan).unwrap();
        assert_eq!(run.c_i8.unwrap(), oracle_quant(&a, &b, 7));
    }

    #[test]
    fn gemm_exact_panel_b_single_feed() {
        let mut rng = XorShiftRng::new(0xF16_C);
        let mut sim = CgraSim::new(ArchConfig::default());
        let (m, k, n) = (32, 32, 64);
        let a = random_mat(&mut rng, m, k, 10);
        let b = random_mat(&mut rng, k, n, 10);
        let plan = GemmPlan::new_with_strategy(
            &sim.cfg,
            m,
            k,
            n,
            OutputMode::Quant { shift: 7 },
            Strategy::PanelB,
        )
        .unwrap();
        assert_eq!(plan.feed, FeedKind::Single);
        let run = run_gemm(&mut sim, &a, &b, &plan).unwrap();
        assert_eq!(run.c_i8.unwrap(), oracle_quant(&a, &b, 7));
    }

    #[test]
    fn gemm_exact_unpadded_odd_shapes() {
        let mut rng = XorShiftRng::new(0xF16_5);
        let mut sim = CgraSim::new(ArchConfig::default());
        let (m, k, n) = (10, 12, 22);
        let a = random_mat(&mut rng, m, k, 16);
        let b = random_mat(&mut rng, k, n, 16);
        let plan = GemmPlan::new(&sim.cfg, m, k, n, OutputMode::Quant { shift: 5 }).unwrap();
        let run = run_gemm(&mut sim, &a, &b, &plan).unwrap();
        assert_eq!(run.c_i8.unwrap(), oracle_quant(&a, &b, 5));
    }

    #[test]
    fn gemm_raw_accumulators_exact() {
        let mut rng = XorShiftRng::new(0xF16_6);
        let mut sim = CgraSim::new(big_ctx_cfg());
        let (m, k, n) = (16, 24, 16);
        let a = random_mat(&mut rng, m, k, 20);
        let b = random_mat(&mut rng, k, n, 20);
        let plan = GemmPlan::new(&sim.cfg, m, k, n, OutputMode::Raw).unwrap();
        let run = run_gemm(&mut sim, &a, &b, &plan).unwrap();
        assert_eq!(run.c_i32.unwrap(), a.matmul(&b));
    }

    #[test]
    fn gemm_switched_variant_matches_torus_numerics() {
        let mut rng = XorShiftRng::new(0xF16_7);
        let mut sim = CgraSim::new(ArchConfig::switched_baseline());
        let (m, k, n) = (32, 16, 32);
        let a = random_mat(&mut rng, m, k, 9);
        let b = random_mat(&mut rng, k, n, 9);
        let plan = GemmPlan::for_variant(
            &sim.cfg,
            m,
            k,
            n,
            OutputMode::Quant { shift: 6 },
            MapVariant::Switched,
        )
        .unwrap();
        let run = run_gemm(&mut sim, &a, &b, &plan).unwrap();
        assert_eq!(run.c_i8.unwrap(), oracle_quant(&a, &b, 6));
    }

    #[test]
    fn switched_takes_more_cycles_and_interconnect_energy() {
        // TAB3's claim: the switchless torus wins on both latency and
        // interconnect energy against the routed-NoC baseline.
        let mut rng = XorShiftRng::new(0xF16_8);
        let (m, k, n) = (32, 32, 32);
        let a = random_mat(&mut rng, m, k, 9);
        let b = random_mat(&mut rng, k, n, 9);

        let mut sim_t = CgraSim::new(ArchConfig::default());
        let plan_t = GemmPlan::new(&sim_t.cfg, m, k, n, OutputMode::Quant { shift: 6 }).unwrap();
        let run_t = run_gemm(&mut sim_t, &a, &b, &plan_t).unwrap();

        let mut sim_s = CgraSim::new(ArchConfig::switched_baseline());
        let plan_s = GemmPlan::for_variant(
            &sim_s.cfg,
            m,
            k,
            n,
            OutputMode::Quant { shift: 6 },
            MapVariant::Switched,
        )
        .unwrap();
        let run_s = run_gemm(&mut sim_s, &a, &b, &plan_s).unwrap();

        assert!(
            run_s.outcome.cycles > run_t.outcome.cycles,
            "switched NoC must be slower: {} vs {}",
            run_s.outcome.cycles,
            run_t.outcome.cycles
        );
        let em = crate::energy::EnergyModel::default();
        let e_t = em.evaluate(&sim_t.stats, 100.0).interconnect_pj;
        let e_s = em.evaluate(&sim_s.stats, 100.0).interconnect_pj;
        assert!(e_s > 2.0 * e_t, "router energy must dominate: {e_s} vs {e_t}");
    }

    #[test]
    fn peload_variant_matches_and_stalls_more() {
        // TAB4's claim: dedicated MOBs reduce PE idle time.
        let mut rng = XorShiftRng::new(0xF16_9);
        let (m, k, n) = (16, 32, 16);
        let a = random_mat(&mut rng, m, k, 9);
        let b = random_mat(&mut rng, k, n, 9);

        // Both arms start from host-prestaged L1 panels so the
        // comparison isolates streaming decoupling from staging cost.
        let mut sim_m = CgraSim::new(ArchConfig::default());
        let plan_m = GemmPlan::new(&sim_m.cfg, m, k, n, OutputMode::Quant { shift: 6 })
            .unwrap()
            .with_prestaged()
            .unwrap();
        let run_m = run_gemm(&mut sim_m, &a, &b, &plan_m).unwrap();

        let mut sim_p = CgraSim::new(big_ctx_cfg());
        let plan_p = GemmPlan::for_variant(
            &sim_p.cfg,
            m,
            k,
            n,
            OutputMode::Quant { shift: 6 },
            MapVariant::PeLoad,
        )
        .unwrap();
        let run_p = run_gemm(&mut sim_p, &a, &b, &plan_p).unwrap();

        assert_eq!(run_m.c_i8.unwrap(), run_p.c_i8.unwrap(), "both variants exact");
        assert!(
            run_p.outcome.cycles > run_m.outcome.cycles,
            "PE-issued loads must be slower: {} vs {}",
            run_p.outcome.cycles,
            run_m.outcome.cycles
        );
        let u_m = sim_m.stats.pe_utilization(16);
        let u_p = sim_p.stats.pe_utilization(16);
        assert!(u_m > u_p, "MOB decoupling must raise utilization: {u_m} vs {u_p}");
    }

    #[test]
    fn blocked_beats_naive_ext_traffic() {
        // TAB2's premise: DMA-staged panels cross the external boundary
        // once; naive direct-Ext streaming re-reads per tile.
        let mut rng = XorShiftRng::new(0xF16_A);
        let (m, k, n) = (64, 32, 64);
        let a = random_mat(&mut rng, m, k, 9);
        let b = random_mat(&mut rng, k, n, 9);

        let mut sim_b = CgraSim::new(ArchConfig::default());
        let plan_b = GemmPlan::new(&sim_b.cfg, m, k, n, OutputMode::Quant { shift: 6 }).unwrap();
        run_gemm(&mut sim_b, &a, &b, &plan_b).unwrap();

        let mut sim_n = CgraSim::new(ArchConfig::default());
        let plan_n = GemmPlan::new_with_strategy(
            &sim_n.cfg,
            m,
            k,
            n,
            OutputMode::Quant { shift: 6 },
            Strategy::NaiveExt,
        )
        .unwrap();
        let run_n = run_gemm(&mut sim_n, &a, &b, &plan_n).unwrap();
        assert_eq!(run_n.c_i8.unwrap(), oracle_quant(&a, &b, 6), "naive still exact");

        assert!(
            sim_n.stats.ext_reads > 2 * sim_b.stats.ext_reads,
            "naive must re-read operands: {} vs {}",
            sim_n.stats.ext_reads,
            sim_b.stats.ext_reads
        );
    }

    #[test]
    fn dual_feed_utilization_near_peak() {
        // The dual-feed schedule's dependency chains are all satisfiable
        // with equality (mapper docs), so steady state sustains ≈1 MAC
        // per PE per cycle for long-K GEMMs.
        let mut rng = XorShiftRng::new(0xF16_B);
        let mut sim = CgraSim::new(ArchConfig::default());
        let (m, k, n) = (16, 256, 16);
        let a = random_mat(&mut rng, m, k, 5);
        let b = random_mat(&mut rng, k, n, 5);
        let plan = GemmPlan::new(&sim.cfg, m, k, n, OutputMode::Quant { shift: 7 }).unwrap();
        assert_eq!(plan.feed, FeedKind::Dual);
        run_gemm(&mut sim, &a, &b, &plan).unwrap();
        let u = sim.stats.pe_utilization(16);
        // 0.42 (single feed) → 0.57 here; the residual gap is the
        // DMA-staging window serialized behind the preamble barrier
        // (ext_bw-bound), not schedule bubbles — with ext_bw=32 the same
        // workload reaches 0.75+. EXPERIMENTS.md §Perf tracks the
        // staging-overlap optimization.
        assert!(u > 0.55, "dual-feed utilization regressed: {u}");
    }

    #[test]
    fn context_fits_4kib_for_large_gemm() {
        // §III-A: the context is independent of matrix size and must fit
        // the paper's 4 KiB budget even for a 256³ GEMM.
        let cfg = ArchConfig::default();
        let plan = GemmPlan::new(&cfg, 256, 256, 256, OutputMode::Quant { shift: 8 }).unwrap();
        let (ctx, _) = build_context(&plan).unwrap();
        let bytes = ctx.encoded_size();
        assert!(bytes <= 4096, "context {bytes} B exceeds 4 KiB");
    }

    #[test]
    fn prop_gemm_random_shapes_exact() {
        use crate::util::prop::{ensure, prop_check, PropConfig};
        prop_check(
            "blocked GEMM exact over random shapes",
            PropConfig { cases: 8, base_seed: 0x6E77 },
            |rng| {
                let m = rng.range(1, 40);
                let k = rng.range(1, 48);
                let n = rng.range(1, 40);
                let mut a = MatI8::zeros(m, k);
                let mut b = MatI8::zeros(k, n);
                rng.fill_i8(&mut a.data, 25);
                rng.fill_i8(&mut b.data, 25);
                let mut sim = CgraSim::new(ArchConfig::default());
                let plan =
                    GemmPlan::new(&sim.cfg, m, k, n, OutputMode::Quant { shift: 6 }).unwrap();
                let run = run_gemm(&mut sim, &a, &b, &plan).unwrap();
                ensure(run.c_i8.unwrap() == oracle_quant(&a, &b, 6), || {
                    format!("mismatch at m={m} k={k} n={n}")
                })
            },
        );
    }
}
