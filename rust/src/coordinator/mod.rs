//! Inference coordinator: the serving layer for §IV-B2's edge scenario.
//!
//! A worker thread owns the CGRA (one accelerator per edge device) and
//! drains a request queue in batches; clients submit token inputs and
//! receive encoder outputs. Timing is accounted in *simulated cycles*
//! (queueing by arrival stamps, service by measured kernel cycles), so
//! latency/throughput numbers are deterministic and frequency-scalable —
//! wall-clock simulation speed is reported separately.
//!
//! The build environment vendors no tokio; the runtime is `std::thread`
//! + `mpsc`, which an edge deployment would arguably prefer anyway.

use crate::config::ArchConfig;
use crate::sim::{CgraSim, Stats};
use crate::util::mat::MatF32;
use crate::xformer::{run_encoder_on_cgra, EncoderModel};
use anyhow::Result;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// A single inference request.
pub struct Request {
    /// Client-assigned id.
    pub id: u64,
    /// Input activations (seq × d_model).
    pub input: MatF32,
    /// Arrival time in simulated cycles (from the workload generator's
    /// arrival process).
    pub arrival_cycle: u64,
}

/// A completed inference.
pub struct Response {
    pub id: u64,
    pub output: MatF32,
    /// Cycles the request waited before service began.
    pub queue_cycles: u64,
    /// Cycles of array execution + configuration for this request.
    pub service_cycles: u64,
    /// Simulated completion time.
    pub completion_cycle: u64,
}

/// Aggregated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub completed: u64,
    pub total_queue_cycles: u64,
    pub total_service_cycles: u64,
    /// Latest completion time (simulated makespan).
    pub makespan_cycles: u64,
    /// Cumulative simulator stats over all served requests.
    pub stats: Stats,
}

impl ServeMetrics {
    /// Mean end-to-end latency in cycles.
    pub fn mean_latency_cycles(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        (self.total_queue_cycles + self.total_service_cycles) as f64 / self.completed as f64
    }

    /// Throughput in requests per second at `freq_mhz`.
    pub fn throughput_rps(&self, freq_mhz: f64) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan_cycles as f64 / (freq_mhz * 1e6))
    }
}

/// The coordinator: owns the worker thread and the request channel.
pub struct Coordinator {
    tx: Option<mpsc::Sender<Request>>,
    rx_out: mpsc::Receiver<Response>,
    worker: Option<JoinHandle<Result<ServeMetrics>>>,
}

impl Coordinator {
    /// Spawn a worker owning a fresh simulator and model.
    pub fn spawn(cfg: ArchConfig, model: EncoderModel, batch: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Request>();
        let (tx_out, rx_out) = mpsc::channel::<Response>();
        let worker = std::thread::spawn(move || -> Result<ServeMetrics> {
            let mut sim = CgraSim::new(cfg);
            let mut metrics = ServeMetrics::default();
            // The accelerator's own clock: a request can't start before
            // it arrives nor before the previous one finishes.
            let mut now: u64 = 0;
            let mut pending: Vec<Request> = Vec::new();
            loop {
                if pending.is_empty() {
                    match rx.recv() {
                        Ok(r) => pending.push(r),
                        Err(_) => break, // all clients gone
                    }
                }
                // Opportunistically drain up to `batch` (dynamic batching).
                while pending.len() < batch {
                    match rx.try_recv() {
                        Ok(r) => pending.push(r),
                        Err(_) => break,
                    }
                }
                for req in pending.drain(..) {
                    let start = now.max(req.arrival_cycle);
                    let queue_cycles = start - req.arrival_cycle;
                    sim.reset_stats();
                    let (output, report) = run_encoder_on_cgra(&mut sim, &model, &req.input)?;
                    let service = report.cycles + report.config_cycles;
                    now = start + service;
                    metrics.completed += 1;
                    metrics.total_queue_cycles += queue_cycles;
                    metrics.total_service_cycles += service;
                    metrics.makespan_cycles = metrics.makespan_cycles.max(now);
                    metrics.stats.merge(&sim.stats);
                    let _ = tx_out.send(Response {
                        id: req.id,
                        output,
                        queue_cycles,
                        service_cycles: service,
                        completion_cycle: now,
                    });
                }
            }
            Ok(metrics)
        });
        Self { tx: Some(tx), rx_out, worker: Some(worker) }
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(req)
            .map_err(|_| anyhow::anyhow!("worker terminated"))
    }

    /// Receive the next completed response (blocking).
    pub fn recv(&self) -> Result<Response> {
        self.rx_out.recv().map_err(|_| anyhow::anyhow!("worker terminated"))
    }

    /// Close the queue and join the worker, returning final metrics.
    pub fn shutdown(mut self) -> Result<ServeMetrics> {
        drop(self.tx.take());
        let worker = self.worker.take().expect("already joined");
        worker.join().map_err(|_| anyhow::anyhow!("worker panicked"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;
    use crate::xformer::XformerConfig;

    fn tiny_model() -> EncoderModel {
        EncoderModel::new(
            XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 },
            42,
        )
    }

    fn input(seed: u64) -> MatF32 {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(16, 32);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        x
    }

    #[test]
    fn serves_requests_in_order_with_metrics() {
        let coord = Coordinator::spawn(ArchConfig::default(), tiny_model(), 4);
        for id in 0..6 {
            coord
                .submit(Request { id, input: input(id), arrival_cycle: id * 100 })
                .unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..6 {
            let resp = coord.recv().unwrap();
            assert!(resp.service_cycles > 0);
            assert!(resp.output.data.iter().all(|v| v.is_finite()));
            seen.push(resp.id);
        }
        let metrics = coord.shutdown().unwrap();
        assert_eq!(metrics.completed, 6);
        assert!(metrics.mean_latency_cycles() > 0.0);
        assert!(metrics.throughput_rps(100.0) > 0.0);
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5], "FIFO service order");
    }

    #[test]
    fn queueing_accumulates_under_burst() {
        // All requests arrive at cycle 0: later ones must queue.
        let coord = Coordinator::spawn(ArchConfig::default(), tiny_model(), 8);
        for id in 0..4 {
            coord.submit(Request { id, input: input(id), arrival_cycle: 0 }).unwrap();
        }
        let mut queue_cycles = Vec::new();
        for _ in 0..4 {
            queue_cycles.push(coord.recv().unwrap().queue_cycles);
        }
        let metrics = coord.shutdown().unwrap();
        assert_eq!(metrics.completed, 4);
        assert_eq!(queue_cycles[0], 0, "first request starts immediately");
        assert!(queue_cycles[3] > queue_cycles[1], "burst builds queueing delay");
    }

    #[test]
    fn identical_inputs_identical_outputs() {
        let coord = Coordinator::spawn(ArchConfig::default(), tiny_model(), 2);
        coord.submit(Request { id: 0, input: input(7), arrival_cycle: 0 }).unwrap();
        coord.submit(Request { id: 1, input: input(7), arrival_cycle: 0 }).unwrap();
        let a = coord.recv().unwrap();
        let b = coord.recv().unwrap();
        coord.shutdown().unwrap();
        assert_eq!(a.output.max_abs_diff(&b.output), 0.0);
        assert_eq!(a.service_cycles, b.service_cycles, "deterministic service time");
    }
}
