//! Inference coordinator: the serving layer for §IV-B2's edge scenario.
//!
//! A worker thread owns the CGRA (one accelerator per edge device) and
//! drains a request queue in batches; clients submit token inputs and
//! receive encoder outputs. Timing is accounted in *simulated cycles*
//! (queueing by arrival stamps, service by measured kernel cycles), so
//! latency/throughput numbers are deterministic and frequency-scalable —
//! wall-clock simulation speed is reported separately.
//!
//! The worker loop is a thin adapter over the fleet layer's
//! single-device engine ([`crate::cluster::DeviceEngine`]): the
//! coordinator owns the channel plumbing, the engine owns every timing
//! rule, so one-device serving and [`crate::cluster::FleetSim`] serving
//! can never drift apart. A standalone engine serves on its own device
//! clock (`ref_mhz == freq_mhz`, the identity conversion), so
//! coordinator cycle numbers read directly in device cycles; only
//! fleets with mixed device classes rebase onto a shared reference
//! clock.
//!
//! ## Batching semantics
//!
//! The worker drains pending requests and serves them in **true
//! stacked batches**: up to `batch` requests that have all arrived by
//! the group's start cycle run as one encoder job
//! ([`DeviceEngine::serve_encoder_batch`]), with every projection/FFN
//! GEMM executed as a single `(B·seq) × d_model` kernel — weights
//! streamed and the context configured once for the whole group. Batch
//! membership is decided from simulated arrival stamps (a request only
//! joins a group it had arrived for), and the static per-model
//! calibration makes every request's output bit-identical regardless
//! of which group served it. **Determinism contract with `batch > 1`:**
//! a live channel server cannot know whether another same-stamp request
//! is still in flight, so group *boundaries* — and therefore timing
//! attribution (service cycles, p50/p99) — can vary with channel-drain
//! races; outputs never do. With `batch = 1` the worker serves strictly
//! per request from stamps and metrics are reproducible, as before; for
//! strictly reproducible *batched* timing studies use
//! [`crate::cluster::FleetSim`], whose batch formation is a pure
//! function of the workload. Context reuse across *groups* keeps the
//! old rule: a group starting back-to-back after a same-model group
//! pays zero reconfiguration; after an idle gap the context memory is
//! assumed power-collapsed and the full cost returns.
//!
//! ## Observability
//!
//! `spawn_observed` threads the same [`ObsConfig`] the fleet sims take,
//! so coordinator runs get the full analysis stack for free: event
//! traces, windowed series, and — with `spans`/`audit` armed — the
//! per-request latency anatomy of [`crate::obs::anatomy`] and the blame
//! report of [`crate::obs::audit`]. The observer stays write-only from
//! the worker's perspective (recording never feeds back into timing),
//! so an observed coordinator run serves bit-identical outputs to an
//! unobserved one.
//!
//! The build environment vendors no tokio; the runtime is `std::thread`
//! + `mpsc`, which an edge deployment would arguably prefer anyway.

use crate::cluster::{DeviceEngine, GenRequest, LatencyHistogram};
use crate::config::{ArchConfig, DeviceClass};
use crate::decode::{DecodeMetrics, DecodeSchedule, DeviceDecoder, GenCompletion, KvConfig};
use crate::obs::{EventKind, ObsConfig, Observer};
use crate::sim::Stats;
use crate::util::mat::MatF32;
use crate::xformer::{DecoderModel, EncoderModel, EncoderQuant, XformerConfig};
use anyhow::Result;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Seed for the coordinator's static quantization calibration (the
/// fleet derives per-model seeds instead; any fixed seed works — it
/// only has to be the same for every run of the same model).
pub const COORD_CALIB_SEED: u64 = 0xCA11_B247;

/// A single inference request.
pub struct Request {
    /// Client-assigned id.
    pub id: u64,
    /// Input activations (seq × d_model).
    pub input: MatF32,
    /// Arrival time in simulated cycles (from the workload generator's
    /// arrival process).
    pub arrival_cycle: u64,
}

/// A completed inference.
pub struct Response {
    pub id: u64,
    pub output: MatF32,
    /// Cycles the request waited before service began.
    pub queue_cycles: u64,
    /// Cycles of array execution + configuration charged to the
    /// *group* that served this request — shared by every member of a
    /// stacked batch, so summing it across responses over-counts device
    /// busy time by the occupancy factor (configuration is discounted
    /// under context reuse — see the module docs on batching).
    pub service_cycles: u64,
    /// Simulated completion time.
    pub completion_cycle: u64,
}

/// Aggregated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub completed: u64,
    /// Latest completion time (simulated makespan).
    pub makespan_cycles: u64,
    /// End-to-end latency samples (queue + service) — the same
    /// histogram type the fleet metrics use, so percentile definitions
    /// agree at every scale. Per-request queue/service breakdowns
    /// travel on each [`Response`].
    pub latency: LatencyHistogram,
    /// Cumulative simulator stats over all served requests.
    pub stats: Stats,
}

impl ServeMetrics {
    /// Record one completed request.
    pub fn record(&mut self, queue_cycles: u64, service_cycles: u64, completion_cycle: u64) {
        self.completed += 1;
        self.makespan_cycles = self.makespan_cycles.max(completion_cycle);
        self.latency.record(queue_cycles + service_cycles);
    }

    /// Median end-to-end latency in cycles.
    pub fn p50_latency_cycles(&self) -> u64 {
        self.latency.p50()
    }

    /// Tail (99th-percentile) end-to-end latency in cycles.
    pub fn p99_latency_cycles(&self) -> u64 {
        self.latency.p99()
    }

    /// Mean end-to-end latency in cycles.
    #[deprecated(
        note = "mean-only reporting hides the tail; use `latency` \
                percentiles (p50_latency_cycles / p99_latency_cycles)"
    )]
    pub fn mean_latency_cycles(&self) -> f64 {
        self.latency.mean()
    }

    /// Throughput in requests per second at `freq_mhz`.
    pub fn throughput_rps(&self, freq_mhz: f64) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan_cycles as f64 / (freq_mhz * 1e6))
    }
}

/// The coordinator: owns the worker thread and the request channel.
pub struct Coordinator {
    tx: Option<mpsc::Sender<Request>>,
    rx_out: mpsc::Receiver<Response>,
    worker: Option<JoinHandle<Result<(ServeMetrics, Observer)>>>,
}

impl Coordinator {
    /// Spawn a worker owning a fresh simulator and model.
    pub fn spawn(cfg: ArchConfig, model: EncoderModel, batch: usize) -> Self {
        Self::spawn_observed(cfg, model, batch, ObsConfig::default())
    }

    /// [`Self::spawn`] with observation armed: the worker records
    /// arrival/serve/complete events and phase-tagged kernel rows.
    /// Observation is strictly one-way (nothing in the serving loop
    /// reads it back), but note the module-level caveat: with
    /// `batch > 1` group boundaries — and therefore event timing —
    /// can vary with channel-drain races; outputs never do.
    pub fn spawn_observed(
        cfg: ArchConfig,
        model: EncoderModel,
        batch: usize,
        obs_cfg: ObsConfig,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Request>();
        let (tx_out, rx_out) = mpsc::channel::<Response>();
        let worker = std::thread::spawn(move || -> Result<(ServeMetrics, Observer)> {
            // The single-device engine owns the serving clock and every
            // timing rule; this loop only moves requests between
            // channels and the engine.
            let mut engine = DeviceEngine::new(cfg);
            let quant = EncoderQuant::calibrate_seeded(&model, COORD_CALIB_SEED);
            let mut metrics = ServeMetrics::default();
            let mut obs = Observer::new(&obs_cfg, vec!["dev0".to_string()]);
            let mut pending: Vec<Request> = Vec::new();
            loop {
                if pending.is_empty() {
                    match rx.recv() {
                        Ok(r) => pending.push(r),
                        Err(_) => break, // all clients gone
                    }
                }
                // Opportunistically drain up to `batch` (dynamic batching).
                while pending.len() < batch {
                    match rx.try_recv() {
                        Ok(r) => pending.push(r),
                        Err(_) => break,
                    }
                }
                // Service order and group membership follow simulated
                // stamps, not drain order.
                pending.sort_by_key(|r| (r.arrival_cycle, r.id));
                while !pending.is_empty() {
                    // A group can't start before its first member
                    // arrives nor before the previous group finishes,
                    // and only stacks requests already arrived by then.
                    let start = engine.free_at.max(pending[0].arrival_cycle);
                    let mut take = 1;
                    while take < pending.len()
                        && take < batch.max(1)
                        && pending[take].arrival_cycle <= start
                    {
                        take += 1;
                    }
                    let group: Vec<Request> = pending.drain(..take).collect();
                    let inputs: Vec<&MatF32> = group.iter().map(|r| &r.input).collect();
                    let (outputs, service, _report) =
                        engine.serve_encoder_batch(0, &model, &quant, &inputs, start)?;
                    let completion = start + service;
                    if obs.enabled() {
                        let batch_n = inputs.len();
                        obs.record(
                            start,
                            0,
                            crate::obs::NO_SEQ,
                            EventKind::Serve { model: 0, batch: batch_n, dur: service },
                        );
                        if obs.kernels_on() {
                            obs.kernel(
                                format!("m0_b{batch_n}"),
                                "encoder",
                                engine.sim.stats.clone(),
                            );
                        }
                    }
                    for (req, output) in group.into_iter().zip(outputs) {
                        let queue_cycles = start - req.arrival_cycle;
                        metrics.record(queue_cycles, service, completion);
                        if obs.enabled() {
                            let arr = req.arrival_cycle;
                            let latency = completion - arr;
                            obs.record(arr, 0, req.id, EventKind::Arrival { model: 0 });
                            obs.record(completion, 0, req.id, EventKind::Complete { latency });
                        }
                        let _ = tx_out.send(Response {
                            id: req.id,
                            output,
                            queue_cycles,
                            service_cycles: service,
                            completion_cycle: completion,
                        });
                    }
                }
            }
            metrics.stats = engine.stats.clone();
            obs.finish(metrics.makespan_cycles);
            Ok((metrics, obs))
        });
        Self { tx: Some(tx), rx_out, worker: Some(worker) }
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(req)
            .map_err(|_| anyhow::anyhow!("worker terminated"))
    }

    /// Receive the next completed response (blocking).
    pub fn recv(&self) -> Result<Response> {
        self.rx_out.recv().map_err(|_| anyhow::anyhow!("worker terminated"))
    }

    /// Close the queue and join the worker, returning final metrics.
    /// Requests already submitted but not yet served are still drained
    /// and served before the worker exits (graceful shutdown).
    pub fn shutdown(self) -> Result<ServeMetrics> {
        Ok(self.shutdown_observed()?.0)
    }

    /// [`Self::shutdown`] that also hands back the worker's
    /// [`Observer`] (disabled — and empty — unless spawned with
    /// [`Self::spawn_observed`]).
    pub fn shutdown_observed(mut self) -> Result<(ServeMetrics, Observer)> {
        drop(self.tx.take());
        let worker = self.worker.take().expect("already joined");
        worker.join().map_err(|_| anyhow::anyhow!("worker panicked"))?
    }
}

/// The generation-serving coordinator: one worker thread owning a
/// [`DeviceDecoder`] (engine + paged KV + continuous-batching
/// lifecycle), fed [`GenRequest`]s over a channel and answering with
/// [`GenCompletion`]s as sequences finish.
///
/// Timing follows simulated arrival stamps, with the same live-channel
/// caveat as the encoder coordinator's batching: the worker can only
/// interleave requests it has already drained, so *which tick* an
/// arrival joins — and therefore timing attribution — can vary with
/// channel races; **outputs never do** (the decode paths are
/// bit-identical whichever batch a row rides in). For strictly
/// reproducible generation timing studies use
/// [`crate::decode::DecodeFleetSim`], whose scheduling is a pure
/// function of the workload.
pub struct DecodeCoordinator {
    tx: Option<mpsc::Sender<GenRequest>>,
    rx_out: mpsc::Receiver<GenCompletion>,
    worker: Option<JoinHandle<Result<(DecodeMetrics, Observer)>>>,
}

impl DecodeCoordinator {
    /// Spawn a worker serving generation on one device of `class`,
    /// with a fresh decoder model (deterministic from `model_seed`),
    /// at most `max_running` concurrently-decoding sequences, and the
    /// given prefill/decode interleaving (`DecodeSchedule::Chunked`
    /// bounds how long a big prompt can stall running sequences —
    /// outputs are bit-identical under every schedule).
    pub fn spawn(
        class: DeviceClass,
        model_cfg: XformerConfig,
        model_seed: u64,
        max_running: usize,
        schedule: DecodeSchedule,
    ) -> Self {
        let obs_cfg = ObsConfig::default();
        Self::spawn_observed(class, model_cfg, model_seed, max_running, schedule, obs_cfg)
    }

    /// [`Self::spawn`] with observation armed: every admission, chunk,
    /// tick, preemption and completion the device lifecycle takes
    /// lands in the worker's [`Observer`] (retrieve it with
    /// [`Self::shutdown_observed`]). One-way, same as the fleet.
    pub fn spawn_observed(
        class: DeviceClass,
        model_cfg: XformerConfig,
        model_seed: u64,
        max_running: usize,
        schedule: DecodeSchedule,
        obs_cfg: ObsConfig,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<GenRequest>();
        let (tx_out, rx_out) = mpsc::channel::<GenCompletion>();
        let worker = std::thread::spawn(move || -> Result<(DecodeMetrics, Observer)> {
            let model = DecoderModel::new(model_cfg, model_seed);
            let quant = EncoderQuant::calibrate_causal_seeded(&model, COORD_CALIB_SEED);
            let models = vec![model];
            let quants = vec![quant];
            let kv_cfg = KvConfig::for_class(&class);
            let ref_mhz = class.freq_mhz;
            let mut obs = Observer::new(&obs_cfg, vec![format!("dev0 {}", class.name)]);
            let mut dec = DeviceDecoder::new(&class, ref_mhz, kv_cfg, max_running, schedule);
            let mut metrics = DecodeMetrics::default();
            let mut completions: Vec<GenCompletion> = Vec::new();
            let mut future: Vec<GenRequest> = Vec::new();
            let mut now = 0u64;
            loop {
                if !dec.has_work() && future.is_empty() {
                    match rx.recv() {
                        Ok(r) => future.push(r),
                        Err(_) => break, // all clients gone, nothing pending
                    }
                }
                while let Ok(r) = rx.try_recv() {
                    future.push(r);
                }
                future.sort_by_key(|r| (r.arrival_cycle, r.id));
                // Serve everything currently known on the simulated
                // timeline (late-drained stamps clamp to `now`).
                loop {
                    while future.first().is_some_and(|r| r.arrival_cycle <= now) {
                        let r = future.remove(0);
                        let id = r.id;
                        if let Err(e) = dec.submit(r, &models[0].cfg) {
                            metrics.rejected += 1;
                            let reason = e.to_string();
                            if obs.enabled() {
                                let kind = EventKind::Reject { reason: reason.clone() };
                                obs.record(now, 0, id, kind);
                            }
                            metrics.rejections.push((id, reason));
                        } else if obs.enabled() {
                            obs.record(now, 0, id, EventKind::Arrival { model: 0 });
                        }
                    }
                    while dec.free_at() <= now && dec.has_work() {
                        let stepped = dec.step(
                            now,
                            &models,
                            &quants,
                            &mut metrics,
                            &mut completions,
                            &mut obs,
                            0,
                        )?;
                        if !stepped {
                            break;
                        }
                    }
                    for c in completions.drain(..) {
                        let _ = tx_out.send(c);
                    }
                    let mut next = future.first().map(|r| r.arrival_cycle);
                    if dec.has_work() && dec.free_at() > now {
                        let t = dec.free_at();
                        next = Some(next.map_or(t, |n| n.min(t)));
                    }
                    match next {
                        Some(t) => now = now.max(t),
                        None => break,
                    }
                }
            }
            metrics.makespan_cycles = metrics.makespan_cycles.max(now);
            obs.finish(metrics.makespan_cycles);
            Ok((metrics, obs))
        });
        Self { tx: Some(tx), rx_out, worker: Some(worker) }
    }

    /// Submit a generation request (non-blocking).
    pub fn submit(&self, req: GenRequest) -> Result<()> {
        self.tx
            .as_ref()
            .expect("decode coordinator already shut down")
            .send(req)
            .map_err(|_| anyhow::anyhow!("worker terminated"))
    }

    /// Receive the next finished sequence (blocking).
    pub fn recv(&self) -> Result<GenCompletion> {
        self.rx_out.recv().map_err(|_| anyhow::anyhow!("worker terminated"))
    }

    /// Close the queue, serve everything still pending, and return the
    /// final metrics plus any completions not yet received.
    pub fn shutdown(self) -> Result<(DecodeMetrics, Vec<GenCompletion>)> {
        let (metrics, done, _) = self.shutdown_observed()?;
        Ok((metrics, done))
    }

    /// [`Self::shutdown`] that also hands back the worker's
    /// [`Observer`] (disabled — and empty — unless spawned with
    /// [`Self::spawn_observed`]).
    pub fn shutdown_observed(mut self) -> Result<(DecodeMetrics, Vec<GenCompletion>, Observer)> {
        drop(self.tx.take());
        let worker = self.worker.take().expect("already joined");
        let (metrics, obs) = worker.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        let mut done = Vec::new();
        while let Ok(c) = self.rx_out.try_recv() {
            done.push(c);
        }
        Ok((metrics, done, obs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;
    use crate::xformer::XformerConfig;

    fn tiny_model() -> EncoderModel {
        EncoderModel::new(
            XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 },
            42,
        )
    }

    fn input(seed: u64) -> MatF32 {
        let mut rng = XorShiftRng::new(seed);
        let mut x = MatF32::zeros(16, 32);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        x
    }

    #[test]
    fn serves_requests_in_order_with_metrics() {
        let coord = Coordinator::spawn(ArchConfig::default(), tiny_model(), 4);
        for id in 0..6 {
            coord
                .submit(Request { id, input: input(id), arrival_cycle: id * 100 })
                .unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..6 {
            let resp = coord.recv().unwrap();
            assert!(resp.service_cycles > 0);
            assert!(resp.output.data.iter().all(|v| v.is_finite()));
            seen.push(resp.id);
        }
        let metrics = coord.shutdown().unwrap();
        assert_eq!(metrics.completed, 6);
        assert_eq!(metrics.latency.count(), 6);
        assert!(metrics.p50_latency_cycles() > 0);
        assert!(metrics.p99_latency_cycles() >= metrics.p50_latency_cycles());
        #[allow(deprecated)]
        {
            assert!(metrics.mean_latency_cycles() > 0.0, "deprecated mean still consistent");
        }
        assert!(metrics.throughput_rps(100.0) > 0.0);
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5], "FIFO service order");
    }

    #[test]
    fn queueing_accumulates_under_burst() {
        // All requests arrive at cycle 0 with batching off: later ones
        // must queue behind the serial service.
        let coord = Coordinator::spawn(ArchConfig::default(), tiny_model(), 1);
        for id in 0..4 {
            coord.submit(Request { id, input: input(id), arrival_cycle: 0 }).unwrap();
        }
        let mut queue_cycles = Vec::new();
        for _ in 0..4 {
            queue_cycles.push(coord.recv().unwrap().queue_cycles);
        }
        let metrics = coord.shutdown().unwrap();
        assert_eq!(metrics.completed, 4);
        assert_eq!(queue_cycles[0], 0, "first request starts immediately");
        assert!(queue_cycles[3] > queue_cycles[1], "burst builds queueing delay");
    }

    #[test]
    fn identical_inputs_identical_outputs() {
        // Batching off so the context-reuse discount is observable on
        // the second request.
        let coord = Coordinator::spawn(ArchConfig::default(), tiny_model(), 1);
        coord.submit(Request { id: 0, input: input(7), arrival_cycle: 0 }).unwrap();
        coord.submit(Request { id: 1, input: input(7), arrival_cycle: 0 }).unwrap();
        let a = coord.recv().unwrap();
        let b = coord.recv().unwrap();
        coord.shutdown().unwrap();
        assert_eq!(a.output.max_abs_diff(&b.output), 0.0);
        // The second request starts back-to-back with the same model
        // resident, so it is charged strictly less than the first
        // (context reuse skips reconfiguration).
        assert!(
            b.service_cycles < a.service_cycles,
            "back-to-back same-model request must reuse context: {} vs {}",
            b.service_cycles,
            a.service_cycles
        );
    }

    #[test]
    fn batch_config_reuse_is_deterministic_by_arrival_stamps() {
        // Serialized submit/recv pins each request to its own group:
        // a back-to-back follower is discounted by exactly the
        // configuration cost, and after a long idle gap the full cost
        // returns. Both effects depend only on simulated arrival
        // stamps, so the numbers are reproducible run-to-run.
        let coord = Coordinator::spawn(ArchConfig::default(), tiny_model(), 8);
        coord.submit(Request { id: 0, input: input(1), arrival_cycle: 0 }).unwrap();
        let a = coord.recv().unwrap();
        coord.submit(Request { id: 1, input: input(1), arrival_cycle: 0 }).unwrap();
        let b = coord.recv().unwrap();
        // Arrives long after the burst drains: pays full configuration.
        coord.submit(Request { id: 2, input: input(1), arrival_cycle: 1_000_000_000 }).unwrap();
        let c = coord.recv().unwrap();
        coord.shutdown().unwrap();
        assert!(b.service_cycles < a.service_cycles, "burst follower discounted");
        assert_eq!(c.service_cycles, a.service_cycles, "idle gap restores full config cost");
        assert_eq!(c.queue_cycles, 0, "late request never queued");
    }

    #[test]
    fn stacked_batch_outputs_match_solo_runs_bitwise() {
        // Whatever groups the worker happens to form, every response
        // must be bit-identical to serving that input alone — the
        // static calibration makes batching output-neutral, so this
        // assertion is immune to channel-drain races.
        use crate::sim::CgraSim;
        use crate::xformer::run_encoder_batch;
        let model = tiny_model();
        let quant = EncoderQuant::calibrate_seeded(&model, COORD_CALIB_SEED);
        let coord = Coordinator::spawn(ArchConfig::default(), model.clone(), 4);
        for id in 0..4 {
            coord.submit(Request { id, input: input(id), arrival_cycle: 0 }).unwrap();
        }
        let mut outputs: Vec<Option<MatF32>> = vec![None; 4];
        for _ in 0..4 {
            let r = coord.recv().unwrap();
            outputs[r.id as usize] = Some(r.output);
        }
        let metrics = coord.shutdown().unwrap();
        assert_eq!(metrics.completed, 4);
        for id in 0..4u64 {
            let mut sim = CgraSim::new(ArchConfig::default());
            let x = input(id);
            let (want, _) = run_encoder_batch(&mut sim, &model, &quant, &[&x]).unwrap();
            let got = outputs[id as usize].as_ref().expect("response received");
            assert_eq!(got.data, want[0].data, "request {id} diverged from its solo run");
        }
    }

    fn gen_prompt(rows: usize, seed: u64) -> MatF32 {
        let mut rng = XorShiftRng::new(1000 + seed);
        let mut x = MatF32::zeros(rows, 16);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        x
    }

    #[test]
    fn decode_coordinator_serves_generation_and_is_output_neutral() {
        let cfg = XformerConfig { n_layers: 1, seq: 8, d_model: 16, n_heads: 2, d_ff: 32 };
        let class = DeviceClass::paper();
        let req = |id: u64| GenRequest {
            id,
            model: 0,
            prompt: gen_prompt(2 + id as usize, id),
            max_new_tokens: 3,
            arrival_cycle: 0,
        };
        let coord =
            DecodeCoordinator::spawn(class.clone(), cfg, 42, 4, DecodeSchedule::PrefillFirst);
        for id in 0..3 {
            coord.submit(req(id)).unwrap();
        }
        let (metrics, mut done) = coord.shutdown().unwrap();
        assert_eq!(metrics.completed, 3, "shutdown must drain pending generations");
        assert_eq!(metrics.tokens, 9);
        assert_eq!(metrics.rejected, 0);
        assert!(metrics.prefill_jobs > 0 && metrics.decode_ticks > 0);
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3);
        for c in &done {
            assert_eq!(c.tokens.rows, 3);
            assert!(c.ttft_cycles > 0);
            assert!(c.tokens.data.iter().all(|v| v.is_finite()));
        }
        // Output neutrality: whatever ticks the worker formed, each
        // sequence must be bit-identical to serving it alone.
        for c in &done {
            let solo =
                DecodeCoordinator::spawn(class.clone(), cfg, 42, 1, DecodeSchedule::PrefillFirst);
            solo.submit(req(c.id)).unwrap();
            let first = solo.recv().unwrap();
            let (sm, _) = solo.shutdown().unwrap();
            assert_eq!(sm.completed, 1);
            assert_eq!(
                first.tokens.data, c.tokens.data,
                "sequence {} perturbed by continuous batching",
                c.id
            );
        }
    }

    #[test]
    fn decode_coordinator_chunked_schedule_is_output_neutral() {
        // The same request set under Chunked{2} must emit bit-identical
        // tokens to the PrefillFirst worker — chunking changes timing
        // attribution, never results.
        let cfg = XformerConfig { n_layers: 1, seq: 16, d_model: 16, n_heads: 2, d_ff: 32 };
        let class = DeviceClass::paper();
        let req = |id: u64| GenRequest {
            id,
            model: 0,
            prompt: gen_prompt(5 + id as usize, 40 + id),
            max_new_tokens: 3,
            arrival_cycle: 0,
        };
        let run = |schedule: DecodeSchedule| {
            let coord = DecodeCoordinator::spawn(class.clone(), cfg, 42, 4, schedule);
            for id in 0..3 {
                coord.submit(req(id)).unwrap();
            }
            let (m, mut done) = coord.shutdown().unwrap();
            assert_eq!(m.completed, 3);
            done.sort_by_key(|c| c.id);
            (m, done)
        };
        let (mc, dc) = run(DecodeSchedule::Chunked { chunk_tokens: 2 });
        let (_, dp) = run(DecodeSchedule::PrefillFirst);
        assert!(mc.prefill_chunks > 0, "5..7-row prompts at budget 2 must chunk");
        for (a, b) in dc.iter().zip(&dp) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens.data, b.tokens.data, "request {} perturbed by chunking", a.id);
        }
    }

    #[test]
    fn decode_coordinator_rejects_oversized_requests_with_reasons() {
        let cfg = XformerConfig { n_layers: 1, seq: 8, d_model: 16, n_heads: 2, d_ff: 32 };
        let coord = DecodeCoordinator::spawn(
            DeviceClass::paper(),
            cfg,
            42,
            2,
            DecodeSchedule::PrefillFirst,
        );
        // Worst case 6 + 4 − 1 = 9 > the 8-token context.
        coord
            .submit(GenRequest {
                id: 7,
                model: 0,
                prompt: gen_prompt(6, 7),
                max_new_tokens: 4,
                arrival_cycle: 0,
            })
            .unwrap();
        let (metrics, done) = coord.shutdown().unwrap();
        assert_eq!(metrics.completed, 0);
        assert_eq!(metrics.rejected, 1);
        assert_eq!(metrics.rejections[0].0, 7);
        assert!(done.is_empty());
    }

    #[test]
    fn shutdown_drains_requests_still_in_flight() {
        // Submit and immediately shut down without receiving: the
        // worker must serve everything already submitted before it
        // exits, and the final metrics must account all of it.
        let coord = Coordinator::spawn(ArchConfig::default(), tiny_model(), 4);
        for id in 0..5 {
            coord.submit(Request { id, input: input(id), arrival_cycle: id * 50 }).unwrap();
        }
        let metrics = coord.shutdown().unwrap();
        assert_eq!(metrics.completed, 5, "in-flight requests served during shutdown");
        assert_eq!(metrics.latency.count(), 5);
        assert!(metrics.makespan_cycles > 0);
        assert!(metrics.stats.kernels > 0, "device stats survive into final metrics");
    }
}
