//! `cgra-edge` CLI: drive the simulated CGRA from the command line.
//!
//! Subcommands:
//!   info                         — print the configuration summary
//!   gemm M K N [--cfg f] [--shift s] [--variant torus|switched|peload]
//!                                — run + verify one GEMM, print metrics
//!   encoder [--layers n] [--seq s] [--dmodel d] [--heads h] [--dff f]
//!                                — run a tiny encoder on the array
//!   serve [--requests n] [--rate rps] [--batch b] [--decode]
//!         [--chunk-tokens t] [--threads n] [--trace-out f]
//!         [--metrics-window w] [--metrics-out f] [--kernel-trace f]
//!                                — closed-loop serving demo
//!                                  (coordinator); --decode serves
//!                                  generation requests through the
//!                                  single-device decode coordinator
//!                                  (--chunk-tokens N for chunked
//!                                  prefill)
//!   cluster [--fleet SPEC | --devices d] [--requests n] [--rate rps]
//!           [--policy p] [--queue q] [--arrival a] [--seed s]
//!           [--batch b] [--batch-wait w] [--no-steal]
//!           [--workload encoder|decode]
//!           [--max-running r] [--page-words w]
//!           [--schedule prefill-first|decode-first|chunked]
//!           [--chunk-tokens t] [--migrate] [--pin-device d]
//!           [--disagg] [--prefix-block t] [--prefix-share p]
//!           [--threads n] [--trace-out f] [--stream-trace]
//!           [--metrics-window w] [--metrics-out f] [--kernel-trace f]
//!           [--spans] [--audit-out f]
//!                                — fleet-serving simulation (cluster);
//!                                  --fleet takes a class roster like
//!                                  `4x4@100:3,8x4@200:1` (mixed array
//!                                  geometries and clocks; --devices N
//!                                  is sugar for N homogeneous devices),
//!                                  --batch > 1 stacks same-model
//!                                  requests into true batch GEMM jobs,
//!                                  work-stealing is on unless
//!                                  --no-steal. --workload decode runs
//!                                  autoregressive generation instead:
//!                                  prefill + paged-KV decode with
//!                                  continuous batching (--max-running
//!                                  sequences per device, --page-words
//!                                  KV pages, --schedule interleaving;
//!                                  --chunk-tokens N selects chunked
//!                                  prefill with an N-row budget, and
//!                                  --migrate lets idle devices pull
//!                                  waiting/running sequences — KV
//!                                  pages move over the entry links;
//!                                  --disagg splits the fleet into
//!                                  prefill-only and decode roles with
//!                                  every prefilled sequence handed
//!                                  off over the same links,
//!                                  --prefix-block T arms the
//!                                  fleet-wide prefix cache on T-token
//!                                  blocks, and --prefix-share P draws
//!                                  a workload where a fraction P of
//!                                  prompts reuse a pooled prefix
//!                                  bitwise), reporting TTFT /
//!                                  inter-token
//!                                  latency / tokens-per-second / KV
//!                                  occupancy, preemptions and
//!                                  migrations. Observability (both
//!                                  workloads and serve): --trace-out
//!                                  writes a Chrome/Perfetto trace
//!                                  JSON, --metrics-window W folds the
//!                                  run into W-cycle windows (CSV to
//!                                  --metrics-out or stdout),
//!                                  --kernel-trace writes phase-tagged
//!                                  per-kernel stats; tracing on vs
//!                                  off is bit-identical, and
//!                                  --pin-device D forces placement
//!                                  onto one device (deterministic
//!                                  migration demos). --threads N runs
//!                                  the fleet event loop on N worker
//!                                  threads (both workloads) — output
//!                                  is bit-identical to --threads 1.
//!                                  Latency anatomy: --spans appends
//!                                  per-request causal span tracks to
//!                                  the trace JSON, --audit-out writes
//!                                  the fleet blame / SLA-miss report
//!                                  (JSON, or per-window CSV when the
//!                                  path ends in .csv), --stream-trace
//!                                  spills the trace to --trace-out
//!                                  while the run executes instead of
//!                                  holding it in memory (cluster
//!                                  only; bytes identical to the
//!                                  in-memory render). --batch-wait W
//!                                  lets a device hold a partial batch
//!                                  up to W ref cycles for a fuller
//!                                  one (encoder workload; the hold
//!                                  shows up as its own trace span,
//!                                  series column, and anatomy
//!                                  component)

use anyhow::{bail, Result};
use cgra_edge::baseline::Gpp;
use cgra_edge::cli::Args;
use cgra_edge::cluster::{
    ArrivalProcess, BatchPolicy, Discipline, FleetConfig, FleetSim, ModelClass, Placement,
    WorkloadGen,
};
use cgra_edge::config::{ArchConfig, DeviceClass};
use cgra_edge::coordinator::{Coordinator, DecodeCoordinator, Request};
use cgra_edge::decode::{DecodeFleetConfig, DecodeFleetSim, DecodeSchedule, KvConfig};
use cgra_edge::energy::EnergyModel;
use cgra_edge::gemm::{oracle_quant, run_gemm, GemmPlan, MapVariant, OutputMode};
use cgra_edge::obs::{AuditConfig, ObsConfig, Observer};
use cgra_edge::sim::CgraSim;
use cgra_edge::util::mat::{MatF32, MatI8};
use cgra_edge::util::rng::XorShiftRng;
use cgra_edge::xformer::{run_encoder_on_cgra, EncoderModel, XformerConfig};

fn load_cfg(args: &Args) -> Result<ArchConfig> {
    match args.flag("cfg") {
        Some(path) => ArchConfig::from_file(path),
        None => Ok(ArchConfig::default()),
    }
}

/// Roster from `--fleet SPEC` or `--devices N` of the `--cfg` arch.
fn parse_roster(args: &Args, arch: &ArchConfig) -> Result<Vec<DeviceClass>> {
    let devices: usize = args.flag_parse("devices", 4usize)?;
    if devices == 0 {
        bail!("--devices must be at least 1");
    }
    match args.flag("fleet") {
        Some(spec) => DeviceClass::parse_roster(spec),
        None => Ok(vec![DeviceClass::from_arch(arch.clone()); devices]),
    }
}

/// One-line `3x4x4@100 + 1x8x4@200`-style roster summary.
fn roster_summary(roster: &[DeviceClass]) -> String {
    let mut counts: Vec<(String, usize)> = Vec::new();
    for c in roster {
        match counts.iter_mut().find(|(name, _)| *name == c.name) {
            Some((_, k)) => *k += 1,
            None => counts.push((c.name.clone(), 1)),
        }
    }
    counts.iter().map(|(name, k)| format!("{k}x{name}")).collect::<Vec<_>>().join(" + ")
}

/// Observer configuration from the observability flags: `--trace-out
/// FILE` arms event tracing, `--metrics-window N` arms the windowed
/// series (N ref cycles per window), `--kernel-trace FILE` arms the
/// per-kernel CSV, `--spans` arms per-request anatomy span tracks in
/// the trace JSON, `--audit-out FILE` arms the fleet blame report.
/// All off by default — and a run with them on is bit-identical to
/// the same run with them off.
fn parse_obs_cfg(args: &Args) -> Result<ObsConfig> {
    let window: u64 = args.flag_parse("metrics-window", 0u64)?;
    Ok(ObsConfig {
        trace: args.flag("trace-out").is_some(),
        window_cycles: (window > 0).then_some(window),
        kernels: args.flag("kernel-trace").is_some(),
        spans: args.switch("spans"),
        audit: args.flag("audit-out").is_some(),
    })
}

/// Audit window in ref cycles: `--metrics-window` when set, so audit
/// windows line up with the series rows, else 100k cycles (1 ms at
/// the 100 MHz paper clock).
fn audit_cfg(args: &Args, ref_mhz: u64, sla_ms_by_class: &[f64]) -> Result<AuditConfig> {
    let window: u64 = args.flag_parse("metrics-window", 0u64)?;
    let window = if window > 0 { window } else { 100_000 };
    let sla = sla_ms_by_class
        .iter()
        .map(|&ms| (ms > 0.0).then(|| (ms * ref_mhz as f64 * 1e3) as u64))
        .collect();
    Ok(AuditConfig::new(window, sla))
}

/// Write whatever the observer recorded: trace JSON to `--trace-out`
/// (already on disk when `--stream-trace` spilled it during the run),
/// series CSV to `--metrics-out` (stdout without it), kernel CSV to
/// `--kernel-trace`, the blame report to `--audit-out` (JSON, or the
/// per-window CSV table when the path ends in `.csv`). `ref_mhz` and
/// `sla_ms_by_class` size the audit's per-class SLA budgets.
fn write_obs_outputs(
    obs: &Observer,
    args: &Args,
    ref_mhz: u64,
    sla_ms_by_class: &[f64],
) -> Result<()> {
    if obs.is_streaming() {
        if let Some(path) = args.flag("trace-out") {
            if let Some(err) = obs.stream_error() {
                bail!("streaming trace to {path} failed: {err}");
            }
            let n = obs.event_count();
            println!("trace    : {n} events streamed -> {path} (chrome://tracing / Perfetto)");
        }
    } else if let (Some(path), Some(json)) = (args.flag("trace-out"), obs.trace_json()) {
        std::fs::write(path, json)?;
        let n = obs.event_count();
        println!("trace    : {n} events -> {path} (chrome://tracing / Perfetto)");
    }
    if let Some(csv) = obs.series_csv() {
        match args.flag("metrics-out") {
            Some(path) => {
                std::fs::write(path, csv)?;
                println!("metrics  : windowed series -> {path}");
            }
            None => print!("{csv}"),
        }
    }
    if let (Some(path), Some(csv)) = (args.flag("kernel-trace"), obs.kernel_csv()) {
        std::fs::write(path, csv)?;
        println!("kernels  : per-kernel rows -> {path}");
    }
    if let Some(path) = args.flag("audit-out") {
        let acfg = audit_cfg(args, ref_mhz, sla_ms_by_class)?;
        let rendered =
            if path.ends_with(".csv") { obs.audit_csv(&acfg) } else { obs.audit_json(&acfg) };
        if let Some(text) = rendered {
            std::fs::write(path, text)?;
            println!("audit    : latency blame report -> {path}");
        }
    }
    Ok(())
}

/// Arm the streaming trace writer when `--stream-trace` rides along
/// with `--trace-out` (cluster paths; the observer must be armed
/// before the run starts).
fn arm_stream_trace(obs: &mut Observer, args: &Args) -> Result<()> {
    if args.switch("stream-trace") {
        let Some(path) = args.flag("trace-out") else {
            bail!("--stream-trace needs --trace-out FILE");
        };
        let file = std::fs::File::create(path)?;
        obs.stream_trace_to(Box::new(std::io::BufWriter::new(file)));
    }
    Ok(())
}

/// `--threads N` (default 1): worker-thread count for the fleet event
/// loops. Any value is bit-identity-safe; 0 is rejected.
fn parse_threads(args: &Args) -> Result<usize> {
    let threads: usize = args.flag_parse("threads", 1usize)?;
    if threads == 0 {
        bail!("--threads must be at least 1");
    }
    Ok(threads)
}

/// `--arrival poisson|bursty|diurnal` at `--rate`.
fn parse_arrival(args: &Args, rate: f64) -> Result<ArrivalProcess> {
    Ok(match args.flag("arrival").unwrap_or("poisson") {
        "poisson" => ArrivalProcess::Poisson { rate_rps: rate },
        "bursty" => ArrivalProcess::BurstyOnOff {
            rate_on_rps: rate * 4.0,
            rate_off_rps: rate * 0.1,
            mean_on_s: 0.05,
            mean_off_s: 0.05,
        },
        "diurnal" => ArrivalProcess::DiurnalRamp {
            base_rps: rate * 0.2,
            peak_rps: rate * 2.0,
            period_s: 1.0,
        },
        other => bail!("unknown arrival process '{other}' (poisson|bursty|diurnal)"),
    })
}

fn cmd_gemm(args: &Args) -> Result<()> {
    let m: usize = args.pos(0)?.parse()?;
    let k: usize = args.pos(1)?.parse()?;
    let n: usize = args.pos(2)?.parse()?;
    let shift: u8 = args.flag_parse("shift", 6u8)?;
    let variant = match args.flag("variant").unwrap_or("torus") {
        "torus" => MapVariant::Torus,
        "switched" => MapVariant::Switched,
        "peload" => MapVariant::PeLoad,
        other => bail!("unknown variant {other}"),
    };
    let mut cfg = load_cfg(args)?;
    if variant == MapVariant::Switched {
        cfg.fabric = cgra_edge::interconnect::FabricKind::Switched;
    }
    let mut rng = XorShiftRng::new(args.flag_parse("seed", 1u64)?);
    let mut a = MatI8::zeros(m, k);
    let mut b = MatI8::zeros(k, n);
    rng.fill_i8(&mut a.data, 16);
    rng.fill_i8(&mut b.data, 16);
    let mut sim = CgraSim::new(cfg.clone());
    let plan = GemmPlan::for_variant(&sim.cfg, m, k, n, OutputMode::Quant { shift }, variant)?;
    let run = run_gemm(&mut sim, &a, &b, &plan)?;
    let exact = run.c_i8.as_ref().unwrap() == &oracle_quant(&a, &b, shift);
    let em = EnergyModel::default();
    let e = em.evaluate(&sim.stats, cfg.freq_mhz);
    println!("config  : {}", cfg.summary());
    println!("plan    : {:?} feed={:?} tiles={}", plan.strategy, plan.feed, plan.tiles());
    println!(
        "cycles  : {} (+{} config; ideal {})",
        run.outcome.cycles,
        run.outcome.config_cycles,
        plan.ideal_cycles()
    );
    println!("exact   : {exact}");
    println!("util    : {:.3}", sim.stats.pe_utilization(16));
    println!(
        "energy  : {:.2} µJ  avg power {:.3} mW  {:.1} GOPS/W",
        e.total_uj(),
        em.avg_power_mw(&sim.stats, cfg.freq_mhz),
        em.gops_per_watt(&sim.stats, cfg.freq_mhz)
    );
    let gpp = Gpp::default();
    let gc = gpp.gemm_cost(m, k, n);
    println!(
        "vs GPP  : {:.1}× cycles, {:.1}× energy",
        gc.cycles as f64 / (run.outcome.cycles + run.outcome.config_cycles) as f64,
        gc.energy_pj / e.total_pj()
    );
    if !exact {
        bail!("output mismatch vs oracle");
    }
    Ok(())
}

fn cmd_encoder(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let xcfg = XformerConfig {
        n_layers: args.flag_parse("layers", 2usize)?,
        seq: args.flag_parse("seq", 32usize)?,
        d_model: args.flag_parse("dmodel", 64usize)?,
        n_heads: args.flag_parse("heads", 4usize)?,
        d_ff: args.flag_parse("dff", 128usize)?,
    };
    let model = EncoderModel::new(xcfg, args.flag_parse("seed", 42u64)?);
    let mut rng = XorShiftRng::new(7);
    let mut x = MatF32::zeros(xcfg.seq, xcfg.d_model);
    for v in &mut x.data {
        *v = rng.normal() * 0.5;
    }
    let want = model.forward_f32(&x)?;
    let mut sim = CgraSim::new(cfg.clone());
    let (got, rep) = run_encoder_on_cgra(&mut sim, &model, &x)?;
    let em = EnergyModel::default();
    let e = em.evaluate(&sim.stats, cfg.freq_mhz);
    println!("model    : {xcfg:?} ({} params)", xcfg.param_count());
    println!("kernels  : {} ({} GEMM MACs)", rep.kernels, xcfg.gemm_macs());
    println!(
        "cycles   : {} (+{} config) = {:.2} ms @ {} MHz",
        rep.cycles,
        rep.config_cycles,
        (rep.cycles + rep.config_cycles) as f64 / (cfg.freq_mhz * 1e3),
        cfg.freq_mhz
    );
    println!(
        "accuracy : max |Δ| vs float reference = {:.4} (out amax {:.3})",
        got.max_abs_diff(&want),
        want.abs_max()
    );
    println!(
        "energy   : {:.2} µJ, avg power {:.3} mW",
        e.total_uj(),
        em.avg_power_mw(&sim.stats, cfg.freq_mhz)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Accepted for CLI parity with `cluster`: serving drives a single
    // device, so extra workers have nothing to shard over.
    let threads = parse_threads(args)?;
    if threads > 1 {
        println!(
            "threads  : {threads} requested — serve drives one device; \
             the threaded backend is fleet-side (`cluster --threads`)"
        );
    }
    if args.switch("decode") {
        return cmd_serve_decode(args);
    }
    let cfg = load_cfg(args)?;
    let n: u64 = args.flag_parse("requests", 16u64)?;
    let rate: f64 = args.flag_parse("rate", 50.0f64)?; // requests/sec
    let batch: usize = args.flag_parse("batch", 4usize)?;
    let xcfg = XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
    let model = EncoderModel::new(xcfg, 42);
    let obs_cfg = parse_obs_cfg(args)?;
    let coord = Coordinator::spawn_observed(cfg.clone(), model, batch, obs_cfg);
    let mut rng = XorShiftRng::new(99);
    let mut t = 0.0f64;
    for id in 0..n {
        t += rng.exp(rate);
        let arrival_cycle = (t * cfg.freq_mhz * 1e6) as u64;
        let mut x = MatF32::zeros(xcfg.seq, xcfg.d_model);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        coord.submit(Request { id, input: x, arrival_cycle })?;
    }
    for _ in 0..n {
        let r = coord.recv()?;
        println!(
            "req {:>3}: queue {:>8} cy, service {:>8} cy, done @ {:>10}",
            r.id, r.queue_cycles, r.service_cycles, r.completion_cycle
        );
    }
    let (m, obs) = coord.shutdown_observed()?;
    println!(
        "served {} requests: latency p50 {} / p99 {} cycles ({:.2} / {:.2} ms), \
         throughput {:.1} req/s",
        m.completed,
        m.p50_latency_cycles(),
        m.p99_latency_cycles(),
        m.p50_latency_cycles() as f64 / (cfg.freq_mhz * 1e3),
        m.p99_latency_cycles() as f64 / (cfg.freq_mhz * 1e3),
        m.throughput_rps(cfg.freq_mhz)
    );
    write_obs_outputs(&obs, args, cfg.freq_mhz_u64(), &[0.0])?;
    Ok(())
}

/// `serve --decode`: single-device generation serving through the
/// decode coordinator (the cluster decode path's one-device sibling).
fn cmd_serve_decode(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let n: usize = args.flag_parse("requests", 8usize)?;
    let rate: f64 = args.flag_parse("rate", 50.0f64)?;
    let max_running: usize = args.flag_parse("max-running", 4usize)?;
    let chunk_tokens: usize = args.flag_parse("chunk-tokens", 0usize)?;
    let schedule = if chunk_tokens > 0 {
        DecodeSchedule::Chunked { chunk_tokens }
    } else {
        DecodeSchedule::PrefillFirst
    };
    let xcfg = XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
    let class = DeviceClass::from_arch(cfg.clone());
    let obs_cfg = parse_obs_cfg(args)?;
    let coord = DecodeCoordinator::spawn_observed(class, xcfg, 42, max_running, schedule, obs_cfg);
    // One generation-workload source for both serving entry points:
    // the same generator the `cluster --workload decode` path uses.
    let classes = vec![ModelClass {
        name: "serve-decode",
        cfg: xcfg,
        weight: 1.0,
        sla_ms: 0.0,
        priority: 0,
    }];
    let mut gen = WorkloadGen::new(
        ArrivalProcess::Poisson { rate_rps: rate },
        classes,
        cfg.freq_mhz,
        99,
    );
    for req in gen.generate_gen(n) {
        coord.submit(req)?;
    }
    let (m, mut done, obs) = coord.shutdown_observed()?;
    done.sort_by_key(|c| c.id);
    for c in &done {
        println!(
            "req {:>3}: {:>2} tokens, ttft {:>8} cy, done @ {:>10}{}",
            c.id,
            c.tokens.rows,
            c.ttft_cycles,
            c.finish_cycle,
            if c.preemptions > 0 { " (preempted+resumed)" } else { "" }
        );
    }
    println!(
        "served {} generations ({} tokens, {} rejected): ttft p50 {:.2} ms, \
         itl p50 {:.2} ms, {:.1} tok/s",
        m.completed,
        m.tokens,
        m.rejected,
        m.ttft.p50() as f64 / (cfg.freq_mhz * 1e3),
        m.itl.p50() as f64 / (cfg.freq_mhz * 1e3),
        m.tokens_per_sec(cfg.freq_mhz)
    );
    write_obs_outputs(&obs, args, cfg.freq_mhz_u64(), &[0.0])?;
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    match args.flag("workload").unwrap_or("encoder") {
        "encoder" => {}
        "decode" => return cmd_cluster_decode(args),
        other => bail!("unknown workload '{other}' (encoder|decode)"),
    }
    let arch = load_cfg(args)?;
    // --fleet takes a class roster (`4x4@100:3,8x4@200:1`); --devices N
    // stays as sugar for a homogeneous roster of the --cfg architecture.
    let roster = parse_roster(args, &arch)?;
    let steal = !args.switch("no-steal");
    let n: usize = args.flag_parse("requests", 64usize)?;
    let rate: f64 = args.flag_parse("rate", 400.0f64)?;
    let seed: u64 = args.flag_parse("seed", 1u64)?;
    let policy = match args.flag("policy").unwrap_or("least") {
        "rr" => Placement::RoundRobin,
        "least" => Placement::LeastLoaded,
        "sjf" => Placement::ShortestExpectedJob,
        "affinity" => Placement::ModelAffinity,
        other => bail!("unknown policy '{other}' (rr|least|sjf|affinity)"),
    };
    let discipline = match args.flag("queue").unwrap_or("fifo") {
        "fifo" => Discipline::Fifo,
        "prio" => Discipline::Priority,
        "edf" => Discipline::Edf,
        other => bail!("unknown queue discipline '{other}' (fifo|prio|edf)"),
    };
    let arrival = parse_arrival(args, rate)?;
    let max_batch: usize = args.flag_parse("batch", 1usize)?;
    if max_batch == 0 {
        bail!("--batch must be at least 1");
    }
    // `--batch-wait W`: park a partial batch up to W ref cycles for a
    // fuller one (0 = greedy, the default). The hold is visible as a
    // `hold` trace span, the series' hold_permille column, and the
    // anatomy's hold component.
    let batch_wait: u64 = args.flag_parse("batch-wait", 0u64)?;
    let threads = parse_threads(args)?;
    let classes = ModelClass::edge_mix();
    let ref_mhz = arch.freq_mhz_u64();
    let mut gen = WorkloadGen::new(arrival, classes.clone(), ref_mhz as f64, seed);
    let requests = gen.generate(n);
    let n_devices = roster.len();
    let roster_str = roster_summary(&roster);
    let mut fleet = FleetSim::new(
        FleetConfig {
            roster,
            policy,
            discipline,
            batch: BatchPolicy {
                max_batch,
                max_wait_cycles: batch_wait,
                latency_aware: false,
            },
            steal,
            ref_mhz,
            threads,
            ..Default::default()
        },
        &classes,
        42,
    );
    fleet.enable_obs(&parse_obs_cfg(args)?);
    arm_stream_trace(fleet.obs_mut(), args)?;
    let m = fleet.run(requests)?;
    let em = EnergyModel::default();
    let freq_ref = ref_mhz as f64;
    let e = m.fleet_energy(&em, freq_ref);
    let ms = |cy: u64| cy as f64 / (freq_ref * 1e3);
    println!(
        "fleet    : {roster_str} ({n_devices} devices, timeline @ {ref_mhz} MHz, \
         {threads} thread{})",
        if threads == 1 { "" } else { "s" }
    );
    println!(
        "policy   : {policy:?} / {discipline:?}, arrival {arrival:?}, stealing {}",
        if steal { "on" } else { "off" }
    );
    println!(
        "served   : {} completed, {} dropped, {} SLA misses",
        m.completed, m.dropped, m.sla_misses
    );
    println!(
        "latency  : p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms (queue p99 {:.3} ms)",
        ms(m.latency.p50()),
        ms(m.latency.p95()),
        ms(m.latency.p99()),
        ms(m.queue_wait.p99())
    );
    println!(
        "thruput  : {:.1} req/s over {:.2} ms makespan",
        m.throughput_rps(freq_ref),
        ms(m.makespan_cycles)
    );
    let utils: Vec<String> =
        (0..n_devices).map(|d| format!("{:.2}", m.utilization(d))).collect();
    println!("util     : mean {:.3} [{}]", m.mean_utilization(), utils.join(" "));
    if steal {
        println!(
            "stealing : {} steals moved {} requests",
            m.steals, m.stolen_requests
        );
    }
    if max_batch > 1 {
        println!(
            "batching : {} jobs, mean occupancy {:.2}, {} ext words saved by weight reuse",
            m.batches(),
            m.mean_batch_occupancy(),
            m.weight_reuse_words
        );
    }
    println!(
        "energy   : {:.2} µJ fleet total, {:.3} µJ/request",
        e.total_uj(),
        if m.completed > 0 { e.total_uj() / m.completed as f64 } else { 0.0 }
    );
    let sla_ms: Vec<f64> = classes.iter().map(|c| c.sla_ms).collect();
    write_obs_outputs(fleet.obs(), args, ref_mhz, &sla_ms)?;
    Ok(())
}

/// `cluster --workload decode`: generation serving on the fleet —
/// prefill + paged-KV decode with continuous batching.
fn cmd_cluster_decode(args: &Args) -> Result<()> {
    let arch = load_cfg(args)?;
    let roster = parse_roster(args, &arch)?;
    let n: usize = args.flag_parse("requests", 32usize)?;
    let rate: f64 = args.flag_parse("rate", 200.0f64)?;
    let seed: u64 = args.flag_parse("seed", 1u64)?;
    let max_running: usize = args.flag_parse("max-running", 8usize)?;
    if max_running == 0 {
        bail!("--max-running must be at least 1");
    }
    let page_words: usize = args.flag_parse("page-words", KvConfig::DEFAULT_PAGE_WORDS)?;
    let chunk_tokens: usize = args.flag_parse("chunk-tokens", 0usize)?;
    // `--chunk-tokens N` implies the chunked schedule; `--schedule
    // chunked` without a budget uses a 32-row default. An explicitly
    // non-chunked schedule plus a chunk budget is contradictory —
    // reject it rather than silently dropping the budget.
    let default_schedule = if chunk_tokens > 0 { "chunked" } else { "prefill-first" };
    let sched_flag = args.flag("schedule").unwrap_or(default_schedule);
    let schedule = match sched_flag {
        "prefill-first" | "decode-first" if chunk_tokens > 0 => bail!(
            "--chunk-tokens only applies with --schedule chunked (got --schedule {sched_flag})"
        ),
        "prefill-first" => DecodeSchedule::PrefillFirst,
        "decode-first" => DecodeSchedule::DecodeFirst,
        "chunked" => DecodeSchedule::Chunked {
            chunk_tokens: if chunk_tokens > 0 { chunk_tokens } else { 32 },
        },
        other => bail!("unknown schedule '{other}' (prefill-first|decode-first|chunked)"),
    };
    let migrate = args.switch("migrate");
    // `--pin-device D` forces every admissible request onto device D —
    // the deterministic way to crowd one device and watch `--migrate`
    // rescue it in the trace (the CI smoke run does exactly this).
    let pin_device = match args.flag("pin-device") {
        Some(s) => Some(s.parse::<usize>()?),
        None => None,
    };
    // `--disagg` splits the fleet by phase (prefill-only vs decode);
    // `--prefix-block T` arms the fleet-wide prefix cache on T-token
    // blocks; `--prefix-share P` draws the shared-prefix workload that
    // gives the cache something to hit.
    let disagg = args.switch("disagg");
    let prefix_block: usize = args.flag_parse("prefix-block", 0usize)?;
    let prefix_share: f64 = args.flag_parse("prefix-share", 0.0f64)?;
    if !(0.0..=1.0).contains(&prefix_share) {
        bail!("--prefix-share must be in [0, 1]");
    }
    let threads = parse_threads(args)?;
    let arrival = parse_arrival(args, rate)?;
    let classes = ModelClass::edge_mix();
    let ref_mhz = arch.freq_mhz_u64();
    let mut gen = WorkloadGen::new(arrival, classes.clone(), ref_mhz as f64, seed);
    let requests = if prefix_share > 0.0 {
        gen.generate_gen_shared(n, prefix_share, prefix_block.max(4), 4)
    } else {
        gen.generate_gen(n)
    };
    let n_devices = roster.len();
    let roster_str = roster_summary(&roster);
    let mut fleet = DecodeFleetSim::new(
        DecodeFleetConfig {
            roster,
            ref_mhz,
            max_running,
            page_words,
            kv_pages: None,
            schedule,
            migrate,
            pin_device,
            timing_only: false,
            threads,
            disagg,
            prefix_block_tokens: (prefix_block > 0).then_some(prefix_block),
        },
        &classes,
        42,
    );
    fleet.enable_obs(&parse_obs_cfg(args)?);
    arm_stream_trace(fleet.obs_mut(), args)?;
    let (m, _completions) = fleet.run(requests)?;
    let em = EnergyModel::default();
    let freq_ref = ref_mhz as f64;
    let e = m.fleet_energy(&em, freq_ref);
    let ms = |cy: u64| cy as f64 / (freq_ref * 1e3);
    println!(
        "fleet    : {roster_str} ({n_devices} devices, timeline @ {ref_mhz} MHz, \
         {threads} thread{})",
        if threads == 1 { "" } else { "s" }
    );
    println!(
        "workload : decode, {n} generation requests, arrival {arrival:?}, \
         {schedule:?}, max {max_running} running/device"
    );
    println!(
        "served   : {} completed, {} rejected, {} tokens",
        m.completed, m.rejected, m.tokens
    );
    for (id, reason) in m.rejections.iter().take(3) {
        println!("  reject : request {id}: {reason}");
    }
    println!(
        "ttft     : p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms",
        ms(m.ttft.p50()),
        ms(m.ttft.p95()),
        ms(m.ttft.p99())
    );
    println!(
        "itl      : p50 {:.3} ms  p99 {:.3} ms (inter-token)",
        ms(m.itl.p50()),
        ms(m.itl.p99())
    );
    println!(
        "thruput  : {:.1} tok/s over {:.2} ms makespan (e2e p99 {:.3} ms)",
        m.tokens_per_sec(freq_ref),
        ms(m.makespan_cycles),
        ms(m.e2e.p99())
    );
    println!(
        "batching : {} prefill jobs ({} partial chunks), {} decode ticks, mean occupancy {:.2}",
        m.prefill_jobs,
        m.prefill_chunks,
        m.decode_ticks,
        m.mean_decode_occupancy()
    );
    if migrate {
        println!(
            "migrate  : {} sequences moved, {} words over the entry links",
            m.migrations, m.migrated_words
        );
    }
    if disagg {
        println!(
            "disagg   : {} hand-offs, {} words over the entry links",
            m.handoffs, m.handoff_words
        );
    }
    if prefix_block > 0 {
        println!(
            "prefix   : {} hits, {} tokens served from cache, {} words copied, {} evictions",
            m.prefix_hits, m.prefix_hit_tokens, m.prefix_copied_words, m.prefix_evictions
        );
    }
    println!(
        "kv       : occupancy p50 {:.1}% max {:.1}%, {} fill words, {} read words, \
         {} preemptions",
        m.kv_occupancy_permille.p50() as f64 / 10.0,
        m.kv_occupancy_permille.max() as f64 / 10.0,
        m.kv_fill_words,
        m.kv_read_words,
        m.preemptions
    );
    println!(
        "energy   : {:.2} µJ fleet total, {:.3} µJ/token",
        e.total_uj(),
        if m.tokens > 0 { e.total_uj() / m.tokens as f64 } else { 0.0 }
    );
    let sla_ms: Vec<f64> = classes.iter().map(|c| c.sla_ms).collect();
    write_obs_outputs(fleet.obs(), args, ref_mhz, &sla_ms)?;
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_str() {
        "info" => {
            let cfg = load_cfg(&args)?;
            println!("{}", cfg.summary());
            Ok(())
        }
        "gemm" => cmd_gemm(&args),
        "encoder" => cmd_encoder(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "" => {
            eprintln!("usage: cgra-edge <info|gemm|encoder|serve|cluster> …");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'"),
    }
}
