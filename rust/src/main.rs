//! `cgra-edge` CLI: drive the simulated CGRA from the command line.
//!
//! Subcommands:
//!   info                         — print the configuration summary
//!   gemm M K N [--cfg f] [--shift s] [--variant torus|switched|peload]
//!                                — run + verify one GEMM, print metrics
//!   encoder [--layers n] [--seq s] [--dmodel d] [--heads h] [--dff f]
//!                                — run a tiny encoder on the array
//!   serve [--requests n] [--rate rps] [--batch b]
//!                                — closed-loop serving demo (coordinator)
//!   cluster [--fleet SPEC | --devices d] [--requests n] [--rate rps]
//!           [--policy p] [--queue q] [--arrival a] [--seed s]
//!           [--batch b] [--no-steal]
//!                                — fleet-serving simulation (cluster);
//!                                  --fleet takes a class roster like
//!                                  `4x4@100:3,8x4@200:1` (mixed array
//!                                  geometries and clocks; --devices N
//!                                  is sugar for N homogeneous devices),
//!                                  --batch > 1 stacks same-model
//!                                  requests into true batch GEMM jobs,
//!                                  work-stealing is on unless
//!                                  --no-steal

use anyhow::{bail, Result};
use cgra_edge::baseline::Gpp;
use cgra_edge::cli::Args;
use cgra_edge::cluster::{
    ArrivalProcess, BatchPolicy, Discipline, FleetConfig, FleetSim, ModelClass, Placement,
    WorkloadGen,
};
use cgra_edge::config::{ArchConfig, DeviceClass};
use cgra_edge::coordinator::{Coordinator, Request};
use cgra_edge::energy::EnergyModel;
use cgra_edge::gemm::{oracle_quant, run_gemm, GemmPlan, MapVariant, OutputMode};
use cgra_edge::sim::CgraSim;
use cgra_edge::util::mat::{MatF32, MatI8};
use cgra_edge::util::rng::XorShiftRng;
use cgra_edge::xformer::{run_encoder_on_cgra, EncoderModel, XformerConfig};

fn load_cfg(args: &Args) -> Result<ArchConfig> {
    match args.flag("cfg") {
        Some(path) => ArchConfig::from_file(path),
        None => Ok(ArchConfig::default()),
    }
}

fn cmd_gemm(args: &Args) -> Result<()> {
    let m: usize = args.pos(0)?.parse()?;
    let k: usize = args.pos(1)?.parse()?;
    let n: usize = args.pos(2)?.parse()?;
    let shift: u8 = args.flag_parse("shift", 6u8)?;
    let variant = match args.flag("variant").unwrap_or("torus") {
        "torus" => MapVariant::Torus,
        "switched" => MapVariant::Switched,
        "peload" => MapVariant::PeLoad,
        other => bail!("unknown variant {other}"),
    };
    let mut cfg = load_cfg(args)?;
    if variant == MapVariant::Switched {
        cfg.fabric = cgra_edge::interconnect::FabricKind::Switched;
    }
    let mut rng = XorShiftRng::new(args.flag_parse("seed", 1u64)?);
    let mut a = MatI8::zeros(m, k);
    let mut b = MatI8::zeros(k, n);
    rng.fill_i8(&mut a.data, 16);
    rng.fill_i8(&mut b.data, 16);
    let mut sim = CgraSim::new(cfg.clone());
    let plan = GemmPlan::for_variant(&sim.cfg, m, k, n, OutputMode::Quant { shift }, variant)?;
    let run = run_gemm(&mut sim, &a, &b, &plan)?;
    let exact = run.c_i8.as_ref().unwrap() == &oracle_quant(&a, &b, shift);
    let em = EnergyModel::default();
    let e = em.evaluate(&sim.stats, cfg.freq_mhz);
    println!("config  : {}", cfg.summary());
    println!("plan    : {:?} feed={:?} tiles={}", plan.strategy, plan.feed, plan.tiles());
    println!(
        "cycles  : {} (+{} config; ideal {})",
        run.outcome.cycles,
        run.outcome.config_cycles,
        plan.ideal_cycles()
    );
    println!("exact   : {exact}");
    println!("util    : {:.3}", sim.stats.pe_utilization(16));
    println!(
        "energy  : {:.2} µJ  avg power {:.3} mW  {:.1} GOPS/W",
        e.total_uj(),
        em.avg_power_mw(&sim.stats, cfg.freq_mhz),
        em.gops_per_watt(&sim.stats, cfg.freq_mhz)
    );
    let gpp = Gpp::default();
    let gc = gpp.gemm_cost(m, k, n);
    println!(
        "vs GPP  : {:.1}× cycles, {:.1}× energy",
        gc.cycles as f64 / (run.outcome.cycles + run.outcome.config_cycles) as f64,
        gc.energy_pj / e.total_pj()
    );
    if !exact {
        bail!("output mismatch vs oracle");
    }
    Ok(())
}

fn cmd_encoder(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let xcfg = XformerConfig {
        n_layers: args.flag_parse("layers", 2usize)?,
        seq: args.flag_parse("seq", 32usize)?,
        d_model: args.flag_parse("dmodel", 64usize)?,
        n_heads: args.flag_parse("heads", 4usize)?,
        d_ff: args.flag_parse("dff", 128usize)?,
    };
    let model = EncoderModel::new(xcfg, args.flag_parse("seed", 42u64)?);
    let mut rng = XorShiftRng::new(7);
    let mut x = MatF32::zeros(xcfg.seq, xcfg.d_model);
    for v in &mut x.data {
        *v = rng.normal() * 0.5;
    }
    let want = model.forward_f32(&x)?;
    let mut sim = CgraSim::new(cfg.clone());
    let (got, rep) = run_encoder_on_cgra(&mut sim, &model, &x)?;
    let em = EnergyModel::default();
    let e = em.evaluate(&sim.stats, cfg.freq_mhz);
    println!("model    : {xcfg:?} ({} params)", xcfg.param_count());
    println!("kernels  : {} ({} GEMM MACs)", rep.kernels, xcfg.gemm_macs());
    println!(
        "cycles   : {} (+{} config) = {:.2} ms @ {} MHz",
        rep.cycles,
        rep.config_cycles,
        (rep.cycles + rep.config_cycles) as f64 / (cfg.freq_mhz * 1e3),
        cfg.freq_mhz
    );
    println!(
        "accuracy : max |Δ| vs float reference = {:.4} (out amax {:.3})",
        got.max_abs_diff(&want),
        want.abs_max()
    );
    println!(
        "energy   : {:.2} µJ, avg power {:.3} mW",
        e.total_uj(),
        em.avg_power_mw(&sim.stats, cfg.freq_mhz)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let n: u64 = args.flag_parse("requests", 16u64)?;
    let rate: f64 = args.flag_parse("rate", 50.0f64)?; // requests/sec
    let batch: usize = args.flag_parse("batch", 4usize)?;
    let xcfg = XformerConfig { n_layers: 1, seq: 16, d_model: 32, n_heads: 2, d_ff: 64 };
    let model = EncoderModel::new(xcfg, 42);
    let coord = Coordinator::spawn(cfg.clone(), model, batch);
    let mut rng = XorShiftRng::new(99);
    let mut t = 0.0f64;
    for id in 0..n {
        t += rng.exp(rate);
        let arrival_cycle = (t * cfg.freq_mhz * 1e6) as u64;
        let mut x = MatF32::zeros(xcfg.seq, xcfg.d_model);
        for v in &mut x.data {
            *v = rng.normal() * 0.5;
        }
        coord.submit(Request { id, input: x, arrival_cycle })?;
    }
    for _ in 0..n {
        let r = coord.recv()?;
        println!(
            "req {:>3}: queue {:>8} cy, service {:>8} cy, done @ {:>10}",
            r.id, r.queue_cycles, r.service_cycles, r.completion_cycle
        );
    }
    let m = coord.shutdown()?;
    println!(
        "served {} requests: latency p50 {} / p99 {} cycles ({:.2} / {:.2} ms), \
         throughput {:.1} req/s",
        m.completed,
        m.p50_latency_cycles(),
        m.p99_latency_cycles(),
        m.p50_latency_cycles() as f64 / (cfg.freq_mhz * 1e3),
        m.p99_latency_cycles() as f64 / (cfg.freq_mhz * 1e3),
        m.throughput_rps(cfg.freq_mhz)
    );
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let arch = load_cfg(args)?;
    let devices: usize = args.flag_parse("devices", 4usize)?;
    if devices == 0 {
        bail!("--devices must be at least 1");
    }
    // --fleet takes a class roster (`4x4@100:3,8x4@200:1`); --devices N
    // stays as sugar for a homogeneous roster of the --cfg architecture.
    let roster: Vec<DeviceClass> = match args.flag("fleet") {
        Some(spec) => DeviceClass::parse_roster(spec)?,
        None => vec![DeviceClass::from_arch(arch.clone()); devices],
    };
    let steal = !args.switch("no-steal");
    let n: usize = args.flag_parse("requests", 64usize)?;
    let rate: f64 = args.flag_parse("rate", 400.0f64)?;
    let seed: u64 = args.flag_parse("seed", 1u64)?;
    let policy = match args.flag("policy").unwrap_or("least") {
        "rr" => Placement::RoundRobin,
        "least" => Placement::LeastLoaded,
        "sjf" => Placement::ShortestExpectedJob,
        "affinity" => Placement::ModelAffinity,
        other => bail!("unknown policy '{other}' (rr|least|sjf|affinity)"),
    };
    let discipline = match args.flag("queue").unwrap_or("fifo") {
        "fifo" => Discipline::Fifo,
        "prio" => Discipline::Priority,
        "edf" => Discipline::Edf,
        other => bail!("unknown queue discipline '{other}' (fifo|prio|edf)"),
    };
    let arrival = match args.flag("arrival").unwrap_or("poisson") {
        "poisson" => ArrivalProcess::Poisson { rate_rps: rate },
        "bursty" => ArrivalProcess::BurstyOnOff {
            rate_on_rps: rate * 4.0,
            rate_off_rps: rate * 0.1,
            mean_on_s: 0.05,
            mean_off_s: 0.05,
        },
        "diurnal" => ArrivalProcess::DiurnalRamp {
            base_rps: rate * 0.2,
            peak_rps: rate * 2.0,
            period_s: 1.0,
        },
        other => bail!("unknown arrival process '{other}' (poisson|bursty|diurnal)"),
    };
    let max_batch: usize = args.flag_parse("batch", 1usize)?;
    if max_batch == 0 {
        bail!("--batch must be at least 1");
    }
    let classes = ModelClass::edge_mix();
    let ref_mhz = arch.freq_mhz_u64();
    let mut gen = WorkloadGen::new(arrival, classes.clone(), ref_mhz as f64, seed);
    let requests = gen.generate(n);
    let n_devices = roster.len();
    // Group the roster by class name for the one-line fleet summary.
    let mut roster_counts: Vec<(String, usize)> = Vec::new();
    for c in &roster {
        match roster_counts.iter_mut().find(|(name, _)| *name == c.name) {
            Some((_, k)) => *k += 1,
            None => roster_counts.push((c.name.clone(), 1)),
        }
    }
    let roster_str = roster_counts
        .iter()
        .map(|(name, k)| format!("{k}x{name}"))
        .collect::<Vec<_>>()
        .join(" + ");
    let mut fleet = FleetSim::new(
        FleetConfig {
            roster,
            policy,
            discipline,
            batch: BatchPolicy::greedy(max_batch),
            steal,
            ref_mhz,
        },
        &classes,
        42,
    );
    let m = fleet.run(requests)?;
    let em = EnergyModel::default();
    let freq_ref = ref_mhz as f64;
    let e = m.fleet_energy(&em, freq_ref);
    let ms = |cy: u64| cy as f64 / (freq_ref * 1e3);
    println!("fleet    : {roster_str} ({n_devices} devices, timeline @ {ref_mhz} MHz)");
    println!(
        "policy   : {policy:?} / {discipline:?}, arrival {arrival:?}, stealing {}",
        if steal { "on" } else { "off" }
    );
    println!(
        "served   : {} completed, {} dropped, {} SLA misses",
        m.completed, m.dropped, m.sla_misses
    );
    println!(
        "latency  : p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms (queue p99 {:.3} ms)",
        ms(m.latency.p50()),
        ms(m.latency.p95()),
        ms(m.latency.p99()),
        ms(m.queue_wait.p99())
    );
    println!(
        "thruput  : {:.1} req/s over {:.2} ms makespan",
        m.throughput_rps(freq_ref),
        ms(m.makespan_cycles)
    );
    let utils: Vec<String> =
        (0..n_devices).map(|d| format!("{:.2}", m.utilization(d))).collect();
    println!("util     : mean {:.3} [{}]", m.mean_utilization(), utils.join(" "));
    if steal {
        println!(
            "stealing : {} steals moved {} requests",
            m.steals, m.stolen_requests
        );
    }
    if max_batch > 1 {
        println!(
            "batching : {} jobs, mean occupancy {:.2}, {} ext words saved by weight reuse",
            m.batches(),
            m.mean_batch_occupancy(),
            m.weight_reuse_words
        );
    }
    println!(
        "energy   : {:.2} µJ fleet total, {:.3} µJ/request",
        e.total_uj(),
        if m.completed > 0 { e.total_uj() / m.completed as f64 } else { 0.0 }
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_str() {
        "info" => {
            let cfg = load_cfg(&args)?;
            println!("{}", cfg.summary());
            Ok(())
        }
        "gemm" => cmd_gemm(&args),
        "encoder" => cmd_encoder(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "" => {
            eprintln!("usage: cgra-edge <info|gemm|encoder|serve|cluster> …");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'"),
    }
}
