//! Indexed wake-up calendar for the fleet event loops.
//!
//! Both fleet simulators ([`super::fleet::FleetSim`] and
//! [`crate::decode::DecodeFleetSim`]) used to find the next event by
//! re-scanning every device on every loop iteration — O(D) per event,
//! superlinear wall-time growth with roster size. [`WakeCalendar`] is
//! the replacement: a binary min-heap of `(reference cycle, device)`
//! wake-ups with **lazy invalidation**.
//!
//! ## Lazy invalidation
//!
//! The loops never delete entries in place. A device's wake-up is
//! pushed at every busy transition (`free_at` moves forward) and
//! whenever a condition that gates its next service appears (work
//! queued behind a busy device). When the loop asks for the earliest
//! event it passes a validity predicate; stale entries — superseded
//! `free_at` stamps, or devices whose queue has since drained — are
//! popped and discarded on the way to the first valid one. This is
//! sound because:
//!
//! - `free_at` is monotone non-decreasing, so a stale stamp is always
//!   *earlier* than the device's true wake-up and a fresh entry has
//!   already been pushed at the transition that superseded it;
//! - every condition that can make a discarded device relevant again
//!   (new work queued, a new busy transition) performs its own push at
//!   the state change.
//!
//! Each entry is pushed once and popped once, so the amortized cost per
//! event is O(log D) instead of O(D).
//!
//! ## Stale-fraction compaction
//!
//! Migration- and steal-heavy runs re-push the same device many times
//! between queries, so superseded entries can pile up faster than lazy
//! discard drains them and the heap grows past O(D). The calendar
//! therefore counts provably superseded entries — an entry is
//! *superseded* when a later stamp has since been pushed for the same
//! device, which (stamps being monotone per device) means its
//! `free_at == at` validity can never hold again — and rebuilds the
//! heap without them once they exceed half the entries (and the heap
//! is big enough for the rebuild to matter). Compaction drops only
//! entries the lazy discard was already guaranteed to throw away, so
//! query results are unchanged — it bounds the heap at 2× the live
//! entry count (plus the [`Self::COMPACT_MIN`] floor) without touching
//! scheduling.
//!
//! ## Determinism
//!
//! The calendar only ever answers "what is the minimum wake-up
//! *time*". Which devices act at that time — and in what order — is
//! decided by the loops themselves, which always process same-cycle
//! work in ascending device index (see the `ready` sets in both
//! `run` loops). Heap internals therefore never leak into scheduling
//! decisions, which is what keeps the calendar loops bit-identical to
//! the reference scan loops (`run_reference`), the conformance oracle
//! pinned by `tests/calendar_props.rs`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A binary min-heap of `(wake-up cycle, device)` entries with lazy
/// invalidation and stale-fraction compaction (see the module docs for
/// the soundness argument).
#[derive(Debug, Default)]
pub struct WakeCalendar {
    heap: BinaryHeap<Reverse<(u64, usize)>>,
    /// Latest stamp pushed per device (0 = never pushed; the loops only
    /// push future stamps, so 0 is never a real entry's stamp clash).
    latest: Vec<u64>,
    /// Entries in the heap carrying `latest[d]` for their device — the
    /// only entries whose validity predicate can still accept them.
    live_at_latest: Vec<u32>,
    /// Entries provably superseded by a later push for their device.
    stale: usize,
}

impl WakeCalendar {
    /// Below this heap length compaction is never attempted: rebuilding
    /// a tiny heap costs more than the stale entries it would drop.
    pub const COMPACT_MIN: usize = 64;

    pub fn new() -> Self {
        Self::default()
    }

    fn ensure_device(&mut self, device: usize) {
        if device >= self.latest.len() {
            self.latest.resize(device + 1, 0);
            self.live_at_latest.resize(device + 1, 0);
        }
    }

    /// Schedule a wake-up for `device` at cycle `at`. Duplicates are
    /// fine — stale ones are discarded at query time (or dropped in
    /// bulk by compaction once they dominate the heap).
    pub fn push(&mut self, at: u64, device: usize) {
        self.ensure_device(device);
        match at.cmp(&self.latest[device]) {
            std::cmp::Ordering::Greater => {
                self.stale += self.live_at_latest[device] as usize;
                self.latest[device] = at;
                self.live_at_latest[device] = 1;
            }
            std::cmp::Ordering::Equal => self.live_at_latest[device] += 1,
            // A push below the device's latest stamp arrives already
            // superseded (the loops never do this, but the accounting
            // must stay exact either way).
            std::cmp::Ordering::Less => self.stale += 1,
        }
        self.heap.push(Reverse((at, device)));
        if self.stale * 2 > self.heap.len() && self.heap.len() >= Self::COMPACT_MIN {
            self.compact();
        }
    }

    /// Account one entry leaving the heap (any pop path).
    fn note_removed(&mut self, at: u64, device: usize) {
        if at == self.latest[device] && self.live_at_latest[device] > 0 {
            self.live_at_latest[device] -= 1;
        } else {
            self.stale -= 1;
        }
    }

    /// Rebuild the heap without superseded entries. Pure dead-weight
    /// removal: every dropped entry fails `at == latest[device]`, which
    /// the monotone-stamp argument shows can never become valid again,
    /// so every query answers exactly as before.
    fn compact(&mut self) {
        let latest = &self.latest;
        let entries = std::mem::take(&mut self.heap).into_vec();
        self.heap = entries
            .into_iter()
            .filter(|&Reverse((at, d))| at == latest[d])
            .collect::<Vec<_>>()
            .into();
        self.stale = 0;
    }

    /// The earliest entry satisfying `valid`, discarding stale entries
    /// on the way. The returned entry stays in the heap (it is still
    /// the next wake-up); `None` when no valid entry remains.
    pub fn earliest_valid(
        &mut self,
        mut valid: impl FnMut(u64, usize) -> bool,
    ) -> Option<(u64, usize)> {
        while let Some(&Reverse((at, d))) = self.heap.peek() {
            if valid(at, d) {
                return Some((at, d));
            }
            self.heap.pop();
            self.note_removed(at, d);
        }
        None
    }

    /// Pop every entry with a stamp ≤ `t`, feeding each to `f` (valid
    /// and stale alike — the caller re-checks device state, which is
    /// cheaper than a predicate here and keeps the hot loop branchless).
    pub fn pop_until(&mut self, t: u64, mut f: impl FnMut(u64, usize)) {
        while let Some(&Reverse((at, d))) = self.heap.peek() {
            if at > t {
                break;
            }
            self.heap.pop();
            self.note_removed(at, d);
            f(at, d);
        }
    }

    /// Entries currently in the heap (valid + stale).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_valid_skips_stale_entries() {
        let mut cal = WakeCalendar::new();
        cal.push(10, 0);
        cal.push(5, 1); // stale: device 1's true wake-up is 20
        cal.push(20, 1);
        let fresh = |at: u64, d: usize| if d == 1 { at == 20 } else { true };
        assert_eq!(cal.earliest_valid(fresh), Some((10, 0)));
        // The stale (5, 1) entry was discarded, the rest stayed.
        assert_eq!(cal.len(), 2);
        assert_eq!(cal.earliest_valid(|_, _| true), Some((10, 0)));
    }

    #[test]
    fn pop_until_drains_in_stamp_order() {
        let mut cal = WakeCalendar::new();
        for (at, d) in [(30u64, 2usize), (10, 0), (20, 1), (10, 3)] {
            cal.push(at, d);
        }
        let mut seen = Vec::new();
        cal.pop_until(20, |at, d| seen.push((at, d)));
        assert_eq!(seen, vec![(10, 0), (10, 3), (20, 1)]);
        assert_eq!(cal.len(), 1);
        cal.pop_until(100, |at, d| seen.push((at, d)));
        assert_eq!(seen.last(), Some(&(30, 2)));
        assert!(cal.is_empty());
    }

    #[test]
    fn empty_calendar_answers_none() {
        let mut cal = WakeCalendar::new();
        assert_eq!(cal.earliest_valid(|_, _| true), None);
        cal.pop_until(u64::MAX, |_, _| panic!("nothing to pop"));
    }

    /// ISSUE 8 satellite: a migration/steal-heavy push pattern — the
    /// same few devices re-pushed with ever-later stamps — must not
    /// grow the heap without bound. With one live entry per device the
    /// heap stays under `max(2 × live + 1, COMPACT_MIN + 1)` at every
    /// step, instead of the 40 000 entries the uncompacted heap held.
    #[test]
    fn compaction_bounds_heap_length_under_repeated_supersession() {
        let devices = 4usize;
        let mut cal = WakeCalendar::new();
        let bound = (2 * devices + 1).max(WakeCalendar::COMPACT_MIN + 1);
        for round in 1..=10_000u64 {
            for d in 0..devices {
                cal.push(round * 10 + d as u64, d);
                assert!(
                    cal.len() <= bound,
                    "heap grew to {} entries (bound {bound}) at round {round}",
                    cal.len()
                );
            }
        }
        // Everything but the last round's stamps is superseded; the
        // final state is within one compaction of the live count.
        assert!(cal.len() <= bound);
    }

    /// Compaction is a pure dead-weight removal: the surviving pop
    /// order (`pop_until` to the horizon) is exactly the live entries
    /// in `(stamp, device)` order — identical to what the uncompacted
    /// heap delivers once lazy discard has skipped the stale stamps.
    #[test]
    fn compaction_preserves_pop_order_of_live_entries() {
        let devices = 8usize;
        let mut cal = WakeCalendar::new();
        let mut latest = vec![0u64; devices];
        // Deterministic churn: device d is superseded many times, with
        // interleaved stamp order across devices.
        for round in 1..=2_000u64 {
            let d = (round as usize * 5 + 3) % devices;
            let at = round * 7 + d as u64;
            latest[d] = at;
            cal.push(at, d);
        }
        // The live set is each device's latest stamp; stale entries are
        // filtered by the same free_at-style predicate the loops use.
        let mut expect: Vec<(u64, usize)> =
            (0..devices).map(|d| (latest[d], d)).collect();
        expect.sort_unstable();
        assert_eq!(
            cal.earliest_valid(|at, d| at == latest[d]),
            Some(expect[0]),
            "earliest live entry must survive compaction"
        );
        let mut seen = Vec::new();
        cal.pop_until(u64::MAX, |at, d| {
            if at == latest[d] {
                seen.push((at, d));
            }
        });
        assert_eq!(seen, expect, "live pop order changed under compaction");
        assert!(cal.is_empty());
    }

    /// A stamp pushed twice for one device is *live* twice (the loops
    /// push `free_at` from several code paths): compaction must keep
    /// the duplicates, and popping one must not mark the other stale.
    #[test]
    fn duplicate_latest_stamps_survive_compaction() {
        let mut cal = WakeCalendar::new();
        for _ in 0..WakeCalendar::COMPACT_MIN {
            cal.push(100, 0); // same stamp: all live, nothing to drop
        }
        assert_eq!(cal.len(), WakeCalendar::COMPACT_MIN);
        cal.push(200, 0); // supersedes all of them at once
        assert!(cal.len() <= WakeCalendar::COMPACT_MIN + 1, "supersession must compact");
        let mut seen = Vec::new();
        cal.pop_until(u64::MAX, |at, d| seen.push((at, d)));
        assert_eq!(seen.last(), Some(&(200, 0)));
    }
}
