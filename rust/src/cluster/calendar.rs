//! Indexed wake-up calendar for the fleet event loops.
//!
//! Both fleet simulators ([`super::fleet::FleetSim`] and
//! [`crate::decode::DecodeFleetSim`]) used to find the next event by
//! re-scanning every device on every loop iteration — O(D) per event,
//! superlinear wall-time growth with roster size. [`WakeCalendar`] is
//! the replacement: a binary min-heap of `(reference cycle, device)`
//! wake-ups with **lazy invalidation**.
//!
//! ## Lazy invalidation
//!
//! The loops never delete entries. A device's wake-up is pushed at
//! every busy transition (`free_at` moves forward) and whenever a
//! condition that gates its next service appears (work queued behind a
//! busy device). When the loop asks for the earliest event it passes a
//! validity predicate; stale entries — superseded `free_at` stamps, or
//! devices whose queue has since drained — are popped and discarded on
//! the way to the first valid one. This is sound because:
//!
//! - `free_at` is monotone non-decreasing, so a stale stamp is always
//!   *earlier* than the device's true wake-up and a fresh entry has
//!   already been pushed at the transition that superseded it;
//! - every condition that can make a discarded device relevant again
//!   (new work queued, a new busy transition) performs its own push at
//!   the state change.
//!
//! Each entry is pushed once and popped once, so the amortized cost per
//! event is O(log D) instead of O(D).
//!
//! ## Determinism
//!
//! The calendar only ever answers "what is the minimum wake-up
//! *time*". Which devices act at that time — and in what order — is
//! decided by the loops themselves, which always process same-cycle
//! work in ascending device index (see the `ready` sets in both
//! `run` loops). Heap internals therefore never leak into scheduling
//! decisions, which is what keeps the calendar loops bit-identical to
//! the reference scan loops (`run_reference`), the conformance oracle
//! pinned by `tests/calendar_props.rs`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A binary min-heap of `(wake-up cycle, device)` entries with lazy
/// invalidation (see the module docs for the soundness argument).
#[derive(Debug, Default)]
pub struct WakeCalendar {
    heap: BinaryHeap<Reverse<(u64, usize)>>,
}

impl WakeCalendar {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule a wake-up for `device` at cycle `at`. Duplicates are
    /// fine — stale ones are discarded at query time.
    pub fn push(&mut self, at: u64, device: usize) {
        self.heap.push(Reverse((at, device)));
    }

    /// The earliest entry satisfying `valid`, discarding stale entries
    /// on the way. The returned entry stays in the heap (it is still
    /// the next wake-up); `None` when no valid entry remains.
    pub fn earliest_valid(
        &mut self,
        mut valid: impl FnMut(u64, usize) -> bool,
    ) -> Option<(u64, usize)> {
        while let Some(&Reverse((at, d))) = self.heap.peek() {
            if valid(at, d) {
                return Some((at, d));
            }
            self.heap.pop();
        }
        None
    }

    /// Pop every entry with a stamp ≤ `t`, feeding each to `f` (valid
    /// and stale alike — the caller re-checks device state, which is
    /// cheaper than a predicate here and keeps the hot loop branchless).
    pub fn pop_until(&mut self, t: u64, mut f: impl FnMut(u64, usize)) {
        while let Some(&Reverse((at, d))) = self.heap.peek() {
            if at > t {
                break;
            }
            self.heap.pop();
            f(at, d);
        }
    }

    /// Entries currently in the heap (valid + stale).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_valid_skips_stale_entries() {
        let mut cal = WakeCalendar::new();
        cal.push(10, 0);
        cal.push(5, 1); // stale: device 1's true wake-up is 20
        cal.push(20, 1);
        let fresh = |at: u64, d: usize| if d == 1 { at == 20 } else { true };
        assert_eq!(cal.earliest_valid(fresh), Some((10, 0)));
        // The stale (5, 1) entry was discarded, the rest stayed.
        assert_eq!(cal.len(), 2);
        assert_eq!(cal.earliest_valid(|_, _| true), Some((10, 0)));
    }

    #[test]
    fn pop_until_drains_in_stamp_order() {
        let mut cal = WakeCalendar::new();
        for (at, d) in [(30u64, 2usize), (10, 0), (20, 1), (10, 3)] {
            cal.push(at, d);
        }
        let mut seen = Vec::new();
        cal.pop_until(20, |at, d| seen.push((at, d)));
        assert_eq!(seen, vec![(10, 0), (10, 3), (20, 1)]);
        assert_eq!(cal.len(), 1);
        cal.pop_until(100, |at, d| seen.push((at, d)));
        assert_eq!(seen.last(), Some(&(30, 2)));
        assert!(cal.is_empty());
    }

    #[test]
    fn empty_calendar_answers_none() {
        let mut cal = WakeCalendar::new();
        assert_eq!(cal.earliest_valid(|_, _| true), None);
        cal.pop_until(u64::MAX, |_, _| panic!("nothing to pop"));
    }
}
