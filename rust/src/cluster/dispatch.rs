//! Request dispatch: placement policies across devices and queue
//! disciplines within each device queue.
//!
//! The dispatcher owns one queue per device and makes two decisions:
//!
//! - **Placement** (on arrival): which device queue a request joins.
//!   Round-robin ignores state; least-loaded balances queue depth;
//!   shortest-expected-job balances *expected cycles* using the fleet's
//!   per-`(model, device-class)` cycle-cost cache (EdgeTran's
//!   co-designed-runtime lever) — on a heterogeneous fleet the same
//!   model costs different cycles on different classes, which is how
//!   fast classes absorb the expensive models; model-affinity routes a
//!   model class to the device that first received it (context-reuse
//!   sticky routing — it deliberately concentrates load, the hot queues
//!   work-stealing is designed to drain).
//! - **Discipline** (on service): which queued request a freed device
//!   takes next. FIFO, priority tiers (0 = highest, FIFO within a
//!   tier), or earliest-deadline-first with drop-on-SLA-miss — a
//!   request whose deadline has already passed when it would start is
//!   dropped instead of served, the standard soft-real-time policy.
//!
//! All tie-breaks are by lowest device index / earliest insertion, so a
//! fleet run is a pure function of (workload, policy, discipline).

use super::workload::FleetRequest;
use std::collections::{BTreeMap, VecDeque};
use std::ops::Range;

/// Reusable output buffers for batch pops. The run loops hold one per
/// serve context and clear-and-refill it every pop instead of
/// allocating fresh `Vec`s per tick (the steady-state allocation cut
/// from ISSUE 8).
#[derive(Debug, Default)]
pub struct PopScratch {
    /// EDF deadline misses removed on the way to the batch.
    pub dropped: Vec<FleetRequest>,
    /// The coalesced batch to serve.
    pub batch: Vec<FleetRequest>,
}

/// Queue access for the generic serve path, addressed by **global**
/// device index. Three implementors: the full [`Dispatcher`]
/// (single-threaded loops and the lockstep coordinator),
/// [`ShardQueuesMut`] (a borrowed slice of the dispatcher's queues
/// owned by one lockstep epoch worker), and [`OffsetQueues`] (a
/// shard-private dispatcher inside a decoupled worker). All three run
/// the *same* pop internals, so batch formation is bit-identical no
/// matter which executor drives it.
pub trait QueueSource {
    /// Requests queued on device `d` (excludes the one in service).
    fn queued(&self, d: usize) -> usize;
    /// Preview the batch a pop would form on device `d`.
    fn peek_batch(&self, d: usize, key_of: impl Fn(usize) -> u64) -> Option<BatchOutlook>;
    /// Pop the discipline head plus coalescible followers into
    /// `out` (cleared first), recording EDF expiries in `out.dropped`.
    fn pop_batch_into(
        &mut self,
        d: usize,
        now: u64,
        max_batch: usize,
        key_of: impl Fn(usize) -> u64 + Copy,
        out: &mut PopScratch,
    );
}

/// Index of the next request in `q` per `discipline`, optionally
/// restricted to one batch-key group (batch coalescing; `key_of` maps
/// a model id to its coalescing key — shape-identical aliases share
/// one). `None` when no candidate exists.
fn select_in(
    q: &VecDeque<FleetRequest>,
    discipline: Discipline,
    group: Option<u64>,
    key_of: impl Fn(usize) -> u64,
) -> Option<usize> {
    let key = |r: &FleetRequest| r.deadline_cycle.unwrap_or(u64::MAX);
    let mut best: Option<usize> = None;
    for (i, r) in q.iter().enumerate() {
        if group.is_some_and(|g| key_of(r.model) != g) {
            continue;
        }
        best = Some(match best {
            None => i,
            Some(b) => {
                let better = match discipline {
                    // Queue order is arrival order, so the first
                    // candidate wins.
                    Discipline::Fifo => false,
                    Discipline::Priority => r.priority < q[b].priority,
                    Discipline::Edf => key(r) < key(&q[b]),
                };
                if better {
                    i
                } else {
                    b
                }
            }
        });
    }
    best
}

/// Pop the next request per the discipline (restricted to one
/// batch-key group when coalescing), appending EDF deadline misses to
/// `dropped`. Returns how many requests left the queue (served +
/// dropped) so the caller can settle its depth accounting.
fn pop_filtered_in(
    q: &mut VecDeque<FleetRequest>,
    discipline: Discipline,
    now: u64,
    group: Option<u64>,
    key_of: impl Fn(usize) -> u64,
    dropped: &mut Vec<FleetRequest>,
) -> (usize, Option<FleetRequest>) {
    let mut removed = 0usize;
    loop {
        let Some(idx) = select_in(q, discipline, group, &key_of) else {
            return (removed, None);
        };
        // The discipline head is the queue front for FIFO (and
        // whenever arrival order wins): pop instead of shifting.
        let req = if idx == 0 {
            q.pop_front().expect("selected head")
        } else {
            q.remove(idx).expect("index in range")
        };
        removed += 1;
        if discipline == Discipline::Edf {
            if let Some(dl) = req.deadline_cycle {
                if dl < now {
                    dropped.push(req);
                    continue;
                }
            }
        }
        return (removed, Some(req));
    }
}

/// The shared batch-pop body (see [`Dispatcher::pop_batch`] for
/// semantics). Appends to `dropped`/`batch` and returns how many
/// requests left `q`.
fn pop_batch_in(
    q: &mut VecDeque<FleetRequest>,
    scratch: &mut VecDeque<FleetRequest>,
    discipline: Discipline,
    now: u64,
    max_batch: usize,
    key_of: impl Fn(usize) -> u64 + Copy,
    dropped: &mut Vec<FleetRequest>,
    batch: &mut Vec<FleetRequest>,
) -> usize {
    let b0 = batch.len();
    let d0 = dropped.len();
    if discipline == Discipline::Fifo {
        // FIFO fast path: the head is the queue front and there is
        // no expiry, so one swap/drain pass partitions the queue
        // into (batch, keepers) — O(n) total instead of an O(n)
        // `VecDeque::remove` per coalesced follower. Keepers return
        // in their original relative order, exactly as the
        // remove-by-index path left them.
        let cap = max_batch.max(1);
        let mut pending = std::mem::take(scratch);
        std::mem::swap(q, &mut pending);
        let mut group: Option<u64> = None;
        for r in pending.drain(..) {
            match group {
                None => {
                    group = Some(key_of(r.model));
                    batch.push(r);
                }
                Some(g) if batch.len() - b0 < cap && key_of(r.model) == g => batch.push(r),
                Some(_) => q.push_back(r),
            }
        }
        *scratch = pending;
        return batch.len() - b0;
    }
    let (mut removed, head) = pop_filtered_in(q, discipline, now, None, key_of, dropped);
    let Some(head) = head else {
        return removed;
    };
    let group = key_of(head.model);
    batch.push(head);
    while batch.len() - b0 < max_batch.max(1) {
        let (r, follower) = pop_filtered_in(q, discipline, now, Some(group), key_of, dropped);
        removed += r;
        match follower {
            Some(req) => batch.push(req),
            None => break,
        }
    }
    debug_assert_eq!(removed, (batch.len() - b0) + (dropped.len() - d0));
    removed
}

/// Preview the batch a pop would form on `q` (see
/// [`Dispatcher::peek_batch`]).
fn peek_batch_in(
    q: &VecDeque<FleetRequest>,
    discipline: Discipline,
    key_of: impl Fn(usize) -> u64,
) -> Option<BatchOutlook> {
    let idx = select_in(q, discipline, None, &key_of)?;
    let model = q[idx].model;
    let group = key_of(model);
    let count = q.iter().filter(|r| key_of(r.model) == group).count();
    Some(BatchOutlook {
        count,
        model,
        head_arrival: q[idx].arrival_cycle,
        head_deadline: q[idx].deadline_cycle,
    })
}

/// Device-placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Rotate over devices regardless of load.
    RoundRobin,
    /// Fewest pending requests (queued + in service).
    LeastLoaded,
    /// Earliest expected completion, estimating each queued request's
    /// service time from the per-`(model, device-class)` cycle-cost
    /// cache — including the arriving request's own cost on each
    /// candidate device, which is what steers expensive models to fast
    /// classes on a mixed fleet.
    ShortestExpectedJob,
    /// Sticky context-reuse routing: every request of a model class goes
    /// to the device that first received that class (first choice by
    /// least-loaded). Maximizes back-to-back context reuse at the price
    /// of hot queues — pair it with work-stealing.
    ModelAffinity,
}

/// Within-queue service discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// Arrival order.
    Fifo,
    /// Priority tiers (0 = highest), FIFO within a tier.
    Priority,
    /// Earliest deadline first; requests whose deadline already passed
    /// at service start are dropped (counted, never executed).
    Edf,
}

/// Same-model batch coalescing applied at pop time: when a device takes
/// work, it also takes up to `max_batch - 1` further queued requests of
/// the *same model class* (in discipline order), so the engine can run
/// them as one stacked encoder job with the weights streamed once.
///
/// `max_wait_cycles` bounds the fill delay: a device whose coalescible
/// batch is still short may stay idle until `head_arrival +
/// max_wait_cycles` waiting for more same-model arrivals; at the
/// deadline (or when no arrivals remain) it serves the partial batch.
/// With `latency_aware` set, a head that carries a deadline derives its
/// hold budget from the deadline *slack* instead of the fixed budget —
/// the policy trades waiting against the SLA rather than a constant.
/// All decisions depend only on simulated stamps, so batched fleet runs
/// stay seed-deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Largest number of same-model requests one device job may stack
    /// (1 = no batching; 0 is treated as 1 — see [`Self::cap`], the
    /// single normalization point every consumer reads).
    pub max_batch: usize,
    /// Longest the discipline head may be held waiting for the batch to
    /// fill before the device serves a partial batch (the fixed budget;
    /// ignored for deadline-carrying heads when `latency_aware`).
    pub max_wait_cycles: u64,
    /// Derive the hold budget from the head's deadline slack when the
    /// head has a deadline (hold until `deadline − expected service`),
    /// falling back to the fixed `max_wait_cycles` budget otherwise.
    pub latency_aware: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 1, max_wait_cycles: 0, latency_aware: false }
    }
}

impl BatchPolicy {
    /// Batching without any fill delay: stack whatever is queued.
    pub fn greedy(max_batch: usize) -> Self {
        Self { max_batch, max_wait_cycles: 0, latency_aware: false }
    }

    /// Latency-aware batching: the hold budget for a deadline-carrying
    /// head is its full slack (no fixed budget for deadline-free heads).
    pub fn sla_driven(max_batch: usize) -> Self {
        Self { max_batch, max_wait_cycles: 0, latency_aware: true }
    }

    /// The effective batch bound: `max_batch` clamped to ≥ 1, so a
    /// zero (a plausible "batching off" spelling) serves singly
    /// instead of deadlocking or panicking.
    pub fn cap(&self) -> usize {
        self.max_batch.max(1)
    }

    /// Absolute cycle until which the discipline head may be held for a
    /// fuller batch. `est_cycles` is the expected service cost of the
    /// batch the head would currently join. A deadline always caps the
    /// hold at the latest start that still meets it (`deadline − est`,
    /// by the current estimate — the estimate is optimistic, so a tight
    /// deadline can still be missed; the cap only keeps the *hold* from
    /// causing the miss). With `latency_aware`, that slack *is* the
    /// budget; otherwise the fixed `max_wait_cycles` applies too.
    pub fn hold_until(
        &self,
        head_arrival: u64,
        head_deadline: Option<u64>,
        est_cycles: u64,
    ) -> u64 {
        let fixed = head_arrival.saturating_add(self.max_wait_cycles);
        match head_deadline {
            Some(dl) if self.latency_aware => dl.saturating_sub(est_cycles),
            Some(dl) => fixed.min(dl.saturating_sub(est_cycles)),
            None => fixed,
        }
    }
}

/// Per-device request queues plus the placement/discipline state.
#[derive(Debug)]
pub struct Dispatcher {
    policy: Placement,
    discipline: Discipline,
    queues: Vec<VecDeque<FleetRequest>>,
    rr_next: usize,
    /// Model class → device sticky route (ModelAffinity placement).
    affinity: BTreeMap<usize, usize>,
    /// Incrementally maintained total of all queue depths, so
    /// [`Self::total_queued`] is O(1) in the event-loop hot path
    /// instead of re-summing every queue per iteration.
    total: usize,
    /// Reusable drain buffer for the FIFO batch pop (swap/drain instead
    /// of per-element `VecDeque::remove`); always empty between calls.
    scratch: VecDeque<FleetRequest>,
    /// Per-shard drain buffers lent to [`ShardQueuesMut`] views during
    /// a lockstep parallel epoch (one per worker, reused across
    /// epochs); sized lazily on first `shard_views_mut`.
    shard_scratch: Vec<VecDeque<FleetRequest>>,
}

impl Dispatcher {
    pub fn new(policy: Placement, discipline: Discipline, devices: usize) -> Self {
        assert!(devices > 0, "dispatcher needs at least one device");
        Self {
            policy,
            discipline,
            queues: (0..devices).map(|_| VecDeque::new()).collect(),
            rr_next: 0,
            affinity: BTreeMap::new(),
            total: 0,
            scratch: VecDeque::new(),
            shard_scratch: Vec::new(),
        }
    }

    /// Requests queued on device `d` (excludes the one in service).
    pub fn queued(&self, d: usize) -> usize {
        self.queues[d].len()
    }

    /// Total queued requests across the fleet (O(1): maintained on
    /// every push and pop).
    pub fn total_queued(&self) -> usize {
        self.total
    }

    /// The least-loaded device (queued + in service), ties to the
    /// lowest index — also the affinity policy's first-contact choice.
    fn least_loaded(&self, now: u64, free_at: impl Fn(usize) -> u64) -> usize {
        (0..self.queues.len())
            .min_by_key(|&d| self.queues[d].len() + usize::from(free_at(d) > now))
            .expect("non-empty fleet")
    }

    /// Place `req` on a device queue and return the chosen device.
    ///
    /// `free_at(d)` is device `d`'s earliest idle cycle (an accessor
    /// rather than a slice, so the caller never materializes a
    /// per-arrival snapshot); `est(model, device)` returns the expected
    /// service cycles of one request of that model class *on that
    /// device* (the per-`(model, class)` cycle-cost cache lookup — on a
    /// heterogeneous fleet the same model costs different cycles per
    /// class).
    pub fn dispatch(
        &mut self,
        req: FleetRequest,
        now: u64,
        free_at: impl Fn(usize) -> u64,
        est: impl Fn(usize, usize) -> u64,
    ) -> usize {
        let n = self.queues.len();
        let dev = match self.policy {
            Placement::RoundRobin => {
                let d = self.rr_next % n;
                self.rr_next = (self.rr_next + 1) % n;
                d
            }
            Placement::LeastLoaded => self.least_loaded(now, free_at),
            Placement::ShortestExpectedJob => (0..n)
                .min_by_key(|&d| {
                    let backlog: u64 = self.queues[d].iter().map(|r| est(r.model, d)).sum();
                    free_at(d).max(now) + backlog + est(req.model, d)
                })
                .expect("non-empty fleet"),
            Placement::ModelAffinity => match self.affinity.get(&req.model) {
                Some(&d) => d,
                None => {
                    let d = self.least_loaded(now, free_at);
                    self.affinity.insert(req.model, d);
                    d
                }
            },
        };
        self.queues[dev].push_back(req);
        self.total += 1;
        dev
    }

    /// Append `req` to device `d`'s queue directly, bypassing the
    /// placement scan. The decoupled threaded backend pre-routes
    /// round-robin arrivals (pure rotation — the routing is a function
    /// of the arrival index alone) and replays the placement into each
    /// shard's private dispatcher with this.
    pub fn enqueue(&mut self, d: usize, req: FleetRequest) {
        self.queues[d].push_back(req);
        self.total += 1;
    }

    /// Pop device `d`'s next request per the discipline. Returns the
    /// requests dropped on the way (EDF deadline misses) and the request
    /// to serve, if any.
    pub fn pop(&mut self, d: usize, now: u64) -> (Vec<FleetRequest>, Option<FleetRequest>) {
        let mut dropped = Vec::new();
        let (removed, job) = pop_filtered_in(
            &mut self.queues[d],
            self.discipline,
            now,
            None,
            |m| m as u64,
            &mut dropped,
        );
        self.total -= removed;
        (dropped, job)
    }

    /// Pop the discipline head plus up to `max_batch - 1` further queued
    /// requests sharing its **batch key** (in discipline order): the
    /// batch one device job will serve as a single stacked encoder run.
    /// `key_of` maps model ids to coalescing keys (the fleet passes
    /// [`super::fleet::model_batch_key`] values, so shape-identical
    /// aliases of one deployed model coalesce across ids; the identity
    /// map `|m| m as u64` restores strict per-model batching).
    pub fn pop_batch(
        &mut self,
        d: usize,
        now: u64,
        max_batch: usize,
        key_of: impl Fn(usize) -> u64 + Copy,
    ) -> (Vec<FleetRequest>, Vec<FleetRequest>) {
        let mut out = PopScratch::default();
        QueueSource::pop_batch_into(self, d, now, max_batch, key_of, &mut out);
        (out.dropped, out.batch)
    }

    /// Preview the batch a pop would form on device `d` (the fleet's
    /// hold-for-fill decision). `None` when the queue is empty. EDF
    /// expiry is ignored here — an expired head resolves at pop time.
    /// The reported `count` spans every queued request sharing the
    /// head's batch key; `model` is the head's own id.
    pub fn peek_batch(&self, d: usize, key_of: impl Fn(usize) -> u64) -> Option<BatchOutlook> {
        peek_batch_in(&self.queues[d], self.discipline, key_of)
    }

    /// Borrow the queues as disjoint per-shard views for one lockstep
    /// parallel epoch. `ranges` must partition `0..devices`
    /// contiguously in ascending order (the shard layout from
    /// `threads::shard_ranges`). Each view owns a reusable drain
    /// buffer and counts its own pops; the caller settles the O(1)
    /// depth total afterwards with [`Self::note_removed`].
    pub fn shard_views_mut(&mut self, ranges: &[Range<usize>]) -> Vec<ShardQueuesMut<'_>> {
        if self.shard_scratch.len() < ranges.len() {
            self.shard_scratch.resize_with(ranges.len(), VecDeque::new);
        }
        let discipline = self.discipline;
        let mut views = Vec::with_capacity(ranges.len());
        let mut queues_rest: &mut [VecDeque<FleetRequest>] = &mut self.queues;
        let mut scratch_rest: &mut [VecDeque<FleetRequest>] = &mut self.shard_scratch;
        let mut off = 0usize;
        for r in ranges {
            debug_assert_eq!(r.start, off, "shard ranges must partition the roster");
            let (qs, q_rest) = queues_rest.split_at_mut(r.end - off);
            queues_rest = q_rest;
            let (sc, sc_rest) = scratch_rest.split_at_mut(1);
            scratch_rest = sc_rest;
            views.push(ShardQueuesMut {
                base: off,
                discipline,
                queues: qs,
                scratch: &mut sc[0],
                popped: 0,
            });
            off = r.end;
        }
        views
    }

    /// Settle the O(1) depth total after a parallel epoch: `removed`
    /// requests left shard queues through [`ShardQueuesMut`] views
    /// (which cannot reach the total themselves — that is the whole
    /// point of handing each worker only its shard).
    pub fn note_removed(&mut self, removed: usize) {
        self.total -= removed;
    }
}

impl QueueSource for Dispatcher {
    fn queued(&self, d: usize) -> usize {
        Dispatcher::queued(self, d)
    }

    fn peek_batch(&self, d: usize, key_of: impl Fn(usize) -> u64) -> Option<BatchOutlook> {
        Dispatcher::peek_batch(self, d, key_of)
    }

    fn pop_batch_into(
        &mut self,
        d: usize,
        now: u64,
        max_batch: usize,
        key_of: impl Fn(usize) -> u64 + Copy,
        out: &mut PopScratch,
    ) {
        out.dropped.clear();
        out.batch.clear();
        let removed = pop_batch_in(
            &mut self.queues[d],
            &mut self.scratch,
            self.discipline,
            now,
            max_batch,
            key_of,
            &mut out.dropped,
            &mut out.batch,
        );
        self.total -= removed;
    }
}

/// One shard's slice of the dispatcher's queues, lent to a lockstep
/// epoch worker. Addressed by global device index; counts its own
/// pops for the coordinator to settle at the barrier.
#[derive(Debug)]
pub struct ShardQueuesMut<'a> {
    base: usize,
    discipline: Discipline,
    queues: &'a mut [VecDeque<FleetRequest>],
    scratch: &'a mut VecDeque<FleetRequest>,
    popped: usize,
}

impl ShardQueuesMut<'_> {
    /// Requests removed through this view (for
    /// [`Dispatcher::note_removed`]).
    pub fn popped(&self) -> usize {
        self.popped
    }
}

impl QueueSource for ShardQueuesMut<'_> {
    fn queued(&self, d: usize) -> usize {
        self.queues[d - self.base].len()
    }

    fn peek_batch(&self, d: usize, key_of: impl Fn(usize) -> u64) -> Option<BatchOutlook> {
        peek_batch_in(&self.queues[d - self.base], self.discipline, key_of)
    }

    fn pop_batch_into(
        &mut self,
        d: usize,
        now: u64,
        max_batch: usize,
        key_of: impl Fn(usize) -> u64 + Copy,
        out: &mut PopScratch,
    ) {
        out.dropped.clear();
        out.batch.clear();
        self.popped += pop_batch_in(
            &mut self.queues[d - self.base],
            self.scratch,
            self.discipline,
            now,
            max_batch,
            key_of,
            &mut out.dropped,
            &mut out.batch,
        );
    }
}

/// A shard-private dispatcher addressed by **global** device index —
/// the decoupled threaded backend runs one `Dispatcher` per shard
/// (local queues only) while the shared serve path speaks global
/// indices.
#[derive(Debug)]
pub struct OffsetQueues<'a> {
    pub base: usize,
    pub inner: &'a mut Dispatcher,
}

impl QueueSource for OffsetQueues<'_> {
    fn queued(&self, d: usize) -> usize {
        self.inner.queued(d - self.base)
    }

    fn peek_batch(&self, d: usize, key_of: impl Fn(usize) -> u64) -> Option<BatchOutlook> {
        self.inner.peek_batch(d - self.base, key_of)
    }

    fn pop_batch_into(
        &mut self,
        d: usize,
        now: u64,
        max_batch: usize,
        key_of: impl Fn(usize) -> u64 + Copy,
        out: &mut PopScratch,
    ) {
        QueueSource::pop_batch_into(self.inner, d - self.base, now, max_batch, key_of, out);
    }
}

/// What a pop would take from a device queue right now — the input to
/// the fleet's hold-for-fill decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchOutlook {
    /// Queued requests sharing the discipline head's model class.
    pub count: usize,
    /// The head's model class.
    pub model: usize,
    /// The head's arrival cycle (the anchor for `max_wait_cycles`).
    pub head_arrival: u64,
    /// The head's absolute deadline, if any (caps how long a hold may
    /// defer service).
    pub head_deadline: Option<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mat::MatF32;

    fn req(id: u64, model: usize, priority: u8, deadline: Option<u64>) -> FleetRequest {
        FleetRequest {
            id,
            model,
            input: MatF32::zeros(1, 1),
            arrival_cycle: 0,
            priority,
            deadline_cycle: deadline,
        }
    }

    #[test]
    fn round_robin_rotates() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 3);
        let picks: Vec<usize> =
            (0..6).map(|i| d.dispatch(req(i, 0, 0, None), 0, |_| 0, |_, _| 1)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_avoids_busy_device() {
        let mut d = Dispatcher::new(Placement::LeastLoaded, Discipline::Fifo, 2);
        // Device 0 busy (free at 100 > now 0), device 1 idle.
        let busy0 = |dev: usize| if dev == 0 { 100 } else { 0 };
        assert_eq!(d.dispatch(req(0, 0, 0, None), 0, busy0, |_, _| 1), 1);
        // Now both have equal pending count (0: busy, 1: one queued) —
        // the tie prefers the lower index.
        assert_eq!(d.dispatch(req(1, 0, 0, None), 0, busy0, |_, _| 1), 0);
    }

    #[test]
    fn sjf_weighs_backlog_by_expected_cost() {
        let mut d = Dispatcher::new(Placement::ShortestExpectedJob, Discipline::Fifo, 2);
        // Model 1 is 10x the cost of model 0. Queue an expensive request
        // on device 0; the next request must go to device 1 even though
        // both queues have length 1 after it.
        let cost = |m: usize, _d: usize| if m == 0 { 10u64 } else { 100u64 };
        assert_eq!(d.dispatch(req(0, 1, 0, None), 0, |_| 0, cost), 0);
        assert_eq!(d.dispatch(req(1, 0, 0, None), 0, |_| 0, cost), 1);
        // Device 0 backlog 100 vs device 1 backlog 10: cheap requests
        // keep landing on device 1 until the totals cross.
        assert_eq!(d.dispatch(req(2, 0, 0, None), 0, |_| 0, cost), 1);
    }

    #[test]
    fn priority_tiers_preempt_fifo_order() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Priority, 1);
        d.dispatch(req(0, 0, 2, None), 0, |_| 0, |_, _| 1);
        d.dispatch(req(1, 0, 0, None), 0, |_| 0, |_, _| 1);
        d.dispatch(req(2, 0, 0, None), 0, |_| 0, |_, _| 1);
        let (_, first) = d.pop(0, 0);
        let (_, second) = d.pop(0, 0);
        let (_, third) = d.pop(0, 0);
        assert_eq!(first.unwrap().id, 1, "highest tier first");
        assert_eq!(second.unwrap().id, 2, "FIFO within tier");
        assert_eq!(third.unwrap().id, 0);
    }

    #[test]
    fn edf_orders_by_deadline_and_drops_expired() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Edf, 1);
        d.dispatch(req(0, 0, 0, Some(500)), 0, |_| 0, |_, _| 1);
        d.dispatch(req(1, 0, 0, Some(50)), 0, |_| 0, |_, _| 1); // already expired at now=100
        d.dispatch(req(2, 0, 0, Some(200)), 0, |_| 0, |_, _| 1);
        let (dropped, job) = d.pop(0, 100);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, 1, "expired request dropped, not served");
        assert_eq!(job.unwrap().id, 2, "earliest live deadline served first");
        let (dropped, job) = d.pop(0, 100);
        assert!(dropped.is_empty());
        assert_eq!(job.unwrap().id, 0);
        let (dropped, job) = d.pop(0, 100);
        assert!(dropped.is_empty() && job.is_none());
    }

    #[test]
    fn pop_batch_coalesces_same_model_in_fifo_order() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 1);
        // Interleaved models: 0, 1, 0, 0, 1.
        for (id, model) in [(0u64, 0usize), (1, 1), (2, 0), (3, 0), (4, 1)] {
            d.dispatch(req(id, model, 0, None), 0, |_| 0, |_, _| 1);
        }
        let (dropped, batch) = d.pop_batch(0, 0, 4, |m| m as u64);
        assert!(dropped.is_empty());
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 3], "head's model coalesced in arrival order");
        let (_, batch2) = d.pop_batch(0, 0, 4, |m| m as u64);
        let ids2: Vec<u64> = batch2.iter().map(|r| r.id).collect();
        assert_eq!(ids2, vec![1, 4], "other model forms the next batch");
        assert!(d.pop_batch(0, 0, 4, |m| m as u64).1.is_empty());
    }

    #[test]
    fn pop_batch_coalesces_across_aliased_model_ids() {
        // Models 0 and 2 share a batch key (shape-identical aliases of
        // one deployed model); model 1 is distinct. Coalescing must
        // group by key, not id — and the identity key must not.
        let key = |m: usize| if m == 2 { 0u64 } else { m as u64 };
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 1);
        for (id, model) in [(0u64, 0usize), (1, 1), (2, 2), (3, 0)] {
            d.dispatch(req(id, model, 0, None), 0, |_| 0, |_, _| 1);
        }
        let peek = d.peek_batch(0, key).unwrap();
        assert_eq!(peek.count, 3, "peek must count the whole key group");
        assert_eq!(peek.model, 0, "the head keeps its own id");
        let (_, batch) = d.pop_batch(0, 0, 4, key);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 3], "aliased ids coalesce in arrival order");
        let (_, rest) = d.pop_batch(0, 0, 4, key);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].model, 1);
    }

    #[test]
    fn pop_batch_respects_max_batch() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 1);
        for id in 0..5 {
            d.dispatch(req(id, 0, 0, None), 0, |_| 0, |_, _| 1);
        }
        let (_, batch) = d.pop_batch(0, 0, 2, |m| m as u64);
        assert_eq!(batch.len(), 2);
        assert_eq!(d.queued(0), 3);
        // max_batch 0 is clamped to 1 (no batching), never an empty pop.
        let (_, batch) = d.pop_batch(0, 0, 0, |m| m as u64);
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn pop_batch_edf_drops_expired_followers() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Edf, 1);
        d.dispatch(req(0, 0, 0, Some(500)), 0, |_| 0, |_, _| 1);
        d.dispatch(req(1, 0, 0, Some(50)), 0, |_| 0, |_, _| 1); // expired at now=100
        d.dispatch(req(2, 0, 0, Some(400)), 0, |_| 0, |_, _| 1);
        let (dropped, batch) = d.pop_batch(0, 100, 3, |m| m as u64);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, 1);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 0], "live requests batched in deadline order");
    }

    #[test]
    fn peek_batch_reports_head_model_count_and_arrival() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 1);
        assert_eq!(d.peek_batch(0, |m| m as u64), None);
        let mut r0 = req(0, 0, 0, Some(900));
        r0.arrival_cycle = 7;
        d.dispatch(r0, 7, |_| 0, |_, _| 1);
        d.dispatch(req(1, 1, 0, None), 8, |_| 0, |_, _| 1);
        d.dispatch(req(2, 0, 0, None), 9, |_| 0, |_, _| 1);
        assert_eq!(
            d.peek_batch(0, |m| m as u64),
            Some(BatchOutlook { count: 2, model: 0, head_arrival: 7, head_deadline: Some(900) }),
            "two model-0 requests behind the head"
        );
        // Peeking must not consume anything.
        assert_eq!(d.queued(0), 3);
    }

    #[test]
    fn fifo_preserves_order() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 1);
        for i in 0..4 {
            d.dispatch(req(i, 0, 0, None), 0, |_| 0, |_, _| 1);
        }
        for i in 0..4 {
            assert_eq!(d.pop(0, 0).1.unwrap().id, i);
        }
    }

    #[test]
    fn sjf_prefers_the_cheaper_device_class() {
        // Same model, heterogeneous devices: device 1's class serves it
        // in a quarter of the cycles. SJF must route there even though
        // ties normally break to the lowest index, and keep routing
        // there until the backlog crosses over.
        let mut d = Dispatcher::new(Placement::ShortestExpectedJob, Discipline::Fifo, 2);
        let cost = |_m: usize, dev: usize| if dev == 0 { 100u64 } else { 25u64 };
        assert_eq!(d.dispatch(req(0, 0, 0, None), 0, |_| 0, cost), 1);
        assert_eq!(d.dispatch(req(1, 0, 0, None), 0, |_| 0, cost), 1);
        assert_eq!(d.dispatch(req(2, 0, 0, None), 0, |_| 0, cost), 1);
        // Device 1 backlog 75 + 25 = 100 vs device 0's 0 + 100: the tie
        // finally falls back to the lower index.
        assert_eq!(d.dispatch(req(3, 0, 0, None), 0, |_| 0, cost), 0);
    }

    #[test]
    fn model_affinity_sticks_to_first_contact() {
        let mut d = Dispatcher::new(Placement::ModelAffinity, Discipline::Fifo, 3);
        // First contact of model 0 goes least-loaded (device 0); every
        // later model-0 request sticks there even as the queue grows.
        assert_eq!(d.dispatch(req(0, 0, 0, None), 0, |_| 0, |_, _| 1), 0);
        assert_eq!(d.dispatch(req(1, 0, 0, None), 0, |_| 0, |_, _| 1), 0);
        assert_eq!(d.dispatch(req(2, 0, 0, None), 0, |_| 0, |_, _| 1), 0);
        // A different model class takes the next least-loaded device.
        assert_eq!(d.dispatch(req(3, 1, 0, None), 0, |_| 0, |_, _| 1), 1);
        assert_eq!(d.dispatch(req(4, 1, 0, None), 0, |_| 0, |_, _| 1), 1);
        assert_eq!(d.queued(0), 3);
        assert_eq!(d.queued(1), 2);
        assert_eq!(d.queued(2), 0);
    }

    #[test]
    fn hold_until_fixed_budget_and_deadline_cap() {
        let p = BatchPolicy { max_batch: 4, max_wait_cycles: 1_000, latency_aware: false };
        assert_eq!(p.hold_until(500, None, 200), 1_500, "fixed budget from head arrival");
        assert_eq!(p.hold_until(500, Some(1_200), 200), 1_000, "deadline slack caps the hold");
        assert_eq!(p.hold_until(500, Some(100), 200), 0, "expired slack saturates to zero");
    }

    #[test]
    fn queued_counters_track_every_push_and_pop_path() {
        // The O(1) total must agree with the per-queue depths across
        // every mutation path: dispatch, single pop, batch pop (both
        // the FIFO swap/drain and the select/remove path), EDF drops.
        let consistent = |d: &Dispatcher| {
            let sum: usize = (0..2).map(|q| d.queued(q)).sum();
            assert_eq!(d.total_queued(), sum, "incremental total drifted from queue depths");
        };
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 2);
        for (id, model) in [(0u64, 0usize), (1, 1), (2, 0), (3, 0)] {
            d.dispatch(req(id, model, 0, None), 0, |_| 0, |_, _| 1);
            consistent(&d);
        }
        let (_, batch) = d.pop_batch(0, 0, 4, |m| m as u64);
        assert_eq!(batch.len(), 2, "models 0 coalesce on device 0");
        consistent(&d);
        let (_, job) = d.pop(1, 0);
        assert!(job.is_some());
        consistent(&d);
        // EDF drops decrement the total too (the dropped request left
        // its queue even though it was never served).
        let mut e = Dispatcher::new(Placement::RoundRobin, Discipline::Edf, 1);
        e.dispatch(req(0, 0, 0, Some(10)), 0, |_| 0, |_, _| 1);
        e.dispatch(req(1, 0, 0, Some(900)), 0, |_| 0, |_, _| 1);
        let (dropped, job) = e.pop_batch(0, 100, 4, |m| m as u64);
        assert_eq!(dropped.len(), 1);
        assert_eq!(job.len(), 1);
        assert_eq!(e.total_queued(), 0);
        assert_eq!(e.queued(0), 0);
    }

    #[test]
    fn hold_until_latency_aware_uses_slack_not_budget() {
        let p = BatchPolicy::sla_driven(4);
        assert_eq!(p.max_wait_cycles, 0);
        // A deadline-free head gets the (zero) fixed budget…
        assert_eq!(p.hold_until(500, None, 200), 500);
        // …but a deadline-carrying head may wait out its whole slack,
        // far beyond any fixed budget.
        assert_eq!(p.hold_until(500, Some(100_000), 200), 99_800);
    }
}
