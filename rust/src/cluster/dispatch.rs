//! Request dispatch: placement policies across devices and queue
//! disciplines within each device queue.
//!
//! The dispatcher owns one queue per device and makes two decisions:
//!
//! - **Placement** (on arrival): which device queue a request joins.
//!   Round-robin ignores state; least-loaded balances queue depth;
//!   shortest-expected-job balances *expected cycles* using the fleet's
//!   per-model cycle-cost cache (EdgeTran's co-designed-runtime lever).
//! - **Discipline** (on service): which queued request a freed device
//!   takes next. FIFO, priority tiers (0 = highest, FIFO within a
//!   tier), or earliest-deadline-first with drop-on-SLA-miss — a
//!   request whose deadline has already passed when it would start is
//!   dropped instead of served, the standard soft-real-time policy.
//!
//! All tie-breaks are by lowest device index / earliest insertion, so a
//! fleet run is a pure function of (workload, policy, discipline).

use super::workload::FleetRequest;
use std::collections::VecDeque;

/// Device-placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Rotate over devices regardless of load.
    RoundRobin,
    /// Fewest pending requests (queued + in service).
    LeastLoaded,
    /// Earliest expected completion, estimating each queued request's
    /// service time from the per-model cycle-cost cache.
    ShortestExpectedJob,
}

/// Within-queue service discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// Arrival order.
    Fifo,
    /// Priority tiers (0 = highest), FIFO within a tier.
    Priority,
    /// Earliest deadline first; requests whose deadline already passed
    /// at service start are dropped (counted, never executed).
    Edf,
}

/// Per-device request queues plus the placement/discipline state.
#[derive(Debug)]
pub struct Dispatcher {
    policy: Placement,
    discipline: Discipline,
    queues: Vec<VecDeque<FleetRequest>>,
    rr_next: usize,
}

impl Dispatcher {
    pub fn new(policy: Placement, discipline: Discipline, devices: usize) -> Self {
        assert!(devices > 0, "dispatcher needs at least one device");
        Self {
            policy,
            discipline,
            queues: (0..devices).map(|_| VecDeque::new()).collect(),
            rr_next: 0,
        }
    }

    /// Requests queued on device `d` (excludes the one in service).
    pub fn queued(&self, d: usize) -> usize {
        self.queues[d].len()
    }

    /// Total queued requests across the fleet.
    pub fn total_queued(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    /// Place `req` on a device queue and return the chosen device.
    ///
    /// `free_at[d]` is device `d`'s earliest idle cycle; `est(model)`
    /// returns the expected service cycles for a model class (the
    /// cycle-cost cache lookup).
    pub fn dispatch(
        &mut self,
        req: FleetRequest,
        now: u64,
        free_at: &[u64],
        est: impl Fn(usize) -> u64,
    ) -> usize {
        let n = self.queues.len();
        debug_assert_eq!(free_at.len(), n);
        let dev = match self.policy {
            Placement::RoundRobin => {
                let d = self.rr_next % n;
                self.rr_next = (self.rr_next + 1) % n;
                d
            }
            Placement::LeastLoaded => (0..n)
                .min_by_key(|&d| self.queues[d].len() + usize::from(free_at[d] > now))
                .expect("non-empty fleet"),
            Placement::ShortestExpectedJob => (0..n)
                .min_by_key(|&d| {
                    let backlog: u64 = self.queues[d].iter().map(|r| est(r.model)).sum();
                    free_at[d].max(now) + backlog
                })
                .expect("non-empty fleet"),
        };
        self.queues[dev].push_back(req);
        dev
    }

    /// Pop device `d`'s next request per the discipline. Returns the
    /// requests dropped on the way (EDF deadline misses) and the request
    /// to serve, if any.
    pub fn pop(&mut self, d: usize, now: u64) -> (Vec<FleetRequest>, Option<FleetRequest>) {
        let discipline = self.discipline;
        let q = &mut self.queues[d];
        let mut dropped = Vec::new();
        let job = loop {
            if q.is_empty() {
                break None;
            }
            let idx = match discipline {
                Discipline::Fifo => 0,
                Discipline::Priority => {
                    let mut best = 0;
                    for i in 1..q.len() {
                        if q[i].priority < q[best].priority {
                            best = i;
                        }
                    }
                    best
                }
                Discipline::Edf => {
                    let key = |r: &FleetRequest| r.deadline_cycle.unwrap_or(u64::MAX);
                    let mut best = 0;
                    for i in 1..q.len() {
                        if key(&q[i]) < key(&q[best]) {
                            best = i;
                        }
                    }
                    best
                }
            };
            let req = q.remove(idx).expect("index in range");
            if discipline == Discipline::Edf {
                if let Some(dl) = req.deadline_cycle {
                    if dl < now {
                        dropped.push(req);
                        continue;
                    }
                }
            }
            break Some(req);
        };
        (dropped, job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mat::MatF32;

    fn req(id: u64, model: usize, priority: u8, deadline: Option<u64>) -> FleetRequest {
        FleetRequest {
            id,
            model,
            input: MatF32::zeros(1, 1),
            arrival_cycle: 0,
            priority,
            deadline_cycle: deadline,
        }
    }

    #[test]
    fn round_robin_rotates() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 3);
        let picks: Vec<usize> =
            (0..6).map(|i| d.dispatch(req(i, 0, 0, None), 0, &[0, 0, 0], |_| 1)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_avoids_busy_device() {
        let mut d = Dispatcher::new(Placement::LeastLoaded, Discipline::Fifo, 2);
        // Device 0 busy (free at 100 > now 0), device 1 idle.
        assert_eq!(d.dispatch(req(0, 0, 0, None), 0, &[100, 0], |_| 1), 1);
        // Now both have equal pending count (0: busy, 1: one queued) —
        // the tie prefers the lower index.
        assert_eq!(d.dispatch(req(1, 0, 0, None), 0, &[100, 0], |_| 1), 0);
    }

    #[test]
    fn sjf_weighs_backlog_by_expected_cost() {
        let mut d = Dispatcher::new(Placement::ShortestExpectedJob, Discipline::Fifo, 2);
        // Model 1 is 10x the cost of model 0. Queue an expensive request
        // on device 0; the next request must go to device 1 even though
        // both queues have length 1 after it.
        let cost = |m: usize| if m == 0 { 10u64 } else { 100u64 };
        assert_eq!(d.dispatch(req(0, 1, 0, None), 0, &[0, 0], cost), 0);
        assert_eq!(d.dispatch(req(1, 0, 0, None), 0, &[0, 0], cost), 1);
        // Device 0 backlog 100 vs device 1 backlog 10: cheap requests
        // keep landing on device 1 until the totals cross.
        assert_eq!(d.dispatch(req(2, 0, 0, None), 0, &[0, 0], cost), 1);
    }

    #[test]
    fn priority_tiers_preempt_fifo_order() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Priority, 1);
        d.dispatch(req(0, 0, 2, None), 0, &[0], |_| 1);
        d.dispatch(req(1, 0, 0, None), 0, &[0], |_| 1);
        d.dispatch(req(2, 0, 0, None), 0, &[0], |_| 1);
        let (_, first) = d.pop(0, 0);
        let (_, second) = d.pop(0, 0);
        let (_, third) = d.pop(0, 0);
        assert_eq!(first.unwrap().id, 1, "highest tier first");
        assert_eq!(second.unwrap().id, 2, "FIFO within tier");
        assert_eq!(third.unwrap().id, 0);
    }

    #[test]
    fn edf_orders_by_deadline_and_drops_expired() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Edf, 1);
        d.dispatch(req(0, 0, 0, Some(500)), 0, &[0], |_| 1);
        d.dispatch(req(1, 0, 0, Some(50)), 0, &[0], |_| 1); // already expired at now=100
        d.dispatch(req(2, 0, 0, Some(200)), 0, &[0], |_| 1);
        let (dropped, job) = d.pop(0, 100);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, 1, "expired request dropped, not served");
        assert_eq!(job.unwrap().id, 2, "earliest live deadline served first");
        let (dropped, job) = d.pop(0, 100);
        assert!(dropped.is_empty());
        assert_eq!(job.unwrap().id, 0);
        let (dropped, job) = d.pop(0, 100);
        assert!(dropped.is_empty() && job.is_none());
    }

    #[test]
    fn fifo_preserves_order() {
        let mut d = Dispatcher::new(Placement::RoundRobin, Discipline::Fifo, 1);
        for i in 0..4 {
            d.dispatch(req(i, 0, 0, None), 0, &[0], |_| 1);
        }
        for i in 0..4 {
            assert_eq!(d.pop(0, 0).1.unwrap().id, i);
        }
    }
}
