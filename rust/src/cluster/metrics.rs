//! Fleet-level serving metrics: latency percentiles, per-device
//! utilization, SLA accounting, fleet energy.
//!
//! Latency lives in two containers with the same nearest-rank
//! percentile definition: the exact-sample [`LatencyHistogram`]
//! (kept for the single-device [`crate::coordinator::ServeMetrics`]
//! and as the conformance oracle in tests) and the O(buckets)
//! mergeable [`LogHistogram`](crate::obs::LogHistogram) that
//! [`FleetMetrics`] and the decode fleet's metrics now record into —
//! bounded relative error, constant memory regardless of request
//! count, exact merge across devices (the ROADMAP "incremental
//! percentile sketches instead of full latency vecs" item).

use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::obs::LogHistogram;
use crate::sim::Stats;

/// Exact-sample latency recorder with nearest-rank percentiles.
///
/// All values are in simulated cycles; convert with the clock frequency
/// for wall-time reporting (`cycles / (freq_mhz * 1e3)` → ms).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LatencyHistogram {
    /// Kept sorted on insert, so every percentile query is O(1).
    samples: Vec<u64>,
}

impl LatencyHistogram {
    /// Record one latency sample (cycles).
    pub fn record(&mut self, cycles: u64) {
        let idx = self.samples.partition_point(|&s| s <= cycles);
        self.samples.insert(idx, cycles);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Mean over all samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.samples.last().copied().unwrap_or(0)
    }

    /// Nearest-rank percentile: the smallest sample ≥ `q`% of the
    /// distribution. `q` in (0, 100]; returns 0 when empty.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let rank = ((q / 100.0) * self.samples.len() as f64).ceil() as usize;
        self.samples[rank.clamp(1, self.samples.len()) - 1]
    }

    /// Median latency.
    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    /// 95th-percentile latency.
    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    /// 99th-percentile tail latency.
    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }
}

/// Per-device accounting inside a fleet run.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceMetrics {
    /// Requests this device completed.
    pub served: u64,
    /// Cycles this device spent executing (charged service time, on the
    /// fleet's reference clock).
    pub busy_cycles: u64,
    /// Steal operations this device executed as the *thief* (batches it
    /// pulled from a backlogged neighbour's queue).
    pub steals: u64,
    /// This device's own simulator event counters (the fleet-level
    /// `stats` is their merge) — kept per device so energy can apply
    /// per-class voltage scaling to the dynamic part.
    pub stats: Stats,
    /// Leakage-power multiplier of the device's class
    /// ([`crate::config::DeviceClass::leakage_scale`]; 1.0 = the
    /// paper's 4×4@100 design point).
    pub leakage_scale: f64,
    /// Dynamic-energy (V²) multiplier of the device's class
    /// ([`crate::config::DeviceClass::dynamic_scale`]).
    pub dynamic_scale: f64,
}

impl Default for DeviceMetrics {
    fn default() -> Self {
        Self {
            served: 0,
            busy_cycles: 0,
            steals: 0,
            stats: Stats::default(),
            leakage_scale: 1.0,
            dynamic_scale: 1.0,
        }
    }
}

/// Aggregated metrics for one fleet run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetMetrics {
    /// Requests served to completion.
    pub completed: u64,
    /// Requests dropped by the queue discipline (EDF drop-on-SLA-miss).
    pub dropped: u64,
    /// Completed requests that finished after their deadline.
    pub sla_misses: u64,
    /// Latest completion time across all devices (simulated makespan).
    pub makespan_cycles: u64,
    /// End-to-end latency (queue + service) of completed requests.
    pub latency: LogHistogram,
    /// Queue-wait component of latency (diagnostic for placement),
    /// excluding any batch-formation hold the device chose to take.
    pub queue_wait: LogHistogram,
    /// Batch-formation hold component of latency: cycles a completed
    /// request sat in a deliberately parked partial batch (one sample
    /// per completion, zero when its batch never held).
    pub hold_wait: LogHistogram,
    /// Requests per executed batch, one sample per device job
    /// (`mean()` is the average occupancy, `count()` the job count).
    pub batch_occupancy: LogHistogram,
    /// External-memory words avoided by streaming shared weights once
    /// per stacked kernel instead of once per request.
    pub weight_reuse_words: u64,
    /// Steal operations across the fleet: an idle device pulling a
    /// coalescible batch from the deepest backlogged neighbour queue.
    pub steals: u64,
    /// Requests that changed device via stealing (a stolen batch of
    /// size B counts B here and 1 in `steals`).
    pub stolen_requests: u64,
    /// Per-device service counters, indexed by device id.
    pub per_device: Vec<DeviceMetrics>,
    /// Merged simulator event counters across every device.
    pub stats: Stats,
}

impl FleetMetrics {
    /// Fold a shard worker's partial run metrics into this one (the
    /// threaded fleet backends' merge — every field is commutative to
    /// aggregate except the makespan maximum, and [`LogHistogram`]
    /// merges are exact bucket-count sums, so shard-order merging
    /// reproduces the single-threaded run's metrics bit-for-bit).
    /// Per-device counters are not merged here: both backends rebuild
    /// `per_device` from the device engines themselves at finalize.
    pub fn merge_run(&mut self, other: FleetMetrics) {
        debug_assert!(other.per_device.is_empty(), "shard metrics carry no per-device rows");
        self.completed += other.completed;
        self.dropped += other.dropped;
        self.sla_misses += other.sla_misses;
        self.makespan_cycles = self.makespan_cycles.max(other.makespan_cycles);
        self.latency.merge(&other.latency);
        self.queue_wait.merge(&other.queue_wait);
        self.hold_wait.merge(&other.hold_wait);
        self.batch_occupancy.merge(&other.batch_occupancy);
        self.weight_reuse_words += other.weight_reuse_words;
        self.steals += other.steals;
        self.stolen_requests += other.stolen_requests;
        self.stats.merge(&other.stats);
    }

    /// Fleet throughput in requests per second at `freq_mhz`.
    pub fn throughput_rps(&self, freq_mhz: f64) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan_cycles as f64 / (freq_mhz * 1e6))
    }

    /// Fraction of the makespan device `d` spent busy.
    pub fn utilization(&self, d: usize) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.per_device[d].busy_cycles as f64 / self.makespan_cycles as f64
    }

    /// Device jobs executed (a stacked batch of any size is one job).
    pub fn batches(&self) -> u64 {
        self.batch_occupancy.count() as u64
    }

    /// Mean batch occupancy: completed requests per device job (1.0
    /// when batching is off; 0 when nothing ran).
    pub fn mean_batch_occupancy(&self) -> f64 {
        self.batch_occupancy.mean()
    }

    /// Mean utilization across the fleet.
    pub fn mean_utilization(&self) -> f64 {
        if self.per_device.is_empty() {
            return 0.0;
        }
        (0..self.per_device.len()).map(|d| self.utilization(d)).sum::<f64>()
            / self.per_device.len() as f64
    }

    /// Fleet energy, **per device class**: each device's dynamic energy
    /// is evaluated from its own event counters with its class's V²
    /// scaling, and each device leaks at its class's area×V rate over
    /// the *whole* makespan — an idle device still leaks, which is
    /// exactly the scale-out cost the ultra-low-power story cares
    /// about. On a homogeneous paper fleet every scale is 1.0 and the
    /// result is identical to the old flat-leakage accounting; on a
    /// big.LITTLE fleet the fast class's µJ premium finally shows up.
    pub fn fleet_energy(&self, em: &EnergyModel, freq_mhz: f64) -> EnergyBreakdown {
        per_device_energy(&self.per_device, self.makespan_cycles, em, freq_mhz)
    }
}

/// Shared fleet-energy evaluation over per-device metrics (used by both
/// the encoder fleet's [`FleetMetrics`] and the decode fleet's
/// metrics): Σ over devices of class-scaled dynamic energy plus
/// class-scaled leakage × makespan.
pub fn per_device_energy(
    per_device: &[DeviceMetrics],
    makespan_cycles: u64,
    em: &EnergyModel,
    freq_mhz: f64,
) -> EnergyBreakdown {
    let seconds = makespan_cycles as f64 / (freq_mhz * 1e6);
    let mut total = EnergyBreakdown::default();
    for d in per_device {
        let scaled = EnergyModel::new(em.params.scaled(d.dynamic_scale, 1.0));
        let mut e = scaled.evaluate(&d.stats, freq_mhz);
        e.leakage_pj = em.params.leakage_uw * d.leakage_scale * seconds * 1e6;
        total.accumulate(&e);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut h = LatencyHistogram::default();
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.p50(), 50);
        assert_eq!(h.p95(), 95);
        assert_eq!(h.p99(), 99);
        assert_eq!(h.percentile(100.0), 100);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut h = LatencyHistogram::default();
        h.record(42);
        assert_eq!(h.p50(), 42);
        assert_eq!(h.p99(), 42);
    }

    #[test]
    fn utilization_and_throughput() {
        let m = FleetMetrics {
            completed: 10,
            makespan_cycles: 1_000_000,
            per_device: vec![
                DeviceMetrics { served: 6, busy_cycles: 900_000, ..Default::default() },
                DeviceMetrics { served: 4, busy_cycles: 300_000, ..Default::default() },
            ],
            ..Default::default()
        };
        // 10 requests over 10 ms at 100 MHz = 1000 req/s.
        assert!((m.throughput_rps(100.0) - 1000.0).abs() < 1e-9);
        assert!((m.utilization(0) - 0.9).abs() < 1e-12);
        assert!((m.mean_utilization() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn batch_occupancy_mean_and_job_count() {
        let mut m = FleetMetrics::default();
        assert_eq!(m.mean_batch_occupancy(), 0.0);
        assert_eq!(m.batches(), 0);
        for occ in [1u64, 3, 4, 4] {
            m.batch_occupancy.record(occ);
        }
        assert_eq!(m.batches(), 4);
        assert!((m.mean_batch_occupancy() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn fleet_energy_applies_per_class_scales() {
        // One paper device + one 8x4@200-style device: leakage must be
        // (1.0 + 2.8)× the single-device figure, and the fast device's
        // dynamic energy must carry the V² factor.
        let em = EnergyModel::default();
        let stats = Stats { pe_macp: 1_000, ..Default::default() };
        let m = FleetMetrics {
            makespan_cycles: 1_000_000,
            per_device: vec![
                DeviceMetrics { stats: stats.clone(), ..Default::default() },
                DeviceMetrics {
                    stats: stats.clone(),
                    leakage_scale: 2.8,
                    dynamic_scale: 1.96,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        let e = m.fleet_energy(&em, 100.0);
        let seconds = 1_000_000.0 / (100.0 * 1e6);
        let per_dev_leak = em.params.leakage_uw * seconds * 1e6;
        assert!((e.leakage_pj - per_dev_leak * (1.0 + 2.8)).abs() < 1e-6);
        let base_compute = 1_000.0 * em.params.pe_macp_pj;
        assert!((e.compute_pj - base_compute * (1.0 + 1.96)).abs() < 1e-6);
    }

    #[test]
    fn fleet_leakage_scales_with_device_count() {
        let em = EnergyModel::default();
        let base = FleetMetrics {
            makespan_cycles: 1_000_000,
            per_device: vec![DeviceMetrics::default(); 2],
            ..Default::default()
        };
        let wide = FleetMetrics {
            per_device: vec![DeviceMetrics::default(); 8],
            ..base.clone()
        };
        let e2 = base.fleet_energy(&em, 100.0).leakage_pj;
        let e8 = wide.fleet_energy(&em, 100.0).leakage_pj;
        assert!((e8 / e2 - 4.0).abs() < 1e-9, "leakage must scale 4x: {e2} vs {e8}");
    }
}
