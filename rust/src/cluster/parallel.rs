//! Tile-level model parallelism: one GEMM split across several devices
//! on a **2D (i×j) shard grid**, heterogeneity-aware.
//!
//! A blocked GEMM's output blocks are independent, so `C = A·B` can be
//! carved into row bands of A × column bands of B and each block run
//! through the *unchanged* per-device pipeline — `plan` → `pack` →
//! `mapper` → simulate — on the sub-problem. Each output element is
//! still `requant(Σ a·b, shift)` over the full K reduction on one
//! device, so the merged result is **bit-identical** to the
//! single-device run (the acceptance check in the integration tests).
//!
//! ## Grid shape and heterogeneous sizing
//!
//! `D` devices form `ceil(sqrt(D))` row bands with the devices dealt
//! heaviest-first across the rows, so each grid row has comparable
//! aggregate throughput. Band sizes are proportional to **class
//! throughput** ([`crate::config::DeviceClass::throughput_weight`]:
//! peak MACs/cycle × clock): row bands to each grid row's aggregate
//! weight, column bands within a row to each device's weight — a
//! `8x4@200` shard gets ~4× the output area of a `4x4@100` shard, so
//! heterogeneous shards finish together instead of waiting on the
//! slowest. Identical devices degrade to the even split, and two
//! devices degrade to the classic row split.
//!
//! ## Broadcast traffic, accounted per replica
//!
//! Sharding is not free: every shard in a grid row re-reads that row's
//! A band, and every grid row re-reads all of B. The replicated
//! ext-memory words are accounted **per replica** (not once) in
//! [`ShardedGemmRun::broadcast_a_words`] / `broadcast_b_words` — the
//! scale-out bandwidth cost the ROADMAP's "model the broadcast
//! traffic" item called for. A pure row split (`D×1`) replicates only
//! B; a pure column split (`1×D`) replicates only A; the 2D grid
//! balances the two, which is exactly why it wins past ~4 devices.
//!
//! This is the paper's "scalable pathway" argument made concrete: scale
//! *out* with more arrays rather than *up* with a wider fabric (FIG5
//! shows columns stop paying past 4).

use crate::gemm::{run_gemm, GemmPlan, OutputMode};
use crate::sim::{CgraSim, SimOutcome};
use crate::util::mat::MatI8;
use anyhow::{ensure, Result};

/// One shard of a 2D-sharded GEMM: the output block one device computed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardShape {
    /// Index into the `sims` slice of the device that ran the shard.
    pub device: usize,
    /// Device clock in integer MHz (for wall-time makespan).
    pub freq_mhz: u64,
    /// First output row and row count of the block.
    pub i0: usize,
    pub mi: usize,
    /// First output column and column count of the block.
    pub j0: usize,
    pub nj: usize,
}

/// Result of a multi-device GEMM.
pub struct ShardedGemmRun {
    /// Merged requantized output, bit-identical to a single-device run.
    pub c: MatI8,
    /// Per-shard simulator outcomes (index-aligned with `shards`).
    pub outcomes: Vec<SimOutcome>,
    /// The output block each device computed.
    pub shards: Vec<ShardShape>,
    /// Grid actually used: (row bands, widest row's column shards).
    pub grid: (usize, usize),
    /// A-operand ext words fetched *beyond* the single copy a
    /// one-device run reads (each extra shard in a grid row re-reads
    /// the row's A band).
    pub broadcast_a_words: u64,
    /// B-operand ext words fetched beyond the single copy (each extra
    /// grid row re-reads all of B).
    pub broadcast_b_words: u64,
}

impl ShardedGemmRun {
    /// Makespan of the parallel execution in cycles: the slowest shard,
    /// counting its configuration time (each device configures
    /// independently). Directly comparable only on a uniform-clock
    /// fleet — use [`Self::parallel_ns`] when clocks differ.
    pub fn parallel_cycles(&self) -> u64 {
        self.outcomes.iter().map(|o| o.cycles + o.config_cycles).max().unwrap_or(0)
    }

    /// Makespan in nanoseconds: the slowest shard at its own clock —
    /// the finish-together figure of merit for heterogeneous fleets.
    pub fn parallel_ns(&self) -> u64 {
        self.outcomes
            .iter()
            .zip(&self.shards)
            .map(|(o, s)| (o.cycles + o.config_cycles) * 1_000 / s.freq_mhz.max(1))
            .max()
            .unwrap_or(0)
    }

    /// Total device-cycles spent (the energy-relevant sum).
    pub fn total_cycles(&self) -> u64 {
        self.outcomes.iter().map(|o| o.cycles + o.config_cycles).sum()
    }

    /// Ext-memory words crossed *because of* replication: operand words
    /// fetched beyond the single copy a one-device run would read.
    pub fn broadcast_ext_words(&self) -> u64 {
        self.broadcast_a_words + self.broadcast_b_words
    }
}

/// Split `total` units over `weights` proportionally (largest-remainder
/// apportionment, exact sum). While `total >= weights.len()`, every bin
/// gets at least one unit — a zero-width shard would idle its device.
/// Deterministic: remainder ties and donor picks break by index.
fn proportional_split(total: usize, weights: &[u64]) -> Vec<usize> {
    let n = weights.len();
    debug_assert!(n > 0);
    let wsum: u128 = weights.iter().map(|&w| u128::from(w)).sum::<u128>().max(1);
    let mut out = vec![0usize; n];
    let mut rems: Vec<(u128, usize)> = Vec::with_capacity(n);
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let exact = total as u128 * u128::from(w);
        out[i] = (exact / wsum) as usize;
        assigned += out[i];
        rems.push((exact % wsum, i));
    }
    // Hand the leftover units to the largest remainders, lowest index
    // first on ties. The floor sum leaves fewer than `n` units over.
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut left = total - assigned;
    for &(_, i) in &rems {
        if left == 0 {
            break;
        }
        out[i] += 1;
        left -= 1;
    }
    if total >= n {
        // Minimum-one fixup: move units from the fullest bins (ties to
        // the lowest index) into empty ones.
        loop {
            let Some(zi) = out.iter().position(|&v| v == 0) else { break };
            let donor = (0..n)
                .max_by_key(|&i| (out[i], std::cmp::Reverse(i)))
                .expect("non-empty weights");
            if out[donor] <= 1 {
                break;
            }
            out[donor] -= 1;
            out[zi] += 1;
        }
    }
    out
}

fn row_band(m: &MatI8, lo: usize, len: usize) -> MatI8 {
    MatI8::from_slice(len, m.cols, &m.data[lo * m.cols..(lo + len) * m.cols])
}

fn col_band(m: &MatI8, lo: usize, len: usize) -> MatI8 {
    let mut out = MatI8::zeros(m.rows, len);
    for r in 0..m.rows {
        for c in 0..len {
            *out.at_mut(r, c) = m.at(r, lo + c);
        }
    }
    out
}

/// Throughput weight of one device: peak MACs/cycle × integer clock —
/// the same figure [`crate::config::DeviceClass::throughput_weight`]
/// reports for its class.
fn device_weight(sim: &CgraSim) -> u64 {
    sim.cfg.peak_macs_per_cycle() * sim.cfg.freq_mhz_u64()
}

/// Run `C = A·B` (requantized with `shift`) across the given devices on
/// a throughput-weighted 2D shard grid. With one device this degrades
/// to a plain [`run_gemm`]; identical devices get an even split. Each
/// shard re-plans its sub-problem against its *own* device config, so
/// mixed-geometry fleets work out of the box and the merge is
/// bit-identical to a single-device run.
pub fn run_gemm_sharded(
    sims: &mut [CgraSim],
    a: &MatI8,
    b: &MatI8,
    shift: u8,
) -> Result<ShardedGemmRun> {
    ensure!(!sims.is_empty(), "need at least one device");
    ensure!(a.cols == b.rows, "inner dims must agree");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    ensure!(m > 0 && k > 0 && n > 0, "GEMM dims must be positive");
    let output = OutputMode::Quant { shift };
    let d_total = sims.len();

    // Grid shape: ceil(sqrt(D)) row bands (2 devices → the classic row
    // split, 4 → 2×2), capped by the row count; devices dealt to rows
    // heaviest-first so rows have comparable aggregate throughput.
    let gi = ((d_total as f64).sqrt().ceil() as usize).clamp(1, d_total.min(m));
    let mut order: Vec<usize> = (0..d_total).collect();
    order.sort_by_key(|&d| (std::cmp::Reverse(device_weight(&sims[d])), d));
    let mut rows_devs: Vec<Vec<usize>> = vec![Vec::new(); gi];
    for (pos, &d) in order.iter().enumerate() {
        rows_devs[pos % gi].push(d);
    }
    let row_weights: Vec<u64> = rows_devs
        .iter()
        .map(|ds| ds.iter().map(|&d| device_weight(&sims[d])).sum())
        .collect();
    let row_bands = proportional_split(m, &row_weights);

    // Build the shard list: row bands ∝ row aggregate weight, column
    // bands within a row ∝ device weight. Zero-width bands drop their
    // device (more devices offered than the problem can use).
    let mut shards: Vec<ShardShape> = Vec::new();
    let mut grid_cols_max = 0usize;
    let mut grid_rows = 0usize;
    let mut i0 = 0usize;
    for (r, devs) in rows_devs.iter().enumerate() {
        let mi = row_bands[r];
        if mi == 0 {
            continue;
        }
        let dw: Vec<u64> = devs.iter().map(|&d| device_weight(&sims[d])).collect();
        let col_bands = proportional_split(n, &dw);
        let mut j0 = 0usize;
        let mut cols_here = 0usize;
        for (q, &d) in devs.iter().enumerate() {
            let nj = col_bands[q];
            if nj == 0 {
                continue;
            }
            let freq_mhz = sims[d].cfg.freq_mhz_u64();
            shards.push(ShardShape { device: d, freq_mhz, i0, mi, j0, nj });
            j0 += nj;
            cols_here += 1;
        }
        grid_rows += 1;
        grid_cols_max = grid_cols_max.max(cols_here);
        i0 += mi;
    }
    debug_assert!(!shards.is_empty(), "a positive-size GEMM always yields a shard");

    // Broadcast accounting: ext words (4 packed int8 lanes per word)
    // each shard fetches for its operands, beyond the one logical copy
    // a single-device run reads. A band re-read by every shard of its
    // grid row; B re-read by every grid row. The whole-operand copy is
    // subtracted in *elements* first and the ÷4 word packing applied
    // once to the surplus — packing per shard before subtracting would
    // report a few phantom words whenever an odd band size leaves a
    // partially filled word (the ROADMAP rounding item).
    let words = |elems: u64| elems.div_ceil(4);
    let a_elems_total: u64 = shards.iter().map(|s| (s.mi * k) as u64).sum();
    let b_elems_total: u64 = shards.iter().map(|s| (k * s.nj) as u64).sum();
    let broadcast_a_words = words(a_elems_total.saturating_sub((m * k) as u64));
    let broadcast_b_words = words(b_elems_total.saturating_sub((k * n) as u64));

    let mut c = MatI8::zeros(m, n);
    let mut outcomes = Vec::with_capacity(shards.len());
    for s in &shards {
        let sub_a = row_band(a, s.i0, s.mi);
        let sub_b = col_band(b, s.j0, s.nj);
        let plan = GemmPlan::new(&sims[s.device].cfg, s.mi, k, s.nj, output)?;
        let run = run_gemm(&mut sims[s.device], &sub_a, &sub_b, &plan)?;
        let part = run.c_i8.expect("quant mode");
        for r in 0..s.mi {
            for j in 0..s.nj {
                *c.at_mut(s.i0 + r, s.j0 + j) = part.at(r, j);
            }
        }
        outcomes.push(run.outcome);
    }
    Ok(ShardedGemmRun {
        c,
        outcomes,
        shards,
        grid: (grid_rows, grid_cols_max),
        broadcast_a_words,
        broadcast_b_words,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, DeviceClass};
    use crate::gemm::oracle_quant;
    use crate::util::rng::XorShiftRng;

    fn fleet(n: usize) -> Vec<CgraSim> {
        (0..n).map(|_| CgraSim::new(ArchConfig::default())).collect()
    }

    fn random_mat(rng: &mut XorShiftRng, rows: usize, cols: usize) -> MatI8 {
        let mut m = MatI8::zeros(rows, cols);
        rng.fill_i8(&mut m.data, 12);
        m
    }

    #[test]
    fn proportional_split_is_exact_and_floor_protected() {
        assert_eq!(proportional_split(64, &[1, 1]), vec![32, 32]);
        assert_eq!(proportional_split(20, &[1, 3]), vec![5, 15]);
        // Largest remainder: 10 over 3:3:3 weights → 4,3,3.
        assert_eq!(proportional_split(10, &[3, 3, 3]), vec![4, 3, 3]);
        // A tiny weight still gets one unit while there is enough.
        assert_eq!(proportional_split(4, &[1000, 1, 1, 1]), vec![1, 1, 1, 1]);
        // Fewer units than bins: some bins legitimately get zero.
        let s = proportional_split(2, &[1, 1, 1, 1]);
        assert_eq!(s.iter().sum::<usize>(), 2);
        // Exact sum always.
        assert_eq!(proportional_split(97, &[7, 3, 5]).iter().sum::<usize>(), 97);
    }

    #[test]
    fn two_devices_row_split_matches_oracle() {
        let mut rng = XorShiftRng::new(0xC01);
        let (m, k, n) = (44, 16, 16);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = fleet(2);
        let run = run_gemm_sharded(&mut sims, &a, &b, 5).unwrap();
        assert_eq!(run.grid, (2, 1), "two equal devices form the classic row split");
        assert_eq!(run.shards.len(), 2);
        assert_eq!(run.shards[0].mi, 22);
        assert_eq!(run.shards[1].mi, 22);
        assert_eq!(run.c, oracle_quant(&a, &b, 5));
        // Row split: B is the replicated operand, A is not.
        assert_eq!(run.broadcast_a_words, 0);
        assert_eq!(run.broadcast_b_words, ((k * n) as u64).div_ceil(4));
    }

    #[test]
    fn four_devices_form_a_2d_grid() {
        let mut rng = XorShiftRng::new(0xC02);
        let (m, k, n) = (64, 24, 64);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = fleet(4);
        let run = run_gemm_sharded(&mut sims, &a, &b, 6).unwrap();
        assert_eq!(run.grid, (2, 2), "4 devices → 2×2 grid");
        assert_eq!(run.shards.len(), 4);
        assert_eq!(run.c, oracle_quant(&a, &b, 6));
        // 2×2: each operand is replicated once over.
        assert!(run.broadcast_a_words > 0);
        assert!(run.broadcast_b_words > 0);
        assert_eq!(run.broadcast_a_words, ((m * k) as u64).div_ceil(4));
    }

    #[test]
    fn broadcast_words_are_exact_across_odd_band_sizes() {
        // Sweep odd matrix sizes whose row split leaves partially
        // filled packed words. A pure 2-device row split replicates
        // only B: A must report exactly zero broadcast words (the old
        // per-shard packing reported phantom words here), and B must
        // report exactly one extra whole-operand copy.
        let mut rng = XorShiftRng::new(0xC06);
        for (m, k, n) in [(45usize, 7usize, 9usize), (33, 5, 11), (21, 13, 3), (9, 3, 5)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let mut sims = fleet(2);
            let run = run_gemm_sharded(&mut sims, &a, &b, 5).unwrap();
            assert_eq!(run.grid, (2, 1), "two equal devices row-split");
            assert_eq!(run.c, oracle_quant(&a, &b, 5));
            assert_eq!(
                run.broadcast_a_words, 0,
                "{m}x{k}x{n}: a partitioned operand has zero broadcast surplus"
            );
            assert_eq!(
                run.broadcast_b_words,
                ((k * n) as u64).div_ceil(4),
                "{m}x{k}x{n}: one extra whole-B copy, packed once"
            );
        }
        // A 1×2 column split (m = 1 caps the grid at one row band) is
        // the mirror image: B partitioned exactly — zero surplus even
        // though 23/22 column bands of 7 rows pack unevenly — and A
        // replicated once over.
        let (m, k, n) = (1usize, 7usize, 45usize);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = fleet(2);
        let run = run_gemm_sharded(&mut sims, &a, &b, 5).unwrap();
        assert_eq!(run.grid, (1, 2));
        assert_eq!(run.c, oracle_quant(&a, &b, 5));
        assert_eq!(run.broadcast_b_words, 0, "a partitioned B has zero broadcast surplus");
        assert_eq!(run.broadcast_a_words, ((m * k) as u64).div_ceil(4));
    }

    #[test]
    fn single_device_degrades_to_plain_run() {
        let mut rng = XorShiftRng::new(0xC03);
        let (m, k, n) = (32, 16, 32);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = fleet(1);
        let run = run_gemm_sharded(&mut sims, &a, &b, 6).unwrap();
        assert_eq!(run.grid, (1, 1));
        assert_eq!(run.shards.len(), 1);
        assert_eq!(run.broadcast_ext_words(), 0, "one device replicates nothing");
        assert_eq!(run.c, oracle_quant(&a, &b, 6));
    }

    #[test]
    fn heterogeneous_shards_sized_by_class_throughput() {
        // One paper device + one 8x4@200: the big device carries ~4× the
        // weight, so its output block must be decisively larger, and the
        // merge still matches the oracle bit-for-bit.
        let mut rng = XorShiftRng::new(0xC04);
        let (m, k, n) = (60, 16, 32);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = vec![
            CgraSim::new(ArchConfig::default()),
            CgraSim::new(DeviceClass::parse("8x4@200").unwrap().arch),
        ];
        let run = run_gemm_sharded(&mut sims, &a, &b, 6).unwrap();
        assert_eq!(run.c, oracle_quant(&a, &b, 6));
        let area = |s: &ShardShape| s.mi * s.nj;
        let small = run.shards.iter().find(|s| s.device == 0).expect("paper shard");
        let big = run.shards.iter().find(|s| s.device == 1).expect("big shard");
        assert!(
            area(big) >= 3 * area(small),
            "throughput-proportional sizing: {big:?} vs {small:?}"
        );
        assert_eq!(big.freq_mhz, 200);
    }

    #[test]
    fn more_devices_than_rows_still_merge_exactly() {
        // m = 2 caps the grid at 2 row bands; 5 devices spread over the
        // columns instead, some possibly dropped.
        let mut rng = XorShiftRng::new(0xC05);
        let (m, k, n) = (2, 16, 40);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = fleet(5);
        let run = run_gemm_sharded(&mut sims, &a, &b, 6).unwrap();
        assert!(run.grid.0 <= 2);
        assert_eq!(run.c, oracle_quant(&a, &b, 6));
    }
}
