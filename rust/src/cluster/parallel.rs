//! Tile-level model parallelism: one GEMM split across several devices.
//!
//! A blocked GEMM's output tiles are independent, so the tile grid the
//! planner already produces ([`GemmPlan::n_it`] × [`GemmPlan::n_jt`])
//! is a ready-made sharding map: give each device a contiguous band of
//! i-tiles (rows of A / C) or j-tiles (columns of B / C) and run the
//! *unchanged* per-device pipeline — `plan` → `pack` → `mapper` →
//! simulate — on the sub-problem. Each output element is still
//! `requant(Σ a·b, shift)` over the full K reduction on one device, so
//! the merged result is **bit-identical** to the single-device run (the
//! acceptance check in the integration tests).
//!
//! This is the paper's "scalable pathway" argument made concrete: scale
//! *out* with more arrays rather than *up* with a wider fabric (FIG5
//! shows columns stop paying past 4).

use crate::gemm::{run_gemm, GemmPlan, OutputMode};
use crate::sim::{CgraSim, SimOutcome};
use crate::util::mat::MatI8;
use anyhow::{ensure, Result};

/// Which tile axis a sharded run split on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitAxis {
    /// i-tile bands: each device gets a row band of A and all of B.
    Rows,
    /// j-tile bands: each device gets a column band of B and all of A.
    Cols,
    /// Problem had a single tile block (or one device): no split.
    None,
}

/// Result of a multi-device GEMM.
pub struct ShardedGemmRun {
    /// Merged requantized output, bit-identical to a single-device run.
    pub c: MatI8,
    /// Per-shard simulator outcomes (index-aligned with the devices
    /// actually used; may be fewer than offered).
    pub outcomes: Vec<SimOutcome>,
    pub axis: SplitAxis,
}

impl ShardedGemmRun {
    /// Makespan of the parallel execution: the slowest shard, counting
    /// its configuration time (each device configures independently).
    pub fn parallel_cycles(&self) -> u64 {
        self.outcomes.iter().map(|o| o.cycles + o.config_cycles).max().unwrap_or(0)
    }

    /// Total device-cycles spent (the energy-relevant sum).
    pub fn total_cycles(&self) -> u64 {
        self.outcomes.iter().map(|o| o.cycles + o.config_cycles).sum()
    }
}

/// Split `tiles` tiles of size `tile` (covering `total` rows/cols) into
/// at most `devices` contiguous bands, as evenly as possible.
fn split_tiles(tiles: usize, tile: usize, total: usize, devices: usize) -> Vec<(usize, usize)> {
    let shards = devices.min(tiles).max(1);
    let per = tiles / shards;
    let rem = tiles % shards;
    let mut out = Vec::with_capacity(shards);
    let mut t0 = 0usize;
    for s in 0..shards {
        let nt = per + usize::from(s < rem);
        let lo = t0 * tile;
        let hi = ((t0 + nt) * tile).min(total);
        out.push((lo, hi - lo));
        t0 += nt;
    }
    out
}

fn row_band(m: &MatI8, lo: usize, len: usize) -> MatI8 {
    MatI8::from_slice(len, m.cols, &m.data[lo * m.cols..(lo + len) * m.cols])
}

fn col_band(m: &MatI8, lo: usize, len: usize) -> MatI8 {
    let mut out = MatI8::zeros(m.rows, len);
    for r in 0..m.rows {
        for c in 0..len {
            *out.at_mut(r, c) = m.at(r, lo + c);
        }
    }
    out
}

/// Run `C = A·B` (requantized with `shift`) across the given devices,
/// splitting the tile grid of the single-device plan. With one device —
/// or a single-tile problem — this degrades to a plain [`run_gemm`].
pub fn run_gemm_sharded(
    sims: &mut [CgraSim],
    a: &MatI8,
    b: &MatI8,
    shift: u8,
) -> Result<ShardedGemmRun> {
    ensure!(!sims.is_empty(), "need at least one device");
    ensure!(a.cols == b.rows, "inner dims must agree");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let output = OutputMode::Quant { shift };
    // The reference plan's tile grid decides the shard axis; each shard
    // then re-plans its own sub-problem through the unchanged planner.
    let ref_plan = GemmPlan::new(&sims[0].cfg, m, k, n, output)?;
    let mt = 4 * ref_plan.rows;
    let nt = 4 * ref_plan.pe_cols;

    let mut c = MatI8::zeros(m, n);
    let mut outcomes = Vec::new();
    let axis = if sims.len() >= 2 && ref_plan.n_it >= 2 {
        for (d, (lo, len)) in split_tiles(ref_plan.n_it, mt, m, sims.len()).into_iter().enumerate()
        {
            let sub_a = row_band(a, lo, len);
            let plan = GemmPlan::new(&sims[d].cfg, len, k, n, output)?;
            let run = run_gemm(&mut sims[d], &sub_a, b, &plan)?;
            let part = run.c_i8.expect("quant mode");
            c.data[lo * n..(lo + len) * n].copy_from_slice(&part.data);
            outcomes.push(run.outcome);
        }
        SplitAxis::Rows
    } else if sims.len() >= 2 && ref_plan.n_jt >= 2 {
        for (d, (lo, len)) in split_tiles(ref_plan.n_jt, nt, n, sims.len()).into_iter().enumerate()
        {
            let sub_b = col_band(b, lo, len);
            let plan = GemmPlan::new(&sims[d].cfg, m, k, len, output)?;
            let run = run_gemm(&mut sims[d], a, &sub_b, &plan)?;
            let part = run.c_i8.expect("quant mode");
            for r in 0..m {
                for j in 0..len {
                    *c.at_mut(r, lo + j) = part.at(r, j);
                }
            }
            outcomes.push(run.outcome);
        }
        SplitAxis::Cols
    } else {
        let run = run_gemm(&mut sims[0], a, b, &ref_plan)?;
        c = run.c_i8.expect("quant mode");
        outcomes.push(run.outcome);
        SplitAxis::None
    };
    Ok(ShardedGemmRun { c, outcomes, axis })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::gemm::oracle_quant;
    use crate::util::rng::XorShiftRng;

    fn fleet(n: usize) -> Vec<CgraSim> {
        (0..n).map(|_| CgraSim::new(ArchConfig::default())).collect()
    }

    fn random_mat(rng: &mut XorShiftRng, rows: usize, cols: usize) -> MatI8 {
        let mut m = MatI8::zeros(rows, cols);
        rng.fill_i8(&mut m.data, 12);
        m
    }

    #[test]
    fn split_tiles_covers_exactly() {
        assert_eq!(split_tiles(4, 16, 64, 2), vec![(0, 32), (32, 32)]);
        assert_eq!(split_tiles(3, 16, 48, 2), vec![(0, 32), (32, 16)]);
        // Ragged final tile: 2 tiles of 16 covering 20 rows.
        assert_eq!(split_tiles(2, 16, 20, 2), vec![(0, 16), (16, 4)]);
        // More devices than tiles: only `tiles` shards.
        assert_eq!(split_tiles(2, 16, 32, 8), vec![(0, 16), (16, 16)]);
    }

    #[test]
    fn column_split_matches_oracle() {
        // m = 16: a single i-tile forces the j-tile split path.
        let mut rng = XorShiftRng::new(0xC01);
        let (m, k, n) = (16, 24, 64);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = fleet(2);
        let run = run_gemm_sharded(&mut sims, &a, &b, 6).unwrap();
        assert_eq!(run.axis, SplitAxis::Cols);
        assert_eq!(run.outcomes.len(), 2);
        assert_eq!(run.c, oracle_quant(&a, &b, 6));
    }

    #[test]
    fn single_device_degrades_to_plain_run() {
        let mut rng = XorShiftRng::new(0xC02);
        let (m, k, n) = (32, 16, 32);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = fleet(1);
        let run = run_gemm_sharded(&mut sims, &a, &b, 6).unwrap();
        assert_eq!(run.axis, SplitAxis::None);
        assert_eq!(run.outcomes.len(), 1);
        assert_eq!(run.c, oracle_quant(&a, &b, 6));
    }

    #[test]
    fn ragged_row_split_matches_oracle() {
        // 3 i-tiles over 44 rows across 2 devices: uneven bands, last
        // one ragged.
        let mut rng = XorShiftRng::new(0xC03);
        let (m, k, n) = (44, 16, 16);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut sims = fleet(2);
        let run = run_gemm_sharded(&mut sims, &a, &b, 5).unwrap();
        assert_eq!(run.axis, SplitAxis::Rows);
        assert_eq!(run.c, oracle_quant(&a, &b, 5));
    }
}
