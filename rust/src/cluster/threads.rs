//! Shared machinery for the deterministic threaded fleet backends
//! (ISSUE 8): shard partitioning and per-shard observation buffers.
//!
//! Both threaded executors — the decoupled encoder backend (whole-run
//! shard threads) and the lockstep epoch backend (per-epoch worker
//! scopes) — follow the same discipline: workers never touch shared
//! mutable state. Every observation a worker would have written to the
//! fleet's [`Observer`] is buffered in a [`ShardObs`] tagged with its
//! position in the *reference* event order, and the coordinator
//! replays the buffers into the one true observer:
//!
//! - lockstep: drained at each epoch barrier in shard order — shards
//!   are contiguous ascending device ranges, so shard order *is*
//!   ascending device order, the order the reference loop visits ready
//!   devices in;
//! - decoupled: merged once at end-of-run by stable sort on the tag
//!   `(cycle, phase, order, seq)`, where `order` is the global arrival
//!   index for admission events and the device index for serve events
//!   — exactly the (admit arrivals in `(arrival, id)` order, then
//!   serve ready devices ascending) structure of every reference
//!   epoch.
//!
//! Because the replayed stream reaches the observer in the same order
//! the single-threaded loop would have produced it, the rendered trace
//! JSON and windowed series CSV are byte-identical — the property
//! `tests/calendar_props.rs` pins for `threads ∈ {2, 3, 8}`.

use crate::obs::{EventKind, ObsEvent, ObsSink, Observer};
use crate::sim::Stats;
use std::ops::Range;

/// Admission events (dispatcher placement) sort before serve events
/// within an epoch, mirroring the reference loop's phase order.
pub const PHASE_ARRIVE: u8 = 0;
/// Device-serve events; `order` is the global device index.
pub const PHASE_SERVE: u8 = 1;

/// Partition `devices` into at most `threads` contiguous shards of
/// near-equal size (the first `devices % shards` shards take one
/// extra). Contiguity is load-bearing: concatenating shard results in
/// shard order yields ascending device order, the reference visit
/// order. More threads than devices degrades to one device per shard.
pub fn shard_ranges(devices: usize, threads: usize) -> Vec<Range<usize>> {
    let shards = threads.min(devices).max(1);
    let base = devices / shards;
    let extra = devices % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// One buffered observation with its reference-order tag.
#[derive(Debug)]
pub struct TaggedObs {
    /// `(cycle, phase, order, seq)`: the event's position in the
    /// reference emission order (see the module docs).
    pub key: (u64, u8, u64, u32),
    pub payload: ObsPayload,
}

#[derive(Debug)]
pub enum ObsPayload {
    Event(ObsEvent),
    Kernel(String, &'static str, Stats),
}

/// A worker-side [`ObsSink`]: records nothing when the fleet observer
/// is disabled (so the threaded hot path stays as cheap as the
/// single-threaded one), buffers tagged events otherwise.
#[derive(Debug)]
pub struct ShardObs {
    enabled: bool,
    kernels: bool,
    pub buf: Vec<TaggedObs>,
    ctx: (u64, u8, u64),
    seq: u32,
}

impl ShardObs {
    /// A buffer mirroring the enablement of the fleet's observer.
    pub fn mirroring(obs: &Observer) -> Self {
        Self {
            enabled: obs.enabled(),
            kernels: obs.kernels_on(),
            buf: Vec::new(),
            ctx: (0, 0, 0),
            seq: 0,
        }
    }

    /// Set the reference-order context for subsequent records: the
    /// epoch cycle, the phase, and the within-phase order (global
    /// arrival index or device index). Resets the intra-context
    /// sequence counter.
    pub fn set_ctx(&mut self, now: u64, phase: u8, order: u64) {
        self.ctx = (now, phase, order);
        self.seq = 0;
    }

    fn tag(&mut self) -> (u64, u8, u64, u32) {
        let key = (self.ctx.0, self.ctx.1, self.ctx.2, self.seq);
        self.seq += 1;
        key
    }
}

impl ObsSink for ShardObs {
    #[inline]
    fn enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    fn kernels_on(&self) -> bool {
        self.kernels
    }

    #[inline]
    fn record(&mut self, cycle: u64, device: usize, seq: u64, kind: EventKind) {
        if self.enabled {
            let key = self.tag();
            self.buf.push(TaggedObs {
                key,
                payload: ObsPayload::Event(ObsEvent { cycle, device, seq, kind }),
            });
        }
    }

    #[inline]
    fn kernel(&mut self, label: String, phase: &'static str, stats: Stats) {
        if self.kernels {
            let key = self.tag();
            self.buf.push(TaggedObs { key, payload: ObsPayload::Kernel(label, phase, stats) });
        }
    }
}

/// Replay buffered observations into the real observer in the order
/// given (the caller has already established reference order — by
/// shard concatenation for lockstep, by [`merge_replay`] for
/// decoupled). Feeding `Observer::record` here is what rebuilds the
/// windowed series identically: the series folds events in arrival
/// order, so replaying in reference order reproduces its bytes.
pub fn replay_into(obs: &mut Observer, buf: impl IntoIterator<Item = TaggedObs>) {
    for t in buf {
        match t.payload {
            ObsPayload::Event(e) => obs.record(e.cycle, e.device, e.seq, e.kind),
            ObsPayload::Kernel(label, phase, stats) => obs.kernel(label, phase, stats),
        }
    }
}

/// Merge whole-run shard buffers into reference order and replay
/// (decoupled backend). The tag sort is total across shards: `order`
/// (arrival index / device index) belongs to exactly one shard, so no
/// two shards produce colliding keys.
pub fn merge_replay(obs: &mut Observer, shards: impl IntoIterator<Item = Vec<TaggedObs>>) {
    let mut all: Vec<TaggedObs> = shards.into_iter().flatten().collect();
    all.sort_by_key(|t| t.key);
    replay_into(obs, all);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_contiguously() {
        for devices in [1usize, 2, 3, 7, 8, 64, 255] {
            for threads in [1usize, 2, 3, 8, 300] {
                let ranges = shard_ranges(devices, threads);
                assert_eq!(ranges.len(), threads.min(devices));
                assert_eq!(ranges[0].start, 0);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start, "shards must tile");
                    // Near-equal: earlier shards are never smaller.
                    assert!(w[0].len() >= w[1].len());
                    assert!(w[0].len() - w[1].len() <= 1);
                }
                assert_eq!(ranges.last().unwrap().end, devices);
            }
        }
    }

    #[test]
    fn shard_obs_tags_in_context_order() {
        let obs = Observer::new(
            &crate::obs::ObsConfig::full(100),
            vec!["d0".into(), "d1".into()],
        );
        let mut shard = ShardObs::mirroring(&obs);
        shard.set_ctx(10, PHASE_SERVE, 1);
        shard.record(10, 1, 7, EventKind::Arrival { model: 0 });
        shard.record(10, 1, 7, EventKind::QueueDepth { depth: 2 });
        shard.set_ctx(10, PHASE_ARRIVE, 0);
        shard.record(10, 0, 3, EventKind::Arrival { model: 1 });
        let mut keys: Vec<_> = shard.buf.iter().map(|t| t.key).collect();
        keys.sort_unstable();
        assert_eq!(
            keys,
            vec![(10, PHASE_ARRIVE, 0, 0), (10, PHASE_SERVE, 1, 0), (10, PHASE_SERVE, 1, 1)],
            "arrival phase sorts first; intra-context order by seq"
        );
    }

    #[test]
    fn disabled_shard_obs_buffers_nothing() {
        let mut shard = ShardObs::mirroring(&Observer::disabled());
        shard.record(1, 0, 0, EventKind::Arrival { model: 0 });
        shard.kernel("k".into(), "encoder", Stats::default());
        assert!(shard.buf.is_empty());
    }
}
