//! Multi-device fleet serving: the scale-out layer above one CGRA.
//!
//! The paper positions the 4×4 array as a scalable pathway for edge
//! transformer inference; a real deployment runs *fleets* of such
//! accelerators behind a dispatcher. This subsystem is a deterministic
//! discrete-event simulator of exactly that:
//!
//! - [`workload`] — reproducible request streams: Poisson / bursty
//!   on-off / diurnal-ramp arrival processes over a model-class mix,
//!   all drawn from one [`crate::util::rng::XorShiftRng`] seed.
//! - [`dispatch`] — the [`Dispatcher`]: pluggable placement policies
//!   (round-robin, least-loaded, shortest-expected-job via a per-model
//!   cycle-cost cache pre-seeded from the analytic cycle model), queue
//!   disciplines (FIFO, priority tiers, earliest-deadline-first with
//!   drop-on-SLA-miss), and [`BatchPolicy`] same-model coalescing at
//!   pop time.
//! - [`fleet`] — [`DeviceEngine`] (one simulator + serving clock; the
//!   engine the single-device [`crate::coordinator`] adapts) and
//!   [`FleetSim`], the N-device event loop. With batching on, a freed
//!   device serves its coalesced batch as one stacked encoder job
//!   (true batch GEMM: weights streamed once per layer), bit-identical
//!   per request to unbatched serving.
//! - [`metrics`] — [`FleetMetrics`] with exact p50/p95/p99 latency
//!   percentiles ([`LatencyHistogram`], shared with the coordinator's
//!   `ServeMetrics`), per-device utilization, SLA-miss / drop counts,
//!   batch occupancy, weight-reuse words, and fleet energy (idle
//!   devices still leak).
//! - [`parallel`] — tile-level model parallelism: one large GEMM's
//!   i-/j-tile grid split across ≥2 devices with bit-identical merged
//!   output, reusing `gemm::plan`/`mapper` unchanged.
//!
//! Everything is accounted in simulated cycles, so fleet experiments
//! are reproducible from a printed seed and frequency-scalable, like
//! the rest of the cycle model.

pub mod dispatch;
pub mod fleet;
pub mod metrics;
pub mod parallel;
pub mod workload;

pub use dispatch::{BatchOutlook, BatchPolicy, Discipline, Dispatcher, Placement};
pub use fleet::{analytic_encoder_cycles, DeviceEngine, FleetConfig, FleetSim};
pub use metrics::{DeviceMetrics, FleetMetrics, LatencyHistogram};
pub use parallel::{run_gemm_sharded, ShardedGemmRun, SplitAxis};
pub use workload::{ArrivalProcess, FleetRequest, ModelClass, WorkloadGen};
