//! Multi-device fleet serving: the scale-out layer above one CGRA.
//!
//! The paper positions the 4×4 array as a scalable pathway for edge
//! transformer inference; a real deployment runs *fleets* of such
//! accelerators behind a dispatcher — and real fleets mix silicon
//! generations and array sizes (big.LITTLE style). This subsystem is a
//! deterministic discrete-event simulator of exactly that:
//!
//! - **Device classes** — a fleet is built from a roster of
//!   [`crate::config::DeviceClass`]es (`4x4@100`, `8x4@200`, …): array
//!   geometry, integer-MHz clock, and row-scaled memory provisioning.
//!   The fleet timeline runs on one reference clock; device cycles
//!   convert exactly ([`fleet::to_ref_cycles`]), so mixed-clock runs
//!   stay reproducible. PE columns cap at 4 (the FIG5 entry-link
//!   saturation); rows and clock are the scaling axes.
//! - [`workload`] — reproducible request streams: Poisson / bursty
//!   on-off / diurnal-ramp arrival processes over a model-class mix,
//!   all drawn from one [`crate::util::rng::XorShiftRng`] seed.
//! - [`dispatch`] — the [`Dispatcher`]: pluggable placement policies
//!   (round-robin, least-loaded, shortest-expected-job via a
//!   per-`(model, device-class)` cycle-cost cache pre-seeded from the
//!   analytic cycle model of each class's geometry, and model-affinity
//!   sticky routing), queue disciplines (FIFO, priority tiers,
//!   earliest-deadline-first with drop-on-SLA-miss), and
//!   [`BatchPolicy`] same-model coalescing at pop time — with an
//!   optional latency-aware hold budget derived from the head's
//!   deadline slack.
//! - [`fleet`] — [`DeviceEngine`] (one simulator + serving clock; the
//!   engine the single-device [`crate::coordinator`] adapts) and
//!   [`FleetSim`], the N-device event loop. With batching on, a freed
//!   device serves its coalesced batch as one stacked encoder job
//!   (true batch GEMM: weights streamed once per layer), bit-identical
//!   per request to unbatched serving. **Work-stealing** (on by
//!   default): an idle device pops a coalescible batch from the
//!   deepest queue whose owner is busy — deterministic thief/victim
//!   order, steals respect the batch policy and EDF expiry, and steal
//!   counts land in the metrics.
//! - [`metrics`] — [`FleetMetrics`] with p50/p95/p99 latency
//!   percentiles over mergeable log-bucket histograms
//!   ([`crate::obs::LogHistogram`]; the exact-sample
//!   [`LatencyHistogram`] remains the coordinator's `ServeMetrics`
//!   container and the conformance oracle), per-device utilization and
//!   steal counts, SLA-miss / drop counts, batch occupancy,
//!   weight-reuse words, and fleet energy (idle devices still leak).
//! - [`parallel`] — tile-level model parallelism: one large GEMM split
//!   over a 2D (i×j) shard grid, shards sized proportionally to each
//!   device's class throughput so heterogeneous shards finish
//!   together, with the replicated-operand broadcast traffic accounted
//!   per replica ([`ShardedGemmRun::broadcast_ext_words`]) and a merge
//!   that stays bit-identical to the single-device run.
//!
//! Everything is accounted in simulated cycles, so fleet experiments
//! are reproducible from a printed seed and frequency-scalable, like
//! the rest of the cycle model.

pub mod calendar;
pub mod dispatch;
pub mod fleet;
pub mod metrics;
pub mod parallel;
pub mod threads;
pub mod workload;

pub use crate::config::DeviceClass;
pub use calendar::WakeCalendar;
pub use dispatch::{
    BatchOutlook, BatchPolicy, Discipline, Dispatcher, OffsetQueues, Placement, PopScratch,
    QueueSource, ShardQueuesMut,
};
pub use threads::{shard_ranges, ShardObs};
pub use fleet::{
    analytic_encoder_cycles, analytic_encoder_ref_cycles, model_batch_key, to_ref_cycles,
    DeviceEngine, FleetConfig, FleetSim,
};
pub use crate::obs::LogHistogram;
pub use metrics::{per_device_energy, DeviceMetrics, FleetMetrics, LatencyHistogram};
pub use parallel::{run_gemm_sharded, ShardShape, ShardedGemmRun};
pub use workload::{ArrivalProcess, FleetRequest, GenProfile, GenRequest, ModelClass, WorkloadGen};
